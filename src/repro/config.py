"""Config registry: architectures (``--arch``), input shapes (``--shape``),
and per-cell parallelism rule overrides.

Each ``repro/configs/<id>.py`` exports ``CONFIG`` (the exact published
configuration from the assignment) and ``SMOKE`` (a reduced same-family
config for CPU tests). ``SHAPES`` are the four assigned input shapes;
applicability (e.g. ``long_500k`` needs sub-quadratic attention) is
encoded here and surfaced as SKIP rows in the roofline table.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.base import ArchConfig

ARCHS = (
    "rwkv6_1g6b", "stablelm_12b", "chatglm3_6b", "gemma3_1b",
    "starcoder2_3b", "dbrx_132b", "deepseek_v2_236b", "hymba_1g5b",
    "internvl2_1b", "whisper_base",
)

# canonical assignment ids → module names
ARCH_IDS = {
    "rwkv6-1.6b": "rwkv6_1g6b",
    "stablelm-12b": "stablelm_12b",
    "chatglm3-6b": "chatglm3_6b",
    "gemma3-1b": "gemma3_1b",
    "starcoder2-3b": "starcoder2_3b",
    "dbrx-132b": "dbrx_132b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "hymba-1.5b": "hymba_1g5b",
    "internvl2-1b": "internvl2_1b",
    "whisper-base": "whisper_base",
}


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

# long_500k requires sub-quadratic attention: run for SSM/hybrid/local-
# attention archs, skip pure full-attention archs (DESIGN.md §5).
LONG_CONTEXT_ARCHS = {"rwkv6_1g6b", "hymba_1g5b", "gemma3_1b"}


# ---------------------------------------------------------------------------
# Engine backend default (DESIGN.md §14)
#
# Which *lowering* of the plan IR `run_window_plan`/`run_scan_plan` pick
# when the caller passes backend=None: "tpu" (core/engine.py's
# sublane/lane tiling) or "gpu" (core/engine_gpu.py's warp-shuffle
# tiling). Distinct from jax.default_backend() — that is the device
# platform; this is which kernel *shape* we emit (the GPU lowering runs
# fine in interpret mode on CPU, which is how CI proves equivalence).

ENGINE_BACKENDS = ("tpu", "gpu")
ENGINE_BACKEND_ENV = "REPRO_ENGINE_BACKEND"
_ENGINE_BACKEND: str | None = None


def resolve_engine_backend(backend: str) -> str:
    """Normalize a user-facing backend name; ``auto`` follows the jax
    platform (GPU devices get the GPU lowering, everything else TPU)."""
    if backend == "auto":
        import jax

        return "gpu" if jax.default_backend() == "gpu" else "tpu"
    if backend not in ENGINE_BACKENDS:
        raise ValueError(
            f"unknown engine backend {backend!r}: expected one of "
            f"{ENGINE_BACKENDS + ('auto',)}")
    return backend


def engine_backend() -> str:
    """The session's default engine backend: ``set_engine_backend()`` if
    called, else ``$REPRO_ENGINE_BACKEND``, else ``auto``."""
    import os

    if _ENGINE_BACKEND is not None:
        return _ENGINE_BACKEND
    return resolve_engine_backend(os.environ.get(ENGINE_BACKEND_ENV, "auto"))


def set_engine_backend(backend: str | None) -> None:
    """Pin the process-wide default engine backend (``None`` restores the
    env/auto resolution)."""
    global _ENGINE_BACKEND
    _ENGINE_BACKEND = None if backend is None else resolve_engine_backend(backend)


# ---------------------------------------------------------------------------
# Failure policy (DESIGN.md §16)
#
# What a guarded ops.* dispatch does when an execution level fails:
# 'fallback' walks the degradation lattice (tuned → default → alternate
# strategy/backend → reference oracle), 'raise' surfaces a structured
# error naming the failing site. Same resolution order as the engine
# backend: session global → $REPRO_ON_FAILURE → default 'fallback'.

ON_FAILURE_MODES = ("fallback", "raise")
ON_FAILURE_ENV = "REPRO_ON_FAILURE"
CHECK_NUMERICS_ENV = "REPRO_CHECK_NUMERICS"
_ON_FAILURE: str | None = None
_CHECK_NUMERICS: bool | None = None


def resolve_on_failure(mode: str) -> str:
    if mode not in ON_FAILURE_MODES:
        raise ValueError(
            f"unknown on_failure mode {mode!r}: expected one of {ON_FAILURE_MODES}")
    return mode


def on_failure() -> str:
    """The session's failure policy: ``set_on_failure()`` if called, else
    ``$REPRO_ON_FAILURE``, else ``'fallback'``."""
    import os

    if _ON_FAILURE is not None:
        return _ON_FAILURE
    return resolve_on_failure(os.environ.get(ON_FAILURE_ENV, "fallback"))


def set_on_failure(mode: str | None) -> None:
    """Pin the process-wide failure policy (``None`` restores env/default)."""
    global _ON_FAILURE
    _ON_FAILURE = None if mode is None else resolve_on_failure(mode)


def check_numerics() -> bool:
    """Opt-in non-finite output detection on guarded dispatches:
    ``set_check_numerics()`` if called, else truthy ``$REPRO_CHECK_NUMERICS``."""
    import os

    if _CHECK_NUMERICS is not None:
        return _CHECK_NUMERICS
    env = os.environ.get(CHECK_NUMERICS_ENV, "")
    return bool(env) and env.lower() not in ("0", "false", "off")


def set_check_numerics(flag: bool | None) -> None:
    global _CHECK_NUMERICS
    _CHECK_NUMERICS = None if flag is None else bool(flag)


def normalize_arch(arch: str) -> str:
    arch = arch.replace("-", "_").replace(".", "g")
    return ARCH_IDS.get(arch, arch)


def get_config(arch: str, *, smoke: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{normalize_arch(arch)}")
    return mod.SMOKE if smoke else mod.CONFIG


def cell_applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skip) for an (arch × shape) cell."""
    arch = normalize_arch(arch)
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "SKIP(full-attn): 500k decode needs sub-quadratic attention"
    return True, ""


def all_cells():
    for arch in ARCHS:
        for shape in SHAPES:
            yield arch, shape


def active_param_count(cfg: ArchConfig) -> tuple[int, int]:
    """(total_params, active_params) — active excludes embeddings and
    counts MoE experts at top_k/n_experts utilization (MODEL_FLOPS = 6·N_active·D)."""
    from repro.models import build_model
    from repro.nn.spec import param_count

    model = build_model(cfg)
    total = param_count(model.specs())
    embed = cfg.vocab * cfg.d_model
    if not cfg.tie_embeddings:
        embed *= 2
    active = total - embed
    if cfg.moe:
        expert_total = 3 * cfg.n_experts * cfg.d_model * cfg.d_ff * cfg.n_layers
        active = active - expert_total + expert_total * cfg.top_k / cfg.n_experts
    return total, int(active)
