"""Decoder-only transformer covering the dense / MoE / MLA / VLM archs:
stablelm-12b, chatglm3-6b, gemma3-1b, starcoder2-3b, dbrx-132b,
deepseek-v2-236b, internvl2-1b (stub patch-embed prefix).

One scan-over-layers implementation; per-layer heterogeneity (gemma3's
5:1 local:global pattern, dual RoPE bases) rides through the scan as
traced per-layer scalars so the HLO stays O(1) in depth.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.nn import attention as attn
from repro.nn import layers as nnl
from repro.nn import moe as nnmoe
from repro.nn.spec import ParamSpec, stack_specs
from .base import (ArchConfig, TOKEN_AXES, cache_spec, chunked_cross_entropy,
                   remat, token_inputs)


def _stacked_token_write(cache, token_slices, index):
    """Write (L, B, 1, …) token slices into the (L, B, S, …) stacked cache
    at ``index`` (scalar, or (B,) per-slot) — in place under donation."""
    if jnp.ndim(index) == 0:
        start = (0, 0, index) + (0,) * (cache.ndim - 3)
        return jax.lax.dynamic_update_slice(cache, token_slices.astype(cache.dtype), start)

    def per_row(c, n, i):  # c: (L, S, …), n: (L, 1, …)
        return jax.lax.dynamic_update_slice(
            c, n, (0, i) + (0,) * (c.ndim - 2))

    return jax.vmap(per_row, in_axes=(1, 1, 0), out_axes=1)(
        cache, token_slices.astype(cache.dtype), index)


class Transformer:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        c = cfg
        self.attn_cfg = attn.AttnConfig(
            d_model=c.d_model, n_heads=c.n_heads, kv_heads=c.kv_heads,
            head_dim=c.head_dim, rope_base=c.rope_base,
            rot_dim=int(c.head_dim * c.rot_frac) // 2 * 2,
            bias=c.attn_bias, qk_norm=c.qk_norm, window=c.window,
            block_q=c.block_q, block_kv=c.block_kv,
            constrain_cache=c.constrain_cache)
        self.mla_cfg = attn.MLAConfig(
            d_model=c.d_model, n_heads=c.n_heads, q_lora=c.q_lora,
            kv_lora=c.kv_lora, qk_nope=c.qk_nope, qk_rope=c.qk_rope,
            v_head=c.v_head, rope_base=c.rope_base,
            block_q=c.block_q, block_kv=c.block_kv,
            constrain_cache=c.constrain_cache) if c.mla else None

    # ---- parameters -------------------------------------------------------
    def _norm_specs(self):
        c = self.cfg
        if c.norm == "layernorm":
            return nnl.layernorm_specs(c.d_model)
        return nnl.rmsnorm_specs(c.d_model, plus_one=(c.norm == "rmsnorm_p1"))

    def _norm(self, p, x):
        c = self.cfg
        if c.norm == "layernorm":
            return nnl.layernorm_apply(p, x)
        return nnl.rmsnorm_apply(p, x, plus_one=(c.norm == "rmsnorm_p1"))

    def layer_specs(self) -> dict:
        c = self.cfg
        s: dict[str, Any] = {"norm_attn": self._norm_specs(),
                             "norm_mlp": self._norm_specs()}
        if c.sandwich_norm:
            s["norm_attn_post"] = self._norm_specs()
            s["norm_mlp_post"] = self._norm_specs()
        if c.mla:
            s["attn"] = attn.mla_specs(self.mla_cfg)
        else:
            s["attn"] = attn.gqa_specs(c.d_model, c.n_heads, c.kv_heads,
                                       c.head_dim, bias=c.attn_bias,
                                       qk_norm=c.qk_norm)
        if c.moe:
            s["ffn"] = nnmoe.moe_specs(c.d_model, c.d_ff, c.n_experts,
                                       n_shared=c.n_shared, shared_ff=c.d_ff)
        elif c.mlp.startswith("gated"):
            s["ffn"] = nnl.gated_mlp_specs(c.d_model, c.d_ff)
        else:
            s["ffn"] = nnl.mlp_specs(c.d_model, c.d_ff, bias=c.attn_bias)
        return s

    def specs(self) -> dict:
        c = self.cfg
        s = {
            "embed": nnl.embedding_specs(c.vocab, c.d_model),
            "layers": stack_specs(self.layer_specs(), c.n_layers),
            "norm_f": self._norm_specs(),
        }
        if not c.tie_embeddings:
            s["lm_head"] = {"w": ParamSpec((c.d_model, c.vocab),
                                           ("embed", "vocab"))}
        if c.n_prefix:
            s["prefix_proj"] = {"w": ParamSpec((c.d_model, c.d_model),
                                               ("embed", None))}
        if c.pos_emb == "learned":
            s["pos_embed"] = {"table": ParamSpec((32768, c.d_model),
                                                 (None, "embed"), init="small")}
        return s

    # ---- inputs ------------------------------------------------------------
    def train_inputs(self, batch: int, seq: int):
        inp = token_inputs(batch, seq)
        axes = dict(TOKEN_AXES)
        if self.cfg.n_prefix:
            inp["prefix_embeds"] = jax.ShapeDtypeStruct(
                (batch, self.cfg.n_prefix, self.cfg.d_model), self.cfg.param_dtype)
            axes["prefix_embeds"] = ("batch", "seq", "embed")
        return inp, axes

    # ---- forward -----------------------------------------------------------
    def _ffn(self, p, x):
        c = self.cfg
        if c.moe:
            return nnmoe.moe_apply(
                p, x, top_k=c.top_k, capacity_factor=c.capacity_factor,
                norm_gates=True, act="silu")
        if c.mlp == "gated_silu":
            return nnl.gated_mlp_apply(p, x, act="silu"), 0.0
        if c.mlp == "gated_gelu":
            return nnl.gated_mlp_apply(p, x, act="gelu"), 0.0
        return nnl.mlp_apply(p, x, act="gelu"), 0.0

    def _layer(self, p, x, *, positions, is_global, cache=None,
               cache_index=None, write_through=True):
        c = self.cfg
        h = self._norm(p["norm_attn"], x)
        base = c.rope_base
        if c.rope_base_global is not None:
            base = jnp.where(is_global, c.rope_base_global, c.rope_base)
        if c.mla:
            a, new_cache = attn.mla_apply(p["attn"], h, self.mla_cfg,
                                          positions=positions, cache=cache,
                                          cache_index=cache_index,
                                          write_through=write_through)
        else:
            a, new_cache = attn.gqa_apply(p["attn"], h, self.attn_cfg,
                                          positions=positions,
                                          is_global=is_global, rope_base=base,
                                          cache=cache, cache_index=cache_index,
                                          write_through=write_through)
        if c.sandwich_norm:
            a = self._norm(p["norm_attn_post"], a)
        x = x + a
        h = self._norm(p["norm_mlp"], x)
        f, aux = self._ffn(p["ffn"], h)
        if c.sandwich_norm:
            f = self._norm(p["norm_mlp_post"], f)
        return x + f, aux, new_cache

    def _embed(self, params, batch):
        c = self.cfg
        x = nnl.embedding_apply(params["embed"], batch["tokens"])
        x = x.astype(c.param_dtype)
        if c.emb_scale:
            x = x * jnp.sqrt(jnp.float32(c.d_model)).astype(x.dtype)
        if c.n_prefix and "prefix_embeds" in batch:
            pre = batch["prefix_embeds"].astype(x.dtype)
            pre = pre @ params["prefix_proj"]["w"].astype(x.dtype)
            x = jnp.concatenate([pre, x], axis=1)
        if c.pos_emb == "learned":
            S = x.shape[1]
            x = x + params["pos_embed"]["table"][:S].astype(x.dtype)
        return x

    def forward(self, params, batch):
        """Full-sequence forward → (final hidden, aux_loss)."""
        c = self.cfg
        x = self._embed(params, batch)
        x = constrain(x, ("batch", "seq", "embed"))
        S = x.shape[1]
        positions = jnp.arange(S)
        is_global = self.is_global_arr()

        def body(carry, layer):
            xx, aux = carry
            p_i, g_i = layer
            xx = constrain(xx, ("batch", "seq", "embed"))
            y, a, _ = self._layer(p_i, xx, positions=positions, is_global=g_i)
            return (y, aux + a), None

        body_fn = remat(body, c.remat)
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0)),
                                   (params["layers"], is_global))
        x = self._norm(params["norm_f"], x)
        return x, aux / c.n_layers

    def is_global_arr(self):
        return self.cfg.is_global_layers()

    def loss(self, params, batch):
        c = self.cfg
        x, aux = self.forward(params, batch)
        if c.n_prefix:
            x = x[:, c.n_prefix:]
        table = (params["embed"]["table"] if c.tie_embeddings
                 else params["lm_head"]["w"].T)
        ce = chunked_cross_entropy(x, table, batch["labels"],
                                   chunk=c.loss_chunk)
        return ce + c.aux_loss_weight * aux

    def prefill_logits(self, params, batch):
        """Inference prefill: full forward, last-position logits."""
        c = self.cfg
        x, _ = self.forward(params, batch)
        table = (params["embed"]["table"] if c.tie_embeddings
                 else params["lm_head"]["w"].T)
        return (x[:, -1] @ table.T.astype(x.dtype)).astype(jnp.float32)

    # ---- decode ------------------------------------------------------------
    def decode_state_specs(self, batch: int, cache_len: int) -> dict:
        c = self.cfg
        if c.mla:
            axes = ("layers", "batch", "cache_seq", "lora")
            return {
                "c_kv": ParamSpec((c.n_layers, batch, cache_len, c.kv_lora),
                                  axes, init="zeros", dtype=c.param_dtype),
                "k_rope": ParamSpec((c.n_layers, batch, cache_len, c.qk_rope),
                                    axes, init="zeros", dtype=c.param_dtype),
            }
        return cache_spec(c.n_layers, batch, cache_len, c.kv_heads,
                          c.head_dim, c.param_dtype)

    def serve_step(self, params, state, tokens, index):
        """One decode step. tokens (B, 1) int32; index: scalar position.

        Returns (logits (B, V), new_state)."""
        c = self.cfg
        x = nnl.embedding_apply(params["embed"], tokens).astype(c.param_dtype)
        if c.emb_scale:
            x = x * jnp.sqrt(jnp.float32(c.d_model)).astype(x.dtype)
        if c.pos_emb == "learned":
            x = x + jnp.take(params["pos_embed"]["table"],
                             jnp.atleast_1d(index), axis=0).astype(x.dtype)
        positions = (jnp.array([0]) + index if jnp.ndim(index) == 0
                     else index[:, None])
        is_global = self.is_global_arr()

        wt = not c.decode_write_outside

        def body(xx, layer):
            p_i, g_i, cache_i = layer
            y, _, new_cache = self._layer(
                p_i, xx, positions=positions, is_global=g_i,
                cache=cache_i, cache_index=index, write_through=wt)
            return y, new_cache

        x, new_state = jax.lax.scan(body, x, (params["layers"], is_global, state))
        if c.decode_write_outside:
            # ONE stacked in-place token write per step (§Perf cell A):
            # scan emitted (L, B, 1, …) token slices, not full caches.
            new_state = {
                key: _stacked_token_write(state[key], new_state[key], index)
                for key in state
            }
            if c.constrain_cache:
                from repro.distributed.sharding import constrain
                ax = {"k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                      "v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                      "c_kv": ("layers", "batch", "cache_seq", "lora"),
                      "k_rope": ("layers", "batch", "cache_seq", "lora")}
                new_state = {key: constrain(val, ax[key])
                             for key, val in new_state.items()}
        x = self._norm(params["norm_f"], x)
        table = (params["embed"]["table"] if c.tie_embeddings
                 else params["lm_head"]["w"].T)
        logits = (x[:, 0] @ table.T.astype(x.dtype)).astype(jnp.float32)
        return logits, new_state
