"""Hymba — hybrid-head architecture: attention and Mamba heads in parallel
within every layer (arXiv:2411.13676), sliding-window attention with a few
global layers (first / middle / last).

The Mamba branch runs the SSAM conv1d + linear-recurrence plans
(DESIGN.md §5). Decode state = O(1) SSM state + windowed KV cache, which
is why this arch runs the ``long_500k`` cell.

Simplifications vs the paper, recorded here per DESIGN.md §7: meta tokens
and cross-layer KV sharing are omitted; the two branch outputs are
mean-combined after per-branch normalization (the paper's β-weighted
variant is a learned scalar — we keep the learned scalars).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.nn import attention as attn
from repro.nn import layers as nnl
from repro.nn import ssm
from repro.nn.spec import ParamSpec, stack_specs
from .base import (ArchConfig, TOKEN_AXES, cache_spec, chunked_cross_entropy,
                   remat, token_inputs)


class Hymba:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.attn_cfg = attn.AttnConfig(
            d_model=cfg.d_model, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
            head_dim=cfg.head_dim, rope_base=cfg.rope_base,
            window=cfg.window, block_q=cfg.block_q, block_kv=cfg.block_kv,
            constrain_cache=cfg.constrain_cache)

    def layer_specs(self) -> dict:
        c = self.cfg
        return {
            "norm_mix": nnl.rmsnorm_specs(c.d_model),
            "norm_mlp": nnl.rmsnorm_specs(c.d_model),
            "attn": attn.gqa_specs(c.d_model, c.n_heads, c.kv_heads, c.head_dim),
            "mamba": ssm.mamba_specs(c.d_model, d_inner=c.d_inner,
                                     ssm_state=c.ssm_state, conv_k=c.conv_k),
            "beta_attn": ParamSpec((c.d_model,), ("embed",), init="ones"),
            "beta_mamba": ParamSpec((c.d_model,), ("embed",), init="ones"),
            "ffn": nnl.gated_mlp_specs(c.d_model, c.d_ff),
        }

    def specs(self) -> dict:
        c = self.cfg
        return {
            "embed": nnl.embedding_specs(c.vocab, c.d_model),
            "layers": stack_specs(self.layer_specs(), c.n_layers),
            "norm_f": nnl.rmsnorm_specs(c.d_model),
        }

    def train_inputs(self, batch: int, seq: int):
        return token_inputs(batch, seq), dict(TOKEN_AXES)

    def _layer(self, p, x, *, positions, is_global, attn_cache=None,
               mamba_state=None, cache_index=None, write_through=True):
        c = self.cfg
        h = nnl.rmsnorm_apply(p["norm_mix"], x)
        a, new_cache = attn.gqa_apply(p["attn"], h, self.attn_cfg,
                                      positions=positions, is_global=is_global,
                                      cache=attn_cache, cache_index=cache_index,
                                      write_through=write_through)
        m, new_mstate = ssm.mamba_apply(p["mamba"], h, ssm_state=c.ssm_state,
                                        conv_k=c.conv_k, state=mamba_state,
                                        work_dtype=jnp.dtype(c.scan_dtype),
                                        scan_impl=c.scan_impl)
        # per-branch rescale then mean-combine (hybrid-head fusion)
        mix = 0.5 * (a * p["beta_attn"].astype(x.dtype)
                     + m * p["beta_mamba"].astype(x.dtype))
        x = x + mix
        h = nnl.rmsnorm_apply(p["norm_mlp"], x)
        x = x + nnl.gated_mlp_apply(p["ffn"], h, act="silu")
        return x, new_cache, new_mstate

    def forward(self, params, batch):
        c = self.cfg
        x = nnl.embedding_apply(params["embed"], batch["tokens"]).astype(c.param_dtype)
        x = constrain(x, ("batch", "seq", "embed"))
        positions = jnp.arange(x.shape[1])
        is_global = c.is_global_layers()

        def body(xx, layer):
            p_i, g_i = layer
            xx = constrain(xx, ("batch", "seq", "embed"))
            y, _, _ = self._layer(p_i, xx, positions=positions, is_global=g_i)
            return y, None

        x, _ = jax.lax.scan(remat(body, c.remat), x, (params["layers"], is_global))
        return nnl.rmsnorm_apply(params["norm_f"], x), jnp.float32(0)

    def loss(self, params, batch):
        x, _ = self.forward(params, batch)
        return chunked_cross_entropy(x, params["embed"]["table"],
                                     batch["labels"], chunk=self.cfg.loss_chunk)

    def prefill_logits(self, params, batch):
        x, _ = self.forward(params, batch)
        return (x[:, -1] @ params["embed"]["table"].T.astype(x.dtype)).astype(jnp.float32)

    # ---- decode: windowed KV + O(1) SSM state ------------------------------
    def decode_state_specs(self, batch: int, cache_len: int) -> dict:
        c = self.cfg
        kv = cache_spec(c.n_layers, batch, cache_len, c.kv_heads, c.head_dim,
                        c.param_dtype)
        return {
            **kv,
            "h": ParamSpec((c.n_layers, batch, c.d_inner, c.ssm_state),
                           ("layers", "batch", "ff", "state"), init="zeros"),
            "conv": ParamSpec((c.n_layers, batch, c.conv_k - 1, c.d_inner),
                              ("layers", "batch", None, "ff"), init="zeros",
                              dtype=c.param_dtype),
        }

    def serve_step(self, params, state, tokens, index):
        c = self.cfg
        x = nnl.embedding_apply(params["embed"], tokens).astype(c.param_dtype)
        positions = (jnp.array([0]) + index if jnp.ndim(index) == 0
                     else index[:, None])
        is_global = c.is_global_layers()

        wt = not c.decode_write_outside

        def body(xx, layer):
            p_i, g_i, st_i = layer
            y, new_cache, new_m = self._layer(
                p_i, xx, positions=positions, is_global=g_i,
                attn_cache={"k": st_i["k"], "v": st_i["v"]},
                mamba_state={"h": st_i["h"], "conv": st_i["conv"]},
                cache_index=index, write_through=wt)
            return y, {**new_cache, **new_m}

        x, new_state = jax.lax.scan(body, x, (params["layers"], is_global, state))
        if c.decode_write_outside:
            from .transformer import _stacked_token_write
            for key in ("k", "v"):
                new_state[key] = _stacked_token_write(state[key],
                                                      new_state[key], index)
        x = nnl.rmsnorm_apply(params["norm_f"], x)
        logits = (x[:, 0] @ params["embed"]["table"].T.astype(x.dtype)).astype(jnp.float32)
        return logits, new_state
