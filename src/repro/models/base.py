"""Model base: ArchConfig, scan-over-layers helpers, chunked loss, caches.

Every architecture implements the same protocol:

* ``specs()``            — ParamSpec tree (drives init/sharding/dry-run),
* ``train_inputs(shape)`` — ShapeDtypeStruct stand-ins + logical axes,
* ``loss(params, batch)`` — scalar LM loss (jit/grad-able),
* ``decode_state_specs`` / ``init_decode_state`` — KV cache or recurrent
  state tree (ParamSpecs: shapes + logical axes, init zeros),
* ``prefill`` / ``serve_step`` — cache-filling and one-token decode.

Layer stacks run under ``jax.lax.scan`` over stacked parameters so the
HLO is O(1) in depth — required for tractable 512-device dry-run
compiles and standard practice at Megatron/MaxText scale.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn.spec import ParamSpec


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # attention
    rope_base: float = 1e4
    rot_frac: float = 1.0        # partial rotary (stablelm 0.25, chatglm 0.5)
    attn_bias: bool = False
    qk_norm: bool = False
    norm: str = "rmsnorm"        # rmsnorm | layernorm | rmsnorm_p1 (gemma)
    mlp: str = "gated_silu"      # gated_silu | gated_gelu | mlp_gelu
    sandwich_norm: bool = False  # gemma3 post-norms
    # local:global attention pattern
    window: int = 0              # 0 ⇒ all-global
    global_every: int = 0        # every Nth layer is global (gemma3: 6)
    global_layers: tuple[int, ...] = ()   # explicit global layers (hymba)
    rope_base_global: float | None = None
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    capacity_factor: float = 1.25
    # MLA (deepseek)
    mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head: int = 0
    # ssm / rwkv / hybrid
    ssm_state: int = 0
    d_inner: int = 0
    conv_k: int = 4
    head_k: int = 0
    head_v: int = 0
    wkv_chunk: int = 64
    # modality stubs
    n_prefix: int = 0            # VLM patches / enc-dec handled separately
    encoder_layers: int = 0      # whisper
    n_frames: int = 0            # whisper encoder frames (stub embeds)
    conv_frontend: bool = False  # whisper: real mel conv stem through the
    n_mels: int = 0              #   SSAM engine (2×conv k=3, stride 1/2)
    conv_strategy: str | None = None  # frontend lowering: None (auto) |
    #   "lanes" (VPU shift-fma) | "mxu" (im2row matmul, DESIGN.md §13)
    pos_emb: str = "rope"        # rope | learned
    # numerics / runtime
    tie_embeddings: bool = True
    emb_scale: bool = False      # gemma ×√d
    dtype: str = "float32"
    remat: bool = True
    block_q: int = 512
    block_kv: int = 1024
    # §Perf cell-A optimizations — default ON (bit-exact vs write-through,
    # proven by tests; 31.6× on the collective-bound decode cell):
    constrain_cache: bool = True    # re-pin decode-cache sharding in-scan
    decode_write_outside: bool = True   # one stacked cache write/step
    scan_dtype: str = "float32"     # §Perf: recurrence-chunk intermediate dtype
    # recurrence schedule: None → backend default (chunk-streamed engine
    # on TPU, XLA chunked scan elsewhere); or one of 'engine',
    # 'engine_unchunked', 'chunked' (DESIGN.md §12)
    scan_impl: str | None = None
    loss_chunk: int = 512
    aux_loss_weight: float = 0.01

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def is_global_layers(self) -> jnp.ndarray:
        """Bool (L,) — which layers use global (non-windowed) attention."""
        if self.window == 0:
            return jnp.ones((self.n_layers,), bool)
        idx = jnp.arange(self.n_layers)
        g = jnp.zeros((self.n_layers,), bool)
        if self.global_every:
            g = g | ((idx % self.global_every) == self.global_every - 1)
        for i in self.global_layers:
            g = g.at[i].set(True)
        return g


def token_inputs(batch: int, seq: int) -> dict:
    """Standard LM batch: tokens + next-token labels (ShapeDtypeStructs)."""
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }


TOKEN_AXES = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}


def chunked_cross_entropy(x, table, labels, *, chunk: int = 512,
                          emb_scale: float | None = None):
    """Mean next-token CE without materializing (B, S, V) logits.

    Scans over sequence chunks; with remat the backward recomputes each
    chunk's logits. ``table`` is the (V, d) embedding for tied readout.
    """
    B, S, d = x.shape
    V = table.shape[0]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (S + pad) // chunk
    xc = jnp.moveaxis(x.reshape(B, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    def step(acc, args):
        xx, ll = args
        logits = (xx @ table.T.astype(xx.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(ll, 0)[..., None], axis=-1)[..., 0]
        valid = (ll >= 0).astype(jnp.float32)
        loss = ((lse - tgt) * valid).sum()
        return (acc[0] + loss, acc[1] + valid.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)), (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def cache_spec(L: int, B: int, S: int, kv: int, hd: int, dtype) -> dict:
    """Stacked KV-cache spec tree with logical axes for sharding."""
    axes = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
    return {
        "k": ParamSpec((L, B, S, kv, hd), axes, init="zeros", dtype=dtype),
        "v": ParamSpec((L, B, S, kv, hd), axes, init="zeros", dtype=dtype),
    }


def remat(fn, enabled: bool):
    if not enabled:
        return fn
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
