"""RWKV6 "Finch" — attention-free LM with data-dependent decay.

The WKV recurrence is the SSAM linear-recurrence plan (DESIGN.md §5):
per-(head, k, v)-channel ``S_t = d_t·S_{t−1} + k_tᵀv_t`` executed by the
chunked form in :mod:`repro.nn.ssm` (production) and validated against
:func:`repro.kernels.ops.linear_recurrence` (the paper-faithful SSAM
kernel) in tests. Decode state is O(1) in sequence length — the reason
this arch runs the ``long_500k`` cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.nn import layers as nnl
from repro.nn import ssm
from repro.nn.spec import ParamSpec, stack_specs
from .base import (ArchConfig, TOKEN_AXES, chunked_cross_entropy, remat,
                   token_inputs)


class RWKV6:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        assert cfg.head_k and cfg.head_v and cfg.n_heads

    def layer_specs(self) -> dict:
        c = self.cfg
        return {
            "norm_tm": nnl.rmsnorm_specs(c.d_model),
            "norm_cm": nnl.rmsnorm_specs(c.d_model),
            "tm": ssm.rwkv6_timemix_specs(
                c.d_model, n_heads=c.n_heads, head_k=c.head_k, head_v=c.head_v),
            "cm": ssm.rwkv6_channelmix_specs(c.d_model, c.d_ff),
        }

    def specs(self) -> dict:
        c = self.cfg
        return {
            "embed": nnl.embedding_specs(c.vocab, c.d_model),
            "norm_in": nnl.rmsnorm_specs(c.d_model),
            "layers": stack_specs(self.layer_specs(), c.n_layers),
            "norm_f": nnl.rmsnorm_specs(c.d_model),
        }

    def train_inputs(self, batch: int, seq: int):
        return token_inputs(batch, seq), dict(TOKEN_AXES)

    def _layer(self, p, x, *, state=None):
        c = self.cfg
        tm_state = None if state is None else {"S": state["S"], "prev": state["prev_tm"]}
        cm_state = None if state is None else {"prev": state["prev_cm"]}
        h, tm_new = ssm.rwkv6_timemix_apply(
            p["tm"], nnl.rmsnorm_apply(p["norm_tm"], x),
            n_heads=c.n_heads, head_k=c.head_k, head_v=c.head_v,
            chunk=c.wkv_chunk, state=tm_state,
            work_dtype=jnp.dtype(c.scan_dtype), wkv_impl=c.scan_impl)
        x = x + h
        h, cm_new = ssm.rwkv6_channelmix_apply(
            p["cm"], nnl.rmsnorm_apply(p["norm_cm"], x), state=cm_state)
        x = x + h
        new_state = {"S": tm_new["S"], "prev_tm": tm_new["prev"],
                     "prev_cm": cm_new["prev"]}
        return x, new_state

    def forward(self, params, batch):
        c = self.cfg
        x = nnl.embedding_apply(params["embed"], batch["tokens"]).astype(c.param_dtype)
        x = nnl.rmsnorm_apply(params["norm_in"], x)
        x = constrain(x, ("batch", "seq", "embed"))

        def body(xx, p_i):
            xx = constrain(xx, ("batch", "seq", "embed"))
            y, _ = self._layer(p_i, xx)
            return y, None

        x, _ = jax.lax.scan(remat(body, c.remat), x, params["layers"])
        return nnl.rmsnorm_apply(params["norm_f"], x), jnp.float32(0)

    def loss(self, params, batch):
        x, _ = self.forward(params, batch)
        return chunked_cross_entropy(x, params["embed"]["table"],
                                     batch["labels"], chunk=self.cfg.loss_chunk)

    def prefill_logits(self, params, batch):
        x, _ = self.forward(params, batch)
        return (x[:, -1] @ params["embed"]["table"].T.astype(x.dtype)).astype(jnp.float32)

    # ---- decode: O(1) recurrent state -------------------------------------
    def prefill(self, params, tokens):
        """Whole-prompt prefill through the chunked scan plans.

        ``tokens`` is ``(B, L)`` int32 for fresh (zero-state) streams. Each
        layer's WKV recurrence runs once over the full prompt via
        :func:`repro.nn.ssm.wkv6_chunked` — the chunk-streamed engine
        schedule on TPU (DESIGN.md §12) — instead of L ``serve_step``
        calls. Returns ``(last-token logits, decode state)``; the state
        stacks layer-first, matching :meth:`decode_state_specs`.
        """
        c = self.cfg
        x = nnl.embedding_apply(params["embed"], tokens).astype(c.param_dtype)
        x = nnl.rmsnorm_apply(params["norm_in"], x)

        def body(xx, p_i):
            y, st = self._layer(p_i, xx)
            return y, st

        x, new_state = jax.lax.scan(body, x, params["layers"])
        x = nnl.rmsnorm_apply(params["norm_f"], x)
        logits = (x[:, -1] @ params["embed"]["table"].T.astype(x.dtype)).astype(jnp.float32)
        return logits, new_state

    def decode_state_specs(self, batch: int, cache_len: int) -> dict:
        """cache_len is irrelevant — state is O(1) (the long-context story)."""
        c = self.cfg
        return {
            "S": ParamSpec((c.n_layers, batch, c.n_heads, c.head_k, c.head_v),
                           ("layers", "batch", "heads", "head_dim", None),
                           init="zeros"),
            "prev_tm": ParamSpec((c.n_layers, batch, 1, c.d_model),
                                 ("layers", "batch", None, "embed"),
                                 init="zeros", dtype=c.param_dtype),
            "prev_cm": ParamSpec((c.n_layers, batch, 1, c.d_model),
                                 ("layers", "batch", None, "embed"),
                                 init="zeros", dtype=c.param_dtype),
        }

    def serve_step(self, params, state, tokens, index):
        c = self.cfg
        del index  # position-free architecture
        x = nnl.embedding_apply(params["embed"], tokens).astype(c.param_dtype)
        x = nnl.rmsnorm_apply(params["norm_in"], x)

        def body(xx, layer):
            p_i, st_i = layer
            y, new_st = self._layer(p_i, xx, state=st_i)
            return y, new_st

        x, new_state = jax.lax.scan(body, x, (params["layers"], state))
        x = nnl.rmsnorm_apply(params["norm_f"], x)
        logits = (x[:, 0] @ params["embed"]["table"].T.astype(x.dtype)).astype(jnp.float32)
        return logits, new_state
