"""Model factory: ArchConfig → model instance by family."""
from __future__ import annotations

from .base import ArchConfig


def build_model(cfg: ArchConfig):
    from .hymba import Hymba
    from .rwkv6 import RWKV6
    from .transformer import Transformer
    from .whisper import Whisper

    if cfg.family == "ssm":
        return RWKV6(cfg)
    if cfg.family == "hybrid":
        return Hymba(cfg)
    if cfg.family == "audio":
        return Whisper(cfg)
    # dense | moe | vlm all run on the Transformer
    return Transformer(cfg)
