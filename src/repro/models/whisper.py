"""Whisper-style encoder-decoder backbone (audio arch).

The modality frontend has two modes:

* **stub** (default, ``conv_frontend=False``): ``train_inputs`` provides
  precomputed frame embeddings (B, n_frames, d) — the conv layers live
  outside the measured backbone.
* **conv** (``conv_frontend=True``, ``n_mels`` set): the real Whisper
  mel stem — two k=3 convs + GELU, the second at stride 2 — executed
  through the SSAM engine's reduce-axes plan
  (:func:`repro.nn.layers.conv2d_apply`): the mel spectrogram is an
  NCHW batch ``(B, n_mels, 1, 2·n_frames)``, the mel→d_model channel
  mix is the plan's C_in reduction, and time rides the lane axis.

Encoder: bidirectional self-attention. Decoder: causal self-attention +
cross-attention to the encoder output. LayerNorm + biases + GELU MLP +
learned positions, per the original architecture.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.nn import attention as attn
from repro.nn import layers as nnl
from repro.nn.spec import ParamSpec, stack_specs
from .base import ArchConfig, chunked_cross_entropy, remat


class Whisper:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.attn_cfg = attn.AttnConfig(
            d_model=cfg.d_model, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
            head_dim=cfg.head_dim, bias=True, rot_dim=0,
            block_q=cfg.block_q, block_kv=cfg.block_kv)

    # ---- specs -------------------------------------------------------------
    def _xattn_specs(self):
        c = self.cfg
        return attn.gqa_specs(c.d_model, c.n_heads, c.kv_heads, c.head_dim,
                              bias=True)

    def enc_layer_specs(self) -> dict:
        c = self.cfg
        return {
            "norm_attn": nnl.layernorm_specs(c.d_model),
            "attn": self._xattn_specs(),
            "norm_mlp": nnl.layernorm_specs(c.d_model),
            "ffn": nnl.mlp_specs(c.d_model, c.d_ff, bias=True),
        }

    def dec_layer_specs(self) -> dict:
        s = self.enc_layer_specs()
        s["norm_xattn"] = nnl.layernorm_specs(self.cfg.d_model)
        s["xattn"] = self._xattn_specs()
        return s

    def frontend_specs(self) -> dict:
        c = self.cfg
        return {
            "conv1": nnl.conv2d_specs(c.n_mels, c.d_model, (1, 3)),
            "conv2": nnl.conv2d_specs(c.d_model, c.d_model, (1, 3)),
        }

    def specs(self) -> dict:
        c = self.cfg
        s = {
            "enc_pos": {"table": ParamSpec((c.n_frames, c.d_model),
                                           (None, "embed"), init="small")},
            "enc_layers": stack_specs(self.enc_layer_specs(), c.encoder_layers),
            "enc_norm": nnl.layernorm_specs(c.d_model),
            "embed": nnl.embedding_specs(c.vocab, c.d_model),
            "dec_pos": {"table": ParamSpec((32768, c.d_model),
                                           (None, "embed"), init="small")},
            "dec_layers": stack_specs(self.dec_layer_specs(), c.n_layers),
            "dec_norm": nnl.layernorm_specs(c.d_model),
        }
        if c.conv_frontend:
            s["frontend"] = self.frontend_specs()
        return s

    def train_inputs(self, batch: int, seq: int):
        c = self.cfg
        if c.conv_frontend:
            inp = {
                "mel": jax.ShapeDtypeStruct((batch, c.n_mels, 2 * c.n_frames),
                                            c.param_dtype),
                "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
                "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            }
            axes = {"mel": ("batch", None, "seq"),
                    "tokens": ("batch", "seq"), "labels": ("batch", "seq")}
            return inp, axes
        inp = {
            "frames": jax.ShapeDtypeStruct((batch, c.n_frames, c.d_model),
                                           c.param_dtype),
            "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
        axes = {"frames": ("batch", "seq", "embed"),
                "tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        return inp, axes

    # ---- conv frontend (SSAM engine) ----------------------------------------
    def frontend(self, p, mel, *, impl: str | None = None):
        """Real Whisper mel stem through the engine's reduce-axes plan.

        ``mel (B, n_mels, T)`` → frames ``(B, T//2, d_model)``: two k=3
        'same' convs with GELU, the second at stride 2 — each an NCHW
        minibatch ``(B, C, 1, T)`` through one engine ``pallas_call``
        (channel mix = the plan's C_in reduction, time on the lane axis).
        The bias+GELU of each conv is the kernel's fused *epilogue*
        (DESIGN.md §11) — the activation never round-trips HBM between
        the two engine calls — and the second conv's stride-2 lowers as
        an output-strided grid computing only every other time lane
        instead of the dense result a subsample would discard.
        ``impl=None`` trains on the engine path (conv2d_apply's default):
        the backward pass lowers through the adjoint plans of
        :mod:`repro.core.adjoint`, not the XLA oracle.
        """
        c = self.cfg
        x = mel[:, :, None, :]                       # (B, n_mels, 1, T)
        x = nnl.conv2d_apply(p["conv1"], x, impl=impl, activation="gelu",
                             strategy=c.conv_strategy)
        x = nnl.conv2d_apply(p["conv2"], x, stride=(1, 2), impl=impl,
                             activation="gelu", strategy=c.conv_strategy)
        return x[:, :, 0, :].transpose(0, 2, 1).astype(c.param_dtype)

    # ---- attention helpers --------------------------------------------------
    def _self_attn(self, p, x, positions, *, causal, cache=None, cache_index=None):
        """GQA without rope; bidirectional when causal=False (encoder)."""
        cfg = self.attn_cfg
        B, S, _ = x.shape
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype)) + p["bq"].astype(x.dtype)
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype)) + p["bk"].astype(x.dtype)
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype)) + p["bv"].astype(x.dtype)
        if cache is not None:
            k = attn._cache_write(cache["k"], k, cache_index)
            v = attn._cache_write(cache["v"], v, cache_index)
            kv_pos = jnp.arange(k.shape[1])
        else:
            kv_pos = positions
        q_pos = positions if causal else jnp.full_like(positions, 2**30)
        if cache is None and x.shape[1] > 1024:
            out = attn.mha_chunked(q, k, v, q_pos, kv_pos,
                                   window=jnp.iinfo(jnp.int32).max,
                                   is_global=True, block_q=self.attn_cfg.block_q,
                                   block_kv=self.attn_cfg.block_kv)
        else:
            out = attn.mha_direct(q, k, v, q_pos, kv_pos,
                                  window=jnp.iinfo(jnp.int32).max, is_global=True)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)) + p["bo"].astype(x.dtype)
        new_cache = {"k": k, "v": v} if cache is not None else None
        return y, new_cache

    def _cross_attn(self, p, x, enc, *, enc_kv=None):
        """Cross-attention; enc_kv (decode) holds precomputed K/V."""
        if enc_kv is None:
            k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"].astype(x.dtype)) + p["bk"].astype(x.dtype)
            v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"].astype(x.dtype)) + p["bv"].astype(x.dtype)
        else:
            k, v = enc_kv["xk"], enc_kv["xv"]
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype)) + p["bq"].astype(x.dtype)
        S = x.shape[1]
        q_pos = jnp.full((S,), 2**30)          # no causal constraint
        kv_pos = jnp.arange(k.shape[1])
        if S > 1024:
            out = attn.mha_chunked(q, k, v, q_pos, kv_pos,
                                   window=jnp.iinfo(jnp.int32).max,
                                   is_global=True, block_q=self.attn_cfg.block_q,
                                   block_kv=self.attn_cfg.block_kv)
        else:
            out = attn.mha_direct(q, k, v, q_pos, kv_pos,
                                  window=jnp.iinfo(jnp.int32).max, is_global=True)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)) + p["bo"].astype(x.dtype)

    # ---- forward ------------------------------------------------------------
    def encode(self, params, frames):
        c = self.cfg
        x = frames.astype(c.param_dtype) + params["enc_pos"]["table"].astype(c.param_dtype)
        positions = jnp.arange(x.shape[1])

        def body(xx, p_i):
            h = nnl.layernorm_apply(p_i["norm_attn"], xx)
            a, _ = self._self_attn(p_i["attn"], h, positions, causal=False)
            xx = xx + a
            h = nnl.layernorm_apply(p_i["norm_mlp"], xx)
            return xx + nnl.mlp_apply(p_i["ffn"], h, act="gelu"), None

        x, _ = jax.lax.scan(remat(body, c.remat), x, params["enc_layers"])
        return nnl.layernorm_apply(params["enc_norm"], x)

    def decode_train(self, params, enc, tokens):
        c = self.cfg
        S = tokens.shape[1]
        x = nnl.embedding_apply(params["embed"], tokens).astype(c.param_dtype)
        x = x + params["dec_pos"]["table"][:S].astype(c.param_dtype)
        positions = jnp.arange(S)

        def body(xx, p_i):
            h = nnl.layernorm_apply(p_i["norm_attn"], xx)
            a, _ = self._self_attn(p_i["attn"], h, positions, causal=True)
            xx = xx + a
            h = nnl.layernorm_apply(p_i["norm_xattn"], xx)
            xx = xx + self._cross_attn(p_i["xattn"], h, enc)
            h = nnl.layernorm_apply(p_i["norm_mlp"], xx)
            return xx + nnl.mlp_apply(p_i["ffn"], h, act="gelu"), None

        x, _ = jax.lax.scan(remat(body, c.remat), x, params["dec_layers"])
        return nnl.layernorm_apply(params["dec_norm"], x)

    def _frames(self, params, batch):
        """Encoder input: conv-frontend mel stem or the stub embeddings."""
        if self.cfg.conv_frontend:
            return self.frontend(params["frontend"], batch["mel"])
        return batch["frames"]

    def loss(self, params, batch):
        enc = self.encode(params, self._frames(params, batch))
        enc = constrain(enc, ("batch", "seq", "embed"))
        x = self.decode_train(params, enc, batch["tokens"])
        return chunked_cross_entropy(x, params["embed"]["table"],
                                     batch["labels"], chunk=self.cfg.loss_chunk)

    def prefill_logits(self, params, batch):
        enc = self.encode(params, self._frames(params, batch))
        x = self.decode_train(params, enc, batch["tokens"])
        return (x[:, -1] @ params["embed"]["table"].T.astype(x.dtype)).astype(jnp.float32)

    # ---- decode -------------------------------------------------------------
    def decode_state_specs(self, batch: int, cache_len: int) -> dict:
        c = self.cfg
        L, KV, hd = c.n_layers, c.kv_heads, c.head_dim
        axes = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
        xaxes = ("layers", "batch", "seq", "kv_heads", "head_dim")
        return {
            "k": ParamSpec((L, batch, cache_len, KV, hd), axes, init="zeros",
                           dtype=c.param_dtype),
            "v": ParamSpec((L, batch, cache_len, KV, hd), axes, init="zeros",
                           dtype=c.param_dtype),
            "xk": ParamSpec((L, batch, c.n_frames, KV, hd), xaxes, init="zeros",
                            dtype=c.param_dtype),
            "xv": ParamSpec((L, batch, c.n_frames, KV, hd), xaxes, init="zeros",
                            dtype=c.param_dtype),
        }

    def prime_cross_cache(self, params, enc):
        """Precompute per-layer cross K/V from the encoder output."""
        def per_layer(p_i):
            k = jnp.einsum("bsd,dhk->bshk", enc, p_i["xattn"]["wk"].astype(enc.dtype)) + p_i["xattn"]["bk"].astype(enc.dtype)
            v = jnp.einsum("bsd,dhk->bshk", enc, p_i["xattn"]["wv"].astype(enc.dtype)) + p_i["xattn"]["bv"].astype(enc.dtype)
            return k, v

        ks, vs = jax.vmap(per_layer)(params["dec_layers"])
        return ks, vs

    def serve_step(self, params, state, tokens, index):
        c = self.cfg
        x = nnl.embedding_apply(params["embed"], tokens).astype(c.param_dtype)
        x = x + jnp.take(params["dec_pos"]["table"],
                         jnp.atleast_1d(index), axis=0).astype(x.dtype)
        positions = (jnp.array([0]) + index if jnp.ndim(index) == 0
                     else index[:, None])

        def body(xx, layer):
            p_i, st_i = layer
            h = nnl.layernorm_apply(p_i["norm_attn"], xx)
            a, new_cache = self._self_attn(
                p_i["attn"], h, positions, causal=True,
                cache={"k": st_i["k"], "v": st_i["v"]}, cache_index=index)
            xx = xx + a
            h = nnl.layernorm_apply(p_i["norm_xattn"], xx)
            xx = xx + self._cross_attn(p_i["xattn"], h, None,
                                       enc_kv={"xk": st_i["xk"], "xv": st_i["xv"]})
            h = nnl.layernorm_apply(p_i["norm_mlp"], xx)
            xx = xx + nnl.mlp_apply(p_i["ffn"], h, act="gelu")
            return xx, {**new_cache, "xk": st_i["xk"], "xv": st_i["xv"]}

        x, new_state = jax.lax.scan(body, x, (params["dec_layers"], state))
        x = nnl.layernorm_apply(params["dec_norm"], x)
        logits = (x[:, 0] @ params["embed"]["table"].T.astype(x.dtype)).astype(jnp.float32)
        return logits, new_state
