"""The paper's §5 analytical performance model, parameterized by hardware.

Eq. 4:  L_reg  = M·N·(T_mad + T_smem_read + 2·T_reg) + (M−1)·T_shfl
        L_smem = M·N·(T_mad + 2·T_smem_read + 2·T_reg)
Eq. 5:  Dif_smem_reg = L_smem − L_reg = M·N·T_smem_read − (M−1)·T_shfl

plus the §5.3 halo-overhead analysis. Latency tables: the P100/V100 rows
are the paper's own micro-benchmarks (Table 2); the TPU v5e row re-maps
each term to its TPU analogue (DESIGN.md §2) — scratchpad→VMEM,
shuffle→VPU lane roll, registers→VREG — using engineering estimates
(cycles per VREG-wide op) that are clearly marked as estimates: they feed
the *relative* comparisons the paper makes, never absolute wall-time
claims. Roofline numbers (the graded perf metric) come from
:mod:`repro.core.rooflines`, not from this model.
"""
from __future__ import annotations

import dataclasses

from .plan import SystolicPlan


@dataclasses.dataclass(frozen=True)
class HardwareLatencies:
    """Per-warp (GPU) / per-VREG (TPU) op latencies in cycles.

    The two ``t_mxu_*`` terms price the DESIGN.md §13 im2row lowering:
    a matmul unit (MXU / tensor core) retires MACs far faster than the
    vector unit (``t_mxu_mac ≪ t_mad``), but every tap row of the
    im2row operand must first be *staged* — gathered as a shifted view
    into the matmul operand — at roughly a vector-copy per element
    (``t_mxu_stage``, overlappable with MXU issue). Defaults of 0
    mean "no matmul unit modeled" (the paper's P100/V100 rows predate
    the tensor-core formulation of arxiv 2603.00477).
    """

    name: str
    t_shfl: float        # partial-sum interconnect (shuffle / lane roll)
    t_mad: float         # fused multiply-add
    t_smem_read: float   # scratchpad read (shared memory / VMEM load)
    t_reg: float         # register file access
    t_gmem_read: float   # global/HBM read (coalesced, per warp-equivalent)
    t_mxu_mac: float = 0.0    # matmul-unit MAC, per VREG-row-normalized elem
    t_mxu_stage: float = 0.0  # im2row operand staging per tap row element


@dataclasses.dataclass(frozen=True)
class MachineModel(HardwareLatencies):
    """A :class:`HardwareLatencies` row plus the machine *geometry* the
    tuner and the §14 GPU lowering need: lane/warp widths (which tile
    shapes are natural), HBM bandwidth (the roofline denominator of
    :func:`repro.core.tuning.model_cost`), and the engine backend this
    row describes (``"tpu"`` or ``"gpu"`` — the dispatch key of
    :func:`machine_for`, NOT jax's device platform).

    On TPU a "warp" is the 8-sublane group of a VREG and ``lanes`` the
    128-lane minor axis; on GPU ``warp`` is the 32-thread shuffle scope
    of ``__shfl_up_sync`` and ``lanes`` the threads-per-block the engine
    tiles the minor axis with (4 warps — the CUDA-guide default block).
    """

    lanes: int = 128        # natural minor-axis tile width
    warp: int = 8           # shuffle scope: lanes reachable in one t_shfl
    hbm_gbps: float = 800.0  # memory-bound roofline denominator
    backend: str = "tpu"    # engine backend this row models


# Paper Table 2 (measured by the authors' micro-benchmarks).
P100 = HardwareLatencies("P100", t_shfl=33, t_mad=6, t_smem_read=33, t_reg=1, t_gmem_read=300)
V100 = HardwareLatencies("V100", t_shfl=22, t_mad=4, t_smem_read=27, t_reg=1, t_gmem_read=300)
# TPU v5e estimates (DESIGN.md §2): VPU lane roll ≈ 2 cyc, VPU FMA ≈ 1 cyc/VREG,
# VMEM load ≈ 8 cyc (deep-pipelined), VREG ≈ 0-cost operand, HBM ≈ 100s of cyc.
# MXU (§13): a 128×128 systolic MAC per cycle vs the VPU's 8×128 → ~1/16
# cyc per VREG-row-normalized MAC; staging a tap row into the im2row
# operand is a VPU copy, largely overlappable with MXU issue → ~0.7.
# With the 8-row alignment floor these put the lanes/mxu crossover
# around ~20 taps: 5/9-point stars stay on the VPU, 25/27-point boxes
# flip to the MXU — the shape dependence of arxiv 2406.08923.
TPU_V5E = MachineModel("TPUv5e", t_shfl=2, t_mad=1, t_smem_read=8,
                       t_reg=0, t_gmem_read=200,
                       t_mxu_mac=1 / 16, t_mxu_stage=0.7,
                       lanes=128, warp=8, hbm_gbps=819.0, backend="tpu")
# A100-shaped entry: scaled from the paper's measured V100 row along the
# Volta→Ampere deltas (shuffle and SMEM latency roughly halved, FMA
# issue unchanged, HBM2e ~1.94× V100's 900 GB/s) plus the tensor-core
# terms of the §13 im2row lowering (a 16×8×16 mma.sync retires ~8× the
# CUDA-core FMA rate → ~0.5 cyc per warp-normalized MAC; ldmatrix
# staging ~1 cyc/row, poorly overlapped vs the MXU's decoupled DMA).
# Estimates, clearly marked as such (arxiv 2406.08923's tuning study is
# the calibration target once a GPU runner exists) — they feed the
# *relative* rankings of the tuner, never absolute wall-time claims.
A100 = MachineModel("A100", t_shfl=11, t_mad=4, t_smem_read=19,
                    t_reg=1, t_gmem_read=290,
                    t_mxu_mac=0.5, t_mxu_stage=1.0,
                    lanes=128, warp=32, hbm_gbps=1555.0, backend="gpu")

#: Engine-backend → machine description consumed by ``model_cost`` and
#: the tuner's candidate enumeration. One entry per *backend*, not per
#: SKU — recalibration swaps the row, not the key.
MACHINES: dict[str, MachineModel] = {"tpu": TPU_V5E, "gpu": A100}


def machine_for(backend: str) -> MachineModel:
    """The :class:`MachineModel` for an engine backend (``tpu``/``gpu``)."""
    try:
        return MACHINES[backend]
    except KeyError:
        raise ValueError(
            f"no machine model for backend {backend!r}: known backends are "
            f"{sorted(MACHINES)} (register one in perfmodel.MACHINES)"
        ) from None


def l_smem(hw: HardwareLatencies, M: int, N: int) -> float:
    """Latency of one output element with scratchpad-cached data (§5.2)."""
    return M * N * (hw.t_mad + 2 * hw.t_smem_read + 2 * hw.t_reg)


def l_reg(hw: HardwareLatencies, M: int, N: int) -> float:
    """Eq. 4 — latency with SSAM register-cached data + (M−1) shuffles."""
    return M * N * (hw.t_mad + hw.t_smem_read + 2 * hw.t_reg) + (M - 1) * hw.t_shfl


def dif_smem_reg(hw: HardwareLatencies, M: int, N: int) -> float:
    """Eq. 5 — SSAM's per-output advantage. Paper: ≫ 0 for M,N ≥ 2."""
    return M * N * hw.t_smem_read - (M - 1) * hw.t_shfl


def avg_dif_lower_bound(hw: HardwareLatencies, plan: SystolicPlan) -> float:
    """§5.3 AvgDif lower bound — per-loaded-element advantage incl. halo cost."""
    M, N, P, C = plan.M, plan.N, plan.P, plan.C
    return (
        hw.t_smem_read
        - hw.t_gmem_read * (N / (N + P - 1) + M / plan.S)
        + P * M * N * hw.t_smem_read / (N + P - 1)
        - (M - 1) * hw.t_shfl
    )


def plan_cycles_per_window(hw: HardwareLatencies, plan: SystolicPlan) -> float:
    """Price an arbitrary plan: Σ taps·T_mad + Σ shifts·T_shfl per window
    step. Fused pipelines price as the sum of their stage schedules plus
    one VPU op per fused epilogue stage — the flop side of the §11 "summed
    flop terms, one load+store" account (the memory side lives in
    :func:`repro.core.tuning.model_cost`)."""
    mads = plan.mads_per_output_window()    # summed over stages when fused
    shifts = plan.shift_count()
    epi = plan.epilogue_op_count() * hw.t_mad
    return (plan.P * (mads * (hw.t_mad + hw.t_reg))
            + plan.P * shifts * hw.t_shfl + plan.P * epi)


def mxu_tap_rows(taps: int, align: int = 8) -> int:
    """Tap rows of the §13 im2row operand after fp32 sublane alignment:
    the engine zero-pads the tap dimension to ``8·k`` so the matmul
    operand is ``(8·k, lanes)``-tiled — padding is priced like real
    rows (the MXU retires them either way)."""
    return -(-taps // align) * align


def mxu_cycles_per_window(hw: HardwareLatencies, plan: SystolicPlan) -> float:
    """Price a windowed plan under the §13 MXU strategy.

    Per window step, each (alignment-padded) tap row costs one staged
    gather (``t_mxu_stage``) plus one MXU MAC (``t_mxu_mac``); there are
    no lane shifts (the shifted views are static crops) and epilogues
    stay on the VPU. Small footprints lose to padding (a 5-tap star
    pays for 8 rows); big tap sets amortize it — exactly the shape
    dependence arxiv 2406.08923 observes, and the flip the autotuner
    exists to catch. Fused chains stage each stage's own tap set.
    """
    stages = plan.stages or (plan,)
    rows = sum(mxu_tap_rows(s.mads_per_output_window()) for s in stages)
    epi = plan.epilogue_op_count() * hw.t_mad
    return plan.P * (rows * (hw.t_mxu_stage + hw.t_mxu_mac) + epi)
