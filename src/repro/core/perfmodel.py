"""The paper's §5 analytical performance model, parameterized by hardware.

Eq. 4:  L_reg  = M·N·(T_mad + T_smem_read + 2·T_reg) + (M−1)·T_shfl
        L_smem = M·N·(T_mad + 2·T_smem_read + 2·T_reg)
Eq. 5:  Dif_smem_reg = L_smem − L_reg = M·N·T_smem_read − (M−1)·T_shfl

plus the §5.3 halo-overhead analysis. Latency tables: the P100/V100 rows
are the paper's own micro-benchmarks (Table 2); the TPU v5e row re-maps
each term to its TPU analogue (DESIGN.md §2) — scratchpad→VMEM,
shuffle→VPU lane roll, registers→VREG — using engineering estimates
(cycles per VREG-wide op) that are clearly marked as estimates: they feed
the *relative* comparisons the paper makes, never absolute wall-time
claims. Roofline numbers (the graded perf metric) come from
:mod:`repro.core.rooflines`, not from this model.
"""
from __future__ import annotations

import dataclasses

from .plan import SystolicPlan


@dataclasses.dataclass(frozen=True)
class HardwareLatencies:
    """Per-warp (GPU) / per-VREG (TPU) op latencies in cycles."""

    name: str
    t_shfl: float        # partial-sum interconnect (shuffle / lane roll)
    t_mad: float         # fused multiply-add
    t_smem_read: float   # scratchpad read (shared memory / VMEM load)
    t_reg: float         # register file access
    t_gmem_read: float   # global/HBM read (coalesced, per warp-equivalent)


# Paper Table 2 (measured by the authors' micro-benchmarks).
P100 = HardwareLatencies("P100", t_shfl=33, t_mad=6, t_smem_read=33, t_reg=1, t_gmem_read=300)
V100 = HardwareLatencies("V100", t_shfl=22, t_mad=4, t_smem_read=27, t_reg=1, t_gmem_read=300)
# TPU v5e estimates (DESIGN.md §2): VPU lane roll ≈ 2 cyc, VPU FMA ≈ 1 cyc/VREG,
# VMEM load ≈ 8 cyc (deep-pipelined), VREG ≈ 0-cost operand, HBM ≈ 100s of cyc.
TPU_V5E = HardwareLatencies("TPUv5e", t_shfl=2, t_mad=1, t_smem_read=8, t_reg=0, t_gmem_read=200)


def l_smem(hw: HardwareLatencies, M: int, N: int) -> float:
    """Latency of one output element with scratchpad-cached data (§5.2)."""
    return M * N * (hw.t_mad + 2 * hw.t_smem_read + 2 * hw.t_reg)


def l_reg(hw: HardwareLatencies, M: int, N: int) -> float:
    """Eq. 4 — latency with SSAM register-cached data + (M−1) shuffles."""
    return M * N * (hw.t_mad + hw.t_smem_read + 2 * hw.t_reg) + (M - 1) * hw.t_shfl


def dif_smem_reg(hw: HardwareLatencies, M: int, N: int) -> float:
    """Eq. 5 — SSAM's per-output advantage. Paper: ≫ 0 for M,N ≥ 2."""
    return M * N * hw.t_smem_read - (M - 1) * hw.t_shfl


def avg_dif_lower_bound(hw: HardwareLatencies, plan: SystolicPlan) -> float:
    """§5.3 AvgDif lower bound — per-loaded-element advantage incl. halo cost."""
    M, N, P, C = plan.M, plan.N, plan.P, plan.C
    return (
        hw.t_smem_read
        - hw.t_gmem_read * (N / (N + P - 1) + M / plan.S)
        + P * M * N * hw.t_smem_read / (N + P - 1)
        - (M - 1) * hw.t_shfl
    )


def plan_cycles_per_window(hw: HardwareLatencies, plan: SystolicPlan) -> float:
    """Price an arbitrary plan: Σ taps·T_mad + Σ shifts·T_shfl per window
    step. Fused pipelines price as the sum of their stage schedules plus
    one VPU op per fused epilogue stage — the flop side of the §11 "summed
    flop terms, one load+store" account (the memory side lives in
    :func:`repro.core.tuning.model_cost`)."""
    mads = plan.mads_per_output_window()    # summed over stages when fused
    shifts = plan.shift_count()
    epi = plan.epilogue_op_count() * hw.t_mad
    return (plan.P * (mads * (hw.t_mad + hw.t_reg))
            + plan.P * shifts * hw.t_shfl + plan.P * epi)
