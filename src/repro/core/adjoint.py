"""Adjoint plans — symbolic transposition of systolic plans.

Every ``ops.*`` kernel is a *linear* operator in its data input (and,
for convs, in its coefficients), so its backward pass is itself a
regular memory-bound kernel of the same family — exactly the class the
SSAM model targets. This module derives those backward kernels
**symbolically, at the plan level**, so the whole backward pass lowers
through the same :func:`repro.core.engine.run_window_plan` /
:func:`repro.core.engine.run_scan_plan` engine (and the same sharded
halo-exchange layer) as the forward pass. Nothing re-derives gradients
numerically; a plan in, a plan out (DESIGN.md §10).

Derivation rules:

* **Windowed plans (backward-input)** — the forward computes
  ``y[o] = Σ_k xp[o + k] · c_k`` over the tap footprint ``k ∈ [0, ext)``
  with ``lead``/``trail`` origin padding. Its transpose is the same
  windowed form on the cotangent with the **point-reflected tap set**
  (``k → ext − 1 − k``, coefficients riding along) and the lead/trail
  halo geometry **swapped through the footprint**:
  ``lead' = ext − 1 − lead``, ``trail' = ext − 1 − trail``. A 'valid'
  conv (pads nothing, output shrinks) transposes to a 'full' conv (pads
  ``ext − 1`` on both sides, output grows back); a shape-preserving
  stencil/'same' conv transposes to a shape-preserving plan with lead
  and trail exchanged — which is why the sharded adjoint's ppermute
  pushes run in the reversed direction with no new collective code.
  For reduce plans (NCHW), the channel roles flip: the forward's
  ``C_out`` (out axis) becomes the adjoint's reduction and vice versa —
  plan-side this swaps ``out_axes``/``reduce_axes``; the runtime
  coefficient array is viewed with its out/reduce axes swapped.

* **Windowed plans (backward-weight)** — ``∂L/∂c_k = Σ_o g[o]·xp[o+k]``
  is a *correlation* of the padded input with the cotangent, expressed
  through the engine's reduce machinery with **batch and the spatial
  tiles as the reduction**: the grid sweeps batch × spatial output
  tiles as block-1 reduce iterates, each accumulating a filter-shaped
  partial into an fp32 VMEM scratch block
  (:func:`repro.core.engine.run_weight_grad_plan`). 'table' plans
  (stencils) have no runtime coefficients and no weight gradient.

* **Scan/recurrence plans** — the transpose of an inclusive scan is the
  time-reversed scan: ``(cumsum)ᵀ g = rev(cumsum(rev g))``. For the
  linear recurrence ``h_t = a_t·h_{t−1} + b_t`` the adjoint state obeys
  ``λ_t = g_t + a_{t+1}·λ_{t+1}`` — the same recurrence run backwards
  in time with the coefficients shifted one step
  (:func:`reversed_recurrence_coeffs`); then ``∂b = λ`` and
  ``∂a_t = λ_t · h_{t−1}``. Both lower through ``run_scan_plan`` on
  flipped operands — a time-reversed scan plan.

The adjoint of an adjoint is the original plan (taps reflect twice,
lead/trail swap twice) — asserted in tests as the basic sanity check of
the symbolic rules.

Backward lowerings are counted in :data:`BACKWARD_LOWERINGS` (plan kind
→ count) so tests and CI can *prove* a gradient went through the engine
rather than silently falling back to an XLA autodiff path.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp

from repro.obs import metrics as _metrics

from .plan import Step, SystolicPlan, Tap

# kind → number of backward lowerings dispatched through the engine.
# Incremented by the ops-layer custom_vjp rules at backward trace time;
# the gradcheck suite asserts these move, which is the acceptance proof
# that jax.grad(ops.*) runs on the plan engine.
#
# Since PR 9 this is an alias of the registry counter
# ``adjoint.backward_lowerings`` (repro.obs.metrics), so the counts show
# up in metrics snapshots; it is still a ``collections.Counter``
# subclass, and ``metrics.reset()`` clears it in place, so every
# existing ``BACKWARD_LOWERINGS[kind]`` / ``dict(...)`` consumer is
# unchanged.
BACKWARD_LOWERINGS = _metrics.counter("adjoint.backward_lowerings")


def record_lowering(kind: str) -> None:
    BACKWARD_LOWERINGS[kind] += 1


def reset_lowering_counts() -> None:
    BACKWARD_LOWERINGS.clear()


# ---------------------------------------------------------------------------
# Windowed plans: backward-input
# ---------------------------------------------------------------------------

def iter_tap_offsets(
    plan: SystolicPlan,
) -> Iterator[tuple[tuple[int, ...], tuple[int, ...]]]:
    """Yield ``(offset, coeff_id)`` per tap of a windowed plan.

    ``offset`` is the tap's read position relative to the output point's
    window origin, axes ordered like ``plan.exts`` (lane axis last). The
    lane coordinate is the cumulative partial-sum shift at the tap's
    step — the engine's roll schedule flattened back into footprint
    coordinates.
    """
    assert plan.combine == "fma", plan.combine
    cum = 0
    for step in plan.steps:
        assert not step.masked, "windowed plans carry no masked steps"
        cum += step.shift
        for tap in step.taps:
            if plan.ndim_spatial == 3:
                yield (tap.z_offset, tap.row_offset, cum), tap.coeff_id
            else:
                yield (tap.row_offset, cum), tap.coeff_id


def _steps_from_offsets(
    taps: list[tuple[tuple[int, ...], tuple[int, ...]]], M: int
) -> tuple[Step, ...]:
    """Regroup footprint-coordinate taps into the engine's column steps."""
    cols: dict[int, list] = {}
    for off, cid in taps:
        if len(off) == 3:
            z, row, col = off
        else:
            z, (row, col) = 0, off
        cols.setdefault(col, []).append((z, row, cid))
    steps = []
    for m in range(M):
        col_taps = tuple(
            Tap(row, cid, z_offset=z) for z, row, cid in sorted(
                cols.get(m, ()), key=lambda t: (t[0], t[1])))
        steps.append(Step(shift=1 if m > 0 else 0, taps=col_taps))
    return tuple(steps)


def input_adjoint_plan(plan: SystolicPlan) -> SystolicPlan:
    """The backward-input plan: point-reflected taps, swapped halo.

    ``run_window_plan(g, w̃, plan=input_adjoint_plan(p))`` computes
    ``∂L/∂x`` of ``y = run_window_plan(x, w, plan=p)`` given the
    cotangent ``g = ∂L/∂y`` — same engine, same block/variant knobs,
    autotuned under its own plan signature. For reduce plans, ``w̃`` is
    the forward coefficient array with its out/reduce axes swapped
    (``w.swapaxes(0, 1)`` for NCHW); dense/perlane plans otherwise
    reuse ``w`` unchanged because the reflection lives in the tap
    ``coeff_id``s, not the array.

    The adjoint transposes the *linear* part only: any epilogue is
    stripped (its VJP is an elementwise chain the ops layer recomputes
    from saved pre-activations, DESIGN.md §11.4). A fused pipeline
    transposes to the **reversed chain of stage adjoints** —
    ``(P_k ∘ … ∘ P_1)ᵀ = P_1ᵀ ∘ … ∘ P_kᵀ`` — which is itself a fused
    plan, so a purely linear chain differentiates through one fused
    backward kernel.
    """
    if plan.combine != "fma":
        raise ValueError(
            f"input_adjoint_plan wants a windowed plan, got combine="
            f"{plan.combine!r}; scan plans transpose to time-reversed "
            "scans (see reversed_recurrence_coeffs)")
    if any(v > 1 for v in plan.stride_per_axis()):
        raise ValueError(
            "the transpose of an output-strided plan is input-dilated, "
            "which is not a windowed plan; the ops layer dilates the "
            "cotangent and transposes the stride-free plan instead")
    if plan.stages:
        # stage strategies ride the replace below unchanged; a strategy
        # pinned only on the composite pushes down so the transposed
        # chain stays on the same lowering (an mxu forward transposes to
        # an mxu backward, DESIGN.md §13)
        from .fuse import fuse_plans
        return fuse_plans(*[
            input_adjoint_plan(dataclasses.replace(
                s, epilogue=(), strategy=s.strategy or plan.strategy))
            for s in reversed(plan.stages)])
    exts = plan.exts
    reflected = [
        (tuple(e - 1 - o for e, o in zip(exts, off)), cid)
        for off, cid in iter_tap_offsets(plan)
    ]
    lead, trail = plan.lead_trail()
    kind = plan.kind[4:] if plan.kind.startswith("adj_") else \
        "adj_" + plan.kind
    # all-zero pads normalize to None (the builders' default) so that
    # the adjoint of an adjoint is *identically* the original plan.
    norm = lambda t: t if any(t) else None
    return dataclasses.replace(
        plan,
        kind=kind,
        steps=_steps_from_offsets(reflected, plan.M),
        lead=norm(tuple(e - 1 - l for e, l in zip(exts, lead))),
        trail=norm(tuple(e - 1 - r for e, r in zip(exts, trail))),
        # channel roles flip: the forward's out axis is summed over in
        # the adjoint and its reduce axis is produced.
        reduce_axes=plan.out_axes,
        out_axes=plan.reduce_axes,
        epilogue=(),            # the adjoint is of the linear part only
    )


def adjoint_coeff_array(plan: SystolicPlan, w):
    """View the forward coefficient array in the adjoint plan's layout
    (out and reduce axes swapped); identity for plans without them."""
    if w is None or not (plan.out_axes or plan.reduce_axes):
        return w
    no, nr = plan.out_axes, plan.reduce_axes
    perm = tuple(range(no, no + nr)) + tuple(range(no)) + tuple(
        range(no + nr, w.ndim))
    return jnp.transpose(w, perm)


def fold_replicate_edges(plan: SystolicPlan, dxp):
    """Transpose of the edge clamp ``E``: fold halo bands onto the edges.

    A ``boundary='replicate'`` forward is ``y = V(E x)`` — the
    valid-mode plan ``V`` on the edge-extended input ``E x``, where
    ``E`` repeats row 0 ``lead`` times ahead of the domain and row
    ``N−1`` ``trail`` times behind it (per windowed axis). ``Eᵀ`` is a
    scatter-add back through that fan-out: every cotangent row that was
    *read from* a clamped copy accumulates onto the edge row it was
    copied from. Given ``dxp = Vᵀ g`` on the widened lattice
    (``N + lead + trail`` rows per axis), this folds, per axis, rows
    ``[0, lead]`` into the new first row and rows ``[lead+N−1, end)``
    into the new last row, returning the ``N``-row gradient.
    """
    lead, trail = plan.lead_trail()
    nd = dxp.ndim - plan.ndim_spatial
    for a, (l, r) in enumerate(zip(lead, trail)):
        if l == 0 and r == 0:
            continue
        ax = nd + a
        n = dxp.shape[ax] - l - r
        if n == 1:
            dxp = jnp.sum(dxp, axis=ax, keepdims=True)
            continue
        head = jnp.sum(jax.lax.slice_in_dim(dxp, 0, l + 1, axis=ax),
                       axis=ax, keepdims=True)
        tail = jnp.sum(jax.lax.slice_in_dim(dxp, l + n - 1, l + n + r,
                                            axis=ax), axis=ax, keepdims=True)
        mid = jax.lax.slice_in_dim(dxp, l + 1, l + n - 1, axis=ax)
        dxp = jnp.concatenate([head, mid, tail], axis=ax)
    return dxp


# ---------------------------------------------------------------------------
# Epilogues: the jnp replay and its VJP (DESIGN.md §11.4)
# ---------------------------------------------------------------------------

def apply_epilogue(plan: SystolicPlan, y, args):
    """Replay a plan's epilogue stages on ``y`` in plain jnp.

    This is the semantic reference of what the engine fuses in VMEM —
    used by the ``impl='xla'`` oracle path and, crucially, by the
    backward rules: an epilogue makes the op affine/nonlinear, so its
    VJP is this elementwise chain differentiated by JAX at the saved
    pre-activation (``jax.vjp(lambda z, a: apply_epilogue(plan, z, a),
    z, args)``), after which the remaining cotangent flows through the
    *linear* adjoint plan on the engine. Bias broadcasting follows the
    plan's layout: per-C_out ahead of the spatial axes for out-axes
    plans, per-lane (trailing axis) for perlane plans, scalar otherwise.
    """
    ai = 0
    for st in plan.epilogue:
        if st.op == "gelu":
            y = jax.nn.gelu(y, approximate=True)
        elif st.op == "silu":
            y = jax.nn.silu(y)
        elif st.op == "relu":
            y = jnp.maximum(y, 0)
        elif st.op == "scale":
            y = y * st.value
        elif st.op == "bias":
            b = args[ai].astype(y.dtype)
            ai += 1
            if plan.out_axes:
                b = b.reshape(b.shape + (1,) * plan.ndim_spatial)
            y = y + b
        elif st.op == "residual_add":
            y = y + args[ai].astype(y.dtype)
            ai += 1
        else:
            raise ValueError(st.op)
    return y


# ---------------------------------------------------------------------------
# Windowed plans: backward-weight
# ---------------------------------------------------------------------------

def weight_adjoint_plan(plan: SystolicPlan) -> SystolicPlan:
    """Descriptor plan for the backward-weight correlation.

    Carries the forward schedule under a ``wgrad_``-prefixed kind so the
    §5 tuner/sidecar keys it independently of the forward and the
    backward-input plan. The lowering itself
    (:func:`repro.core.engine.run_weight_grad_plan`) reads the grid
    extents off the operand shapes — batch and the cotangent's spatial
    tiles become the grid's reduce sweep, the filter footprint the
    accumulated output block.
    """
    if plan.coeff_mode == "table":
        raise ValueError(
            f"{plan.kind!r} has compile-time 'table' coefficients — no "
            "runtime coefficient array, hence no weight gradient")
    return dataclasses.replace(plan, kind="wgrad_" + plan.kind)


# ---------------------------------------------------------------------------
# Scan plans: time reversal
# ---------------------------------------------------------------------------

def time_reversed(x):
    """Reverse the systolic time (lane) axis — the data movement of a
    transposed scan plan (the Kogge–Stone schedule itself is symmetric)."""
    return jnp.flip(x, axis=-1)


def reversed_recurrence_coeffs(a):
    """Coefficients of the adjoint recurrence, *forward-time* layout.

    The adjoint state of ``h_t = a_t·h_{t−1} + b_t`` obeys
    ``λ_t = g_t + a_{t+1}·λ_{t+1}`` (``λ`` at the last step = ``g``
    there): the same affine recurrence run in reversed time with the
    ``a`` sequence shifted one step toward the past. Returns
    ``ā_t = a_{t+1}`` (identity 1 in the final slot); run
    ``λ = rev(linrec(rev(ā), rev(g)))`` through the scan engine.
    """
    return jnp.concatenate([a[..., 1:], jnp.ones_like(a[..., :1])], axis=-1)


def shifted_state(h, h0=None):
    """``h_{t−1}`` stream for ``∂a_t = λ_t·h_{t−1}``.

    ``h0`` is the carry entering the block (``(..., 1)``); ``None`` keeps
    the monolithic zero initial state. Under the chunk-streamed schedule
    (DESIGN.md §12) each chunk passes its carry-in so the first in-chunk
    coefficient gradient sees the true predecessor state.
    """
    if h0 is None:
        h0 = jnp.zeros_like(h[..., :1])
    return jnp.concatenate([h0.astype(h.dtype), h[..., :-1]], axis=-1)


def chunk_carry_cotangent(a, lam):
    """Cotangent of the chunk's carry-in state (DESIGN.md §12).

    With ``h_t = a_t·h_{t−1} + b_t`` inside a chunk seeded by carry
    ``h₋₁``, only the first step touches the carry, so
    ``∂L/∂h₋₁ = a₀·λ₀``. Under ``lax.scan`` over chunks this value flows
    backward as the next-older chunk's carry-out cotangent — the λ
    recurrence composes across chunks through the scan carry exactly as
    the forward transfer pairs compose forward.
    """
    return a[..., :1] * lam[..., :1]
