"""Plan→Pallas GPU lowering — the paper's own target (DESIGN.md §14).

The source paper is a *GPU* execution model: partial sums hop between
CUDA threads via ``__shfl_up_sync``, the register file is the cache, and
shared memory holds only what registers cannot. This module lowers the
unchanged :class:`repro.core.plan.SystolicPlan` IR onto that shape. The
lowering map (§14):

==============================  =======================================
plan-IR construct               GPU primitive
==============================  =======================================
``shift_psum`` lane roll        ``__shfl_up_sync`` within each 32-lane
                                warp + a shared-memory hand-off for the
                                lane that crosses the warp boundary
halo lead/trail geometry        shared-memory staging of the block
                                skirt (interior + halo loaded once)
accumulator / valid-lane crop   per-thread register accumulator arrays
``strategy='mxu'``              tensor-core im2row (the same
                                dialect-neutral ``dot_general`` as §13)
==============================  =======================================

**Emulation caveat (documented, by design):** the current JAX Pallas
GPU dialects (Triton, Mosaic-GPU) expose block-level array ops, not a
per-thread ``shfl_up`` intrinsic. :func:`warp_shift` therefore *models*
the shuffle as its exact semantic decomposition — an intra-warp roll
(the ``__shfl_up_sync`` picture) stitched to a previous-warp tail
hand-off (the SMEM picture), which composes to precisely
``jnp.roll(v, shift, axis=-1)``. That makes the GPU lowering **bitwise
equal** to the TPU lane roll for the same block geometry, which is what
lets interpret-mode CI prove backend equivalence on any host; on a real
CUDA build the same decomposition is what a Mosaic-GPU warpgroup
executes natively. Lane extents that are not a whole number of warps
fall back to the plain roll (same values, no warp decomposition).

Geometry (padding, overlapped BlockSpecs, grids, crops) is shared with
the TPU path through :func:`repro.core.engine._window_call` /
:func:`repro.core.engine._scan_call`, so the two backends cannot drift:
a backend contributes only its kernel body and scratch request.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.robust import faults as rfaults

from . import engine
from .plan import (EPILOGUE_OPERANDS, GPU_WARP_LANES, SystolicPlan,
                   chain_epilogue_operand_stages)
from .fuse import pipeline_coeff_count

try:  # pragma: no cover - import probe
    # Mosaic-GPU ships with jax's CUDA builds *and* provides a faithful
    # SMEM memory-space stand-in under interpret mode on CPU hosts.
    from jax.experimental.pallas import mosaic_gpu as plgpu

    HAS_MOSAIC_GPU = True

    def _smem(shape, dtype):
        return plgpu.SMEM(shape, dtype)

except ImportError:  # pragma: no cover - CPU-only wheels without mosaic
    from jax.experimental.pallas import tpu as _pltpu

    HAS_MOSAIC_GPU = False

    def _smem(shape, dtype):
        # Documented emulation: VMEM scratch stands in for SMEM so the
        # lowering still runs (interpret mode) when the GPU dialect is
        # absent from the wheel. Numerics are identical — scratch is a
        # staging copy either way.
        return _pltpu.VMEM(shape, dtype)


GPU_BLOCK_WARPS = 4      # CUDA-guide default block: 128 threads / 4 warps


def warp_shift(v: jnp.ndarray, shift: int,
               warp: int = GPU_WARP_LANES) -> jnp.ndarray:
    """Shift ``v`` along the lane (last) axis the way a GPU warp would.

    Decomposes ``shift = q·warp + r`` (``0 ≤ r < warp``): the
    ``q``-warp part is a whole-warp hand-off (warp *i*'s registers go to
    warp ``i+q`` — on hardware, a shared-memory exchange), and the
    ``r``-lane part is ``__shfl_up_sync(0xffffffff, x, r)`` inside each
    warp, with the ``r`` boundary lanes taking the previous warp's tail
    through shared memory. The composition is exactly
    ``jnp.roll(v, shift, axis=-1)`` — bitwise, it is a pure reindexing —
    which is the equivalence interpret-mode CI asserts
    (``tests/test_engine_gpu.py::TestWarpShift``).

    Negative ``shift`` (the shift_data variant pulls data *down*) maps
    to ``__shfl_down_sync`` the same way via Python's floor divmod.
    """
    if shift == 0:
        return v
    S = v.shape[-1]
    if S % warp:
        # No clean warp decomposition for a fractional-warp lane extent:
        # fall back to the plain roll (documented emulation, same values).
        return jnp.roll(v, shift, axis=-1)
    q, r = divmod(shift, warp)
    if q:
        v = jnp.roll(v, q * warp, axis=-1)      # whole-warp SMEM hand-off
    if r:
        w = v.reshape(v.shape[:-1] + (S // warp, warp))
        intra = jnp.roll(w, r, axis=-1)         # __shfl_up_sync(…, r)
        tail = jnp.roll(jnp.roll(w, 1, axis=-2), r, axis=-1)
        lane = jax.lax.broadcasted_iota(jnp.int32, w.shape, w.ndim - 1)
        # Lanes [0, r) fell off the shuffle's low edge: they take the
        # previous warp's top r registers (the SMEM boundary hand-off).
        v = jnp.where(lane < r, tail, intra).reshape(v.shape)
    return v


def _apply_plan_once_gpu(xb, stage: SystolicPlan, w_ref, variant: str,
                         acc_dtype, strategy: str = "lanes"):
    """One application of ``stage`` with GPU-shaped data movement.

    Same tap walk and accumulation *order* as
    :func:`repro.core.engine._apply_plan_once` — hence the same fp
    results — but every lane roll goes through :func:`warp_shift`
    (shuffle + warp-boundary hand-off) and the partial sums live in the
    per-thread register accumulator ``s``. ``strategy='mxu'`` routes to
    the tensor core via the dialect-neutral im2row ``dot_general``
    (§13's :func:`~repro.core.engine._apply_plan_mxu` — on CUDA that
    contraction is an ``mma.sync``).
    """
    if strategy == "mxu":
        return engine._apply_plan_mxu(xb, stage, w_ref, acc_dtype)
    if any(v > 1 for v in stage.stride_per_axis()):
        # Output-strided plans are data-stationary static gathers — no
        # shuffles on either backend; share the schedule verbatim.
        return engine._apply_plan_once(xb, stage, w_ref, variant, acc_dtype)
    exts = stage.exts
    M = stage.M
    valid = tuple(n - (e - 1) for n, e in zip(xb.shape, exts))
    # Register accumulator: full lane width until the valid-lane crop.
    s = jnp.zeros(valid[:-1] + (xb.shape[-1],), acc_dtype)
    if variant == "shift_psum":
        # Paper Listing 1/2 verbatim: shuffle the partial sums one
        # column-step up, then FMA that column's vertical register taps.
        for step in stage.steps:
            if step.shift:
                s = warp_shift(s, step.shift)
            for tap in step.taps:
                s = s + engine._tap_read(xb, tap, valid) * engine._coeff(
                    stage, w_ref, tap, acc_dtype)
        return s[..., M - 1 : M - 1 + valid[-1]]
    if variant == "shift_data":
        # Stationary accumulator: shuffle the *data* down by the
        # cumulative shift (shfl_down) instead. Same per-lane sums.
        cum = 0
        for step in stage.steps:
            cum += step.shift
            xs = warp_shift(xb, -cum) if cum else xb
            for tap in step.taps:
                s = s + engine._tap_read(xs, tap, valid) * engine._coeff(
                    stage, w_ref, tap, acc_dtype)
        return s[..., : valid[-1]]
    raise ValueError(variant)


def _gpu_window_kernel(*refs, plan: SystolicPlan, block: tuple[int, ...],
                       time_steps: int, variant: str, acc_dtype):
    """One overlapped block of a windowed plan, GPU-shaped.

    Ref layout matches the TPU kernel —
    ``(x_ref, *w_refs, *epi_refs, o_ref, smem_ref[, acc_ref])`` — plus
    the SMEM staging scratch: the halo-extended input block (interior +
    lead/trail skirt) is written to shared memory **once**, and every
    tap read below hits SMEM/registers, never HBM — the paper's §4.5
    branch-free block with its skirt staged, rather than re-reading the
    global overlap per tap. The reduce accumulator (NCHW channel sweep)
    is the per-thread register array discipline; Pallas scratch models
    it (on real hardware it is register-resident until the flush).
    """
    nb, nr, no = plan.batch_axes, plan.reduce_axes, plan.out_axes
    n_w = pipeline_coeff_count(plan)
    epi_entries = chain_epilogue_operand_stages(plan)
    x_ref = refs[0]
    w_refs = refs[1:1 + n_w]
    epi_refs = refs[1 + n_w:1 + n_w + len(epi_entries)]
    o_pos = 1 + n_w + len(epi_entries)
    o_ref = refs[o_pos]
    smem_ref = refs[o_pos + 1]
    acc_ref = refs[o_pos + 2] if nr else None
    # §14: stage the block skirt through shared memory, one coalesced
    # global read per element of interior+halo.
    smem_ref[...] = (x_ref[(0,) * (nb + nr)] if nb + nr
                     else x_ref[...]).astype(acc_dtype)
    xb = smem_ref[...]
    ei0 = 0                 # epilogue-operand cursor, shared across the chain
    if plan.stages:
        wi = 0
        for si, stage in enumerate(plan.stages):
            w_ref = None
            if stage.coeff_mode == "dense":
                w_ref = w_refs[wi]
                wi += 1
            xb = _apply_plan_once_gpu(xb, stage, w_ref, variant, acc_dtype,
                                      strategy=stage.strategy or plan.strategy
                                      or "lanes")
            if si < len(plan.stages) - 1:
                for st in stage.epilogue:
                    ref = None
                    if st.op in EPILOGUE_OPERANDS:
                        ref = epi_refs[ei0]
                        ei0 += 1
                    xb = engine._apply_epilogue_val(st, xb, ref, plan,
                                                    acc_dtype, None)
    else:
        w_ref = w_refs[0] if n_w else None
        for _ in range(time_steps):
            xb = _apply_plan_once_gpu(xb, plan, w_ref, variant, acc_dtype,
                                      strategy=plan.strategy or "lanes")
    res = xb[tuple(slice(0, b) for b in block)]
    o_idx = (0,) * (nb + no) if nb + no else ...

    def epilogue_fn(val):
        ei = ei0
        for st in plan.final_epilogue():
            ref = None
            if st.op in EPILOGUE_OPERANDS:
                ref = epi_refs[ei]
                ei += 1
            val = engine._apply_epilogue_val(st, val, ref, plan, acc_dtype,
                                             o_idx)
        return val

    if nr:
        rdims = range(nb + no + plan.ndim_spatial,
                      nb + no + plan.ndim_spatial + nr)
        engine._accumulate_over_reduce(acc_ref, o_ref, res, tuple(rdims),
                                       o_idx, epilogue_fn)
    else:
        o_ref[o_idx] = epilogue_fn(res).astype(o_ref.dtype)


def run_window_plan_gpu(x, w=None, **kw):
    """Fault-checked entry: ``engine.gpu.window`` fires per *call*, not
    per trace — the jitted lowering below would only run its Python body
    once per compilation, so an armed site would miss warm-cache calls."""
    rfaults.check("engine.gpu.window")
    return _run_window_plan_gpu_jit(x, w, **kw)


@functools.partial(
    jax.jit,
    static_argnames=("plan", "block", "time_steps", "variant", "interpret",
                     "acc_dtype", "strategy"),
)
def _run_window_plan_gpu_jit(
    x: jax.Array,
    w=None,
    *,
    plan: SystolicPlan,
    block: tuple[int, ...],
    time_steps: int = 1,
    variant: str = "shift_psum",
    interpret: bool = True,
    acc_dtype=jnp.float32,
    epilogue_args: tuple = (),
    strategy: str | None = None,
) -> jax.Array:
    """The GPU lowering of :func:`repro.core.engine.run_window_plan`.

    Same signature, same results (bitwise vs the TPU path for identical
    blocks when the lane extent is warp-aligned, fp32-tolerance
    otherwise only through XLA contraction choices): warp-shuffle psum
    shifts, SMEM skirt staging, per-thread register accumulators.
    Callers normally reach this through ``run_window_plan(backend=
    'gpu')``; calling it directly skips the config default.
    """
    if strategy is not None:
        plan = dataclasses.replace(plan, strategy=strategy)

    def make_kernel(B):
        return functools.partial(
            _gpu_window_kernel, plan=plan, block=B, time_steps=time_steps,
            variant=variant, acc_dtype=acc_dtype)

    def make_scratch(B, in_block):
        scratch = [_smem(in_block, acc_dtype)]      # halo-skirt staging
        if plan.reduce_axes:
            scratch.append(_smem(B, acc_dtype))     # register accumulator
        return scratch

    with engine._obs_lowering(plan=plan, block=block, backend="gpu",
                              time_steps=time_steps, variant=variant):
        return engine._window_call(
            x, w, plan=plan, block=block, time_steps=time_steps,
            variant=variant, interpret=interpret, acc_dtype=acc_dtype,
            epilogue_args=epilogue_args, make_kernel=make_kernel,
            make_scratch=make_scratch)


def _gpu_scan_kernel(*refs, plan: SystolicPlan, acc_dtype, has_carry: bool,
                     want_carry: bool):
    """Kogge–Stone over one ``(BR, BT)`` tile with warp-shaped arrows.

    Identical masked shift-accumulate math to the TPU kernel (§3.6,
    Fig. 1e) with each arrow routed per its span: shifts shorter than a
    warp are intra-warp shuffles, warp-crossing shifts go through the
    shared-memory hand-off of :func:`warp_shift`. The inter-tile carry
    lives in the SMEM scratch — scratchpad used only *between* systolic
    blocks, exactly as SSAM prescribes (§1).
    """
    carry = refs[-1]
    idx = len(refs) - 1
    co_ref = None
    if want_carry:
        idx -= 1
        co_ref = refs[idx]
    idx -= 1
    o_ref = refs[idx]
    c_ref = None
    if has_carry:
        idx -= 1
        c_ref = refs[idx]
    ins = refs[:idx]

    @pl.when(pl.program_id(1) == 0)
    def _reset():
        if has_carry:
            carry[:] = c_ref[:].astype(carry.dtype)   # h₋₁ = carry-in
        else:
            carry[:] = jnp.zeros_like(carry)

    def store(s):
        out = s
        for st in plan.epilogue:
            out = engine._apply_epilogue_val(st, out, None, plan, acc_dtype,
                                             None)
        o_ref[:] = out.astype(o_ref.dtype)

    lane = jax.lax.broadcasted_iota(jnp.int32, ins[0].shape, 1)
    if plan.combine == "add":
        s = ins[0][:].astype(acc_dtype)
        for step in plan.steps:           # ctrl() of Eq. 1 gates each arrow
            shifted = warp_shift(s, step.shift)
            s = s + jnp.where(lane >= step.shift, shifted, jnp.zeros_like(s))
        s = s + carry[:]
        carry[:] = s[:, -1:]
        store(s)
    elif plan.combine == "linrec":
        A = ins[0][:].astype(acc_dtype)   # transfer pairs (a, b)
        B = ins[1][:].astype(acc_dtype)
        for step in plan.steps:
            As = warp_shift(A, step.shift)
            Bs = warp_shift(B, step.shift)
            ctrl = lane >= step.shift
            As = jnp.where(ctrl, As, jnp.ones_like(As))   # identity (1, 0)
            Bs = jnp.where(ctrl, Bs, jnp.zeros_like(Bs))
            A, B = A * As, A * Bs + B     # f_t ∘ f_{t−d}
        h = A * carry[:] + B
        carry[:] = h[:, -1:]
        store(h)
    else:
        raise ValueError(plan.combine)
    if want_carry:
        co_ref[:] = carry[:].astype(co_ref.dtype)


def run_scan_plan_gpu(*operands, **kw):
    """Fault-checked entry for the scan lowering (site ``engine.gpu.scan``)."""
    rfaults.check("engine.gpu.scan")
    return _run_scan_plan_gpu_jit(*operands, **kw)


@functools.partial(
    jax.jit, static_argnames=("plan", "block_r", "interpret", "acc_dtype",
                              "return_carry")
)
def _run_scan_plan_gpu_jit(
    *operands: jax.Array,
    plan: SystolicPlan,
    block_r: int = 8,
    interpret: bool = True,
    acc_dtype=jnp.float32,
    carry: jax.Array | None = None,
    return_carry: bool = False,
):
    """The GPU lowering of :func:`repro.core.engine.run_scan_plan` —
    warp-shaped Kogge–Stone arrows, SMEM inter-tile carry. Same
    signature; cumsum results are bitwise for warp-aligned ``plan.S``,
    linrec results agree to ≤1 ulp (XLA may contract the per-step
    ``A·Bs + B`` FMA differently between the two kernel bodies)."""

    def make_kernel(has_carry):
        return functools.partial(_gpu_scan_kernel, plan=plan,
                                 acc_dtype=acc_dtype, has_carry=has_carry,
                                 want_carry=return_carry)

    def make_scratch(BR):
        return [_smem((BR, 1), acc_dtype)]

    with engine._obs_lowering(plan=plan, block=(block_r, plan.S),
                              backend="gpu"):
        return engine._scan_call(
            *operands, plan=plan, block_r=block_r, interpret=interpret,
            acc_dtype=acc_dtype, carry=carry, return_carry=return_carry,
            make_kernel=make_kernel, make_scratch=make_scratch)
