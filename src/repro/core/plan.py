"""SSAM plan formulation — the paper's four-tuple 𝒥 = (O, D, X, Y) (§3.4).

A :class:`SystolicPlan` is the static description of how a regular
memory-bound kernel executes on a software systolic array of ``S`` lanes
(GPU warp: S=32; TPU VREG lane axis: S=128):

* ``O`` (operations)  — the ``(⊗, ⊕)`` pair of Eq. 1, here fixed to
  (multiply, add) for convolution/stencil plans and exposed as the
  ``combine`` field for scan/recurrence plans.
* ``D`` (dependencies) — the ordered :class:`Step` list. Each step first
  *shifts* the partial-sum vector along the lane axis (the CUDA
  ``__shfl_up_sync`` of §4.4 / the TPU lane roll), then accumulates a set
  of *taps* — vertical, in-lane register reads (cheap direction of
  Fig. 1d).
* ``X`` / ``Y`` (inputs/outputs) — the register-cache geometry: each lane
  caches ``C = N + P − 1`` elements (Eq. 3) and produces ``P`` outputs by
  the sliding window of §4.2; a step's valid outputs live in lanes
  ``[M−1, S)`` (§4.4).

Plans are *data*: they are executed by :mod:`repro.core.executor` (pure
JAX, lane rolls) and lowered to Pallas kernels by the generic engine in
:mod:`repro.core.engine` — the modules in :mod:`repro.kernels` are thin
plan builders over that engine. The perf model
(:mod:`repro.core.perfmodel`) prices a plan with the paper's §5
equations, and :mod:`repro.core.tuning` picks block configs with it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

# Lane widths of the "warp" on each target. The paper's S is WarpSize=32;
# on TPU the natural systolic lane axis is the 128-wide VREG minor dim.
GPU_WARP_LANES = 32
TPU_VREG_LANES = 128

# Closed vocabulary of fusable elementwise epilogue stages (DESIGN.md §11).
# Applied in VMEM between the accumulator flush and the output store, so a
# conv→activation seam stops round-tripping HBM. The activations all fix 0
# (gelu(0) = silu(0) = relu(0) = s·0 = 0), so they sit *between* fused
# pipeline stages without disturbing the zero-boundary pad-once semantics.
# `bias` may also sit mid-chain: it applies to the whole pad-once
# intermediate, exactly matching the unfused per-stage fallback (though
# near the boundary both differ from per-op same-shape application, since
# bias(0) != 0). `residual_add` stays final-only — its operand is
# output-shaped and a mid-chain residual would have to materialize the
# intermediate it skips.
EPILOGUE_OPS = ("bias", "gelu", "silu", "relu", "scale", "residual_add")
# op → number of runtime operands it consumes from ``epilogue_args``.
EPILOGUE_OPERANDS = {"bias": 1, "residual_add": 1}


@dataclasses.dataclass(frozen=True)
class EpilogueStage:
    """One elementwise output stage: ``op`` from :data:`EPILOGUE_OPS`.

    ``value`` is the static operand of ``'scale'`` (compile-time, like a
    'table' coefficient); ``'bias'``/``'residual_add'`` take *runtime*
    operands from the ``epilogue_args`` of the engine call instead.
    """

    op: str
    value: float | None = None


def normalize_epilogue(epilogue) -> tuple[EpilogueStage, ...]:
    """Normalize user epilogue spec → ``tuple[EpilogueStage, ...]``.

    Accepts None, a single op name, an :class:`EpilogueStage`, a
    ``(op, value)`` pair, or any sequence of those. Unknown ops raise a
    named ``ValueError`` here — before any ``pallas_call``.
    """
    if epilogue is None:
        return ()
    if isinstance(epilogue, (str, EpilogueStage)):
        epilogue = (epilogue,)
    elif (isinstance(epilogue, tuple) and len(epilogue) == 2
          and isinstance(epilogue[0], str)
          and isinstance(epilogue[1], (int, float))):
        epilogue = (epilogue,)
    out = []
    for st in epilogue:
        if isinstance(st, str):
            st = EpilogueStage(st)
        elif isinstance(st, tuple):
            op, value = st
            st = EpilogueStage(op, float(value))
        if not isinstance(st, EpilogueStage) or st.op not in EPILOGUE_OPS:
            raise ValueError(
                f"unknown epilogue stage {st!r}: the fusable vocabulary is "
                f"{EPILOGUE_OPS} (DESIGN.md §11)")
        if st.op == "scale" and st.value is None:
            raise ValueError("epilogue stage 'scale' needs a static value: "
                             "pass ('scale', s)")
        if st.op != "scale" and st.value is not None:
            raise ValueError(
                f"epilogue stage {st.op!r} takes no static value (got "
                f"{st.value!r}); only 'scale' does — bias/residual operands "
                "ride in epilogue_args")
        out.append(st)
    return tuple(out)


def epilogue_operand_stages(
    stages: tuple[EpilogueStage, ...]
) -> tuple[EpilogueStage, ...]:
    """The subsequence of stages that consume a runtime operand, in order."""
    return tuple(st for st in stages if st.op in EPILOGUE_OPERANDS)


def chain_epilogue_operand_stages(plan) -> tuple[EpilogueStage, ...]:
    """Operand-bearing epilogue stages across a whole plan, in
    application order.

    For a fused pipeline this walks ``plan.stages`` — mid-chain ``bias``
    entries first, the final stage's operands last — which is the order
    the engine consumes ``epilogue_args``. For an unfused plan it equals
    ``epilogue_operand_stages(plan.epilogue)``.
    """
    if getattr(plan, "stages", ()):
        return tuple(st for s in plan.stages
                     for st in epilogue_operand_stages(s.epilogue))
    return epilogue_operand_stages(plan.epilogue)


@dataclasses.dataclass(frozen=True)
class Tap:
    """A vertical (in-lane) register read: ``data[window + row_offset] * coeff``.

    ``coeff_id`` indexes into the plan's coefficient table — for conv2d it
    is ``(row, col)`` into the filter; for stencils it is the index of the
    coefficient grouped into this column (Listing 2 groups {West},
    {North, Current, South}, {East}).

    ``z_offset`` is the depth (Z-slice) offset of the read for 3-D plans —
    on TPU the Z window is VREG-resident, so a Z tap is just another cheap
    vertical read (DESIGN.md §7.5); 2-D plans leave it at 0.
    """

    row_offset: int
    coeff_id: tuple[int, ...]
    z_offset: int = 0


@dataclasses.dataclass(frozen=True)
class Step:
    """One systolic cycle: shift partial sums ``shift`` lanes, then accumulate taps.

    ``shift`` encodes an edge set of the dependency graph ``D``: lane ``j``
    receives lane ``j - shift``'s partial result. ``masked`` marks steps whose
    ctrl() (Eq. 1) gates the shifted operand by lane index (Kogge–Stone scan
    arrows in Fig. 1e); convolution steps are unmasked because out-of-range
    lanes are halo lanes that are discarded anyway (§4.5).
    """

    shift: int
    taps: tuple[Tap, ...] = ()
    masked: bool = False


@dataclasses.dataclass(frozen=True)
class SystolicPlan:
    """Static schedule for one SSAM kernel — see module docstring.

    Beyond the paper's (O, D, X, Y) fields, a plan carries the geometry the
    generic lowering (:mod:`repro.core.engine`) needs to emit a Pallas
    kernel without per-family code:

    * ``depth``/``ndim_spatial`` — footprint extent along Z and the number
      of windowed (blocked, overlapped) axes; the lane axis is always last.
    * ``batch_axes`` — leading axes iterated by the grid with block size 1
      (the depthwise-conv batch dimension). Batch axes appear on both the
      input and the output.
    * ``reduce_axes`` — leading input axes (after the batch axes)
      iterated by the grid with block size 1 whose partial results are
      **accumulated** rather than written separately: the engine carries
      an fp32 accumulator across the reduce iterates and writes the
      output on the last one. This is the §2 shift-psum dataflow applied
      across channels instead of lanes — each reduce iterate runs the
      plan's full tap schedule (the *channel-reduction tap group*) and
      ⊕-combines into the running block sum. The NCHW ``C_in`` axis.
    * ``out_axes`` — leading axes of the *output and the coefficient
      array* that the input lacks (the NCHW ``C_out``): iterated by the
      grid with block size 1, selecting which coefficient slice the tap
      group reads. Operand shapes for a reduce plan are therefore
      ``x: batch + reduce + spatial``, ``w: out + reduce + filter``,
      ``out: batch + out + spatial``.
    * ``lead``/``trail`` — semantic zero-padding per windowed axis applied
      ahead of / behind the data origin *per temporal iterate*: a stencil
      plan pads by its footprint (same-shape output), a causal conv pads
      ``K−1`` in front, a valid conv pads nothing (output shrinks).
    * ``coeffs``/``coeff_mode`` — where tap coefficients come from:
      ``'table'`` (compile-time immediates stored on the plan, §4.8),
      ``'dense'`` (a runtime filter array indexed by ``coeff_id``), or
      ``'perlane'`` (runtime per-lane coefficient rows, depthwise conv).
    """

    kind: str            # 'conv1d' | 'conv2d' | 'stencil2d' | 'stencil3d' | 'scan' | 'recurrence'
    S: int               # systolic array width (lanes)
    C: int               # register-cache depth per lane (Eq. 3)
    P: int               # outputs per lane (sliding-window length, §4.2)
    M: int               # horizontal extent of the dependency footprint (filter cols)
    N: int               # vertical extent (filter rows) — taps per column upper bound
    steps: tuple[Step, ...]
    combine: str = "fma"  # O of Eq. 1: 'fma' (r⊗x ⊕ s) or 'add' (scan) or 'linrec'
    depth: int = 1        # Z extent of the footprint (3-D plans)
    ndim_spatial: int = 2  # windowed axes (lane axis last): 2 or 3
    batch_axes: int = 0   # leading grid axes with block size 1 (x and out)
    reduce_axes: int = 0  # contracted leading x axes (fp32 grid accumulator)
    out_axes: int = 0     # leading out/coeff axes the input lacks (C_out)
    lead: tuple[int, ...] | None = None   # zero-pad ahead of origin per axis
    trail: tuple[int, ...] | None = None  # zero-pad behind the data per axis
    coeffs: tuple[float, ...] | None = None  # immediates for 'table' mode
    coeff_mode: str = "dense"  # 'table' | 'dense' | 'perlane'
    # ---- fused pipelines + output epilogues (DESIGN.md §11) ---------------
    epilogue: tuple[EpilogueStage, ...] = ()  # elementwise output stages
    stride: tuple[int, ...] | None = None  # output stride per windowed axis
    stages: tuple["SystolicPlan", ...] = ()  # fused chain (core.fuse); the
    #   top-level fields then carry the *composite* footprint/lead/trail
    # ---- lowering strategy (DESIGN.md §13) --------------------------------
    # How the engine executes the tap-set contraction per block:
    #   None     — auto: lanes unless the autotuner picks otherwise
    #   'lanes'  — the paper's VPU schedule (lane shifts + per-tap FMA)
    #   'mxu'    — im2row over the tap set in VMEM + one dot_general on
    #              the MXU (arxiv 2603.00477's answer to "do we need
    #              tensor cores for stencils?")
    # Adjoints and fused chains derive plans with dataclasses.replace, so
    # the strategy rides the plan IR unchanged through both.
    strategy: str | None = None

    # ---- X geometry: what the engine lowers from --------------------------
    @property
    def exts(self) -> tuple[int, ...]:
        """Footprint extent per windowed axis, lane axis last."""
        if self.ndim_spatial == 3:
            return (self.depth, self.N, self.M)
        return (self.N, self.M)

    def lead_trail(self) -> tuple[tuple[int, ...], tuple[int, ...]]:
        zeros = (0,) * self.ndim_spatial
        return (self.lead or zeros, self.trail or zeros)

    def stride_per_axis(self) -> tuple[int, ...]:
        """Output stride per windowed axis (1 = dense)."""
        return self.stride or (1,) * self.ndim_spatial

    def halo(self, time_steps: int = 1) -> tuple[int, ...]:
        """Input-over-output overlap per windowed axis — the §4.5 halo,
        widened ``time_steps``-fold under temporal blocking (§6.4). For a
        fused chain (``stages``) the top-level ``exts`` already carry the
        summed stage footprints, so the same expression yields the
        chain-widened halo (DESIGN.md §11)."""
        return tuple(time_steps * (e - 1) for e in self.exts)

    def out_shape(self, in_shape: tuple[int, ...], time_steps: int = 1) -> tuple[int, ...]:
        """Windowed-axes output shape: each valid application shrinks an
        axis by ``ext−1``, the lead/trail zero-pad grows it back, and an
        output stride subsamples what remains."""
        lead, trail = self.lead_trail()
        return tuple(
            (s + time_steps * (l + r) - time_steps * (e - 1) - 1) // v + 1
            for s, l, r, e, v in zip(in_shape, lead, trail, self.exts,
                                     self.stride_per_axis())
        )

    def block_in_shape(self, block: tuple[int, ...], time_steps: int = 1) -> tuple[int, ...]:
        """Overlapped input block for a given output block (§4.5):
        ``(b−1)·stride + 1 + halo`` per axis (stride 1 ⇒ ``b + halo``)."""
        return tuple(
            (b - 1) * v + 1 + h
            for b, h, v in zip(block, self.halo(time_steps),
                               self.stride_per_axis())
        )

    # ---- Y geometry -------------------------------------------------------
    @property
    def valid_lane_lo(self) -> int:
        """First lane holding a valid output (paper: laneId ≥ M−1)."""
        return self.M - 1

    @property
    def valid_lanes(self) -> int:
        """Valid outputs per window step per warp: S − M + 1 (§4.4)."""
        return self.S - self.M + 1

    @property
    def outputs_per_block(self) -> int:
        return self.valid_lanes * self.P

    # ---- redundancy analysis (§5.3) --------------------------------------
    def halo_ratio(self) -> float:
        """Exact fraction of loaded elements that are halo.

        The paper bounds this as HR_rc = (S·C − (S−M)(C−N)) / (S·C); we
        report the exact value 1 − (valid lanes × P)/(S·C).
        """
        loaded = self.S * self.C
        useful = self.valid_lanes * self.P
        return 1.0 - useful / loaded

    def halo_ratio_paper_bound(self) -> float:
        """The paper's §5.3 closed form (an upper-bound style estimate)."""
        s, c, m, n = self.S, self.C, self.M, self.N
        return (s * c - (s - m) * (c - n)) / (s * c)

    def shift_count(self) -> int:
        """Total lane shifts per window step (the (M−1)·T_shfl term of
        Eq. 4); summed over the chain for a fused plan."""
        if self.stages:
            return sum(s.shift_count() for s in self.stages)
        return sum(1 for st in self.steps if st.shift)

    def mads_per_output_window(self) -> int:
        """MAD ops per window step per lane (M·N for dense conv); summed
        over the chain for a fused plan — the §5 flop terms of the whole
        pipeline priced against a single load+store (DESIGN.md §11)."""
        if self.stages:
            return sum(s.mads_per_output_window() for s in self.stages)
        return sum(len(st.taps) for st in self.steps)

    def epilogue_op_count(self) -> int:
        """Total elementwise epilogue stages across the plan/chain."""
        n = len(self.epilogue)
        return n + sum(len(s.epilogue) for s in self.stages)

    def final_epilogue(self) -> tuple[EpilogueStage, ...]:
        """The epilogue applied at the output store: the last stage's for
        a fused chain, the plan's own otherwise (mid-chain epilogues are
        applied between stages inside the kernel)."""
        return self.stages[-1].epilogue if self.stages else self.epilogue


# ---------------------------------------------------------------------------
# Plan builders
# ---------------------------------------------------------------------------

def _check_origin_straddle(kind: str, bounds: tuple[tuple[int, int], ...]):
    """Stencil offsets must straddle the output point on every axis
    (lo ≤ 0 ≤ hi) — same-shape zero-boundary semantics need non-negative
    lead/trail padding. Caught here so the failure names the stencil
    instead of surfacing as a negative-pad error inside the jitted engine.
    """
    for axis, (lo, hi) in enumerate(bounds):
        if not (lo <= 0 <= hi):
            raise ValueError(
                f"{kind}: offsets must straddle the origin on every axis; "
                f"axis {axis} spans [{lo}, {hi}]")

def conv1d_plan(M: int, *, S: int = TPU_VREG_LANES, P: int = 1) -> SystolicPlan:
    """§3.5 motivating example: 1-D convolution of filter width M.

    One tap per step (N=1); the register cache holds C = P elements (the
    window slides along the lane axis, not the cache axis, for 1-D).
    """
    steps = tuple(
        Step(shift=1 if m > 0 else 0, taps=(Tap(0, (m,)),)) for m in range(M)
    )
    return SystolicPlan("conv1d", S=S, C=P, P=P, M=M, N=1, steps=steps)


def conv2d_plan(M: int, N: int, *, S: int = TPU_VREG_LANES, P: int = 4) -> SystolicPlan:
    """Listing 1: M×N filter → M shift-steps of N taps each; C = N + P − 1."""
    steps = tuple(
        Step(
            shift=1 if m > 0 else 0,
            taps=tuple(Tap(n, (n, m)) for n in range(N)),
        )
        for m in range(M)
    )
    return SystolicPlan("conv2d", S=S, C=N + P - 1, P=P, M=M, N=N, steps=steps)


def conv2d_same_plan(M: int, N: int, *, S: int = TPU_VREG_LANES, P: int = 4) -> SystolicPlan:
    """'Same'-mode conv2d: Listing 1's schedule with the centre-anchor
    boundary folded into the plan's lead/trail fields.

    Same steps/taps as :func:`conv2d_plan`; the ``(N−1)//2`` /
    ``(M−1)//2`` zero rows/cols a 'same' convolution needs around the
    domain become plan geometry instead of a manual ``jnp.pad`` — which
    makes the plan shape-preserving per axis (``lead+trail = ext−1``)
    and therefore shardable by :mod:`repro.distributed.halo_exchange`.
    """
    base = conv2d_plan(M, N, S=S, P=P)
    top, left = (N - 1) // 2, (M - 1) // 2
    return dataclasses.replace(
        base, lead=(top, left), trail=(N - 1 - top, M - 1 - left))


def conv2d_batched_plan(
    M: int, N: int, *, S: int = TPU_VREG_LANES, P: int = 4,
    mode: str = "valid",
) -> SystolicPlan:
    """A minibatch of single-channel images through Listing 1's schedule.

    Identical steps/taps to :func:`conv2d_plan`; the leading image axis
    becomes a block-1 grid axis (``batch_axes=1``), so a ``(B, H, W)``
    stack convolves against one ``(N, M)`` filter in a single engine
    call — no Python loop over images.
    """
    base = conv2d_same_plan(M, N, S=S, P=P) if mode == "same" \
        else conv2d_plan(M, N, S=S, P=P)
    return dataclasses.replace(base, batch_axes=1)


def conv2d_nchw_plan(
    B: int, C_in: int, C_out: int, M: int, N: int,
    *, S: int = TPU_VREG_LANES, P: int = 4, mode: str = "valid",
    groups: int = 1,
) -> SystolicPlan:
    """Batched multi-channel NCHW convolution — the paper's headline
    convolution workload (2.5× over NPP for general 2-D filters),
    expressed as reduction axes over Listing 1's schedule.

    The plan is :func:`conv2d_plan`'s M-step/N-tap schedule with three
    grid axes layered on top: the minibatch ``B`` (``batch_axes=1``),
    the output channel ``C_out`` (``out_axes=1`` — selects the
    ``w[c_out]`` coefficient slice per iterate) and the input channel
    ``C_in`` (``reduce_axes=1`` — the engine ⊕-accumulates the tap
    group's partial sums across iterates in an fp32 scratch block and
    writes the output on the last one). Operands:
    ``x (B, C_in, H, W)``, ``w (C_out, C_in, N, M)``,
    ``out (B, C_out, H', W')``.

    ``B``/``C_in``/``C_out`` are validated here but *not* baked into the
    frozen plan: the engine reads the grid extents off the operand
    shapes, so one plan signature covers every batch/channel count and
    the tuning sidecar's nearest-shape seeding keeps working across
    them (shapes carry B/C; the schedule does not need to).

    ``groups`` validates a grouped convolution (``lax``'s
    ``feature_group_count``): both channel counts must divide evenly.
    The returned plan describes ONE group's reduce sweep — its
    ``reduce_axes`` contraction covers the group's ``C_in/groups``
    slice; :func:`repro.kernels.ops.conv2d` slices operands per group
    and runs this same plan over each (depthwise-2d is
    ``groups == C_in``).
    """
    for nm, v in (("B", B), ("C_in", C_in), ("C_out", C_out),
                  ("groups", groups)):
        if v < 1:
            raise ValueError(f"conv2d_nchw_plan: {nm} must be >= 1, got {v}")
    if C_in % groups or C_out % groups:
        raise ValueError(
            f"conv2d_nchw_plan: groups={groups} must divide both "
            f"C_in={C_in} and C_out={C_out} (per-group reduce slices)")
    base = conv2d_same_plan(M, N, S=S, P=P) if mode == "same" \
        else conv2d_plan(M, N, S=S, P=P)
    return dataclasses.replace(
        base, kind="conv2d_nchw", batch_axes=1, reduce_axes=1, out_axes=1)


def stencil2d_plan(
    offsets: Sequence[tuple[int, int]],
    *,
    coeffs: Sequence[float] | None = None,
    S: int = TPU_VREG_LANES,
    P: int = 4,
) -> SystolicPlan:
    """Listing 2 generalized: group stencil taps by column offset (dx).

    ``offsets`` are (dy, dx) pairs relative to the output point. The plan
    walks columns left→right (dx ascending), shifting partial sums once per
    column — {West}, {North,Current,South}, {East} for the 5-point stencil.
    """
    dys = [dy for dy, _ in offsets]
    dxs = [dx for _, dx in offsets]
    lo_dy, hi_dy = min(dys), max(dys)
    lo_dx, hi_dx = min(dxs), max(dxs)
    _check_origin_straddle("stencil2d", ((lo_dy, hi_dy), (lo_dx, hi_dx)))
    M = hi_dx - lo_dx + 1
    N = hi_dy - lo_dy + 1
    cols: dict[int, list[tuple[int, int]]] = {}
    for k, (dy, dx) in enumerate(offsets):
        cols.setdefault(dx - lo_dx, []).append((dy - lo_dy, k))
    steps = []
    for m in range(M):
        taps = tuple(Tap(row, (k,)) for row, k in sorted(cols.get(m, ())))
        steps.append(Step(shift=1 if m > 0 else 0, taps=taps))
    return SystolicPlan(
        "stencil2d", S=S, C=N + P - 1, P=P, M=M, N=N, steps=tuple(steps),
        lead=(-lo_dy, -lo_dx), trail=(hi_dy, hi_dx),
        coeffs=None if coeffs is None else tuple(float(c) for c in coeffs),
        coeff_mode="table",
    )


def stencil3d_plan(
    offsets: Sequence[tuple[int, int, int]],
    *,
    coeffs: Sequence[float] | None = None,
    S: int = TPU_VREG_LANES,
    P: int = 2,
) -> SystolicPlan:
    """§4.9: 3-D stencils. (dz, dy, dx) taps.

    The X–Y plane is handled exactly like :func:`stencil2d_plan`; the Z
    direction becomes additional *vertical* taps (in-lane register reads of
    the neighbouring Z-slices held in the same register cache). On GPU the
    paper spills Z-partials to shared memory (inter-warp); on TPU we keep
    the whole Z window in VREG-resident accumulators (DESIGN.md §7.5), so a
    3-D plan is structurally a 2-D plan whose taps carry a dz coordinate.
    """
    dzs = [o[0] for o in offsets]
    dys = [o[1] for o in offsets]
    dxs = [o[2] for o in offsets]
    lo_dz, hi_dz = min(dzs), max(dzs)
    lo_dy, hi_dy = min(dys), max(dys)
    lo_dx, hi_dx = min(dxs), max(dxs)
    _check_origin_straddle(
        "stencil3d", ((lo_dz, hi_dz), (lo_dy, hi_dy), (lo_dx, hi_dx)))
    M = hi_dx - lo_dx + 1
    N = hi_dy - lo_dy + 1
    depth = hi_dz - lo_dz + 1
    cols: dict[int, list[tuple[int, int, int]]] = {}
    for k, (dz, dy, dx) in enumerate(offsets):
        cols.setdefault(dx - lo_dx, []).append((dz - lo_dz, dy - lo_dy, k))
    steps = []
    for m in range(M):
        taps = tuple(
            Tap(row, (k,), z_offset=z) for z, row, k in sorted(cols.get(m, ()))
        )
        steps.append(Step(shift=1 if m > 0 else 0, taps=taps))
    return SystolicPlan(
        "stencil3d", S=S, C=N + P - 1, P=P, M=M, N=N, steps=tuple(steps),
        depth=depth, ndim_spatial=3,
        lead=(-lo_dz, -lo_dy, -lo_dx), trail=(hi_dz, hi_dy, hi_dx),
        coeffs=None if coeffs is None else tuple(float(c) for c in coeffs),
        coeff_mode="table",
    )


def depthwise_conv1d_plan(K: int, *, S: int = TPU_VREG_LANES) -> SystolicPlan:
    """Depthwise causal 1-D conv in the *D-optimal* SSAM mapping (§5.4).

    Channels ride the lane axis and time rides sublanes, so every tap is a
    vertical (in-lane, cheap) register read and no lane shifts are needed
    at all — M=1, N=K. Coefficients are per-lane rows of a runtime
    ``(K, D)`` filter (``coeff_mode='perlane'``). The leading batch axis is
    iterated by the grid (``batch_axes=1``); causality is the ``K−1`` lead
    zeros on the time axis.
    """
    taps = tuple(Tap(k, (k,)) for k in range(K))
    return SystolicPlan(
        "conv1d", S=S, C=K, P=1, M=1, N=K, steps=(Step(shift=0, taps=taps),),
        batch_axes=1, lead=(K - 1, 0), coeff_mode="perlane",
    )


def scan_plan(n: int, *, S: int | None = None) -> SystolicPlan:
    """§3.6: Kogge–Stone inclusive scan over ``n`` lanes (Fig. 1e).

    log2(n) masked steps with doubling shift; r ≡ 1 so steps carry no taps.
    """
    S = S or n
    assert n & (n - 1) == 0, "Kogge–Stone scan wants a power-of-two width"
    steps = tuple(Step(shift=1 << k, masked=True) for k in range(int(math.log2(n))))
    return SystolicPlan("scan", S=S, C=1, P=1, M=1, N=1, steps=steps, combine="add")


def linear_recurrence_plan(n: int, *, S: int | None = None) -> SystolicPlan:
    """Kogge–Stone over the associative operator of ``h_t = a_t·h_{t−1} + b_t``.

    (a₂,b₂)∘(a₁,b₁) = (a₁a₂, b₁a₂ + b₂). This is Eq. 1 with ⊗/⊕ acting on
    transfer pairs; it executes the RWKV6 WKV recurrence and the Mamba/Hymba
    selective-scan inner loop (DESIGN.md §3).
    """
    S = S or n
    assert n & (n - 1) == 0, "Kogge–Stone scan wants a power-of-two width"
    steps = tuple(Step(shift=1 << k, masked=True) for k in range(int(math.log2(n))))
    return SystolicPlan(
        "recurrence", S=S, C=1, P=1, M=1, N=1, steps=steps, combine="linrec"
    )
