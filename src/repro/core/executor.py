"""Pure-JAX executor for SSAM plans — the model-level semantics of SSAM.

This module *interprets* a :class:`repro.core.plan.SystolicPlan` with
``jnp.roll`` standing in for the partial-sum interconnect (GPU:
``__shfl_up_sync``; TPU: VPU lane roll). It has two roles:

1. **Executable semantics** of the systolic model, tested against the
   mathematical oracles in ``repro.kernels.*.ref`` — this validates that
   the *model* (shift/accumulate schedule, halo geometry) is correct,
   independently of any Pallas lowering.
2. **Reference for the Pallas kernels**: the kernels in
   :mod:`repro.kernels` implement the same schedule with real BlockSpec
   tiling; their unit tests assert equality with both this executor and
   the oracle.

Two execution styles are provided, mirroring the paper:

* ``*_block`` functions operate on one register-cache block of shape
  ``(C, S)`` — a single "warp" step, Fig. 2a.
* ``*_global`` functions run the same schedule over a whole array (the
  S→∞ limit), which is the cleanest statement of the systolic dataflow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .plan import SystolicPlan


def _shift_partial_sums(s: jnp.ndarray, shift: int, axis: int = -1) -> jnp.ndarray:
    """The D-edge: lane j receives lane j−shift (CUDA shfl_up / TPU roll).

    Wrap-around writes into lanes < shift; those are halo lanes for conv
    plans (discarded per §4.5) and are masked by the caller for scan plans.
    """
    return jnp.roll(s, shift, axis=axis)


# ---------------------------------------------------------------------------
# Convolution / stencil plans
# ---------------------------------------------------------------------------

def execute_conv_block(
    plan: SystolicPlan, data: jnp.ndarray, coeffs: jnp.ndarray
) -> jnp.ndarray:
    """Run a conv/stencil plan on one register-cache block.

    Args:
      plan: a conv2d/stencil2d plan.
      data: ``(C, S)`` block — lane j's register cache is column j (Fig. 2a).
      coeffs: filter table; indexed by each tap's ``coeff_id``
        (``(N, M)`` matrix for conv2d, flat vector for stencils).

    Returns:
      ``(P, S)`` partial-result matrix. Lanes ``[M−1, S)`` hold the valid
      outputs; output x-position = lane − (M−1) (§4.4).
    """
    P, S = plan.P, plan.S
    assert data.shape == (plan.C, S), (data.shape, (plan.C, S))
    out_rows = []
    for i in range(P):  # sliding window (§4.2) — P output rows per lane
        s = jnp.zeros((S,), data.dtype)
        for step in plan.steps:
            if step.shift:
                s = _shift_partial_sums(s, step.shift)
            for tap in step.taps:
                s = s + data[i + tap.row_offset, :] * coeffs[tap.coeff_id]
        out_rows.append(s)
    return jnp.stack(out_rows)


def execute_conv_global(
    plan: SystolicPlan, data: jnp.ndarray, coeffs: jnp.ndarray
) -> jnp.ndarray:
    """Whole-array systolic execution (the S→∞ limit of the same schedule).

    ``data`` is ``(H, W)``; returns the *valid* cross-correlation of shape
    ``(H − N + 1, W − M + 1)``: every output row window runs the plan with
    the full row width as the lane axis, then valid lanes ``[M−1, W)`` are
    kept.
    """
    H, W = data.shape
    M, N = plan.M, plan.N
    out_h = H - N + 1
    rows = []
    for y in range(out_h):
        s = jnp.zeros((W,), data.dtype)
        for step in plan.steps:
            if step.shift:
                s = _shift_partial_sums(s, step.shift)
            for tap in step.taps:
                s = s + data[y + tap.row_offset, :] * coeffs[tap.coeff_id]
        rows.append(s[M - 1 :])
    return jnp.stack(rows)


# ---------------------------------------------------------------------------
# Scan plans (§3.6, Fig. 1e)
# ---------------------------------------------------------------------------

def execute_scan(plan: SystolicPlan, x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Kogge–Stone inclusive scan: masked shift-accumulate, Eq. 1 with r≡1."""
    axis = axis % x.ndim
    n = x.shape[axis]
    assert n == plan.S, (n, plan.S)
    lane = jnp.arange(n)
    shape = [1] * x.ndim
    shape[axis] = n
    lane = lane.reshape(shape)
    s = x
    for step in plan.steps:
        shifted = _shift_partial_sums(s, step.shift, axis=axis)
        ctrl = lane >= step.shift  # ctrl() of Eq. 1: gate the KS arrows
        s = s + jnp.where(ctrl, shifted, jnp.zeros_like(shifted))
    return s


def execute_linear_recurrence(
    plan: SystolicPlan, a: jnp.ndarray, b: jnp.ndarray, axis: int = -1
) -> jnp.ndarray:
    """Kogge–Stone over the transfer-pair operator (aᵢ, bᵢ) — DESIGN.md §3.

    Solves ``h_t = a_t · h_{t−1} + b_t`` (h₋₁ = 0) along ``axis``.
    Composition: (A, B) ∘ shifted (A', B') = (A'·A, B'·A + B).
    """
    axis = axis % a.ndim
    n = a.shape[axis]
    assert n == plan.S, (n, plan.S)
    lane_shape = [1] * a.ndim
    lane_shape[axis] = n
    lane = jnp.arange(n).reshape(lane_shape)
    A, B = a, b
    for step in plan.steps:
        As = _shift_partial_sums(A, step.shift, axis=axis)
        Bs = _shift_partial_sums(B, step.shift, axis=axis)
        ctrl = lane >= step.shift
        ones = jnp.ones_like(As)
        zeros = jnp.zeros_like(Bs)
        As = jnp.where(ctrl, As, ones)    # identity element (1, 0)
        Bs = jnp.where(ctrl, Bs, zeros)
        # f_t ∘ f_{t−d}: later segment applied to the earlier one.
        A, B = A * As, A * Bs + B
    return B
