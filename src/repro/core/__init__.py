"""SSAM core: the paper's systolic execution model as data + executors.

- :mod:`repro.core.plan` — 𝒥 = (O, D, X, Y) plan formulation (§3.4).
- :mod:`repro.core.executor` — pure-JAX lane-roll interpreter of plans.
- :mod:`repro.core.engine` — generic plan→Pallas lowering (every kernel).
- :mod:`repro.core.adjoint` — symbolic plan transposition: every
  backward pass as an adjoint plan through the same engine.
- :mod:`repro.core.fuse` — chain composition: consecutive
  shape-preserving windowed plans fused into one pipeline plan
  (epilogues + fused chains, DESIGN.md §11).
- :mod:`repro.core.halo` — halo geometry shared by the engine, the
  sharded halo-exchange layer and per-shard tuning.
- :mod:`repro.core.tuning` — §5 perf-model-guided block-config autotuner
  (with JSON-sidecar persistence + nearest-shape seeding).
- :mod:`repro.core.perfmodel` — the paper's §5 analytical latency model.
- :mod:`repro.core.rooflines` — TPU v5e 3-term roofline from XLA artifacts.
"""
from .plan import (
    EPILOGUE_OPS,
    GPU_WARP_LANES,
    TPU_VREG_LANES,
    EpilogueStage,
    Step,
    SystolicPlan,
    Tap,
    epilogue_operand_stages,
    normalize_epilogue,
    conv1d_plan,
    conv2d_batched_plan,
    conv2d_nchw_plan,
    conv2d_plan,
    conv2d_same_plan,
    depthwise_conv1d_plan,
    linear_recurrence_plan,
    scan_plan,
    stencil2d_plan,
    stencil3d_plan,
)
from .halo import (
    check_shard_geometry,
    is_shape_preserving,
    origin_pads,
    shard_halo,
)
from .executor import (
    execute_conv_block,
    execute_conv_global,
    execute_linear_recurrence,
    execute_scan,
)
from .engine import (run_scan_plan, run_weight_grad_plan, run_window_plan,
                     run_window_plan_mxu)
from .fuse import fuse_plans
from .adjoint import (
    adjoint_coeff_array,
    apply_epilogue,
    input_adjoint_plan,
    reversed_recurrence_coeffs,
    weight_adjoint_plan,
)

__all__ = [
    "EPILOGUE_OPS",
    "GPU_WARP_LANES",
    "TPU_VREG_LANES",
    "EpilogueStage",
    "Step",
    "SystolicPlan",
    "Tap",
    "epilogue_operand_stages",
    "normalize_epilogue",
    "fuse_plans",
    "apply_epilogue",
    "check_shard_geometry",
    "conv1d_plan",
    "conv2d_batched_plan",
    "conv2d_nchw_plan",
    "conv2d_plan",
    "conv2d_same_plan",
    "depthwise_conv1d_plan",
    "is_shape_preserving",
    "origin_pads",
    "shard_halo",
    "linear_recurrence_plan",
    "scan_plan",
    "stencil2d_plan",
    "stencil3d_plan",
    "execute_conv_block",
    "execute_conv_global",
    "execute_linear_recurrence",
    "execute_scan",
    "run_scan_plan",
    "run_weight_grad_plan",
    "run_window_plan",
    "run_window_plan_mxu",
    "adjoint_coeff_array",
    "input_adjoint_plan",
    "reversed_recurrence_coeffs",
    "weight_adjoint_plan",
]
