"""SSAM core: the paper's systolic execution model as data + executors.

- :mod:`repro.core.plan` — 𝒥 = (O, D, X, Y) plan formulation (§3.4).
- :mod:`repro.core.executor` — pure-JAX lane-roll interpreter of plans.
- :mod:`repro.core.engine` — generic plan→Pallas lowering (every kernel).
- :mod:`repro.core.tuning` — §5 perf-model-guided block-config autotuner.
- :mod:`repro.core.perfmodel` — the paper's §5 analytical latency model.
- :mod:`repro.core.rooflines` — TPU v5e 3-term roofline from XLA artifacts.
"""
from .plan import (
    GPU_WARP_LANES,
    TPU_VREG_LANES,
    Step,
    SystolicPlan,
    Tap,
    conv1d_plan,
    conv2d_plan,
    depthwise_conv1d_plan,
    linear_recurrence_plan,
    scan_plan,
    stencil2d_plan,
    stencil3d_plan,
)
from .executor import (
    execute_conv_block,
    execute_conv_global,
    execute_linear_recurrence,
    execute_scan,
)
from .engine import run_scan_plan, run_window_plan

__all__ = [
    "GPU_WARP_LANES",
    "TPU_VREG_LANES",
    "Step",
    "SystolicPlan",
    "Tap",
    "conv1d_plan",
    "conv2d_plan",
    "depthwise_conv1d_plan",
    "linear_recurrence_plan",
    "scan_plan",
    "stencil2d_plan",
    "stencil3d_plan",
    "execute_conv_block",
    "execute_conv_global",
    "execute_linear_recurrence",
    "execute_scan",
    "run_scan_plan",
    "run_window_plan",
]
