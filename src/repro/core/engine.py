"""Generic plan→Pallas lowering — one engine for every systolic plan.

This module is the "compiler" half of the SSAM formulation: a
:class:`repro.core.plan.SystolicPlan` is pure data (𝒥 = (O, D, X, Y),
§3.4) and the engine lowers *any* plan to a Pallas TPU kernel. The five
former per-family kernels (``ssam_conv1d/conv2d/stencil2d/stencil3d/
ssam_scan``) are now ~20-line plan builders over two lowerings here:

* :func:`run_window_plan` — the windowed (conv/stencil) family. From the
  plan's geometry it derives the overlapped-block ``pl.Element``
  BlockSpecs (§4.5), the pad/halo arithmetic (lead/trail origin padding,
  tile round-up), temporal blocking (t-step fusion inside the block,
  §6.4), the valid-lane crop (outputs live in lanes ``[M−1, S)``, §4.4)
  and both schedule variants (DESIGN.md §2):

  - ``variant='shift_psum'`` — paper-faithful: the *partial sums* roll
    along the lane axis (the ``__shfl_up_sync`` of §4.4).
  - ``variant='shift_data'`` — re-associated: the accumulator stays put
    and the *data* rolls by the cumulative shift instead; the rolls of
    all M steps become independent of the accumulator chain and can
    issue in parallel with the FMAs. Per output lane the same products
    are added in the same order, so results agree to the last ulp modulo
    XLA's FMA-contraction choices (observed ≤ 1 ulp on CPU).

* :func:`run_scan_plan` — the scan family (cumsum / linear recurrence):
  Kogge–Stone masked shift-accumulate over the lane axis (§3.6, Fig. 1e)
  with an inter-block carry in VMEM scratch — scratchpad used only
  *between* systolic blocks, exactly as SSAM prescribes (§1).

Everything the lowering needs — footprint extents, origin padding, batch
axes, coefficient source — comes from plan fields, so a new kernel family
is a new plan builder, not a new kernel body.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import obs
from repro.robust import faults as rfaults

from .fuse import pipeline_coeff_count
from .halo import origin_pads
from .plan import (EPILOGUE_OPERANDS, EpilogueStage, SystolicPlan, Tap,
                   chain_epilogue_operand_stages, epilogue_operand_stages)


def _obs_lowering(*, plan: SystolicPlan, block, backend: str,
                  time_steps: int = 1, variant: str = "shift_psum"):
    """Trace-time telemetry for one plan lowering (both backends call it).

    This runs inside the ``jax.jit``-ed lowering bodies, so its Python
    side effects fire once per *compilation*, not per call: the
    ``engine.lowering`` counter is the lowering-cache-miss (recompile)
    count, and the returned span — the "one span per plan lowering"
    event — times the trace+lower work itself, carrying the plan
    signature, strategy, block and the §5 predicted cost. Disabled
    tracing pays one counter bump and one boolean check.
    """
    strategy = (plan.strategy or "lanes") if plan.combine == "fma" \
        else plan.combine
    obs.metrics.inc("engine.lowering", f"{backend}:{plan.kind}")
    if not obs.trace.enabled():
        return obs.trace.NULL
    from . import tuning
    try:
        cost = tuning.model_cost(
            plan, tuning.KernelConfig(tuple(block), variant, plan.strategy),
            time_steps, tuning.machine_for(backend))
    except Exception:
        cost = None       # telemetry never turns a lowering into an error
    return obs.span(
        "engine.lower", cat="engine", plan=tuning.plan_signature(plan),
        kind=plan.kind, backend=backend, strategy=strategy,
        block=list(block), time_steps=time_steps, model_cost=cost)


def _obs_call_drift(plan: SystolicPlan, block, backend: str, time_steps: int,
                    variant: str, out, t0: float, shape) -> None:
    """Opt-in per-call model-vs-measured sample (``REPRO_DRIFT``).

    Blocks on ``out`` — which defeats async dispatch, hence opt-in —
    and records wall µs against the launch's predicted §5 cost. Skipped
    under an enclosing jit trace (there is nothing to time).
    """
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) * 1e6
    from . import tuning
    try:
        cost = tuning.model_cost(
            plan, tuning.KernelConfig(tuple(block), variant, plan.strategy),
            time_steps, tuning.machine_for(backend))
    except Exception:
        return
    obs.drift.record(tuning.plan_signature(plan), backend, plan.strategy,
                     cost, us, shape=shape, source="call")


# ---------------------------------------------------------------------------
# Windowed family: conv1d / conv2d / stencil2d / stencil3d
# ---------------------------------------------------------------------------

def _coeff(plan: SystolicPlan, w_ref, tap: Tap, acc_dtype):
    """Resolve a tap's coefficient per the plan's coeff_mode.

    For reduce plans the coefficient block carries ``out_axes +
    reduce_axes`` leading block-1 axes (the grid already selected the
    (c_out, c_in) slice via the BlockSpec index map), so the tap's
    ``coeff_id`` is prefixed with zeros — the *channel-reduction tap
    group*: same taps, one coefficient slice per reduce iterate.
    """
    if plan.coeff_mode == "table":          # compile-time immediate (§4.8)
        return plan.coeffs[tap.coeff_id[-1]]
    if plan.coeff_mode == "dense":          # runtime filter, scalar element
        pre = (0,) * (plan.out_axes + plan.reduce_axes)
        return w_ref[pre + tap.coeff_id].astype(acc_dtype)
    if plan.coeff_mode == "perlane":        # runtime per-lane coefficient row
        return w_ref[tap.coeff_id[-1], :].astype(acc_dtype)
    raise ValueError(plan.coeff_mode)


def _accumulate_over_reduce(acc_ref, o_ref, contrib, rdims, o_idx,
                            epilogue_fn=None):
    """Grid-reduce epilogue shared by every accumulating kernel.

    The sweep over ``rdims`` (innermost, sequential grid dims) revisits
    the same output block: reset the scratch on the first reduce
    iterate, ⊕-accumulate the block's contribution, flush to the output
    ref on the last — the matmul-k pattern (DESIGN.md §9.2/§10.1).
    ``epilogue_fn`` (plan-IR output stages, DESIGN.md §11) applies at
    the flush, i.e. to the *summed* block, in VMEM — between the
    accumulator flush and the output store.
    """
    first = functools.reduce(
        jnp.logical_and, [pl.program_id(d) == 0 for d in rdims])
    last = functools.reduce(
        jnp.logical_and,
        [pl.program_id(d) == pl.num_programs(d) - 1 for d in rdims])

    @pl.when(first)
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += contrib.astype(acc_ref.dtype)

    @pl.when(last)
    def _flush():
        out = acc_ref[...]
        if epilogue_fn is not None:
            out = epilogue_fn(out)
        o_ref[o_idx] = out.astype(o_ref.dtype)


def _tap_read(xb: jnp.ndarray, tap: Tap, valid: tuple[int, ...]) -> jnp.ndarray:
    """The vertical (in-lane, cheap-direction) register read of Fig. 1d."""
    if xb.ndim == 3:
        return xb[
            tap.z_offset : tap.z_offset + valid[0],
            tap.row_offset : tap.row_offset + valid[1],
            :,
        ]
    return xb[tap.row_offset : tap.row_offset + valid[0], :]


MXU_TAP_ALIGN = 8       # fp32 sublane tiling: taps pad to (8·k, lanes)


def _flat_taps(stage: SystolicPlan) -> list[tuple[int, Tap]]:
    """The tap set flattened to ``(cumulative_shift, tap)`` pairs.

    The cumulative lane shift is the tap's horizontal offset in
    shift_data coordinates: output lane ``l`` reads input lane
    ``l + cum`` (strided plans: ``l·stride + cum``).
    """
    out, cum = [], 0
    for step in stage.steps:
        cum += step.shift
        for tap in step.taps:
            out.append((cum, tap))
    return out


def _apply_plan_mxu(xb, stage: SystolicPlan, w_ref, acc_dtype):
    """One application of ``stage`` as an im2row matmul on the MXU.

    Instead of walking the tap set with per-tap FMAs (the VPU 'lanes'
    schedule), gather every tap's shifted view of the block into a
    ``(taps, out_elems)`` operand **in VMEM** — im2row over the tap set,
    never materialized in HBM — pad the tap dimension to the fp32
    sublane tile (``8·k`` rows, zero rows contribute nothing) and
    contract it with the coefficient vector in ONE
    ``jax.lax.dot_general`` with ``preferred_element_type=f32``, which
    Mosaic routes to the MXU (DESIGN.md §13). The per-lane sums equal
    the shift_data association, so both strategies agree to fp32
    tolerance. For NCHW reduce plans this runs once per ``C_in``
    iterate of the reduce sweep into the same fp32 accumulator: the
    effective contraction dimension is ``C_in·taps``.

    Per-lane coefficient rows ('perlane', depthwise conv1d) have no
    shared coefficient vector; they contract the tap dimension under a
    lane-axis *batch* dimension instead — a batched mat-vec, still a
    single MXU-shaped ``dot_general``.
    """
    exts = stage.exts
    stride = stage.stride_per_axis()
    strided = any(v > 1 for v in stride)
    taps = _flat_taps(stage)
    if strided:
        sh, sw = stride
        out_sp = tuple((n - e) // v + 1
                       for n, e, v in zip(xb.shape, exts, stride))
    else:
        # shift_data coordinates: out lane l ← in lane l + cum, so the
        # tap view is a static crop — no roll, no valid-lane shuffle.
        out_sp = tuple(n - (e - 1) for n, e in zip(xb.shape, exts))
    views = []
    for cum, tap in taps:
        if strided:
            views.append(xb[
                tap.row_offset : tap.row_offset + out_sp[0] * sh : sh,
                cum : cum + out_sp[1] * sw : sw,
            ])
        elif xb.ndim == 3:
            views.append(xb[
                tap.z_offset : tap.z_offset + out_sp[0],
                tap.row_offset : tap.row_offset + out_sp[1],
                cum : cum + out_sp[2],
            ])
        else:
            views.append(xb[
                tap.row_offset : tap.row_offset + out_sp[0],
                cum : cum + out_sp[1],
            ])
    T = len(views)
    Tp = -(-T // MXU_TAP_ALIGN) * MXU_TAP_ALIGN
    if stage.coeff_mode == "perlane":
        # (T, R, L) taps × (T, L) per-lane rows: contract T, batch L.
        A = jnp.stack(views)
        Wm = jnp.stack([w_ref[tap.coeff_id[-1], :].astype(acc_dtype)
                        for _, tap in taps])
        if Tp != T:
            A = jnp.pad(A, ((0, Tp - T),) + ((0, 0),) * (A.ndim - 1))
            Wm = jnp.pad(Wm, ((0, Tp - T), (0, 0)))
        out = jax.lax.dot_general(
            Wm, A, dimension_numbers=(((0,), (0,)), ((1,), (2,))),
            preferred_element_type=jnp.float32)
        return out.T.astype(acc_dtype)      # (L, R) → (R, L)
    # (1, 8·k) coefficient row × (8·k, out_elems) im2row operand.
    if stage.coeff_mode == "table":
        # Compile-time immediates cannot ride a materialized coefficient
        # vector (a Pallas kernel may not capture array constants): fold
        # each scalar into its im2row row and contract with a broadcast
        # ones row — the same single dot_general over the tap dimension.
        A = jnp.stack([v.reshape(-1) * stage.coeffs[tap.coeff_id[-1]]
                       for v, (_, tap) in zip(views, taps)])
        if Tp != T:
            A = jnp.pad(A, ((0, Tp - T), (0, 0)))
        c = jnp.ones((Tp,), acc_dtype)      # splat; zero rows contribute 0
    else:                                   # dense runtime filter
        pre = (0,) * (stage.out_axes + stage.reduce_axes)
        A = jnp.stack([v.reshape(-1) for v in views])
        c = jnp.stack([w_ref[pre + tap.coeff_id].astype(acc_dtype)
                       for _, tap in taps])
        if Tp != T:
            A = jnp.pad(A, ((0, Tp - T), (0, 0)))
            c = jnp.pad(c, (0, Tp - T))
    out = jax.lax.dot_general(
        c.reshape(1, Tp), A, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return out.reshape(out_sp).astype(acc_dtype)


def _apply_plan_once(xb, stage: SystolicPlan, w_ref, variant: str, acc_dtype,
                     strategy: str = "lanes"):
    """One valid application of ``stage``'s schedule on the block ``xb``.

    Dense (stride-1) plans run either schedule variant (DESIGN.md §2).
    Output-strided plans use the data-stationary strided read directly —
    output lane ``l`` gathers input lane ``l·stride + cum`` per column
    step, so the kernel computes only the lanes the stride keeps instead
    of the dense result it would subsample. ``strategy='mxu'`` replaces
    the whole tap walk with the im2row matmul of
    :func:`_apply_plan_mxu`; the ``variant`` knob is then moot (there
    are no rolls to re-associate).
    """
    if strategy == "mxu":
        return _apply_plan_mxu(xb, stage, w_ref, acc_dtype)
    exts = stage.exts
    M = stage.M
    stride = stage.stride_per_axis()
    if any(v > 1 for v in stride):
        sh, sw = stride
        out_sp = tuple((n - e) // v + 1
                       for n, e, v in zip(xb.shape, exts, stride))
        s = jnp.zeros(out_sp, acc_dtype)
        cum = 0
        for step in stage.steps:
            cum += step.shift
            for tap in step.taps:
                patch = xb[
                    tap.row_offset : tap.row_offset + out_sp[0] * sh : sh,
                    cum : cum + out_sp[1] * sw : sw,
                ]
                s = s + patch * _coeff(stage, w_ref, tap, acc_dtype)
        return s
    valid = tuple(n - (e - 1) for n, e in zip(xb.shape, exts))
    # Partial sums keep the full lane width until the valid-lane crop.
    s = jnp.zeros(valid[:-1] + (xb.shape[-1],), acc_dtype)
    if variant == "shift_psum":
        # Paper Listing 1/2: shift the partial sums one lane per
        # column-step, then accumulate that column's vertical taps.
        for step in stage.steps:
            if step.shift:
                s = jnp.roll(s, step.shift, axis=-1)
            for tap in step.taps:
                s = s + _tap_read(xb, tap, valid) * _coeff(
                    stage, w_ref, tap, acc_dtype)
        return s[..., M - 1 : M - 1 + valid[-1]]
    if variant == "shift_data":
        # Stationary accumulator: roll the data by the cumulative
        # shift instead. Same per-lane sums in the same order.
        cum = 0
        for step in stage.steps:
            cum += step.shift
            xs = jnp.roll(xb, -cum, axis=-1) if cum else xb
            for tap in step.taps:
                s = s + _tap_read(xs, tap, valid) * _coeff(
                    stage, w_ref, tap, acc_dtype)
        return s[..., : valid[-1]]
    raise ValueError(variant)


def _apply_epilogue_val(st: EpilogueStage, val, epi_ref, plan: SystolicPlan,
                        acc_dtype, o_idx):
    """One elementwise epilogue stage on an in-VMEM block (DESIGN.md §11)."""
    if st.op == "gelu":
        return jax.nn.gelu(val, approximate=True)
    if st.op == "silu":
        return jax.nn.silu(val)
    if st.op == "relu":
        return jnp.maximum(val, 0)
    if st.op == "scale":
        return val * st.value
    if st.op == "bias":
        if plan.out_axes:                 # per-out-channel (NCHW): scalar
            return val + epi_ref[(0,) * plan.out_axes].astype(acc_dtype)
        if plan.coeff_mode == "perlane":  # per-lane (depthwise conv) row
            return val + epi_ref[...].astype(acc_dtype)
        return val + epi_ref[0].astype(acc_dtype)
    if st.op == "residual_add":
        return val + epi_ref[o_idx].astype(acc_dtype)
    raise ValueError(st.op)


def _window_kernel(*refs, plan: SystolicPlan, block: tuple[int, ...],
                   time_steps: int, variant: str, acc_dtype):
    """One overlapped block of any windowed plan.

    ``refs`` is ``(x_ref, *w_refs, *epilogue_refs, o_ref[, acc_ref])``.
    The block runs ``time_steps`` fused applications of the plan (§6.4)
    — or, for a fused pipeline, one application of each ``plan.stages``
    entry with the stage's own taps/coefficients and any mid-chain
    elementwise epilogues applied between stages, all in VMEM
    (DESIGN.md §11). Each application consumes one stage-footprint of
    halo per axis. The final epilogue applies between the accumulator
    flush and the output store. Reduce plans carry the block's partial
    sum in an fp32 VMEM scratch accumulator across the (innermost,
    sequential) reduce grid iterates and write the output on the last
    one — §2's shift-psum dataflow applied across channels instead of
    lanes.
    """
    nb, nr, no = plan.batch_axes, plan.reduce_axes, plan.out_axes
    n_w = pipeline_coeff_count(plan)
    epi_entries = chain_epilogue_operand_stages(plan)
    x_ref = refs[0]
    w_refs = refs[1:1 + n_w]
    epi_refs = refs[1 + n_w:1 + n_w + len(epi_entries)]
    o_ref = refs[1 + n_w + len(epi_entries)]
    acc_ref = refs[-1] if nr else None
    xb = (x_ref[(0,) * (nb + nr)] if nb + nr else x_ref[...]).astype(acc_dtype)
    ei0 = 0                 # epilogue-operand cursor, shared across the chain
    if plan.stages:
        wi = 0
        for si, stage in enumerate(plan.stages):
            w_ref = None
            if stage.coeff_mode == "dense":
                w_ref = w_refs[wi]
                wi += 1
            # A stage's own pinned strategy wins; otherwise it inherits
            # the chain's (the tuner pins the chain as ONE kernel).
            xb = _apply_plan_once(xb, stage, w_ref, variant, acc_dtype,
                                  strategy=stage.strategy or plan.strategy
                                  or "lanes")
            if si < len(plan.stages) - 1:
                # mid-chain epilogues fix zero or are a scalar bias
                # (fuse_plans); either way they apply to the whole
                # pad-once intermediate, so the trapezoidal boundary
                # stays shared with the unfused fallback.
                for st in stage.epilogue:
                    ref = None
                    if st.op in EPILOGUE_OPERANDS:
                        ref = epi_refs[ei0]
                        ei0 += 1
                    xb = _apply_epilogue_val(st, xb, ref, plan, acc_dtype,
                                             None)
    else:
        w_ref = w_refs[0] if n_w else None
        for _ in range(time_steps):
            xb = _apply_plan_once(xb, plan, w_ref, variant, acc_dtype,
                                  strategy=plan.strategy or "lanes")
    res = xb[tuple(slice(0, b) for b in block)]
    o_idx = (0,) * (nb + no) if nb + no else ...

    def epilogue_fn(val):
        ei = ei0
        for st in plan.final_epilogue():
            ref = None
            if st.op in EPILOGUE_OPERANDS:
                ref = epi_refs[ei]
                ei += 1
            val = _apply_epilogue_val(st, val, ref, plan, acc_dtype, o_idx)
        return val

    if nr:
        # Reduce grid dims are innermost: per output block the sweep is
        # sequential, so the scratch accumulator is exact fp32 ⊕ (§2).
        rdims = range(nb + no + plan.ndim_spatial,
                      nb + no + plan.ndim_spatial + nr)
        _accumulate_over_reduce(acc_ref, o_ref, res, tuple(rdims), o_idx,
                                epilogue_fn)
    else:
        o_ref[o_idx] = epilogue_fn(res).astype(o_ref.dtype)


def _window_call(
    x: jax.Array,
    w,
    *,
    plan: SystolicPlan,
    block: tuple[int, ...],
    time_steps: int,
    variant: str,
    interpret: bool,
    acc_dtype,
    epilogue_args: tuple,
    make_kernel,
    make_scratch,
) -> jax.Array:
    """Backend-shared windowed-family driver (DESIGN.md §14).

    Everything about a windowed lowering that is backend-*independent*
    lives here: plan validation, the t-widened origin/halo padding, the
    overlapped ``pl.Unblocked`` input BlockSpecs, coefficient/epilogue
    operand layout, the batch × out × spatial × reduce grid, and the
    final valid crop. A backend contributes only its kernel body and
    scratch request — ``make_kernel(B)`` → kernel fn for output block
    ``B``, ``make_scratch(B, in_block)`` → ``scratch_shapes`` list — so
    the TPU (sublane/lane) and GPU (warp-shuffle + SMEM skirt) lowerings
    share one geometry and can only differ in how a block is computed.
    """
    nb, nr, no, nd = (plan.batch_axes, plan.reduce_axes, plan.out_axes,
                      plan.ndim_spatial)
    assert x.ndim == nb + nr + nd, (x.shape, nb, nr, nd)
    assert len(block) == nd, (block, nd)
    for p in (plan,) + plan.stages:
        if p.strategy not in (None, "lanes", "mxu"):
            raise ValueError(
                f"unknown lowering strategy {p.strategy!r} on {p.kind!r}: "
                "expected None (auto), 'lanes' (VPU shift schedule) or "
                "'mxu' (im2row dot_general, DESIGN.md §13)")
    if nr or no:
        assert plan.coeff_mode == "dense" and w is not None, (
            "reduce/out axes need a dense runtime coefficient array")
        assert w.ndim == no + nr + 2, (w.shape, no, nr)
        assert time_steps == 1, (
            "temporal blocking does not commute with a channel reduction: "
            "iterate t must see the *summed* output of iterate t-1, which "
            "only exists after the full reduce sweep")
    if plan.stages:
        assert time_steps == 1, "a fused pipeline already is the fusion"
        assert isinstance(w, tuple) and len(w) == len(plan.stages), (
            "fused plans take one coefficient entry per stage (None for "
            "'table' stages)", plan.kind)
    if any(v > 1 for v in plan.stride_per_axis()):
        assert nd == 2 and time_steps == 1 and not plan.stages, (
            "output strides support single 2-D plan applications")
    epi_entries = chain_epilogue_operand_stages(plan)
    assert len(epilogue_args) == len(epi_entries), (
        "epilogue_args must match the chain's operand-bearing epilogue "
        "stages, in application order", [s.op for s in epi_entries])
    t = time_steps
    spatial_in = x.shape[nb + nr:]
    out_sp = plan.out_shape(spatial_in, t)
    assert all(o >= 1 for o in out_sp), (spatial_in, out_sp)

    B = tuple(min(b, o) for b, o in zip(block, out_sp))
    g = tuple(pl.cdiv(o, b) for o, b in zip(out_sp, B))
    stride = plan.stride_per_axis()
    # Origin + round-up padding (core.halo): t·lead zeros ahead of the
    # origin, then enough behind so every (including the last) overlapped
    # input block is in-bounds.
    pads = [(0, 0)] * (nb + nr) + origin_pads(plan, spatial_in, g, B, t)
    xp = jnp.pad(x, pads)

    # Grid layout: batch × out × spatial × reduce — reduce innermost so
    # the sweep over it is sequential per output block and the scratch
    # accumulator carries (the matmul-k pattern of the TPU grid).
    batch_dims = x.shape[:nb]
    out_dims = w.shape[:no] if no else ()
    red_dims = x.shape[nb:nb + nr]
    grid = batch_dims + out_dims + g + red_dims
    sp0 = nb + no                      # first spatial grid dim
    rd0 = sp0 + nd                     # first reduce grid dim

    # Overlapped input blocks (§4.5): element-indexed specs — output tiles
    # are disjoint, input tiles overlap by the halo, so grid steps never
    # communicate (the TPU analogue of the paper's branch-free warp blocks).
    # An output-strided grid reads input tiles at stride-scaled origins.
    in_block = plan.block_in_shape(B, t)
    x_spec = pl.BlockSpec(
        (1,) * (nb + nr) + in_block,
        lambda *ids: ids[:nb] + ids[rd0:rd0 + nr] + tuple(
            i * b * v for i, b, v in zip(ids[sp0:sp0 + nd], B, stride)),
        indexing_mode=pl.Unblocked(),
    )
    in_specs = [x_spec]
    operands = [xp]
    if plan.stages:
        for stage, w_s in zip(plan.stages, w):
            if stage.coeff_mode == "table":
                assert w_s is None, (stage.kind, "table stage took a w")
                continue
            fil = w_s.shape
            in_specs.append(pl.BlockSpec(
                fil, lambda *ids, _n=len(fil): (0,) * _n))
            operands.append(w_s)
    elif plan.coeff_mode == "dense":
        fil = w.shape[no + nr:]
        in_specs.append(pl.BlockSpec(
            (1,) * (no + nr) + fil,
            lambda *ids: ids[nb:nb + no] + ids[rd0:rd0 + nr]
            + (0,) * len(fil)))
        operands.append(w)
    elif plan.coeff_mode == "perlane":
        assert w.shape[-1] == spatial_in[-1], (w.shape, spatial_in)
        wp = jnp.pad(w, ((0, 0), (0, g[-1] * B[-1] - w.shape[-1])))
        in_specs.append(
            pl.BlockSpec((w.shape[0], B[-1]),
                         lambda *ids: (0, ids[sp0 + nd - 1])))
        operands.append(wp)

    # Epilogue operands (DESIGN.md §11): bias rides per-channel/lane/
    # scalar, a residual rides blocked exactly like the output.
    for st, arr in zip(epi_entries, epilogue_args):
        if st.op == "bias":
            if no:
                assert arr.shape == out_dims, (arr.shape, out_dims)
                in_specs.append(pl.BlockSpec(
                    (1,) * no, lambda *ids: ids[nb:nb + no]))
                operands.append(arr)
            elif plan.coeff_mode == "perlane" and not plan.stages:
                assert arr.shape == (spatial_in[-1],), (arr.shape, spatial_in)
                bp = jnp.pad(arr, (0, g[-1] * B[-1] - arr.shape[-1]))
                in_specs.append(pl.BlockSpec(
                    (B[-1],), lambda *ids: (ids[sp0 + nd - 1],)))
                operands.append(bp)
            else:
                assert arr.size == 1, ("scalar bias expected for "
                                       f"{plan.kind!r}", arr.shape)
                in_specs.append(pl.BlockSpec((1,), lambda *ids: (0,)))
                operands.append(jnp.reshape(arr, (1,)))
        else:                           # residual_add: output layout
            assert arr.shape == batch_dims + out_dims + out_sp, (
                arr.shape, batch_dims + out_dims + out_sp)
            rp = jnp.pad(arr, [(0, 0)] * (nb + no) + [
                (0, gi * bi - o) for gi, bi, o in zip(g, B, out_sp)])
            in_specs.append(pl.BlockSpec(
                (1,) * (nb + no) + B, lambda *ids: ids[:rd0]))
            operands.append(rp)

    out = pl.pallas_call(
        make_kernel(B),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1,) * (nb + no) + B,
                               lambda *ids: ids[:rd0]),
        out_shape=jax.ShapeDtypeStruct(
            batch_dims + out_dims + tuple(gi * bi for gi, bi in zip(g, B)),
            x.dtype),
        scratch_shapes=make_scratch(B, in_block),
        interpret=interpret,
    )(*operands)
    return out[(slice(None),) * (nb + no)
               + tuple(slice(0, o) for o in out_sp)]


@functools.partial(
    jax.jit,
    static_argnames=("plan", "block", "time_steps", "variant", "interpret",
                     "acc_dtype", "strategy"),
)
def _run_window_plan_tpu(
    x: jax.Array,
    w=None,
    *,
    plan: SystolicPlan,
    block: tuple[int, ...],
    time_steps: int = 1,
    variant: str = "shift_psum",
    interpret: bool = True,
    acc_dtype=jnp.float32,
    epilogue_args: tuple = (),
    strategy: str | None = None,
) -> jax.Array:
    """The TPU lowering: 8×128 sublane/lane tiles, VPU lane rolls for
    ``shift_psum``, fp32 VMEM scratch for the reduce accumulator."""
    if strategy is not None:
        # kwarg convenience for the thin family wrappers + tuner replay:
        # the strategy still lives on the plan IR (adjoints/fusion
        # inherit it from there), this just pins it at the call site.
        plan = dataclasses.replace(plan, strategy=strategy)

    def make_kernel(B):
        return functools.partial(
            _window_kernel, plan=plan, block=B, time_steps=time_steps,
            variant=variant, acc_dtype=acc_dtype)

    def make_scratch(B, in_block):
        return [pltpu.VMEM(B, acc_dtype)] if plan.reduce_axes else []

    with _obs_lowering(plan=plan, block=block, backend="tpu",
                       time_steps=time_steps, variant=variant):
        return _window_call(
            x, w, plan=plan, block=block, time_steps=time_steps,
            variant=variant, interpret=interpret, acc_dtype=acc_dtype,
            epilogue_args=epilogue_args, make_kernel=make_kernel,
            make_scratch=make_scratch)


def run_window_plan(
    x: jax.Array,
    w=None,
    *,
    plan: SystolicPlan,
    block: tuple[int, ...],
    time_steps: int = 1,
    variant: str = "shift_psum",
    interpret: bool = True,
    acc_dtype=jnp.float32,
    epilogue_args: tuple = (),
    strategy: str | None = None,
    backend: str | None = None,
) -> jax.Array:
    """Lower a windowed plan to a Pallas call and run it.

    Args:
      x: ``batch_axes + reduce_axes + ndim_spatial``-dim input, lane axis
        last.
      w: runtime coefficients for ``coeff_mode`` 'dense' (full filter,
        prefixed by ``out_axes + reduce_axes`` channel axes for reduce
        plans) or 'perlane' (``(K, lanes)`` rows); None for 'table' plans.
        For a fused pipeline (``plan.stages``), a tuple with one entry
        per stage — an array for 'dense' stages, None for 'table' ones.
      plan: the systolic schedule + geometry (lead/trail, footprint).
      block: output block size per windowed axis, lane axis last.
      time_steps: fused plan applications per block (§6.4).
      epilogue_args: runtime operands of the chain's operand-bearing
        epilogue stages, in application order (mid-chain ``bias``
        entries first for fused pipelines, the final stage's last) —
        ``bias`` (per-C_out for out-axes plans, per-lane for perlane
        plans, scalar otherwise; always scalar mid-chain) and/or
        ``residual_add`` (shaped like the output, final stage only).
      strategy: pin the lowering strategy for this call ('lanes' or
        'mxu', DESIGN.md §13); None keeps whatever the plan carries.
      backend: which lowering of the plan IR to emit — 'tpu'
        (:func:`_run_window_plan_tpu`), 'gpu'
        (:func:`repro.core.engine_gpu.run_window_plan_gpu`: warp-shuffle
        psum shifts + SMEM halo skirt, DESIGN.md §14) or 'auto'; None
        defers to :func:`repro.config.engine_backend`. Both backends run
        under ``interpret=True`` on any host, which is how CI proves
        their equivalence.

    Returns:
      The plan's output, ``batch + out_axes + spatial``-shaped: per
      windowed axis, ``out = (in + t·(lead+trail) − t·(ext−1) − 1) //
      stride + 1``; reduce axes are contracted away (fp32 grid
      accumulator).
    """
    from repro.config import engine_backend, resolve_engine_backend

    backend = (resolve_engine_backend(backend) if backend is not None
               else engine_backend())
    kw = dict(plan=plan, block=block, time_steps=time_steps, variant=variant,
              interpret=interpret, acc_dtype=acc_dtype,
              epilogue_args=epilogue_args, strategy=strategy)
    eff = dataclasses.replace(plan, strategy=strategy) if strategy else plan
    strat = (eff.strategy or "lanes") if eff.combine == "fma" else eff.combine
    rfaults.check("engine.window")
    obs.metrics.inc("engine.launch", f"{backend}:{strat}")
    t0 = time.perf_counter()
    with obs.span("engine.run_window_plan", cat="engine", kind=plan.kind,
                  backend=backend, strategy=strat):
        if backend == "gpu":
            from . import engine_gpu

            out = engine_gpu.run_window_plan_gpu(x, w, **kw)
        else:
            out = _run_window_plan_tpu(x, w, **kw)
    if obs.drift.per_call() and not isinstance(x, jax.core.Tracer):
        _obs_call_drift(eff, block, backend, time_steps, variant, out, t0,
                        x.shape)
    return out


def run_window_plan_mxu(x: jax.Array, w=None, *, plan: SystolicPlan, **kw):
    """:func:`run_window_plan` with the tap-set contraction forced onto
    the MXU: im2row over the tap set in VMEM + one ``dot_general`` per
    block application (DESIGN.md §13). Equivalent to pinning
    ``strategy='mxu'`` on the plan (and on every fused stage, via
    inheritance); same signature, same output to fp32 tolerance as the
    lanes schedule.
    """
    return run_window_plan(
        x, w, plan=dataclasses.replace(plan, strategy="mxu"), **kw)


# ---------------------------------------------------------------------------
# Windowed family: backward-weight (the adjoint correlation, DESIGN.md §10)
# ---------------------------------------------------------------------------

def _wgrad_dense_kernel(x_ref, g_ref, o_ref, acc_ref, *, exts, block,
                        acc_dtype):
    """One reduce iterate of ``∂L/∂w[n,m] = Σ_{b,o} g[b,o]·xp[b,o+(n,m)]``.

    The filter footprint is the *output* here; every grid step over
    batch × cotangent tiles is a reduce iterate contributing one
    filter-shaped partial to the fp32 scratch accumulator — the same
    accumulator pattern as the NCHW channel reduction, with batch and
    the spatial tiles playing the reduction.
    """
    N, M = exts
    bh, bw = block
    xb = x_ref[0, 0].astype(acc_dtype)
    gb = g_ref[0, 0].astype(acc_dtype)
    contrib = jnp.stack([
        jnp.stack([jnp.sum(xb[n:n + bh, m:m + bw] * gb) for m in range(M)])
        for n in range(N)])
    _accumulate_over_reduce(acc_ref, o_ref, contrib, (2, 3, 4), (0, 0))


def _wgrad_perlane_kernel(x_ref, g_ref, o_ref, acc_ref, *, K, block,
                          acc_dtype):
    """Per-lane backward-weight: ``∂L/∂w[k,d] = Σ_{b,t} g[b,t,d]·xp[b,t+k,d]``.

    Lanes (channels) are an *output* grid axis; batch and the time tiles
    are the reduce sweep.
    """
    bt, _ = block
    xb = x_ref[0].astype(acc_dtype)
    gb = g_ref[0].astype(acc_dtype)
    contrib = jnp.stack([
        jnp.sum(xb[k:k + bt, :] * gb, axis=0) for k in range(K)])
    _accumulate_over_reduce(acc_ref, o_ref, contrib, (1, 2), ...)


@functools.partial(
    jax.jit,
    static_argnames=("plan", "block", "interpret", "acc_dtype", "pre_padded"),
)
def run_weight_grad_plan(
    x: jax.Array,
    g: jax.Array,
    *,
    plan: SystolicPlan,
    block: tuple[int, ...] = (8, 128),
    interpret: bool = True,
    acc_dtype=jnp.float32,
    pre_padded: bool = False,
) -> jax.Array:
    """Backward-weight of a windowed plan: ``∂L/∂w`` of
    ``y = run_window_plan(x, w, plan=plan)`` given the cotangent ``g``.

    This is the adjoint *correlation* expressed through the engine's
    reduce machinery (DESIGN.md §10): batch and the cotangent's spatial
    tiles become block-1 grid **reduce** iterates, each accumulating a
    filter-shaped partial (``Σ`` over the tile of ``g · shifted x``) in
    an fp32 VMEM scratch block that is flushed once at the end of the
    sweep. The output is the coefficient array's own shape — tiny — so
    the whole gradient is one ``pallas_call`` with no Python loop over
    batch, channels or tiles.

    Args:
      x: the forward input (same layout run_window_plan consumed).
      g: the cotangent, shaped like the forward output.
      block: tile of ``g``'s spatial axes per reduce iterate (clamped).
      pre_padded: the sharded path passes ``x`` already halo-extended by
        the plan's lead/trail (neighbor rows via ppermute); skip the
        origin padding then.

    Returns:
      ``∂L/∂w`` in ``acc_dtype`` with the forward coefficient layout:
      ``(N, M)`` dense, ``(C_out, C_in, N, M)`` NCHW (out+reduce
      leading), ``(K, D)`` perlane.
    """
    if plan.combine != "fma" or plan.coeff_mode == "table":
        raise ValueError(
            f"no weight gradient for {plan.kind!r} "
            f"(combine={plan.combine!r}, coeff_mode={plan.coeff_mode!r})")
    # Jitted directly: fires once per compilation (recompile count).
    obs.metrics.inc("engine.lowering", f"tpu:wgrad-{plan.kind}")
    nb, nr, no = plan.batch_axes, plan.reduce_axes, plan.out_axes

    if plan.coeff_mode == "perlane":
        K = plan.N
        B, T, D = x.shape
        assert g.shape[0] == B and g.shape[2] == D, (x.shape, g.shape)
        lead = 0 if pre_padded else (plan.lead or (0, 0))[0]
        Tg = g.shape[1]
        assert Tg == T + lead + (0 if pre_padded else
                                 (plan.trail or (0, 0))[0]) - (K - 1), \
            (x.shape, g.shape)
        bt, bd = min(block[0], Tg), min(block[1], D)
        gt, gd = pl.cdiv(Tg, bt), pl.cdiv(D, bd)
        gp = jnp.pad(g, ((0, 0), (0, gt * bt - Tg), (0, gd * bd - D)))
        xp = jnp.pad(x, ((0, 0), (lead, gt * bt + K - 1 - lead - T),
                         (0, gd * bd - D)))
        kern = functools.partial(_wgrad_perlane_kernel, K=K, block=(bt, bd),
                                 acc_dtype=acc_dtype)
        out = pl.pallas_call(
            kern,
            grid=(gd, B, gt),               # lanes out; batch × time reduce
            in_specs=[
                pl.BlockSpec((1, bt + K - 1, bd),
                             lambda d, b, i: (b, i * bt, d * bd),
                             indexing_mode=pl.Unblocked()),
                pl.BlockSpec((1, bt, bd),
                             lambda d, b, i: (b, i * bt, d * bd),
                             indexing_mode=pl.Unblocked()),
            ],
            out_specs=pl.BlockSpec((K, bd), lambda d, b, i: (0, d)),
            out_shape=jax.ShapeDtypeStruct((K, gd * bd), acc_dtype),
            scratch_shapes=[pltpu.VMEM((K, bd), acc_dtype)],
            interpret=interpret,
        )(xp, gp)
        return out[:, :D]

    assert plan.coeff_mode == "dense" and plan.ndim_spatial == 2, plan.kind
    assert no == nr, (no, nr)            # plain dense (0,0) or NCHW (1,1)
    N, M = plan.exts
    x4 = x if nb else x[None]
    x4 = x4 if nr else x4[:, None]       # (B, C_in, H, W)
    g4 = g if nb else g[None]
    g4 = g4 if no else g4[:, None]       # (B, C_out, H', W')
    B, C_in, H, W = x4.shape
    _, C_out, Ho, Wo = g4.shape
    lead, trail = ((0, 0), (0, 0)) if pre_padded else plan.lead_trail()
    assert Ho == H + lead[0] + trail[0] - (N - 1), (x.shape, g.shape)
    assert Wo == W + lead[1] + trail[1] - (M - 1), (x.shape, g.shape)
    bh, bw = min(block[0], Ho), min(block[1], Wo)
    gh, gw = pl.cdiv(Ho, bh), pl.cdiv(Wo, bw)
    gp = jnp.pad(g4, ((0, 0), (0, 0), (0, gh * bh - Ho), (0, gw * bw - Wo)))
    xp = jnp.pad(x4, ((0, 0), (0, 0),
                      (lead[0], gh * bh + N - 1 - lead[0] - H),
                      (lead[1], gw * bw + M - 1 - lead[1] - W)))
    kern = functools.partial(_wgrad_dense_kernel, exts=(N, M),
                             block=(bh, bw), acc_dtype=acc_dtype)
    out = pl.pallas_call(
        kern,
        grid=(C_out, C_in, B, gh, gw),   # channels out; batch×tiles reduce
        in_specs=[
            pl.BlockSpec((1, 1, bh + N - 1, bw + M - 1),
                         lambda co, ci, b, i, j: (b, ci, i * bh, j * bw),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((1, 1, bh, bw),
                         lambda co, ci, b, i, j: (b, co, i * bh, j * bw),
                         indexing_mode=pl.Unblocked()),
        ],
        out_specs=pl.BlockSpec((1, 1, N, M),
                               lambda co, ci, b, i, j: (co, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((C_out, C_in, N, M), acc_dtype),
        scratch_shapes=[pltpu.VMEM((N, M), acc_dtype)],
        interpret=interpret,
    )(xp, gp)
    return out if no else out[0, 0]


# ---------------------------------------------------------------------------
# Scan family: cumsum / linear recurrence (§3.6, Fig. 1e)
# ---------------------------------------------------------------------------

def _scan_kernel(*refs, plan: SystolicPlan, acc_dtype, has_carry: bool,
                 want_carry: bool):
    """Kogge–Stone over one ``(BR, BT)`` tile, carry across grid steps.

    Ref layout: ``(*data_ins, [c_ref], o_ref, [co_ref], scratch)`` — the
    optional ``c_ref`` seeds the VMEM carry at the first sequential tile
    (inter-chunk carry-in), the optional ``co_ref`` publishes the final
    carry (its block index ignores the sequential axis, so the last grid
    step's write wins).
    """
    carry = refs[-1]
    idx = len(refs) - 1
    co_ref = None
    if want_carry:
        idx -= 1
        co_ref = refs[idx]
    idx -= 1
    o_ref = refs[idx]
    c_ref = None
    if has_carry:
        idx -= 1
        c_ref = refs[idx]
    ins = refs[:idx]

    @pl.when(pl.program_id(1) == 0)
    def _reset():
        if has_carry:
            carry[:] = c_ref[:].astype(carry.dtype)   # h₋₁ = carry-in
        else:
            carry[:] = jnp.zeros_like(carry)   # h₋₁ = 0 for both combines

    def store(s):
        # The epilogue applies to the *stored* copy only (DESIGN.md §11);
        # the inter-block carry keeps the raw scan state — fusing an
        # activation must not corrupt the recurrence.
        out = s
        for st in plan.epilogue:
            out = _apply_epilogue_val(st, out, None, plan, acc_dtype, None)
        o_ref[:] = out.astype(o_ref.dtype)

    lane = jax.lax.broadcasted_iota(jnp.int32, ins[0].shape, 1)
    if plan.combine == "add":
        s = ins[0][:].astype(acc_dtype)
        for step in plan.steps:           # ctrl() of Eq. 1 gates each arrow
            shifted = jnp.roll(s, step.shift, axis=1)
            s = s + jnp.where(lane >= step.shift, shifted, jnp.zeros_like(s))
        s = s + carry[:]                  # inter-block carry (scratchpad)
        carry[:] = s[:, -1:]
        store(s)
    elif plan.combine == "linrec":
        A = ins[0][:].astype(acc_dtype)   # transfer pairs (a, b)
        B = ins[1][:].astype(acc_dtype)
        for step in plan.steps:
            As = jnp.roll(A, step.shift, axis=1)
            Bs = jnp.roll(B, step.shift, axis=1)
            ctrl = lane >= step.shift
            As = jnp.where(ctrl, As, jnp.ones_like(As))   # identity (1, 0)
            Bs = jnp.where(ctrl, Bs, jnp.zeros_like(Bs))
            A, B = A * As, A * Bs + B     # f_t ∘ f_{t−d}
        h = A * carry[:] + B              # prefix applied to the carry
        carry[:] = h[:, -1:]
        store(h)
    else:
        raise ValueError(plan.combine)
    if want_carry:
        co_ref[:] = carry[:].astype(co_ref.dtype)


def _scan_call(
    *operands: jax.Array,
    plan: SystolicPlan,
    block_r: int,
    interpret: bool,
    acc_dtype,
    carry: jax.Array | None,
    return_carry: bool,
    make_kernel,
    make_scratch,
):
    """Backend-shared scan-family driver (DESIGN.md §14): identity-element
    padding, the ``(R, T)`` tiling with T sequential, carry-in/-out spec
    plumbing. The backend contributes the Kogge–Stone kernel body
    (``make_kernel()``) and its carry scratch (``make_scratch(BR)``)."""
    if epilogue_operand_stages(plan.epilogue):
        raise ValueError(
            f"scan plans take operand-free epilogue stages only, got "
            f"{[s.op for s in plan.epilogue]}: bias/residual operands "
            "have no blocked layout along the sequential carry")
    R, T = operands[0].shape
    BT = plan.S
    BR = min(block_r, R)
    gr, gt = pl.cdiv(R, BR), pl.cdiv(T, BT)
    pad = ((0, gr * BR - R), (0, gt * BT - T))
    if plan.combine == "linrec":
        a, b = operands
        assert a.shape == b.shape
        padded = [jnp.pad(a, pad, constant_values=1), jnp.pad(b, pad)]
    else:
        padded = [jnp.pad(operands[0], pad)]

    has_carry = carry is not None
    if has_carry:
        c = carry.reshape(R, 1).astype(operands[0].dtype)
        padded.append(jnp.pad(c, ((0, gr * BR - R), (0, 0))))

    kern = make_kernel(has_carry)
    in_specs = [pl.BlockSpec((BR, BT), lambda i, j: (i, j))] * (len(padded)
                                                                - has_carry)
    if has_carry:
        in_specs.append(pl.BlockSpec((BR, 1), lambda i, j: (i, 0)))
    out_specs = pl.BlockSpec((BR, BT), lambda i, j: (i, j))
    out_shape = jax.ShapeDtypeStruct((gr * BR, gt * BT), operands[0].dtype)
    if return_carry:
        # carry-out block ignores j: each sequential step overwrites it,
        # so the value left behind is the final state of the row tile.
        out_specs = (out_specs, pl.BlockSpec((BR, 1), lambda i, j: (i, 0)))
        out_shape = (out_shape,
                     jax.ShapeDtypeStruct((gr * BR, 1), operands[0].dtype))
    res = pl.pallas_call(
        kern,
        grid=(gr, gt),                    # T sequential per row-tile
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=make_scratch(BR),
        interpret=interpret,
    )(*padded)
    if return_carry:
        out, co = res
        return out[:R, :T], co[:R]
    return res[:R, :T]


@functools.partial(
    jax.jit, static_argnames=("plan", "block_r", "interpret", "acc_dtype",
                              "return_carry")
)
def _run_scan_plan_tpu(
    *operands: jax.Array,
    plan: SystolicPlan,
    block_r: int = 8,
    interpret: bool = True,
    acc_dtype=jnp.float32,
    carry: jax.Array | None = None,
    return_carry: bool = False,
):
    """The TPU scan lowering: VPU lane rolls for the Kogge–Stone arrows,
    inter-tile carry in VMEM scratch."""

    def make_kernel(has_carry):
        return functools.partial(_scan_kernel, plan=plan,
                                 acc_dtype=acc_dtype, has_carry=has_carry,
                                 want_carry=return_carry)

    def make_scratch(BR):
        return [pltpu.VMEM((BR, 1), acc_dtype)]

    with _obs_lowering(plan=plan, block=(block_r, plan.S), backend="tpu"):
        return _scan_call(
            *operands, plan=plan, block_r=block_r, interpret=interpret,
            acc_dtype=acc_dtype, carry=carry, return_carry=return_carry,
            make_kernel=make_kernel, make_scratch=make_scratch)


def run_scan_plan(
    *operands: jax.Array,
    plan: SystolicPlan,
    block_r: int = 8,
    interpret: bool = True,
    acc_dtype=jnp.float32,
    carry: jax.Array | None = None,
    return_carry: bool = False,
    backend: str | None = None,
):
    """Lower a scan/recurrence plan over ``(R, T)`` operands.

    ``plan.S`` is the lane-tile width BT (a power of two); T is tiled into
    sequential grid steps whose carries ride in VMEM scratch. Padding uses
    the combine's identity element ('add': 0; 'linrec': (1, 0)) so padded
    tail lanes are no-ops. ``plan.epilogue`` may carry *operand-free*
    elementwise stages (gelu/silu/relu/scale), applied to the stored
    output only — the carry keeps the raw scan state.

    ``carry`` (``(R,)`` or ``(R, 1)``) seeds the VMEM carry — the state
    h₋₁ entering the first tile — and ``return_carry=True`` additionally
    returns the final raw state ``(R, 1)``; together they promote the
    intra-kernel VMEM carry to an inter-chunk carry (DESIGN.md §12).

    ``backend`` picks the lowering ('tpu'/'gpu'/'auto', DESIGN.md §14);
    None defers to :func:`repro.config.engine_backend`. The GPU lowering
    runs Kogge–Stone arrows shorter than a warp as intra-warp shuffles
    and warp-crossing arrows through the shared-memory hand-off.
    """
    from repro.config import engine_backend, resolve_engine_backend

    backend = (resolve_engine_backend(backend) if backend is not None
               else engine_backend())
    kw = dict(plan=plan, block_r=block_r, interpret=interpret,
              acc_dtype=acc_dtype, carry=carry, return_carry=return_carry)
    rfaults.check("engine.scan")
    obs.metrics.inc("engine.launch", f"{backend}:{plan.combine}")
    t0 = time.perf_counter()
    with obs.span("engine.run_scan_plan", cat="engine", kind=plan.kind,
                  backend=backend, strategy=plan.combine):
        if backend == "gpu":
            from . import engine_gpu

            out = engine_gpu.run_scan_plan_gpu(*operands, **kw)
        else:
            out = _run_scan_plan_tpu(*operands, **kw)
    if (obs.drift.per_call()
            and not isinstance(operands[0], jax.core.Tracer)):
        _obs_call_drift(plan, (block_r, plan.S), backend, 1, "shift_psum",
                        out, t0, operands[0].shape)
    return out


def check_chunk_geometry(plan: SystolicPlan, chunk: int) -> None:
    """Pre-pallas guards for the chunk-streamed scan schedule.

    Named errors (PR 4/5 pattern) so bad geometry fails before tracing a
    kernel: the chunk must hold a whole number of lane tiles, and the
    streamed path keeps the raw state in the ``lax.scan`` carry — fused
    epilogues would make the recomputed backward state disagree with the
    stored forward copy, so they are rejected here.
    """
    if plan.epilogue_op_count():
        raise ValueError(
            f"{plan.kind}: epilogue stages are illegal under chunking — the "
            "chunk-streamed schedule carries the raw scan state between "
            "chunks and recomputes it on backward; apply activations to "
            "the streamed output instead")
    if chunk < plan.S:
        raise ValueError(
            f"{plan.kind}: chunk={chunk} is smaller than the lane tile "
            f"S={plan.S}; a chunk must hold at least one Kogge–Stone tile")
    if chunk % plan.S:
        raise ValueError(
            f"{plan.kind}: chunk={chunk} is not a multiple of the lane "
            f"tile S={plan.S}; partial tiles would shift the carry "
            "hand-off off the tile boundary")


def run_scan_plan_chunked(
    *operands: jax.Array,
    plan: SystolicPlan,
    chunk: int,
    block_r: int = 8,
    interpret: bool = True,
    acc_dtype=jnp.float32,
    carry: jax.Array | None = None,
    return_carry: bool = False,
    backend: str | None = None,
):
    """Stream a scan/recurrence plan over ``(R, chunk)`` slabs (§12).

    Runs :func:`run_scan_plan` inside a ``lax.scan`` whose carry is the
    per-row state, so peak live state is O(R·chunk) instead of O(R·T):
    the transfer-pair algebra that already composes across lane shifts
    composes identically across chunks. The body is ``jax.checkpoint``-
    wrapped — reverse-mode through this runner saves only the O(T/chunk)
    chunk-boundary carries and recomputes in-chunk state.
    """
    check_chunk_geometry(plan, chunk)
    R, T = operands[0].shape
    nc = pl.cdiv(T, chunk)
    pad_t = ((0, 0), (0, nc * chunk - T))
    if plan.combine == "linrec":
        a, b = operands
        padded = (jnp.pad(a, pad_t, constant_values=1), jnp.pad(b, pad_t))
    else:
        padded = (jnp.pad(operands[0], pad_t),)
    c0 = (jnp.zeros((R, 1), operands[0].dtype) if carry is None
          else carry.reshape(R, 1).astype(operands[0].dtype))

    def body(c, i):
        slabs = tuple(jax.lax.dynamic_slice_in_dim(o, i * chunk, chunk, 1)
                      for o in padded)
        out, c_new = run_scan_plan(
            *slabs, plan=plan, block_r=block_r, interpret=interpret,
            acc_dtype=acc_dtype, carry=c, return_carry=True,
            backend=backend)
        return c_new, out

    c_fin, outs = jax.lax.scan(jax.checkpoint(body), c0, jnp.arange(nc))
    out = jnp.moveaxis(outs, 0, 1).reshape(R, nc * chunk)[:, :T]
    return (out, c_fin) if return_carry else out
