"""Perf-model-guided autotuner for engine block configurations (§5).

For a given plan + problem shape the tuner enumerates candidate block
configs — ``(block_h, block_w[, block_z], variant)`` for windowed plans,
``(block_r, block_t)`` for scans — prices each with an extension of the
paper's §5 latency model (Eq. 4 compute terms + the §5.3 halo/redundancy
accounting, applied to the *actual* block geometry instead of the warp),
optionally measures the model's top-k candidates with the real kernel,
and caches the winner per (plan, shape, time_steps, backend).

Winners persist: when ``REPRO_TUNING_CACHE`` names a JSON sidecar, every
measured winner is written through to it (keyed by plan signature /
shape / time_steps / backend / context) and the file is loaded on
import, so a warm sidecar makes a cold process perform **zero** tuning
measurements. Shapes never tuned before are *seeded* from the nearest
cached shape of the same plan (log-space distance) instead of retuning —
the engine clamps block configs to the output shape, so a neighbor
shape's winner is always runnable.

Sharding: :func:`shard_tuning_shape` maps a (global shape, mesh
assignment) pair to the halo-extended shard-local shape the engine
actually lowers per device — tune against *that* shape and the winner
stays valid under sharding (the block never exceeds the shard).

Pricing per useful output element (see :func:`model_cost`):

* **compute** — ``t · mads · (T_mad + T_reg)`` plus the shift term
  ``t · shifts · T_shfl`` amortized over the P output rows a roll covers
  (one lane-roll of the whole (P, S) psum block serves all P rows, the
  TPU widening of Eq. 4's per-output (M−1)·T_shfl). ``shift_data``
  halves the effective shift cost: its rolls leave the accumulator
  dependency chain and overlap with FMAs (DESIGN.md §2).
* **memory** — every loaded element costs ``T_gmem/LANES``; the loaded/
  useful ratio is exactly the halo redundancy of §5.3 for the block,
  ``Π(block+t·(ext−1)) / Π(block)``, which temporal blocking widens.

The absolute cycle counts are estimates (the TPU latency row is marked
as such in :mod:`repro.core.perfmodel`); the tuner only consumes the
*ranking*, and the measured pass — which always includes the default
config — guarantees the returned config never loses to the default on
the measured metric.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import time
import zlib
from typing import Callable, Sequence

import jax

from repro import obs
from repro.robust import faults as rfaults
from repro.robust import guard as rguard
from repro.robust.guard import MeasurementError, SidecarError

from .perfmodel import (TPU_V5E, HardwareLatencies, machine_for,
                        mxu_tap_rows)
from .plan import SystolicPlan

SIDECAR_ENV = "REPRO_TUNING_CACHE"
MEASURE_REPS_ENV = "REPRO_MEASURE_REPS"
MEASURE_RETRIES_ENV = "REPRO_MEASURE_RETRIES"
TUNE_BUDGET_ENV = "REPRO_TUNE_BUDGET_S"

# A candidate whose IQR exceeds this fraction of its median is a noisy
# sample: re-measure once before letting it into the ranking (§16.4).
OUTLIER_SPREAD_FRACTION = 0.5

# Engine schema version stamped on every sidecar entry. Bump whenever the
# engine's lowering changes what a measured winner *means* (block
# semantics, grid layout, accumulator placement) — stale entries are
# ignored on load and dropped on the next write-through, so a sidecar
# shipped with a checkpoint ages out instead of silently replaying
# configs measured against a different kernel.
#   v1 — PR 1/2 lowering (spatial grids only).
#   v2 — reduction axes: grid gained out/reduce dims + scratch
#        accumulator; NCHW/batched shapes join the key space.
#   v3 — fused pipelines + epilogues + output-strided grids: kernels may
#        carry extra epilogue operands, iterate stage lists and read
#        stride-scaled input tiles.
#   v4 — chunk-streamed scans: scan winners may carry a third block
#        dimension (the chunk length of the streamed schedule), and the
#        scan kernel gained carry-in/-out ports; v3 scan entries priced a
#        different lowering.
#   v5 — lowering strategy: windowed winners carry a ``strategy`` field
#        ('lanes' VPU schedule vs 'mxu' im2row dot_general, DESIGN.md
#        §13) and sidecar keys gain a sixth component (the plan's pinned
#        strategy, or 'auto') so nearest-shape seeding never crosses
#        strategies; v4 entries never tuned over the algorithm choice.
#   v6 — engine backend: sidecar keys gain a seventh component (the
#        engine backend, 'tpu' | 'gpu', DESIGN.md §14) and candidates
#        come from backend-specific grids (warp-multiple pow2 tiles on
#        GPU vs 8×128 sublane/lane tiles on TPU), so a winner measured
#        against one lowering never replays — or seeds — the other;
#        v5 entries never recorded which lowering they measured.
#   v7 — measurement spread: entries carry the ``spread_us`` (IQR across
#        :func:`measure_us` reps) of the winning measurement, so drift
#        analysis (DESIGN.md §15) can tell noisy wins from modeled ones;
#        v6 entries carry medians whose confidence is unknown, and a
#        replayed winner with unknown noise is exactly what the drift
#        monitor exists to rule out.
ENGINE_SCHEMA_VERSION = 7

# VMEM working-set budget per block (f32 elements): input block + psum +
# output must fit comfortably in ~16 MB VMEM; stay conservative.
VMEM_BUDGET_ELEMS = 1 << 20

_WINDOW_BLOCK_H = (8, 16, 32, 64)
_WINDOW_BLOCK_W = (128, 256, 512)
_WINDOW_BLOCK_Z = (4, 8, 16)
_SCAN_BLOCK_R = (8, 16, 32)
_SCAN_BLOCK_T = (128, 256, 512, 1024)
_SCAN_CHUNK_TILES = (1, 2, 4)        # chunk = m × lane tile (streamed scans)

# GPU candidate grids (DESIGN.md §14): warp-multiple pow2 tiles — the
# Triton tile-chooser idiom (BLOCK = next_pow2(n), masked overhang)
# rather than the TPU's 8×128 sublane/lane tiling. Lane tiles are whole
# multiples of the 32-lane warp so every shift_psum hop decomposes into
# intra-warp shuffles + whole-warp hand-offs; row tiles stay small
# because GPU blocks hold 4 warps, not 8 sublanes of a VREG.
_GPU_BLOCK_H = (4, 8, 16, 32)
_GPU_BLOCK_W = (32, 64, 128, 256)
_GPU_BLOCK_Z = (2, 4, 8)


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def _engine_backend(backend: str | None) -> str:
    """Resolve the tuner's backend argument against the config default."""
    from repro.config import engine_backend, resolve_engine_backend

    return (engine_backend() if backend is None
            else resolve_engine_backend(backend))


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One candidate schedule: output block per windowed axis + variant
    + (since schema v5) the lowering strategy — the tuner's first choice
    between *algorithms* rather than block geometries (DESIGN.md §13).
    ``strategy=None`` means "whatever the plan says" (auto → lanes)."""

    block: tuple[int, ...]          # lane axis last
    variant: str = "shift_psum"
    strategy: str | None = None     # None | 'lanes' | 'mxu'

    def as_kwargs(self, plan: SystolicPlan) -> dict:
        """Render into the kwargs the thin kernel wrappers accept."""
        if plan.combine != "fma":
            kw = {"block_r": self.block[0], "block_t": self.block[1]}
            if len(self.block) == 3:        # chunk-streamed scan (§12)
                kw["chunk"] = self.block[2]
            return kw
        if plan.kind == "conv1d":
            kw = {"block_t": self.block[0], "block_d": self.block[1]}
            if self.strategy is not None:
                kw["strategy"] = self.strategy
            return kw
        kw = {"block_h": self.block[-2], "block_w": self.block[-1]}
        if plan.ndim_spatial == 3:
            kw["block_z"] = self.block[0]
        if plan.M > 1:
            kw["variant"] = self.variant
        if self.strategy is not None:
            kw["strategy"] = self.strategy
        return kw


@dataclasses.dataclass(frozen=True)
class TuneResult:
    config: KernelConfig
    model_cost: float               # est. cycles per useful output
    measured_us: float | None       # None when model-only
    source: str                     # 'model' | 'measured' | 'cache'


_CACHE: dict[tuple, TuneResult] = {}


def clear_cache() -> None:
    _CACHE.clear()


def _cache_key(plan: SystolicPlan, shape: tuple[int, ...], time_steps: int,
               context: tuple = (), backend: str = "tpu"):
    # jax.default_backend() is the device *platform* (cpu/tpu/gpu host);
    # ``backend`` is the engine lowering ('tpu'/'gpu' kernel shape) —
    # both dimensions key winners, e.g. interpret-mode GPU lowering on a
    # CPU host is (platform='cpu', backend='gpu').
    return (plan, tuple(shape), time_steps, jax.default_backend(), context,
            backend)


# ---------------------------------------------------------------------------
# JSON sidecar persistence + nearest-shape seeding
# ---------------------------------------------------------------------------

def plan_signature(plan: SystolicPlan) -> str:
    """Stable cross-process identity of a plan's schedule + geometry.

    Adjoint plans key apart automatically: ``core.adjoint`` derives
    backward plans with ``adj_``/``wgrad_``-prefixed kinds and
    reflected taps / swapped lead-trail, so a backward-input winner
    never replays a forward winner (and vice versa) in the cache or the
    sidecar — the adjoint is a different kernel with its own block
    optimum (DESIGN.md §10.3).
    """
    digest = hashlib.sha1(repr(plan).encode()).hexdigest()[:16]
    return f"{plan.kind}-{digest}"


def _jsonable(obj):
    if isinstance(obj, (tuple, list)):
        return [_jsonable(o) for o in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def _sidecar_key(sig: str, shape, time_steps: int, context: tuple,
                 strategy: str = "auto", backend: str = "tpu") -> str:
    # strategy is the *plan's* pinned strategy (or 'auto'): a plan pinned
    # to 'mxu' must never replay — or seed from — winners tuned while the
    # tuner was free to pick, and vice versa. backend (v6, seventh
    # component) is the engine lowering the winner was measured against:
    # a GPU warp-tile winner means nothing to the TPU kernel and vice
    # versa, so winners never cross backends.
    return json.dumps([sig, list(shape), time_steps, jax.default_backend(),
                       _jsonable(context), strategy, backend])


# sidecar key → (KernelConfig, model_cost, measured_us)
_SIDECAR: dict[str, tuple[KernelConfig, float, float | None]] = {}
# Measurement spread (IQR µs across reps) rides in a parallel map rather
# than widening the tuple above: tests and checkpoint code construct /
# unpack 3-tuples directly, and spread is v7 metadata, not identity.
_SIDECAR_SPREAD: dict[str, float] = {}


def sidecar_path() -> str | None:
    return os.environ.get(SIDECAR_ENV) or None


def entry_crc(val: dict) -> str:
    """Per-entry checksum over the fields that make a winner a winner.

    Computed over the canonical JSON of the identity-bearing fields (not
    the raw file bytes), so a sidecar re-serialized with different
    whitespace/key order still verifies, while a flipped block size or
    strategy does not."""
    payload = json.dumps([
        _jsonable(val.get("block")), val.get("variant"), val.get("strategy"),
        val.get("model_cost"), val.get("measured_us"), val.get("schema"),
    ])
    return format(zlib.crc32(payload.encode()), "08x")


def _entry_ok(val: dict) -> bool:
    """Schema + checksum gate shared by every sidecar ingest path.

    Wrong-schema entries are *stale* (measured against a different
    lowering); entries whose stored ``crc`` disagrees with the recomputed
    one are *corrupt* (bit-rotted or hand-edited). Entries with no crc at
    all pass — pre-hardening v7 sidecars (and tests that hand-write
    entries) stay loadable; they pick up checksums on the next save."""
    if not isinstance(val, dict) or val.get("schema", 1) != ENGINE_SCHEMA_VERSION:
        obs.metrics.inc("tuner.sidecar_stale")
        return False
    if "crc" in val and val["crc"] != entry_crc(val):
        obs.metrics.inc("tuner.sidecar_corrupt_entry")
        return False
    return True


def _quarantine_sidecar(path: str, err: Exception,
                        on_corrupt: str | None) -> int:
    """Handle an unreadable/corrupt sidecar *file* per policy.

    ``'raise'`` surfaces a :class:`SidecarError` naming the site;
    ``'quarantine'`` renames the file to ``<path>.corrupt`` (so the next
    save starts fresh and the evidence survives for inspection), bumps
    ``tuner.sidecar_quarantined`` and reports zero entries loaded.
    ``None`` resolves from the session failure policy."""
    mode = on_corrupt
    if mode is None:
        mode = "raise" if rguard.on_failure() == "raise" else "quarantine"
    if mode == "raise":
        raise SidecarError(
            f"tuning.sidecar.load: corrupt/unreadable sidecar {path!r}: "
            f"{type(err).__name__}: {err}") from err
    obs.metrics.inc("tuner.sidecar_quarantined")
    try:
        os.replace(path, path + ".corrupt")
    except OSError:
        pass    # already gone / unwritable dir: fresh start regardless
    return 0


def load_sidecar(path: str, *, on_corrupt: str | None = None) -> int:
    """Merge a sidecar file into the persistent store; returns #entries.

    Entries whose ``schema`` does not match :data:`ENGINE_SCHEMA_VERSION`
    are *stale* — measured against a different engine lowering — and are
    skipped (the next :func:`save_sidecar` rewrites the file without
    them, so staleness ages out rather than accumulating). Entries whose
    per-entry checksum fails, or that are structurally broken, are
    skipped individually (``tuner.sidecar_corrupt_entry``). A file that
    cannot be parsed at all goes through :func:`_quarantine_sidecar`:
    under ``on_corrupt='quarantine'`` (or failure policy 'fallback') it
    is renamed ``*.corrupt`` and loading reports 0 entries; under
    ``'raise'`` a :class:`SidecarError` names the site.
    """
    try:
        rfaults.check("tuning.sidecar.load")
        with open(path) as f:
            doc = json.load(f)
        entries = doc.get("entries", {})
        if not isinstance(entries, dict):
            raise ValueError("sidecar 'entries' is not an object")
    except (KeyboardInterrupt, SystemExit):
        raise
    except Exception as e:
        return _quarantine_sidecar(path, e, on_corrupt)
    n = 0
    with obs.span("tuner.load_sidecar", cat="tuner", path=path):
        for key, val in entries.items():
            if not _entry_ok(val):
                continue
            try:
                cfg = KernelConfig(tuple(val["block"]),
                                   val.get("variant", "shift_psum"),
                                   val.get("strategy"))
            except (KeyError, TypeError):
                obs.metrics.inc("tuner.sidecar_corrupt_entry")
                continue
            _SIDECAR[key] = (cfg, val.get("model_cost", 0.0),
                             val.get("measured_us"))
            if val.get("spread_us") is not None:
                _SIDECAR_SPREAD[key] = float(val["spread_us"])
            n += 1
    obs.metrics.inc("tuner.sidecar_load", n=n)
    return n


def _wire_entry(key: str, cfg: KernelConfig, cost, us) -> dict:
    """One sidecar entry in wire format, checksum stamped last."""
    val = {"block": list(cfg.block), "variant": cfg.variant,
           "strategy": cfg.strategy,
           "model_cost": cost, "measured_us": us,
           "spread_us": _SIDECAR_SPREAD.get(key),
           "schema": ENGINE_SCHEMA_VERSION}
    val["crc"] = entry_crc(val)
    return val


def save_sidecar(path: str | None = None) -> str | None:
    """Atomically write the persistent store to ``path`` (or the env path).

    Re-merges the file first so concurrent processes sharing one sidecar
    keep each other's winners (this process's entries win conflicts);
    an unreadable pre-existing file is counted (``tuner.sidecar_remerge_
    failed``) and overwritten — the atomic tmp+rename means a failed
    *write* never destroys the old file. Write failures follow the
    failure policy: 'raise' surfaces a :class:`SidecarError` naming the
    ``tuning.sidecar.save`` site, 'fallback' counts
    ``tuner.sidecar_save_failed`` and keeps the process alive (the store
    is still in memory; the next save retries).
    """
    path = path or sidecar_path()
    if not path:
        return None
    if os.path.exists(path):
        try:
            load_file_only = json.load(open(path)).get("entries", {})
            for key, val in load_file_only.items():
                # Stale-schema / corrupt entries are dropped here:
                # ignored on load, not re-merged on save — the rewrite
                # ages them out.
                if not _entry_ok(val):
                    continue
                if key not in _SIDECAR:
                    _SIDECAR[key] = (
                        KernelConfig(tuple(val["block"]),
                                     val.get("variant", "shift_psum"),
                                     val.get("strategy")),
                        val.get("model_cost", 0.0), val.get("measured_us"))
                    if val.get("spread_us") is not None:
                        _SIDECAR_SPREAD[key] = float(val["spread_us"])
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception:
            # unreadable file: overwrite with our entries, but visibly
            obs.metrics.inc("tuner.sidecar_remerge_failed")
    entries = {
        key: _wire_entry(key, cfg, cost, us)
        for key, (cfg, cost, us) in sorted(_SIDECAR.items())
    }
    try:
        rfaults.check("tuning.sidecar.save")
        tmp = f"{path}.tmp.{os.getpid()}"
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(tmp, "w") as f:
            json.dump({"version": 1, "entries": entries}, f, indent=1)
        os.replace(tmp, path)
    except (KeyboardInterrupt, SystemExit):
        raise
    except Exception as e:
        if rguard.on_failure() == "raise":
            raise SidecarError(
                f"tuning.sidecar.save: failed writing sidecar {path!r}: "
                f"{type(e).__name__}: {e}") from e
        obs.metrics.inc("tuner.sidecar_save_failed")
        return None
    return path


def _sidecar_store(skey: str, result: TuneResult) -> None:
    """Write-through of a measured winner — only when persistence is on
    (env path set or a sidecar explicitly loaded), so that without a
    sidecar the tuner's in-process behavior is unchanged."""
    if not sidecar_path() and not _SIDECAR:
        return
    _SIDECAR[skey] = (result.config, result.model_cost, result.measured_us)
    if sidecar_path():
        save_sidecar()


def _nearest_sidecar(sig: str, shape, time_steps: int, context: tuple,
                     strategy: str = "auto",
                     backend: str = "tpu") -> KernelConfig | None:
    """The winner of the closest already-tuned shape of the same plan.

    Same plan signature, time_steps, platform, context, pinned
    strategy **and engine backend** — a neighbor tuned under a different
    strategy pin ran a different algorithm, and one tuned against the
    other backend ran a different kernel entirely, so neither may seed
    this one (the v5/v6 key components exist precisely to enforce that).
    Closest by summed |log| ratio of extents. Seeding replays that
    winner with no measurement — the engine clamps blocks to the output
    shape, so the neighbor's config is always runnable on the new shape.
    """
    want = [sig, time_steps, jax.default_backend(), _jsonable(context),
            strategy, backend]
    best, best_d = None, None
    for key, (cfg, _, _) in _SIDECAR.items():
        try:
            ksig, kshape, kt, kplat, kctx, kstrat, kback = json.loads(key)
        except ValueError:      # pre-v6 key arity smuggled past the
            continue            # schema gate: never a seed candidate
        if ([ksig, kt, kplat, kctx, kstrat, kback] != want
                or len(kshape) != len(shape)):
            continue
        d = sum(abs(math.log(k / s)) for k, s in zip(kshape, shape))
        if best_d is None or d < best_d:
            best, best_d = cfg, d
    return best


def clear_sidecar() -> None:
    _SIDECAR.clear()
    _SIDECAR_SPREAD.clear()


def sidecar_entries() -> dict:
    """The persistent store as a JSON-ready entries dict (schema-stamped,
    same wire format as :func:`save_sidecar`). Checkpoints embed this so
    tuned winners survive host moves (DESIGN.md §13)."""
    return {
        key: _wire_entry(key, cfg, cost, us)
        for key, (cfg, cost, us) in sorted(_SIDECAR.items())
    }


def merge_sidecar_entries(entries: dict) -> int:
    """Merge checkpoint-shipped entries into the store; returns #merged.

    Mirrors :func:`load_sidecar`'s staleness + checksum rules
    (wrong-schema or crc-failing entries are skipped) but **never
    clobbers** an existing key: the live process's winners — possibly
    measured on *this* host — outrank whatever the checkpoint carried.
    Does not write through to the env sidecar; the next measured winner
    does, via the usual path.
    """
    n = 0
    for key, val in (entries or {}).items():
        if not _entry_ok(val) or key in _SIDECAR:
            continue
        cfg = KernelConfig(tuple(val["block"]), val.get("variant", "shift_psum"),
                           val.get("strategy"))
        _SIDECAR[key] = (cfg, val.get("model_cost", 0.0), val.get("measured_us"))
        if val.get("spread_us") is not None:
            _SIDECAR_SPREAD[key] = float(val["spread_us"])
        n += 1
    return n


# Import must never break on a bad sidecar, whatever the failure policy:
# force quarantine mode here (rename *.corrupt + counter + fresh start)
# instead of the old silent `except Exception` swallow.
if sidecar_path() and os.path.exists(sidecar_path()):
    load_sidecar(sidecar_path(), on_corrupt="quarantine")


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------

def candidate_configs(
    plan: SystolicPlan,
    shape: Sequence[int],
    time_steps: int = 1,
    *,
    vmem_budget: int = VMEM_BUDGET_ELEMS,
    chunked: bool = False,
    backend: str = "tpu",
) -> list[KernelConfig]:
    """Feasible block configs for ``plan`` on a problem of ``shape``.

    Blocks are clamped to the output shape, deduplicated, and filtered by
    the VMEM working-set budget (input block + halo, widened by temporal
    blocking). Scan plans tune (block_r, block_t) with power-of-two lane
    tiles; ``chunked=True`` (the streamed schedule, DESIGN.md §12) grows
    a third chunk-length dimension — whole multiples of the lane tile, so
    every candidate passes the chunk-geometry guards; windowed plans tune
    the output tile, the schedule variant, and — when the plan leaves
    ``strategy`` unpinned — the lowering *algorithm* ('lanes' vs 'mxu',
    DESIGN.md §13). MXU candidates carry one canonical variant: the
    im2row views are static crops, so the psum/data-stationary knob is
    moot under that strategy and enumerating both would make the runner
    time the identical kernel twice.

    ``backend`` selects the grid family (DESIGN.md §14): the TPU grids
    are sublane/lane-tiled (8×128-shaped), the GPU grids warp-multiple
    pow2 tiles clamped by the Triton ``next_pow2`` idiom (a tile may
    overhang the output; the grid round-up masks the overhang) so every
    candidate keeps whole-warp shuffle decompositions. Scan tiles are
    already pow2 warp multiples and are shared across backends.
    """
    if plan.combine != "fma":                       # scan family
        R, T = shape
        out: list[KernelConfig] = []
        for br in _SCAN_BLOCK_R:
            for bt in _SCAN_BLOCK_T:
                bt_eff = 1 << (min(bt, T).bit_length() - 1)
                if not chunked:
                    cfg = KernelConfig((min(br, R), bt_eff))
                    if cfg.block[0] * cfg.block[1] <= vmem_budget:
                        out.append(cfg)
                    continue
                for mult in _SCAN_CHUNK_TILES:      # chunk = m lane tiles
                    chunk = bt_eff * mult
                    if chunk > max(T, bt_eff):
                        continue
                    cfg = KernelConfig((min(br, R), bt_eff, chunk))
                    if cfg.block[0] * chunk <= vmem_budget:
                        out.append(cfg)
        return sorted(set(out), key=lambda c: c.block)

    spatial = tuple(shape)[plan.batch_axes + plan.reduce_axes:]
    out_sp = plan.out_shape(spatial, time_steps)
    gpu = backend == "gpu"
    axes: list[tuple[int, ...]] = []
    if plan.ndim_spatial == 3:
        axes.append(_GPU_BLOCK_Z if gpu else _WINDOW_BLOCK_Z)
    axes.append(_GPU_BLOCK_H if gpu else _WINDOW_BLOCK_H)
    axes.append(_GPU_BLOCK_W if gpu else _WINDOW_BLOCK_W)
    # TPU clamps a candidate to the output extent; GPU clamps to the
    # next pow2 ≥ the extent (tile-chooser idiom) so tiles stay
    # warp-decomposable — the engine's own min(b, out) does the rest.
    clamp = ((lambda b, o: min(b, _next_pow2(o))) if gpu
             else (lambda b, o: min(b, o)))
    if any(v > 1 for v in plan.stride_per_axis()):
        # strided grids use the data-stationary strided read — the
        # variant knob does not apply.
        variants = ("shift_data",)
    else:
        variants = (("shift_psum", "shift_data") if plan.shift_count()
                    else ("shift_psum",))

    if plan.strategy is None:
        # Auto: the tuner owns the algorithm choice. Strategies are
        # explicit on the candidates so a sidecar replay of the winner
        # pins the same lowering on a later, untuned process.
        strat_opts = [("lanes", variants), ("mxu", variants[:1])]
    elif plan.strategy == "mxu":
        # Pinned: candidates restate the pin (so measurement closures
        # that rebuild the plan from kwargs lower the pinned kernel);
        # only the variant knob remains, and under 'mxu' that too
        # collapses to one canonical value.
        strat_opts = [("mxu", variants[:1])]
    else:
        strat_opts = [("lanes", variants)]

    configs: set[KernelConfig] = set()
    def rec(i: int, acc: tuple[int, ...]):
        if i == len(axes):
            if math.prod(plan.block_in_shape(acc, time_steps)) > vmem_budget:
                return
            for s, svariants in strat_opts:
                for v in svariants:
                    configs.add(KernelConfig(acc, v, s))
            return
        for b in axes[i]:
            rec(i + 1, acc + (clamp(b, out_sp[i]),))
    rec(0, ())
    return sorted(configs, key=lambda c: (c.block, c.variant, c.strategy or ""))


# ---------------------------------------------------------------------------
# §5-model pricing
# ---------------------------------------------------------------------------

def model_cost(
    plan: SystolicPlan,
    cfg: KernelConfig,
    time_steps: int = 1,
    hw: HardwareLatencies | None = None,
    *,
    backend: str | None = None,
) -> float:
    """Estimated cycles per useful output element for one block config.

    ``hw`` prices against an explicit latency row; when None it resolves
    from the machine registry for ``backend``
    (:func:`repro.core.perfmodel.machine_for` — 'tpu' → TPU_V5E, 'gpu' →
    the A100-shaped entry; ``backend=None`` follows the config default).
    Each backend is priced by **its own** machine model, never the
    other's: that per-backend prediction is what BENCH_8 quotes next to
    measurements.

    For reduce plans (NCHW conv) this is the cost of *one channel
    iterate* per output element; the full per-output cost scales by
    ``C_in``, which multiplies every candidate identically and so drops
    out of the ranking (the bench applies the C_in factor when quoting
    absolute predictions).

    A fused pipeline (``plan.stages``) prices as one kernel: the flop
    terms are the *summed* stage MADs/shifts (``plan`` methods sum over
    stages) against a **single** load+store whose redundancy uses the
    chain-widened composite halo — whereas the unfused sequence pays the
    memory term once per stage. Epilogue stages add one VPU op each.
    Output strides shrink useful outputs per loaded element, which
    ``block_in_shape``'s stride term prices automatically.
    """
    if hw is None:
        hw = machine_for(_engine_backend(backend))
    t = time_steps
    if plan.combine != "fma":                       # Kogge–Stone scan
        br, bt = cfg.block[:2]
        steps = math.log2(max(bt, 2))
        ops_per_elem = 2.0 if plan.combine == "linrec" else 1.0
        compute = steps * ops_per_elem * (hw.t_shfl + hw.t_mad + hw.t_reg)
        carry = (hw.t_smem_read + hw.t_mad) / bt    # inter-block carry
        memory = hw.t_gmem_read / plan.S
        if len(cfg.block) == 3:                     # streamed schedule (§12)
            # inter-chunk hand-off: the carry round-trips HBM between the
            # lax.scan steps and the slab is re-sliced per chunk — one
            # extra read + scratch touch amortized over chunk elements.
            carry += (hw.t_gmem_read + hw.t_smem_read) / cfg.block[2]
        return compute + carry + memory

    block = cfg.block
    useful = math.prod(block)
    loaded = math.prod(plan.block_in_shape(block, t))
    memory = (loaded / useful) * hw.t_gmem_read / plan.S
    if (cfg.strategy or plan.strategy) == "mxu":
        # §13 im2row pricing: each alignment-padded tap row costs one
        # staged gather + one MXU MAC; no lane shifts (the views are
        # static crops). Padding is priced like real rows, so small
        # footprints lose to the 8-row floor and wide tap sets win —
        # the shape-dependent flip the strategy dimension exists for.
        stages = plan.stages or (plan,)
        rows = sum(mxu_tap_rows(s.mads_per_output_window()) for s in stages)
        compute = t * rows * (hw.t_mxu_stage + hw.t_mxu_mac)
        compute += plan.epilogue_op_count() * hw.t_mad
        return compute + memory
    mads = plan.mads_per_output_window()
    shifts = plan.shift_count()
    P = block[-2]                                   # rows one roll amortizes
    shfl = hw.t_shfl * (0.5 if cfg.variant == "shift_data" else 1.0)
    compute = t * mads * (hw.t_mad + hw.t_reg) + t * shifts * shfl / max(P, 1)
    compute += plan.epilogue_op_count() * hw.t_mad  # fused output stages
    return compute + memory


# ---------------------------------------------------------------------------
# Measurement + the tuner
# ---------------------------------------------------------------------------

class Measurement(float):
    """A measured median that still *is* its µs float — every existing
    consumer (min/sort/format/JSON) handles it unchanged — but carries
    the sample dispersion: ``spread_us`` is the inter-quartile range
    across reps (0.0 when reps < 3 can't resolve quartiles) and
    ``reps`` the sample count. Monkeypatched stand-ins that return bare
    floats stay legal; readers use ``getattr(us, "spread_us", None)``."""

    __slots__ = ("spread_us", "reps")

    def __new__(cls, median_us: float, spread_us: float = 0.0, reps: int = 1):
        m = super().__new__(cls, median_us)
        m.spread_us = float(spread_us)
        m.reps = int(reps)
        return m


def measure_us(fn: Callable[[], jax.Array],
               reps: int | None = None) -> "Measurement":
    """Median wall-time (µs) of ``fn`` post-warmup.

    ``reps`` defaults to ``$REPRO_MEASURE_REPS`` (else 3) so noisy hosts
    (CI) can buy tighter medians without touching call sites. Returns a
    :class:`Measurement` — a float subclass whose ``spread_us`` (IQR
    across the reps) the tuner persists next to the winner (schema v7)
    and the drift monitor uses to separate noise from model error.

    Unusable samples raise a named :class:`MeasurementError` instead of
    leaking into the ranking: a non-finite warmup output (the kernel
    under time produced NaN/Inf — its speed is meaningless) or a
    non-finite/negative median (a clock anomaly). The tuner's
    per-candidate wrapper converts that into retry-then-quarantine.
    """
    rfaults.check("tuning.measure")
    if reps is None:
        try:
            reps = int(os.environ.get(MEASURE_REPS_ENV, "") or 3)
        except ValueError:
            reps = 3
    reps = max(reps, 1)
    out = fn()
    jax.block_until_ready(out)
    if rguard.has_nonfinite(out):
        raise MeasurementError(
            "tuning.measure: candidate produced non-finite output during "
            "warmup — refusing to rank a kernel that computes garbage")
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    median = ts[len(ts) // 2] * 1e6
    iqr = (ts[(3 * (len(ts) - 1)) // 4] - ts[(len(ts) - 1) // 4]) * 1e6
    if not math.isfinite(median) or median < 0:
        raise MeasurementError(
            f"tuning.measure: non-finite/negative median {median!r} µs "
            f"across {reps} reps")
    return Measurement(median, iqr, reps)


def _measure_candidate(runner, cfg: KernelConfig, *, backend: str,
                       retries: int | None = None):
    """One candidate through the hardened measurement path (§16.4).

    Retry-with-backoff on failure, one extra re-measurement when the
    sample is an IQR outlier (spread > ``OUTLIER_SPREAD_FRACTION`` of
    the median — a noisy sample must not decide the ranking), and
    quarantine (returns ``None``) when every attempt fails — so one bad
    candidate can neither win nor abort the sweep. Under
    ``on_failure='raise'`` an injected fault or measurement error
    surfaces immediately as a structured :class:`GuardedExecutionError`;
    organic exceptions re-raise unchanged.
    """
    if retries is None:
        try:
            retries = int(os.environ.get(MEASURE_RETRIES_ENV, "") or 2)
        except ValueError:
            retries = 2
    backoff = 0.005
    for attempt in range(retries + 1):
        try:
            us = runner(cfg)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:
            if rguard.on_failure() == "raise":
                if isinstance(e, (rfaults.FaultInjected, MeasurementError)):
                    raise rguard.GuardedExecutionError(
                        "tuner.measure", [(f"candidate {cfg.block}", e)]
                    ) from e
                raise
            obs.metrics.inc("tuner.measure_retry", backend)
            if attempt < retries:
                time.sleep(backoff * (2 ** attempt))
            continue
        m = float(us)
        if not math.isfinite(m) or m < 0:
            # runner bypassed measure_us (bare-float stand-ins): apply
            # the same rejection here so garbage never enters min().
            obs.metrics.inc("tuner.measure_nonfinite", backend)
            continue
        spread = getattr(us, "spread_us", 0.0) or 0.0
        if spread > OUTLIER_SPREAD_FRACTION * max(m, 1e-9) and attempt < retries:
            obs.metrics.inc("tuner.measure_outlier", backend)
            continue
        return us
    obs.metrics.inc("tuner.quarantined", backend)
    return None


def autotune(
    plan: SystolicPlan,
    shape: Sequence[int],
    *,
    time_steps: int = 1,
    default: KernelConfig | None = None,
    runner: Callable[[KernelConfig], float] | None = None,
    hw: HardwareLatencies | None = None,
    top_k: int = 3,
    context: tuple = (),
    fixed: dict | None = None,
    chunked: bool = False,
    backend: str | None = None,
) -> TuneResult:
    """Pick a block config for ``plan`` on ``shape``.

    Ranks candidates by :func:`model_cost`; when ``runner`` is given
    (a ``cfg → µs`` measurement closure) the model's top-k **plus the
    default config** are measured and the measured winner is returned —
    so the result can never regress the default on the measured metric.
    Winners are cached per (plan, shape, time_steps, backend, context);
    ``context`` must capture anything else that changes what the runner
    actually measures (caller-forced kwargs, op mode, impl), otherwise a
    winner measured under one context is replayed under another.

    ``backend`` is the engine lowering being tuned ('tpu'/'gpu'/'auto';
    None follows the config default): it selects the candidate grid
    family and — unless ``hw`` overrides — the machine model, and it
    keys the cache and the v6 sidecar so winners never cross backends
    (DESIGN.md §14). The caller's runner must lower with the same
    backend, or the recorded winner prices one kernel and replays
    another.

    ``fixed`` names kwargs the caller pins (they override the candidate
    at run time): candidates are restricted to those agreeing with the
    pinned values — and deduplicated by their *effective* kwargs — so the
    runner never measures the same kernel twice and the recorded winner
    is the config that actually ran.
    """
    backend = _engine_backend(backend)
    if hw is None:
        hw = machine_for(backend)
    key = _cache_key(plan, tuple(shape), time_steps, context, backend)
    if key in _CACHE:
        obs.metrics.inc("tuner.cache_hit", backend)
        cached = _CACHE[key]
        return dataclasses.replace(cached, source="cache")

    def _agrees(cfg: KernelConfig) -> bool:
        return not fixed or all(
            cfg.as_kwargs(plan).get(k, v) == v for k, v in fixed.items())

    if (default is not None and default.strategy is None
            and plan.combine == "fma" and plan.strategy is not None):
        # Under a pinned plan every measured config runs the pinned
        # lowering anyway — restate the pin on the default (as
        # candidate_configs does) so a default win records a config
        # whose strategy matches its sidecar key.
        default = dataclasses.replace(default, strategy=plan.strategy)

    sig = plan_signature(plan)
    pstrat = (plan.strategy or "auto") if plan.combine == "fma" else "auto"
    skey = _sidecar_key(sig, shape, time_steps, context, pstrat, backend)
    hit = _SIDECAR.get(skey)
    if hit is not None and _agrees(hit[0]):
        obs.metrics.inc("tuner.sidecar_hit", backend)
        result = TuneResult(hit[0], hit[1], hit[2], "sidecar")
        _CACHE[key] = result
        return result
    with obs.span("tuner.seed", cat="tuner", plan=sig, backend=backend):
        seed = _nearest_sidecar(sig, shape, time_steps, context, pstrat,
                                backend)
    if seed is not None and _agrees(seed):
        obs.metrics.inc("tuner.sidecar_seed", backend)
        result = TuneResult(seed, model_cost(plan, seed, time_steps, hw),
                            None, "seeded")
        _CACHE[key] = result
        return result
    obs.metrics.inc("tuner.sidecar_miss", backend)

    with obs.span("tuner.candidates", cat="tuner", plan=sig, backend=backend):
        cands = candidate_configs(plan, shape, time_steps, chunked=chunked,
                                  backend=backend)
    if default is not None and default not in cands:
        cands.append(default)
    if fixed:
        agreeing = [c for c in cands
                    if all(c.as_kwargs(plan).get(k, v) == v
                           for k, v in fixed.items())]
        if agreeing:
            cands = agreeing
        else:      # pinned value outside the grid: dedupe by what runs
            seen: dict[tuple, KernelConfig] = {}
            for c in cands:
                eff = tuple(sorted({**c.as_kwargs(plan), **fixed}.items()))
                seen.setdefault(eff, c)
            cands = list(seen.values())
    if not cands:
        raise ValueError(f"no feasible block configs for {plan.kind} {shape}")
    ranked = sorted(cands, key=lambda c: model_cost(plan, c, time_steps, hw))

    if runner is None:
        best = ranked[0]
        result = TuneResult(best, model_cost(plan, best, time_steps, hw),
                            None, "model")
    else:
        if plan.combine == "fma" and plan.strategy is None:
            # Open algorithm choice (DESIGN.md §13): measure the model's
            # top-k of EACH strategy present, not the global top-k — the
            # model proposes a per-strategy shortlist, measurement gets
            # the final say *across* algorithms. A global top-k could be
            # one strategy wall-to-wall and silently never time the
            # other lowering on this hardware.
            by_strat: dict[str | None, list[KernelConfig]] = {}
            for c in ranked:
                by_strat.setdefault(c.strategy, []).append(c)
            to_measure = [c for group in by_strat.values()
                          for c in group[:top_k]]
        else:
            to_measure = list(ranked[:top_k])
        if default is not None and default not in to_measure:
            to_measure.append(default)
        try:
            budget_s = float(os.environ.get(TUNE_BUDGET_ENV, "") or 0.0)
        except ValueError:
            budget_s = 0.0
        deadline = (time.monotonic() + budget_s) if budget_s > 0 else None
        timed = []
        for idx, c in enumerate(to_measure):
            if deadline is not None and timed and time.monotonic() > deadline:
                # Wall-clock budget exhausted: rank what we have. Never
                # skip the *first* candidate — a budget too small to
                # measure anything would silently become model-only.
                obs.metrics.inc("tuner.budget_skipped", backend,
                                n=len(to_measure) - idx)
                break
            with obs.span("tuner.measure", cat="tuner", plan=sig,
                          backend=backend, block=list(c.block),
                          variant=c.variant, strategy=c.strategy or "auto"):
                us_c = _measure_candidate(runner, c, backend=backend)
            if us_c is None:
                continue        # quarantined: neither wins nor aborts
            obs.metrics.inc("tuner.measure", backend)
            # Every measured candidate is a free (predicted, measured)
            # drift sample — not just the winner (DESIGN.md §15).
            obs.drift.record(sig, backend, c.strategy,
                             model_cost(plan, c, time_steps, hw),
                             float(us_c), shape=tuple(shape))
            timed.append((us_c, c))
        if not timed:
            # Every measurement quarantined: fall back to the model's
            # ranking rather than crashing the sweep — the §5 model is
            # exactly the prior we keep for when measurement is broken.
            obs.metrics.inc("tuner.model_fallback", backend)
            best = ranked[0]
            result = TuneResult(best, model_cost(plan, best, time_steps, hw),
                                None, "model_fallback")
        else:
            us, best = min(timed, key=lambda p: p[0])
            result = TuneResult(best, model_cost(plan, best, time_steps, hw),
                                us, "measured")
            _sidecar_store(skey, result)
            spread = getattr(us, "spread_us", None)
            if spread is not None and skey in _SIDECAR:
                _SIDECAR_SPREAD[skey] = float(spread)
    _CACHE[key] = result
    return result


# ---------------------------------------------------------------------------
# Shard-local tuning
# ---------------------------------------------------------------------------

def shard_tuning_shape(
    plan: SystolicPlan,
    global_spatial: Sequence[int],
    mesh_per_axis: Sequence[tuple[str, int] | None],
    time_steps: int = 1,
    boundary: str = "zero",
) -> tuple[int, ...]:
    """The halo-extended shard-local shape a sharded run lowers per device.

    This — not the global shape — is what per-shard block configs must
    be tuned against: the engine inside ``shard_map`` sees
    ``local + halo_lo + halo_hi`` rows per sharded axis (under
    'wrap'/'replicate' boundaries, per *every* axis — unsharded axes
    halo-extend locally too). A winner measured on this shape is the
    monolithic (``overlap=False``) per-device lowering; the overlapped
    schedule decomposes the same data volume into an interior call on
    the un-extended block plus thin frame strips, so the measured
    ranking carries over while absolute times differ by the frame
    recompute. Raises the same :class:`ValueError`\\ s as the sharded
    path itself (indivisible mesh axis, shard smaller than the halo).
    """
    from .halo import check_shard_geometry, shard_halo
    local = check_shard_geometry(
        plan, tuple(global_spatial), tuple(mesh_per_axis), time_steps)
    halos = shard_halo(plan, time_steps)
    return tuple(
        n + (lo + hi
             if boundary != "zero" or (assign is not None and assign[1] > 1)
             else 0)
        for n, assign, (lo, hi) in zip(local, mesh_per_axis, halos))
