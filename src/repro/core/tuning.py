"""Perf-model-guided autotuner for engine block configurations (§5).

For a given plan + problem shape the tuner enumerates candidate block
configs — ``(block_h, block_w[, block_z], variant)`` for windowed plans,
``(block_r, block_t)`` for scans — prices each with an extension of the
paper's §5 latency model (Eq. 4 compute terms + the §5.3 halo/redundancy
accounting, applied to the *actual* block geometry instead of the warp),
optionally measures the model's top-k candidates with the real kernel,
and caches the winner per (plan, shape, time_steps, backend).

Pricing per useful output element (see :func:`model_cost`):

* **compute** — ``t · mads · (T_mad + T_reg)`` plus the shift term
  ``t · shifts · T_shfl`` amortized over the P output rows a roll covers
  (one lane-roll of the whole (P, S) psum block serves all P rows, the
  TPU widening of Eq. 4's per-output (M−1)·T_shfl). ``shift_data``
  halves the effective shift cost: its rolls leave the accumulator
  dependency chain and overlap with FMAs (DESIGN.md §2).
* **memory** — every loaded element costs ``T_gmem/LANES``; the loaded/
  useful ratio is exactly the halo redundancy of §5.3 for the block,
  ``Π(block+t·(ext−1)) / Π(block)``, which temporal blocking widens.

The absolute cycle counts are estimates (the TPU latency row is marked
as such in :mod:`repro.core.perfmodel`); the tuner only consumes the
*ranking*, and the measured pass — which always includes the default
config — guarantees the returned config never loses to the default on
the measured metric.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Sequence

import jax

from .perfmodel import TPU_V5E, HardwareLatencies
from .plan import SystolicPlan

# VMEM working-set budget per block (f32 elements): input block + psum +
# output must fit comfortably in ~16 MB VMEM; stay conservative.
VMEM_BUDGET_ELEMS = 1 << 20

_WINDOW_BLOCK_H = (8, 16, 32, 64)
_WINDOW_BLOCK_W = (128, 256, 512)
_WINDOW_BLOCK_Z = (4, 8, 16)
_SCAN_BLOCK_R = (8, 16, 32)
_SCAN_BLOCK_T = (128, 256, 512, 1024)


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One candidate schedule: output block per windowed axis + variant."""

    block: tuple[int, ...]          # lane axis last
    variant: str = "shift_psum"

    def as_kwargs(self, plan: SystolicPlan) -> dict:
        """Render into the kwargs the thin kernel wrappers accept."""
        if plan.combine != "fma":
            return {"block_r": self.block[0], "block_t": self.block[1]}
        if plan.kind == "conv1d":
            return {"block_t": self.block[0], "block_d": self.block[1]}
        kw = {"block_h": self.block[-2], "block_w": self.block[-1]}
        if plan.ndim_spatial == 3:
            kw["block_z"] = self.block[0]
        if plan.M > 1:
            kw["variant"] = self.variant
        return kw


@dataclasses.dataclass(frozen=True)
class TuneResult:
    config: KernelConfig
    model_cost: float               # est. cycles per useful output
    measured_us: float | None       # None when model-only
    source: str                     # 'model' | 'measured' | 'cache'


_CACHE: dict[tuple, TuneResult] = {}


def clear_cache() -> None:
    _CACHE.clear()


def _cache_key(plan: SystolicPlan, shape: tuple[int, ...], time_steps: int,
               context: tuple = ()):
    return (plan, tuple(shape), time_steps, jax.default_backend(), context)


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------

def candidate_configs(
    plan: SystolicPlan,
    shape: Sequence[int],
    time_steps: int = 1,
    *,
    vmem_budget: int = VMEM_BUDGET_ELEMS,
) -> list[KernelConfig]:
    """Feasible block configs for ``plan`` on a problem of ``shape``.

    Blocks are clamped to the output shape, deduplicated, and filtered by
    the VMEM working-set budget (input block + halo, widened by temporal
    blocking). Scan plans tune (block_r, block_t) with power-of-two lane
    tiles; windowed plans tune the output tile and the schedule variant.
    """
    if plan.combine != "fma":                       # scan family
        R, T = shape
        out: list[KernelConfig] = []
        for br in _SCAN_BLOCK_R:
            for bt in _SCAN_BLOCK_T:
                bt_eff = 1 << (min(bt, T).bit_length() - 1)
                cfg = KernelConfig((min(br, R), bt_eff))
                if cfg.block[0] * cfg.block[1] <= vmem_budget:
                    out.append(cfg)
        return sorted(set(out), key=lambda c: c.block)

    spatial = tuple(shape)[plan.batch_axes:]
    out_sp = plan.out_shape(spatial, time_steps)
    axes: list[tuple[int, ...]] = []
    if plan.ndim_spatial == 3:
        axes.append(_WINDOW_BLOCK_Z)
    axes.append(_WINDOW_BLOCK_H)
    axes.append(_WINDOW_BLOCK_W)
    variants = ("shift_psum", "shift_data") if plan.shift_count() else ("shift_psum",)

    configs: set[KernelConfig] = set()
    def rec(i: int, acc: tuple[int, ...]):
        if i == len(axes):
            if math.prod(plan.block_in_shape(acc, time_steps)) > vmem_budget:
                return
            for v in variants:
                configs.add(KernelConfig(acc, v))
            return
        for b in axes[i]:
            rec(i + 1, acc + (min(b, out_sp[i]),))
    rec(0, ())
    return sorted(configs, key=lambda c: (c.block, c.variant))


# ---------------------------------------------------------------------------
# §5-model pricing
# ---------------------------------------------------------------------------

def model_cost(
    plan: SystolicPlan,
    cfg: KernelConfig,
    time_steps: int = 1,
    hw: HardwareLatencies = TPU_V5E,
) -> float:
    """Estimated cycles per useful output element for one block config."""
    t = time_steps
    if plan.combine != "fma":                       # Kogge–Stone scan
        br, bt = cfg.block
        steps = math.log2(max(bt, 2))
        ops_per_elem = 2.0 if plan.combine == "linrec" else 1.0
        compute = steps * ops_per_elem * (hw.t_shfl + hw.t_mad + hw.t_reg)
        carry = (hw.t_smem_read + hw.t_mad) / bt    # inter-block carry
        memory = hw.t_gmem_read / plan.S
        return compute + carry + memory

    block = cfg.block
    useful = math.prod(block)
    loaded = math.prod(plan.block_in_shape(block, t))
    mads = plan.mads_per_output_window()
    shifts = plan.shift_count()
    P = block[-2]                                   # rows one roll amortizes
    shfl = hw.t_shfl * (0.5 if cfg.variant == "shift_data" else 1.0)
    compute = t * mads * (hw.t_mad + hw.t_reg) + t * shifts * shfl / max(P, 1)
    memory = (loaded / useful) * hw.t_gmem_read / plan.S
    return compute + memory


# ---------------------------------------------------------------------------
# Measurement + the tuner
# ---------------------------------------------------------------------------

def measure_us(fn: Callable[[], jax.Array], reps: int = 3) -> float:
    """Median wall-time (µs) of ``fn`` post-warmup."""
    jax.block_until_ready(fn())
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def autotune(
    plan: SystolicPlan,
    shape: Sequence[int],
    *,
    time_steps: int = 1,
    default: KernelConfig | None = None,
    runner: Callable[[KernelConfig], float] | None = None,
    hw: HardwareLatencies = TPU_V5E,
    top_k: int = 3,
    context: tuple = (),
    fixed: dict | None = None,
) -> TuneResult:
    """Pick a block config for ``plan`` on ``shape``.

    Ranks candidates by :func:`model_cost`; when ``runner`` is given
    (a ``cfg → µs`` measurement closure) the model's top-k **plus the
    default config** are measured and the measured winner is returned —
    so the result can never regress the default on the measured metric.
    Winners are cached per (plan, shape, time_steps, backend, context);
    ``context`` must capture anything else that changes what the runner
    actually measures (caller-forced kwargs, op mode, impl), otherwise a
    winner measured under one context is replayed under another.

    ``fixed`` names kwargs the caller pins (they override the candidate
    at run time): candidates are restricted to those agreeing with the
    pinned values — and deduplicated by their *effective* kwargs — so the
    runner never measures the same kernel twice and the recorded winner
    is the config that actually ran.
    """
    key = _cache_key(plan, tuple(shape), time_steps, context)
    if key in _CACHE:
        cached = _CACHE[key]
        return dataclasses.replace(cached, source="cache")

    cands = candidate_configs(plan, shape, time_steps)
    if default is not None and default not in cands:
        cands.append(default)
    if fixed:
        agreeing = [c for c in cands
                    if all(c.as_kwargs(plan).get(k, v) == v
                           for k, v in fixed.items())]
        if agreeing:
            cands = agreeing
        else:      # pinned value outside the grid: dedupe by what runs
            seen: dict[tuple, KernelConfig] = {}
            for c in cands:
                sig = tuple(sorted({**c.as_kwargs(plan), **fixed}.items()))
                seen.setdefault(sig, c)
            cands = list(seen.values())
    if not cands:
        raise ValueError(f"no feasible block configs for {plan.kind} {shape}")
    ranked = sorted(cands, key=lambda c: model_cost(plan, c, time_steps, hw))

    if runner is None:
        best = ranked[0]
        result = TuneResult(best, model_cost(plan, best, time_steps, hw),
                            None, "model")
    else:
        to_measure = list(ranked[:top_k])
        if default is not None and default not in to_measure:
            to_measure.append(default)
        timed = [(runner(c), c) for c in to_measure]
        us, best = min(timed, key=lambda p: p[0])
        result = TuneResult(best, model_cost(plan, best, time_steps, hw),
                            us, "measured")
    _CACHE[key] = result
    return result
