"""Fused plan pipelines — chain composition in the plan IR (DESIGN.md §11).

The paper's §6.4 temporal blocking fuses ``t`` applications of the *same*
plan inside one block; :func:`fuse_plans` generalizes that machinery from
"same plan × t" to an arbitrary **plan list**: consecutive shape-preserving
windowed plans compose into one :class:`~repro.core.plan.SystolicPlan`
whose ``stages`` field carries the per-stage tap sets/coefficients and
whose top-level footprint/lead/trail are the *summed* stage geometry.

Because the composite is an ordinary ``SystolicPlan``, every downstream
layer gets chains for free:

* the **engine** iterates ``plan.stages`` inside the block exactly where
  temporal blocking iterated ``time_steps`` copies — partial activations
  between stages never leave VMEM/VREGs (the whole point: each seam of an
  unfused chain is a full HBM write+read of the activation);
* the **halo geometry** (:mod:`repro.core.halo`) sees summed
  lead/trail/ext, so :func:`~repro.core.halo.shard_halo` ships **one
  widened halo per fused chain** over the mesh, same as temporal blocking;
* the **tuner** keys the chain as one plan signature whose §5 cost is the
  summed flop terms against a single load+store;
* the **adjoint** of a chain is the reversed chain of stage adjoints
  (:func:`repro.core.adjoint.input_adjoint_plan` recurses into stages), so
  a purely linear fused pipeline differentiates through one fused backward
  kernel.

Legality (checked here, pre-``pallas_call``, with named errors):

* every stage is a windowed (``combine='fma'``) plan — scans carry a
  sequential inter-block carry and cannot sit in a spatial chain;
* no stage has reduce/out axes — a channel reduction (NCHW conv) must
  complete its full accumulator sweep before the next stage may read the
  summed output, exactly the reason temporal blocking refuses reduce
  plans (route those through a fused *epilogue* instead);
* every stage is shape-preserving per axis (``lead+trail = ext−1``) so
  intermediate shapes survive the chain and the composite stays
  shardable;
* stage epilogues between stages must fix zero (gelu/silu/relu/scale)
  or be ``bias`` — a scalar bias applies to the whole pad-once
  intermediate, matching the unfused fallback exactly; ``residual_add``
  stays final-only (its output-shaped operand would have to materialize
  the intermediate it skips).

Semantics are pad-once (trapezoidal), shared with temporal blocking and
``ref.stencil_iterate``: the domain is zero-padded once by the *summed*
leads/trails, then the stages apply as valid windows in order. Where the
mid-chain epilogues fix zero, this agrees with per-op same-shape
zero-boundary application on the interior at distance > Σ radius from
the boundary; a mid-chain ``bias`` (which shifts zero) keeps the
fused/unfused/oracle agreement but diverges from per-op same-shape
application near the boundary.
"""
from __future__ import annotations

import dataclasses

from repro import obs

from .plan import SystolicPlan, epilogue_operand_stages


def _check_stage(i: int, p: SystolicPlan, n: int) -> None:
    tag = f"fuse_plans: stage {i} ({p.kind!r})"
    if p.strategy not in (None, "lanes", "mxu"):
        raise ValueError(
            f"{tag} has unknown lowering strategy {p.strategy!r}: expected "
            "None (auto), 'lanes' or 'mxu' (DESIGN.md §13)")
    if p.combine != "fma":
        raise ValueError(
            f"{tag} is a scan plan (combine={p.combine!r}); only windowed "
            "plans chain-fuse — scans carry a sequential inter-block carry")
    if p.stages:
        raise ValueError(f"{tag} is already a fused chain; flatten the "
                         "stage list instead of nesting pipelines")
    if p.reduce_axes or p.out_axes:
        raise ValueError(
            f"{tag} carries reduce/out axes: a channel reduction must "
            "complete its accumulator sweep before the next stage can read "
            "the summed output, so NCHW conv stages cannot chain-fuse — "
            "fuse their activation as an epilogue instead (DESIGN.md §11)")
    if p.coeff_mode == "perlane":
        raise ValueError(
            f"{tag} uses per-lane coefficients; depthwise plans do not "
            "chain-fuse (their lane axis is the channel axis)")
    if p.stride and any(v > 1 for v in p.stride):
        raise ValueError(
            f"{tag} is output-strided; a strided stage changes the domain "
            "extent mid-chain, so strides fuse only as the final engine "
            "call's own grid (unfused)")
    lead, trail = p.lead_trail()
    for a in range(p.ndim_spatial):
        if lead[a] + trail[a] != p.exts[a] - 1:
            raise ValueError(
                f"{tag} is not shape-preserving on axis {a} "
                f"(lead+trail={lead[a] + trail[a]} != ext-1="
                f"{p.exts[a] - 1}); only shape-preserving stages chain "
                "(for conv2d use mode='same')")
    if i < n - 1:
        bad = [s.op for s in epilogue_operand_stages(p.epilogue)
               if s.op != "bias"]
        if bad:
            raise ValueError(
                f"{tag} carries a residual_add epilogue ({bad}) mid-chain: "
                "the residual operand is output-shaped and would have to "
                "materialize the intermediate it skips, so residual_add is "
                "only legal on the final stage of a fused pipeline (bias "
                "may sit mid-chain)")


def summed_lead_trail(
    plans,
) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Per-axis (Σ lead, Σ trail) of a chain — the pad-once frame both
    the fused composite plan and the unfused fallback/oracle share."""
    nd = plans[0].ndim_spatial
    lead = tuple(sum(p.lead_trail()[0][a] for p in plans)
                 for a in range(nd))
    trail = tuple(sum(p.lead_trail()[1][a] for p in plans)
                  for a in range(nd))
    return lead, trail


def fuse_plans(*plans: SystolicPlan) -> SystolicPlan:
    """Compose consecutive windowed plans into one fused pipeline plan.

    ``fuse_plans(p1, p2, p3)`` executes ``p3(p2(p1(x)))`` in a single
    engine kernel. The returned plan's ``stages`` are the inputs in
    application order; its top-level footprint / lead / trail are the
    summed stage geometry, so halo arithmetic, sharding validation and
    §5 pricing treat the chain as one (wider) windowed plan. Raises
    named ``ValueError``\\ s for chains that do not qualify (see module
    docstring) — callers that want an automatic unfused fallback catch
    them (``ops.pipeline(fuse='auto')``).
    """
    if not plans:
        raise ValueError("fuse_plans needs at least one plan")
    if len(plans) == 1:
        return plans[0]
    head = plans[0]
    n = len(plans)
    for i, p in enumerate(plans):
        _check_stage(i, p, n)
        if p.ndim_spatial != head.ndim_spatial:
            raise ValueError(
                f"fuse_plans: stage {i} is {p.ndim_spatial}-D but stage 0 "
                f"is {head.ndim_spatial}-D; chains must share the domain")
        if p.S != head.S:
            raise ValueError(
                f"fuse_plans: stage {i} has lane width S={p.S} != {head.S}")
        if p.batch_axes != head.batch_axes:
            raise ValueError(
                f"fuse_plans: stage {i} has batch_axes={p.batch_axes} != "
                f"{head.batch_axes}; every stage must see the same batch")

    strategies = {p.strategy for p in plans if p.strategy is not None}
    if len(strategies) > 1:
        raise ValueError(
            "fuse_plans: stages pin conflicting lowering strategies "
            f"{sorted(strategies)}: the chain lowers as ONE kernel over a "
            "shared VMEM tile, so every stage must agree (pin one strategy "
            "for the whole chain, or leave stages on auto — DESIGN.md §13)")

    exts = tuple(
        1 + sum(p.exts[a] - 1 for p in plans)
        for a in range(head.ndim_spatial))
    lead, trail = summed_lead_trail(plans)
    if head.ndim_spatial == 3:
        depth, N, M = exts
    else:
        depth, (N, M) = 1, exts
    obs.metrics.inc("fuse.chains", f"n{n}")
    with obs.span("fuse.fuse_plans", cat="fuse", n=n,
                  kinds=[p.kind for p in plans]):
        return dataclasses.replace(
            head,
            kind="pipe%d_%s" % (n, "+".join(p.kind for p in plans)),
            stages=tuple(plans),
            steps=(),                   # per-stage steps live on the stages
            M=M, N=N, depth=depth,
            C=N + head.P - 1,
            lead=lead if any(lead) else None,
            trail=trail if any(trail) else None,
            coeffs=None,
            coeff_mode="dense" if any(p.coeff_mode == "dense" for p in plans)
            else "table",
            epilogue=(),                # stage epilogues live on the stages
            # one pinned stage pins the chain (single kernel); else auto —
            # the engine resolves each stage as stage.strategy or composite's
            strategy=strategies.pop() if strategies else None,
        )


def pipeline_coeff_count(plan: SystolicPlan) -> int:
    """Number of runtime coefficient operands a fused plan consumes (one
    per 'dense' stage, in stage order); 0/1 for unfused plans."""
    if plan.stages:
        return sum(1 for s in plan.stages if s.coeff_mode == "dense")
    return 0 if plan.coeff_mode == "table" else 1
