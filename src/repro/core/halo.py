"""Halo geometry of windowed plans — one module, three consumers.

Everything about *how much extra input a windowed plan needs around an
output region* lives here, factored out of the engine so the same
arithmetic serves:

* :mod:`repro.core.engine` — origin padding + overlapped-block halos for
  the single-device Pallas lowering (§4.5 of the paper);
* :mod:`repro.distributed.halo_exchange` — the per-shard halo widths a
  device mesh must exchange via ``ppermute`` neighbor pushes, and the
  crop that maps a halo-extended engine output back to the local shard;
* :mod:`repro.core.tuning` — shard-local shapes for per-shard block
  tuning.

Two distinct "halos" appear and must not be conflated:

* the **block halo** ``t·(ext−1)`` per axis (``plan.halo(t)``) — the
  input-over-output overlap of adjacent engine blocks *within* one
  device; it is symmetric-free (all of it trails the block origin).
* the **shard halo** ``(t·lead, t·trail)`` per axis — the split of that
  same total into data that lies *before* vs *after* a shard's rows in
  the global domain. A shard needs ``t·lead`` rows from its low-side
  neighbor and ``t·trail`` from its high side; for shape-preserving
  plans ``lead + trail = ext − 1`` so the two views carry the same
  total, ``shard_halo_lo + shard_halo_hi = plan.halo(t)`` per axis.
"""
from __future__ import annotations

from .plan import SystolicPlan


def origin_pads(
    plan: SystolicPlan,
    spatial_in: tuple[int, ...],
    grid: tuple[int, ...],
    block: tuple[int, ...],
    time_steps: int = 1,
) -> list[tuple[int, int]]:
    """Per-windowed-axis (lo, hi) zero padding for the engine's input.

    ``t·lead`` zeros ahead of the origin (the plan's semantic boundary
    padding), then enough behind so every — including the last —
    overlapped input block of the ``grid × block`` tiling is in-bounds:
    the tiling reads ``(g·b − 1)·stride + 1 + halo`` input rows per axis
    (stride 1 ⇒ the familiar ``g·b + halo``). Fused chains need no case
    here: their composite ``exts``/``lead`` already carry the summed
    stage footprints (DESIGN.md §11).
    """
    lead, _ = plan.lead_trail()
    halo = plan.halo(time_steps)
    stride = plan.stride_per_axis()
    # A strided tiling can need *fewer* input rows than provided (the
    # stride skips the tail); clamp at zero — the surplus rows are
    # simply never read by any block.
    return [
        ((time_steps * l),
         max(0, (g * b - 1) * v + 1 + h - time_steps * l - s))
        for l, g, b, h, s, v in zip(lead, grid, block, halo, spatial_in,
                                    stride)
    ]


def shard_halo(
    plan: SystolicPlan, time_steps: int = 1
) -> tuple[tuple[int, int], ...]:
    """Per-axis (lo, hi) halo a shard must import from its neighbors.

    ``lo = t·lead`` rows ride in from the low-side neighbor (they sit
    *before* the shard's rows in the global domain), ``hi = t·trail``
    from the high side. Exchanging exactly these widths once per
    ``time_steps``-fused engine call — one engine-halo per temporal
    step, batched — reproduces the single-device pad-once semantics:
    domain-edge shards receive zeros from ``ppermute``'s unsourced
    links, which is exactly the engine's own origin padding.
    """
    lead, trail = plan.lead_trail()
    t = time_steps
    return tuple((t * l, t * r) for l, r in zip(lead, trail))


def is_shape_preserving(plan: SystolicPlan, axis: int) -> bool:
    """True when the plan keeps an axis's extent: ``lead+trail == ext−1``.

    Only such axes can be sharded — every shard then owns an equal slice
    of both the input and the output, so the ``shard_map`` output spec
    mirrors the input spec.
    """
    lead, trail = plan.lead_trail()
    return lead[axis] + trail[axis] == plan.exts[axis] - 1


def extended_crop(
    plan: SystolicPlan,
    time_steps: int,
    axis: int,
    local_extent: int,
) -> slice:
    """Slice mapping the engine's output on a halo-extended input back
    to the shard's own rows.

    Feeding ``[halo_lo | local | halo_hi]`` through the engine yields
    ``local + t·(lead+trail)`` output rows on a shape-preserving axis
    (the engine re-applies its origin padding outside the halo); the
    shard's rows start after the ``t·lead`` outputs that belong to the
    low-side neighbor.
    """
    lo, _ = shard_halo(plan, time_steps)[axis]
    return slice(lo, lo + local_extent)


def check_shard_geometry(
    plan: SystolicPlan,
    global_spatial: tuple[int, ...],
    mesh_per_axis: tuple[tuple[str, int] | None, ...],
    time_steps: int = 1,
) -> tuple[int, ...]:
    """Validate a sharding layout; return the shard-local spatial shape.

    ``mesh_per_axis[a]`` is ``(mesh_axis_name, size)`` for sharded
    domain axes, None for replicated ones. Raises ``ValueError`` — not
    an XLA shape error deep inside ``pallas_call`` — when a mesh axis
    does not divide its domain axis, when the halo is wider than the
    whole domain axis (no exchange schedule can source rows that do not
    exist), or when a sharded axis is not shape-preserving. A halo
    wider than one *shard* is fine: the exchange layer chains
    ``ppermute`` hops across as many neighbors as the width spans
    (``halo_exchange._multihop_slab``).
    """
    halos = shard_halo(plan, time_steps)
    local = []
    for a, (n, assign) in enumerate(zip(global_spatial, mesh_per_axis)):
        if assign is None:
            local.append(n)
            continue
        name, size = assign
        if not is_shape_preserving(plan, a):
            raise ValueError(
                f"cannot shard domain axis {a} of a {plan.kind!r} plan over "
                f"mesh axis {name!r}: the axis is not shape-preserving "
                f"(lead+trail={sum(plan.lead_trail()[i][a] for i in (0, 1))} "
                f"!= ext-1={plan.exts[a] - 1}), so shards would not own "
                "equal input and output slices")
        if n % size != 0:
            raise ValueError(
                f"mesh axis {name!r} (size {size}) does not divide domain "
                f"axis {a} (size {n}) for {plan.kind!r}; pad the domain or "
                "pick a mesh whose axis divides it")
        shard = n // size
        lo, hi = halos[a]
        if size > 1 and max(lo, hi) > n:
            raise ValueError(
                f"the plan's halo is wider than domain axis {a} itself: "
                f"time_steps={time_steps} needs a ({lo}, {hi}) halo but the "
                f"axis has only {n} rows in total; no exchange over mesh "
                f"axis {name!r} can source rows beyond the domain — shrink "
                "time_steps or grow the domain")
        local.append(shard)
    return tuple(local)
