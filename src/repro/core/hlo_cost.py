"""Trip-count-aware cost roll-up over optimized HLO text.

``compiled.cost_analysis()`` counts every ``while`` body **once**, so any
scan-over-layers / flash-attention-block / loss-chunk loop is undercounted
by its trip count (verified: a length-10 scan reports 10× fewer FLOPs than
its unrolled twin). This module re-derives the three roofline inputs from
``compiled.as_text()`` with loops properly multiplied:

* **flops**            — 2·|result|·|contracted| per dot/convolution
  (MXU-dominant ops; fused elementwise flops are ignored as they ride the
  memory term),
* **memory bytes**     — Σ(operand + result bytes) of top-level ops at
  fusion boundaries (fusion internals stay in registers/VMEM — the
  boundary traffic is the HBM-roofline-relevant quantity),
* **collective bytes** — result bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute(+`-start` forms),

each computed per HLO computation and rolled up through ``while`` ops at
``body_cost × trip_count`` (trip count parsed from the loop-condition
constant; nested loops recurse). Everything is per-device (post-SPMD).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5, "u4": 0.5, "token": 0,
    "opaque": 0,
}

_COLLECTIVE_PREFIXES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{")
_OP_ASSIGN = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_ARRAY_TYPE = re.compile(r"^([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)")
_OPCODE = re.compile(r"^\s*([\w\-]+)\((.*)$")
_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND = re.compile(r"%([\w\.\-]+)")
_WHILE_ATTRS = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims={([0-9,]*)}")


def _shape_bytes(type_str: str) -> float:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0.0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    opcode: str
    rest: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0            # raw: every fusion-boundary tensor (CPU-XLA granularity)
    bytes_fused: float = 0.0      # ideal-fusion: elementwise producer→consumer edges coalesced (TPU-like)
    collective_bytes: float = 0.0
    collective_by_kind: dict = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.bytes_fused += o.bytes_fused
        self.collective_bytes += o.collective_bytes
        for k, v in o.collective_by_kind.items():
            self.collective_by_kind[k] = self.collective_by_kind.get(k, 0.0) + v
        return self

    def scaled(self, n: float) -> "Cost":
        return Cost(self.flops * n, self.bytes * n, self.bytes_fused * n,
                    self.collective_bytes * n,
                    {k: v * n for k, v in self.collective_by_kind.items()})


# STAGING ops: pure dtype-cast / layout ops that a TPU compiler always
# folds into the consumer (the MXU reads bf16 directly; copies/transposes
# ride the load path). These never materialize in the fused-bytes model.
# Arithmetic elementwise fusions (norms, residuals, activations) DO count
# as kernels — conservative vs TPU's bigger fusions, but stable.
_STAGING_TOKENS = {
    "convert", "copy", "bitcast", "transpose", "reshape", "broadcast",
    "wrapped",
}


def _is_fusible_elementwise(op: "_Op") -> bool:
    """True for pure staging ops/fusions (see _STAGING_TOKENS)."""
    if op.opcode != "fusion":
        return op.opcode in _STAGING_TOKENS
    raw = [t.split(".")[0] for t in op.name.replace("-", "_").split("_")]
    tokens = [t for t in raw if t and not t.isdigit() and t != "fusion"]
    return bool(tokens) and all(t in _STAGING_TOKENS for t in tokens)


_SKIP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id",
}


def parse_computations(hlo: str) -> dict[str, list[_Op]]:
    comps: dict[str, list[_Op]] = {}
    cur: list[_Op] | None = None
    for line in hlo.splitlines():
        s = line.strip()
        hdr = _COMP_HDR.match(s)
        if hdr and ("->" in s):
            cur = comps.setdefault(hdr.group(1), [])
            continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_ASSIGN.match(s)
        if m:
            op = _split_rhs(m.group(1), m.group(2))
            if op is not None:
                cur.append(op)
    return comps


def _split_rhs(name: str, rhs: str) -> "_Op | None":
    """Split `TYPE opcode(rest` where TYPE may be a tuple containing
    nested parens and /*index=N*/ comments."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_str, rest = rhs[: end + 1], rhs[end + 1:]
    else:
        tm = _ARRAY_TYPE.match(rhs)
        if not tm:
            return None
        type_str, rest = tm.group(1), rhs[len(tm.group(1)):]
    om = _OPCODE.match(rest)
    if not om:
        return None
    return _Op(name, type_str, om.group(1), om.group(2))


def _dot_flops(op: _Op, shapes: dict[str, str]) -> float:
    result_elems = _shape_elems(op.type_str)
    cm = _CONTRACT.search(op.rest)
    operands = _OPERAND.findall(op.rest.split(")", 1)[0])
    if not operands:
        return 0.0
    lhs_type = shapes.get(operands[0], "")
    sm = _SHAPE.search(lhs_type)
    if not sm:
        return 2.0 * result_elems  # unknown — count as elementwise-ish
    lhs_dims = [int(d) for d in sm.group(2).split(",") if d]
    contracted = 1
    if cm:
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contracted *= lhs_dims[int(idx)]
    return 2.0 * result_elems * contracted


def _conv_flops(op: _Op, shapes: dict[str, str]) -> float:
    # result elems × (2 × kernel spatial × in_channels): approximate via
    # rhs (kernel) total elems / out_channels
    result_elems = _shape_elems(op.type_str)
    operands = _OPERAND.findall(op.rest.split(")", 1)[0])
    if len(operands) < 2:
        return 2.0 * result_elems
    k_elems = _shape_elems(shapes.get(operands[1], ""))
    rm = _SHAPE.search(op.type_str)
    out_ch = 1
    if rm:
        dims = [int(d) for d in rm.group(2).split(",") if d]
        out_ch = dims[-1] if dims else 1
    return 2.0 * result_elems * max(k_elems // max(out_ch, 1), 1)


def _dus_update_bytes(op: "_Op", comps) -> float:
    """Sum of dynamic-update-slice *update* operand bytes inside a fusion body."""
    m = re.search(r"calls=%?([\w\.\-]+)", op.rest)
    if not m:
        return 0.0
    body = comps.get(m.group(1), [])
    shapes = {o.name: o.type_str for o in body}
    total = 0.0
    for o in body:
        if o.opcode == "dynamic-update-slice":
            ops_named = _OPERAND.findall(o.rest.split("),", 1)[0])
            if len(ops_named) > 1:
                total += _shape_bytes(shapes.get(ops_named[1], ""))
    return total


def cost_of(hlo: str, entry: str | None = None) -> Cost:
    comps = parse_computations(hlo)
    if not comps:
        return Cost()
    # entry: the computation whose header followed ENTRY; detect by regex
    entry_m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    entry = entry or (entry_m.group(1) if entry_m else next(iter(comps)))

    # computations called by fusions/reduces: excluded from the walk —
    # their cost is represented at the call site.
    memo: dict[str, Cost] = {}

    def trip_count(cond_name: str, while_rest: str = "") -> float:
        cm = _TRIP_CFG.search(while_rest)      # XLA's own trip-count analysis
        if cm:
            return float(cm.group(1))
        ops = comps.get(cond_name, [])
        consts = []
        for op in ops:
            consts += [int(v) for v in _CONST_S32.findall(
                f"{op.type_str} {op.opcode}({op.rest}")]
        return float(max(consts)) if consts else 1.0

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()        # cycle guard
        total = Cost()
        ops = comps.get(name, [])
        shapes = {op.name: op.type_str for op in ops}
        fusible = {op.name for op in ops if _is_fusible_elementwise(op)}
        op_by_name = {op.name: op for op in ops}

        # bytes_fused v2 — dataflow-resolved HBM roots: a "virtual" op
        # (elementwise/cast/copy fusion) never materializes on TPU; real
        # consumers charge the *root* tensors reached through virtual
        # chains at their storage dtype. This makes the metric invariant
        # to CPU-XLA's f32 staging of bf16 dot operands.
        root_memo: dict[str, tuple] = {}

        def roots_of(opname: str):
            if opname in root_memo:
                return root_memo[opname]
            op = op_by_name.get(opname)
            if op is None or op.name not in fusible:
                root_memo[opname] = (opname,)
                return root_memo[opname]
            rs = []
            root_memo[opname] = ()  # cycle guard
            for on in _OPERAND.findall(op.rest.split("),", 1)[0]):
                if on in shapes:
                    rs.extend(roots_of(on))
            root_memo[opname] = tuple(dict.fromkeys(rs))
            return root_memo[opname]

        def fused_read_bytes(op) -> float:
            """Reads charged at the *immediate operand's shape* (the slice
            the op actually touches — a loop-body dot must not be charged
            the full stacked buffer its staging chain roots at) times the
            root's dtype width (un-counting CPU-XLA's hoisted f32 staging
            of bf16 storage where visible)."""
            seen = set()
            tot = 0.0
            for on in _OPERAND.findall(op.rest.split("),", 1)[0]):
                if on not in shapes or on in seen:
                    continue
                seen.add(on)
                elems = _shape_elems(shapes[on])
                rts = roots_of(on)
                width = None
                for r in rts:
                    m = _SHAPE.search(shapes.get(r, ""))
                    if m and m.group(1) in _DTYPE_BYTES:
                        w = _DTYPE_BYTES[m.group(1)]
                        width = w if width is None else min(width, w)
                if width is None:
                    m = _SHAPE.search(shapes[on])
                    width = _DTYPE_BYTES.get(m.group(1), 4) if m else 4
                tot += elems * width
            return tot
        for op in ops:
            oc = op.opcode
            if oc in _SKIP_OPS:
                continue
            if oc == "while":
                wm = _WHILE_ATTRS.search(op.rest)
                if wm:
                    n = trip_count(wm.group(1), op.rest)
                    total += comp_cost(wm.group(2)).scaled(n)
                    # loop state traffic: the while op reads/writes carry once
                    total += Cost(bytes=2 * _shape_bytes(op.type_str),
                                  bytes_fused=2 * _shape_bytes(op.type_str))
                continue
            if oc in ("call", "conditional", "async-start"):
                for cn in re.findall(r"(?:to_apply|calls)=%?([\w\.\-]+)", op.rest):
                    total += comp_cost(cn)
                continue
            is_coll = any(oc.startswith(p) for p in _COLLECTIVE_PREFIXES)
            if oc.endswith("-done"):
                continue
            if oc == "dynamic-update-slice":
                # in-place on TPU (and CPU when safe): traffic = the update
                # slice written + read, NOT the whole buffer. Critical for
                # scan ys-stacking and KV-cache writes, which would
                # otherwise count the full stacked buffer per iteration.
                ops_named = _OPERAND.findall(op.rest.split("),", 1)[0])
                upd = ops_named[1] if len(ops_named) > 1 else None
                upd_b = _shape_bytes(shapes.get(upd, "")) if upd else 0.0
                total += Cost(bytes=2.0 * upd_b, bytes_fused=2.0 * upd_b)
                continue
            if oc in ("dynamic-slice", "gather"):
                b = 2.0 * _shape_bytes(op.type_str)
                total += Cost(bytes=b, bytes_fused=b)
                continue
            if op.name in fusible:
                # virtual on TPU: materializes nothing; consumers charge
                # its roots. Raw metric still counts it below? No — raw
                # keeps CPU granularity via the op_bytes path; fall through.
                op_bytes = _shape_bytes(op.type_str)
                for on in _OPERAND.findall(op.rest.split("),", 1)[0]):
                    if on in shapes:
                        op_bytes += _shape_bytes(shapes[on])
                total += Cost(bytes=op_bytes, bytes_fused=0.0)
                continue
            op_bytes = _shape_bytes(op.type_str)
            # operand bytes: look up named operands (first paren group)
            operand_bytes = []
            for on in _OPERAND.findall(op.rest.split("),", 1)[0]):
                if on in shapes:
                    operand_bytes.append(_shape_bytes(shapes[on]))
            op_bytes += sum(operand_bytes)
            fused_b = _shape_bytes(op.type_str) + fused_read_bytes(op)
            if oc == "fusion" and "dynamic-update-slice" in op.name:
                # fused in-place update: exclude the pass-through buffer
                # (the operand matching the result size) from both sides.
                res_b = _shape_bytes(op.type_str)
                for b in operand_bytes:
                    if b == res_b:
                        op_bytes -= 2.0 * b
                        break
                # fused metric: resolve the true update size from inside
                # the fusion body — on TPU the buffer is updated in place
                # (no staged copy, regardless of any fused dtype converts).
                upd_b = _dus_update_bytes(op, comps)
                fused_b = (2.0 * upd_b if upd_b
                           else max(fused_b - 2.0 * res_b, 0.0))
            if is_coll:
                kind = oc.replace("-start", "")
                total += Cost(
                    bytes=op_bytes, bytes_fused=op_bytes,
                    collective_bytes=_shape_bytes(op.type_str),
                    collective_by_kind={kind: _shape_bytes(op.type_str)})
                continue
            flops = 0.0
            if oc in ("dot", "dot-general"):
                flops = _dot_flops(op, shapes)
            elif oc == "convolution":
                flops = _conv_flops(op, shapes)
            total += Cost(flops=flops, bytes=op_bytes, bytes_fused=fused_b)
        memo[name] = total
        return total

    # exclude computations that are only fusion bodies: comp_cost(entry)
    # walks exactly the reachable-through-while/call graph.
    return comp_cost(entry)
