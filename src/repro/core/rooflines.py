"""Three-term TPU v5e roofline from compiled XLA artifacts.

    compute term    = HLO_FLOPs          / (chips × peak_FLOP/s)
    memory term     = HLO_bytes          / (chips × HBM_bw)
    collective term = collective_bytes   / (chips × link_bw)

``cost_analysis()`` provides HLO_FLOPs and HLO_bytes. Collective bytes are
*not* in cost_analysis, so :func:`collective_bytes_from_hlo` parses the
(stable-)HLO text and sums operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e hardware constants (per chip) — fixed by the assignment.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4,
    "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# HLO: `%x = f32[128,1024]{1,0} all-gather(...)`; StableHLO/MLIR:
# `"mhlo.all_gather"(%a) ... : (tensor<128x1024xf32>) -> ...`.
_HLO_OP_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9_,\[\]{}\s]+?)\)?\s+(" + "|".join(_COLLECTIVES) + r")\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_MLIR_OP_RE = re.compile(
    r'"?(?:mhlo|stablehlo)\.(all_gather|all_reduce|reduce_scatter|all_to_all|'
    r"collective_permute|collective_broadcast)\"?[^:]*:\s*\(([^)]*)\)"
)
_MLIR_TENSOR_RE = re.compile(r"tensor<([0-9x]*)x?([a-z0-9]+)>")


def _shape_bytes(dtype: str, dims: str) -> float:
    size = _DTYPE_BYTES.get(dtype, 4)
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * size


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, float]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def collective_bytes_from_hlo(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective op in HLO (or StableHLO) text."""
    bytes_by_kind: dict[str, float] = {}
    count_by_kind: dict[str, int] = {}

    for m in _HLO_OP_RE.finditer(hlo_text):
        shapes_txt, kind = m.group(1), m.group(2)
        total = 0.0
        for sm in _SHAPE_RE.finditer(shapes_txt):
            total += _shape_bytes(sm.group(1), sm.group(2))
        bytes_by_kind[kind] = bytes_by_kind.get(kind, 0.0) + total
        count_by_kind[kind] = count_by_kind.get(kind, 0) + 1

    if not count_by_kind:  # fall back to StableHLO/MLIR syntax
        for m in _MLIR_OP_RE.finditer(hlo_text):
            kind = m.group(1).replace("_", "-")
            total = 0.0
            for tm in _MLIR_TENSOR_RE.finditer(m.group(2)):
                dims = tm.group(1)
                dtype = tm.group(2)
                n = 1
                for d in dims.split("x"):
                    if d:
                        n *= int(d)
                total += n * _DTYPE_BYTES.get(dtype, 4)
            bytes_by_kind[kind] = bytes_by_kind.get(kind, 0.0) + total
            count_by_kind[kind] = count_by_kind.get(kind, 0) + 1

    return CollectiveStats(bytes_by_kind, count_by_kind)


@dataclasses.dataclass
class Roofline:
    """Per-step roofline terms, all in seconds."""

    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    chips: int
    model_flops: float = 0.0   # 6·N·D useful-FLOPs estimate (set by caller)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste.
        (model_flops is global; hlo_flops per-device ⇒ scale by chips.)"""
        if not self.hlo_flops:
            return 0.0
        return self.model_flops / (self.hlo_flops * self.chips)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achievable at the modeled bound:
        time at peak compute / max(all three terms)."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        return ideal / self.bound_s if self.bound_s else 0.0


def roofline_from_artifacts(
    cost: dict, hlo_text: str, chips: int, model_flops: float = 0.0,
    ici_links: int = 1,
) -> Roofline:
    """Build a Roofline from ``compiled.cost_analysis()`` + HLO text.

    NOTE: for an SPMD-compiled program, ``cost_analysis()`` reports the
    **per-device** module (verified empirically: an 8-way sharded matmul
    reports global/8 flops), and the post-partitioning HLO shapes (hence
    our collective bytes) are per-device too. The assignment's
    ``HLO_FLOPs / (chips × peak)`` is therefore computed as
    ``per_device_FLOPs / peak``; ``model_flops`` stays *global* and is
    divided by chips where compared.
    """
    flops = float(cost.get("flops", 0.0))
    hbm_bytes = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes_from_hlo(hlo_text)
    return Roofline(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=hbm_bytes / HBM_BW,
        collective_s=coll.total_bytes / (ICI_BW * ici_links),
        hlo_flops=flops,
        hlo_bytes=hbm_bytes,
        collective_bytes=coll.total_bytes,
        chips=chips,
        model_flops=model_flops,
    )
