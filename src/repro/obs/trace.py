"""Nestable span tracer with Chrome-trace/Perfetto JSON export.

Spans are wall-clock intervals with string attributes, collected into a
process-wide buffer and exported as Chrome ``traceEvents`` (``ph: "X"``
complete events — ``chrome://tracing`` and https://ui.perfetto.dev both
open the file directly). Nesting is per-thread: a thread-local stack
records the enclosing span, so events carry their parent's name and the
viewer stacks them on the thread's track.

Enabling:

* ``REPRO_TRACE=1`` (or any truthy value) at import, or
  ``REPRO_TRACE=/path/out.json`` to also set the default export path;
* :func:`tracing` as a context manager (exports on exit when given a
  path);
* :func:`enable` / :func:`disable` imperatively.

Overhead policy (DESIGN.md §15): when disabled, :func:`span` returns
the shared :data:`NULL` no-op — one function call, one module-global
boolean read, zero allocation, no clock read. Instrumentation sites
that compute *attributes* (plan signatures, model costs) must guard
that work with :func:`enabled` themselves; the tracer cannot un-pay
work done before the call.

A note on jit: spans emitted inside a ``jax.jit``-ed function body run
at **trace time** — once per compilation, not per call. That is the
"one span per plan lowering" semantic the engine uses deliberately:
the jitted kernel bodies emit lowering spans, while the un-jitted
dispatchers (``run_window_plan``/``run_scan_plan``) emit per-call
spans.
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time

TRACE_ENV = "REPRO_TRACE"

_enabled = False
_default_path: str | None = None
_events: list[dict] = []
_lock = threading.Lock()
_tls = threading.local()
# Trace timestamps are µs relative to this origin (Chrome trace wants
# monotonically comparable ts, not epoch time).
_T0 = time.perf_counter()


class _NullSpan:
    """Shared do-nothing span: the disabled-mode fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL = _NullSpan()


def enabled() -> bool:
    return _enabled


def enable(path: str | None = None) -> None:
    """Turn span collection on (``path`` sets the default export file)."""
    global _enabled, _default_path
    _enabled = True
    if path:
        _default_path = path


def disable() -> None:
    global _enabled
    _enabled = False


def clear() -> None:
    with _lock:
        _events.clear()


def events() -> list[dict]:
    """A copy of the collected Chrome-trace events."""
    with _lock:
        return list(_events)


def _stack() -> list["_Span"]:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def current_stack() -> tuple[str, ...]:
    """Names of the open spans on this thread, outermost first."""
    return tuple(s.name for s in _stack())


def annotate(**attrs) -> None:
    """Attach attributes to the innermost open span on this thread.

    The guarded dispatcher uses this to stamp demotions onto whatever
    engine/op span is already open, without threading span objects
    through the lattice. No-op when tracing is disabled or no span is
    open — same one-bool-read discipline as :func:`span`.
    """
    if not _enabled:
        return
    st = _stack()
    if st:
        st[-1].attrs.update(attrs)


class _Span:
    __slots__ = ("name", "cat", "attrs", "_t0")

    def __init__(self, name: str, cat: str, attrs: dict):
        self.name = name
        self.cat = cat
        self.attrs = attrs

    def __enter__(self):
        st = _stack()
        if st:
            self.attrs.setdefault("parent", st[-1].name)
        st.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        self.attrs["depth"] = len(st)
        with _lock:
            _events.append({
                "name": self.name,
                "cat": self.cat,
                "ph": "X",
                "ts": (self._t0 - _T0) * 1e6,
                "dur": (t1 - self._t0) * 1e6,
                "pid": os.getpid(),
                "tid": threading.get_ident(),
                "args": self.attrs,
            })
        return False


def span(name: str, cat: str = "repro", **attrs):
    """A span context manager — or the shared no-op when disabled.

    Attribute values must be JSON-serializable (stringify plans and
    dtypes at the call site, and only when :func:`enabled`).
    """
    if not _enabled:
        return NULL
    return _Span(name, cat, attrs)


def traced(name: str | None = None, cat: str = "repro"):
    """Decorator form of :func:`span` (zero-overhead when disabled)."""

    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _enabled:
                return fn(*args, **kwargs)
            with _Span(label, cat, {}):
                return fn(*args, **kwargs)

        return wrapper

    return deco


class tracing:
    """``with obs.tracing("out.json"): ...`` — enable, run, export.

    Restores the previous enabled state on exit, so nested/tested use
    cannot leak tracing into the rest of the process.
    """

    def __init__(self, path: str | None = None, *, fresh: bool = True):
        self.path = path
        self.fresh = fresh
        self._was = False

    def __enter__(self):
        self._was = _enabled
        if self.fresh:
            clear()
        enable(self.path)
        return self

    def __exit__(self, *exc):
        if self.path:
            export(self.path)
        if not self._was:
            disable()
        return False


def export(path: str | None = None) -> str | None:
    """Write the collected events as Chrome-trace JSON; returns the path.

    The document shape is the Chrome Trace Event Format's object form:
    ``{"traceEvents": [...], "displayTimeUnit": "ms"}`` — what
    ``chrome://tracing`` and Perfetto ingest unmodified.
    """
    path = path or _default_path
    if not path:
        return None
    doc = {"traceEvents": events(), "displayTimeUnit": "ms"}
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


_env = os.environ.get(TRACE_ENV, "")
if _env and _env.lower() not in ("0", "false", "off"):
    enable(None if _env.lower() in ("1", "true", "on") else _env)
