"""Drift-report CLI: render the model-vs-measured table.

Usage:
  python -m repro.obs.report METRICS.json      # file from --metrics / metrics.export
  python -m repro.obs.report --live            # the in-process recorder

The input is either a :func:`repro.obs.metrics.export` document
(``{"metrics": ..., "drift": ...}``) or a bare
:func:`repro.obs.drift.state` document (``{"cells": ...}``). Rows sort
worst-drift-first: the (plan signature, backend, strategy) cells whose
µs-per-predicted-cycle calibration sits farthest from their backend's
pooled ratio — the shapes where the §5 model is most likely to
mis-rank candidates and the first targets for real-hardware
recalibration (ROADMAP).
"""
from __future__ import annotations

import argparse
import json

from . import drift

_COLS = ("signature", "backend", "strategy", "n", "ratio", "spread",
         "drift", "shape")


def _fmt(v, nd=3):
    return f"{v:.{nd}g}" if isinstance(v, float) else str(v)


def render(doc: dict | None = None) -> str:
    """The drift table as aligned text (one line per cell)."""
    rows = drift.report(doc)
    if not rows:
        return "drift: no model-vs-measured samples recorded"
    table = [_COLS] + [
        (r["signature"], r["backend"], r["strategy"], str(r["n"]),
         _fmt(r["ratio_us_per_cyc"]), _fmt(r["spread_geo"]),
         _fmt(r["drift"]),
         "x".join(map(str, r["last_shape"] or ())) or "-")
        for r in rows
    ]
    widths = [max(len(row[i]) for row in table) for i in range(len(_COLS))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip()
             for row in table]
    lines.insert(1, "  ".join("-" * w for w in widths))
    agg = drift.aggregate(doc)
    for b, a in sorted(agg.items()):
        lines.append(
            f"[{b}] pooled={a['pooled_ratio']:.3g} us/cyc over "
            f"{a['cells']} cells / {a['samples']} samples; worst drift "
            f"{a['max_drift']:.3g}x at {a['worst_signature']}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="render the model-vs-measured drift table")
    ap.add_argument("path", nargs="?", default=None,
                    help="metrics/drift JSON (from benchmarks/run.py "
                         "--metrics PATH or repro.obs.metrics.export)")
    ap.add_argument("--live", action="store_true",
                    help="report the in-process recorder instead of a file")
    args = ap.parse_args(argv)
    doc = None
    if args.path:
        with open(args.path) as f:
            loaded = json.load(f)
        doc = loaded.get("drift", loaded)
    elif not args.live:
        ap.error("give a metrics JSON path (or --live)")
    print(render(doc))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
