"""Engine telemetry: span tracing, metrics, model-vs-measured drift.

The observability layer the rest of the stack reports into (DESIGN.md
§15). Three parts, all stdlib-only so any core module may import them
without cycles:

* :mod:`repro.obs.trace` — a nestable span tracer (context manager +
  decorator, thread-local stack) exporting Chrome-trace/Perfetto JSON.
  Disabled by default; enabled via ``$REPRO_TRACE`` or
  :func:`tracing`. When disabled a span call returns one shared no-op
  object — no allocation, no clock read.
* :mod:`repro.obs.metrics` — a process-wide registry of counters,
  gauges and histograms with ``snapshot()``/``reset()`` and JSON
  export. Always live (a counter bump is a dict add); the registry
  allocates state only for metrics actually touched.
* :mod:`repro.obs.drift` — pairs each launch's predicted §5
  ``model_cost`` cycles with measured µs and ranks the
  (signature, backend, strategy) cells whose calibration drifts from
  the backend-wide ratio — the artifact perf-model recalibration
  consumes (``python -m repro.obs.report``).

Overhead policy: with tracing off and per-call drift sampling off, the
hot path pays one module-level boolean check per instrumentation point
(asserted by ``tests/test_obs.py``). Telemetry never changes results —
every hook is read-only on the data path.
"""
from __future__ import annotations

from . import drift, metrics, trace
from .trace import span, tracing

__all__ = ["drift", "metrics", "trace", "span", "tracing"]
