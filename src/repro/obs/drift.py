"""Model-vs-measured drift recorder for the §5 performance model.

Every measured launch contributes a pair: the §5 model's predicted
``model_cost`` (cycles per useful output element) and the measured
wall-time (µs). Their ratio ``µs / cycle`` is the *calibration
constant* of the (plan signature, engine backend, strategy) cell —
on a perfectly modeled machine it is the same constant everywhere
(cycle time × elements), so the interesting signal is **dispersion**:

* a cell whose ratio sits far from its backend's pooled geometric-mean
  ratio is a shape class the model mis-prices — exactly where the
  tuner's ranking can flip (the paper's §5 validation concern, and the
  pre-work the ROADMAP "real-hardware recalibration" item needs);
* a cell with a wide geometric spread across its own samples is noisy
  measurement, not model error — the report separates the two.

Pairs arrive from two sources:

* **autotune sampling** (always on, free): every candidate the tuner's
  measuring pass times already has both numbers in hand
  (:func:`repro.core.tuning.autotune` records each one);
* **per-call timing** (opt-in: ``REPRO_DRIFT=1`` or
  :func:`sample_calls`): the engine dispatchers block on the result
  and record wall-time against the launch's model cost — off by
  default because the block defeats async dispatch.

Ratios are tracked in log space (running sum + sum of squares), so the
state is O(#cells) regardless of sample count and merges trivially.
"""
from __future__ import annotations

import math
import os
import threading

DRIFT_ENV = "REPRO_DRIFT"

_lock = threading.Lock()
# key "signature|backend|strategy" → running log-space stats
_cells: dict[str, dict] = {}

_per_call = bool(os.environ.get(DRIFT_ENV, "").lower()
                 not in ("", "0", "false", "off"))


def per_call() -> bool:
    """Is opt-in per-launch timing on? (One bool read on the hot path.)"""
    return _per_call


def sample_calls(on: bool) -> None:
    global _per_call
    _per_call = bool(on)


def _key(signature: str, backend: str, strategy: str | None) -> str:
    return f"{signature}|{backend}|{strategy or 'lanes'}"


def record(signature: str, backend: str, strategy: str | None,
           predicted_cycles: float, measured_us: float,
           shape=None, source: str = "autotune") -> None:
    """Fold one (predicted cycles, measured µs) pair into its cell."""
    if not (predicted_cycles > 0 and measured_us > 0):
        return
    lg = math.log(measured_us / predicted_cycles)
    key = _key(signature, backend, strategy)
    with _lock:
        c = _cells.get(key)
        if c is None:
            c = _cells[key] = {
                "signature": signature, "backend": backend,
                "strategy": strategy or "lanes",
                "n": 0, "sum_log": 0.0, "sum_log_sq": 0.0,
                "min_ratio": None, "max_ratio": None,
                "last_shape": None, "sources": {},
            }
        ratio = measured_us / predicted_cycles
        c["n"] += 1
        c["sum_log"] += lg
        c["sum_log_sq"] += lg * lg
        c["min_ratio"] = (ratio if c["min_ratio"] is None
                          else min(c["min_ratio"], ratio))
        c["max_ratio"] = (ratio if c["max_ratio"] is None
                          else max(c["max_ratio"], ratio))
        if shape is not None:
            c["last_shape"] = list(shape)
        c["sources"][source] = c["sources"].get(source, 0) + 1


def reset() -> None:
    with _lock:
        _cells.clear()


def state() -> dict:
    """The recorder state as a JSON-ready dict (mergeable/loadable)."""
    with _lock:
        return {"cells": {k: dict(v, sources=dict(v["sources"]))
                          for k, v in _cells.items()}}


def load_state(doc: dict) -> int:
    """Merge a :func:`state` document back in; returns #cells merged."""
    cells = (doc or {}).get("cells", {})
    n = 0
    with _lock:
        for key, c in cells.items():
            mine = _cells.get(key)
            if mine is None:
                _cells[key] = {**c, "sources": dict(c.get("sources", {}))}
            else:
                mine["n"] += c["n"]
                mine["sum_log"] += c["sum_log"]
                mine["sum_log_sq"] += c["sum_log_sq"]
                for lim, pick in (("min_ratio", min), ("max_ratio", max)):
                    if c.get(lim) is not None:
                        mine[lim] = (c[lim] if mine[lim] is None
                                     else pick(mine[lim], c[lim]))
                for s, k in c.get("sources", {}).items():
                    mine["sources"][s] = mine["sources"].get(s, 0) + k
            n += 1
    return n


def report(doc: dict | None = None) -> list[dict]:
    """Drift rows, worst first.

    Per cell: the geometric-mean calibration ratio (µs/cycle), its
    geometric spread (σ in log space, exponentiated — ~1.0 means tight
    samples), and ``drift`` = the cell ratio over its backend's pooled
    ratio (log-signed: >1 the model is optimistic for this shape, <1
    pessimistic). Rows sort by |log drift| — the cells most likely to
    make the §5 ranking flip come first.
    """
    cells = ((doc or state()).get("cells") or {})
    pooled: dict[str, list[float]] = {}
    for c in cells.values():
        pooled.setdefault(c["backend"], []).append((c["sum_log"], c["n"]))
    base = {
        b: math.exp(sum(s for s, _ in pairs) / max(sum(n for _, n in pairs), 1))
        for b, pairs in pooled.items()
    }
    rows = []
    for c in cells.values():
        n = max(c["n"], 1)
        mean_log = c["sum_log"] / n
        var = max(c["sum_log_sq"] / n - mean_log * mean_log, 0.0)
        ratio = math.exp(mean_log)
        drift = ratio / base[c["backend"]]
        rows.append({
            "signature": c["signature"], "backend": c["backend"],
            "strategy": c["strategy"], "n": c["n"],
            "ratio_us_per_cyc": ratio,
            "spread_geo": math.exp(math.sqrt(var)),
            "backend_ratio": base[c["backend"]],
            "drift": drift,
            "abs_log_drift": abs(math.log(drift)) if drift > 0 else 0.0,
            "min_ratio": c.get("min_ratio"),
            "max_ratio": c.get("max_ratio"),
            "last_shape": c.get("last_shape"),
        })
    rows.sort(key=lambda r: r["abs_log_drift"], reverse=True)
    return rows


def aggregate(doc: dict | None = None) -> dict:
    """Fleet-level summary for bench artifacts (BENCH_9 rows): per
    backend, the pooled ratio, the worst cell drift and the cell count."""
    rows = report(doc)
    out: dict[str, dict] = {}
    best: dict[str, float] = {}
    for r in rows:
        agg = out.setdefault(r["backend"], {
            "cells": 0, "samples": 0, "pooled_ratio": r["backend_ratio"],
            "max_drift": 1.0, "worst_signature": None,
        })
        agg["cells"] += 1
        agg["samples"] += r["n"]
        if r["abs_log_drift"] >= best.get(r["backend"], -1.0):
            best[r["backend"]] = r["abs_log_drift"]
            agg["max_drift"] = r["drift"]
            agg["worst_signature"] = r["signature"]
    return out
