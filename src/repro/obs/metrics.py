"""Process-wide metrics registry: counters, gauges, histograms.

One flat namespace of dotted metric names (``"tuner.sidecar_hit"``,
``"engine.launch"``, ``"serve.request_us"``). Three kinds:

* **counters** — monotone :class:`LabeledCounter` maps (a
  ``collections.Counter`` subclass, so existing Counter-shaped call
  sites like ``adjoint.BACKWARD_LOWERINGS`` migrate by aliasing the
  registry object). Unlabeled increments use the ``""`` key; labeled
  ones key by an arbitrary string (``"gpu:mxu"``).
* **gauges** — last-write-wins floats.
* **histograms** — bounded reservoirs of observations with
  count/sum/min/max and percentile readout (p50/p99 in
  :func:`snapshot`); the reservoir keeps the most recent
  :data:`HISTOGRAM_CAP` values, the scalar aggregates cover everything
  ever observed.

Always live: a counter bump is a dict add (~100 ns) and the registry
allocates state only for metrics actually touched, which is the
zero-state-when-unused half of the §15 overhead policy (the tracer
carries the zero-overhead-when-disabled half). :func:`reset` clears
registered objects **in place** so module-level aliases stay valid.
"""
from __future__ import annotations

import collections
import json
import os
import threading

HISTOGRAM_CAP = 8192

_lock = threading.Lock()


class LabeledCounter(collections.Counter):
    """A registry-held Counter: label → count (``""`` = unlabeled)."""

    __slots__ = ()

    def total_count(self) -> float:
        # Counter.total() exists only on 3.10+; keep an explicit form.
        return float(sum(self.values()))


class Histogram:
    """Bounded-reservoir histogram with exact global count/sum/min/max."""

    __slots__ = ("values", "count", "sum", "min", "max")

    def __init__(self):
        self.values: collections.deque = collections.deque(
            maxlen=HISTOGRAM_CAP)
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None

    def observe(self, v: float) -> None:
        v = float(v)
        self.values.append(v)
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def percentile(self, p: float) -> float | None:
        """Nearest-rank percentile over the retained reservoir."""
        if not self.values:
            return None
        vs = sorted(self.values)
        idx = min(len(vs) - 1, max(0, int(round(p / 100.0 * (len(vs) - 1)))))
        return vs[idx]

    def clear(self) -> None:
        self.values.clear()
        self.count = 0
        self.sum = 0.0
        self.min = self.max = None

    def summary(self) -> dict:
        mean = self.sum / self.count if self.count else None
        return {"count": self.count, "sum": self.sum, "min": self.min,
                "max": self.max, "mean": mean,
                "p50": self.percentile(50), "p99": self.percentile(99)}


_counters: dict[str, LabeledCounter] = {}
_gauges: dict[str, float] = {}
_histograms: dict[str, Histogram] = {}


def counter(name: str) -> LabeledCounter:
    """The (lazily created) counter registered under ``name``."""
    c = _counters.get(name)
    if c is None:
        with _lock:
            c = _counters.setdefault(name, LabeledCounter())
    return c


def inc(name: str, label: str = "", n: float = 1) -> None:
    counter(name)[label] += n


def gauge(name: str, value: float) -> None:
    _gauges[name] = float(value)


def histogram(name: str) -> Histogram:
    h = _histograms.get(name)
    if h is None:
        with _lock:
            h = _histograms.setdefault(name, Histogram())
    return h


def observe(name: str, value: float) -> None:
    histogram(name).observe(value)


def snapshot() -> dict:
    """The whole registry as a JSON-ready dict.

    Counters render as ``{label: count}`` maps plus a ``total``;
    histograms as their scalar summaries with p50/p99.
    """
    return {
        "counters": {
            name: {"total": c.total_count(), "by_label": dict(c)}
            for name, c in sorted(_counters.items()) if c
        },
        "gauges": dict(sorted(_gauges.items())),
        "histograms": {
            name: h.summary()
            for name, h in sorted(_histograms.items()) if h.count
        },
    }


def counter_total(name: str) -> float:
    """Total across labels of one counter (0 when never touched)."""
    c = _counters.get(name)
    return c.total_count() if c else 0.0


def reset() -> None:
    """Zero every registered metric **in place** — module-level aliases
    (``adjoint.BACKWARD_LOWERINGS``) keep pointing at the live object."""
    with _lock:
        for c in _counters.values():
            c.clear()
        _gauges.clear()
        for h in _histograms.values():
            h.clear()


def export(path: str) -> str:
    """Write :func:`snapshot` (plus the drift state, so one file feeds
    ``python -m repro.obs.report``) as JSON; returns the path."""
    from . import drift
    doc = {"metrics": snapshot(), "drift": drift.state()}
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path
