"""Core NN layers: norms, dense, embeddings, RoPE variants, MLPs.

Functional style: ``<layer>_specs(...)`` returns a ParamSpec tree,
``<layer>_apply(params, ...)`` consumes the materialized (or abstract)
tree. Logical axis names on every ParamSpec drive sharding
(:mod:`repro.distributed.sharding`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .spec import ParamSpec


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_specs(d: int, *, plus_one: bool = False) -> dict:
    # gemma convention: scale parameterized around zero, applied as (1+scale)
    return {"scale": ParamSpec((d,), ("embed",), init="zeros" if plus_one else "ones")}


def rmsnorm_apply(p, x, *, eps: float = 1e-6, plus_one: bool = False):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    scale = p["scale"].astype(jnp.float32)
    if plus_one:
        scale = scale + 1.0
    return (y * scale).astype(dt)


def layernorm_specs(d: int) -> dict:
    return {
        "scale": ParamSpec((d,), ("embed",), init="ones"),
        "bias": ParamSpec((d,), ("embed",), init="zeros"),
    }


def layernorm_apply(p, x, *, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# Dense / embeddings
# ---------------------------------------------------------------------------

def dense_specs(d_in: int, d_out: int, *, axes=("embed", "ff"), bias: bool = False,
                init: str = "normal") -> dict:
    s = {"w": ParamSpec((d_in, d_out), axes, init=init)}
    if bias:
        s["b"] = ParamSpec((d_out,), (axes[1],), init="zeros")
    return s


def dense_apply(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def conv2d_specs(c_in: int, c_out: int, k: int | tuple[int, int], *,
                 bias: bool = True) -> dict:
    """NCHW 2-D convolution layer: OIHW filter + per-channel bias.

    The ``conv_out``/``conv_in`` logical axes are replicated by the
    default rule tables (filters are small; the engine shards the
    *activation* batch/spatial axes instead, see halo_exchange).
    """
    kh, kw = (k, k) if isinstance(k, int) else k
    s = {"w": ParamSpec((c_out, c_in, kh, kw),
                        ("conv_out", "conv_in", None, None))}
    if bias:
        s["b"] = ParamSpec((c_out,), ("conv_out",), init="zeros")
    return s


def conv2d_apply(p, x, *, mode: str = "same", stride: int | tuple[int, int] = 1,
                 impl: str | None = None, activation: str | None = None,
                 **kw):
    """NCHW convolution lowered through the SSAM engine.

    ``x (B, C_in, H, W) → (B, C_out, H', W')`` via
    :func:`repro.kernels.ops.conv2d`'s reduce-axes plan — one
    ``pallas_call`` whose grid iterates batch × C_out × spatial × C_in
    with an fp32 channel accumulator; no Python loop over batch or
    channels. ``impl=None`` picks the backend's *engine* path (compiled
    Mosaic on TPU, Pallas interpret elsewhere): with the adjoint-plan
    subsystem the engine is fully differentiable, so training no longer
    silently falls back to the XLA oracle — forward and backward both
    lower through the plan engine. Pass ``impl="xla"`` explicitly for
    the pjit-shardable oracle.

    The per-channel bias and ``activation`` ('gelu'/'silu'/'relu') ride
    :func:`repro.kernels.ops.conv2d`'s **epilogue** — fused into the
    kernel between accumulator flush and output store on the engine
    path (no XLA elementwise pass, no HBM round-trip of the
    activation), replayed in jnp by the ``impl="xla"`` oracle — and a
    stride lowers as an **output-strided grid** computing only the kept
    lanes (DESIGN.md §11). Exception: under ``mesh=`` the stride stays
    a local subsample of the dense sharded conv (an output-strided
    domain is not shape-preserving, so it cannot shard).
    """
    from repro.kernels import ops as kops
    impl = impl or kops.default_engine_impl()
    sh, sw = (stride, stride) if isinstance(stride, int) else stride
    epilogue, epi_args = [], []
    if "b" in p:
        epilogue.append("bias")
        epi_args.append(p["b"])
    if activation is not None:
        epilogue.append(activation)
    strided = (sh, sw) != (1, 1)
    # under a mesh the dense sharded conv runs and the stride subsamples
    # locally (elementwise epilogues commute with the subsample)
    subsample_locally = strided and kw.get("mesh") is not None
    y = kops.conv2d(
        x, p["w"], mode=mode, impl=impl,
        stride=(sh, sw) if strided and not subsample_locally else None,
        epilogue=tuple(epilogue) or None, epilogue_args=tuple(epi_args),
        **kw)
    if subsample_locally:
        y = y[..., ::sh, ::sw]
    return y


def embedding_specs(vocab: int, d: int) -> dict:
    return {"table": ParamSpec((vocab, d), ("vocab", "embed"), init="embed")}


def embedding_apply(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def logits_apply(p, x):
    """Tied-embedding readout: x @ tableᵀ → (…, vocab)."""
    return x @ p["table"].T.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (standard / partial / dual-base)
# ---------------------------------------------------------------------------

def rope_table(positions: jax.Array, rot_dim: int, base: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables: positions (…,) → (…, rot_dim/2)."""
    assert rot_dim % 2 == 0
    inv = 1.0 / (base ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array, rot_dim: int) -> jax.Array:
    """Rotate the first ``rot_dim`` features of ``x`` (…, S, H, hd).

    Half-split (NeoX) convention; cos/sin are (…, S, rot_dim/2) and
    broadcast over the head axis.
    """
    if rot_dim == 0:
        return x
    rot, rest = x[..., :rot_dim], x[..., rot_dim:]
    half = rot_dim // 2
    x1, x2 = rot[..., :half], rot[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    r1 = x1 * c - x2 * s
    r2 = x2 * c + x1 * s
    return jnp.concatenate([r1, r2, rest], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def gated_mlp_specs(d: int, ff: int) -> dict:
    """SwiGLU/GeGLU style gated MLP (llama/chatglm/dbrx/gemma)."""
    return {
        "w_gate": ParamSpec((d, ff), ("embed", "ff")),
        "w_up": ParamSpec((d, ff), ("embed", "ff")),
        "w_down": ParamSpec((ff, d), ("ff", "embed")),
    }


def gated_mlp_apply(p, x, *, act: str = "silu"):
    g = x @ p["w_gate"].astype(x.dtype)
    u = x @ p["w_up"].astype(x.dtype)
    if act == "silu":
        g = jax.nn.silu(g)
    elif act == "gelu":
        g = jax.nn.gelu(g, approximate=True)
    else:
        raise ValueError(act)
    return (g * u) @ p["w_down"].astype(x.dtype)


def mlp_specs(d: int, ff: int, *, bias: bool = True) -> dict:
    """Plain 2-layer MLP (starcoder2, whisper)."""
    s = {
        "w_in": ParamSpec((d, ff), ("embed", "ff")),
        "w_out": ParamSpec((ff, d), ("ff", "embed")),
    }
    if bias:
        s["b_in"] = ParamSpec((ff,), ("ff",), init="zeros")
        s["b_out"] = ParamSpec((d,), ("embed",), init="zeros")
    return s


def mlp_apply(p, x, *, act: str = "gelu"):
    h = x @ p["w_in"].astype(x.dtype)
    if "b_in" in p:
        h = h + p["b_in"].astype(x.dtype)
    h = jax.nn.gelu(h, approximate=True) if act == "gelu" else jax.nn.silu(h)
    y = h @ p["w_out"].astype(x.dtype)
    if "b_out" in p:
        y = y + p["b_out"].astype(x.dtype)
    return y
