"""Minimal functional NN substrate: ParamSpec trees + layer apply functions."""
from . import attention, layers, moe, spec, ssm  # noqa: F401
