"""State-space / linear-attention blocks: Mamba (Hymba branch) and RWKV6.

Both are driven by the SSAM linear-recurrence plan (DESIGN.md §3): the
elementwise recurrence ``h_t = a_t·h_{t−1} + b_t`` *is* the paper's Eq. 1
with the Kogge–Stone dependency graph. Execution paths:

* ``impl='engine'`` (TPU default) → the chunk-streamed SSAM schedule
  (DESIGN.md §12): per-chunk transfer pairs run through the engine's
  carry op inside a ``lax.scan``, contracted against C/r immediately, so
  peak live state is O(B·chunk·rows) at any context length — forward and
  backward (chunk-boundary checkpointing).
* ``impl='engine_unchunked'`` → the monolithic O(T) engine lowering,
  kept as the validation reference.
* ``impl='chunked'`` (non-TPU default) → chunked matmul forms below
  (MXU-friendly, O(L²) intra-chunk attention-like matmuls + state
  passing across chunks), the beyond-paper optimized path recorded in
  EXPERIMENTS.md §Perf.
* ``impl=None`` resolves per backend via
  :func:`repro.kernels.ops.default_scan_impl`.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .spec import ParamSpec
from .layers import rmsnorm_apply


# ---------------------------------------------------------------------------
# Mamba (selective SSM) — used by Hymba's parallel mamba heads
# ---------------------------------------------------------------------------

def mamba_specs(d: int, *, d_inner: int, ssm_state: int, conv_k: int = 4,
                dt_rank: int | None = None) -> dict:
    dt_rank = dt_rank or max(d // 16, 1)
    return {
        "in_proj": ParamSpec((d, 2 * d_inner), ("embed", "ff")),
        "conv_w": ParamSpec((conv_k, d_inner), ("conv", "ff")),
        "conv_b": ParamSpec((d_inner,), ("ff",), init="zeros"),
        "x_proj": ParamSpec((d_inner, dt_rank + 2 * ssm_state), ("ff", "lora")),
        "dt_w": ParamSpec((dt_rank, d_inner), ("lora", "ff")),
        "dt_b": ParamSpec((d_inner,), ("ff",), init="small"),
        "A_log": ParamSpec((d_inner, ssm_state), ("ff", "state"), init="small"),
        "D": ParamSpec((d_inner,), ("ff",), init="ones"),
        "out_proj": ParamSpec((d_inner, d), ("ff", "embed")),
    }


def _engine_scan_rows(a, b):
    """Run ``h_t = a_t·h_{t−1} + b_t`` through the SSAM engine.

    a, b: (..., T) fp32 transfer pairs, time last. Delegates to
    :func:`repro.kernels.ops.chunked_linear_recurrence`'s monolithic
    engine path — one flatten-to-rows wrapper for the model-side
    validation paths (the streamed schedules below never materialize
    the full-T pairs in the first place).
    """
    from repro.kernels import ops as kops
    return kops.chunked_linear_recurrence(a, b, impl="engine_unchunked")


def _selective_scan_engine(delta, A_log, Bmat, Cmat, x):
    """Engine-lowered selective scan: the per-(channel, state) scalar
    recurrence of Eq. h[t] = exp(Δ_t·A)⊙h[t−1] + (Δ_t·x_t)·B_t run as
    ``B·Di·N`` independent rows through ``run_scan_plan``.

    Materializes the (B, T, Di, N) transfer pairs and state history —
    the paper-faithful validation path, not the O(chunk)-memory
    production schedule (use ``impl='chunked'`` for that).
    """
    Bsz, T, Di = x.shape
    N = A_log.shape[1]
    A = -jnp.exp(A_log.astype(jnp.float32))                       # (Di, N)
    d32 = delta.astype(jnp.float32)
    a = jnp.exp(d32[..., None] * A)                               # (B,T,Di,N)
    b = (d32 * x.astype(jnp.float32))[..., None] \
        * Bmat.astype(jnp.float32)[:, :, None, :]
    hs = _engine_scan_rows(jnp.moveaxis(a, 1, -1), jnp.moveaxis(b, 1, -1))
    hs = jnp.moveaxis(hs, -1, 1)                                  # (B,T,Di,N)
    y = jnp.einsum("btin,btn->bti", hs, Cmat.astype(jnp.float32))
    return y.astype(x.dtype), hs[:, -1]


def _selective_scan_engine_stream(delta, A_log, Bmat, Cmat, x, *, chunk):
    """Chunk-streamed engine selective scan (DESIGN.md §12).

    Streams the sequence through ``(B, L, Di, N)`` slabs: each chunk's
    transfer pairs run as ``B·Di·N`` rows through the engine's carry op
    and are contracted against ``C`` before the next chunk starts, so
    peak live state is O(B·L·Di·N) at any T. The ``lax.scan`` carry is
    the per-row state; the body is ``jax.checkpoint``-wrapped, so the
    backward saves only chunk-boundary carries and re-runs the engine
    kernel per chunk — both directions engine-lowered.
    """
    from repro.kernels import ops as kops

    Bsz, T, Di = x.shape
    N = A_log.shape[1]
    L = min(chunk, T)
    pad = (-T) % L
    if pad:
        # Δ pads with zeros: a = exp(0·A) = 1, b = 0 — identity transfers.
        delta = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    nc = (T + pad) // L
    A = -jnp.exp(A_log.astype(jnp.float32))                       # (Di, N)

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(Bsz, nc, L, *t.shape[2:]), 1, 0)

    dc, Bc, Cc, xc = map(to_chunks, (delta, Bmat, Cmat, x))

    def chunk_step(h, args):
        d_k, B_k, C_k, x_k = args                                  # (B, L, …)
        d32 = d_k.astype(jnp.float32)
        a = jnp.exp(d32[..., None] * A)                            # (B,L,Di,N)
        b = (d32 * x_k.astype(jnp.float32))[..., None] \
            * B_k.astype(jnp.float32)[:, :, None, :]
        rows_a = jnp.moveaxis(a, 1, -1).reshape(-1, L)             # (B·Di·N, L)
        rows_b = jnp.moveaxis(b, 1, -1).reshape(-1, L)
        hs, h_new = kops.linear_recurrence_carry(rows_a, rows_b, h)
        hs = jnp.moveaxis(hs.reshape(Bsz, Di, N, L), -1, 1)        # (B,L,Di,N)
        y = jnp.einsum("blin,bln->bli", hs, C_k.astype(jnp.float32))
        return h_new, y

    h0 = jnp.zeros((Bsz * Di * N, 1), jnp.float32)
    h_last, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0, (dc, Bc, Cc, xc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, T + pad, Di)[:, :T]
    return y.astype(x.dtype), h_last.reshape(Bsz, Di, N)


def selective_scan(delta, A_log, Bmat, Cmat, x, *, chunk: int = 128,
                   work_dtype=jnp.float32, impl: str | None = None):
    """Chunked selective scan.

    delta, x: (B, T, Di); Bmat, Cmat: (B, T, N); A_log: (Di, N).
    h[t] = exp(Δ_t·A)⊙h[t−1] + (Δ_t·x_t)·B_t ;  y[t] = C_t·h[t] + D-term (caller).
    Only one chunk of the (B, L, Di, N) tensor is ever live.

    ``impl``: ``None`` resolves per backend
    (:func:`repro.kernels.ops.default_scan_impl` — the streamed engine
    on TPU); 'chunked' is the MXU-friendly matmul schedule; 'engine' the
    chunk-streamed SSAM schedule (O(chunk) live state, the production
    engine path); 'engine_unchunked' the monolithic O(T) engine
    validation lowering. All agree to fp32 tolerance.
    """
    from repro.kernels import ops as kops
    impl = impl or kops.default_scan_impl()
    if impl == "engine":
        return _selective_scan_engine_stream(delta, A_log, Bmat, Cmat, x,
                                             chunk=chunk)
    if impl == "engine_unchunked":
        return _selective_scan_engine(delta, A_log, Bmat, Cmat, x)
    if impl != "chunked":
        raise ValueError(impl)
    Bsz, T, Di = x.shape
    N = A_log.shape[1]
    L = min(chunk, T)
    pad = (-T) % L
    if pad:
        delta = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    nc = (T + pad) // L
    A = -jnp.exp(A_log.astype(jnp.float32))                       # (Di, N)

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(Bsz, nc, L, *t.shape[2:]), 1, 0)

    dc, Bc, Cc, xc = map(to_chunks, (delta, Bmat, Cmat, x))

    def chunk_step(h, args):
        d_k, B_k, C_k, x_k = args                                  # (B, L, …)
        # §Perf lever: the (B,L,Di,N) transfer pairs and scan levels may
        # run in bf16 (work_dtype) while the carried state stays f32.
        a = jnp.exp(d_k.astype(jnp.float32)[..., None] * A).astype(work_dtype)
        b = ((d_k * x_k).astype(jnp.float32)[..., None]
             * B_k.astype(jnp.float32)[:, :, None, :]).astype(work_dtype)
        Ap, Bp = jax.lax.associative_scan(
            lambda l, r: (l[0] * r[0], l[1] * r[0] + r[1]), (a, b), axis=1)
        hs = Ap.astype(jnp.float32) * h[:, None] + Bp.astype(jnp.float32)
        y = jnp.einsum("blin,bln->bli", hs.astype(work_dtype),
                       C_k.astype(work_dtype),
                       preferred_element_type=jnp.float32)
        return hs[:, -1], y

    h0 = jnp.zeros((Bsz, Di, N), jnp.float32)
    h_last, ys = jax.lax.scan(chunk_step, h0, (dc, Bc, Cc, xc))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, T + pad, Di)[:, :T]
    return y.astype(x.dtype), h_last


def mamba_apply(p, x, *, ssm_state: int, conv_k: int = 4, chunk: int = 128,
                state=None, work_dtype=jnp.float32, conv_impl: str | None = None,
                scan_impl: str | None = None):
    """Mamba block. Train/prefill: state=None. Decode: state dict with
    {"h": (B, Di, N), "conv": (B, K−1, Di)} — O(1) per-token step.

    ``conv_impl`` routes the depthwise causal conv: None picks the
    backend's *engine* path (the D-optimal SSAM plan — compiled Mosaic
    on TPU, Pallas interpret elsewhere; differentiable via its adjoint
    plan, so training runs on the engine by default);
    'interpret'/'pallas'/'xla' force a path. ``scan_impl``
    (None | 'chunked' | 'engine' | 'engine_unchunked') selects the
    selective-scan execution, see :func:`selective_scan`.
    """
    from repro.kernels import ops as kops

    B, T, _ = x.shape
    Di = p["in_proj"].shape[1] // 2
    dt_rank = p["dt_w"].shape[0]
    xz = x @ p["in_proj"].astype(x.dtype)
    xs, z = xz[..., :Di], xz[..., Di:]

    if state is None:
        impl = conv_impl or kops.default_engine_impl()
        if impl == "xla":
            xs = kops.conv1d_causal(xs, p["conv_w"], impl="xla") \
                + p["conv_b"].astype(x.dtype)
            xs = jax.nn.silu(xs)
        else:
            # bias + SiLU ride the depthwise plan's fused epilogue: the
            # conv output never stores to HBM before the activation
            # (DESIGN.md §11; previously an XLA silu between two stores).
            xs = kops.conv1d_causal(
                xs, p["conv_w"], impl=impl,
                epilogue=("bias", "silu"), epilogue_args=(p["conv_b"],))
        dbc = xs @ p["x_proj"].astype(x.dtype)
        dt = jax.nn.softplus(dbc[..., :dt_rank] @ p["dt_w"].astype(x.dtype)
                             + p["dt_b"].astype(x.dtype))
        Bmat = dbc[..., dt_rank : dt_rank + ssm_state]
        Cmat = dbc[..., dt_rank + ssm_state :]
        y, h_last = selective_scan(dt, p["A_log"], Bmat, Cmat, xs, chunk=chunk,
                                   work_dtype=work_dtype, impl=scan_impl)
        y = y + xs * p["D"].astype(x.dtype)
        new_state = {"h": h_last, "conv": xs[:, -(conv_k - 1):, :] if T >= conv_k - 1 else None}
    else:
        # single-token recurrent step (T == 1)
        conv_tail = state["conv"]                                  # (B, K−1, Di)
        window = jnp.concatenate([conv_tail, xs], axis=1)          # (B, K, Di)
        xs1 = jnp.einsum("bkd,kd->bd", window, p["conv_w"].astype(x.dtype))
        xs1 = jax.nn.silu(xs1 + p["conv_b"].astype(x.dtype))[:, None, :]
        dbc = xs1 @ p["x_proj"].astype(x.dtype)
        dt = jax.nn.softplus(dbc[..., :dt_rank] @ p["dt_w"].astype(x.dtype)
                             + p["dt_b"].astype(x.dtype))          # (B,1,Di)
        Bmat = dbc[..., dt_rank : dt_rank + ssm_state]
        Cmat = dbc[..., dt_rank + ssm_state :]
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)[:, 0]   # (B,Di,N)
        b = (dt * xs1).astype(jnp.float32)[..., None][:, 0] * Bmat.astype(jnp.float32)[:, 0, None, :]
        h = a * state["h"] + b
        y = jnp.einsum("bin,bn->bi", h, Cmat.astype(jnp.float32)[:, 0])[:, None, :]
        y = y.astype(x.dtype) + xs1 * p["D"].astype(x.dtype)
        new_state = {"h": h, "conv": window[:, 1:, :]}
    out = (y * jax.nn.silu(z)) @ p["out_proj"].astype(x.dtype)
    return out, new_state


# ---------------------------------------------------------------------------
# RWKV6 ("Finch"): data-dependent token shift + WKV recurrence
# ---------------------------------------------------------------------------

def rwkv6_timemix_specs(d: int, *, n_heads: int, head_k: int, head_v: int,
                        shift_lora: int = 32, decay_lora: int = 64) -> dict:
    return {
        "mu_x": ParamSpec((d,), ("embed",), init="small"),
        "mu": ParamSpec((5, d), (None, "embed"), init="small"),
        "shift_w1": ParamSpec((d, 5 * shift_lora), ("embed", "lora"), init="small"),
        "shift_w2": ParamSpec((5, shift_lora, d), (None, "lora", "embed"), init="small"),
        "w0": ParamSpec((n_heads, head_k), ("heads", "head_dim"), init="small"),
        "decay_w1": ParamSpec((d, decay_lora), ("embed", "lora"), init="small"),
        "decay_w2": ParamSpec((decay_lora, n_heads, head_k), ("lora", "heads", "head_dim"), init="small"),
        "u": ParamSpec((n_heads, head_k), ("heads", "head_dim"), init="small"),
        "wr": ParamSpec((d, n_heads, head_k), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, n_heads, head_k), ("embed", "heads", "head_dim")),
        "wv": ParamSpec((d, n_heads, head_v), ("embed", "heads", "head_dim")),
        "wg": ParamSpec((d, n_heads, head_v), ("embed", "heads", "head_dim")),
        "ln_x": ParamSpec((n_heads, head_v), ("heads", "head_dim"), init="ones"),
        "wo": ParamSpec((n_heads, head_v, d), ("heads", "head_dim", "embed")),
    }


def _wkv6_engine(r, k, v, logw, u):
    """Engine-lowered WKV6: the state recurrence is diagonal per
    ``(head, k, v)`` pair — ``S[k,v]_t = exp(logw_t[k])·S[k,v]_{t−1} +
    k_t[k]·v_t[v]`` — so it runs as ``B·H·K·V`` scalar rows through
    ``run_scan_plan``, then ``y_t = r_t·S_{t−1} + (r⊙u⊙k)·v`` reads the
    shifted inclusive scan.

    Materializes the (B, T, H, K, V) state history — the validation
    path proving the production WKV runs on the same engine as the
    benchmarks; use ``impl='chunked'`` for the O(chunk)-memory matmul
    schedule.
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    k32 = k.astype(jnp.float32)
    a = jnp.broadcast_to(
        jnp.exp(logw.astype(jnp.float32))[..., None], (B, T, H, K, V))
    b = k32[..., None] * v.astype(jnp.float32)[..., None, :]      # (B,T,H,K,V)
    S = _engine_scan_rows(jnp.moveaxis(a, 1, -1), jnp.moveaxis(b, 1, -1))
    S = jnp.moveaxis(S, -1, 1)                                    # (B,T,H,K,V)
    S_prev = jnp.concatenate([jnp.zeros_like(S[:, :1]), S[:, :-1]], axis=1)
    r32 = r.astype(jnp.float32)
    diag = (r32 * u[None, None].astype(jnp.float32) * k32).sum(-1)
    y = jnp.einsum("bthk,bthkv->bthv", r32, S_prev) \
        + diag[..., None] * v.astype(jnp.float32)
    return y.astype(r.dtype), S[:, -1]


def _wkv6_engine_stream(r, k, v, logw, u, *, chunk):
    """Chunk-streamed engine WKV6 (DESIGN.md §12).

    Streams the sequence through ``(B, L, H, K, V)`` slabs: each chunk's
    diagonal state recurrence runs as ``B·H·K·V`` rows through the
    engine's carry op, the output contraction
    ``y_t = r_t·S_{t−1} + (r⊙u⊙k)·v`` happens before the next chunk, and
    the ``lax.scan`` carry is the flattened state matrix — peak live
    state O(B·L·H·K·V) at any T, checkpointed backward.
    """
    from repro.kernels import ops as kops

    B, T, H, K = r.shape
    V = v.shape[-1]
    L = min(chunk, T)
    pad = (-T) % L
    if pad:
        # logw pads with zeros: a = exp(0) = 1; k·v = 0 — identity steps.
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, logw = z(r), z(k), z(v), z(logw)
    nc = (T + pad) // L

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(B, nc, L, H, -1), 1, 0)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, logw))
    u32 = u[None, None].astype(jnp.float32)

    def chunk_step(S, args):
        r_k, k_k, v_k, w_k = args                                  # (B, L, H, ·)
        k32 = k_k.astype(jnp.float32)
        a = jnp.broadcast_to(
            jnp.exp(w_k.astype(jnp.float32))[..., None], (B, L, H, K, V))
        b = k32[..., None] * v_k.astype(jnp.float32)[..., None, :]
        rows_a = jnp.moveaxis(a, 1, -1).reshape(-1, L)             # (B·H·K·V, L)
        rows_b = jnp.moveaxis(b, 1, -1).reshape(-1, L)
        Ss, S_new = kops.linear_recurrence_carry(rows_a, rows_b, S)
        Ss = jnp.moveaxis(Ss.reshape(B, H, K, V, L), -1, 1)        # (B,L,H,K,V)
        S_prev = jnp.concatenate(
            [S.reshape(B, 1, H, K, V), Ss[:, :-1]], axis=1)
        r32 = r_k.astype(jnp.float32)
        diag = (r32 * u32 * k32).sum(-1)
        y = jnp.einsum("blhk,blhkv->blhv", r32, S_prev) \
            + diag[..., None] * v_k.astype(jnp.float32)
        return S_new, y

    S0 = jnp.zeros((B * H * K * V, 1), jnp.float32)
    S_last, ys = jax.lax.scan(jax.checkpoint(chunk_step), S0, (rc, kc, vc, wc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T + pad, H, V)[:, :T]
    return y.astype(r.dtype), S_last.reshape(B, H, K, V)


def wkv6_chunked(r, k, v, logw, u, *, chunk: int = 64,
                 work_dtype=jnp.float32, impl: str | None = None):
    """Chunked WKV6: y_t = r_t·S_{t−1} + (r_t⊙u⊙k_t)·v_t,
    S_t = diag(exp(logw_t))·S_{t−1} + k_tᵀv_t.

    r, k, logw: (B, T, H, K); v: (B, T, H, V); u: (H, K). logw ≤ 0.
    Intra-chunk terms use the factorized r̃/k̃ matmul form (log-domain
    cumulative decays) — the GLA-style chunk algebra, same associative
    operator as the SSAM linear-recurrence plan.
    Returns (y, S_last) with S_last (B, H, K, V).

    ``impl``: ``None`` resolves per backend
    (:func:`repro.kernels.ops.default_scan_impl` — the streamed engine
    on TPU); 'chunked' the matmul schedule; 'engine' the chunk-streamed
    engine recurrence (O(chunk) live state); 'engine_unchunked' the
    monolithic O(T) engine validation lowering (fp32-tolerance equal).
    """
    if impl is None:
        from repro.kernels import ops as kops
        impl = kops.default_scan_impl()
    if impl == "engine":
        return _wkv6_engine_stream(r, k, v, logw, u, chunk=chunk)
    if impl == "engine_unchunked":
        return _wkv6_engine(r, k, v, logw, u)
    if impl != "chunked":
        raise ValueError(impl)
    B, T, H, K = r.shape
    V = v.shape[-1]
    L = min(chunk, T)
    pad = (-T) % L
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (T + pad) // L

    def to_chunks(t):
        return jnp.moveaxis(t.reshape(B, nc, L, H, -1), 1, 0)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, logw))
    mask_strict = jnp.tril(jnp.ones((L, L), jnp.float32), -1)

    def chunk_step(S, args):
        r_k, k_k, v_k, w_k = args
        # cumulative decays stay f32; the big intra-chunk operands may run
        # in bf16 (work_dtype, §Perf lever) with f32 MXU accumulation.
        k_f = k_k.astype(jnp.float32)
        w_k = w_k.astype(jnp.float32)
        cum_incl = jnp.cumsum(w_k, axis=1)             # Σ_{i≤t} logw
        cum_excl = cum_incl - w_k
        r_t = (r_k.astype(jnp.float32) * jnp.exp(cum_excl)).astype(work_dtype)
        k_t = (k_f * jnp.exp(-cum_incl)).astype(work_dtype)
        v_w = v_k.astype(work_dtype)
        A = jnp.einsum("blhk,bmhk->bhlm", r_t, k_t,
                       preferred_element_type=jnp.float32)
        A = (A * mask_strict[None, None]).astype(work_dtype)
        diag = jnp.einsum("blhk,hk,blhk->blh", r_k.astype(jnp.float32),
                          u.astype(jnp.float32), k_f)
        y_intra = jnp.einsum("bhlm,bmhv->blhv", A, v_w,
                             preferred_element_type=jnp.float32) \
            + diag[..., None] * v_k.astype(jnp.float32)
        y_inter = jnp.einsum("blhk,bhkv->blhv", r_t, S.astype(work_dtype),
                             preferred_element_type=jnp.float32)
        d_all = jnp.exp(cum_incl[:, -1])               # (B,H,K)
        k_tail = (k_f * jnp.exp(cum_incl[:, -1][:, None] - cum_incl)).astype(work_dtype)
        S_new = d_all[..., None] * S + jnp.einsum(
            "blhk,blhv->bhkv", k_tail, v_w, preferred_element_type=jnp.float32)
        return S_new, y_inter + y_intra

    S0 = jnp.zeros((B, H, K, V), jnp.float32)
    S_last, ys = jax.lax.scan(chunk_step, S0, (rc, kc, vc, wc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T + pad, H, V)[:, :T]
    return y.astype(r.dtype), S_last


def wkv6_sequential(r, k, v, logw, u):
    """Sequential oracle for wkv6 (lax.scan over time) — test reference."""
    B, T, H, K = r.shape

    def step(S, args):
        r_t, k_t, v_t, w_t = args
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S) + (
            (r_t * u[None] * k_t).sum(-1)[..., None] * v_t)
        S = jnp.exp(w_t)[..., None] * S + k_t[..., None] * v_t[..., None, :]
        return S, y

    S0 = jnp.zeros((B, H, K, v.shape[-1]), jnp.float32)
    tfirst = lambda t: jnp.moveaxis(t.astype(jnp.float32), 1, 0)
    S_last, ys = jax.lax.scan(step, S0, (tfirst(r), tfirst(k), tfirst(v), tfirst(logw)))
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), S_last


def _token_shift(x, shifted=None):
    """Previous-token stream: the width-2 SSAM conv1d special case."""
    if shifted is not None:
        return shifted
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def rwkv6_timemix_apply(p, x, *, n_heads: int, head_k: int, head_v: int,
                        chunk: int = 64, state=None,
                        work_dtype=jnp.float32, wkv_impl: str | None = None):
    """RWKV6 time-mix. state (decode): {"S": (B,H,K,V), "prev": (B,1,d)}.

    ``wkv_impl`` selects the WKV execution (None | 'chunked' | 'engine' |
    'engine_unchunked'), see :func:`wkv6_chunked`.
    """
    B, T, d = x.shape
    H, K, V = n_heads, head_k, head_v
    prev = _token_shift(x) if state is None else jnp.concatenate(
        [state["prev"], x[:, :-1]], axis=1)
    dx = prev - x
    # data-dependent token shift (ddlerp, the "Finch" contribution).
    # (§Perf note: a per-stream restructure of this block measured +54%
    # memory — the batched (B,T,5,d) einsum is the better schedule; kept.)
    xxx = x + dx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(xxx @ p["shift_w1"].astype(x.dtype))
    lora = lora.reshape(B, T, 5, -1)
    mix = jnp.einsum("btfl,fld->btfd", lora, p["shift_w2"].astype(x.dtype))
    mix = mix + p["mu"].astype(x.dtype)[None, None]
    xw, xk, xv, xr, xg = [x + dx * mix[:, :, i] for i in range(5)]

    # (§Perf note: batching these four projections into one stacked einsum
    # measured +6% memory — reverted; see EXPERIMENTS.md §Perf cell C.)
    r = jnp.einsum("btd,dhk->bthk", xr, p["wr"].astype(x.dtype))
    kk = jnp.einsum("btd,dhk->bthk", xk, p["wk"].astype(x.dtype))
    vv = jnp.einsum("btd,dhk->bthk", xv, p["wv"].astype(x.dtype))
    g = jnp.einsum("btd,dhk->bthk", xg, p["wg"].astype(x.dtype))
    dec = jnp.einsum("btd,dl->btl", xw, p["decay_w1"].astype(x.dtype))
    w = p["w0"].astype(jnp.float32) + jnp.einsum(
        "btl,lhk->bthk", jnp.tanh(dec).astype(jnp.float32),
        p["decay_w2"].astype(jnp.float32))
    logw = -jnp.exp(w)                                  # log decay ≤ 0

    if state is None:
        y, S_last = wkv6_chunked(r, kk, vv, logw.astype(r.dtype), p["u"],
                                 chunk=chunk, work_dtype=work_dtype,
                                 impl=wkv_impl)
        new_state = {"S": S_last, "prev": x[:, -1:]}
    else:
        S = state["S"]
        r1 = r[:, 0].astype(jnp.float32)
        k1 = kk[:, 0].astype(jnp.float32)
        v1 = vv[:, 0].astype(jnp.float32)
        y = jnp.einsum("bhk,bhkv->bhv", r1, S) + (
            (r1 * p["u"][None].astype(jnp.float32) * k1).sum(-1)[..., None] * v1)
        S = jnp.exp(logw[:, 0])[..., None] * S + k1[..., None] * v1[..., None, :]
        y = y[:, None].astype(x.dtype)
        new_state = {"S": S, "prev": x[:, -1:]}

    # per-head groupnorm, gate, project out
    y32 = y.astype(jnp.float32)
    mu = y32.mean(-1, keepdims=True)
    var = y32.var(-1, keepdims=True)
    y = ((y32 - mu) * jax.lax.rsqrt(var + 64e-5) * p["ln_x"].astype(jnp.float32))
    y = y.astype(x.dtype) * jax.nn.silu(g)
    out = jnp.einsum("bthv,hvd->btd", y, p["wo"].astype(x.dtype))
    return out, new_state


def rwkv6_channelmix_specs(d: int, ff: int) -> dict:
    return {
        "mu_k": ParamSpec((d,), ("embed",), init="small"),
        "mu_r": ParamSpec((d,), ("embed",), init="small"),
        "wk": ParamSpec((d, ff), ("embed", "ff")),
        "wv": ParamSpec((ff, d), ("ff", "embed")),
        "wr": ParamSpec((d, d), ("embed", None)),
    }


def rwkv6_channelmix_apply(p, x, *, state=None):
    prev = _token_shift(x) if state is None else jnp.concatenate(
        [state["prev"], x[:, :-1]], axis=1)
    dx = prev - x
    xk = x + dx * p["mu_k"].astype(x.dtype)
    xr = x + dx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    out = jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype)) * (k @ p["wv"].astype(x.dtype))
    return out, {"prev": x[:, -1:]}
