"""Attention: GQA (chunked/flash softmax, sliding-window masks, KV cache)
and DeepSeek-style MLA (latent KV compression with absorbed decode).

Attention is MXU-bound, so it stays in XLA (DESIGN.md §5); the chunked
softmax bounds live memory to O(block_q·block_kv) per step so that 32k+
prefill compiles within HBM at 512 devices.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .layers import apply_rope
from .spec import ParamSpec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_specs(d: int, n_heads: int, kv_heads: int, head_dim: int,
              *, bias: bool = False, qk_norm: bool = False) -> dict:
    s = {
        "wq": ParamSpec((d, n_heads, head_dim), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv_heads, head_dim), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv_heads, head_dim), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((n_heads, head_dim, d), ("heads", "head_dim", "embed")),
    }
    if bias:
        s["bq"] = ParamSpec((n_heads, head_dim), ("heads", "head_dim"), init="zeros")
        s["bk"] = ParamSpec((kv_heads, head_dim), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = ParamSpec((kv_heads, head_dim), ("kv_heads", "head_dim"), init="zeros")
        s["bo"] = ParamSpec((d,), ("embed",), init="zeros")
    if qk_norm:
        s["q_norm"] = ParamSpec((head_dim,), ("head_dim",), init="ones")
        s["k_norm"] = ParamSpec((head_dim,), ("head_dim",), init="ones")
    return s


def _rms(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _window_mask(q_pos, kv_pos, window, is_global):
    """causal ∧ (global ∨ within sliding window). Traced per-layer scalars OK.

    q_pos: (Sq,) or (B, Sq) — the batched form serves per-slot decode
    indices (continuous batching). Returns (…, Sq, Skv)."""
    causal = kv_pos <= q_pos[..., :, None]
    dist = q_pos[..., :, None] - kv_pos
    win = jnp.where(is_global, jnp.iinfo(jnp.int32).max, window)
    return causal & (dist < win)


def mha_chunked(q, k, v, q_pos, kv_pos, *, window, is_global,
                block_q: int = 512, block_kv: int = 1024, scale=None):
    """Masked online-softmax attention, O(block_q·block_kv) live logits.

    q: (B, Sq, H, hd); k/v: (B, Skv, KV, hd); GQA via head grouping.
    window/is_global may be traced scalars (scan-over-layers friendly).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    dv = v.shape[-1]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    nq, nkv = -(-Sq // bq), -(-Skv // bkv)
    # pad to whole blocks
    qp = jnp.pad(q, ((0, 0), (0, nq * bq - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nkv * bkv - Skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nkv * bkv - Skv), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, (0, nq * bq - Sq), constant_values=-1)
    kpos = jnp.pad(kv_pos, (0, nkv * bkv - Skv), constant_values=jnp.iinfo(jnp.int32).max)

    qb = qp.reshape(B, nq, bq, KV, G, hd)
    kb = kp.reshape(B, nkv, bkv, KV, hd)
    vb = vp.reshape(B, nkv, bkv, KV, dv)
    qposb = qpos.reshape(nq, bq)
    kposb = kpos.reshape(nkv, bkv)

    def q_block(carry, qi):
        q_i, qpos_i = qi  # (B, bq, KV, G, hd), (bq,)

        @jax.checkpoint
        def kv_block(state, kj):
            m, l, acc = state
            k_j, v_j, kpos_j = kj
            logits = jnp.einsum("bqkgh,btkh->bkgqt", q_i, k_j,
                                preferred_element_type=jnp.float32) * scale
            mask = _window_mask(qpos_i, kpos_j, window, is_global)
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, bq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, bq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), kposb),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry, out  # (B, KV, G, bq, hd)

    _, outs = jax.lax.scan(
        q_block, None, (jnp.moveaxis(qb, 1, 0), qposb)
    )  # (nq, B, KV, G, bq, hd)
    out = jnp.moveaxis(outs, 0, 1)                      # (B, nq, KV, G, bq, hd)
    out = jnp.moveaxis(out, -2, 2)                      # (B, nq, bq, KV, G, hd)
    out = out.reshape(B, nq * bq, H, dv)[:, :Sq]
    return out.astype(q.dtype)


def mha_direct(q, k, v, q_pos, kv_pos, *, window, is_global, scale=None):
    """Un-chunked attention (decode steps, short sequences)."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    dv = v.shape[-1]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, KV, G, hd)
    # keep K/V in their storage dtype end-to-end: QK and PV accumulate in
    # f32 on the MXU (preferred_element_type) without materializing an
    # f32 copy of the cache — the §Perf "dtype discipline" fix.
    logits = jnp.einsum("bqkgh,btkh->bkgqt", qg, k,
                        preferred_element_type=jnp.float32) * scale
    mask = _window_mask(q_pos, kv_pos, window, is_global)
    mask = mask[None, None, None] if mask.ndim == 2 else mask[:, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bkgqh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = jnp.moveaxis(out, -2, 1).reshape(B, Sq, H, dv)
    return out.astype(q.dtype)


def _cache_write(cache, new, index):
    """Write (B, 1, …) ``new`` at ``index`` (scalar, or (B,) per-slot)."""
    new = new.astype(cache.dtype)
    if jnp.ndim(index) == 0:
        return jax.lax.dynamic_update_slice(
            cache, new, (0,) + (index,) + (0,) * (cache.ndim - 2))
    def per_row(c, n, i):
        # inside vmap the batch dim is stripped: c is (S, …)
        return jax.lax.dynamic_update_slice(c, n, (i,) + (0,) * (c.ndim - 1))
    return jax.vmap(per_row)(cache, new, index)


def _decode_attend_readonly(q, k_new, v_new, cache, q_pos, window,
                            is_global, scale=None):
    """One-token attention over [read-only cache | current token].

    Cache positions strictly before q_pos are visible (the current token's
    slot in the cache is stale); the current token contributes a separate
    logit column. Numerically identical to write-then-attend."""
    B, Sq, H, hd = q.shape
    kc, vc = cache["k"], cache["v"]
    KV = kc.shape[2]
    dv = vc.shape[-1]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, KV, G, hd)
    kv_pos = jnp.arange(kc.shape[1])
    lc = jnp.einsum("bqkgh,btkh->bkgqt", qg, kc,
                    preferred_element_type=jnp.float32) * scale
    # strict causal: cache slot at q_pos is stale, exclude it
    causal = kv_pos < q_pos[..., :, None]
    dist = q_pos[..., :, None] - kv_pos
    win = jnp.where(is_global, jnp.iinfo(jnp.int32).max, window)
    mask = causal & (dist < win)
    mask = mask[None, None, None] if mask.ndim == 2 else mask[:, None, None]
    lc = jnp.where(mask, lc, NEG_INF)
    ls = jnp.einsum("bqkgh,bqkh->bkgq", qg, k_new.reshape(B, Sq, KV, hd),
                    preferred_element_type=jnp.float32)[..., None] * scale
    logits = jnp.concatenate([lc, ls], axis=-1)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bkgqh", p[..., :-1].astype(vc.dtype), vc,
                     preferred_element_type=jnp.float32)
    out = out + p[..., -1:].astype(jnp.float32) * v_new.reshape(
        B, Sq, KV, dv)[:, :, :, None].transpose(0, 2, 3, 1, 4)
    out = jnp.moveaxis(out, -2, 1).reshape(B, Sq, H, dv)
    return out.astype(q.dtype)


@dataclasses.dataclass
class AttnConfig:
    d_model: int
    n_heads: int
    kv_heads: int
    head_dim: int
    rope_base: float = 10000.0
    rot_dim: int | None = None          # partial rotary (stablelm/chatglm)
    bias: bool = False
    qk_norm: bool = False
    window: int = 0                     # 0 = always global
    scale: float | None = None
    block_q: int = 512
    block_kv: int = 1024
    constrain_cache: bool = False       # re-pin decode cache sharding (§Perf)


def gqa_apply(p, x, cfg: AttnConfig, *, positions, is_global=True,
              rope_base=None, cache=None, cache_index=None,
              write_through=True):
    """GQA attention over x (B, S, d).

    cache: optional dict {"k","v"} of (B, S_max, KV, hd) for decode; the
    new k/v are written at ``cache_index`` and attention runs over the
    whole cache (positions beyond the write point are masked by q_pos).
    Returns (out, new_cache).
    """
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.kv_heads, cfg.head_dim
    rot = cfg.rot_dim if cfg.rot_dim is not None else hd
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q, k, v = q + p["bq"].astype(x.dtype), k + p["bk"].astype(x.dtype), v + p["bv"].astype(x.dtype)
    if "q_norm" in p:
        q = _rms(q, p["q_norm"])
        k = _rms(k, p["k_norm"])
    from .layers import rope_table

    base = rope_base if rope_base is not None else cfg.rope_base
    cos, sin = rope_table(positions, rot, base)
    q = apply_rope(q, cos, sin, rot)
    k = apply_rope(k, cos, sin, rot)

    window = cfg.window if cfg.window > 0 else jnp.iinfo(jnp.int32).max
    if cache is None:
        kv_pos = positions
        if S > 1024:
            out = mha_chunked(q, k, v, positions, kv_pos, window=window,
                              is_global=is_global, block_q=cfg.block_q,
                              block_kv=cfg.block_kv, scale=cfg.scale)
        else:
            out = mha_direct(q, k, v, positions, kv_pos, window=window,
                             is_global=is_global, scale=cfg.scale)
        new_cache = None
    elif not write_through:
        # §Perf "write-outside-scan" decode: the cache is read-only here;
        # the new token's k/v are returned to the caller, which performs
        # ONE stacked in-place write after the layer scan — the per-layer
        # full-cache ys copy disappears (EXPERIMENTS.md §Perf cell A).
        if cfg.constrain_cache:
            from repro.distributed.sharding import constrain
            axes = ("batch", None, "kv_heads", "head_dim")
            k = constrain(k, axes)
            v = constrain(v, axes)
        out = _decode_attend_readonly(q, k, v, cache, positions, window,
                                      is_global, cfg.scale)
        new_cache = {"k": k.astype(cache["k"].dtype),
                     "v": v.astype(cache["v"].dtype)}
    else:
        # decode: write k/v at cache_index (scalar lockstep or (B,) per-slot
        # continuous-batching), attend over the full cache
        kc = _cache_write(cache["k"], k, cache_index)
        vc = _cache_write(cache["v"], v, cache_index)
        if cfg.constrain_cache:
            from repro.distributed.sharding import constrain
            axes = ("batch", "cache_seq", "kv_heads", "head_dim")
            kc = constrain(kc, axes)
            vc = constrain(vc, axes)
        kv_pos = jnp.arange(kc.shape[1])
        out = mha_direct(q, kc, vc, positions, kv_pos, window=window,
                         is_global=is_global, scale=cfg.scale)
        new_cache = {"k": kc, "v": vc}
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if "bo" in p:
        y = y + p["bo"].astype(x.dtype)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent KV compression, decoupled RoPE, absorbed decode
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128
    rope_base: float = 10000.0
    block_q: int = 512
    block_kv: int = 1024
    constrain_cache: bool = False


def mla_specs(cfg: MLAConfig) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    return {
        "wq_a": ParamSpec((d, cfg.q_lora), ("embed", "lora")),
        "q_norm": ParamSpec((cfg.q_lora,), ("lora",), init="ones"),
        "wq_b": ParamSpec((cfg.q_lora, H, cfg.qk_nope + cfg.qk_rope),
                          ("lora", "heads", "head_dim")),
        "wkv_a": ParamSpec((d, cfg.kv_lora + cfg.qk_rope), ("embed", "lora")),
        "kv_norm": ParamSpec((cfg.kv_lora,), ("lora",), init="ones"),
        "wk_b": ParamSpec((cfg.kv_lora, H, cfg.qk_nope), ("lora", "heads", "head_dim")),
        "wv_b": ParamSpec((cfg.kv_lora, H, cfg.v_head), ("lora", "heads", "head_dim")),
        "wo": ParamSpec((H, cfg.v_head, d), ("heads", "head_dim", "embed")),
    }


def mla_apply(p, x, cfg: MLAConfig, *, positions, cache=None,
              cache_index=None, write_through=True):
    """MLA attention. Train/prefill: materialize per-head K/V (parallel path).
    Decode: cache only the 512-d latent + 64-d rope key; score in latent
    space with the absorbed-matmul trick (DESIGN.md §4)."""
    from .layers import rope_table

    B, S, _ = x.shape
    H = cfg.n_heads
    scale = 1.0 / math.sqrt(cfg.qk_nope + cfg.qk_rope)

    q_lat = x @ p["wq_a"].astype(x.dtype)
    q_lat = _rms(q_lat, p["q_norm"])
    q = jnp.einsum("bsl,lhk->bshk", q_lat, p["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., : cfg.qk_nope], q[..., cfg.qk_nope :]

    kv_a = x @ p["wkv_a"].astype(x.dtype)
    c_kv, k_rope = kv_a[..., : cfg.kv_lora], kv_a[..., cfg.kv_lora :]
    c_kv = _rms(c_kv, p["kv_norm"])

    cos, sin = rope_table(positions, cfg.qk_rope, cfg.rope_base)
    q_rope = apply_rope(q_rope, cos, sin, cfg.qk_rope)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin, cfg.qk_rope)[:, :, 0]

    if cache is None:
        k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["wk_b"].astype(x.dtype))
        v = jnp.einsum("bsl,lhk->bshk", c_kv, p["wv_b"].astype(x.dtype))
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope[:, :, None, :], (B, S, H, cfg.qk_rope))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        if S > 1024:
            out = mha_chunked(qf, k, v, positions, positions,
                              window=jnp.iinfo(jnp.int32).max, is_global=True,
                              block_q=cfg.block_q, block_kv=cfg.block_kv,
                              scale=scale)
        else:
            out = mha_direct(qf, k, v, positions, positions,
                             window=jnp.iinfo(jnp.int32).max, is_global=True,
                             scale=scale)
        new_cache = None
    elif not write_through:
        # --- absorbed decode, read-only cache (write-outside-scan) ---
        ckv_c, krope_c = cache["c_kv"], cache["k_rope"]
        q_abs = jnp.einsum("bshk,lhk->bshl", q_nope, p["wk_b"].astype(x.dtype))
        lc = (jnp.einsum("bshl,btl->bhst", q_abs, ckv_c,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshk,btk->bhst", q_rope, krope_c,
                           preferred_element_type=jnp.float32)) * scale
        kv_pos = jnp.arange(ckv_c.shape[1])
        mask = kv_pos < positions[..., :, None]        # strict: stale slot
        mask = mask[None, None] if mask.ndim == 2 else mask[:, None]
        lc = jnp.where(mask, lc, NEG_INF)
        ls = (jnp.einsum("bshl,bsl->bhs", q_abs, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshk,bsk->bhs", q_rope, k_rope,
                           preferred_element_type=jnp.float32))[..., None] * scale
        logits = jnp.concatenate([lc, ls], axis=-1)     # (B,H,S,T+1)
        pattn = jax.nn.softmax(logits, axis=-1)
        lat_out = jnp.einsum("bhst,btl->bshl", pattn[..., :-1].astype(ckv_c.dtype),
                             ckv_c, preferred_element_type=jnp.float32)
        lat_out = lat_out + pattn[..., -1].swapaxes(1, 2)[..., None] * c_kv[:, :, None].astype(jnp.float32)
        out = jnp.einsum("bshl,lhk->bshk", lat_out.astype(x.dtype),
                         p["wv_b"].astype(x.dtype))
        new_cache = {"c_kv": c_kv.astype(ckv_c.dtype),
                     "k_rope": k_rope.astype(krope_c.dtype)}
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
        return y, new_cache
    else:
        # --- absorbed decode ---
        ckv_c = _cache_write(cache["c_kv"], c_kv, cache_index)
        krope_c = _cache_write(cache["k_rope"], k_rope, cache_index)
        if cfg.constrain_cache:
            from repro.distributed.sharding import constrain
            ckv_c = constrain(ckv_c, ("batch", "cache_seq", "lora"))
            krope_c = constrain(krope_c, ("batch", "cache_seq", "lora"))
        # absorb W_uk into q: q_abs (B,S,H,kv_lora)
        q_abs = jnp.einsum("bshk,lhk->bshl", q_nope, p["wk_b"].astype(x.dtype))
        logits = (jnp.einsum("bshl,btl->bhst", q_abs, ckv_c,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshk,btk->bhst", q_rope, krope_c,
                               preferred_element_type=jnp.float32))
        logits = logits * scale
        kv_pos = jnp.arange(ckv_c.shape[1])
        mask = kv_pos <= positions[..., :, None]
        mask = mask[None, None] if mask.ndim == 2 else mask[:, None]
        logits = jnp.where(mask, logits, NEG_INF)
        pattn = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        lat_out = jnp.einsum("bhst,btl->bshl", pattn.astype(ckv_c.dtype),
                             ckv_c, preferred_element_type=jnp.float32)
        out = jnp.einsum("bshl,lhk->bshk", lat_out.astype(x.dtype),
                         p["wv_b"].astype(x.dtype))
        new_cache = {"c_kv": ckv_c, "k_rope": krope_c}
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, new_cache
