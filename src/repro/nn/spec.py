"""Parameter-spec system: one source of truth for shapes, init, and sharding.

A model is described by a *spec tree* — a nested dict whose leaves are
:class:`ParamSpec` (shape + dtype + initializer + **logical axis names**).
From the one spec tree we derive:

* ``init_params``     — materialized parameters (PRNG-keyed),
* ``abstract_params`` — ``ShapeDtypeStruct`` stand-ins (dry-run: no alloc),
* ``axes_tree``       — logical axes per leaf → ``PartitionSpec`` via
  :mod:`repro.distributed.sharding` rules,
* ``param_count``     — exact parameter counts (MODEL_FLOPS, logging).

Keeping these derived from a single tree is what makes the 512-device
dry-run cheap: nothing is ever allocated, yet shardings stay consistent
with what a real ``init`` would produce.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Shape/dtype/init/logical-axes of one parameter tensor."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis name per dim
    init: str = "normal"                  # normal | zeros | ones | embed | small
    scale: float | None = None            # stddev override for 'normal'
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: tuple[int, ...]) -> int:
    return shape[0] if len(shape) >= 2 else max(shape[-1], 1)


def _init_leaf(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    std = spec.scale
    if std is None:
        if spec.init == "embed":
            std = 0.02  # conventional LM embedding init (tied readout scale)
        elif spec.init == "small":
            std = 0.02
        else:  # fan-in scaled
            std = 1.0 / math.sqrt(_fan_in(spec.shape))
    return (std * jax.random.normal(key, spec.shape)).astype(spec.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(spec_tree, key: jax.Array):
    """Materialize a spec tree into parameter arrays (deterministic in key)."""
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(spec_tree):
    """ShapeDtypeStruct tree — dry-run stand-in, no device allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=is_spec
    )


def axes_tree(spec_tree):
    """Logical-axes tree mirroring the params tree."""
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def param_count(spec_tree) -> int:
    leaves = jax.tree_util.tree_leaves(spec_tree, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def cast_tree(spec_tree, dtype):
    """Spec tree with every floating leaf recast (e.g. bf16 training)."""
    def cast(s: ParamSpec) -> ParamSpec:
        if jnp.issubdtype(s.dtype, jnp.floating):
            return dataclasses.replace(s, dtype=dtype)
        return s
    return jax.tree.map(cast, spec_tree, is_leaf=is_spec)


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Stack a per-layer spec tree n× along a new leading 'layers' axis.

    Used by the scan-over-layers models: params for all L layers live in
    one (L, ...) tensor per leaf, which keeps the HLO O(1) in depth.
    """
    def stack(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(
            s, shape=(n,) + s.shape, axes=(axis_name,) + s.axes
        )
    return jax.tree.map(stack, spec_tree, is_leaf=is_spec)
