"""Mixture-of-Experts: top-k routing, sort-based capacity dispatch, EP-ready.

Dispatch is gather/scatter ("sort tokens by expert, keep first C per
expert"), not one-hot einsum: the einsum dispatch of the classic
implementation costs O(S²·d·cf) FLOPs — quadratic in sequence — while
this form stays O(S·k·cf·d). Experts carry the "experts" logical axis so
the rule table places them on the model mesh axis (expert parallelism);
under SPMD the gather induces the expected all-gather/all-to-all.

Supports DBRX-style (16e top-4, normalized gates) and DeepSeek-V2-style
(160 routed top-6 + shared experts, gate scaling) via config.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from .layers import gated_mlp_apply, gated_mlp_specs
from .spec import ParamSpec


def moe_specs(d: int, ff: int, n_experts: int, *, n_shared: int = 0,
              shared_ff: int | None = None) -> dict:
    s = {
        "router": ParamSpec((d, n_experts), ("embed", "experts"), init="small"),
        "w_gate": ParamSpec((n_experts, d, ff), ("experts", "embed", "ff")),
        "w_up": ParamSpec((n_experts, d, ff), ("experts", "embed", "ff")),
        "w_down": ParamSpec((n_experts, ff, d), ("experts", "ff", "embed")),
    }
    if n_shared:
        s["shared"] = gated_mlp_specs(d, (shared_ff or ff) * n_shared)
    return s


def moe_apply(p, x, *, top_k: int, capacity_factor: float = 1.25,
              act: str = "silu", norm_gates: bool = True,
              gate_scale: float = 1.0):
    """Returns (out, aux_loss). x: (B, S, D)."""
    B, S, D = x.shape
    E = p["router"].shape[1]
    T = B * S
    xf = x.reshape(T, D)

    logits = (xf @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                     # (T, E)
    gates, eidx = jax.lax.top_k(probs, top_k)                   # (T, k)
    if norm_gates:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    gates = gates * gate_scale

    # load-balance aux loss (Switch-style): E · Σ_e f_e · P_e
    me = probs.mean(0)
    one_hot = jax.nn.one_hot(eidx, E, dtype=jnp.float32)        # (T, k, E)
    fe = one_hot.sum((0, 1)) / (T * top_k)
    aux_loss = E * jnp.sum(fe * me)

    # ---- sort-based dispatch with capacity ----
    C = max(int(math.ceil(T * top_k * capacity_factor / E)), 1)
    flat_e = eidx.reshape(-1)                                    # (T·k,)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e)                                  # stable
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)                      # (E,)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * top_k) - starts[sorted_e]               # rank in expert
    keep = pos < C
    slot = jnp.where(keep, sorted_e * C + pos, E * C)            # E·C = trash slot
    token_idx = order // top_k                                   # token of sorted slot

    token_of_slot = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(
        token_idx.astype(jnp.int32))[: E * C]
    gate_of_slot = jnp.zeros((E * C + 1,), gates.dtype).at[slot].set(
        flat_g[order])[: E * C]
    valid = jnp.zeros((E * C + 1,), jnp.bool_).at[slot].set(keep)[: E * C]

    gathered = xf[token_of_slot] * valid[:, None].astype(x.dtype)
    gathered = constrain(gathered.reshape(E, C, D), ("experts", None, "embed"))

    g = jnp.einsum("ecd,edf->ecf", gathered, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", gathered, p["w_up"].astype(x.dtype))
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    y = jnp.einsum("ecf,efd->ecd", g * u, p["w_down"].astype(x.dtype))
    y = constrain(y, ("experts", None, "embed")).reshape(E * C, D)

    w = (gate_of_slot * valid).astype(x.dtype)[:, None]
    out = jnp.zeros((T, D), x.dtype).at[token_of_slot].add(y * w)

    if "shared" in p:
        out = out + gated_mlp_apply(p["shared"], xf, act=act)
    return out.reshape(B, S, D), aux_loss
