import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimb driver: re-lower one cell with a tagged variant
(config overrides and/or sharding-rule overrides) and print the roofline
delta vs the baseline artifact.

  PYTHONPATH=src python -m repro.launch.perf --arch rwkv6-1.6b \
      --shape train_4k --tag chunk128 --set wkv_chunk=128
  PYTHONPATH=src python -m repro.launch.perf --arch stablelm-12b \
      --shape decode_32k --tag seqshard --rule cache_seq=model
"""
import argparse
import json


def parse_value(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("true", "false"):
        return v == "true"
    return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig override field=value")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding rule logical=mesh_axis[,axis2] ('' to unshard)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_value(v)
    rules = {}
    for kv in args.rule:
        k, v = kv.split("=", 1)
        rules[k] = tuple(x for x in v.split(",") if x)

    from repro.config import normalize_arch
    from repro.launch.dryrun import run_cell

    args.arch = normalize_arch(args.arch)
    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   out_dir=args.out, rules=rules or None, tag=args.tag,
                   cfg_overrides=overrides or None)

    # compare against baseline artifact
    import sys
    sys.path.insert(0, "benchmarks")
    import roofline as rl

    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    base_path = os.path.join(
        args.out, f"{rec['arch']}__{args.shape}__{mesh_name}__baseline.json")
    if os.path.exists(base_path) and rec["status"] == "ok":
        base = json.load(open(base_path))
        rb, rv = rl.roofline_of(base), rl.roofline_of(rec)
        print(f"\n{'':14s} {'baseline':>12s} {args.tag:>12s} {'delta':>8s}")
        for term in ("compute_s", "memory_s", "collective_s"):
            b, v = getattr(rb, term), getattr(rv, term)
            d = (v - b) / b * 100 if b else float("inf")
            print(f"{term:14s} {b:12.6f} {v:12.6f} {d:+7.1f}%")
        print(f"{'bound':14s} {rb.bound_s:12.6f} {rv.bound_s:12.6f} "
              f"{(rv.bound_s - rb.bound_s) / rb.bound_s * 100:+7.1f}%  "
              f"(dominant: {rb.dominant} → {rv.dominant})")
        print(f"{'roofline frac':14s} {rb.roofline_fraction:12.4f} "
              f"{rv.roofline_fraction:12.4f}")


if __name__ == "__main__":
    main()
