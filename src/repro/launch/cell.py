"""Cell programs: (architecture × input-shape × mesh) → jit-able step fn
with full sharding specs and abstract (ShapeDtypeStruct) arguments.

A *cell* lowers one of:
* ``train``   — loss → grads → AdamW update (donated params/opt state),
* ``prefill`` — full forward, last-position logits,
* ``decode``  — one-token ``serve_step`` against a seq_len KV cache/state.

Nothing here allocates: parameters, optimizer states and caches are
ShapeDtypeStructs derived from the ParamSpec trees, and shardings come
from the logical-axis rules (per-cell overridable for §Perf hillclimbs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ShapeConfig, active_param_count
from repro.distributed.sharding import (mesh_context, pspec_for_axes,
                                        shardings_for_specs)
from repro.models import build_model
from repro.models.base import ArchConfig
from repro.nn.spec import abstract_params
from repro.optim import adamw_state_specs, adamw_update, cosine_schedule


@dataclasses.dataclass
class CellProgram:
    name: str
    fn: Callable
    abstract_args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple
    model_flops: float          # MODEL_FLOPS for this step (6·N·D / 2·N·D)
    cfg: ArchConfig
    shape: ShapeConfig

    def lower(self, mesh: Mesh, rules=None):
        with mesh, mesh_context(mesh, rules):
            jitted = jax.jit(
                self.fn,
                in_shardings=self.in_shardings,
                out_shardings=self.out_shardings,
                donate_argnums=self.donate_argnums,
            )
            return jitted.lower(*self.abstract_args)


def _input_shardings(inp, axes, mesh, rules):
    return {
        k: NamedSharding(mesh, pspec_for_axes(axes[k], inp[k].shape, mesh, rules))
        for k in inp
    }


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               *, rules=None, dtype: str = "bfloat16",
               lr: float = 3e-4, lr_warmup: int = 2000,
               lr_total: int = 200_000) -> CellProgram:
    cfg = dataclasses.replace(cfg, dtype=dtype)
    model = build_model(cfg)
    pspecs = model.specs()
    params_abs = abstract_params(pspecs)
    params_sh = shardings_for_specs(pspecs, mesh, rules)
    _, n_active = active_param_count(cfg)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        ospecs = adamw_state_specs(pspecs)
        opt_abs = abstract_params(ospecs)
        opt_sh = shardings_for_specs(ospecs, mesh, rules)
        inp, in_axes = model.train_inputs(shape.batch, shape.seq)
        inp_sh = _input_shardings(inp, in_axes, mesh, rules)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
            lr_t = cosine_schedule(opt_state["step"], base_lr=lr,
                                   warmup=lr_warmup, total=lr_total)
            params, opt_state, gnorm = adamw_update(params, grads, opt_state,
                                                    lr=lr_t)
            return params, opt_state, {"loss": loss, "grad_norm": gnorm}

        metrics_sh = {"loss": repl, "grad_norm": repl}
        flops = 6.0 * n_active * shape.batch * shape.seq
        return CellProgram(
            name=f"{cfg.name}:{shape.name}", fn=train_step,
            abstract_args=(params_abs, opt_abs, inp),
            in_shardings=(params_sh, opt_sh, inp_sh),
            out_shardings=(params_sh, opt_sh, metrics_sh),
            donate_argnums=(0, 1), model_flops=flops, cfg=cfg, shape=shape)

    if shape.kind == "prefill":
        inp, in_axes = model.train_inputs(shape.batch, shape.seq)
        inp.pop("labels")
        in_axes.pop("labels")
        inp_sh = _input_shardings(inp, in_axes, mesh, rules)
        logits_sh = NamedSharding(
            mesh, pspec_for_axes(("batch", "vocab"),
                                 (shape.batch, cfg.vocab), mesh, rules))

        def prefill_step(params, batch):
            return model.prefill_logits(params, batch)

        flops = 2.0 * n_active * shape.batch * shape.seq
        return CellProgram(
            name=f"{cfg.name}:{shape.name}", fn=prefill_step,
            abstract_args=(params_abs, inp),
            in_shardings=(params_sh, inp_sh),
            out_shardings=logits_sh, donate_argnums=(),
            model_flops=flops, cfg=cfg, shape=shape)

    # decode: one new token against a seq_len-deep cache/state
    sspecs = model.decode_state_specs(shape.batch, shape.seq)
    state_abs = abstract_params(sspecs)
    state_sh = shardings_for_specs(sspecs, mesh, rules)
    tokens = jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32)
    tokens_sh = NamedSharding(
        mesh, pspec_for_axes(("batch", None), (shape.batch, 1), mesh, rules))
    index = jax.ShapeDtypeStruct((), jnp.int32)
    logits_sh = NamedSharding(
        mesh, pspec_for_axes(("batch", "vocab"),
                             (shape.batch, cfg.vocab), mesh, rules))

    def serve_step(params, state, tokens, index):
        return model.serve_step(params, state, tokens, index)

    flops = 2.0 * n_active * shape.batch
    return CellProgram(
        name=f"{cfg.name}:{shape.name}", fn=serve_step,
        abstract_args=(params_abs, state_abs, tokens, index),
        in_shardings=(params_sh, state_sh, tokens_sh, repl),
        out_shardings=(logits_sh, state_sh), donate_argnums=(1,),
        model_flops=flops, cfg=cfg, shape=shape)
