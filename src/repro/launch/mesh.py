"""Production mesh construction.

A function (never a module-level constant) so importing this module never
touches jax device state. Single pod: 16×16 = 256 chips ("data","model").
Multi-pod: 2×16×16 = 512 chips ("pod","data","model") — the "pod" axis is
the slow inter-pod (DCN-ish) dimension; the sharding rules fold it into
the batch axis (DESIGN.md §4).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over however many (real or forced) devices exist —
    used by tests and the CPU examples."""
    n = jax.device_count()
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def make_domain_mesh(shape: tuple[int, ...]):
    """1-D/2-D mesh for sharded windowed-domain execution.

    Axis names follow the sharding rule tables ("rows" → ``data``,
    "cols" → ``model``), so ``halo_exchange.default_domain_spec``
    resolves without explicit in_specs. ``shape=(A,)`` shards rows only;
    ``shape=(A, B)`` shards rows over A devices and lanes over B.
    """
    if not 1 <= len(shape) <= 2:
        raise ValueError(f"domain meshes are 1-D or 2-D, got {shape}")
    names = ("data", "model")[: len(shape)]
    return jax.make_mesh(tuple(shape), names)
