import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
for the single-pod 16×16 and multi-pod 2×16×16 production meshes.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM and unsupported collectives all fail here.
Emits one JSON artifact per cell with memory_analysis, cost_analysis and
the collective-byte breakdown parsed from the optimized HLO — the inputs
to EXPERIMENTS.md §Dry-run/§Roofline (see benchmarks/roofline.py).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod-only --out artifacts/
"""
import argparse
import json
import time
import traceback


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str,
             rules=None, tag: str = "baseline", verbose: bool = True,
             cfg_overrides: dict | None = None) -> dict:
    """cfg_overrides: dataclasses.replace() fields on the ArchConfig —
    the §Perf hillclimb lever (block sizes, chunk sizes, remat, …)."""
    import dataclasses

    import jax
    from repro.config import SHAPES, cell_applicable, get_config
    from repro.core.hlo_cost import cost_of
    from repro.core.rooflines import collective_bytes_from_hlo
    from repro.launch.cell import build_cell
    from repro.launch.mesh import make_production_mesh

    shape = SHAPES[shape_name]
    runnable, reason = cell_applicable(arch, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "status": "skip", "reason": reason,
        "cfg_overrides": cfg_overrides or {}, "rules": 
            {k: list(v) if isinstance(v, (list, tuple)) else v
             for k, v in (rules or {}).items()},
    }
    if not runnable:
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            fname = f"{arch}__{shape_name}__{mesh_name}__{tag}.json"
            with open(os.path.join(out_dir, fname), "w") as f:
                json.dump(rec, f, indent=2)
        if verbose:
            print(f"[dryrun] {arch:18s} {shape_name:12s} {mesh_name:10s} "
                  f"{reason}", flush=True)
        return rec

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    cell = build_cell(cfg, shape, mesh, rules=rules)
    lowered = cell.lower(mesh, rules)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # cost_analysis() shape varies by jax version/backend: dict, [dict],
    # or None; some CPU builds omit the "flops" key entirely.
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    cost = dict(cost or {})
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    # Trip-count-aware roll-up (XLA's cost_analysis counts while bodies
    # once — see repro.core.hlo_cost): the roofline reads these fields.
    hc = cost_of(hlo)

    rec.update(
        status="ok",
        chips=chips,
        model_flops=cell.model_flops,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        cost={**{k: cost[k] for k in ("flops", "bytes accessed")
                 if k in cost},
              # backfill from the trip-count-aware HLO roll-up when the
              # XLA backend doesn't report a flops estimate
              **({} if "flops" in cost else {"flops": hc.flops})},
        memory={
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        collectives={
            "bytes_by_kind": coll.bytes_by_kind,
            "count_by_kind": coll.count_by_kind,
            "total_bytes": coll.total_bytes,
        },
        hlo_cost={
            "flops": hc.flops,
            "bytes": hc.bytes,
            "bytes_fused": hc.bytes_fused,
            "collective_bytes": hc.collective_bytes,
            "collective_by_kind": hc.collective_by_kind,
        },
    )
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_name}__{tag}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=2)
    if verbose:
        print(f"[dryrun] {arch:18s} {shape_name:12s} {mesh_name:10s} OK "
              f"lower={t_lower:6.1f}s compile={t_compile:6.1f}s "
              f"flops/dev={hc.flops:.3e} "
              f"coll={hc.collective_bytes:.3e}B", flush=True)
    return rec


def main():
    from repro.config import ARCHS, SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)

    failures = []
    for arch in archs:
        for shape in shapes:
            for multi_pod in meshes:
                try:
                    run_cell(arch, shape, multi_pod=multi_pod, out_dir=args.out,
                             tag=args.tag)
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append((arch, shape, multi_pod, repr(e)))
                    print(f"[dryrun] {arch} {shape} multi_pod={multi_pod} "
                          f"FAILED: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} cell(s) FAILED")
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
