"""Batched serving driver with continuous batching.

A fixed pool of B decode slots advances in lock-step through one jitted
``serve_step`` per token; each slot carries its own write index, so a
finished request's slot is immediately refilled from the queue while the
other slots keep decoding (continuous batching — no batch-wide drain).
Per-slot indices flow through the whole cache machinery
(:func:`repro.nn.attention._cache_write` vmaps the cache write).

Prefill: recurrent archs (RWKV6) expose ``model.prefill`` — the whole
prompt runs through the chunk-streamed scan plans in one call
(DESIGN.md §12) and only the resulting O(1) state lands in the slot;
KV-cache archs feed the prompt token-by-token through ``serve_step``.

Greedy sampling by default; temperature optional. This driver doubles as
the end-to-end serving example (examples/serve_decode.py wraps it).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --slots 4 --max-new 32 --requests 12
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.robust import faults as rfaults
from repro.robust import guard as rguard

# consecutive serve_step failures tolerated before the server sheds load
# (evicts the oldest active request) to break a poison-request livelock
MAX_STEP_RETRIES = 3


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (L,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_assign: float = 0.0       # slot-assignment wall time (latency metric)
    deadline_s: float | None = None   # wall-clock budget from slot assignment
    error: str | None = None    # why the request failed (None = clean finish)


class DecodeServer:
    """Continuous-batching decode server over a fixed slot pool."""

    def __init__(self, model, params, *, slots: int, cache_len: int,
                 temperature: float = 0.0, seed: int = 0):
        from repro.nn.spec import init_params
        self.model = model
        self.params = params
        self.B = slots
        self.cache_len = cache_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.state = init_params(model.decode_state_specs(slots, cache_len),
                                 jax.random.PRNGKey(0))
        self.index = np.zeros((slots,), np.int32)     # per-slot positions
        self.slot_req: list[Request | None] = [None] * slots
        self.prompt_left: list[np.ndarray] = [np.zeros((0,), np.int32)] * slots
        self.step_fn = jax.jit(model.serve_step)
        # recurrent archs expose whole-prompt prefill through the chunked
        # scan plans (DESIGN.md §12); KV-cache archs fall back to feeding
        # the prompt token-by-token through serve_step.
        self.prefill_fn = (jax.jit(model.prefill)
                           if hasattr(model, "prefill") else None)
        self.tokens = np.zeros((slots, 1), np.int32)
        self.active_mask = np.zeros((slots,), bool)
        self.steps = 0
        self.step_failures = 0

    def assign(self, req: Request, slot: int):
        req.t_assign = time.perf_counter()
        self.slot_req[slot] = req
        self.index[slot] = 0
        self.active_mask[slot] = True
        # zero this slot's state so a stale cache cannot leak across requests
        self.state = jax.tree.map(
            lambda s: s.at[:, slot].set(0) if s.ndim >= 2 else s, self.state)
        if self.prefill_fn is not None and len(req.prompt) > 1:
            # one batched scan over prompt[:-1] replaces L−1 serve_step
            # calls; the last prompt token then rides the normal decode
            # step, so the slot's state trajectory is identical to the
            # token-by-token path (greedy outputs match exactly).
            _, st = self.prefill_fn(
                self.params, jnp.asarray(req.prompt[None, :-1]))
            self.state = jax.tree.map(
                lambda s, n: (s.at[:, slot].set(n[:, 0].astype(s.dtype))
                              if s.ndim >= 2 else s),
                self.state, st)
            self.index[slot] = len(req.prompt) - 1
            self.tokens[slot, 0] = req.prompt[-1]
            self.prompt_left[slot] = np.zeros((0,), np.int32)
        else:
            self.tokens[slot, 0] = req.prompt[0]
            self.prompt_left[slot] = req.prompt[1:]

    def step(self):
        """One lock-step decode across all slots."""
        rfaults.check("serve.step")
        t0 = time.perf_counter()
        logits, self.state = self.step_fn(
            self.params, self.state, jnp.asarray(self.tokens),
            jnp.asarray(self.index))
        self.steps += 1
        # Histogram of dispatch wall-time per batched step (the first
        # sample includes the jit compile; p50 is the steady state).
        obs.metrics.observe("serve.step_us",
                            (time.perf_counter() - t0) * 1e6)
        if self.temperature > 0:
            self.key, sub = jax.random.split(self.key)
            nxt = jax.random.categorical(sub, logits / self.temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        nxt = np.asarray(nxt, np.int32)
        for b in range(self.B):
            if not self.active_mask[b]:
                continue
            req = self.slot_req[b]
            self.index[b] += 1
            if len(self.prompt_left[b]):               # still prefilling
                self.tokens[b, 0] = self.prompt_left[b][0]
                self.prompt_left[b] = self.prompt_left[b][1:]
            else:
                req.out.append(int(nxt[b]))
                self.tokens[b, 0] = nxt[b]
                if (len(req.out) >= req.max_new
                        or self.index[b] >= self.cache_len - 1):
                    req.done = True
                    self.active_mask[b] = False
                    self.slot_req[b] = None
                    # assignment→completion latency; p50/p99 come out of
                    # metrics.snapshot()["histograms"]["serve.request_us"]
                    obs.metrics.observe(
                        "serve.request_us",
                        (time.perf_counter() - req.t_assign) * 1e6)
                    obs.metrics.inc("serve.requests")

    def free_slots(self):
        return [b for b in range(self.B) if not self.active_mask[b]]

    def _fail_slot(self, b: int, reason: str):
        """Reclaim slot ``b``: mark its request failed-but-done so the
        driver returns it (with ``.error`` set) instead of hanging, and
        free the slot for the next queued request."""
        req = self.slot_req[b]
        if req is not None:
            req.error = reason
            req.done = True
            obs.metrics.inc("serve.request_error", reason.split(":")[0])
        self.active_mask[b] = False
        self.slot_req[b] = None
        self.prompt_left[b] = np.zeros((0,), np.int32)

    def _sweep_deadlines(self):
        now = time.perf_counter()
        for b in range(self.B):
            req = self.slot_req[b]
            if (req is not None and req.deadline_s is not None
                    and now - req.t_assign > req.deadline_s):
                obs.metrics.inc("serve.deadline_exceeded")
                self._fail_slot(b, "deadline")

    def health(self) -> dict:
        """Liveness snapshot for external monitors (and the chaos bench)."""
        return {
            "steps": self.steps,
            "step_failures": self.step_failures,
            "active_slots": int(self.active_mask.sum()),
            "slots": self.B,
            "requests_completed": obs.metrics.counter_total("serve.requests"),
            "requests_failed":
                obs.metrics.counter_total("serve.request_error"),
        }

    def run(self, requests: list[Request]) -> list[Request]:
        """Drain ``requests`` through the slot pool.

        A step failure no longer hangs the driver: under the session
        policy ``on_failure='raise'`` it propagates (injected faults as
        :class:`GuardedExecutionError` naming ``serve.step``); under
        ``'fallback'`` the step retries up to :data:`MAX_STEP_RETRIES`
        consecutive times, then the oldest active request is evicted
        (``.error`` set, slot freed) so the rest of the pool makes
        progress. Per-request ``deadline_s`` budgets are swept every
        iteration. Every request always comes back ``done`` — check
        ``.error`` to tell clean completions from failures.
        """
        queue = list(requests)
        done: list[Request] = []
        streak = 0
        while queue or self.active_mask.any():
            self._sweep_deadlines()
            for b in self.free_slots():
                if not queue:
                    break
                self.assign(queue.pop(0), b)
            if self.active_mask.any():
                try:
                    self.step()
                    streak = 0
                except (KeyboardInterrupt, SystemExit):
                    raise
                except Exception as e:
                    self.step_failures += 1
                    obs.metrics.inc("serve.step_error", type(e).__name__)
                    if rguard.on_failure() == "raise":
                        if isinstance(e, rfaults.FaultInjected):
                            raise rguard.GuardedExecutionError(
                                "serve.step", [("step", e)]) from e
                        raise
                    streak += 1
                    if streak > MAX_STEP_RETRIES:
                        active = [b for b in range(self.B)
                                  if self.active_mask[b]]
                        if active:
                            oldest = min(
                                active,
                                key=lambda b: self.slot_req[b].t_assign)
                            self._fail_slot(oldest, "step_failure")
                        streak = 0
            for r in requests:
                if r.done and r not in done:
                    done.append(r)
        return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--scan-impl", default=None,
                    choices=("engine", "engine_unchunked", "chunked"),
                    help="recurrence schedule for scan-family archs: "
                         "chunk-streamed engine / monolithic engine / XLA "
                         "chunked scan (default: backend pick, DESIGN.md §12)")
    args = ap.parse_args(argv)

    from repro.config import get_config
    from repro.models import build_model
    from repro.nn.spec import init_params

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.scan_impl:
        cfg = dataclasses.replace(cfg, scan_impl=args.scan_impl)
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    server = DecodeServer(model, params, slots=args.slots,
                          cache_len=args.cache_len)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, args.prompt_len,
                                    dtype=np.int32), args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    done = server.run(reqs)
    dt = time.time() - t0
    tok = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {tok} tokens in {dt:.2f}s "
          f"({tok/dt:.1f} tok/s, {server.steps} batched steps)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out[:8]}…")
    return done


if __name__ == "__main__":
    main()
