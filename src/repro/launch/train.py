"""Production training driver.

Single-host entrypoint that exercises the full stack end-to-end: config →
mesh → sharded init → jitted train step (loss/grads/AdamW) → data
pipeline → checkpoint/restart → straggler watchdog. On a real multi-pod
cluster the same driver runs under ``jax.distributed.initialize`` with
``make_production_mesh``; here the mesh spans however many (real or
XLA-forced) host devices exist.

Fault tolerance:
* checkpoints every ``--ckpt-every`` steps (async, atomic COMMIT marker);
* on start, resumes from the latest committed step automatically;
* ``--fail-at-step N`` raises mid-run (after the step, before its
  checkpoint) to let tests prove bit-exact restart;
* a step-time watchdog EMA flags stragglers (>2.5σ) — on TPU pods this
  is where you would trigger data-shard re-balancing; we log and count.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--conv-frontend", action="store_true",
                    help="audio archs: train the real mel conv stem "
                         "through the SSAM engine instead of the stub "
                         "frame embeddings (whisper)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="inject a crash after this step (fault-tolerance tests)")
    ap.add_argument("--scan-impl", default=None,
                    choices=("engine", "engine_unchunked", "chunked"),
                    help="recurrence schedule for ssm/rwkv archs: "
                         "'engine' streams (R, chunk) slabs through the "
                         "chunk-streamed engine scan (O(chunk) memory, "
                         "DESIGN.md §12); default picks per backend")
    ap.add_argument("--metrics-file", default="")
    args = ap.parse_args(argv)

    from repro.checkpointing import CheckpointManager
    from repro.config import ShapeConfig, get_config
    from repro.data import TokenDataset
    from repro.distributed.sharding import mesh_context, shardings_for_specs
    from repro.launch.cell import build_cell
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.nn.spec import abstract_params, init_params
    from repro.optim import adamw_state_specs

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.conv_frontend:
        if cfg.family != "audio":
            ap.error(f"--conv-frontend is for audio archs, not {cfg.family}")
        n_mels = cfg.n_mels or (8 if args.smoke else 80)
        cfg = dataclasses.replace(cfg, conv_frontend=True, n_mels=n_mels)
    if args.scan_impl:
        cfg = dataclasses.replace(cfg, scan_impl=args.scan_impl)
    mesh = make_host_mesh(args.model_axis)
    shape = ShapeConfig("custom_train", "train", args.seq, args.batch)
    cell = build_cell(cfg, shape, mesh, dtype=args.dtype, lr=args.lr,
                      lr_warmup=max(args.steps // 10, 10),
                      lr_total=max(args.steps, 100))
    model = build_model(dataclasses.replace(cfg, dtype=args.dtype))
    pspecs = model.specs()
    ospecs = adamw_state_specs(pspecs)
    params_sh = shardings_for_specs(pspecs, mesh)
    opt_sh = shardings_for_specs(ospecs, mesh)

    with mesh, mesh_context(mesh):
        init_fn = jax.jit(lambda k: init_params(pspecs, k),
                          out_shardings=params_sh)
        params = init_fn(jax.random.PRNGKey(args.seed))
        opt_state = jax.jit(lambda k: init_params(ospecs, k),
                            out_shardings=opt_sh)(jax.random.PRNGKey(0))

        start_step = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = CheckpointManager(args.ckpt_dir)
            restored = ckpt.restore_latest(
                {"params": abstract_params(pspecs),
                 "opt": abstract_params(ospecs)},
                shardings={"params": params_sh, "opt": opt_sh})
            if restored[0] is not None:
                start_step = restored[0]
                params = restored[1]["params"]
                opt_state = restored[1]["opt"]
                print(f"[train] resumed from step {start_step}", flush=True)

        step_fn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                          out_shardings=cell.out_shardings,
                          donate_argnums=cell.donate_argnums)

        ds = TokenDataset(cfg.vocab, args.seq, seed=args.seed)
        times, losses = [], []
        ema, emvar = None, 0.0
        stragglers = 0
        metrics_f = open(args.metrics_file, "a") if args.metrics_file else None

        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     ds.batch(step, args.batch).items()}
            if cfg.family == "audio":
                if cfg.conv_frontend:
                    batch["mel"] = jax.random.normal(
                        jax.random.PRNGKey(step),
                        (args.batch, cfg.n_mels, 2 * cfg.n_frames),
                        cfg.param_dtype)
                else:
                    batch["frames"] = jax.random.normal(
                        jax.random.PRNGKey(step), (args.batch, cfg.n_frames,
                                                   cfg.d_model),
                        cfg.param_dtype)
            if cfg.family == "vlm":
                batch["prefix_embeds"] = jax.random.normal(
                    jax.random.PRNGKey(step), (args.batch, cfg.n_prefix,
                                               cfg.d_model), cfg.param_dtype)
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            times.append(dt)
            losses.append(loss)
            # straggler watchdog (EMA ± 2.5σ)
            if ema is None:
                ema = dt
            else:
                d = dt - ema
                ema += 0.1 * d
                emvar = 0.9 * (emvar + 0.1 * d * d)
                if step > start_step + 5 and dt > ema + 2.5 * max(emvar, 1e-12) ** 0.5 and dt > 1.5 * ema:
                    stragglers += 1
                    print(f"[watchdog] step {step} straggled: {dt:.3f}s "
                          f"(ema {ema:.3f}s)", flush=True)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step:5d} loss {loss:8.4f} "
                      f"gnorm {float(metrics['grad_norm']):8.3f} {dt*1e3:7.1f}ms",
                      flush=True)
            if metrics_f:
                metrics_f.write(json.dumps(
                    {"step": step, "loss": loss, "dt": dt}) + "\n")
                metrics_f.flush()
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state},
                          meta={"arch": cfg.name})
            if args.fail_at_step == step:
                if ckpt:
                    ckpt.wait()   # durable writes survive the crash; the
                    # in-flight-write case is covered by the COMMIT-marker
                    # atomicity test.
                raise RuntimeError(f"injected failure at step {step}")

        if ckpt:
            ckpt.save(args.steps, {"params": params, "opt": opt_state},
                      meta={"arch": cfg.name})
            ckpt.wait()
        if metrics_f:
            metrics_f.close()
        print(f"[train] done: loss {losses[0]:.4f} → {losses[-1]:.4f}, "
              f"median step {np.median(times)*1e3:.1f}ms, "
              f"stragglers flagged: {stragglers}", flush=True)
        return losses


if __name__ == "__main__":
    main()
