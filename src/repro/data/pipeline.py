"""Deterministic, restartable, shardable token pipeline.

Design points that matter at 1000-node scale:

* **Stateless indexing** — batch ``i`` is a pure function of ``(seed, i)``,
  so restart-after-failure resumes exactly (no iterator state to
  checkpoint beyond the step counter) and any host can produce any shard
  (elastic re-balancing / straggler re-assignment is a host-id remap).
* **Host sharding** — each host materializes only its ``(host_id,
  num_hosts)`` slice of the global batch; `jax.make_array_from_process_
  local_data` would assemble the global array in a multi-host runtime.
* **Synthetic + file-backed sources** — the synthetic stream is a
  deterministic PRNG Zipf-ish mixture (quick-start, benchmarks); the
  file source memory-maps a flat uint16/uint32 token file.

The (tokens, labels) convention: labels are tokens shifted left, with
-1 marking positions excluded from the loss.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenDataset:
    vocab: int
    seq_len: int
    seed: int = 0
    source: str = "synthetic"          # synthetic | file:<path>
    _tokens: np.ndarray | None = None  # file-backed flat token stream

    def __post_init__(self):
        if self.source.startswith("file:"):
            path = self.source[5:]
            self._tokens = np.memmap(path, dtype=np.uint32, mode="r")

    def _synthetic_block(self, idx: np.ndarray) -> np.ndarray:
        """Deterministic pseudo-text: per-row PRNG, Zipf-ish marginals with
        short-range repetition structure (so tiny models can learn)."""
        out = np.empty((len(idx), self.seq_len + 1), np.int32)
        for r, i in enumerate(idx):
            rng = np.random.default_rng(self.seed * 1_000_003 + int(i))
            z = rng.zipf(1.5, size=self.seq_len + 1)
            row = (z - 1) % self.vocab
            # inject copy structure: second half repeats the first half
            # with per-position noise — gives a learnable signal
            half = (self.seq_len + 1) // 2
            noise = rng.random(half) < 0.1
            seg = row[:half].copy()
            seg[noise] = rng.integers(0, self.vocab, noise.sum())
            row[half : half + half] = seg[: self.seq_len + 1 - half][: half]
            out[r] = row
        return out

    def _file_block(self, idx: np.ndarray) -> np.ndarray:
        n = len(self._tokens)
        out = np.empty((len(idx), self.seq_len + 1), np.int32)
        for r, i in enumerate(idx):
            start = (int(i) * self.seq_len) % max(n - self.seq_len - 1, 1)
            out[r] = self._tokens[start : start + self.seq_len + 1]
        return out % self.vocab

    def batch(self, step: int, batch_size: int, *, host_id: int = 0,
              num_hosts: int = 1) -> dict[str, np.ndarray]:
        """Global batch ``step``, host-local slice. Pure in (seed, step)."""
        assert batch_size % num_hosts == 0
        local = batch_size // num_hosts
        base = step * batch_size + host_id * local
        idx = np.arange(base, base + local, dtype=np.int64)
        block = (self._file_block(idx) if self._tokens is not None
                 else self._synthetic_block(idx))
        return {"tokens": block[:, :-1].astype(np.int32),
                "labels": block[:, 1:].astype(np.int32)}


def make_batches(ds: TokenDataset, batch_size: int, start_step: int = 0):
    """Infinite iterator of (step, batch) from ``start_step`` (restartable)."""
    step = start_step
    while True:
        yield step, ds.batch(step, batch_size)
        step += 1
