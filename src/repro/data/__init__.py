"""Data pipeline: deterministic synthetic token streams + host sharding."""
from .pipeline import TokenDataset, make_batches  # noqa: F401
