"""whisper-base — encoder-decoder audio backbone, conv frontend STUBBED.

[arXiv:2212.04356] 6L enc + 6L dec, d_model=512 8H (MHA) d_ff=2048
vocab=51865; 1500 encoder frames (the stub provides precomputed frame
embeddings post-conv).
"""
import dataclasses
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, kv_heads=8, head_dim=64,
    d_ff=2048, vocab=51865,
    encoder_layers=6, n_frames=1500, pos_emb="learned",
    norm="layernorm", mlp="mlp_gelu", attn_bias=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=4, head_dim=16,
    d_ff=160, vocab=512, encoder_layers=2, n_frames=16,
)

# Smoke config with the real mel conv stem through the SSAM engine's
# reduce-axes plan (whisper-base uses n_mels=80; scaled with the rest).
SMOKE_CONV = dataclasses.replace(SMOKE, conv_frontend=True, n_mels=8)

# Same stem pinned to the MXU (im2row matmul) lowering — the stem's
# C_in·taps contraction is exactly the shape class where the tensor-core
# path wins (DESIGN.md §13); the tuner would pick it, this pins it.
SMOKE_CONV_MXU = dataclasses.replace(SMOKE_CONV, conv_strategy="mxu")
