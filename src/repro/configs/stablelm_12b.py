"""stablelm-12b — dense decoder, GQA kv=8, partial rotary (25%).

[hf:stabilityai/stablelm-2-12b] 40L d_model=5120 32H d_ff=13824
vocab=100352.
"""
import dataclasses
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, kv_heads=8, head_dim=160,
    d_ff=13824, vocab=100352,
    rot_frac=0.25, norm="layernorm", mlp="gated_silu",
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=2, head_dim=16,
    d_ff=160, vocab=512,
)
