"""deepseek-v2-236b — MLA (kv_lora=512) + fine-grained MoE
(160 routed top-6 + 2 shared experts).

[arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2] 60L d_model=5120 128H
d_ff=1536(/routed expert) vocab=102400; q_lora=1536, qk_nope=128,
qk_rope=64, v_head=128. (Deviation noted in DESIGN.md: the real model
uses a dense FFN in layer 0; the assignment specifies uniform MoE.)
"""
import dataclasses
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, kv_heads=128, head_dim=128,
    d_ff=1536, vocab=102400,
    mla=True, q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128,
    moe=True, n_experts=160, top_k=6, n_shared=2, capacity_factor=1.25,
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=4, head_dim=16,
    d_ff=64, vocab=512, q_lora=32, kv_lora=16, qk_nope=16, qk_rope=8,
    v_head=16, n_experts=8, top_k=2, n_shared=1,
)
