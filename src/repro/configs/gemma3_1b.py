"""gemma3-1b — dense decoder, 5:1 local:global attention, 128k-ready.

[hf:google/gemma-3-1b-pt] 26L d_model=1152 4H (kv=1, head_dim=256)
d_ff=6912 vocab=262144. Sliding window 512; every 6th layer global with
RoPE base 1e6 (locals 1e4); qk-norm; gemma (1+w) RMSNorm + sandwich
norms; embeddings scaled by sqrt(d).
"""
import dataclasses
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144,
    window=512, global_every=6, rope_base=10000.0, rope_base_global=1e6,
    qk_norm=True, norm="rmsnorm_p1", sandwich_norm=True, emb_scale=True,
    mlp="gated_gelu",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=1, head_dim=16,
    d_ff=160, vocab=512, window=8, global_every=2,
)
