"""internvl2-1b — VLM: InternViT frontend (STUB) + Qwen2-0.5B-style LM.

[arXiv:2404.16821; hf:OpenGVLab/InternVL2-1B] backbone 24L d_model=896
14H (kv=2, head_dim=64) d_ff=4864 vocab=151655. Per the assignment the
vision tower is a stub: input_specs provides 256 precomputed patch
embeddings per image, prepended to the token stream.
"""
import dataclasses
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, kv_heads=2, head_dim=64,
    d_ff=4864, vocab=151655,
    attn_bias=True, n_prefix=256,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=2, head_dim=16,
    d_ff=160, vocab=512, n_prefix=8,
)
