"""hymba-1.5b — hybrid-head: parallel attention + Mamba heads per layer.

[arXiv:2411.13676; hf:nvidia/Hymba-1.5B-Base] 32L d_model=1600 25H
(kv=5, head_dim=64) d_ff=5504 ssm_state=16 vocab=32001. Sliding window
1024 with global layers {first, middle, last} per the paper.
"""
import dataclasses
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    window=1024, global_layers=(0, 15, 31),
    ssm_state=16, d_inner=3200, conv_k=4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=2, head_dim=16,
    d_ff=160, vocab=512, window=8, global_layers=(0,), d_inner=128,
    ssm_state=8,
)
