"""rwkv6-1.6b — RWKV-6 "Finch": attention-free, data-dependent decay.

[arXiv:2404.05892] 24L d_model=2048 d_ff=7168 vocab=65536; 32 heads of
64 (K=V=64) per the RWKV-6 head convention (d_model/64).
"""
import dataclasses
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, kv_heads=32, head_dim=64,
    d_ff=7168, vocab=65536,
    head_k=64, head_v=64, wkv_chunk=64,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=4, head_dim=16,
    d_ff=224, vocab=512, head_k=16, head_v=16, wkv_chunk=16,
)
