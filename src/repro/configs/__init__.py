"""One module per assigned architecture; each exports CONFIG (exact
published config) and SMOKE (reduced same-family config for CPU tests)."""
