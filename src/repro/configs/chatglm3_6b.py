"""chatglm3-6b — dense decoder, GQA kv=2, 2d (half-dim) RoPE, SwiGLU.

[arXiv:2406.12793; hf:THUDM/chatglm3-6b] 28L d_model=4096 32H d_ff=13696
vocab=65024. GLM applies rotary to half the head dim (rot_frac=0.5).
"""
import dataclasses
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, kv_heads=2, head_dim=128,
    d_ff=13696, vocab=65024,
    rot_frac=0.5, norm="rmsnorm", mlp="gated_silu", attn_bias=True,
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=2, head_dim=16,
    d_ff=160, vocab=512,
)
