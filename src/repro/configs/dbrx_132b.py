"""dbrx-132b — fine-grained MoE: 16 experts, top-4, GQA kv=8.

[hf:databricks/dbrx-base] 40L d_model=6144 48H d_ff=10752(/expert)
vocab=100352.
"""
import dataclasses
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, kv_heads=8, head_dim=128,
    d_ff=10752, vocab=100352,
    moe=True, n_experts=16, top_k=4, capacity_factor=1.25,
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=2, head_dim=16,
    d_ff=96, vocab=512, n_experts=4, top_k=2,
)
