"""starcoder2-3b — dense decoder, GQA kv=2, full RoPE, GELU MLP + biases.

[arXiv:2402.19173; hf:bigcode/starcoder2-3b] 30L d_model=3072 24H
d_ff=12288 vocab=49152. LayerNorm, attention + MLP biases.
"""
import dataclasses
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, kv_heads=2, head_dim=128,
    d_ff=12288, vocab=49152,
    norm="layernorm", mlp="mlp_gelu", attn_bias=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=2, head_dim=16,
    d_ff=160, vocab=512,
)
