"""SSAM 2-D convolution Pallas TPU kernel — the paper's Listing 1 on TPU.

Schedule (DESIGN.md §2): the image x-axis maps to the 128-wide VREG lane
axis (the "warp"), the sliding window of §4.2 is vectorized across
sublanes (``BH`` output rows per grid step play the paper's ``P``), and
the M filter columns are the systolic steps — partial sums are *rolled*
one lane per step (the ``__shfl_up_sync`` of §4.4) and accumulated with
an FMA against filter column m:

    s ← roll(s, 1); s ← s ⊕ data[i+n, :] ⊗ w[n, m]        (Eq. 1)

Overlapped blocking (§4.5) is expressed with ``pl.Element`` input
BlockSpecs: output tiles are disjoint, input tiles overlap by the
``(N−1, M−1)`` halo, so grid steps never communicate — the TPU analogue
of the paper's branch-free warp blocks.

Two schedule variants are provided (DESIGN.md §2, third deviation):

* ``variant="shift_psum"`` — paper-faithful: the *partial sums* move.
* ``variant="shift_data"`` — re-associated: the accumulator stays put and
  the data vector is rolled instead; on TPU this breaks the
  roll→FMA→roll dependency chain on the accumulator (the rolls of all M
  steps become independent and can issue in parallel with FMAs). Output
  is bit-identical for f32 because the same products are added in the
  same order per lane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv2d_kernel(x_ref, w_ref, o_ref, *, M: int, N: int, BH: int, BW: int,
                   variant: str, acc_dtype):
    """One overlapped block: x_ref (BH+N−1, BW+M−1) → o_ref (BH, BW)."""
    xb = x_ref[:].astype(acc_dtype)
    BWin = BW + M - 1
    s = jnp.zeros((BH, BWin), acc_dtype)
    if variant == "shift_psum":
        # Paper Listing 1: shift the partial sums, lane j accumulates the
        # column-m inner product of lane j while carrying lane j−1's sum.
        for m in range(M):
            if m > 0:
                s = jnp.roll(s, 1, axis=1)
            for n in range(N):
                s = s + xb[n : n + BH, :] * w_ref[n, m]
        out = s[:, M - 1 : M - 1 + BW]
    else:
        # Re-associated "stationary output": roll the *data* left by m so
        # each lane j accumulates x[:, j+m]·w[:, m] directly. Same sums,
        # no serial dependency through the accumulator's rolls.
        for m in range(M):
            xm = xb if m == 0 else jnp.roll(xb, -m, axis=1)
            for n in range(N):
                s = s + xm[n : n + BH, :] * w_ref[n, m]
        out = s[:, :BW]
    o_ref[:] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_h", "block_w", "variant", "interpret", "acc_dtype"),
)
def conv2d_valid(
    x: jax.Array,
    w: jax.Array,
    *,
    block_h: int = 8,
    block_w: int = 128,
    variant: str = "shift_psum",
    interpret: bool = True,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Valid-mode 2-D cross-correlation ``(H, W) ⋆ (N, M) → (H−N+1, W−M+1)``.

    The driver pads the image up to whole output tiles (zeros land in the
    cropped region), builds the overlapped-block grid and invokes the
    systolic kernel. ``interpret=True`` runs the kernel body on CPU; on a
    real TPU pass ``interpret=False``.
    """
    H, W = x.shape
    N, M = w.shape
    out_h, out_w = H - N + 1, W - M + 1
    BH, BW = block_h, block_w
    gh, gw = pl.cdiv(out_h, BH), pl.cdiv(out_w, BW)
    # Pad so every (incl. last) overlapped input block is in-bounds.
    pad_h = gh * BH + N - 1 - H
    pad_w = gw * BW + M - 1 - W
    xp = jnp.pad(x, ((0, pad_h), (0, pad_w)))

    kern = functools.partial(
        _conv2d_kernel, M=M, N=N, BH=BH, BW=BW, variant=variant,
        acc_dtype=acc_dtype,
    )
    out = pl.pallas_call(
        kern,
        grid=(gh, gw),
        in_specs=[
            pl.BlockSpec(
                (pl.Element(BH + N - 1), pl.Element(BW + M - 1)),
                lambda i, j: (i * BH, j * BW),
            ),
            pl.BlockSpec((N, M), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BH, BW), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gh * BH, gw * BW), x.dtype),
        interpret=interpret,
    )(xp, w)
    return out[:out_h, :out_w]


def conv2d_same(x: jax.Array, w: jax.Array, **kw) -> jax.Array:
    """'Same'-mode convolution (zero boundary), anchor at the filter centre."""
    N, M = w.shape
    top, left = (N - 1) // 2, (M - 1) // 2
    xp = jnp.pad(x, ((top, N - 1 - top), (left, M - 1 - left)))
    return conv2d_valid(xp, w, **kw)
