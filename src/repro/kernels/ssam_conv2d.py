"""SSAM 2-D convolution — the paper's Listing 1 as a plan over the engine.

Schedule (DESIGN.md §2): the image x-axis maps to the lane axis (the
"warp"), the sliding window of §4.2 is vectorized across sublanes
(``block_h`` output rows play the paper's ``P``), and the M filter
columns are the systolic steps — partial sums roll one lane per step
(the ``__shfl_up_sync`` of §4.4) and accumulate an FMA against filter
column m (Eq. 1).

This module is a thin plan builder: :func:`repro.core.plan.conv2d_plan`
describes the schedule, :func:`repro.core.engine.run_window_plan` lowers
it — overlapped blocking, halo padding, valid-lane crop and both
schedule variants (``shift_psum``/``shift_data``, DESIGN.md §2) all come
from the engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import run_window_plan
from repro.core.plan import conv2d_plan, conv2d_same_plan


def plan_for(w_shape: tuple[int, int], mode: str = "valid"):
    """The systolic plan lowered for an ``(N, M)`` filter.

    'same' mode folds the centre-anchor boundary into the plan's
    lead/trail fields, which makes it shape-preserving — the form the
    sharded halo-exchange path requires.
    """
    N, M = w_shape
    return conv2d_same_plan(M, N) if mode == "same" else conv2d_plan(M, N)


def conv2d_valid(
    x: jax.Array,
    w: jax.Array,
    *,
    block_h: int = 8,
    block_w: int = 128,
    variant: str = "shift_psum",
    interpret: bool = True,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Valid-mode 2-D cross-correlation ``(H, W) ⋆ (N, M) → (H−N+1, W−M+1)``."""
    return run_window_plan(
        x, w, plan=plan_for(w.shape), block=(block_h, block_w),
        variant=variant, interpret=interpret, acc_dtype=acc_dtype,
    )


def conv2d_same(
    x: jax.Array,
    w: jax.Array,
    *,
    block_h: int = 8,
    block_w: int = 128,
    variant: str = "shift_psum",
    interpret: bool = True,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """'Same'-mode convolution (zero boundary), anchor at the filter centre.

    The boundary is plan geometry (``conv2d_same_plan``'s lead/trail),
    not a manual pad — single-device and sharded execution lower the
    identical plan.
    """
    return run_window_plan(
        x, w, plan=plan_for(w.shape, "same"), block=(block_h, block_w),
        variant=variant, interpret=interpret, acc_dtype=acc_dtype,
    )
