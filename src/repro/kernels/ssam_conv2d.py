"""SSAM 2-D convolution — the paper's Listing 1 as a plan over the engine.

Schedule (DESIGN.md §2): the image x-axis maps to the lane axis (the
"warp"), the sliding window of §4.2 is vectorized across sublanes
(``block_h`` output rows play the paper's ``P``), and the M filter
columns are the systolic steps — partial sums roll one lane per step
(the ``__shfl_up_sync`` of §4.4) and accumulate an FMA against filter
column m (Eq. 1).

This module is a thin plan builder: :func:`repro.core.plan.conv2d_plan`
describes the schedule, :func:`repro.core.engine.run_window_plan` lowers
it — overlapped blocking, halo padding, valid-lane crop and both
schedule variants (``shift_psum``/``shift_data``, DESIGN.md §2) all come
from the engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import run_window_plan
from repro.core.plan import (conv2d_batched_plan, conv2d_nchw_plan,
                             conv2d_plan, conv2d_same_plan)


def plan_for(w_shape: tuple[int, int], mode: str = "valid"):
    """The systolic plan lowered for an ``(N, M)`` filter.

    'same' mode folds the centre-anchor boundary into the plan's
    lead/trail fields, which makes it shape-preserving — the form the
    sharded halo-exchange path requires.
    """
    N, M = w_shape
    return conv2d_same_plan(M, N) if mode == "same" else conv2d_plan(M, N)


def plan_for_batched(w_shape: tuple[int, int], mode: str = "valid"):
    """Batched single-channel plan for a ``(B, H, W)`` image stack."""
    N, M = w_shape
    return conv2d_batched_plan(M, N, mode=mode)


def plan_for_nchw(x_shape, w_shape, mode: str = "valid", groups: int = 1):
    """Reduce-axes plan for an NCHW minibatch against an OIHW filter.

    ``groups > 1`` describes ONE group's reduce sweep (``C_in/groups``
    channels against ``C_out/groups`` filters): grouped conv slices the
    operands per group and runs this plan once per slice (ops.conv2d).
    """
    B, C_in = x_shape[:2]
    C_out, C_in_w, N, M = w_shape
    if C_in_w * groups != C_in:
        raise ValueError(
            f"conv2d: filter expects C_in={C_in_w * groups} "
            f"({C_in_w} per group × {groups}) but input has C_in={C_in} "
            f"(x {tuple(x_shape)}, w {tuple(w_shape)})")
    return conv2d_nchw_plan(B, C_in, C_out, M, N, mode=mode, groups=groups)


def conv2d_valid(
    x: jax.Array,
    w: jax.Array,
    *,
    block_h: int = 8,
    block_w: int = 128,
    variant: str = "shift_psum",
    interpret: bool = True,
    acc_dtype=jnp.float32,
    strategy: str | None = None,
    backend: str | None = None,
) -> jax.Array:
    """Valid-mode 2-D cross-correlation ``(H, W) ⋆ (N, M) → (H−N+1, W−M+1)``."""
    return run_window_plan(
        x, w, plan=plan_for(w.shape), block=(block_h, block_w),
        variant=variant, interpret=interpret, acc_dtype=acc_dtype,
        strategy=strategy, backend=backend,
    )


def conv2d_same(
    x: jax.Array,
    w: jax.Array,
    *,
    block_h: int = 8,
    block_w: int = 128,
    variant: str = "shift_psum",
    interpret: bool = True,
    acc_dtype=jnp.float32,
    strategy: str | None = None,
    backend: str | None = None,
) -> jax.Array:
    """'Same'-mode convolution (zero boundary), anchor at the filter centre.

    The boundary is plan geometry (``conv2d_same_plan``'s lead/trail),
    not a manual pad — single-device and sharded execution lower the
    identical plan.
    """
    return run_window_plan(
        x, w, plan=plan_for(w.shape, "same"), block=(block_h, block_w),
        variant=variant, interpret=interpret, acc_dtype=acc_dtype,
        strategy=strategy, backend=backend,
    )


def conv2d_batched(
    x: jax.Array,
    w: jax.Array,
    *,
    mode: str = "valid",
    block_h: int = 8,
    block_w: int = 128,
    time_steps: int = 1,
    variant: str = "shift_psum",
    interpret: bool = True,
    acc_dtype=jnp.float32,
    strategy: str | None = None,
    backend: str | None = None,
) -> jax.Array:
    """A ``(B, H, W)`` image stack against one ``(N, M)`` filter — the
    minibatch rides the grid's block-1 batch axis, no Python loop."""
    return run_window_plan(
        x, w, plan=plan_for_batched(w.shape, mode), block=(block_h, block_w),
        time_steps=time_steps, variant=variant, interpret=interpret,
        acc_dtype=acc_dtype, strategy=strategy, backend=backend,
    )


def conv2d_nchw(
    x: jax.Array,
    w: jax.Array,
    *,
    mode: str = "valid",
    block_h: int = 8,
    block_w: int = 128,
    variant: str = "shift_psum",
    interpret: bool = True,
    acc_dtype=jnp.float32,
    strategy: str | None = None,
    backend: str | None = None,
) -> jax.Array:
    """Batched multi-channel NCHW convolution through the reduce-axes
    engine: ``(B, C_in, H, W) ⋆ (C_out, C_in, N, M) → (B, C_out, H', W')``.

    The engine's grid iterates batch × C_out × spatial × C_in with the
    channel reduction carried in an fp32 scratch accumulator — one
    ``pallas_call``, no Python loop over batch or channels.
    """
    return run_window_plan(
        x, w, plan=plan_for_nchw(x.shape, w.shape, mode),
        block=(block_h, block_w), variant=variant, interpret=interpret,
        acc_dtype=acc_dtype, strategy=strategy, backend=backend,
    )
