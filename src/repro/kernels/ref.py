"""Pure-jnp oracles for every SSAM kernel — the ground truth in tests.

Each function is a direct, obviously-correct statement of the math with
no systolic structure. Kernel unit tests sweep shapes/dtypes and
``assert_allclose`` the Pallas kernels (interpret mode) and the
:mod:`repro.core.executor` model against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .stencils import StencilDef


# ---------------------------------------------------------------------------
# Convolution
# ---------------------------------------------------------------------------

def conv2d_valid(x: jax.Array, w: jax.Array) -> jax.Array:
    """Valid cross-correlation: out[y,x] = Σ_{n,m} x[y+n, x+m]·w[n,m]."""
    return jax.lax.conv_general_dilated(
        x[None, None].astype(jnp.float32),
        w[None, None].astype(jnp.float32),
        window_strides=(1, 1),
        padding="VALID",
    )[0, 0].astype(x.dtype)


def conv2d_same(x: jax.Array, w: jax.Array) -> jax.Array:
    """'Same' zero-boundary cross-correlation, anchor at filter centre."""
    N, M = w.shape
    top, left = (N - 1) // 2, (M - 1) // 2
    xp = jnp.pad(x, ((top, N - 1 - top), (left, M - 1 - left)))
    return conv2d_valid(xp, w)


def conv2d_batched(x: jax.Array, w: jax.Array, mode: str = "valid") -> jax.Array:
    """Minibatch of single-channel images against one filter: (B, H, W)."""
    fn = conv2d_same if mode == "same" else conv2d_valid
    return jax.vmap(lambda xi: fn(xi, w))(x)


def conv2d_nchw(x: jax.Array, w: jax.Array, mode: str = "valid",
                groups: int = 1) -> jax.Array:
    """Batched multi-channel cross-correlation.

    x: (B, C_in, H, W); w: (C_out, C_in/groups, N, M) → (B, C_out, H', W').
    'same' mode anchors at the filter centre (top = (N−1)//2), matching
    :func:`conv2d_same` per channel. ``groups`` maps straight to
    ``feature_group_count`` — the oracle the grouped engine path
    validates against.
    """
    N, M = w.shape[2:]
    if mode == "same":
        top, left = (N - 1) // 2, (M - 1) // 2
        padding = [(top, N - 1 - top), (left, M - 1 - left)]
    else:
        padding = "VALID"
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32), (1, 1), padding,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups).astype(x.dtype)


def conv1d_causal(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv: y[b,t,d] = Σ_k x[b, t−K+1+k, d]·w[k,d]."""
    B, T, D = x.shape
    K, _ = w.shape
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros((B, T, D), jnp.promote_types(x.dtype, jnp.float32))
    for k in range(K):
        out = out + xp[:, k : k + T, :] * w[k, :]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Stencils
# ---------------------------------------------------------------------------

def stencil_apply(x: jax.Array, sdef: StencilDef) -> jax.Array:
    """One same-shape stencil application with zeros outside the domain."""
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for off, c in zip(sdef.offsets, sdef.coeffs):
        shifted = x.astype(jnp.float32)
        for axis, d in enumerate(off):
            shifted = jnp.roll(shifted, -d, axis=axis)
            # zero the wrapped region
            idx = jnp.arange(x.shape[axis])
            if d > 0:
                mask = idx < (x.shape[axis] - d)
            elif d < 0:
                mask = idx >= (-d)
            else:
                continue
            shape = [1] * x.ndim
            shape[axis] = x.shape[axis]
            shifted = shifted * mask.reshape(shape)
        out = out + shifted * c
    return out.astype(x.dtype)


def stencil_iterate(x: jax.Array, sdef: StencilDef, steps: int) -> jax.Array:
    """``steps`` applications with the *pad-once* (trapezoidal) semantics.

    The domain is zero-padded once by ``steps`` footprints, then ``steps``
    valid applications follow. For ``steps == 1`` this equals
    :func:`stencil_apply`. This is the semantics the temporally-blocked
    SSAM kernels implement (see ``ssam_stencil2d`` docstring); it agrees
    with classic zero-Dirichlet iteration (:func:`stencil_iterate_dirichlet`)
    on the interior at distance > steps·radius from the boundary.
    """
    los = [min(o[a] for o in sdef.offsets) for a in range(sdef.ndim)]
    his = [max(o[a] for o in sdef.offsets) for a in range(sdef.ndim)]
    pad = [(steps * -lo, steps * hi) for lo, hi in zip(los, his)]
    xp = jnp.pad(x, pad).astype(jnp.float32)
    for _ in range(steps):
        shape = xp.shape
        new_shape = tuple(s - (hi - lo) for s, lo, hi in zip(shape, los, his))
        out = jnp.zeros(new_shape, jnp.float32)
        for off, c in zip(sdef.offsets, sdef.coeffs):
            sl = tuple(
                slice(d - lo, d - lo + n)
                for d, lo, n in zip(off, los, new_shape)
            )
            out = out + xp[sl] * c
        xp = out
    return xp.astype(x.dtype)


def stencil_iterate_dirichlet(x: jax.Array, sdef: StencilDef, steps: int) -> jax.Array:
    """Classic iteration: re-apply zero boundary conditions every step."""
    for _ in range(steps):
        x = stencil_apply(x, sdef)
    return x


# ---------------------------------------------------------------------------
# Scans
# ---------------------------------------------------------------------------

def cumsum(x: jax.Array) -> jax.Array:
    return jnp.cumsum(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def sat(x: jax.Array) -> jax.Array:
    """Summed-area table: SAT[y,x] = Σ_{i≤y,j≤x} X[i,j]."""
    s = jnp.cumsum(x.astype(jnp.float32), axis=-1)
    return jnp.cumsum(s, axis=-2).astype(x.dtype)


def linear_recurrence(a: jax.Array, b: jax.Array) -> jax.Array:
    """Sequential gold: h_t = a_t·h_{t−1} + b_t along the last axis."""

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    h0 = jnp.zeros(a.shape[:-1], jnp.float32)
    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a32, -1, 0), jnp.moveaxis(b32, -1, 0)))
    return jnp.moveaxis(hs, 0, -1).astype(a.dtype)
