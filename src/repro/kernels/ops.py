"""Public jit'd API over the SSAM kernels, with backend dispatch.

Every op takes ``impl``:

* ``"interpret"`` (default here, CPU container) — the engine-lowered
  Pallas kernel executed by the Pallas interpreter: validates the real
  kernel schedule.
* ``"pallas"``    — compiled Mosaic kernel (real TPU only).
* ``"xla"``       — the pure-jnp oracle from :mod:`repro.kernels.ref`;
  shardable under pjit, used by the full-scale models and the dry-run.

``default_impl()`` picks "pallas" on TPU backends and "xla" elsewhere, so
model code can stay backend-agnostic.

Every non-xla op also takes ``autotune``: when True, the block config
(and schedule variant) is chosen by the §5 perf-model autotuner
(:mod:`repro.core.tuning`) — the model ranks candidates, the top few are
measured (the family default always included, so tuning never regresses
it), and winners are cached per (plan, shape, backend). Explicit block
kwargs win over tuned values.

``ops.stencil`` / ``ops.conv2d`` additionally take ``mesh=`` /
``in_specs=`` / ``boundary=``: with a mesh, the domain is sharded per
the PartitionSpec (default: the rule tables via
``halo_exchange.default_domain_spec``) and the plan runs through the
:mod:`repro.distributed.halo_exchange` layer — ppermute halo pushes
once per call, interior compute overlapped with the exchange. Sharding
problems in the resolved layout (an explicitly requested mesh axis that
does not divide the domain, a halo wider than the whole domain axis)
raise ``ValueError`` here, before any ``pallas_call``; a halo wider
than one *shard* is fine — the exchange chains ppermute hops across as
many neighbors as it spans. A *default* spec
follows the rule tables' divisibility fallback and leaves a
non-dividing axis replicated instead. Autotuning under a mesh targets
the *shard-local* halo-extended shape, so the winner is exactly the
per-device kernel.

Fusion surfaces (DESIGN.md §11): windowed ops take ``epilogue=`` /
``epilogue_args=`` — elementwise output stages (bias/gelu/silu/relu/
scale/residual_add) applied in VMEM between the accumulator flush and
the output store, killing the HBM round-trip of a conv→activation seam
— and ``ops.conv2d`` takes ``stride=`` (an output-strided grid that
computes only the kept lanes). :func:`pipeline` chains shape-preserving
windowed stages into ONE fused engine kernel via
:func:`repro.core.fuse.fuse_plans` (``fuse='auto'`` falls back to the
unfused pad-once sequence when the chain does not qualify). Scan ops
reject all of these with named pre-pallas errors — a scan's output is
also its sequential inter-block carry.

Every engine-lowered op is differentiable: the ops are ``custom_vjp``
wrappers whose backward rules rebuild the **adjoint plan**
(:mod:`repro.core.adjoint` — point-reflected taps with swapped
lead/trail for backward-input, the batch+spatial-reduce correlation for
backward-weight, time-reversed scans for the scan family) and lower it
through the same engine; sharded forward ⇒ sharded backward (reversed
ppermute pushes, psum'd weight grads). With ``autotune=True`` the
backward-input plan is tuned independently under its own §5 signature.
``impl="xla"`` keeps JAX's native AD of the oracle — the gradcheck
reference.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import adjoint as adj
from repro.core import tuning
from repro.robust import guard as rguard
from repro.core.engine import run_weight_grad_plan, run_window_plan
from repro.core.fuse import fuse_plans
from repro.core.plan import (SystolicPlan, epilogue_operand_stages,
                             normalize_epilogue)
from . import ref
from . import ssam_conv1d as _c1
from . import ssam_conv2d as _c2
from . import ssam_scan as _sc
from . import ssam_stencil2d as _s2
from . import ssam_stencil3d as _s3
from .stencils import BENCHMARKS, StencilDef


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def default_engine_impl() -> str:
    """The engine-lowered path for the current backend: compiled Mosaic
    on real TPU, the Pallas interpreter elsewhere.

    This is the layer/training default (``nn/layers.conv2d_apply``,
    ``nn/ssm.mamba_apply``): with the adjoint-plan subsystem
    (:mod:`repro.core.adjoint`) every engine op is a ``custom_vjp``
    whose backward pass lowers through the same plan engine, so model
    code no longer silently differentiates through the XLA oracle
    off-TPU. ``default_impl()`` remains the serving/oracle default
    (pjit-shardable XLA off-TPU)."""
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def _interp(impl: str) -> bool:
    if impl not in ("interpret", "pallas"):
        raise ValueError(impl)
    return impl == "interpret"


_DEFAULTS = {
    "conv2d": tuning.KernelConfig((8, 128)),
    "conv2d_nchw": tuning.KernelConfig((8, 128)),
    "stencil2d": tuning.KernelConfig((8, 128)),
    "stencil3d": tuning.KernelConfig((4, 8, 128)),
    "conv1d": tuning.KernelConfig((128, 128)),
    "scan": tuning.KernelConfig((8, 128)),
    "recurrence": tuning.KernelConfig((8, 128)),
}


def engine_interpret() -> bool:
    """Whether engine-lowered paths should run the Pallas interpreter
    (non-TPU backends) or compiled Mosaic (real TPU)."""
    return jax.default_backend() != "tpu"


def _default_cfg(plan) -> tuning.KernelConfig:
    """Family default block config; fused-pipeline kinds fall back to the
    dimensionality default (the chain is one windowed kernel)."""
    cfg = _DEFAULTS.get(plan.kind)
    if cfg is not None:
        return cfg
    if plan.combine != "fma":
        return tuning.KernelConfig((8, 128))
    return tuning.KernelConfig((4, 8, 128) if plan.ndim_spatial == 3
                               else (8, 128))


def _strategy_plan(plan, strategy, op: str):
    """Pin a lowering strategy onto the plan IR (named pre-pallas check).

    The strategy lives on the *plan*, not on the call: adjoints and
    fused chains derive their plans with ``dataclasses.replace``, so an
    mxu forward transposes to an mxu backward with no extra plumbing
    (DESIGN.md §13). ``None``/'auto' leave the plan as-is — the
    autotuner then owns the algorithm choice.
    """
    if strategy in (None, "auto"):
        return plan
    if strategy not in ("lanes", "mxu"):
        raise ValueError(
            f"ops.{op}: strategy must be 'lanes', 'mxu', 'auto' or None, "
            f"got {strategy!r}")
    return dataclasses.replace(plan, strategy=strategy)


def _engine_block(plan, kw: dict) -> tuple[tuple[int, ...], str, dict]:
    """Split family kwargs into (engine block tuple, variant, rest)."""
    kw = dict(kw)
    d = _default_cfg(plan).block
    if plan.kind == "conv1d":
        block = (kw.pop("block_t", d[0]), kw.pop("block_d", d[1]))
    elif plan.ndim_spatial == 3:
        block = (kw.pop("block_z", d[0]), kw.pop("block_h", d[1]),
                 kw.pop("block_w", d[2]))
    else:
        block = (kw.pop("block_h", d[0]), kw.pop("block_w", d[1]))
    return block, kw.pop("variant", "shift_psum"), kw


def _engine_runner(plan, x, w, interpret, *, epi_args=(), time_steps=1,
                   backend=None):
    """Generic tuning-measurement closure: lower ``plan`` itself.

    The thin family wrappers rebuild their plan without epilogue/stride/
    stages, so ops that carry those must measure the *actual* plan — the
    kernel the tuned config will run."""
    def call(**k):
        blk, variant, rest = _engine_block(plan, dict(k))
        t = rest.pop("time_steps", time_steps)
        acc = rest.pop("acc_dtype", jnp.float32)
        strat = rest.pop("strategy", None)
        if rest:
            raise TypeError(f"unexpected kwargs for {plan.kind!r}: "
                            f"{sorted(rest)}")
        return run_window_plan(x, w, plan=plan, block=blk, variant=variant,
                               time_steps=t, interpret=interpret,
                               acc_dtype=acc, epilogue_args=epi_args,
                               strategy=strat, backend=backend)
    return call


def _epilogue_spec(epilogue, epilogue_args, op: str):
    """Normalize + validate an op's epilogue kwargs, pre-pallas."""
    stages = normalize_epilogue(epilogue)
    need = [s.op for s in epilogue_operand_stages(stages)]
    args = tuple(epilogue_args)
    if len(args) != len(need):
        raise ValueError(
            f"ops.{op}: epilogue {tuple(s.op for s in stages)} needs "
            f"{len(need)} runtime operand(s) ({need}) in epilogue_args, "
            f"got {len(args)}")
    return stages, args


def _check_epilogue_operands(plan, args, op: str, x, w=None,
                             time_steps: int = 1) -> None:
    """Named pre-pallas shape validation of epilogue operands.

    Bias follows the plan's layout — per-C_out for out-axes plans,
    per-lane for perlane plans, a scalar otherwise — and a residual
    must be shaped exactly like the op's output. Raised here so the
    failure names the op instead of surfacing as an assert/BlockSpec
    error inside the jitted engine (the mesh path included).
    """
    nb, nr, no = plan.batch_axes, plan.reduce_axes, plan.out_axes
    out_sp = plan.out_shape(tuple(x.shape[nb + nr:]), time_steps)
    for st, arr in zip(epilogue_operand_stages(plan.final_epilogue()), args):
        shape = tuple(getattr(arr, "shape", ()))
        if st.op == "bias":
            if no:
                want = tuple(w.shape[:no])
                what = f"a per-C_out {want} row"
            elif plan.coeff_mode == "perlane":
                want = (x.shape[-1],)
                what = f"a per-channel {want} row (channels are the lanes)"
            else:
                if _shape_size(shape) == 1:
                    continue
                raise ValueError(
                    f"ops.{op}: bias epilogue wants a scalar for "
                    f"{plan.kind!r} plans (no channel axis), got shape "
                    f"{shape}")
            if shape != want:
                raise ValueError(
                    f"ops.{op}: bias epilogue wants {what}, got shape "
                    f"{shape}")
        elif st.op == "residual_add":
            want = tuple(x.shape[:nb]) + (tuple(w.shape[:no]) if no
                                          else ()) + out_sp
            if shape != want:
                raise ValueError(
                    f"ops.{op}: residual_add epilogue wants an "
                    f"output-shaped {want} operand, got shape {shape}")


def _shape_size(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def _check_backend(backend, op: str):
    """Named pre-pallas validation of an op's ``backend=`` kwarg.

    ``None`` defers to :func:`repro.config.engine_backend` at engine
    dispatch time; 'auto'/'tpu'/'gpu' pass through unresolved (the
    engine resolves 'auto' per call) but unknown names fail here with
    the op's name instead of deep inside a jitted engine call."""
    if backend is not None:
        from repro.config import resolve_engine_backend
        try:
            resolve_engine_backend(backend)
        except ValueError as e:
            raise ValueError(f"ops.{op}: {e}") from None
    return backend


def _reject_sharded_residual(epi_stages, mesh) -> None:
    """Shared mesh guard: an output-shaped residual cannot replicate."""
    if mesh is not None and any(s.op == "residual_add" for s in epi_stages):
        raise ValueError(
            "a residual_add epilogue cannot ride a sharded call: the "
            "residual operand is output-shaped and would need the same "
            "sharding; add the residual outside the mesh call")


# ---------------------------------------------------------------------------
# Differentiable engine cores (custom_vjp over adjoint plans)
#
# Every engine-lowered op routes through one of these wrappers. The
# forward is exactly the plan engine (single-device ``run_window_plan``
# or the sharded halo-exchange layer); the backward rule rebuilds the
# *adjoint* plan symbolically (:mod:`repro.core.adjoint`) and lowers it
# through the same engine — point-reflected taps with swapped lead/trail
# for backward-input, the batch+spatial-reduce correlation
# (``run_weight_grad_plan``) for backward-weight, time-reversed scans
# for the scan family. Sharded forward ⇒ sharded backward: the adjoint
# plan's swapped lead/trail reverses the ppermute halo pushes through
# the unchanged halo-exchange layer, and the weight grad psums partial
# filter blocks across the mesh.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _WindowCfg:
    """Static (nondiff) configuration of one windowed engine call."""

    plan: SystolicPlan
    block: tuple[int, ...]
    time_steps: int = 1
    variant: str = "shift_psum"
    interpret: bool = True
    acc_dtype: object = jnp.float32
    mesh: object = None              # jax.sharding.Mesh | None
    in_specs: object = None          # PartitionSpec | None (rule-table default)
    boundary: str = "zero"
    overlap: bool = True
    bwd_tune: tuple | None = None    # tuner context → adjoint tuned on its
    #                                  own plan signature; None → reuse block
    backend: str | None = None       # engine lowering ("tpu"/"gpu"/"auto");
    #                                  None follows config.engine_backend()


def _window_forward(cfg: _WindowCfg, x, w, epi=()):
    if cfg.mesh is not None:
        from repro.distributed import halo_exchange as hx
        return hx.sharded_window_plan(
            x, w, plan=cfg.plan, mesh=cfg.mesh, in_spec=cfg.in_specs,
            block=cfg.block, time_steps=cfg.time_steps, variant=cfg.variant,
            boundary=cfg.boundary, overlap=cfg.overlap,
            interpret=cfg.interpret, acc_dtype=cfg.acc_dtype,
            epilogue_args=epi, backend=cfg.backend)
    return run_window_plan(
        x, w, plan=cfg.plan, block=cfg.block, time_steps=cfg.time_steps,
        variant=cfg.variant, interpret=cfg.interpret, acc_dtype=cfg.acc_dtype,
        epilogue_args=epi, backend=cfg.backend)


def _tuned_adjoint_config(aplan, g_shape, g_dtype, w, cfg: _WindowCfg):
    """Tune the backward-input plan independently of the forward.

    The adjoint is a *different* kernel (its own taps/halo), so it gets
    its own §5 tuner/sidecar signature; measurement runs on zeros of the
    cotangent's (static) shape, which keeps it legal even while the
    backward pass itself is being traced under jit.
    """
    zeros = jnp.zeros(g_shape, g_dtype)
    wa = None if w is None else adj.adjoint_coeff_array(
        cfg.plan, jnp.zeros(w.shape, w.dtype))
    runner = lambda c: tuning.measure_us(lambda: run_window_plan(
        zeros, wa, plan=aplan, block=c.block, time_steps=cfg.time_steps,
        variant=c.variant, interpret=cfg.interpret, acc_dtype=cfg.acc_dtype,
        strategy=c.strategy, backend=cfg.backend))
    res = tuning.autotune(
        aplan, g_shape, time_steps=cfg.time_steps,
        default=tuning.KernelConfig(cfg.block, cfg.variant), runner=runner,
        context=cfg.bwd_tune, backend=cfg.backend)
    return res.config.block, res.config.variant, res.config.strategy


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _window_op(cfg: _WindowCfg, x, w, epi):
    return _window_forward(cfg, x, w, epi)


# custom_vjp rules run at (backward) trace time, so these spans mark
# one adjoint derivation + lowering each, not per-step runtime.
@obs.trace.traced("ops.window_fwd", cat="ops")
def _window_op_fwd(cfg, x, w, epi):
    return _window_forward(cfg, x, w, epi), (x, w, epi)


@obs.trace.traced("ops.window_bwd", cat="ops")
def _window_op_bwd(cfg, res, g):
    x, w, epi = res
    plan = cfg.plan
    if plan.stages:
        return _pipeline_bwd(cfg, x, w, epi, g)
    if cfg.time_steps != 1 and plan.coeff_mode != "table":
        raise ValueError(
            "gradients of temporally-blocked convolutions are not "
            "supported (the weight enters every fused iterate); stencil "
            "plans (compile-time coefficients) differentiate at any "
            "time_steps")
    depi = ()
    if plan.epilogue:
        # The epilogue makes the op affine/nonlinear: recompute the
        # pre-activation z with the *linear* plan, differentiate the
        # elementwise chain there, and feed the remaining cotangent to
        # the linear adjoint plan below (DESIGN.md §11.4).
        lin_plan = dataclasses.replace(plan, epilogue=())
        lin_cfg = dataclasses.replace(cfg, plan=lin_plan)
        z = _window_forward(lin_cfg, x, w, ())
        _, epi_vjp = jax.vjp(
            lambda zz, aa: adj.apply_epilogue(plan, zz, aa), z, epi)
        g, depi = epi_vjp(g.astype(z.dtype))
        plan, cfg = lin_plan, lin_cfg
    if any(v > 1 for v in plan.stride_per_axis()):
        # Transpose of the output-strided grid: scatter the cotangent
        # into the dense output lattice (zeros between kept lanes), then
        # transpose the stride-free plan through the engine as usual.
        dense_plan = dataclasses.replace(plan, stride=None)
        nb, nr = plan.batch_axes, plan.reduce_axes
        dense_out = dense_plan.out_shape(x.shape[nb + nr:], 1)
        lead_nd = g.ndim - plan.ndim_spatial
        gd = jnp.zeros(g.shape[:lead_nd] + dense_out, g.dtype)
        g = gd.at[(slice(None),) * lead_nd + tuple(
            slice(None, None, v) for v in plan.stride_per_axis())].set(g)
        plan = dense_plan
        cfg = dataclasses.replace(cfg, plan=dense_plan)
    if cfg.boundary == "replicate" and cfg.mesh is not None:
        return _replicate_bwd(cfg, plan, x, w, g, depi)
    aplan = adj.input_adjoint_plan(plan)
    block, variant = cfg.block, cfg.variant
    if cfg.bwd_tune is not None and cfg.mesh is None:
        block, variant, astrat = _tuned_adjoint_config(
            aplan, g.shape, g.dtype, w, cfg)
        if astrat is not None:
            # the adjoint is its own kernel: when the forward was auto,
            # the backward tuner picks the adjoint's strategy on the
            # adjoint's own signature (a pinned forward stays pinned —
            # input_adjoint_plan carried the strategy over already)
            aplan = dataclasses.replace(aplan, strategy=astrat)
    acfg = dataclasses.replace(cfg, plan=aplan, block=block, variant=variant,
                               bwd_tune=None)
    adj.record_lowering(aplan.kind)
    dx = _window_forward(acfg, g, adj.adjoint_coeff_array(plan, w))
    dx = dx.astype(x.dtype)
    if w is None or plan.coeff_mode == "table":
        return dx, None, depi
    adj.record_lowering(adj.weight_adjoint_plan(plan).kind)
    wg_block = cfg.block[-2:]
    if cfg.mesh is not None:
        from repro.distributed import halo_exchange as hx
        dw = hx.sharded_weight_grad(
            x, g, plan=plan, mesh=cfg.mesh, in_spec=cfg.in_specs,
            block=wg_block, boundary=cfg.boundary, interpret=cfg.interpret,
            acc_dtype=cfg.acc_dtype)
    else:
        dw = run_weight_grad_plan(
            x, g, plan=plan, block=wg_block, interpret=cfg.interpret,
            acc_dtype=cfg.acc_dtype)
    return dx, dw.astype(w.dtype), depi


def _replicate_bwd(cfg, plan, x, w, g, depi):
    """Backward of a ``boundary='replicate'`` (edge-clamp) sharded call.

    The forward is ``y = V(E x)``: the valid-mode plan ``V`` on the
    edge-extended input ``E x``. The transpose splits cleanly:
    ``dx = Eᵀ(Vᵀ g)``. ``Vᵀ`` is the input adjoint of the valid-mode
    plan — a full-mode kernel whose output lives on the *widened*
    lattice (``N + lead + trail`` rows per axis); that lattice does not
    divide the mesh, so this one backward kernel runs unsharded on the
    gathered cotangent. ``Eᵀ`` then folds the halo bands back onto the
    edge rows they were clamped from
    (:func:`repro.core.adjoint.fold_replicate_edges`). The weight grad
    needs no transpose at all — it is the same correlation against the
    edge-extended input the forward saw — so it reuses the sharded
    halo-exchange correlation with the replicate slabs unchanged.
    """
    valid = dataclasses.replace(plan, lead=None, trail=None)
    aplan = adj.input_adjoint_plan(valid)
    adj.record_lowering(aplan.kind)
    dxp = run_window_plan(
        g, adj.adjoint_coeff_array(valid, w), plan=aplan, block=cfg.block,
        variant=cfg.variant, interpret=cfg.interpret,
        acc_dtype=cfg.acc_dtype, backend=cfg.backend)
    dx = adj.fold_replicate_edges(plan, dxp).astype(x.dtype)
    if w is None or plan.coeff_mode == "table":
        return dx, None, depi
    from repro.distributed import halo_exchange as hx
    adj.record_lowering(adj.weight_adjoint_plan(plan).kind)
    dw = hx.sharded_weight_grad(
        x, g, plan=plan, mesh=cfg.mesh, in_spec=cfg.in_specs,
        block=cfg.block[-2:], boundary=cfg.boundary,
        interpret=cfg.interpret, acc_dtype=cfg.acc_dtype)
    return dx, dw.astype(w.dtype), depi


def _pipeline_bwd(cfg, x, ws, epi, g):
    """Backward of a fused pipeline: stage-by-stage in reverse.

    A purely linear table-coefficient chain transposes to ONE fused
    adjoint kernel (the reversed chain of stage adjoints, DESIGN.md
    §11.4). Chains with epilogues or dense weights recompute the
    pad-once stage inputs/pre-activations forward (engine calls on the
    valid-mode stage plans), then walk the chain backwards: epilogue
    VJPs at the saved pre-activations, per-stage weight-grad
    correlations, and each stage's input-adjoint plan — every linear
    piece lowers through the engine, so training stays on the engine
    path end-to-end.
    """
    plan = cfg.plan
    stages = plan.stages
    if cfg.mesh is not None:
        raise ValueError(
            "gradients of a sharded fused pipeline are not supported yet; "
            "train with fuse=False under a mesh (per-stage sharded "
            "adjoints) or shard the fused forward only")
    if (not any(s.epilogue for s in stages)
            and all(s.coeff_mode == "table" for s in stages)):
        aplan = adj.input_adjoint_plan(plan)        # fused reversed chain
        adj.record_lowering(aplan.kind)
        acfg = dataclasses.replace(cfg, plan=aplan, bwd_tune=None)
        dx = _window_forward(acfg, g, tuple(None for _ in stages), ())
        return dx.astype(x.dtype), tuple(None for _ in stages), ()

    lead, trail = plan.lead_trail()
    nb = plan.batch_axes
    pads = [(0, 0)] * nb + [(l, r) for l, r in zip(lead, trail)]
    h = jnp.pad(x, pads)
    epi_splits = _pipeline_epi_splits(stages, epi)
    hs, zs, valids = [], [], []
    for i, s in enumerate(stages):
        sv = dataclasses.replace(s, lead=None, trail=None, epilogue=())
        w_s = ws[i] if s.coeff_mode == "dense" else None
        hs.append(h)
        valids.append(sv)
        z = run_window_plan(h, w_s, plan=sv, block=cfg.block,
                            variant=cfg.variant, interpret=cfg.interpret,
                            acc_dtype=cfg.acc_dtype, backend=cfg.backend)
        se = dataclasses.replace(sv, epilogue=s.epilogue)
        h = adj.apply_epilogue(se, z, epi_splits[i]).astype(x.dtype)
        zs.append(z)

    depi_parts = [()] * len(stages)
    dws = [None] * len(stages)
    for i in reversed(range(len(stages))):
        s, sv = stages[i], valids[i]
        if s.epilogue:
            se = dataclasses.replace(sv, epilogue=s.epilogue)
            _, epi_vjp = jax.vjp(
                lambda zz, aa, _se=se: adj.apply_epilogue(_se, zz, aa),
                zs[i], epi_splits[i])
            g, depi_parts[i] = epi_vjp(g.astype(zs[i].dtype))
        if s.coeff_mode == "dense":
            adj.record_lowering("wgrad_" + sv.kind)
            dws[i] = run_weight_grad_plan(
                hs[i], g, plan=sv, block=cfg.block[-2:],
                interpret=cfg.interpret,
                acc_dtype=cfg.acc_dtype).astype(ws[i].dtype)
        ap = adj.input_adjoint_plan(sv)     # valid ⇒ full: output grows back
        adj.record_lowering(ap.kind)
        g = run_window_plan(
            g, ws[i] if s.coeff_mode == "dense" else None, plan=ap,
            block=cfg.block, variant=cfg.variant, interpret=cfg.interpret,
            acc_dtype=cfg.acc_dtype, backend=cfg.backend).astype(x.dtype)
    # transpose of the pad-once zero pad: crop the summed lead/trail;
    # epilogue-operand cotangents reassemble in chain order
    depi = tuple(d for part in depi_parts for d in part)
    sl = (slice(None),) * nb + tuple(
        slice(l, l + n) for l, n in zip(lead, x.shape[nb:]))
    return g[sl].astype(x.dtype), tuple(dws), depi


_window_op.defvjp(_window_op_fwd, _window_op_bwd)


# ---------------------------------------------------------------------------
# Guarded dispatch: the degradation lattice (DESIGN.md §16.3)
#
# Every engine-lowered ops.* surface routes its forward call through
# repro.robust.guard with an ordered level list: the tuned/requested
# config first, then the family default block, then the alternate
# lowering (strategy for mxu-pinned plans, the other engine backend
# otherwise), and finally the pure-XLA reference oracle that shares no
# lowering code with the engine. Each step down gives up performance
# before it gives up the engine, and gives up the engine before it
# gives up the answer. Fallback configs are built lazily inside their
# thunks, so the no-failure path pays only closure creation; under
# on_failure='raise' the guard surfaces injected faults as structured
# errors and re-raises organic exceptions (validation ValueErrors etc.)
# completely unchanged.
#
# Scope: the *forward* dispatch is guarded. custom_vjp backward rules
# lower through the same engine but outside the lattice — an adjoint
# failure surfaces under both policies (a silently-demoted gradient
# would be worse than a loud one).
# ---------------------------------------------------------------------------


def _flip_backend(backend) -> str:
    """The other engine lowering: resolve the effective backend, flip it."""
    from repro.config import engine_backend, resolve_engine_backend
    cur = (resolve_engine_backend(backend) if backend is not None
           else engine_backend())
    return "tpu" if cur == "gpu" else "gpu"


def _safe_variant(plan) -> str:
    """The variant the default/alternate levels retreat to: strided grids
    require the data-stationary read; everything else takes shift_psum."""
    return ("shift_data" if any(v > 1 for v in plan.stride_per_axis())
            else "shift_psum")


def _guarded_window(op: str, cfg: _WindowCfg, x, w, epi, oracle=None):
    """One windowed engine call through the §16.3 lattice.

    ``oracle`` is the op's pure-XLA reference closure (same output to
    fp32 tolerance); None drops the level — used where no oracle can
    represent the call (sharded wrap/replicate boundaries). Sharded
    calls with boundary='zero' also get an ``unsharded`` level: the
    halo-exchange layer exists to make the sharded result equal the
    single-device engine, so desharding is an exact fallback when the
    collective itself is what failed.
    """
    if cfg.mesh is not None:
        # configuration errors (sharded reduce axes, non-shape-preserving
        # plans, halo-vs-shard geometry) surface before the lattice: the
        # unsharded/oracle levels drop the mesh and would otherwise
        # "recover" from user misuse by computing something else.
        from repro.distributed import halo_exchange as hx
        hx.validate_sharded_call(x, cfg.plan, cfg.mesh, cfg.in_specs,
                                 time_steps=cfg.time_steps,
                                 boundary=cfg.boundary)

    def default_level():
        c = dataclasses.replace(cfg, block=_default_cfg(cfg.plan).block,
                                variant=_safe_variant(cfg.plan),
                                bwd_tune=None)
        return _window_op(c, x, w, epi)

    def alternate_level():
        c = dataclasses.replace(cfg, block=_default_cfg(cfg.plan).block,
                                variant=_safe_variant(cfg.plan),
                                bwd_tune=None)
        if (c.plan.strategy or "lanes") == "mxu":
            # an mxu lowering bug: retreat to the paper's VPU schedule
            c = dataclasses.replace(
                c, plan=dataclasses.replace(c.plan, strategy="lanes"))
        else:
            c = dataclasses.replace(c, backend=_flip_backend(c.backend))
        return _window_op(c, x, w, epi)

    levels = [
        ("tuned", lambda: _window_op(cfg, x, w, epi)),
        ("default", default_level),
        ("alternate", alternate_level),
    ]
    if cfg.mesh is not None and cfg.boundary == "zero":
        levels.append(("unsharded", lambda: _window_op(
            dataclasses.replace(cfg, mesh=None, in_specs=None), x, w, epi)))
    if oracle is not None and (cfg.mesh is None or cfg.boundary == "zero"):
        levels.append(("oracle", oracle))
    return rguard.run(op, levels)


def _guarded_scan(op: str, cfg: _ScanCfg, call, oracle=None):
    """One scan engine call through the lattice: tuned block → default
    (8, 128) block → the other backend → reference oracle. ``call`` maps
    a (possibly demoted) :class:`_ScanCfg` to the engine invocation, so
    the same helper serves monolithic and chunk-streamed schedules."""
    d = _DEFAULTS["scan"].block
    bt = min(d[1], cfg.chunk) if cfg.chunk else d[1]

    def default_level():
        return call(dataclasses.replace(cfg, block_r=d[0], block_t=bt))

    def alternate_level():
        return call(dataclasses.replace(
            cfg, block_r=d[0], block_t=bt,
            backend=_flip_backend(cfg.backend)))

    levels = [("tuned", lambda: call(cfg)),
              ("default", default_level),
              ("alternate", alternate_level)]
    if oracle is not None:
        levels.append(("oracle", oracle))
    return rguard.run(op, levels)


@dataclasses.dataclass(frozen=True)
class _ScanCfg:
    """Static configuration of one scan-engine call.

    ``chunk`` selects the chunk-streamed schedule (DESIGN.md §12): the
    sequence axis streams through a ``lax.scan`` in ``(R, chunk)`` slabs
    with the inter-chunk carry as the scan state — O(R·chunk) live
    state. ``None`` keeps the monolithic O(R·T) lowering.
    """

    block_r: int = 8
    block_t: int = 128
    interpret: bool = True
    acc_dtype: object = jnp.float32
    chunk: int | None = None
    backend: str | None = None       # engine lowering; None → config default


def _cumsum_run(cfg: _ScanCfg, x):
    return _sc.cumsum(x, block_r=cfg.block_r, block_t=cfg.block_t,
                      interpret=cfg.interpret, acc_dtype=cfg.acc_dtype,
                      backend=cfg.backend)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _cumsum_op(cfg: _ScanCfg, x):
    return _cumsum_run(cfg, x)


@obs.trace.traced("ops.cumsum_fwd", cat="ops")
def _cumsum_op_fwd(cfg, x):
    return _cumsum_run(cfg, x), None


@obs.trace.traced("ops.cumsum_bwd", cat="ops")
def _cumsum_op_bwd(cfg, _, g):
    # (cumsum)ᵀ = the time-reversed scan plan: rev ∘ cumsum ∘ rev.
    adj.record_lowering("adj_scan")
    return (adj.time_reversed(_cumsum_run(cfg, adj.time_reversed(g))),)


_cumsum_op.defvjp(_cumsum_op_fwd, _cumsum_op_bwd)


def _linrec_run(cfg: _ScanCfg, a, b):
    return _sc.linear_recurrence(a, b, block_r=cfg.block_r,
                                 block_t=cfg.block_t,
                                 interpret=cfg.interpret,
                                 acc_dtype=cfg.acc_dtype,
                                 backend=cfg.backend)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _linrec_op(cfg: _ScanCfg, a, b):
    return _linrec_run(cfg, a, b)


@obs.trace.traced("ops.linrec_fwd", cat="ops")
def _linrec_op_fwd(cfg, a, b):
    h = _linrec_run(cfg, a, b)
    return h, (a, h)


@obs.trace.traced("ops.linrec_bwd", cat="ops")
def _linrec_op_bwd(cfg, res, g):
    # λ_t = g_t + a_{t+1}·λ_{t+1}: the same recurrence, time-reversed,
    # with shifted coefficients — lowered through the same scan engine.
    a, h = res
    adj.record_lowering("adj_recurrence")
    abar = adj.reversed_recurrence_coeffs(a)
    lam = adj.time_reversed(_linrec_run(
        cfg, adj.time_reversed(abar), adj.time_reversed(g)))
    da = (lam.astype(jnp.float32)
          * adj.shifted_state(h).astype(jnp.float32)).astype(a.dtype)
    return da, lam.astype(a.dtype)


_linrec_op.defvjp(_linrec_op_fwd, _linrec_op_bwd)


def _linrec_carry_run(cfg: _ScanCfg, a, b, h0):
    return _sc.linear_recurrence(a, b, block_r=cfg.block_r,
                                 block_t=cfg.block_t,
                                 interpret=cfg.interpret,
                                 acc_dtype=cfg.acc_dtype,
                                 carry=h0, return_carry=True,
                                 backend=cfg.backend)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _linrec_carry_op(cfg: _ScanCfg, a, b, h0):
    """One chunk of the streamed recurrence: ``(h, h_T)`` from carry ``h0``."""
    return _linrec_carry_run(cfg, a, b, h0)


@obs.trace.traced("ops.linrec_carry_fwd", cat="ops")
def _linrec_carry_op_fwd(cfg, a, b, h0):
    h, hT = _linrec_carry_run(cfg, a, b, h0)
    return (h, hT), (a, h, h0)


@obs.trace.traced("ops.linrec_carry_bwd", cat="ops")
def _linrec_carry_op_bwd(cfg, res, cts):
    # Chunk-local adjoint (DESIGN.md §12): the carry-out cotangent gc
    # folds into the last in-chunk λ seed (h_T *is* h[:, -1]), the λ
    # recurrence runs reversed through the same engine, and the carry-in
    # cotangent a₀·λ₀ exits as this chunk's gc for the next-older chunk —
    # lax.scan's carry cotangent streams it, no O(T) state saved.
    a, h, h0 = res
    g, gc = cts
    adj.record_lowering("adj_recurrence_chunk")
    g = g.astype(jnp.float32).at[..., -1:].add(
        gc.astype(jnp.float32).reshape(g.shape[:-1] + (1,)))
    abar = adj.reversed_recurrence_coeffs(a)
    lam = adj.time_reversed(_linrec_run(
        cfg, adj.time_reversed(abar), adj.time_reversed(g)))
    da = (lam.astype(jnp.float32)
          * adj.shifted_state(h, h0).astype(jnp.float32)).astype(a.dtype)
    dh0 = adj.chunk_carry_cotangent(a, lam).astype(h0.dtype).reshape(h0.shape)
    return da, lam.astype(a.dtype), dh0


_linrec_carry_op.defvjp(_linrec_carry_op_fwd, _linrec_carry_op_bwd)


def linear_recurrence_carry(a, b, h0, *, impl: str | None = None, **kw):
    """``h_t = a_t·h_{t−1} + b_t`` over (R, T) rows with explicit carry.

    Returns ``(h, h_T)`` where ``h_T`` is the final raw state ``(R, 1)``;
    ``h0`` is ``(R,)`` or ``(R, 1)``. This is the per-chunk engine
    primitive of the streamed schedule (DESIGN.md §12): differentiable
    both through ``h`` and through the carry pair, so ``lax.scan`` over
    chunks composes the λ-recurrence across chunk boundaries for free.
    """
    _reject_scan_kwargs("linear_recurrence_carry", kw)
    impl = impl or default_engine_impl()
    interpret = _interp(impl)
    cfg = _scan_cfg(kw, interpret=interpret, op="linear_recurrence_carry")
    h0c = h0.reshape(a.shape[0], 1)

    def oracle():
        # fold the carry into the first step: h_1 = a_1·h_0 + b_1
        b2 = b.at[:, :1].add(a[:, :1] * h0c)
        h = ref.linear_recurrence(a, b2)
        return h, h[:, -1:]

    return _guarded_scan("linear_recurrence_carry",
                         dataclasses.replace(cfg, chunk=None),
                         lambda c: _linrec_carry_op(c, a, b, h0c), oracle)


def _linrec_stream(cfg: _ScanCfg, a, b):
    """Stream ``(R, T)`` rows through ``(R, chunk)`` engine slabs.

    ``lax.scan`` carries the per-row state between chunks; the body is
    ``jax.checkpoint``-wrapped so reverse-mode saves only the O(T/chunk)
    chunk-boundary carries and re-runs each chunk's engine kernel to
    recover in-chunk state — both directions engine-lowered, peak live
    state O(R·chunk).
    """
    R, T = a.shape
    chunk = cfg.chunk
    nc = -(-T // chunk)
    pad = ((0, 0), (0, nc * chunk - T))
    ap = jnp.pad(a, pad, constant_values=1)   # identity transfers in the tail
    bp = jnp.pad(b, pad)
    inner = dataclasses.replace(cfg, chunk=None)

    def body(c, i):
        asl = jax.lax.dynamic_slice_in_dim(ap, i * chunk, chunk, 1)
        bsl = jax.lax.dynamic_slice_in_dim(bp, i * chunk, chunk, 1)
        h, c_new = _linrec_carry_op(inner, asl, bsl, c)
        return c_new, h

    c0 = jnp.zeros((R, 1), a.dtype)
    _, hs = jax.lax.scan(jax.checkpoint(body), c0, jnp.arange(nc))
    return jnp.moveaxis(hs, 0, 1).reshape(R, nc * chunk)[:, :T]


def _shard_tuning_call(plan, x, mesh, in_specs, time_steps, boundary):
    """(shape, context) the sharded autotune must target: the per-device
    halo-extended block, keyed so winners never leak across meshes or
    boundary modes. For batched plans the leading batch axes shrink to
    their per-shard extent (reduce axes are never sharded)."""
    from repro.distributed import halo_exchange as hx
    spec = in_specs if in_specs is not None else \
        hx.default_plan_spec(plan, x.shape, mesh)
    nb, nr = plan.batch_axes, plan.reduce_axes
    assigns = hx._axis_assignments(spec, mesh, nb + nr + plan.ndim_spatial)
    spatial = tuning.shard_tuning_shape(
        plan, x.shape[nb + nr:], assigns[nb + nr:], time_steps, boundary)
    shape = tuple(
        n // (a[1] if a else 1)
        for n, a in zip(x.shape[:nb], assigns[:nb])
    ) + x.shape[nb:nb + nr] + spatial
    return shape, ("sharded", boundary) + tuple(
        f"{a[0]}:{a[1]}" if a else "-" for a in assigns)


def _tuned_kwargs(plan, shape, call, user_kw, *, time_steps: int = 1,
                  context: tuple = (), chunked: bool = False,
                  default=None, backend=None) -> dict:
    """Autotune block kwargs for ``call``; explicit user kwargs win.

    The cache context carries everything that changes what the runner
    measures beyond (plan, shape): op mode/impl and any caller-forced
    kwargs — without it a winner measured under one context would be
    silently replayed under another. ``chunked=True`` tunes the streamed
    scan schedule: candidates grow the chunk-length dimension
    (``(BR, BT, chunk)``, DESIGN.md §12).
    """
    runner = lambda cfg: tuning.measure_us(
        lambda: call(**{**cfg.as_kwargs(plan), **user_kw}))
    res = tuning.autotune(plan, shape, time_steps=time_steps,
                          default=default or _default_cfg(plan),
                          runner=runner,
                          context=context + tuple(sorted(user_kw.items())),
                          fixed=user_kw, chunked=chunked, backend=backend)
    return {**res.config.as_kwargs(plan), **user_kw}


def _conv2d_grouped(x, w, *, groups, mode, impl, autotune, mesh, stride,
                    epi_stages, epi_args, strategy, backend, kw):
    """Grouped NCHW conv as per-group reduce slices (ISSUE 7 satellite).

    Each group is an ordinary reduce-axes conv on its
    ``(C_in/groups, C_out/groups)`` operand slice — every group lowers
    the *same* plan signature, so the tuner measures group 0 and replays
    the winner for the rest — and the group outputs concatenate along
    C_out. Per-C_out epilogue operands (a bias row, a residual) slice
    along the same axis. ``groups == C_in`` is depthwise-2d.
    """
    if x.ndim != 4:
        raise ValueError(
            f"conv2d: groups={groups} needs a 4-D NCHW input against an "
            f"OIHW filter (grouped channels), got a {x.ndim}-D input")
    if w.ndim != 4:
        raise ValueError(
            f"conv2d: groups={groups} needs an OIHW "
            f"(C_out, C_in/groups, N, M) filter, got w shape "
            f"{tuple(w.shape)}")
    if mesh is not None:
        raise ValueError(
            "sharded grouped conv2d is not supported: each group is its "
            "own engine call and would need its own halo exchange; run "
            "groups under pjit with impl='xla', or shard with groups=1")
    # the plan builder owns the named divisibility checks (pre-pallas)
    plan = _c2.plan_for_nchw(x.shape, w.shape, mode, groups)
    if impl == "xla":
        y = ref.conv2d_nchw(x, w, mode, groups)
        if stride is not None:
            y = y[..., ::stride[0], ::stride[1]]
        if epi_stages:
            y = adj.apply_epilogue(
                dataclasses.replace(plan, epilogue=epi_stages), y, epi_args)
        return y
    Cg = x.shape[1] // groups
    Og = w.shape[0] // groups
    op_stages = epilogue_operand_stages(epi_stages)
    outs = []
    for g in range(groups):
        args_g = tuple(
            arr[g * Og:(g + 1) * Og]
            if (st.op == "bias" and getattr(arr, "ndim", 0) == 1)
            else (arr[:, g * Og:(g + 1) * Og] if st.op == "residual_add"
                  else arr)
            for st, arr in zip(op_stages, epi_args))
        outs.append(conv2d(
            x[:, g * Cg:(g + 1) * Cg], w[g * Og:(g + 1) * Og], mode=mode,
            impl=impl, autotune=autotune, stride=stride,
            epilogue=epi_stages, epilogue_args=args_g, strategy=strategy,
            backend=backend, **kw))
    return jnp.concatenate(outs, axis=1)


def conv2d(x, w, *, mode: str = "same", impl: str | None = None,
           autotune: bool = False, mesh=None, in_specs=None,
           boundary: str = "zero", stride=None, epilogue=None,
           epilogue_args=(), strategy: str | None = None, groups: int = 1,
           backend: str | None = None, **kw):
    """2-D convolution, dispatched on input rank:

    * ``(H, W)``            — single image, single channel (the paper's
      Listing 1 plan).
    * ``(B, H, W)``         — minibatch of single-channel images against
      one ``(N, M)`` filter (block-1 batch grid axis).
    * ``(B, C_in, H, W)``   — NCHW minibatch against an OIHW
      ``(C_out, C_in, N, M)`` filter through the reduce-axes plan: the
      engine grid iterates batch × C_out × spatial × C_in with an fp32
      accumulator across the channel reduction — no Python loop over
      batch or channels.

    ``stride=(sh, sw)`` lowers an **output-strided grid**: the kernel
    computes only every ``s``-th output lane instead of the dense result
    a subsample would discard (DESIGN.md §11.3). ``epilogue=`` fuses
    elementwise output stages (``bias``/``gelu``/``silu``/``relu``/
    ``scale``/``residual_add``) into the kernel between the accumulator
    flush and the output store; runtime operands (a per-C_out bias row,
    a residual) ride in ``epilogue_args``. Both key the tuner cache
    apart automatically (the plan signature carries them).

    ``strategy=`` pins the engine's lowering for the tap-set contraction
    ('lanes' — the paper's VPU shift schedule — or 'mxu', the im2row
    dot_general of DESIGN.md §13); the default/'auto' leaves the choice
    to the autotuner (falling back to 'lanes' untuned). ``groups=``
    (4-D NCHW only) runs a grouped convolution as per-group reduce
    slices — ``groups == C_in`` is depthwise-2d — with an OIHW filter of
    shape ``(C_out, C_in/groups, N, M)``, matching ``lax``'s
    ``feature_group_count``.

    Tuner contexts carry the rank tag and the full operand shape, so
    batched/NCHW winners never collide with single-image winners in the
    cache or the JSON sidecar.

    ``backend=`` selects the engine *lowering* of the plan ('tpu' — the
    sublane/lane tiling — or 'gpu' — the §14 warp-shuffle tiling;
    'auto' follows the jax platform, ``None`` the
    ``repro.config.engine_backend()`` session default). Orthogonal to
    ``impl``: interpret-mode runs either lowering on any host. Tuned
    winners are cached and sidecar'd per backend (DESIGN.md §14).
    """
    impl = impl or default_impl()
    backend = _check_backend(backend, "conv2d")
    epi_stages, epi_args = _epilogue_spec(epilogue, epilogue_args, "conv2d")
    if stride is not None:
        stride = (stride, stride) if isinstance(stride, int) else tuple(stride)
        if len(stride) != 2 or any(int(v) != v or v < 1 for v in stride):
            raise ValueError(f"conv2d: stride must be two ints >= 1, "
                             f"got {stride}")
        stride = tuple(int(v) for v in stride)
        if stride == (1, 1):
            stride = None
    if mesh is not None and stride is not None:
        raise ValueError(
            "sharded strided conv2d is not supported: an output stride "
            "breaks shape preservation, so shards would not own equal "
            "input and output slices; subsample after the sharded call")
    _reject_sharded_residual(epi_stages, mesh)
    if int(groups) != groups or groups < 1:
        raise ValueError(f"conv2d: groups must be an int >= 1, got {groups}")
    if groups != 1:
        return _conv2d_grouped(
            x, w, groups=int(groups), mode=mode, impl=impl,
            autotune=autotune, mesh=mesh, stride=stride,
            epi_stages=epi_stages, epi_args=epi_args, strategy=strategy,
            backend=backend, kw=kw)
    if x.ndim == 4:
        if w.ndim != 4:
            raise ValueError(
                f"conv2d on a 4-D NCHW input needs an OIHW "
                f"(C_out, C_in, N, M) filter, got w shape {tuple(w.shape)}")
        tag = "conv2d_nchw"
        ref_fn = lambda xx, m: ref.conv2d_nchw(xx, w, m)
        plan_fn = lambda: _c2.plan_for_nchw(x.shape, w.shape, mode)
        kernel = lambda xs, **k: _c2.conv2d_nchw(xs, w, mode=mode, **k)
    elif x.ndim == 3:
        if w.ndim != 2:
            raise ValueError(
                f"conv2d on a 3-D (B, H, W) stack needs a 2-D (N, M) "
                f"filter, got w shape {tuple(w.shape)}; for a multi-channel "
                "minibatch pass a 4-D NCHW input with an OIHW filter")
        tag = "conv2d_batched"
        ref_fn = lambda xx, m: ref.conv2d_batched(xx, w, m)
        plan_fn = lambda: _c2.plan_for_batched(w.shape, mode)
        kernel = lambda xs, **k: _c2.conv2d_batched(xs, w, mode=mode, **k)
    else:
        tag = "conv2d"
        ref_fn = lambda xx, m: (ref.conv2d_same(xx, w) if m == "same"
                                else ref.conv2d_valid(xx, w))
        plan_fn = lambda: _c2.plan_for(w.shape, mode)
        kernel = lambda xs, **k: (
            _c2.conv2d_same(xs, w, **k) if mode == "same"
            else _c2.conv2d_valid(xs, w, **k))
    plan = _strategy_plan(plan_fn(), strategy, "conv2d")
    if stride is not None or epi_stages:
        plan = dataclasses.replace(plan, stride=stride, epilogue=epi_stages)
        _check_epilogue_operands(plan, epi_args, "conv2d", x, w)
    if impl == "xla":
        if mesh is not None:
            raise ValueError("mesh= needs the engine path; the 'xla' oracle "
                             "is already shardable under pjit")
        y = ref_fn(x, mode)
        if stride is not None:
            y = y[..., ::stride[0], ::stride[1]]
        if epi_stages:
            y = adj.apply_epilogue(plan, y, epi_args)
        return y

    def oracle():
        # the impl='xla' branch above, as the lattice's level of last
        # resort — stride subsample + epilogue replay included
        y = ref_fn(x, mode)
        if stride is not None:
            y = y[..., ::stride[0], ::stride[1]]
        if epi_stages:
            y = adj.apply_epilogue(plan, y, epi_args)
        return y

    return _conv2d_engine(x, w, plan=plan, kernel=kernel, tag=tag,
                          mode=mode, impl=impl, autotune=autotune, mesh=mesh,
                          in_specs=in_specs, boundary=boundary, kw=kw,
                          epi_args=epi_args, backend=backend, oracle=oracle)


def _window_cfg(plan, kw, *, interpret, time_steps=1, mesh=None,
                in_specs=None, boundary="zero", bwd_tune=None,
                backend=None) -> _WindowCfg:
    """Resolve family kwargs into the static config of one engine call."""
    block, variant, rest = _engine_block(plan, kw)
    # a tuned winner (or an explicit caller) may carry the lowering
    # strategy as a kwarg — it pins the plan IR, like ``stride=`` does
    plan = _strategy_plan(plan, rest.pop("strategy", None), plan.kind)
    cfg = _WindowCfg(
        plan=plan, block=block, variant=variant, interpret=interpret,
        time_steps=rest.pop("time_steps", time_steps),
        acc_dtype=rest.pop("acc_dtype", jnp.float32),
        mesh=mesh, in_specs=in_specs, boundary=boundary,
        overlap=rest.pop("overlap", True), bwd_tune=bwd_tune,
        backend=rest.pop("backend", backend))
    if rest:
        raise TypeError(f"unexpected kwargs for {plan.kind!r}: "
                        f"{sorted(rest)}")
    return cfg


def _conv2d_engine(x, w, *, plan, kernel, tag, mode, impl, autotune, mesh,
                   in_specs, boundary, kw, epi_args=(), backend=None,
                   oracle=None):
    """Shared mesh/autotune scaffolding for every conv2d rank.

    ``kernel(xs, interpret=..., **block_kwargs)`` lowers the engine call
    on ``xs`` for tuning measurements; ``plan`` is its schedule; ``tag``
    keys the tuner context. Plans carrying a stride or an epilogue are
    measured through the generic :func:`_engine_runner` instead — the
    thin wrappers would rebuild the plan without them. The actual call
    goes through the differentiable ``_window_op`` core, so ``jax.grad``
    of any conv2d rank lowers its backward pass through the adjoint
    plans.
    """
    interpret = _interp(impl)
    plain = not plan.epilogue and plan.stride is None
    # a pinned strategy must reach the thin measurement wrappers too —
    # they rebuild the plan from kwargs (candidates restate the pin, but
    # the family *default* config carries none)
    pin = {"strategy": plan.strategy} if plan.strategy else {}
    if mesh is not None:
        if mode != "same":
            raise ValueError(
                "sharded conv2d supports mode='same' only: 'valid' shrinks "
                "the domain, so shards would not own equal output slices")
        if autotune:
            shape, sctx = _shard_tuning_call(plan, x, mesh, in_specs, 1,
                                             boundary)
            zeros = jnp.zeros(shape, x.dtype)
            sharded_kw = {k: kw.pop(k) for k in ("overlap",) if k in kw}
            call = (lambda **k: kernel(zeros, interpret=interpret,
                                       backend=backend, **{**pin, **k})) \
                if plain else _engine_runner(plan, zeros, w, interpret,
                                             epi_args=epi_args,
                                             backend=backend)
            kw = _tuned_kwargs(plan, shape, call, kw,
                               context=(tag, mode, impl) + sctx,
                               backend=backend)
            kw.update(sharded_kw)
        cfg = _window_cfg(plan, kw, interpret=interpret, mesh=mesh,
                          in_specs=in_specs, boundary=boundary,
                          backend=backend)
        return _guarded_window(tag, cfg, x, w, epi_args, oracle)
    bwd_tune = None
    if autotune:
        call = (lambda **k: kernel(x, interpret=interpret, backend=backend,
                                   **{**pin, **k})) \
            if plain else _engine_runner(plan, x, w, interpret,
                                         epi_args=epi_args, backend=backend)
        kw = _tuned_kwargs(plan, x.shape, call, kw, context=(tag, mode, impl),
                           backend=backend)
        bwd_tune = ("adjoint", tag, mode, impl)
    return _guarded_window(tag, _window_cfg(plan, kw, interpret=interpret,
                                            bwd_tune=bwd_tune,
                                            backend=backend),
                           x, w, epi_args, oracle)


def conv1d_causal(x, w, *, impl: str | None = None, autotune: bool = False,
                  epilogue=None, epilogue_args=(), strategy: str | None = None,
                  backend: str | None = None, **kw):
    """Depthwise causal conv through the D-optimal plan (§5.4).

    ``epilogue=`` fuses elementwise output stages into the kernel —
    ``bias`` takes a per-channel ``(D,)`` row (channels are the plan's
    lanes), which is exactly Mamba's ``conv → +b → silu`` seam without
    the HBM round-trip between the conv and the activation.
    """
    impl = impl or default_impl()
    backend = _check_backend(backend, "conv1d_causal")
    if w.shape[-1] != x.shape[-1]:
        # checked for every impl — the oracle would otherwise silently
        # broadcast a mismatched filter across channels
        raise ValueError(f"conv1d_causal: filter lanes {w.shape} do not "
                         f"match input channels {x.shape}")
    epi_stages, epi_args = _epilogue_spec(epilogue, epilogue_args,
                                          "conv1d_causal")
    plan = _strategy_plan(_c1.plan_for(w.shape[0]), strategy,
                          "conv1d_causal")
    if epi_stages:
        plan = dataclasses.replace(plan, epilogue=epi_stages)
        _check_epilogue_operands(plan, epi_args, "conv1d_causal", x)
    if impl == "xla":
        y = ref.conv1d_causal(x, w)
        return adj.apply_epilogue(plan, y, epi_args) if epi_stages else y
    interpret = _interp(impl)
    bwd_tune = None
    if autotune:
        pin = {"strategy": plan.strategy} if plan.strategy else {}
        call = (lambda **k: _c1.conv1d_causal(x, w, interpret=interpret,
                                              backend=backend,
                                              **{**pin, **k})) \
            if not epi_stages else _engine_runner(plan, x, w, interpret,
                                                  epi_args=epi_args,
                                                  backend=backend)
        kw = _tuned_kwargs(plan, x.shape, call, kw, context=("conv1d", impl),
                           backend=backend)
        bwd_tune = ("adjoint", "conv1d", impl)
    plan = _strategy_plan(plan, kw.pop("strategy", None), "conv1d_causal")
    d = _DEFAULTS["conv1d"].block
    cfg = _WindowCfg(
        plan=plan, block=(kw.pop("block_t", d[0]), kw.pop("block_d", d[1])),
        interpret=interpret, acc_dtype=kw.pop("acc_dtype", jnp.float32),
        bwd_tune=bwd_tune, backend=backend)
    if kw:
        raise TypeError(f"unexpected kwargs for conv1d_causal: {sorted(kw)}")

    def oracle():
        y = ref.conv1d_causal(x, w)
        return adj.apply_epilogue(plan, y, epi_args) if epi_stages else y

    return _guarded_window("conv1d_causal", cfg, x, w, epi_args, oracle)


def stencil(x, sdef: StencilDef | str, *, time_steps: int = 1,
            impl: str | None = None, autotune: bool = False, mesh=None,
            in_specs=None, boundary: str = "zero", epilogue=None,
            epilogue_args=(), strategy: str | None = None,
            backend: str | None = None, **kw):
    impl = impl or default_impl()
    backend = _check_backend(backend, "stencil")
    if isinstance(sdef, str):
        sdef = BENCHMARKS[sdef]
    epi_stages, epi_args = _epilogue_spec(epilogue, epilogue_args, "stencil")
    _reject_sharded_residual(epi_stages, mesh)
    mod = _s2 if sdef.ndim == 2 else _s3
    fn = mod.stencil2d if sdef.ndim == 2 else mod.stencil3d
    plan = _strategy_plan(mod.plan_for(sdef), strategy, "stencil")
    if epi_stages:
        plan = dataclasses.replace(plan, epilogue=epi_stages)
        _check_epilogue_operands(plan, epi_args, "stencil", x,
                                 time_steps=time_steps)
    if impl == "xla":
        if mesh is not None:
            raise ValueError("mesh= needs the engine path; the 'xla' oracle "
                             "is already shardable under pjit")
        y = ref.stencil_iterate(x, sdef, time_steps)
        return adj.apply_epilogue(plan, y, epi_args) if epi_stages else y
    interpret = _interp(impl)
    pin = {"strategy": plan.strategy} if plan.strategy else {}

    def oracle():
        y = ref.stencil_iterate(x, sdef, time_steps)
        return adj.apply_epilogue(plan, y, epi_args) if epi_stages else y

    if mesh is not None:
        if autotune:
            shape, sctx = _shard_tuning_call(plan, x, mesh, in_specs,
                                             time_steps, boundary)
            zeros = jnp.zeros(shape, x.dtype)
            # tune with the single-device engine on a shard-shaped block;
            # sharded-layer-only kwargs stay out of the measured closure
            sharded_kw = {k: kw.pop(k) for k in ("overlap",) if k in kw}
            call = (lambda **k: fn(zeros, sdef, time_steps=time_steps,
                                   interpret=interpret, backend=backend,
                                   **{**pin, **k})) \
                if not epi_stages else _engine_runner(
                    plan, zeros, None, interpret, epi_args=epi_args,
                    time_steps=time_steps, backend=backend)
            kw = _tuned_kwargs(plan, shape, call, kw, time_steps=time_steps,
                               context=("stencil", impl) + sctx,
                               backend=backend)
            kw.update(sharded_kw)
        cfg = _window_cfg(plan, kw, interpret=interpret,
                          time_steps=time_steps, mesh=mesh,
                          in_specs=in_specs, boundary=boundary,
                          backend=backend)
        return _guarded_window("stencil", cfg, x, None, epi_args, oracle)
    bwd_tune = None
    if autotune:
        call = (lambda **k: fn(x, sdef, time_steps=time_steps,
                               interpret=interpret, backend=backend,
                               **{**pin, **k})) \
            if not epi_stages else _engine_runner(
                plan, x, None, interpret, epi_args=epi_args,
                time_steps=time_steps, backend=backend)
        kw = _tuned_kwargs(plan, x.shape, call, kw, time_steps=time_steps,
                           context=("stencil", impl), backend=backend)
        bwd_tune = ("adjoint", "stencil", impl)
    return _guarded_window(
        "stencil",
        _window_cfg(plan, kw, interpret=interpret, time_steps=time_steps,
                    bwd_tune=bwd_tune, backend=backend),
        x, None, epi_args, oracle)


# ---------------------------------------------------------------------------
# Fused plan pipelines: ops.pipeline (DESIGN.md §11)
# ---------------------------------------------------------------------------

def _pipeline_stage_plan(x, desc, idx: int):
    """Resolve one pipeline stage descriptor → (plan, w_or_None).

    A descriptor is a Table-3 name / :class:`StencilDef` (table-coeff
    stencil stage), a 2-D filter array (dense 'same'-mode conv stage),
    or a ``(descriptor, epilogue)`` pair attaching elementwise stages
    after it. Stages apply over the domain's *trailing* spatial axes:
    a 2-D stage on a ``(B, H, W)`` stack or ``(B, C, H, W)`` NCHW
    tensor (and a 3-D stage on a batched volume) rides the extra
    leading axes as block-1 batch grid axes — the fused chain stays
    one engine kernel per batch item, no Python loop. Anything else —
    scan ops, OIHW reduce filters — gets a named pre-pallas
    ``ValueError`` (a channel *reduction* still cannot chain-fuse: the
    next stage may only read the summed output after the full
    accumulator sweep).
    """
    epilogue = None
    if (isinstance(desc, tuple) and len(desc) == 2
            and isinstance(desc[0], (str, StencilDef, jax.Array))):
        desc, epilogue = desc
    if isinstance(desc, str):
        if desc not in BENCHMARKS:
            raise ValueError(
                f"ops.pipeline: stage {idx} names unknown stencil "
                f"{desc!r}; known Table-3 stencils: "
                f"{sorted(BENCHMARKS)}")
        desc = BENCHMARKS[desc]
    if isinstance(desc, StencilDef):
        if desc.ndim > x.ndim:
            raise ValueError(
                f"ops.pipeline: stage {idx} ({desc.name}) is "
                f"{desc.ndim}-D but the domain is {x.ndim}-D")
        mod = _s2 if desc.ndim == 2 else _s3
        plan, w = mod.plan_for(desc), None
        if x.ndim > desc.ndim:
            plan = dataclasses.replace(plan, batch_axes=x.ndim - desc.ndim)
    elif isinstance(desc, jax.Array) or hasattr(desc, "ndim"):
        if desc.ndim == 4:
            raise ValueError(
                f"ops.pipeline: stage {idx} is an OIHW (NCHW conv) "
                "filter — reduce plans cannot chain-fuse (the channel "
                "reduction must finish its accumulator sweep first); "
                "run ops.conv2d / nn.layers.conv2d_apply with a fused "
                "epilogue= instead")
        if desc.ndim != 2 or x.ndim < 2:
            raise ValueError(
                f"ops.pipeline: stage {idx} filter must be a 2-D (N, M) "
                f"array on a >= 2-D domain, got filter "
                f"{tuple(desc.shape)} on a {x.ndim}-D domain")
        plan, w = _c2.plan_for(desc.shape, "same"), desc
        if x.ndim > 2:
            plan = dataclasses.replace(plan, batch_axes=x.ndim - 2)
    else:
        raise ValueError(
            f"ops.pipeline: stage {idx} descriptor {type(desc).__name__} "
            "is not a stencil name/StencilDef/2-D filter array; scan ops "
            "(cumsum/linear_recurrence) cannot sit in a spatial chain")
    if epilogue is not None:
        plan = dataclasses.replace(plan,
                                   epilogue=normalize_epilogue(epilogue))
    return plan, w


def _pipeline_epi_splits(plans, epi_args):
    """Split chain-ordered ``epilogue_args`` into per-stage tuples, one
    per plan, in application order (DESIGN.md §11)."""
    out, off = [], 0
    for p in plans:
        k = len(epilogue_operand_stages(p.epilogue))
        out.append(tuple(epi_args[off:off + k]))
        off += k
    return out


def _pipeline_ref(x, plans, ws, epi_args):
    """Pure-jnp oracle of a pipeline: pad-once, then valid stage
    applications (each stage's dense filter materialized from its taps)
    with the stage epilogues replayed elementwise. The gradcheck
    reference for fused backward. Leading batch axes flatten into the
    conv's N dimension — stages convolve the trailing spatial axes per
    batch item exactly as the engine's block-1 batch grid does."""
    import numpy as np
    from repro.core.fuse import summed_lead_trail
    lead, trail = summed_lead_trail(plans)
    nb, nd = plans[0].batch_axes, plans[0].ndim_spatial
    splits = _pipeline_epi_splits(plans, epi_args)
    h = jnp.pad(x, [(0, 0)] * nb + list(zip(lead, trail)))
    h = h.astype(jnp.float32)
    for i, p in enumerate(plans):
        if p.coeff_mode == "dense":
            f = ws[i].astype(jnp.float32)
        else:
            fa = np.zeros(p.exts, np.float32)
            for off, cid in adj.iter_tap_offsets(p):
                fa[off] = p.coeffs[cid[-1]]
            f = jnp.array(fa)
        batch = h.shape[:nb]
        hb = h.reshape((-1, 1) + h.shape[nb:])     # (B_flat, C=1, *spatial)
        if nd == 2:
            hb = jax.lax.conv_general_dilated(
                hb, f[None, None], (1, 1), "VALID")
        else:
            hb = jax.lax.conv_general_dilated(
                hb, f[None, None], (1, 1, 1), "VALID",
                dimension_numbers=("NCDHW", "OIDHW", "NCDHW"))
        h = hb.reshape(batch + hb.shape[2:])
        h = adj.apply_epilogue(p, h, splits[i])
    return h.astype(x.dtype)


def pipeline(x, stages, *, impl: str | None = None, autotune: bool = False,
             fuse="auto", epilogue_args=(), mesh=None, in_specs=None,
             boundary: str = "zero", strategy: str | None = None,
             backend: str | None = None, **kw):
    """Run a chain of shape-preserving windowed ops as ONE fused engine
    kernel — partial activations between stages never leave VMEM
    (DESIGN.md §11).

    ``stages`` is a list of stage descriptors applied left to right:
    Table-3 stencil names / :class:`StencilDef`\\ s, 2-D 'same'-mode
    conv filters, each optionally paired with an epilogue as
    ``(stage, "gelu")``. Stages apply over the domain's trailing
    spatial axes: on a batched ``(B, H, W)`` stack or an NCHW
    ``(B, C, H, W)`` tensor the extra leading axes ride the engine
    grid as block-1 batch axes, so the chain stays fused per item. Mid-chain epilogues must fix zero (preserving
    the pad-once boundary) or be a *scalar* ``bias``; the final stage
    may also take ``residual_add``. ``epilogue_args`` carries the
    operands of every operand-bearing stage in application (chain)
    order — mid-chain biases first, the final stage's operands last.

    Semantics are pad-once (trapezoidal), shared with temporal blocking:
    zero-pad once by the summed stage leads/trails, then apply the
    stages as valid windows — identical to a chain of same-shape per-op
    calls on the interior at distance > Σ radius from the boundary.

    ``fuse``: ``'auto'`` (default) fuses when the chain qualifies and
    silently falls back to the unfused pad-once sequence otherwise;
    ``True`` raises the named legality error instead of falling back;
    ``False`` forces the unfused sequence (one engine call per stage —
    the HBM-round-trip baseline the benchmarks compare against).

    Under ``mesh=`` the *fused* chain runs through the halo-exchange
    layer with one chain-widened halo per call; the unfused fallback
    cannot shard (its stages are valid-mode plans, not shape-preserving).
    """
    impl = impl or default_impl()
    backend = _check_backend(backend, "pipeline")
    if fuse not in (True, False, "auto"):
        raise ValueError(f"ops.pipeline: fuse must be True/False/'auto', "
                         f"got {fuse!r}")
    if not stages:
        raise ValueError("ops.pipeline needs at least one stage")
    resolved = [_pipeline_stage_plan(x, d, i) for i, d in enumerate(stages)]
    nd0 = resolved[0][0].ndim_spatial
    for i, (p, _) in enumerate(resolved):
        if p.ndim_spatial != nd0:
            raise ValueError(
                f"ops.pipeline: stage {i} is {p.ndim_spatial}-D but stage "
                f"0 is {nd0}-D; on a batched domain every stage must "
                "window the same trailing spatial axes")
    # one strategy for the whole chain: every stage shares the VMEM tile,
    # so the pin rides each stage plan and fuse_plans carries it onto
    # the composite (stages keep their own copy for the unfused path)
    plans = [_strategy_plan(p, strategy, "pipeline") for p, _ in resolved]
    ws = tuple(w for _, w in resolved)
    need = [s.op for p in plans for s in epilogue_operand_stages(p.epilogue)]
    if len(tuple(epilogue_args)) != len(need):
        raise ValueError(
            f"ops.pipeline: the chain's epilogues need {len(need)} runtime "
            f"operand(s) ({need}, application order) in epilogue_args, got "
            f"{len(tuple(epilogue_args))}")
    epi_args = tuple(epilogue_args)
    epi_splits = _pipeline_epi_splits(plans, epi_args)
    for i, p in enumerate(plans[:-1]):
        bad = [s.op for s in epilogue_operand_stages(p.epilogue)
               if s.op != "bias"]
        if bad:
            raise ValueError(
                f"ops.pipeline: stage {i} carries a residual_add epilogue "
                "mid-chain; the residual operand is output-shaped and "
                "would materialize the intermediate it skips — only bias "
                "may sit mid-chain, residual_add goes on the final stage")
        for arr in epi_splits[i]:
            if _shape_size(tuple(getattr(arr, "shape", ()))) != 1:
                raise ValueError(
                    f"ops.pipeline: stage {i}'s mid-chain bias must be a "
                    "scalar (it applies to the whole pad-once "
                    "intermediate), got shape "
                    f"{tuple(getattr(arr, 'shape', ()))}")
    if plans[-1].epilogue:
        # pipeline stages are shape-preserving, so the final stage's own
        # layout validates its epilogue operands (named errors)
        _check_epilogue_operands(plans[-1], epi_splits[-1], "pipeline", x)
    if impl == "xla":
        if mesh is not None:
            raise ValueError("mesh= needs the engine path; the 'xla' oracle "
                             "is already shardable under pjit")
        return _pipeline_ref(x, plans, ws, epi_args)
    interpret = _interp(impl)

    fused_plan, fuse_err = None, None
    try:
        fused_plan = fuse_plans(*plans)
    except ValueError as e:
        fuse_err = e
    if fuse is True and fused_plan is None:
        raise fuse_err
    if fuse == "auto" and fused_plan is None or fuse is False:
        if mesh is not None:
            raise ValueError(
                "an unfused pipeline cannot shard: its stages are "
                "valid-mode (pad-once) plans, not shape-preserving; fuse "
                "the chain or run per-op ops.stencil calls under the mesh")
        # Unfused fallback: identical pad-once math, one engine call —
        # and one full HBM round-trip of the activation — per stage.
        # The lattice wraps the whole sequence (a per-stage lattice would
        # fall back stage-by-stage into mixed lowerings): any stage
        # failure retreats to the pure-XLA chain oracle.
        def unfused():
            from repro.core.fuse import summed_lead_trail
            lead, trail = summed_lead_trail(plans)
            h = jnp.pad(x, [(0, 0)] * plans[0].batch_axes
                        + list(zip(lead, trail)))
            for i, p in enumerate(plans):
                pv = dataclasses.replace(p, lead=None, trail=None)
                a = epi_splits[i]
                skw = dict(kw)
                if autotune:
                    skw = _tuned_kwargs(
                        pv, h.shape,
                        _engine_runner(pv, h, ws[i], interpret, epi_args=a,
                                       backend=backend),
                        skw, context=("pipeline_stage", i, impl),
                        backend=backend)
                cfg = _window_cfg(pv, skw, interpret=interpret,
                                  backend=backend)
                h = _window_op(cfg, h, ws[i], a)
            return h

        return rguard.run("pipeline", [
            ("unfused", unfused),
            ("oracle", lambda: _pipeline_ref(x, plans, ws, epi_args))])
    if autotune:
        if mesh is not None:
            shape, sctx = _shard_tuning_call(fused_plan, x, mesh, in_specs,
                                             1, boundary)
            zeros = jnp.zeros(shape, x.dtype)
            sharded_kw = {k: kw.pop(k) for k in ("overlap",) if k in kw}
            kw = _tuned_kwargs(
                fused_plan, shape,
                _engine_runner(fused_plan, zeros,
                               ws if fused_plan.stages else ws[0],
                               interpret, epi_args=epi_args,
                               backend=backend),
                kw, context=("pipeline", impl) + sctx, backend=backend)
            kw.update(sharded_kw)
        else:
            kw = _tuned_kwargs(
                fused_plan, x.shape,
                _engine_runner(fused_plan, x,
                               ws if fused_plan.stages else ws[0],
                               interpret, epi_args=epi_args,
                               backend=backend),
                kw, context=("pipeline", impl), backend=backend)
    cfg = _window_cfg(fused_plan, kw, interpret=interpret, mesh=mesh,
                      in_specs=in_specs, boundary=boundary, backend=backend)
    return _guarded_window("pipeline", cfg, x,
                           ws if fused_plan.stages else ws[0], epi_args,
                           lambda: _pipeline_ref(x, plans, ws, epi_args))


def _reject_scan_kwargs(op: str, kw: dict) -> None:
    """Scan ops cannot shard over the halo-exchange layer and cannot
    take windowed-op fusion kwargs — say so loudly (pre-pallas) instead
    of silently ignoring unknown kwargs."""
    bad = sorted(k for k in ("mesh", "in_specs", "boundary") if k in kw)
    if bad:
        raise ValueError(
            f"ops.{op} does not take {', '.join(bad)}: scan plans carry a "
            "sequential inter-block carry along the lane axis, so the "
            "halo-exchange layer cannot shard them; shard the row axis "
            "under pjit with impl='xla' instead")
    bad = sorted(k for k in ("epilogue", "epilogue_args", "stride",
                             "strategy") if k in kw)
    if bad:
        raise ValueError(
            f"ops.{op} does not take {', '.join(bad)}: fused epilogues, "
            "output strides, chain fusion and the lanes/mxu lowering "
            "strategy are windowed-plan features (DESIGN.md §11/§13) — a "
            "scan's tap contraction is a carried recurrence, not a "
            "matmul, and a fused activation would corrupt the carry; "
            "apply the elementwise stage in XLA after the scan, or fuse "
            "windowed stages with ops.pipeline")


# kept under the old name for callers/tests that used the PR 4 guard
_reject_scan_mesh = _reject_scan_kwargs


def _scan_cfg(kw: dict, *, interpret: bool, op: str) -> _ScanCfg:
    d = _DEFAULTS["scan"].block
    cfg = _ScanCfg(block_r=kw.pop("block_r", d[0]),
                   block_t=kw.pop("block_t", d[1]),
                   interpret=interpret,
                   acc_dtype=kw.pop("acc_dtype", jnp.float32),
                   chunk=kw.pop("chunk", None),
                   backend=_check_backend(kw.pop("backend", None), op))
    if kw:
        raise TypeError(f"unexpected kwargs for ops.{op}: {sorted(kw)}")
    return cfg


def cumsum(x, *, impl: str | None = None, autotune: bool = False, **kw):
    _reject_scan_kwargs("cumsum", kw)
    impl = impl or default_impl()
    if impl == "xla":
        return ref.cumsum(x)
    interpret = _interp(impl)
    if autotune:
        from repro.core.plan import scan_plan
        plan = scan_plan(128)          # schedule signature for the cache key
        kw = _tuned_kwargs(
            plan, x.shape,
            lambda **k: _sc.cumsum(x, interpret=interpret, **k), kw,
            context=("cumsum", impl), backend=kw.get("backend"))
    return _guarded_scan("cumsum",
                         _scan_cfg(kw, interpret=interpret, op="cumsum"),
                         lambda c: _cumsum_op(c, x),
                         lambda: ref.cumsum(x))


def sat(x, *, impl: str | None = None, **kw):
    """Summed-area table (§3.6 / the paper's companion SAT work [7]):
    two passes of the SSAM Kogge–Stone cumsum — rows, then columns."""
    _reject_scan_kwargs("sat", kw)
    rows = cumsum(x, impl=impl, **kw)
    return cumsum(rows.T, impl=impl, **kw).T


def linear_recurrence(a, b, *, impl: str | None = None,
                      autotune: bool = False, **kw):
    """h_t = a_t·h_{t−1} + b_t along the last axis of (R, T)-shaped a, b."""
    _reject_scan_kwargs("linear_recurrence", kw)
    impl = impl or default_impl()
    if impl == "xla":
        return ref.linear_recurrence(a, b)
    interpret = _interp(impl)
    if autotune:
        from repro.core.plan import linear_recurrence_plan
        plan = linear_recurrence_plan(128)
        kw = _tuned_kwargs(
            plan, a.shape,
            lambda **k: _sc.linear_recurrence(a, b, interpret=interpret, **k),
            kw, context=("linrec", impl), backend=kw.get("backend"))
    return _guarded_scan(
        "linear_recurrence",
        _scan_cfg(kw, interpret=interpret, op="linear_recurrence"),
        lambda c: _linrec_op(c, a, b),
        lambda: ref.linear_recurrence(a, b))


# ---------------------------------------------------------------------------
# Shardable chunked recurrence for full-scale models (beyond-paper path).
#
# The elementwise SSAM recurrence is the paper-faithful execution; at
# production sequence lengths the framework uses this chunk-parallel form:
# an associative (Kogge–Stone, same algebra as the SSAM plan) scan within
# chunks under lax.scan state-passing across chunks — O(T·log L) work,
# O(B·L·C) live memory, shardable over batch/channel axes under pjit.
#
# ``impl="engine"`` routes the same math through the chunk-streamed
# engine schedule (DESIGN.md §12): leading axes flatten to the engine's
# row axis and the sequence streams through ``(R, chunk)`` ``run_scan_plan``
# slabs inside a ``lax.scan`` whose carry is the per-row state — O(R·chunk)
# live state forward AND backward (chunk-boundary checkpointing), the
# production LM path exercising the exact kernel the benchmarks measure.
# ``impl="engine_unchunked"`` keeps the monolithic O(R·T) lowering as the
# validation reference.
# ---------------------------------------------------------------------------

def default_scan_impl() -> str:
    """Per-backend default for the production scan surfaces
    (:func:`chunked_linear_recurrence`, ``nn/ssm.selective_scan``,
    ``nn/ssm.wkv6_chunked``): the chunk-streamed engine schedule on real
    TPU, the pjit-shardable XLA chunk form elsewhere (the Pallas
    interpreter is far too slow to be anyone's training default)."""
    return "engine" if jax.default_backend() == "tpu" else "chunked"


@functools.partial(jax.jit, static_argnames=("chunk",))
def _chunked_linrec_xla(a: jax.Array, b: jax.Array, *, chunk: int):
    """Non-engine chunk form: associative scan within chunks, lax.scan
    state-passing across chunks — O(T·log L) work, shardable under pjit."""
    T = a.shape[-1]
    pad = (-T) % chunk
    if pad:
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)], constant_values=1)
        b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, pad)])
    nc = a.shape[-1] // chunk
    ac = a.reshape(a.shape[:-1] + (nc, chunk))
    bc = b.reshape(b.shape[:-1] + (nc, chunk))
    ac = jnp.moveaxis(ac, -2, 0)  # (nc, ..., chunk)
    bc = jnp.moveaxis(bc, -2, 0)

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay + by  # f_y ∘ f_x (x earlier)

    def chunk_step(h, ab):
        a_k, b_k = ab
        A, B = jax.lax.associative_scan(combine, (a_k, b_k), axis=-1)
        h_t = A * h[..., None] + B
        return h_t[..., -1], h_t

    h0 = jnp.zeros(a.shape[:-1], a.dtype)
    _, hs = jax.lax.scan(chunk_step, h0, (ac, bc))
    out = jnp.moveaxis(hs, 0, -2).reshape(a.shape[:-1] + (nc * chunk,))
    return out[..., :T]


def chunked_linear_recurrence(a: jax.Array, b: jax.Array, *,
                              chunk: int = 128, impl: str | None = None,
                              autotune: bool = False, **kw):
    """Same math as :func:`linear_recurrence`; a, b shaped (..., T).

    ``impl``: ``None`` resolves per backend (:func:`default_scan_impl`);
    ``"engine"`` streams ``(R, chunk)`` slabs through the scan engine
    with the inter-chunk carry in the ``lax.scan`` state (O(R·chunk)
    live state, checkpointed backward); ``"engine_unchunked"`` is the
    monolithic O(R·T) engine lowering; ``"chunked"`` is the non-engine
    XLA associative-scan form. ``autotune=True`` tunes
    ``(block_r, block_t, chunk)`` through the §5 model + sidecar for the
    streamed path (``(block_r, block_t)`` for the monolithic one).
    """
    impl = impl or default_scan_impl()
    if impl not in ("engine", "engine_unchunked", "chunked"):
        raise ValueError(impl)
    T = a.shape[-1]
    if impl == "chunked":
        if kw:
            raise TypeError(
                f"unexpected kwargs for ops.chunked_linear_recurrence"
                f"(impl='chunked'): {sorted(kw)}")
        return _chunked_linrec_xla(a, b, chunk=chunk)

    rows_a, rows_b = a.reshape(-1, T), b.reshape(-1, T)
    interpret = engine_interpret()
    streamed = impl == "engine"
    if autotune:
        from repro.core.plan import linear_recurrence_plan
        plan = linear_recurrence_plan(128)   # schedule signature (cache key)

        def call(**k):
            ck = k.pop("chunk", chunk)
            cfg = _ScanCfg(interpret=interpret,
                           chunk=ck if streamed else None, **k)
            return (_linrec_stream(cfg, rows_a, rows_b) if streamed
                    else _linrec_op(cfg, rows_a, rows_b))

        kw = _tuned_kwargs(
            plan, rows_a.shape, call, kw,
            context=("linrec_stream" if streamed else "linrec", impl),
            chunked=streamed,
            default=tuning.KernelConfig((8, 128, chunk)) if streamed
            else None, backend=kw.get("backend"))
    chunk = kw.pop("chunk", chunk)
    if streamed:
        cfg = _scan_cfg(kw, interpret=interpret,
                        op="chunked_linear_recurrence")
        cfg = dataclasses.replace(cfg, chunk=chunk,
                                  block_t=min(cfg.block_t, chunk))
        from repro.core import engine as _eng
        from repro.core.plan import linear_recurrence_plan
        _eng.check_chunk_geometry(
            linear_recurrence_plan(_sc._lane_tile(cfg.block_t, chunk)), chunk)
        out = _guarded_scan(
            "chunked_linear_recurrence", cfg,
            lambda c: _linrec_stream(c, rows_a, rows_b),
            lambda: _chunked_linrec_xla(rows_a, rows_b, chunk=chunk))
    else:
        cfg = _ScanCfg(block_r=kw.pop("block_r", 8),
                       block_t=kw.pop("block_t", chunk),
                       interpret=interpret,
                       acc_dtype=kw.pop("acc_dtype", jnp.float32),
                       backend=_check_backend(
                           kw.pop("backend", None),
                           "chunked_linear_recurrence"))
        if kw:
            raise TypeError(
                f"unexpected kwargs for ops.chunked_linear_recurrence: "
                f"{sorted(kw)}")
        out = _guarded_scan(
            "chunked_linear_recurrence", cfg,
            lambda c: _linrec_op(c, rows_a, rows_b),
            lambda: ref.linear_recurrence(rows_a, rows_b))
    return out.reshape(a.shape)
