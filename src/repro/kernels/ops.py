"""Public jit'd API over the SSAM kernels, with backend dispatch.

Every op takes ``impl``:

* ``"interpret"`` (default here, CPU container) — the Pallas kernel body
  executed by the Pallas interpreter: validates the real kernel schedule.
* ``"pallas"``    — compiled Mosaic kernel (real TPU only).
* ``"xla"``       — the pure-jnp oracle from :mod:`repro.kernels.ref`;
  shardable under pjit, used by the full-scale models and the dry-run.

``default_impl()`` picks "pallas" on TPU backends and "xla" elsewhere, so
model code can stay backend-agnostic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .ssam_conv1d import conv1d_causal as _pl_conv1d
from .ssam_conv2d import conv2d_same as _pl_conv2d_same
from .ssam_conv2d import conv2d_valid as _pl_conv2d_valid
from .ssam_scan import cumsum as _pl_cumsum
from .ssam_scan import linear_recurrence as _pl_linrec
from .ssam_stencil2d import stencil2d as _pl_stencil2d
from .ssam_stencil3d import stencil3d as _pl_stencil3d
from .stencils import BENCHMARKS, StencilDef


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _interp(impl: str) -> bool:
    if impl not in ("interpret", "pallas"):
        raise ValueError(impl)
    return impl == "interpret"


def conv2d(x, w, *, mode: str = "same", impl: str | None = None, **kw):
    impl = impl or default_impl()
    if impl == "xla":
        return ref.conv2d_same(x, w) if mode == "same" else ref.conv2d_valid(x, w)
    fn = _pl_conv2d_same if mode == "same" else _pl_conv2d_valid
    return fn(x, w, interpret=_interp(impl), **kw)


def conv1d_causal(x, w, *, impl: str | None = None, **kw):
    impl = impl or default_impl()
    if impl == "xla":
        return ref.conv1d_causal(x, w)
    return _pl_conv1d(x, w, interpret=_interp(impl), **kw)


def stencil(x, sdef: StencilDef | str, *, time_steps: int = 1,
            impl: str | None = None, **kw):
    impl = impl or default_impl()
    if isinstance(sdef, str):
        sdef = BENCHMARKS[sdef]
    if impl == "xla":
        return ref.stencil_iterate(x, sdef, time_steps)
    fn = _pl_stencil2d if sdef.ndim == 2 else _pl_stencil3d
    return fn(x, sdef, time_steps=time_steps, interpret=_interp(impl), **kw)


def cumsum(x, *, impl: str | None = None, **kw):
    impl = impl or default_impl()
    if impl == "xla":
        return ref.cumsum(x)
    return _pl_cumsum(x, interpret=_interp(impl), **kw)


def sat(x, *, impl: str | None = None, **kw):
    """Summed-area table (§3.6 / the paper's companion SAT work [7]):
    two passes of the SSAM Kogge–Stone cumsum — rows, then columns."""
    rows = cumsum(x, impl=impl, **kw)
    return cumsum(rows.T, impl=impl, **kw).T


def linear_recurrence(a, b, *, impl: str | None = None, **kw):
    """h_t = a_t·h_{t−1} + b_t along the last axis of (R, T)-shaped a, b."""
    impl = impl or default_impl()
    if impl == "xla":
        return ref.linear_recurrence(a, b)
    return _pl_linrec(a, b, interpret=_interp(impl), **kw)


# ---------------------------------------------------------------------------
# Shardable chunked recurrence for full-scale models (beyond-paper path).
#
# The elementwise SSAM recurrence is the paper-faithful execution; at
# production sequence lengths the framework uses this chunk-parallel form:
# an associative (Kogge–Stone, same algebra as the SSAM plan) scan within
# chunks under lax.scan state-passing across chunks — O(T·log L) work,
# O(B·L·C) live memory, shardable over batch/channel axes under pjit.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk",))
def chunked_linear_recurrence(a: jax.Array, b: jax.Array, *, chunk: int = 128):
    """Same math as :func:`linear_recurrence`; a, b shaped (..., T)."""
    T = a.shape[-1]
    pad = (-T) % chunk
    if pad:
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)], constant_values=1)
        b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, pad)])
    nc = a.shape[-1] // chunk
    ac = a.reshape(a.shape[:-1] + (nc, chunk))
    bc = b.reshape(b.shape[:-1] + (nc, chunk))
    ac = jnp.moveaxis(ac, -2, 0)  # (nc, ..., chunk)
    bc = jnp.moveaxis(bc, -2, 0)

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay + by  # f_y ∘ f_x (x earlier)

    def chunk_step(h, ab):
        a_k, b_k = ab
        A, B = jax.lax.associative_scan(combine, (a_k, b_k), axis=-1)
        h_t = A * h[..., None] + B
        return h_t[..., -1], h_t

    h0 = jnp.zeros(a.shape[:-1], a.dtype)
    _, hs = jax.lax.scan(chunk_step, h0, (ac, bc))
    out = jnp.moveaxis(hs, 0, -2).reshape(a.shape[:-1] + (nc * chunk,))
    return out[..., :T]
