"""Public jit'd API over the SSAM kernels, with backend dispatch.

Every op takes ``impl``:

* ``"interpret"`` (default here, CPU container) — the engine-lowered
  Pallas kernel executed by the Pallas interpreter: validates the real
  kernel schedule.
* ``"pallas"``    — compiled Mosaic kernel (real TPU only).
* ``"xla"``       — the pure-jnp oracle from :mod:`repro.kernels.ref`;
  shardable under pjit, used by the full-scale models and the dry-run.

``default_impl()`` picks "pallas" on TPU backends and "xla" elsewhere, so
model code can stay backend-agnostic.

Every non-xla op also takes ``autotune``: when True, the block config
(and schedule variant) is chosen by the §5 perf-model autotuner
(:mod:`repro.core.tuning`) — the model ranks candidates, the top few are
measured (the family default always included, so tuning never regresses
it), and winners are cached per (plan, shape, backend). Explicit block
kwargs win over tuned values.

``ops.stencil`` / ``ops.conv2d`` additionally take ``mesh=`` /
``in_specs=`` / ``boundary=``: with a mesh, the domain is sharded per
the PartitionSpec (default: the rule tables via
``halo_exchange.default_domain_spec``) and the plan runs through the
:mod:`repro.distributed.halo_exchange` layer — ppermute halo pushes
once per call, interior compute overlapped with the exchange. Sharding
problems in the resolved layout (an explicitly requested mesh axis that
does not divide the domain, a shard smaller than the plan's halo) raise
``ValueError`` here, before any ``pallas_call``; a *default* spec
follows the rule tables' divisibility fallback and leaves a
non-dividing axis replicated instead. Autotuning under a mesh targets
the *shard-local* halo-extended shape, so the winner is exactly the
per-device kernel.

Every engine-lowered op is differentiable: the ops are ``custom_vjp``
wrappers whose backward rules rebuild the **adjoint plan**
(:mod:`repro.core.adjoint` — point-reflected taps with swapped
lead/trail for backward-input, the batch+spatial-reduce correlation for
backward-weight, time-reversed scans for the scan family) and lower it
through the same engine; sharded forward ⇒ sharded backward (reversed
ppermute pushes, psum'd weight grads). With ``autotune=True`` the
backward-input plan is tuned independently under its own §5 signature.
``impl="xla"`` keeps JAX's native AD of the oracle — the gradcheck
reference.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import adjoint as adj
from repro.core import tuning
from repro.core.engine import run_weight_grad_plan, run_window_plan
from repro.core.plan import SystolicPlan
from . import ref
from . import ssam_conv1d as _c1
from . import ssam_conv2d as _c2
from . import ssam_scan as _sc
from . import ssam_stencil2d as _s2
from . import ssam_stencil3d as _s3
from .stencils import BENCHMARKS, StencilDef


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def default_engine_impl() -> str:
    """The engine-lowered path for the current backend: compiled Mosaic
    on real TPU, the Pallas interpreter elsewhere.

    This is the layer/training default (``nn/layers.conv2d_apply``,
    ``nn/ssm.mamba_apply``): with the adjoint-plan subsystem
    (:mod:`repro.core.adjoint`) every engine op is a ``custom_vjp``
    whose backward pass lowers through the same plan engine, so model
    code no longer silently differentiates through the XLA oracle
    off-TPU. ``default_impl()`` remains the serving/oracle default
    (pjit-shardable XLA off-TPU)."""
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def _interp(impl: str) -> bool:
    if impl not in ("interpret", "pallas"):
        raise ValueError(impl)
    return impl == "interpret"


_DEFAULTS = {
    "conv2d": tuning.KernelConfig((8, 128)),
    "conv2d_nchw": tuning.KernelConfig((8, 128)),
    "stencil2d": tuning.KernelConfig((8, 128)),
    "stencil3d": tuning.KernelConfig((4, 8, 128)),
    "conv1d": tuning.KernelConfig((128, 128)),
    "scan": tuning.KernelConfig((8, 128)),
    "recurrence": tuning.KernelConfig((8, 128)),
}


def engine_interpret() -> bool:
    """Whether engine-lowered paths should run the Pallas interpreter
    (non-TPU backends) or compiled Mosaic (real TPU)."""
    return jax.default_backend() != "tpu"


def _engine_block(plan, kw: dict) -> tuple[tuple[int, ...], str, dict]:
    """Split family kwargs into (engine block tuple, variant, rest)."""
    kw = dict(kw)
    d = _DEFAULTS[plan.kind].block
    if plan.ndim_spatial == 3:
        block = (kw.pop("block_z", d[0]), kw.pop("block_h", d[1]),
                 kw.pop("block_w", d[2]))
    else:
        block = (kw.pop("block_h", d[0]), kw.pop("block_w", d[1]))
    return block, kw.pop("variant", "shift_psum"), kw


# ---------------------------------------------------------------------------
# Differentiable engine cores (custom_vjp over adjoint plans)
#
# Every engine-lowered op routes through one of these wrappers. The
# forward is exactly the plan engine (single-device ``run_window_plan``
# or the sharded halo-exchange layer); the backward rule rebuilds the
# *adjoint* plan symbolically (:mod:`repro.core.adjoint`) and lowers it
# through the same engine — point-reflected taps with swapped lead/trail
# for backward-input, the batch+spatial-reduce correlation
# (``run_weight_grad_plan``) for backward-weight, time-reversed scans
# for the scan family. Sharded forward ⇒ sharded backward: the adjoint
# plan's swapped lead/trail reverses the ppermute halo pushes through
# the unchanged halo-exchange layer, and the weight grad psums partial
# filter blocks across the mesh.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _WindowCfg:
    """Static (nondiff) configuration of one windowed engine call."""

    plan: SystolicPlan
    block: tuple[int, ...]
    time_steps: int = 1
    variant: str = "shift_psum"
    interpret: bool = True
    acc_dtype: object = jnp.float32
    mesh: object = None              # jax.sharding.Mesh | None
    in_specs: object = None          # PartitionSpec | None (rule-table default)
    boundary: str = "zero"
    overlap: bool = True
    bwd_tune: tuple | None = None    # tuner context → adjoint tuned on its
    #                                  own plan signature; None → reuse block


def _window_forward(cfg: _WindowCfg, x, w):
    if cfg.mesh is not None:
        from repro.distributed import halo_exchange as hx
        return hx.sharded_window_plan(
            x, w, plan=cfg.plan, mesh=cfg.mesh, in_spec=cfg.in_specs,
            block=cfg.block, time_steps=cfg.time_steps, variant=cfg.variant,
            boundary=cfg.boundary, overlap=cfg.overlap,
            interpret=cfg.interpret, acc_dtype=cfg.acc_dtype)
    return run_window_plan(
        x, w, plan=cfg.plan, block=cfg.block, time_steps=cfg.time_steps,
        variant=cfg.variant, interpret=cfg.interpret, acc_dtype=cfg.acc_dtype)


def _tuned_adjoint_config(aplan, g_shape, g_dtype, w, cfg: _WindowCfg):
    """Tune the backward-input plan independently of the forward.

    The adjoint is a *different* kernel (its own taps/halo), so it gets
    its own §5 tuner/sidecar signature; measurement runs on zeros of the
    cotangent's (static) shape, which keeps it legal even while the
    backward pass itself is being traced under jit.
    """
    zeros = jnp.zeros(g_shape, g_dtype)
    wa = None if w is None else adj.adjoint_coeff_array(
        cfg.plan, jnp.zeros(w.shape, w.dtype))
    runner = lambda c: tuning.measure_us(lambda: run_window_plan(
        zeros, wa, plan=aplan, block=c.block, time_steps=cfg.time_steps,
        variant=c.variant, interpret=cfg.interpret, acc_dtype=cfg.acc_dtype))
    res = tuning.autotune(
        aplan, g_shape, time_steps=cfg.time_steps,
        default=tuning.KernelConfig(cfg.block, cfg.variant), runner=runner,
        context=cfg.bwd_tune)
    return res.config.block, res.config.variant


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _window_op(cfg: _WindowCfg, x, w):
    return _window_forward(cfg, x, w)


def _window_op_fwd(cfg, x, w):
    return _window_forward(cfg, x, w), (x, w)


def _window_op_bwd(cfg, res, g):
    x, w = res
    plan = cfg.plan
    if cfg.boundary == "replicate":
        raise ValueError(
            "gradients under boundary='replicate' are not supported: the "
            "transpose of an edge clamp accumulates halo rows onto the "
            "edge, which is not a windowed plan; use 'zero' or 'wrap'")
    if cfg.time_steps != 1 and plan.coeff_mode != "table":
        raise ValueError(
            "gradients of temporally-blocked convolutions are not "
            "supported (the weight enters every fused iterate); stencil "
            "plans (compile-time coefficients) differentiate at any "
            "time_steps")
    aplan = adj.input_adjoint_plan(plan)
    block, variant = cfg.block, cfg.variant
    if cfg.bwd_tune is not None and cfg.mesh is None:
        block, variant = _tuned_adjoint_config(aplan, g.shape, g.dtype, w,
                                               cfg)
    acfg = dataclasses.replace(cfg, plan=aplan, block=block, variant=variant,
                               bwd_tune=None)
    adj.record_lowering(aplan.kind)
    dx = _window_forward(acfg, g, adj.adjoint_coeff_array(plan, w))
    dx = dx.astype(x.dtype)
    if w is None or plan.coeff_mode == "table":
        return dx, None
    adj.record_lowering(adj.weight_adjoint_plan(plan).kind)
    wg_block = cfg.block[-2:]
    if cfg.mesh is not None:
        from repro.distributed import halo_exchange as hx
        dw = hx.sharded_weight_grad(
            x, g, plan=plan, mesh=cfg.mesh, in_spec=cfg.in_specs,
            block=wg_block, boundary=cfg.boundary, interpret=cfg.interpret,
            acc_dtype=cfg.acc_dtype)
    else:
        dw = run_weight_grad_plan(
            x, g, plan=plan, block=wg_block, interpret=cfg.interpret,
            acc_dtype=cfg.acc_dtype)
    return dx, dw.astype(w.dtype)


_window_op.defvjp(_window_op_fwd, _window_op_bwd)


@dataclasses.dataclass(frozen=True)
class _ScanCfg:
    """Static configuration of one scan-engine call."""

    block_r: int = 8
    block_t: int = 128
    interpret: bool = True
    acc_dtype: object = jnp.float32


def _cumsum_run(cfg: _ScanCfg, x):
    return _sc.cumsum(x, block_r=cfg.block_r, block_t=cfg.block_t,
                      interpret=cfg.interpret, acc_dtype=cfg.acc_dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _cumsum_op(cfg: _ScanCfg, x):
    return _cumsum_run(cfg, x)


def _cumsum_op_fwd(cfg, x):
    return _cumsum_run(cfg, x), None


def _cumsum_op_bwd(cfg, _, g):
    # (cumsum)ᵀ = the time-reversed scan plan: rev ∘ cumsum ∘ rev.
    adj.record_lowering("adj_scan")
    return (adj.time_reversed(_cumsum_run(cfg, adj.time_reversed(g))),)


_cumsum_op.defvjp(_cumsum_op_fwd, _cumsum_op_bwd)


def _linrec_run(cfg: _ScanCfg, a, b):
    return _sc.linear_recurrence(a, b, block_r=cfg.block_r,
                                 block_t=cfg.block_t,
                                 interpret=cfg.interpret,
                                 acc_dtype=cfg.acc_dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _linrec_op(cfg: _ScanCfg, a, b):
    return _linrec_run(cfg, a, b)


def _linrec_op_fwd(cfg, a, b):
    h = _linrec_run(cfg, a, b)
    return h, (a, h)


def _linrec_op_bwd(cfg, res, g):
    # λ_t = g_t + a_{t+1}·λ_{t+1}: the same recurrence, time-reversed,
    # with shifted coefficients — lowered through the same scan engine.
    a, h = res
    adj.record_lowering("adj_recurrence")
    abar = adj.reversed_recurrence_coeffs(a)
    lam = adj.time_reversed(_linrec_run(
        cfg, adj.time_reversed(abar), adj.time_reversed(g)))
    da = (lam.astype(jnp.float32)
          * adj.shifted_state(h).astype(jnp.float32)).astype(a.dtype)
    return da, lam.astype(a.dtype)


_linrec_op.defvjp(_linrec_op_fwd, _linrec_op_bwd)


def _shard_tuning_call(plan, x, mesh, in_specs, time_steps, boundary):
    """(shape, context) the sharded autotune must target: the per-device
    halo-extended block, keyed so winners never leak across meshes or
    boundary modes. For batched plans the leading batch axes shrink to
    their per-shard extent (reduce axes are never sharded)."""
    from repro.distributed import halo_exchange as hx
    spec = in_specs if in_specs is not None else \
        hx.default_plan_spec(plan, x.shape, mesh)
    nb, nr = plan.batch_axes, plan.reduce_axes
    assigns = hx._axis_assignments(spec, mesh, nb + nr + plan.ndim_spatial)
    spatial = tuning.shard_tuning_shape(
        plan, x.shape[nb + nr:], assigns[nb + nr:], time_steps, boundary)
    shape = tuple(
        n // (a[1] if a else 1)
        for n, a in zip(x.shape[:nb], assigns[:nb])
    ) + x.shape[nb:nb + nr] + spatial
    return shape, ("sharded", boundary) + tuple(
        f"{a[0]}:{a[1]}" if a else "-" for a in assigns)


def _tuned_kwargs(plan, shape, call, user_kw, *, time_steps: int = 1,
                  context: tuple = ()) -> dict:
    """Autotune block kwargs for ``call``; explicit user kwargs win.

    The cache context carries everything that changes what the runner
    measures beyond (plan, shape): op mode/impl and any caller-forced
    kwargs — without it a winner measured under one context would be
    silently replayed under another.
    """
    runner = lambda cfg: tuning.measure_us(
        lambda: call(**{**cfg.as_kwargs(plan), **user_kw}))
    res = tuning.autotune(plan, shape, time_steps=time_steps,
                          default=_DEFAULTS[plan.kind], runner=runner,
                          context=context + tuple(sorted(user_kw.items())),
                          fixed=user_kw)
    return {**res.config.as_kwargs(plan), **user_kw}


def conv2d(x, w, *, mode: str = "same", impl: str | None = None,
           autotune: bool = False, mesh=None, in_specs=None,
           boundary: str = "zero", **kw):
    """2-D convolution, dispatched on input rank:

    * ``(H, W)``            — single image, single channel (the paper's
      Listing 1 plan).
    * ``(B, H, W)``         — minibatch of single-channel images against
      one ``(N, M)`` filter (block-1 batch grid axis).
    * ``(B, C_in, H, W)``   — NCHW minibatch against an OIHW
      ``(C_out, C_in, N, M)`` filter through the reduce-axes plan: the
      engine grid iterates batch × C_out × spatial × C_in with an fp32
      accumulator across the channel reduction — no Python loop over
      batch or channels.

    Tuner contexts carry the rank tag and the full operand shape, so
    batched/NCHW winners never collide with single-image winners in the
    cache or the JSON sidecar.
    """
    impl = impl or default_impl()
    if x.ndim == 4:
        if w.ndim != 4:
            raise ValueError(
                f"conv2d on a 4-D NCHW input needs an OIHW "
                f"(C_out, C_in, N, M) filter, got w shape {tuple(w.shape)}")
        tag = "conv2d_nchw"
        ref_fn = lambda xx, m: ref.conv2d_nchw(xx, w, m)
        plan_fn = lambda: _c2.plan_for_nchw(x.shape, w.shape, mode)
        kernel = lambda xs, **k: _c2.conv2d_nchw(xs, w, mode=mode, **k)
    elif x.ndim == 3:
        if w.ndim != 2:
            raise ValueError(
                f"conv2d on a 3-D (B, H, W) stack needs a 2-D (N, M) "
                f"filter, got w shape {tuple(w.shape)}; for a multi-channel "
                "minibatch pass a 4-D NCHW input with an OIHW filter")
        tag = "conv2d_batched"
        ref_fn = lambda xx, m: ref.conv2d_batched(xx, w, m)
        plan_fn = lambda: _c2.plan_for_batched(w.shape, mode)
        kernel = lambda xs, **k: _c2.conv2d_batched(xs, w, mode=mode, **k)
    else:
        tag = "conv2d"
        ref_fn = lambda xx, m: (ref.conv2d_same(xx, w) if m == "same"
                                else ref.conv2d_valid(xx, w))
        plan_fn = lambda: _c2.plan_for(w.shape, mode)
        kernel = lambda xs, **k: (
            _c2.conv2d_same(xs, w, **k) if mode == "same"
            else _c2.conv2d_valid(xs, w, **k))
    if impl == "xla":
        if mesh is not None:
            raise ValueError("mesh= needs the engine path; the 'xla' oracle "
                             "is already shardable under pjit")
        return ref_fn(x, mode)
    return _conv2d_engine(x, w, plan=plan_fn(), kernel=kernel, tag=tag,
                          mode=mode, impl=impl, autotune=autotune, mesh=mesh,
                          in_specs=in_specs, boundary=boundary, kw=kw)


def _window_cfg(plan, kw, *, interpret, time_steps=1, mesh=None,
                in_specs=None, boundary="zero", bwd_tune=None) -> _WindowCfg:
    """Resolve family kwargs into the static config of one engine call."""
    block, variant, rest = _engine_block(plan, kw)
    cfg = _WindowCfg(
        plan=plan, block=block, variant=variant, interpret=interpret,
        time_steps=rest.pop("time_steps", time_steps),
        acc_dtype=rest.pop("acc_dtype", jnp.float32),
        mesh=mesh, in_specs=in_specs, boundary=boundary,
        overlap=rest.pop("overlap", True), bwd_tune=bwd_tune)
    if rest:
        raise TypeError(f"unexpected kwargs for {plan.kind!r}: "
                        f"{sorted(rest)}")
    return cfg


def _conv2d_engine(x, w, *, plan, kernel, tag, mode, impl, autotune, mesh,
                   in_specs, boundary, kw):
    """Shared mesh/autotune scaffolding for every conv2d rank.

    ``kernel(xs, interpret=..., **block_kwargs)`` lowers the engine call
    on ``xs`` for tuning measurements; ``plan`` is its schedule; ``tag``
    keys the tuner context. The actual call goes through the
    differentiable ``_window_op`` core, so ``jax.grad`` of any conv2d
    rank lowers its backward pass through the adjoint plans.
    """
    interpret = _interp(impl)
    if mesh is not None:
        if mode != "same":
            raise ValueError(
                "sharded conv2d supports mode='same' only: 'valid' shrinks "
                "the domain, so shards would not own equal output slices")
        if autotune:
            shape, sctx = _shard_tuning_call(plan, x, mesh, in_specs, 1,
                                             boundary)
            zeros = jnp.zeros(shape, x.dtype)
            sharded_kw = {k: kw.pop(k) for k in ("overlap",) if k in kw}
            kw = _tuned_kwargs(
                plan, shape,
                lambda **k: kernel(zeros, interpret=interpret, **k),
                kw, context=(tag, mode, impl) + sctx)
            kw.update(sharded_kw)
        cfg = _window_cfg(plan, kw, interpret=interpret, mesh=mesh,
                          in_specs=in_specs, boundary=boundary)
        return _window_op(cfg, x, w)
    bwd_tune = None
    if autotune:
        kw = _tuned_kwargs(
            plan, x.shape,
            lambda **k: kernel(x, interpret=interpret, **k), kw,
            context=(tag, mode, impl))
        bwd_tune = ("adjoint", tag, mode, impl)
    return _window_op(_window_cfg(plan, kw, interpret=interpret,
                                  bwd_tune=bwd_tune), x, w)


def conv1d_causal(x, w, *, impl: str | None = None, autotune: bool = False,
                  **kw):
    impl = impl or default_impl()
    if w.shape[-1] != x.shape[-1]:
        # checked for every impl — the oracle would otherwise silently
        # broadcast a mismatched filter across channels
        raise ValueError(f"conv1d_causal: filter lanes {w.shape} do not "
                         f"match input channels {x.shape}")
    if impl == "xla":
        return ref.conv1d_causal(x, w)
    interpret = _interp(impl)
    plan = _c1.plan_for(w.shape[0])
    bwd_tune = None
    if autotune:
        kw = _tuned_kwargs(
            plan, x.shape,
            lambda **k: _c1.conv1d_causal(x, w, interpret=interpret, **k), kw,
            context=("conv1d", impl))
        bwd_tune = ("adjoint", "conv1d", impl)
    d = _DEFAULTS["conv1d"].block
    cfg = _WindowCfg(
        plan=plan, block=(kw.pop("block_t", d[0]), kw.pop("block_d", d[1])),
        interpret=interpret, acc_dtype=kw.pop("acc_dtype", jnp.float32),
        bwd_tune=bwd_tune)
    if kw:
        raise TypeError(f"unexpected kwargs for conv1d_causal: {sorted(kw)}")
    return _window_op(cfg, x, w)


def stencil(x, sdef: StencilDef | str, *, time_steps: int = 1,
            impl: str | None = None, autotune: bool = False, mesh=None,
            in_specs=None, boundary: str = "zero", **kw):
    impl = impl or default_impl()
    if isinstance(sdef, str):
        sdef = BENCHMARKS[sdef]
    if impl == "xla":
        if mesh is not None:
            raise ValueError("mesh= needs the engine path; the 'xla' oracle "
                             "is already shardable under pjit")
        return ref.stencil_iterate(x, sdef, time_steps)
    mod = _s2 if sdef.ndim == 2 else _s3
    fn = mod.stencil2d if sdef.ndim == 2 else mod.stencil3d
    interpret = _interp(impl)
    plan = mod.plan_for(sdef)
    if mesh is not None:
        if autotune:
            shape, sctx = _shard_tuning_call(plan, x, mesh, in_specs,
                                             time_steps, boundary)
            zeros = jnp.zeros(shape, x.dtype)
            # tune with the single-device engine on a shard-shaped block;
            # sharded-layer-only kwargs stay out of the measured closure
            sharded_kw = {k: kw.pop(k) for k in ("overlap",) if k in kw}
            kw = _tuned_kwargs(
                plan, shape,
                lambda **k: fn(zeros, sdef, time_steps=time_steps,
                               interpret=interpret, **k),
                kw, time_steps=time_steps,
                context=("stencil", impl) + sctx)
            kw.update(sharded_kw)
        cfg = _window_cfg(plan, kw, interpret=interpret,
                          time_steps=time_steps, mesh=mesh,
                          in_specs=in_specs, boundary=boundary)
        return _window_op(cfg, x, None)
    bwd_tune = None
    if autotune:
        kw = _tuned_kwargs(
            plan, x.shape,
            lambda **k: fn(x, sdef, time_steps=time_steps,
                           interpret=interpret, **k),
            kw, time_steps=time_steps, context=("stencil", impl))
        bwd_tune = ("adjoint", "stencil", impl)
    return _window_op(_window_cfg(plan, kw, interpret=interpret,
                                  time_steps=time_steps, bwd_tune=bwd_tune),
                      x, None)


def _reject_scan_mesh(op: str, kw: dict) -> None:
    """Scan ops cannot shard over the halo-exchange layer — say so
    loudly (pre-pallas) instead of silently ignoring unknown kwargs."""
    bad = sorted(k for k in ("mesh", "in_specs", "boundary") if k in kw)
    if bad:
        raise ValueError(
            f"ops.{op} does not take {', '.join(bad)}: scan plans carry a "
            "sequential inter-block carry along the lane axis, so the "
            "halo-exchange layer cannot shard them; shard the row axis "
            "under pjit with impl='xla' instead")


def _scan_cfg(kw: dict, *, interpret: bool, op: str) -> _ScanCfg:
    cfg = _ScanCfg(block_r=kw.pop("block_r", 8),
                   block_t=kw.pop("block_t", 128),
                   interpret=interpret,
                   acc_dtype=kw.pop("acc_dtype", jnp.float32))
    if kw:
        raise TypeError(f"unexpected kwargs for ops.{op}: {sorted(kw)}")
    return cfg


def cumsum(x, *, impl: str | None = None, autotune: bool = False, **kw):
    _reject_scan_mesh("cumsum", kw)
    impl = impl or default_impl()
    if impl == "xla":
        return ref.cumsum(x)
    interpret = _interp(impl)
    if autotune:
        from repro.core.plan import scan_plan
        plan = scan_plan(128)          # schedule signature for the cache key
        kw = _tuned_kwargs(
            plan, x.shape,
            lambda **k: _sc.cumsum(x, interpret=interpret, **k), kw,
            context=("cumsum", impl))
    return _cumsum_op(_scan_cfg(kw, interpret=interpret, op="cumsum"), x)


def sat(x, *, impl: str | None = None, **kw):
    """Summed-area table (§3.6 / the paper's companion SAT work [7]):
    two passes of the SSAM Kogge–Stone cumsum — rows, then columns."""
    _reject_scan_mesh("sat", kw)
    rows = cumsum(x, impl=impl, **kw)
    return cumsum(rows.T, impl=impl, **kw).T


def linear_recurrence(a, b, *, impl: str | None = None,
                      autotune: bool = False, **kw):
    """h_t = a_t·h_{t−1} + b_t along the last axis of (R, T)-shaped a, b."""
    _reject_scan_mesh("linear_recurrence", kw)
    impl = impl or default_impl()
    if impl == "xla":
        return ref.linear_recurrence(a, b)
    interpret = _interp(impl)
    if autotune:
        from repro.core.plan import linear_recurrence_plan
        plan = linear_recurrence_plan(128)
        kw = _tuned_kwargs(
            plan, a.shape,
            lambda **k: _sc.linear_recurrence(a, b, interpret=interpret, **k),
            kw, context=("linrec", impl))
    return _linrec_op(
        _scan_cfg(kw, interpret=interpret, op="linear_recurrence"), a, b)


# ---------------------------------------------------------------------------
# Shardable chunked recurrence for full-scale models (beyond-paper path).
#
# The elementwise SSAM recurrence is the paper-faithful execution; at
# production sequence lengths the framework uses this chunk-parallel form:
# an associative (Kogge–Stone, same algebra as the SSAM plan) scan within
# chunks under lax.scan state-passing across chunks — O(T·log L) work,
# O(B·L·C) live memory, shardable over batch/channel axes under pjit.
#
# ``impl="engine"`` routes the same math through ``run_scan_plan``
# blocks instead: leading axes flatten to the engine's row axis, T tiles
# into Kogge–Stone lane blocks of width ``chunk`` with the inter-block
# carry in VMEM scratch — the production LM path exercising the exact
# kernel the benchmarks measure.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def chunked_linear_recurrence(a: jax.Array, b: jax.Array, *,
                              chunk: int = 128, impl: str = "chunked"):
    """Same math as :func:`linear_recurrence`; a, b shaped (..., T)."""
    if impl == "engine":
        T = a.shape[-1]
        cfg = _ScanCfg(block_t=chunk, interpret=engine_interpret())
        out = _linrec_op(cfg, a.reshape((-1, T)), b.reshape((-1, T)))
        return out.reshape(a.shape)
    if impl != "chunked":
        raise ValueError(impl)
    T = a.shape[-1]
    pad = (-T) % chunk
    if pad:
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)], constant_values=1)
        b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, pad)])
    nc = a.shape[-1] // chunk
    ac = a.reshape(a.shape[:-1] + (nc, chunk))
    bc = b.reshape(b.shape[:-1] + (nc, chunk))
    ac = jnp.moveaxis(ac, -2, 0)  # (nc, ..., chunk)
    bc = jnp.moveaxis(bc, -2, 0)

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay + by  # f_y ∘ f_x (x earlier)

    def chunk_step(h, ab):
        a_k, b_k = ab
        A, B = jax.lax.associative_scan(combine, (a_k, b_k), axis=-1)
        h_t = A * h[..., None] + B
        return h_t[..., -1], h_t

    h0 = jnp.zeros(a.shape[:-1], a.dtype)
    _, hs = jax.lax.scan(chunk_step, h0, (ac, bc))
    out = jnp.moveaxis(hs, 0, -2).reshape(a.shape[:-1] + (nc * chunk,))
    return out[..., :T]
