"""Public jit'd API over the SSAM kernels, with backend dispatch.

Every op takes ``impl``:

* ``"interpret"`` (default here, CPU container) — the engine-lowered
  Pallas kernel executed by the Pallas interpreter: validates the real
  kernel schedule.
* ``"pallas"``    — compiled Mosaic kernel (real TPU only).
* ``"xla"``       — the pure-jnp oracle from :mod:`repro.kernels.ref`;
  shardable under pjit, used by the full-scale models and the dry-run.

``default_impl()`` picks "pallas" on TPU backends and "xla" elsewhere, so
model code can stay backend-agnostic.

Every non-xla op also takes ``autotune``: when True, the block config
(and schedule variant) is chosen by the §5 perf-model autotuner
(:mod:`repro.core.tuning`) — the model ranks candidates, the top few are
measured (the family default always included, so tuning never regresses
it), and winners are cached per (plan, shape, backend). Explicit block
kwargs win over tuned values.

``ops.stencil`` / ``ops.conv2d`` additionally take ``mesh=`` /
``in_specs=`` / ``boundary=``: with a mesh, the domain is sharded per
the PartitionSpec (default: the rule tables via
``halo_exchange.default_domain_spec``) and the plan runs through the
:mod:`repro.distributed.halo_exchange` layer — ppermute halo pushes
once per call, interior compute overlapped with the exchange. Sharding
problems in the resolved layout (an explicitly requested mesh axis that
does not divide the domain, a shard smaller than the plan's halo) raise
``ValueError`` here, before any ``pallas_call``; a *default* spec
follows the rule tables' divisibility fallback and leaves a
non-dividing axis replicated instead. Autotuning under a mesh targets
the *shard-local* halo-extended shape, so the winner is exactly the
per-device kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import tuning
from . import ref
from . import ssam_conv1d as _c1
from . import ssam_conv2d as _c2
from . import ssam_scan as _sc
from . import ssam_stencil2d as _s2
from . import ssam_stencil3d as _s3
from .stencils import BENCHMARKS, StencilDef


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _interp(impl: str) -> bool:
    if impl not in ("interpret", "pallas"):
        raise ValueError(impl)
    return impl == "interpret"


_DEFAULTS = {
    "conv2d": tuning.KernelConfig((8, 128)),
    "conv2d_nchw": tuning.KernelConfig((8, 128)),
    "stencil2d": tuning.KernelConfig((8, 128)),
    "stencil3d": tuning.KernelConfig((4, 8, 128)),
    "conv1d": tuning.KernelConfig((128, 128)),
    "scan": tuning.KernelConfig((8, 128)),
    "recurrence": tuning.KernelConfig((8, 128)),
}


def engine_interpret() -> bool:
    """Whether engine-lowered paths should run the Pallas interpreter
    (non-TPU backends) or compiled Mosaic (real TPU)."""
    return jax.default_backend() != "tpu"


def _engine_block(plan, kw: dict) -> tuple[tuple[int, ...], str, dict]:
    """Split family kwargs into (engine block tuple, variant, rest)."""
    kw = dict(kw)
    d = _DEFAULTS[plan.kind].block
    if plan.ndim_spatial == 3:
        block = (kw.pop("block_z", d[0]), kw.pop("block_h", d[1]),
                 kw.pop("block_w", d[2]))
    else:
        block = (kw.pop("block_h", d[0]), kw.pop("block_w", d[1]))
    return block, kw.pop("variant", "shift_psum"), kw


def _sharded(plan, x, w, *, mesh, in_specs, time_steps, boundary, impl, kw):
    """Dispatch a windowed op through the halo-exchange layer."""
    from repro.distributed import halo_exchange as hx
    spec = in_specs if in_specs is not None else \
        hx.default_plan_spec(plan, x.shape, mesh)
    block, variant, rest = _engine_block(plan, kw)
    return hx.sharded_window_plan(
        x, w, plan=plan, mesh=mesh, in_spec=spec, block=block,
        time_steps=time_steps, variant=variant, boundary=boundary,
        interpret=_interp(impl), **rest)


def _shard_tuning_call(plan, x, mesh, in_specs, time_steps, boundary):
    """(shape, context) the sharded autotune must target: the per-device
    halo-extended block, keyed so winners never leak across meshes or
    boundary modes. For batched plans the leading batch axes shrink to
    their per-shard extent (reduce axes are never sharded)."""
    from repro.distributed import halo_exchange as hx
    spec = in_specs if in_specs is not None else \
        hx.default_plan_spec(plan, x.shape, mesh)
    nb, nr = plan.batch_axes, plan.reduce_axes
    assigns = hx._axis_assignments(spec, mesh, nb + nr + plan.ndim_spatial)
    spatial = tuning.shard_tuning_shape(
        plan, x.shape[nb + nr:], assigns[nb + nr:], time_steps, boundary)
    shape = tuple(
        n // (a[1] if a else 1)
        for n, a in zip(x.shape[:nb], assigns[:nb])
    ) + x.shape[nb:nb + nr] + spatial
    return shape, ("sharded", boundary) + tuple(
        f"{a[0]}:{a[1]}" if a else "-" for a in assigns)


def _tuned_kwargs(plan, shape, call, user_kw, *, time_steps: int = 1,
                  context: tuple = ()) -> dict:
    """Autotune block kwargs for ``call``; explicit user kwargs win.

    The cache context carries everything that changes what the runner
    measures beyond (plan, shape): op mode/impl and any caller-forced
    kwargs — without it a winner measured under one context would be
    silently replayed under another.
    """
    runner = lambda cfg: tuning.measure_us(
        lambda: call(**{**cfg.as_kwargs(plan), **user_kw}))
    res = tuning.autotune(plan, shape, time_steps=time_steps,
                          default=_DEFAULTS[plan.kind], runner=runner,
                          context=context + tuple(sorted(user_kw.items())),
                          fixed=user_kw)
    return {**res.config.as_kwargs(plan), **user_kw}


def conv2d(x, w, *, mode: str = "same", impl: str | None = None,
           autotune: bool = False, mesh=None, in_specs=None,
           boundary: str = "zero", **kw):
    """2-D convolution, dispatched on input rank:

    * ``(H, W)``            — single image, single channel (the paper's
      Listing 1 plan).
    * ``(B, H, W)``         — minibatch of single-channel images against
      one ``(N, M)`` filter (block-1 batch grid axis).
    * ``(B, C_in, H, W)``   — NCHW minibatch against an OIHW
      ``(C_out, C_in, N, M)`` filter through the reduce-axes plan: the
      engine grid iterates batch × C_out × spatial × C_in with an fp32
      accumulator across the channel reduction — no Python loop over
      batch or channels.

    Tuner contexts carry the rank tag and the full operand shape, so
    batched/NCHW winners never collide with single-image winners in the
    cache or the JSON sidecar.
    """
    impl = impl or default_impl()
    if x.ndim == 4:
        if w.ndim != 4:
            raise ValueError(
                f"conv2d on a 4-D NCHW input needs an OIHW "
                f"(C_out, C_in, N, M) filter, got w shape {tuple(w.shape)}")
        tag = "conv2d_nchw"
        ref_fn = lambda xx, m: ref.conv2d_nchw(xx, w, m)
        plan_fn = lambda: _c2.plan_for_nchw(x.shape, w.shape, mode)
        kernel = lambda xs, **k: _c2.conv2d_nchw(xs, w, mode=mode, **k)
    elif x.ndim == 3:
        if w.ndim != 2:
            raise ValueError(
                f"conv2d on a 3-D (B, H, W) stack needs a 2-D (N, M) "
                f"filter, got w shape {tuple(w.shape)}; for a multi-channel "
                "minibatch pass a 4-D NCHW input with an OIHW filter")
        tag = "conv2d_batched"
        ref_fn = lambda xx, m: ref.conv2d_batched(xx, w, m)
        plan_fn = lambda: _c2.plan_for_batched(w.shape, mode)
        kernel = lambda xs, **k: _c2.conv2d_batched(xs, w, mode=mode, **k)
    else:
        tag = "conv2d"
        ref_fn = lambda xx, m: (ref.conv2d_same(xx, w) if m == "same"
                                else ref.conv2d_valid(xx, w))
        plan_fn = lambda: _c2.plan_for(w.shape, mode)
        kernel = lambda xs, **k: (
            _c2.conv2d_same(xs, w, **k) if mode == "same"
            else _c2.conv2d_valid(xs, w, **k))
    if impl == "xla":
        if mesh is not None:
            raise ValueError("mesh= needs the engine path; the 'xla' oracle "
                             "is already shardable under pjit")
        return ref_fn(x, mode)
    return _conv2d_engine(x, w, plan=plan_fn(), kernel=kernel, tag=tag,
                          mode=mode, impl=impl, autotune=autotune, mesh=mesh,
                          in_specs=in_specs, boundary=boundary, kw=kw)


def _conv2d_engine(x, w, *, plan, kernel, tag, mode, impl, autotune, mesh,
                   in_specs, boundary, kw):
    """Shared mesh/autotune scaffolding for every conv2d rank.

    ``kernel(xs, interpret=..., **block_kwargs)`` lowers the engine call
    on ``xs``; ``plan`` is its schedule; ``tag`` keys the tuner context.
    """
    interpret = _interp(impl)
    if mesh is not None:
        if mode != "same":
            raise ValueError(
                "sharded conv2d supports mode='same' only: 'valid' shrinks "
                "the domain, so shards would not own equal output slices")
        if autotune:
            shape, sctx = _shard_tuning_call(plan, x, mesh, in_specs, 1,
                                             boundary)
            zeros = jnp.zeros(shape, x.dtype)
            sharded_kw = {k: kw.pop(k) for k in ("overlap",) if k in kw}
            kw = _tuned_kwargs(
                plan, shape,
                lambda **k: kernel(zeros, interpret=interpret, **k),
                kw, context=(tag, mode, impl) + sctx)
            kw.update(sharded_kw)
        return _sharded(plan, x, w, mesh=mesh, in_specs=in_specs,
                        time_steps=1, boundary=boundary, impl=impl, kw=kw)
    if autotune:
        kw = _tuned_kwargs(
            plan, x.shape,
            lambda **k: kernel(x, interpret=interpret, **k), kw,
            context=(tag, mode, impl))
    return kernel(x, interpret=interpret, **kw)


def conv1d_causal(x, w, *, impl: str | None = None, autotune: bool = False,
                  **kw):
    impl = impl or default_impl()
    if impl == "xla":
        return ref.conv1d_causal(x, w)
    interpret = _interp(impl)
    if autotune:
        kw = _tuned_kwargs(
            _c1.plan_for(w.shape[0]), x.shape,
            lambda **k: _c1.conv1d_causal(x, w, interpret=interpret, **k), kw,
            context=("conv1d", impl))
    return _c1.conv1d_causal(x, w, interpret=interpret, **kw)


def stencil(x, sdef: StencilDef | str, *, time_steps: int = 1,
            impl: str | None = None, autotune: bool = False, mesh=None,
            in_specs=None, boundary: str = "zero", **kw):
    impl = impl or default_impl()
    if isinstance(sdef, str):
        sdef = BENCHMARKS[sdef]
    if impl == "xla":
        if mesh is not None:
            raise ValueError("mesh= needs the engine path; the 'xla' oracle "
                             "is already shardable under pjit")
        return ref.stencil_iterate(x, sdef, time_steps)
    mod = _s2 if sdef.ndim == 2 else _s3
    fn = mod.stencil2d if sdef.ndim == 2 else mod.stencil3d
    interpret = _interp(impl)
    if mesh is not None:
        plan = mod.plan_for(sdef)
        if autotune:
            shape, sctx = _shard_tuning_call(plan, x, mesh, in_specs,
                                             time_steps, boundary)
            zeros = jnp.zeros(shape, x.dtype)
            # tune with the single-device engine on a shard-shaped block;
            # sharded-layer-only kwargs stay out of the measured closure
            sharded_kw = {k: kw.pop(k) for k in ("overlap",) if k in kw}
            kw = _tuned_kwargs(
                plan, shape,
                lambda **k: fn(zeros, sdef, time_steps=time_steps,
                               interpret=interpret, **k),
                kw, time_steps=time_steps,
                context=("stencil", impl) + sctx)
            kw.update(sharded_kw)
        return _sharded(plan, x, None, mesh=mesh, in_specs=in_specs,
                        time_steps=time_steps, boundary=boundary, impl=impl,
                        kw=kw)
    if autotune:
        kw = _tuned_kwargs(
            mod.plan_for(sdef), x.shape,
            lambda **k: fn(x, sdef, time_steps=time_steps,
                           interpret=interpret, **k),
            kw, time_steps=time_steps, context=("stencil", impl))
    return fn(x, sdef, time_steps=time_steps, interpret=interpret, **kw)


def cumsum(x, *, impl: str | None = None, autotune: bool = False, **kw):
    impl = impl or default_impl()
    if impl == "xla":
        return ref.cumsum(x)
    interpret = _interp(impl)
    if autotune:
        from repro.core.plan import scan_plan
        plan = scan_plan(128)          # schedule signature for the cache key
        kw = _tuned_kwargs(
            plan, x.shape,
            lambda **k: _sc.cumsum(x, interpret=interpret, **k), kw,
            context=("cumsum", impl))
    return _sc.cumsum(x, interpret=interpret, **kw)


def sat(x, *, impl: str | None = None, **kw):
    """Summed-area table (§3.6 / the paper's companion SAT work [7]):
    two passes of the SSAM Kogge–Stone cumsum — rows, then columns."""
    rows = cumsum(x, impl=impl, **kw)
    return cumsum(rows.T, impl=impl, **kw).T


def linear_recurrence(a, b, *, impl: str | None = None,
                      autotune: bool = False, **kw):
    """h_t = a_t·h_{t−1} + b_t along the last axis of (R, T)-shaped a, b."""
    impl = impl or default_impl()
    if impl == "xla":
        return ref.linear_recurrence(a, b)
    interpret = _interp(impl)
    if autotune:
        from repro.core.plan import linear_recurrence_plan
        plan = linear_recurrence_plan(128)
        kw = _tuned_kwargs(
            plan, a.shape,
            lambda **k: _sc.linear_recurrence(a, b, interpret=interpret, **k),
            kw, context=("linrec", impl))
    return _sc.linear_recurrence(a, b, interpret=interpret, **kw)


# ---------------------------------------------------------------------------
# Shardable chunked recurrence for full-scale models (beyond-paper path).
#
# The elementwise SSAM recurrence is the paper-faithful execution; at
# production sequence lengths the framework uses this chunk-parallel form:
# an associative (Kogge–Stone, same algebra as the SSAM plan) scan within
# chunks under lax.scan state-passing across chunks — O(T·log L) work,
# O(B·L·C) live memory, shardable over batch/channel axes under pjit.
#
# ``impl="engine"`` routes the same math through ``run_scan_plan``
# blocks instead: leading axes flatten to the engine's row axis, T tiles
# into Kogge–Stone lane blocks of width ``chunk`` with the inter-block
# carry in VMEM scratch — the production LM path exercising the exact
# kernel the benchmarks measure.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def chunked_linear_recurrence(a: jax.Array, b: jax.Array, *,
                              chunk: int = 128, impl: str = "chunked"):
    """Same math as :func:`linear_recurrence`; a, b shaped (..., T)."""
    if impl == "engine":
        T = a.shape[-1]
        out = _sc.linear_recurrence(
            a.reshape((-1, T)), b.reshape((-1, T)), block_t=chunk,
            interpret=engine_interpret())
        return out.reshape(a.shape)
    if impl != "chunked":
        raise ValueError(impl)
    T = a.shape[-1]
    pad = (-T) % chunk
    if pad:
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)], constant_values=1)
        b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, pad)])
    nc = a.shape[-1] // chunk
    ac = a.reshape(a.shape[:-1] + (nc, chunk))
    bc = b.reshape(b.shape[:-1] + (nc, chunk))
    ac = jnp.moveaxis(ac, -2, 0)  # (nc, ..., chunk)
    bc = jnp.moveaxis(bc, -2, 0)

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, bx * ay + by  # f_y ∘ f_x (x earlier)

    def chunk_step(h, ab):
        a_k, b_k = ab
        A, B = jax.lax.associative_scan(combine, (a_k, b_k), axis=-1)
        h_t = A * h[..., None] + B
        return h_t[..., -1], h_t

    h0 = jnp.zeros(a.shape[:-1], a.dtype)
    _, hs = jax.lax.scan(chunk_step, h0, (ac, bc))
    out = jnp.moveaxis(hs, 0, -2).reshape(a.shape[:-1] + (nc * chunk,))
    return out[..., :T]
