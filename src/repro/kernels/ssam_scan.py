"""SSAM scan kernels — Kogge–Stone plans over the engine (paper §3.6).

Two memory-bound primitives built from the same masked shift-accumulate
schedule (Fig. 1e — the ``ctrl()`` of Eq. 1 gates each arrow):

* :func:`cumsum` — inclusive prefix sum along time
  (:func:`repro.core.plan.scan_plan`, combine='add').
* :func:`linear_recurrence` — ``h_t = a_t · h_{t−1} + b_t`` via
  Kogge–Stone over the affine transfer pairs ``(a, b)``
  (:func:`repro.core.plan.linear_recurrence_plan`, combine='linrec').
  This is the execution engine for the RWKV6 WKV recurrence and the
  Hymba/Mamba selective scan (DESIGN.md §3).

Layout: time on the lane axis (the systolic "warp"), independent
channels on sublanes. Inter-block carries ride in a VMEM scratch
accumulator across sequential grid steps — the TPU analogue of the
paper's inter-warp scratchpad accumulation (§4.9), used only *between*
systolic blocks exactly as SSAM prescribes (§1). The lowering is
:func:`repro.core.engine.run_scan_plan`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import run_scan_plan
from repro.core.plan import linear_recurrence_plan, scan_plan


def _lane_tile(block_t: int, T: int) -> int:
    """Largest power-of-two lane tile ≤ min(block_t, T)."""
    return 1 << (min(block_t, T).bit_length() - 1)


def cumsum(
    x: jax.Array,
    *,
    block_r: int = 8,
    block_t: int = 128,
    interpret: bool = True,
    acc_dtype=jnp.float32,
    carry: jax.Array | None = None,
    return_carry: bool = False,
    backend: str | None = None,
):
    """Inclusive prefix sum along the last axis of ``(R, T)``.

    ``carry``/``return_carry`` thread the running total across chunks
    (DESIGN.md §12)."""
    plan = scan_plan(_lane_tile(block_t, x.shape[-1]))
    return run_scan_plan(x, plan=plan, block_r=block_r, interpret=interpret,
                         acc_dtype=acc_dtype, carry=carry,
                         return_carry=return_carry, backend=backend)


def linear_recurrence(
    a: jax.Array,
    b: jax.Array,
    *,
    block_r: int = 8,
    block_t: int = 128,
    interpret: bool = True,
    acc_dtype=jnp.float32,
    carry: jax.Array | None = None,
    return_carry: bool = False,
    backend: str | None = None,
):
    """Solve ``h_t = a_t · h_{t−1} + b_t`` along the last axis of (R, T).

    ``carry`` seeds h₋₁ (default 0); ``return_carry=True`` additionally
    returns the final state ``(R, 1)`` — together they let the caller
    stream chunks through the inter-chunk carry (DESIGN.md §12).

    Padding note (engine): ``a`` pads with ones and ``b`` with zeros so
    padded tail steps are identity transfers.
    """
    assert a.shape == b.shape
    plan = linear_recurrence_plan(_lane_tile(block_t, a.shape[-1]))
    return run_scan_plan(a, b, plan=plan, block_r=block_r,
                         interpret=interpret, acc_dtype=acc_dtype,
                         carry=carry, return_carry=return_carry,
                         backend=backend)
