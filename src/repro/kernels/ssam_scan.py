"""SSAM scan kernels — Kogge–Stone over the VREG lane axis (paper §3.6).

Two memory-bound primitives built from the same masked shift-accumulate
schedule (Fig. 1e — the ``ctrl()`` of Eq. 1 gates each arrow):

* :func:`cumsum` — inclusive prefix sum along time.
* :func:`linear_recurrence` — ``h_t = a_t · h_{t−1} + b_t`` via
  Kogge–Stone over the affine transfer pairs ``(a, b)``. This is the
  execution engine for the RWKV6 WKV recurrence and the Hymba/Mamba
  selective scan (DESIGN.md §3).

Layout: time on the 128-lane axis (the systolic "warp"), independent
channels on sublanes. Inter-block carries ride in a VMEM scratch
accumulator across sequential grid steps — the TPU analogue of the
paper's inter-warp scratchpad accumulation (§4.9), used only *between*
systolic blocks exactly as SSAM prescribes ("we do not limit the use of
scratchpad for inter-warp communication", §1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lane_index(shape, axis):
    return jax.lax.broadcasted_iota(jnp.int32, shape, axis)


def _cumsum_kernel(x_ref, o_ref, carry, *, BT: int, acc_dtype):
    @pl.when(pl.program_id(1) == 0)
    def _reset():
        carry[:] = jnp.zeros_like(carry)

    s = x_ref[:].astype(acc_dtype)           # (BR, BT)
    lane = _lane_index(s.shape, 1)
    d = 1
    while d < BT:                             # Kogge–Stone: log2(BT) steps
        shifted = jnp.roll(s, d, axis=1)
        s = s + jnp.where(lane >= d, shifted, jnp.zeros_like(s))
        d *= 2
    s = s + carry[:]                          # inter-block carry (scratchpad)
    carry[:] = s[:, -1:]
    o_ref[:] = s.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_t", "interpret", "acc_dtype")
)
def cumsum(
    x: jax.Array,
    *,
    block_r: int = 8,
    block_t: int = 128,
    interpret: bool = True,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Inclusive prefix sum along the last axis of ``(R, T)``."""
    R, T = x.shape
    BR = min(block_r, R)
    BT = 1 << (min(block_t, T).bit_length() - 1)   # largest pow2 ≤ min
    gr, gt = pl.cdiv(R, BR), pl.cdiv(T, BT)
    xp = jnp.pad(x, ((0, gr * BR - R), (0, gt * BT - T)))
    kern = functools.partial(_cumsum_kernel, BT=BT, acc_dtype=acc_dtype)
    out = pl.pallas_call(
        kern,
        grid=(gr, gt),                        # T sequential per row-tile
        in_specs=[pl.BlockSpec((BR, BT), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((BR, BT), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gr * BR, gt * BT), x.dtype),
        scratch_shapes=[pltpu.VMEM((BR, 1), acc_dtype)],
        interpret=interpret,
    )(xp)
    return out[:R, :T]


def _linrec_kernel(a_ref, b_ref, o_ref, hcarry, *, BT: int, acc_dtype):
    @pl.when(pl.program_id(1) == 0)
    def _reset():
        hcarry[:] = jnp.zeros_like(hcarry)

    A = a_ref[:].astype(acc_dtype)            # (BR, BT) transfer pairs
    B = b_ref[:].astype(acc_dtype)
    lane = _lane_index(A.shape, 1)
    d = 1
    while d < BT:                             # KS over (a,b) pairs
        As = jnp.roll(A, d, axis=1)
        Bs = jnp.roll(B, d, axis=1)
        ctrl = lane >= d                      # ctrl() of Eq. 1
        As = jnp.where(ctrl, As, jnp.ones_like(As))
        Bs = jnp.where(ctrl, Bs, jnp.zeros_like(Bs))
        A, B = A * As, A * Bs + B             # f_t ∘ f_{t−d}
        d *= 2
    # h_t = A_prefix_t · h_carry + B_local_t ; carry the block's last h.
    h = A * hcarry[:] + B
    hcarry[:] = h[:, -1:]
    o_ref[:] = h.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_t", "interpret", "acc_dtype")
)
def linear_recurrence(
    a: jax.Array,
    b: jax.Array,
    *,
    block_r: int = 8,
    block_t: int = 128,
    interpret: bool = True,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Solve ``h_t = a_t · h_{t−1} + b_t`` (h₋₁=0) along the last axis of (R, T).

    Padding note: ``a`` is padded with ones and ``b`` with zeros so padded
    tail steps are identity transfers.
    """
    R, T = a.shape
    assert a.shape == b.shape
    BR = min(block_r, R)
    BT = 1 << (min(block_t, T).bit_length() - 1)   # largest pow2 ≤ min
    gr, gt = pl.cdiv(R, BR), pl.cdiv(T, BT)
    ap = jnp.pad(a, ((0, gr * BR - R), (0, gt * BT - T)), constant_values=1)
    bp = jnp.pad(b, ((0, gr * BR - R), (0, gt * BT - T)))
    kern = functools.partial(_linrec_kernel, BT=BT, acc_dtype=acc_dtype)
    out = pl.pallas_call(
        kern,
        grid=(gr, gt),
        in_specs=[
            pl.BlockSpec((BR, BT), lambda i, j: (i, j)),
            pl.BlockSpec((BR, BT), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((BR, BT), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gr * BR, gt * BT), a.dtype),
        scratch_shapes=[pltpu.VMEM((BR, 1), acc_dtype)],
        interpret=interpret,
    )(ap, bp)
    return out[:R, :T]
