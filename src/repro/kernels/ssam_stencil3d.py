"""SSAM 3-D stencil (paper §4.9, TPU-adapted) as a plan over the engine.

On GPU the paper processes one X–Y slice per warp and accumulates the Z
direction through *shared memory* (inter-warp). On TPU the whole 3-D
sub-block lives in one kernel invocation, so Z taps are simply additional
vertical taps into the VREG-resident block — partial sums never touch
scratchpad (DESIGN.md §7.5). The lane-roll systolic schedule runs along X
exactly as in 2-D; Y and Z are in-register reads, carried in the plan as
``Tap.row_offset``/``Tap.z_offset``. Supports the same trapezoidal
temporal blocking as the 2-D kernel; lowering is the generic
:func:`repro.core.engine.run_window_plan`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import run_window_plan
from repro.core.plan import stencil3d_plan
from .stencils import StencilDef


def plan_for(sdef: StencilDef):
    """The systolic plan for a 3-D stencil definition (coeffs baked in)."""
    return stencil3d_plan(sdef.offsets, coeffs=sdef.coeffs)


def stencil3d(
    x: jax.Array,
    sdef: StencilDef,
    *,
    block_z: int = 4,
    block_h: int = 8,
    block_w: int = 128,
    time_steps: int = 1,
    variant: str = "shift_psum",
    interpret: bool = True,
    acc_dtype=jnp.float32,
    strategy: str | None = None,
    backend: str | None = None,
) -> jax.Array:
    """Apply ``sdef`` to ``x`` (Z, Y, X) ``time_steps`` times (zero boundary)."""
    assert sdef.ndim == 3
    return run_window_plan(
        x, plan=plan_for(sdef), block=(block_z, block_h, block_w),
        time_steps=time_steps, variant=variant, interpret=interpret,
        acc_dtype=acc_dtype, strategy=strategy, backend=backend,
    )
