"""SSAM 3-D stencil Pallas kernel (paper §4.9, TPU-adapted).

On GPU the paper processes one X–Y slice per warp and accumulates the Z
direction through *shared memory* (inter-warp). On TPU the whole 3-D
sub-block lives in one kernel invocation, so Z taps are simply additional
vertical taps into the VREG-resident block — partial sums never touch
scratchpad (DESIGN.md §7.5). The lane-roll systolic schedule runs along X
exactly as in 2-D; Y and Z are in-register (sublane / array-dim) reads.

Supports the same trapezoidal temporal blocking as the 2-D kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.plan import stencil3d_plan
from .stencils import StencilDef


def _footprint3d(sdef: StencilDef):
    los, his = [], []
    for axis in range(3):
        vals = [o[axis] for o in sdef.offsets]
        lo, hi = min(vals), max(vals)
        assert lo <= 0 <= hi, sdef.name
        los.append(lo)
        his.append(hi)
    return tuple(los), tuple(his)


def _stencil3d_kernel(x_ref, o_ref, *, sdef: StencilDef, BZ: int, BH: int,
                      BW: int, time_steps: int, acc_dtype):
    los, his = _footprint3d(sdef)
    D = his[0] - los[0] + 1
    N = his[1] - los[1] + 1
    M = his[2] - los[2] + 1
    plan = stencil3d_plan(sdef.offsets, S=BW, P=BH)
    xb = x_ref[:].astype(acc_dtype)
    for _ in range(time_steps):
        zd = xb.shape[0] - (D - 1)
        h = xb.shape[1] - (N - 1)
        w = xb.shape[2] - (M - 1)
        s = jnp.zeros((zd, h, xb.shape[2]), acc_dtype)
        for step in plan.steps:
            if step.shift:
                s = jnp.roll(s, step.shift, axis=2)
            for tap in step.taps:
                z_off, k = tap.coeff_id
                c = sdef.coeffs[k]
                s = s + xb[
                    z_off : z_off + zd,
                    tap.row_offset : tap.row_offset + h,
                    :,
                ] * c
        xb = s[:, :, M - 1 : M - 1 + w]
    o_ref[:] = xb[:BZ, :BH, :BW].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sdef", "block_z", "block_h", "block_w", "time_steps",
                     "interpret", "acc_dtype"),
)
def stencil3d(
    x: jax.Array,
    sdef: StencilDef,
    *,
    block_z: int = 4,
    block_h: int = 8,
    block_w: int = 128,
    time_steps: int = 1,
    interpret: bool = True,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Apply ``sdef`` to ``x`` (Z, Y, X) ``time_steps`` times (zero boundary)."""
    assert sdef.ndim == 3
    Z, H, W = x.shape
    los, his = _footprint3d(sdef)
    D = his[0] - los[0] + 1
    N = his[1] - los[1] + 1
    M = his[2] - los[2] + 1
    t = time_steps
    front, top, left = t * (-los[0]), t * (-los[1]), t * (-los[2])
    BZ, BH, BW = block_z, block_h, block_w
    gz, gh, gw = pl.cdiv(Z, BZ), pl.cdiv(H, BH), pl.cdiv(W, BW)
    pad_back = gz * BZ + t * (D - 1) - front - Z
    pad_bot = gh * BH + t * (N - 1) - top - H
    pad_right = gw * BW + t * (M - 1) - left - W
    xp = jnp.pad(x, ((front, pad_back), (top, pad_bot), (left, pad_right)))

    kern = functools.partial(
        _stencil3d_kernel, sdef=sdef, BZ=BZ, BH=BH, BW=BW, time_steps=t,
        acc_dtype=acc_dtype,
    )
    out = pl.pallas_call(
        kern,
        grid=(gz, gh, gw),
        in_specs=[
            pl.BlockSpec(
                (
                    pl.Element(BZ + t * (D - 1)),
                    pl.Element(BH + t * (N - 1)),
                    pl.Element(BW + t * (M - 1)),
                ),
                lambda i, j, k: (i * BZ, j * BH, k * BW),
            ),
        ],
        out_specs=pl.BlockSpec((BZ, BH, BW), lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct((gz * BZ, gh * BH, gw * BW), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:Z, :H, :W]
