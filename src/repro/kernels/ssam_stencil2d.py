"""SSAM 2-D stencil — the paper's Listing 2 as a plan over the engine.

Taps are grouped by *column offset* exactly as Listing 2 groups the
5-point stencil into {West}, {North, Current, South}, {East} — one
lane-roll of the partial sums per column, sparse vertical taps within a
column. Coefficients are compiled as immediates on the plan (the paper
passes stencil coefficients as kernel arguments, §4.8).

Temporal blocking (paper §6.4 / Fig. 6): ``time_steps > 1`` applies the
stencil t times *inside* the block over a halo widened to ``t``
footprints — partial iterates never leave VMEM/VREGs. Semantics (shared
with ``ref.stencil_iterate``): the domain is zero-padded once by ``t``
footprints, then ``t`` *valid* applications follow. All of the geometry
lives in the plan's lead/trail fields; the lowering is the generic
:func:`repro.core.engine.run_window_plan`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import run_window_plan
from repro.core.plan import stencil2d_plan
from .stencils import StencilDef


def plan_for(sdef: StencilDef):
    """The systolic plan for a 2-D stencil definition (coeffs baked in)."""
    return stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)


def stencil2d(
    x: jax.Array,
    sdef: StencilDef,
    *,
    block_h: int = 8,
    block_w: int = 128,
    time_steps: int = 1,
    variant: str = "shift_psum",
    interpret: bool = True,
    acc_dtype=jnp.float32,
    strategy: str | None = None,
    backend: str | None = None,
) -> jax.Array:
    """Apply ``sdef`` to ``x`` ``time_steps`` times (zero boundary, same shape)."""
    assert sdef.ndim == 2
    return run_window_plan(
        x, plan=plan_for(sdef), block=(block_h, block_w),
        time_steps=time_steps, variant=variant, interpret=interpret,
        acc_dtype=acc_dtype, strategy=strategy, backend=backend,
    )
