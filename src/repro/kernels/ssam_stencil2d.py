"""SSAM 2-D stencil Pallas kernel — the paper's Listing 2 generalized.

Schedule: identical dataflow to :mod:`repro.kernels.ssam_conv2d`, but the
taps are grouped by *column offset* exactly as Listing 2 groups the
5-point stencil into {West}, {North, Current, South}, {East} — one
lane-roll of the partial sums per column, sparse vertical taps within a
column. Coefficients are compiled as immediates (the paper passes stencil
coefficients as kernel arguments, §4.8).

Temporal blocking (paper §6.4 / Fig. 6 comparison): ``time_steps > 1``
applies the stencil t times *inside* the block over a halo widened to
``t`` footprints — partial iterates never leave VMEM/VREGs. The valid
region of a block shrinks by one footprint per step (classic overlapped /
trapezoidal temporal blocking [21, 62]). Semantics (shared with
``ref.stencil2d_iterate``): the domain is zero-padded once by ``t``
footprints, then ``t`` *valid* applications follow — for ``t=1`` this is
the usual same-shape zero-boundary stencil step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.plan import stencil2d_plan
from .stencils import StencilDef


def _footprint2d(sdef: StencilDef) -> tuple[int, int, int, int]:
    """(lo_dy, hi_dy, lo_dx, hi_dx) of the tap footprint (lo ≤ 0 ≤ hi)."""
    dys = [o[0] for o in sdef.offsets]
    dxs = [o[1] for o in sdef.offsets]
    lo_dy, hi_dy, lo_dx, hi_dx = min(dys), max(dys), min(dxs), max(dxs)
    assert lo_dy <= 0 <= hi_dy and lo_dx <= 0 <= hi_dx, sdef.name
    return lo_dy, hi_dy, lo_dx, hi_dx


def _stencil2d_kernel(x_ref, o_ref, *, sdef: StencilDef, BH: int, BW: int,
                      time_steps: int, acc_dtype):
    lo_dy, hi_dy, lo_dx, hi_dx = _footprint2d(sdef)
    N = hi_dy - lo_dy + 1
    M = hi_dx - lo_dx + 1
    plan = stencil2d_plan(sdef.offsets, S=BW, P=BH)
    xb = x_ref[:].astype(acc_dtype)
    for _ in range(time_steps):
        h = xb.shape[0] - (N - 1)        # valid rows of this iterate
        w = xb.shape[1] - (M - 1)        # valid lanes of this iterate
        s = jnp.zeros((h, xb.shape[1]), acc_dtype)
        for step in plan.steps:          # one systolic step per column
            if step.shift:
                s = jnp.roll(s, step.shift, axis=1)
            for tap in step.taps:
                c = sdef.coeffs[tap.coeff_id[0]]
                s = s + xb[tap.row_offset : tap.row_offset + h, :] * c
        # valid lanes after M−1 rolls start at lane M−1 (§4.4)
        xb = s[:, M - 1 : M - 1 + w]
    o_ref[:] = xb[:BH, :BW].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("sdef", "block_h", "block_w", "time_steps", "interpret",
                     "acc_dtype"),
)
def stencil2d(
    x: jax.Array,
    sdef: StencilDef,
    *,
    block_h: int = 8,
    block_w: int = 128,
    time_steps: int = 1,
    interpret: bool = True,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Apply ``sdef`` to ``x`` ``time_steps`` times (zero boundary, same shape)."""
    assert sdef.ndim == 2
    H, W = x.shape
    lo_dy, hi_dy, lo_dx, hi_dx = _footprint2d(sdef)
    N = hi_dy - lo_dy + 1
    M = hi_dx - lo_dx + 1
    t = time_steps
    top, left = t * (-lo_dy), t * (-lo_dx)
    BH, BW = block_h, block_w
    gh, gw = pl.cdiv(H, BH), pl.cdiv(W, BW)
    # Padded array: origin shifted by (top, left); total size covers the
    # last overlapped block.
    pad_bot = gh * BH + t * (N - 1) - top - H
    pad_right = gw * BW + t * (M - 1) - left - W
    xp = jnp.pad(x, ((top, pad_bot), (left, pad_right)))

    kern = functools.partial(
        _stencil2d_kernel, sdef=sdef, BH=BH, BW=BW, time_steps=t,
        acc_dtype=acc_dtype,
    )
    out = pl.pallas_call(
        kern,
        grid=(gh, gw),
        in_specs=[
            pl.BlockSpec(
                (pl.Element(BH + t * (N - 1)), pl.Element(BW + t * (M - 1))),
                lambda i, j: (i * BH, j * BW),
            ),
        ],
        out_specs=pl.BlockSpec((BH, BW), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gh * BH, gw * BW), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:H, :W]
