"""SSAM Pallas TPU kernels (+ interpret-mode CPU validation + jnp oracles).

Modules: ``ssam_conv2d``, ``ssam_stencil2d``, ``ssam_stencil3d``,
``ssam_conv1d``, ``ssam_scan`` (kernels); ``ops`` (public jit'd API with
backend dispatch); ``ref`` (pure-jnp oracles); ``stencils`` (Table 3
benchmark definitions).
"""
from . import ops, ref, stencils  # noqa: F401
