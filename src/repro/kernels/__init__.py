"""SSAM kernels: thin plan builders over the generic Pallas engine.

Modules: ``ssam_conv2d``, ``ssam_stencil2d``, ``ssam_stencil3d``,
``ssam_conv1d``, ``ssam_scan`` (plan builders lowered by
:mod:`repro.core.engine`); ``ops`` (public jit'd API with backend
dispatch + the §5 autotune path); ``ref`` (pure-jnp oracles);
``stencils`` (Table 3 benchmark definitions).
"""
from . import ops, ref, stencils  # noqa: F401
