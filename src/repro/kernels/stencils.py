"""Stencil definitions for the paper's Table 3 benchmark suite.

Star and box stencil generators for 2-D/3-D grids plus the ``poisson``
operator. Each benchmark is a named :class:`StencilDef` holding the tap
offsets, deterministic coefficients (diffusion-like: positive, summing to
1 so iterates stay bounded) and the paper's FPP metadata used to convert
GCells/s → GFLOP/s in the benchmark tables.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class StencilDef:
    name: str
    ndim: int
    offsets: tuple[tuple[int, ...], ...]
    coeffs: tuple[float, ...]
    order: int          # k in Table 3
    fpp: int            # FLOPs-per-point metadata from Table 3

    @property
    def radius(self) -> int:
        return max(max(abs(c) for c in off) for off in self.offsets)


def _norm_coeffs(n: int) -> tuple[float, ...]:
    """Deterministic positive coefficients summing to 1 (diffusion-like)."""
    raw = np.arange(1, n + 1, dtype=np.float64)
    raw = 1.0 + 0.1 * np.sin(raw)          # break symmetry, stay positive
    return tuple((raw / raw.sum()).tolist())


def star2d(k: int) -> tuple[tuple[int, int], ...]:
    offs = [(0, 0)]
    for r in range(1, k + 1):
        offs += [(-r, 0), (r, 0), (0, -r), (0, r)]
    return tuple(offs)


def box2d(r: int) -> tuple[tuple[int, int], ...]:
    return tuple((dy, dx) for dy in range(-r, r + 1) for dx in range(-r, r + 1))


def rect2d(h: int, w: int) -> tuple[tuple[int, int], ...]:
    """h×w dense rectangle anchored top-left (for even-size stencils)."""
    return tuple((dy, dx) for dy in range(h) for dx in range(w))


def star3d(k: int) -> tuple[tuple[int, int, int], ...]:
    offs = [(0, 0, 0)]
    for r in range(1, k + 1):
        offs += [(-r, 0, 0), (r, 0, 0), (0, -r, 0), (0, r, 0), (0, 0, -r), (0, 0, r)]
    return tuple(offs)


def box3d(r: int) -> tuple[tuple[int, int, int], ...]:
    return tuple(
        (dz, dy, dx)
        for dz in range(-r, r + 1)
        for dy in range(-r, r + 1)
        for dx in range(-r, r + 1)
    )


def _mk(name: str, ndim: int, offsets, order: int, fpp: int) -> StencilDef:
    return StencilDef(name, ndim, tuple(offsets), _norm_coeffs(len(offsets)), order, fpp)


# Table 3 of the paper. 2dXpt with X=5,9,13,17,21 and 2ds25pt are star
# stencils of order k; 2d25/64/81/121pt are dense boxes; poisson is the
# classic 3-D 19-point Poisson operator (FPP metadata from the paper).
BENCHMARKS: dict[str, StencilDef] = {
    d.name: d
    for d in [
        _mk("2d5pt", 2, star2d(1), 1, 9),
        _mk("2d9pt", 2, star2d(2), 2, 17),
        _mk("2d13pt", 2, star2d(3), 3, 25),
        _mk("2d17pt", 2, star2d(4), 4, 33),
        _mk("2d21pt", 2, star2d(5), 5, 41),
        _mk("2ds25pt", 2, star2d(6), 6, 49),
        _mk("2d25pt", 2, box2d(2), 2, 33),
        _mk("2d64pt", 2, rect2d(8, 8), 4, 73),
        _mk("2d81pt", 2, box2d(4), 4, 95),
        _mk("2d121pt", 2, box2d(5), 5, 241),
        _mk("3d7pt", 3, star3d(1), 1, 13),
        _mk("3d13pt", 3, star3d(2), 2, 25),
        _mk("3d27pt", 3, box3d(1), 1, 30),
        _mk("3d125pt", 3, box3d(2), 2, 130),
        _mk(
            "poisson", 3,
            # 19-point 3-D Poisson operator: star-1 + face-diagonal taps.
            tuple(
                off for off in box3d(1)
                if sum(1 for c in off if c != 0) <= 2
            ),
            1, 21,
        ),
    ]
}
