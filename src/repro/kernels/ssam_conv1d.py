"""SSAM depthwise causal 1-D convolution as a plan over the engine.

The short depthwise convolution of Mamba-style blocks (Hymba's mamba
branch; RWKV's token-shift is the K=2 special case). The plan
(:func:`repro.core.plan.depthwise_conv1d_plan`) maps *channels* to the
lane axis and *time* to sublanes, so the conv taps walk the **vertical**
(in-register, cheap) direction of Fig. 1d — per the paper's §5.4
guidance to route dependencies through the cheap direction whenever the
dependency graph D allows it. No lane rolls at all: M=1. Causality, the
overlapped time-blocking, and the batch grid axis all come from the
plan's lead/batch fields via :func:`repro.core.engine.run_window_plan`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import run_window_plan
from repro.core.plan import depthwise_conv1d_plan


def plan_for(K: int):
    """The D-optimal depthwise plan for a length-``K`` filter."""
    return depthwise_conv1d_plan(K)


def conv1d_causal(
    x: jax.Array,
    w: jax.Array,
    *,
    block_t: int = 128,
    block_d: int = 128,
    interpret: bool = True,
    acc_dtype=jnp.float32,
    strategy: str | None = None,
    backend: str | None = None,
) -> jax.Array:
    """Depthwise causal conv: ``y[b,t,d] = Σ_k x[b, t−K+1+k, d] · w[k, d]``.

    Args:
      x: ``(B, T, D)`` input.
      w: ``(K, D)`` per-channel filter taps (tap K−1 multiplies x[t]).
    """
    K, Dw = w.shape
    assert Dw == x.shape[-1], (w.shape, x.shape)
    return run_window_plan(
        x, w, plan=plan_for(K), block=(block_t, block_d),
        interpret=interpret, acc_dtype=acc_dtype, strategy=strategy,
        backend=backend,
    )
