"""SSAM depthwise causal 1-D convolution Pallas kernel.

The short depthwise convolution of Mamba-style blocks (Hymba's mamba
branch; RWKV's token-shift is the K=2 special case). Layout maps
*channels* to the VREG lane axis and *time* to sublanes, so the conv taps
walk the **vertical** (in-register, cheap) direction of Fig. 1d — per the
paper's §5.4 guidance to route dependencies through the cheap direction
whenever the dependency graph D allows it. No lane rolls are needed at
all: this is the ``D``-optimal SSAM mapping for depthwise conv, with the
register cache of §4.2 (each lane caches ``C = K + BT − 1`` elements,
sliding window of ``BT`` outputs).

Overlapped blocking along time via ``pl.Element`` input specs (§4.5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv1d_kernel(x_ref, w_ref, o_ref, *, K: int, BT: int, acc_dtype):
    xb = x_ref[0].astype(acc_dtype)          # (BT + K − 1, BD)
    wb = w_ref[:].astype(acc_dtype)          # (K, BD)
    s = jnp.zeros((BT, xb.shape[1]), acc_dtype)
    for k in range(K):                       # vertical taps only (cheap dir.)
        s = s + xb[k : k + BT, :] * wb[k, :]
    o_ref[0] = s.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_t", "block_d", "interpret", "acc_dtype")
)
def conv1d_causal(
    x: jax.Array,
    w: jax.Array,
    *,
    block_t: int = 128,
    block_d: int = 128,
    interpret: bool = True,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """Depthwise causal conv: ``y[b,t,d] = Σ_k x[b, t−K+1+k, d] · w[k, d]``.

    Args:
      x: ``(B, T, D)`` input.
      w: ``(K, D)`` per-channel filter taps (tap K−1 multiplies x[t]).
    """
    B, T, D = x.shape
    K, Dw = w.shape
    assert Dw == D, (w.shape, x.shape)
    BT, BD = min(block_t, T), min(block_d, D)
    gt, gd = pl.cdiv(T, BT), pl.cdiv(D, BD)
    # causal: K−1 zeros in front; pad tail/channels up to whole tiles
    xp = jnp.pad(x, ((0, 0), (K - 1, gt * BT - T), (0, gd * BD - D)))
    wp = jnp.pad(w, ((0, 0), (0, gd * BD - D)))

    kern = functools.partial(_conv1d_kernel, K=K, BT=BT, acc_dtype=acc_dtype)
    out = pl.pallas_call(
        kern,
        grid=(B, gt, gd),
        in_specs=[
            pl.BlockSpec(
                (pl.Element(1), pl.Element(BT + K - 1), pl.Element(BD)),
                lambda b, i, j: (b, i * BT, j * BD),
            ),
            pl.BlockSpec((K, BD), lambda b, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, BT, BD), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, gt * BT, gd * BD), x.dtype),
        interpret=interpret,
    )(xp, wp)
    return out[:, :T, :D]
