"""Sharded systolic execution: any windowed plan on a device mesh.

This is the paper's execution model lifted one level up the memory
hierarchy. Within a device, partial sums shift through VREG lanes while
block halos ride in from neighboring grid blocks (engine, §4.5); across
devices, the *same plan geometry* (:mod:`repro.core.halo`) decides how
many rows each shard must import from its mesh neighbors, and
``lax.ppermute`` plays the role the overlapped BlockSpecs play on-chip.

Schedule per call (DESIGN.md §8):

1. **Exchange** — for every sharded domain axis, each shard pushes its
   trailing ``t·lead`` rows to its high-side neighbor and its leading
   ``t·trail`` rows to its low-side neighbor (two ``ppermute``\\ s per
   axis). Exchanging the ``time_steps``-fold widened halo once per call
   — exactly one engine-halo per temporal step, batched into a single
   push — keeps the ``t`` fused plan applications communication-free
   and reproduces the single-device pad-once semantics (bit-for-bit
   under the monolithic schedule; the overlapped schedule's frame
   recompute can differ by ≤ 1 ulp of XLA FMA contraction).
2. **Interior compute, overlapped** — the shard's interior output block
   (everything ≥ halo-width away from a sharded edge) is lowered from
   the *resident* block alone, so it has no data dependence on the
   in-flight ``ppermute``\\ s and XLA's latency-hiding scheduler can run
   exchange and interior concurrently (the double-buffer: the interior
   output fills while the halo buffers land).
3. **Frame compute** — once the halos land, the boundary frame is
   recomputed from halo-extended slabs and spliced over the interior
   result. Domain edges fall out of the collective's semantics: a
   non-circular ``ppermute`` fills unsourced shards with zeros — which
   IS the engine's own origin padding (``boundary='zero'``); circular
   links give wraparound; ``'replicate'`` clamps the edge row.

Only *shape-preserving* plan axes (``lead+trail = ext−1``: stencils,
'same'-mode convs) can be sharded — each shard then owns equal slices
of input and output and the ``shard_map`` out-spec mirrors the in-spec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import shard_map as shm
from jax.sharding import Mesh, PartitionSpec as P

from repro import obs
from repro.core.engine import run_weight_grad_plan, run_window_plan
from repro.robust import faults as rfaults
from repro.core.halo import (check_shard_geometry, extended_crop,
                             is_shape_preserving, shard_halo)
from repro.core.plan import SystolicPlan
from .sharding import mesh_axis_sizes, pspec_for_axes

BOUNDARIES = ("zero", "replicate", "wrap")

# Logical names of windowed-domain axes (lane axis last), resolved
# against the sharding rule tables when the caller passes no in_specs.
DOMAIN_AXES_2D = ("rows", "cols")
DOMAIN_AXES_3D = ("depth", "rows", "cols")


def default_domain_spec(shape, mesh: Mesh, rules=None) -> P:
    """Default PartitionSpec for a 2-D/3-D domain via the rule tables.

    Reuses :func:`repro.distributed.sharding.pspec_for_axes`, so the
    usual divisibility fallback applies: a mesh axis that does not
    divide the domain axis is skipped (replicated) rather than raising —
    explicit ``in_specs`` get the strict :class:`ValueError` treatment.
    """
    names = DOMAIN_AXES_3D if len(shape) == 3 else DOMAIN_AXES_2D
    return pspec_for_axes(names, shape, mesh, rules)


def default_plan_spec(plan: SystolicPlan, shape, mesh: Mesh, rules=None) -> P:
    """Default PartitionSpec for a plan's full input layout.

    Batch axes resolve through the rule tables' ``"batch"`` entry
    (→ the fast ``data`` axis), reduce axes stay replicated (sharding a
    contraction would need a cross-device psum), and the windowed axes
    get the usual ``rows``/``cols``/``depth`` resolution. Because
    ``pspec_for_axes`` never reuses a mesh axis, a sharded batch axis
    automatically leaves ``rows`` unsharded — batch parallelism first,
    halo exchange only where axes remain.
    """
    nb, nr = plan.batch_axes, plan.reduce_axes
    spatial = DOMAIN_AXES_3D if plan.ndim_spatial == 3 else DOMAIN_AXES_2D
    names = ("batch",) * nb + (None,) * nr + spatial
    return pspec_for_axes(names, shape, mesh, rules)


def _axis_assignments(
    spec, mesh: Mesh, ndim: int
) -> tuple[tuple[str, int] | None, ...]:
    """Resolve a PartitionSpec into per-domain-axis (mesh_axis, size)."""
    sizes = mesh_axis_sizes(mesh)
    entries = list(spec) + [None] * (ndim - len(tuple(spec)))
    if len(entries) > ndim:
        raise ValueError(
            f"in_specs {tuple(spec)} has more entries than the domain has "
            f"axes ({ndim})")
    out: list[tuple[str, int] | None] = []
    for a, e in enumerate(entries):
        if e is None:
            out.append(None)
            continue
        if isinstance(e, (tuple, list)):
            if len(e) != 1:
                raise ValueError(
                    f"domain axis {a} requests mesh axes {e}: halo exchange "
                    "shards each domain axis over at most one mesh axis")
            e = e[0]
        if e not in sizes:
            raise ValueError(
                f"in_specs names mesh axis {e!r} but the mesh has axes "
                f"{tuple(sizes)}")
        out.append((e, sizes[e]))
    return tuple(out)


def _edge_slab(x, axis: int, width: int, *, front: bool):
    """``width`` copies of the domain-edge row — the clamp boundary."""
    n = x.shape[axis]
    sl = lax.slice_in_dim(x, 0, 1, axis=axis) if front else \
        lax.slice_in_dim(x, n - 1, n, axis=axis)
    return jnp.concatenate([sl] * width, axis=axis)


def _multihop_slab(x, axis: int, width: int, name: str, size: int,
                   boundary: str, *, front: bool):
    """Halo slab spanning SEVERAL neighbor shards: chained ppermute hops.

    When the t-widened halo is wider than one shard's resident rows, no
    single neighbor owns the whole slab. Hop ``d`` ships each shard's
    *full* resident block ``d`` shards toward the consumer (one
    ``ppermute`` per hop — a chain of ``ceil(width/shard)`` collectives,
    not a raise); the stacked blocks then crop to the halo width.
    Out-of-domain rows resolve per boundary exactly as in the single-hop
    path: a non-circular ``ppermute`` fills them with zeros (the
    engine's origin padding), ``'wrap'`` uses circular links (mod-size
    sources), and ``'replicate'`` overwrites them with the *global*
    edge row — psum-broadcast from the shard that owns it, then masked
    in per slab row, since with a multi-shard halo several shards clamp
    and only partially.
    """
    n = x.shape[axis]
    hops = -(-width // n)
    blocks = []
    for d in range(1, hops + 1):
        if boundary == "wrap":
            pairs = ([(i, (i + d) % size) for i in range(size)] if front
                     else [((i + d) % size, i) for i in range(size)])
        else:
            pairs = ([(i, i + d) for i in range(size - d)] if front
                     else [(i + d, i) for i in range(size - d)])
        blocks.append(lax.ppermute(x, name, pairs))
    if front:
        # farthest neighbor's rows sit earliest in the global order
        stack = jnp.concatenate(blocks[::-1], axis=axis)
        slab = lax.slice_in_dim(stack, hops * n - width, hops * n, axis=axis)
    else:
        stack = jnp.concatenate(blocks, axis=axis)
        slab = lax.slice_in_dim(stack, 0, width, axis=axis)
    if boundary == "replicate":
        idx = lax.axis_index(name)
        edge_shard = 0 if front else size - 1
        one = _edge_slab(x, axis, 1, front=front)
        edge = lax.psum(jnp.where(idx == edge_shard, one,
                                  jnp.zeros_like(one)), name)
        tiled = jnp.concatenate([edge] * width, axis=axis)
        # slab row j of shard i holds global row i·n − width + j (front)
        # or (i+1)·n + j (back); rows beyond the domain edge clamp.
        iota = lax.broadcasted_iota(jnp.int32, slab.shape, axis)
        oob = (iota < width - idx * n) if front else \
            (iota >= (size - 1 - idx) * n)
        slab = jnp.where(oob, tiled, slab)
    return slab


def _halo_slab(x, axis: int, width: int, assign, boundary: str, *,
               front: bool):
    """One side's halo slab for one axis, or None when nothing to add.

    ``front=True`` is the low-side halo: each shard *pushes* its
    trailing ``width`` rows to its high-side neighbor (and receives
    symmetrically), so the slab this shard prepends is what its low
    neighbor pushed. On a domain edge a non-circular ``ppermute``
    delivers zeros — the engine's own origin padding — unless the
    boundary wraps (circular link) or clamps (edge-row replication).
    Halos wider than one shard chain ppermute hops
    (:func:`_multihop_slab`). Unsharded axes synthesize the same slab
    locally; for ``'zero'`` that is a no-op because the engine already
    zero-pads.
    """
    if width == 0:
        return None
    name, size = assign if assign is not None else (None, 1)
    n = x.shape[axis]
    if size > 1 and width > n:
        return _multihop_slab(x, axis, width, name, size, boundary,
                              front=front)
    if front:
        src = lax.slice_in_dim(x, n - width, n, axis=axis)
    else:
        src = lax.slice_in_dim(x, 0, width, axis=axis)
    if size > 1:
        if front:
            pairs = [(i, i + 1) for i in range(size - 1)]
        else:
            pairs = [(i + 1, i) for i in range(size - 1)]
        if boundary == "wrap":
            pairs.append((size - 1, 0) if front else (0, size - 1))
        slab = lax.ppermute(src, name, pairs)
        if boundary == "replicate":
            edge = 0 if front else size - 1
            slab = jnp.where(lax.axis_index(name) == edge,
                             _edge_slab(x, axis, width, front=front), slab)
        return slab
    if boundary == "wrap":
        return src
    if boundary == "replicate":
        return _edge_slab(x, axis, width, front=front)
    return None      # zero boundary, unsharded: engine origin pad covers it


def _extend_axis(x, axis: int, lo: int, hi: int, assign, boundary: str):
    """Halo-extend ``x`` along one axis (no-op when nothing to add)."""
    front = _halo_slab(x, axis, lo, assign, boundary, front=True)
    back = _halo_slab(x, axis, hi, assign, boundary, front=False)
    parts = [p for p in (front, x, back) if p is not None]
    return x if len(parts) == 1 else jnp.concatenate(parts, axis=axis)


# ---------------------------------------------------------------------------
# The sharded lowering
# ---------------------------------------------------------------------------

def _frame_regions(
    local_out: tuple[int, ...],
    halos: tuple[tuple[int, int], ...],
    exchanged: tuple[int, ...],
) -> list[tuple[tuple[int, int], ...]]:
    """Decompose the boundary frame into slabs, one list entry per slab.

    Axis-``a`` slabs span the full extent of later axes and are
    restricted to the interior of earlier exchanged axes, so every
    frame cell (corners included) is covered exactly by the first
    exchanged axis that owns it.
    """
    regions = []
    for k, a in enumerate(exchanged):
        lo, hi = halos[a]
        base = []
        for ax, n in enumerate(local_out):
            if ax in exchanged[:k]:
                l2, h2 = halos[ax]
                base.append((l2, n - h2))
            else:
                base.append((0, n))
        if any(b[0] >= b[1] for b in base):
            continue        # earlier axes' full-width slabs already cover it
        if lo:
            regions.append(tuple(
                (0, lo) if ax == a else b for ax, b in enumerate(base)))
        if hi:
            regions.append(tuple(
                (local_out[a] - hi, local_out[a]) if ax == a else b
                for ax, b in enumerate(base)))
    return [r for r in regions if all(b[0] < b[1] for b in r)]


def _local_lowering(
    xl, wl, epi, *, plan, block, time_steps, variant, boundary, interpret,
    acc_dtype, assigns, halos, overlap, backend=None,
):
    """The per-shard program: exchange → interior compute → frame splice.

    Batched plans pass through transparently: batch/reduce axes sit
    ahead of the windowed axes on the input (``in_off``) and batch/out
    axes ahead of them on the output (``out_off``); halo extension,
    cropping and the frame splice all index relative to those offsets,
    while the batch entries themselves were already scattered by
    ``shard_map`` (no exchange — batch items are independent).
    """
    nd = plan.ndim_spatial
    in_off = plan.batch_axes + plan.reduce_axes
    out_off = plan.batch_axes + plan.out_axes
    pre_in = (slice(None),) * in_off
    pre_out = (slice(None),) * out_off
    local = xl.shape[in_off:]
    ext = xl
    for a in range(nd):
        lo, hi = halos[a]
        assign = assigns[a]
        if (lo or hi) and assign is not None and assign[1] > 1:
            # A cross-device exchange on this axis. This runs inside the
            # shard_map trace, so the span and counters fire once per
            # compilation with *static* accounting: per-shard slab bytes
            # (both sides) and the ppermute hop count (halos wider than
            # a shard chain ceil(width/n) hops, _multihop_slab).
            n = ext.shape[in_off + a]
            slab_bytes = ((lo + hi) * (ext.size // max(n, 1))
                          * ext.dtype.itemsize)
            hops = sum(-(-width // n) for width in (lo, hi) if width)
            obs.metrics.inc("halo.exchanges", f"axis{a}")
            obs.metrics.inc("halo.bytes", f"axis{a}", n=slab_bytes)
            with obs.span("halo.exchange", cat="halo", kind=plan.kind,
                          axis=a, lo=lo, hi=hi, mesh_axis=assign[0],
                          shards=assign[1], slab_bytes=slab_bytes,
                          hops=hops, boundary=boundary):
                ext = _extend_axis(ext, in_off + a, lo, hi, assign,
                                   boundary)
        else:
            ext = _extend_axis(ext, in_off + a, lo, hi, assign, boundary)
    exchanged = tuple(
        a for a in range(nd) if ext.shape[in_off + a] != local[a])

    # Epilogue operands replicate to every shard (per-channel bias /
    # scalars — residuals are refused upstream); the epilogue itself is
    # elementwise, so applying it per engine call (interior and frame
    # strips alike) matches the single-device fused store.
    engine = functools.partial(
        run_window_plan, plan=plan, block=block, time_steps=time_steps,
        variant=variant, interpret=interpret, acc_dtype=acc_dtype,
        epilogue_args=epi, backend=backend)

    def cropped(e):
        """Engine output on a (partially) extended slab, mapped back to
        the rows the slab's un-extended origin owns."""
        out = engine(e, wl) if wl is not None else engine(e)
        sl = tuple(
            extended_crop(plan, time_steps, a, local[a])
            if a in exchanged else slice(0, local[a])
            for a in range(nd))
        return out[pre_out + sl]

    if not exchanged:
        return cropped(ext)
    if not overlap or any(halos[a][0] + halos[a][1] >= local[a]
                          for a in exchanged):
        # A halo as wide as the shard leaves no interior to overlap with
        # the exchange (the multi-hop regime) — lower the extended block
        # monolithically instead of splicing an empty frame.
        return cropped(ext)

    # Overlapped schedule: the interior lowers from the *resident* block
    # (no data dependence on the in-flight ppermutes), the frame lowers
    # from halo-extended slabs once they land.
    interior = engine(xl, wl) if wl is not None else engine(xl)
    out = interior
    for region in _frame_regions(local, halos, exchanged):
        slab_sl, out_sl, strip_crop = [], [], []
        for a, (lo_r, hi_r) in enumerate(region):
            out_sl.append(slice(lo_r, hi_r))
            if a in exchanged:
                # Output row i reads extended rows [i, i + lo + hi], so
                # the slab for out rows [lo_r, hi_r) is that union and
                # the strip sits ``lo`` rows into the slab's output.
                lo_h, hi_h = halos[a]
                slab_sl.append(slice(lo_r, hi_r + lo_h + hi_h))
                strip_crop.append(slice(lo_h, lo_h + (hi_r - lo_r)))
            else:
                slab_sl.append(slice(None))
                strip_crop.append(slice(lo_r, hi_r))
        strip = ext[pre_in + tuple(slab_sl)]
        s_out = engine(strip, wl) if wl is not None else engine(strip)
        out = out.at[pre_out + tuple(out_sl)].set(
            s_out[pre_out + tuple(strip_crop)])
    return out


def validate_sharded_call(x, plan: SystolicPlan, mesh: Mesh,
                          in_spec: P | None = None, *, time_steps: int = 1,
                          boundary: str = "zero", rules=None):
    """Every pre-``pallas_call`` check of :func:`sharded_window_plan`.

    Factored out so the §16 guard can run it *before* entering the
    degradation lattice: these are configuration errors (a sharded
    reduce axis, a non-shape-preserving plan, halo-vs-shard geometry),
    and a lattice level that drops the mesh would otherwise "recover"
    from user misuse by silently computing something else. Returns the
    resolved ``(in_spec, batch_assigns, spatial_assigns, halos,
    local_shape)`` for the caller to lower with.
    """
    if boundary not in BOUNDARIES:
        raise ValueError(f"boundary must be one of {BOUNDARIES}, "
                         f"got {boundary!r}")
    if boundary == "replicate" and time_steps != 1:
        raise ValueError(
            "boundary='replicate' supports time_steps=1 only: a clamped "
            "halo is static while the true clamped boundary evolves under "
            "temporal fusion")
    nb, nr, nd = plan.batch_axes, plan.reduce_axes, plan.ndim_spatial
    if x.ndim != nb + nr + nd:
        raise ValueError(f"{plan.kind!r} plan wants a "
                         f"{nb + nr + nd}-D input, got shape {x.shape}")
    for a in range(nd):
        if not is_shape_preserving(plan, a):
            raise ValueError(
                f"sharded execution needs a shape-preserving plan "
                f"(lead+trail = ext−1 on every axis) so shards own equal "
                f"input and output slices; {plan.kind!r} violates this on "
                f"axis {a}. For conv2d use mode='same' "
                "(core.plan.conv2d_same_plan).")
    if in_spec is None:
        in_spec = default_plan_spec(plan, x.shape, mesh, rules)
    all_assigns = _axis_assignments(in_spec, mesh, nb + nr + nd)
    batch_assigns = all_assigns[:nb]
    for a, assign in enumerate(all_assigns[nb:nb + nr]):
        if assign is not None:
            raise ValueError(
                f"reduce axis {a} of a {plan.kind!r} plan cannot be "
                f"sharded (mesh axis {assign[0]!r}): the channel "
                "reduction is carried in the engine's accumulator, not a "
                "cross-device psum; shard the batch or spatial axes")
    for a, (n, assign) in enumerate(zip(x.shape[:nb], batch_assigns)):
        if assign is not None and n % assign[1] != 0:
            raise ValueError(
                f"mesh axis {assign[0]!r} (size {assign[1]}) does not "
                f"divide batch axis {a} (size {n}) for {plan.kind!r}")
    assigns = all_assigns[nb + nr:]
    local = check_shard_geometry(plan, x.shape[nb + nr:], assigns,
                                 time_steps)
    halos = shard_halo(plan, time_steps)
    if boundary != "zero":
        # wrap/replicate also extend UNSHARDED axes, locally — the
        # resident block must cover the halo it lends itself. Sharded
        # axes are exempt: halos wider than a shard chain ppermute hops
        # (:func:`_multihop_slab`) instead of slicing the resident rows.
        for a, ((lo, hi), n) in enumerate(zip(halos, local)):
            if (assigns[a] is None or assigns[a][1] == 1) \
                    and max(lo, hi) > n:
                raise ValueError(
                    f"boundary={boundary!r} needs the local block to cover "
                    f"its own axis-{a} halo: {n} rows per shard < "
                    f"({lo}, {hi}) halo")
    from repro.core.plan import epilogue_operand_stages
    for st in epilogue_operand_stages(plan.final_epilogue()):
        if st.op == "residual_add":
            raise ValueError(
                "a residual_add epilogue cannot ride a sharded call: the "
                "residual operand is output-shaped and would need the "
                "same sharding; add the residual outside the mesh call")
    return in_spec, batch_assigns, assigns, halos, local


def sharded_window_plan(
    x: jax.Array,
    w: jax.Array | None = None,
    *,
    plan: SystolicPlan,
    mesh: Mesh,
    in_spec: P | None = None,
    block: tuple[int, ...],
    time_steps: int = 1,
    variant: str = "shift_psum",
    boundary: str = "zero",
    overlap: bool = True,
    interpret: bool = True,
    acc_dtype=jnp.float32,
    rules=None,
    epilogue_args: tuple = (),
    backend: str | None = None,
) -> jax.Array:
    """Run a windowed plan on a domain sharded over a device mesh.

    Args:
      x: the global domain, lane axis last, with the plan's batch and
        reduce axes (if any) leading. May be host-global; ``shard_map``
        scatters it per ``in_spec``.
      w: runtime coefficients (replicated to every shard), or None.
      plan: any windowed :class:`SystolicPlan` whose sharded *spatial*
        axes are shape-preserving. Batch axes shard without any halo
        exchange (items are independent); reduce axes must stay
        replicated (a sharded contraction would need a psum).
      mesh: a 1-D/2-D device mesh (e.g. ``launch.mesh.make_domain_mesh``).
      in_spec: PartitionSpec mapping input axes (batch + reduce +
        domain) to mesh axes; at most one mesh axis per axis. Defaults
        to the rule-table resolution of :func:`default_plan_spec`.
      block / time_steps / variant / interpret / acc_dtype: forwarded to
        the engine, per shard.
      boundary: 'zero' (the engine's semantics — domain-edge shards
        receive the origin padding from the collective itself), 'wrap'
        (torus), or 'replicate' (edge clamp; ``time_steps == 1`` only,
        a static clamped halo does not commute with temporal fusion).
      overlap: lower the interior from the resident block concurrently
        with the exchange, then splice the frame (DESIGN.md §8); with
        False, one monolithic engine call on the extended block. The two
        schedules run the same per-output math and agree to ≤ 1 ulp
        (XLA may contract FMAs differently in the recomputed frame).

    Returns:
      The plan's output (batch + out + spatial axes), batch and spatial
      axes sharded exactly like the input.
    """
    in_spec, batch_assigns, assigns, halos, local = validate_sharded_call(
        x, plan, mesh, in_spec, time_steps=time_steps, boundary=boundary,
        rules=rules)
    nb, nr, no, nd = (plan.batch_axes, plan.reduce_axes, plan.out_axes,
                      plan.ndim_spatial)

    b_names = tuple(a[0] if a else None for a in batch_assigns)
    s_names = tuple(a[0] if a else None for a in assigns)
    spec_in = P(*b_names, *((None,) * nr), *s_names)
    spec_out = P(*b_names, *((None,) * no), *s_names)
    n_w = 1 if w is not None else 0
    # fused plans pass a tuple of per-stage filters — replicate each leaf
    w_args = (w,) if n_w else ()
    w_specs = (jax.tree.map(lambda _: P(), w),) if n_w else ()
    epi = tuple(epilogue_args)
    epi_specs = tuple(P() for _ in epi)

    fn = functools.partial(
        _local_lowering, plan=plan, block=block, time_steps=time_steps,
        variant=variant, boundary=boundary, interpret=interpret,
        acc_dtype=acc_dtype, assigns=assigns, halos=halos, overlap=overlap,
        backend=backend)

    sharded = shm.shard_map(
        lambda xs, *rest: fn(xs, rest[0] if n_w else None,
                             tuple(rest[n_w:])),
        mesh=mesh,
        in_specs=(spec_in,) + w_specs + epi_specs,
        out_specs=spec_out,
        check_rep=False,
    )
    rfaults.check("halo.exchange")
    obs.metrics.inc("halo.launch", plan.kind)
    with obs.span("halo.sharded_window_plan", cat="halo", kind=plan.kind,
                  devices=mesh.size, overlap=overlap, boundary=boundary):
        return sharded(x, *w_args, *epi)


# ---------------------------------------------------------------------------
# Sharded adjoint: backward-weight (DESIGN.md §10)
#
# The backward-*input* of a sharded plan needs no code here at all: the
# adjoint plan (core.adjoint.input_adjoint_plan) swaps lead and trail,
# so running it through sharded_window_plan with the same mesh/in_spec
# reverses the direction of every ppermute halo push automatically —
# the transposed dataflow falls out of the unchanged geometry machinery.
# ---------------------------------------------------------------------------

def sharded_weight_grad(
    x: jax.Array,
    g: jax.Array,
    *,
    plan: SystolicPlan,
    mesh: Mesh,
    in_spec: P | None = None,
    block: tuple[int, ...] = (8, 128),
    boundary: str = "zero",
    interpret: bool = True,
    acc_dtype=jnp.float32,
    rules=None,
) -> jax.Array:
    """``∂L/∂w`` of a sharded windowed-plan call, replicated to all shards.

    A shard's cotangent rows ``o`` pair with forward-input rows
    ``[o − lead, o + trail]`` — exactly the forward's shard halo — so
    the same single-hop ppermute pushes materialize the needed context
    (zeros beyond the domain edge under ``boundary='zero'``, the wrapped
    image under ``'wrap'``). Each shard then runs
    :func:`repro.core.engine.run_weight_grad_plan` on its halo-extended
    local block (batch + local spatial tiles as the grid's reduce
    sweep), and the partial filter gradients ``psum`` over every mesh
    axis the ``in_spec`` actually shards — batch axes included, since
    batch items are independent forward but *summed* in the weight
    gradient.
    """
    nb, nr, no, nd = (plan.batch_axes, plan.reduce_axes, plan.out_axes,
                      plan.ndim_spatial)
    if in_spec is None:
        in_spec = default_plan_spec(plan, x.shape, mesh, rules)
    all_assigns = _axis_assignments(in_spec, mesh, nb + nr + nd)
    batch_assigns, assigns = all_assigns[:nb], all_assigns[nb + nr:]
    check_shard_geometry(plan, x.shape[nb + nr:], assigns, 1)
    halos = shard_halo(plan, 1)
    in_off = nb + nr
    psum_axes = tuple(dict.fromkeys(
        a[0] for a in batch_assigns + assigns if a is not None))

    def local(xl, gl):
        ext = xl
        for a in range(nd):
            lo, hi = halos[a]
            front = _halo_slab(ext, in_off + a, lo, assigns[a], boundary,
                               front=True)
            back = _halo_slab(ext, in_off + a, hi, assigns[a], boundary,
                              front=False)
            # unsharded zero-boundary axes get no slab from the
            # collective — materialize the origin padding locally so the
            # engine sees a uniformly pre-padded block.
            def zeros(width):
                shape = list(ext.shape)
                shape[in_off + a] = width
                return jnp.zeros(shape, ext.dtype)
            parts = [front if front is not None else (zeros(lo) if lo else None),
                     ext,
                     back if back is not None else (zeros(hi) if hi else None)]
            parts = [p for p in parts if p is not None]
            ext = parts[0] if len(parts) == 1 else jnp.concatenate(
                parts, axis=in_off + a)
        dw = run_weight_grad_plan(ext, gl, plan=plan, block=block,
                                  interpret=interpret, acc_dtype=acc_dtype,
                                  pre_padded=True)
        return lax.psum(dw, psum_axes) if psum_axes else dw

    b_names = tuple(a[0] if a else None for a in batch_assigns)
    s_names = tuple(a[0] if a else None for a in assigns)
    sharded = shm.shard_map(
        local, mesh=mesh,
        in_specs=(P(*b_names, *((None,) * nr), *s_names),
                  P(*b_names, *((None,) * no), *s_names)),
        out_specs=P(),
        check_rep=False,
    )
    rfaults.check("halo.exchange")
    return sharded(x, g)
