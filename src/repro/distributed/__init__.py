"""Distribution substrate: logical-axis sharding rules, collectives,
compression, and the sharded systolic halo-exchange layer
(:mod:`repro.distributed.halo_exchange`)."""
