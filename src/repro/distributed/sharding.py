"""Logical-axis → mesh-axis sharding rules (MaxText-style, per-arch overridable).

Parameters and activations carry *logical* axis names ("embed", "heads",
"ff", …). A rule table maps logical names to mesh axes; `pspec_for_axes`
resolves a concrete `PartitionSpec`, skipping any assignment that does
not divide the dimension or would reuse a mesh axis twice — this is what
lets one rule table serve 10 architectures (a 4-head model simply leaves
"heads" unsharded on a 16-way model axis instead of failing).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default logical rules for the production meshes ("pod", "data", "model").
# Entries may be a single mesh axis or a tuple (sharded over both).
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "embed": (),                # replicated by default (TP shards ff/heads)
    "ff": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "seq": (),                  # overridden to ("data",) for SP prefill cells
    "cache_seq": (),            # overridden to ("model",) for long-context decode
    "layers": (),
    "head_dim": (),
    "state": (),
    "lora": (),
    "conv": (),
    "conv_in": (),              # conv2d filter channels: replicated —
    "conv_out": (),             # the engine shards activations instead
    # Windowed-kernel domain axes (halo_exchange): stencil/conv grids
    # shard rows over the fast "data" axis and lanes over "model";
    # the Z extent of 3-D domains stays resident per shard.
    "depth": (),
    "rows": ("data",),
    "cols": ("model",),
}


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def pspec_for_axes(
    axes: Sequence[str | None],
    shape: Sequence[int],
    mesh: Mesh,
    rules: Mapping[str, tuple[str, ...]] | None = None,
) -> P:
    """Resolve logical axes to a PartitionSpec under divisibility constraints."""
    rules = dict(DEFAULT_RULES, **(rules or {}))
    sizes = mesh_axis_sizes(mesh)
    used: set[str] = set()
    entries = []
    for dim, name in zip(shape, axes):
        assignment: tuple[str, ...] = ()
        if name is not None:
            cand = rules.get(name, ())
            if isinstance(cand, str):
                cand = (cand,)
            picked = []
            prod = 1
            for ax in cand:
                if ax in used or ax not in sizes:
                    continue
                if dim % (prod * sizes[ax]) == 0:
                    picked.append(ax)
                    prod *= sizes[ax]
            assignment = tuple(picked)
            used.update(assignment)
        if len(assignment) == 0:
            entries.append(None)
        elif len(assignment) == 1:
            entries.append(assignment[0])
        else:
            entries.append(assignment)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def shardings_for_specs(spec_tree, mesh: Mesh, rules=None):
    """NamedSharding tree for a ParamSpec tree."""
    from repro.nn import spec as pspec_mod  # deferred: avoids import cycle

    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, pspec_for_axes(s.axes, s.shape, mesh, rules)
        ),
        spec_tree,
        is_leaf=pspec_mod.is_spec,
    )


# ---------------------------------------------------------------------------
# Mesh context: lets model code constrain intermediate activations without
# threading the mesh through every call.
# ---------------------------------------------------------------------------

_ctx = threading.local()


@contextlib.contextmanager
def mesh_context(mesh: Mesh, rules: Mapping[str, tuple[str, ...]] | None = None):
    prev = getattr(_ctx, "value", None)
    _ctx.value = (mesh, dict(DEFAULT_RULES, **(rules or {})))
    try:
        yield
    finally:
        _ctx.value = prev


def current_mesh():
    v = getattr(_ctx, "value", None)
    return v


def constrain(x: jax.Array, axes: Sequence[str | None]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside mesh_context."""
    ctx = current_mesh()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = pspec_for_axes(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
