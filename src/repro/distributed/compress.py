"""Gradient compression for data-parallel all-reduce.

Two production tricks, usable in the explicit-collective (shard_map)
data-parallel path:

* **bf16 all-reduce** — halves collective bytes; error ≤ 2⁻⁸ relative,
  standard at scale. (In the pjit path the same effect comes from bf16
  params/grads; here it is explicit.)
* **int8 + error feedback** — 4× fewer bytes. Per-tensor max-abs scale;
  the quantization residual is fed back into the next step's gradient
  (Seide et al. style), which keeps SGD convergence (tested in
  tests/test_distributed.py by training a quadratic to convergence).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def psum_bf16(x: jax.Array, axis_name: str) -> jax.Array:
    return jax.lax.psum(x.astype(jnp.bfloat16), axis_name).astype(jnp.float32)


def psum_int8_ef(x: jax.Array, err: jax.Array, axis_name: str):
    """int8-compressed psum with error feedback.

    Returns (mean-reduced gradient, new error state). The int8 payload is
    summed in int32 (exact), then dequantized by the max of the
    participating scales (conservative shared scale via psum-max).
    """
    x = x + err
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    scale = jax.lax.pmax(scale, axis_name)      # shared scale
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    out = total.astype(jnp.float32) * scale / n.astype(jnp.float32)
    new_err = x - q.astype(jnp.float32) * scale  # local residual
    return out, new_err
