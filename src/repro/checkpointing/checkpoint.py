"""Fault-tolerant checkpointing.

Format: one directory per step, ``step_<n>/``:
  * ``tree.msgpack.zst``  — flattened {path: tensor-bytes} + dtype/shape
    metadata, zstd-compressed msgpack (both libs are local; no orbax).
  * ``META.json``         — step, timestamp, logical shapes, config digest.
  * ``TUNING.json``       — the autotuner's sidecar entries at save time
    (schema-stamped, see ``repro.core.tuning``); restoring a checkpoint
    merges them into the live sidecar so tuned kernel winners survive a
    host move along with the weights. Merge never clobbers: an entry the
    new host has already re-measured wins over the shipped one.
  * ``COMMIT``            — written last; a directory without it is an
    incomplete (crashed) save and is ignored by ``latest_step`` —
    atomicity without rename tricks on network filesystems.

Fault-tolerance properties:
  * **restart** — ``CheckpointManager.restore_latest()`` resumes from the
    newest committed step (tested by killing a train loop mid-run).
  * **async**   — saves run on a background thread from host copies so
    the train loop only blocks for the device→host transfer.
  * **elastic** — tensors are stored *unsharded* (gathered to host); on
    restore they are re-placed under the *current* mesh's NamedShardings,
    so a job may come back on a different device count/mesh shape
    (tested: 8→4→8 reshard round-trip).

At true multi-pod scale the gather-to-host-0 would be replaced by
per-shard files (one writer per data-parallel replica group); the format
already keys by flat tree path to make that switch local to this module.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from ..core import tuning as _tuning

_TUNING = "TUNING.json"

try:  # zstd compression is optional: bare environments fall back to raw
    import zstandard
except ImportError:  # pragma: no cover - exercised on bare images
    zstandard = None

_COMPRESSED = "tree.msgpack.zst"
_RAW = "tree.msgpack"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: dict[str, np.ndarray]):
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for path, leaf in leaves_with_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing tensor {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
        new_leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def save_checkpoint(directory: str, step: int, tree, *, meta: dict | None = None):
    """Synchronous atomic save of a pytree (gathered to host)."""
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    flat = _flatten(tree)
    payload = {
        k: {"dtype": str(v.dtype), "shape": list(v.shape),
            "data": v.tobytes()} for k, v in flat.items()
    }
    raw = msgpack.packb(payload, use_bin_type=True)
    if zstandard is not None:
        write, stale = _COMPRESSED, _RAW
        raw = zstandard.ZstdCompressor(level=3).compress(raw)
    else:
        write, stale = _RAW, _COMPRESSED
    with open(os.path.join(d, write), "wb") as f:
        f.write(raw)
    # A re-save of the same step from an env with the other format must
    # not leave the old file behind — load prefers .zst and would
    # silently restore stale weights.
    stale_path = os.path.join(d, stale)
    if os.path.exists(stale_path):
        os.remove(stale_path)
    with open(os.path.join(d, "META.json"), "w") as f:
        json.dump({"step": step, "time": time.time(), **(meta or {})}, f)
    entries = _tuning.sidecar_entries()
    if entries:
        with open(os.path.join(d, _TUNING), "w") as f:
            json.dump({"version": 1, "entries": entries}, f)
    with open(os.path.join(d, "COMMIT"), "w") as f:
        f.write("ok")
    return d


def load_checkpoint(directory: str, step: int, template, *, shardings=None):
    """Load into the structure of ``template``; optionally re-place under
    ``shardings`` (elastic restore onto a different mesh)."""
    d = os.path.join(directory, f"step_{step:08d}")
    zst_path = os.path.join(d, _COMPRESSED)
    if os.path.exists(zst_path):
        if zstandard is None:
            raise RuntimeError(
                f"{zst_path} is zstd-compressed but the 'zstandard' package "
                "is not installed (pip install repro-ssam[compress])")
        with open(zst_path, "rb") as f:
            raw = zstandard.ZstdDecompressor().decompress(f.read())
    else:
        with open(os.path.join(d, _RAW), "rb") as f:
            raw = f.read()
    payload = msgpack.unpackb(raw, raw=False)
    flat = {
        k: np.frombuffer(v["data"], dtype=v["dtype"]).reshape(v["shape"])
        for k, v in payload.items()
    }
    tree = _unflatten_into(template, flat)
    tuning_path = os.path.join(d, _TUNING)
    if os.path.exists(tuning_path):
        with open(tuning_path) as f:
            doc = json.load(f)
        # Never clobber: entries this host already tuned (possibly under a
        # newer schema) win over the shipped ones; stale-schema shipped
        # entries are dropped by merge_sidecar_entries itself.
        _tuning.merge_sidecar_entries(doc.get("entries", {}))
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and os.path.exists(
                os.path.join(directory, name, "COMMIT")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = True
    _thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, *, meta: dict | None = None):
        """Device→host copy now; serialization on a background thread."""
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)   # blocks on transfer only

        def work():
            save_checkpoint(self.directory, step, host_tree, meta=meta)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_")
            and os.path.exists(os.path.join(self.directory, n, "COMMIT")))
        for s in steps[: -self.keep] if self.keep else []:
            d = os.path.join(self.directory, f"step_{s:08d}")
            for f in os.listdir(d):
                os.remove(os.path.join(d, f))
            os.rmdir(d)

    def restore_latest(self, template, *, shardings=None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, load_checkpoint(self.directory, step, template,
                                     shardings=shardings)
