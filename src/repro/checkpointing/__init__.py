"""Checkpointing: sharded msgpack+zstd snapshots, async save, elastic restore."""
from .checkpoint import (CheckpointManager, load_checkpoint, save_checkpoint)  # noqa: F401
