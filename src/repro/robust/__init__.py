"""Guarded engine execution (DESIGN.md §16).

Three pillars, layered on PR 9's telemetry:

* :mod:`repro.robust.faults` — named, deterministic fault-injection
  sites threaded through engine lowering, tuner measurement, sidecar
  bytes, halo exchange, and the decode-server step.  Off by default
  (one bool read); armed via :func:`faults.inject` or
  ``$REPRO_FAULTS``.
* :mod:`repro.robust.guard` — the degradation lattice every
  engine-lowered ``ops.*`` call dispatches through: tuned config →
  default config → alternate strategy/backend → reference/XLA oracle,
  under ``on_failure='fallback'|'raise'`` with every demotion visible
  in ``obs.metrics`` and the open trace span.
* Hardened tuning + serving live in their home modules
  (``core/tuning.py``, ``launch/serve.py``) and report through the
  same counters.
"""
from __future__ import annotations

from . import faults, guard
from .faults import FaultInjected, inject
from .guard import (GuardedExecutionError, MeasurementError, NumericsError,
                    SidecarError, checking_numerics, failure_policy)

__all__ = [
    "faults", "guard", "inject", "FaultInjected", "GuardedExecutionError",
    "NumericsError", "MeasurementError", "SidecarError", "failure_policy",
    "checking_numerics",
]
