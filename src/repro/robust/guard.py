"""Guarded execution: the degradation lattice behind every ``ops.*`` call.

:func:`run` takes an ordered list of execution *levels* — for a windowed
op typically ``tuned → default → alternate strategy/backend → reference
oracle`` — and serves the result of the first level that succeeds.  What
"succeeds" means, and what happens when nothing does, is set by the
failure policy (``repro.config.on_failure``):

- ``'fallback'`` (the production default): a failing level demotes to
  the next one.  Every demotion bumps the ``robust.demotion`` counter
  (label ``op:from->to``) and annotates the open trace span, so
  degradations are observable, never silent.  If every level fails, the
  last *real* error re-raises unchanged (an injected fault or numerics
  trip with no surviving level raises :class:`GuardedExecutionError`).
- ``'raise'`` (the test-suite default, pinned in tests/conftest.py): an
  injected fault or numerics trip surfaces immediately as a structured
  :class:`GuardedExecutionError` naming the site; any *other* exception
  re-raises completely unchanged, so pre-existing validation errors
  (``ops.stencil: ...`` ValueErrors etc.) keep their types and messages.

The opt-in numerics guard (``repro.config.check_numerics``) treats a
non-finite concrete output as a level failure under the same policy.
Outputs that are still tracers (a guarded op called inside a user
``jax.jit``) are skipped — trace-time values carry no numerics.

Ordering rationale for the lattice lives in DESIGN.md §16.3: each step
down gives up performance before it gives up the engine, and gives up
the engine before it gives up the answer.  The final level is always a
pure-XLA reference oracle, which shares no lowering code with the
engine, so a lowering bug cannot take out its own fallback.

Overhead discipline: with no failure, :func:`run` is one ``try`` around
the primary thunk — no policy read, no config import, no allocation
beyond the level list the caller built.
"""

from __future__ import annotations

import contextlib
from typing import Any, Callable, Sequence

from repro import obs
from repro.robust import faults


# ---------------------------------------------------------------------------
# Structured errors
# ---------------------------------------------------------------------------


class GuardedExecutionError(RuntimeError):
    """A guarded op failed (or was configured to surface a failure).

    ``op`` is the guarded surface (e.g. ``"stencil"``), ``failures`` the
    ``(level, exception)`` chain that was attempted, ``site`` the first
    injection site implicated (``None`` for organic failures).
    """

    def __init__(self, op: str, failures: Sequence[tuple[str, Exception]]):
        self.op = op
        self.failures = list(failures)
        self.site = next(
            (e.site for _, e in self.failures if isinstance(e, faults.FaultInjected)),
            None,
        )
        chain = "; ".join(
            f"level '{lvl}': {type(e).__name__}: {e}" for lvl, e in self.failures
        )
        at = f" at site '{self.site}'" if self.site else ""
        super().__init__(f"guarded op '{op}' failed{at} ({chain})")


class NumericsError(RuntimeError):
    """A guarded level produced non-finite output (REPRO_CHECK_NUMERICS)."""

    def __init__(self, op: str, level: str):
        super().__init__(
            f"guarded op '{op}' level '{level}' produced non-finite output"
        )
        self.op = op
        self.level = level


class MeasurementError(RuntimeError):
    """A tuner candidate measurement was unusable — non-finite/negative
    median or non-finite kernel output (site ``tuning.measure``)."""


class SidecarError(RuntimeError):
    """A tuning-sidecar load/save failed under ``on_failure='raise'``
    (sites ``tuning.sidecar.load`` / ``tuning.sidecar.save``)."""


# ---------------------------------------------------------------------------
# Policy accessors — lazy config import (config pulls in models.base; the
# guard must stay importable from anywhere in core/ without cycles).
# ---------------------------------------------------------------------------


def on_failure() -> str:
    from repro import config

    return config.on_failure()


def set_on_failure(mode: str | None) -> None:
    from repro import config

    config.set_on_failure(mode)


@contextlib.contextmanager
def failure_policy(mode: str):
    """``with failure_policy('raise'): ...`` — scoped policy override."""
    from repro import config

    prev = config._ON_FAILURE
    config.set_on_failure(mode)
    try:
        yield
    finally:
        config._ON_FAILURE = prev


@contextlib.contextmanager
def checking_numerics(flag: bool = True):
    """Scoped override of the non-finite output guard."""
    from repro import config

    prev = config._CHECK_NUMERICS
    config.set_check_numerics(flag)
    try:
        yield
    finally:
        config._CHECK_NUMERICS = prev


def _numerics_on() -> bool:
    from repro import config

    return config.check_numerics()


def has_nonfinite(out: Any) -> bool:
    """True if any concrete inexact leaf of *out* contains NaN/Inf.

    Tracer leaves (inside jit tracing) are skipped — they carry no
    values, and aborting a trace on their account would poison the
    cache with a spurious failure.
    """
    import jax
    import jax.numpy as jnp

    for leaf in jax.tree_util.tree_leaves(out):
        if isinstance(leaf, jax.core.Tracer):
            continue
        dt = getattr(leaf, "dtype", None)
        if dt is None or not jnp.issubdtype(dt, jnp.inexact):
            continue
        if not bool(jnp.isfinite(leaf).all()):
            return True
    return False


# ---------------------------------------------------------------------------
# The guarded dispatcher
# ---------------------------------------------------------------------------

_SYNTHETIC = (faults.FaultInjected, NumericsError)


def run(op: str, levels: Sequence[tuple[str, Callable[[], Any]]]) -> Any:
    """Execute *levels* in order, serving the first success (see module doc).

    *levels* is ``[(name, thunk), ...]`` ordered from the preferred
    execution to the oracle of last resort.  Thunks must be
    self-contained closures: re-invoking a later level never depends on
    state a failed earlier level half-mutated.
    """
    failures: list[tuple[str, Exception]] = []
    n = len(levels)
    check_num = _numerics_on()
    for i, (name, thunk) in enumerate(levels):
        err: Exception | None = None
        try:
            out = thunk()
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:  # noqa: BLE001 — the guard's whole job
            err = e
        if err is None and check_num and has_nonfinite(out):
            err = NumericsError(op, name)
            obs.metrics.inc("robust.nonfinite", op)
        if err is None:
            if i:
                obs.metrics.inc("robust.served_degraded", f"{op}:{name}")
            return out
        failures.append((name, err))
        if on_failure() == "raise":
            if isinstance(err, _SYNTHETIC):
                raise GuardedExecutionError(op, failures) from err
            raise err
        if i + 1 < n:
            nxt = levels[i + 1][0]
            obs.metrics.inc("robust.demotion", f"{op}:{name}->{nxt}")
            obs.trace.annotate(demoted=f"{name}->{nxt}",
                               cause=type(err).__name__)
            continue
        # Lattice exhausted. Surface the most informative error: the
        # last organic exception if any level failed for real, else the
        # structured summary of the injected/numerics chain.
        real = [e for _, e in failures if not isinstance(e, _SYNTHETIC)]
        obs.metrics.inc("robust.exhausted", op)
        if real:
            raise real[-1]
        raise GuardedExecutionError(op, failures) from err
    raise ValueError(f"guarded op '{op}' was given no execution levels")
