"""Deterministic fault injection for the guarded-execution layer.

Every failure-prone seam in the stack declares a named *site* here —
engine lowering, tuner measurement, sidecar bytes, halo exchange, the
decode-server step — and calls :func:`check` at its Python dispatch
point.  When the site is armed and its counter crosses the probability
threshold, ``check`` raises :class:`FaultInjected`; the guarded
dispatcher (``repro.robust.guard``) then either surfaces it as a
structured error or walks the degradation lattice, per policy.

Design constraints (DESIGN.md §16.2):

- **Off by default, one-bool-read fast path.**  ``check`` costs a
  module-global bool test when nothing is armed — the same discipline
  as ``obs.trace``.  Arming is explicit: the :func:`inject` context
  manager, :func:`arm`, or the ``REPRO_FAULTS`` env spec.
- **Deterministic.**  Whether occurrence *n* of a site fires is a pure
  function of ``(seed, site, n)`` via ``zlib.crc32`` — independent of
  the Python hash seed, the process, and every other site — so a chaos
  run replays bit-identically from its spec string.
- **Registry-closed.**  Arming an unknown site is a ``ValueError``
  naming the registry; a typo'd site must not silently never fire.

Spec grammar (env var or :func:`arm`): ``site:prob[:seed]`` joined by
commas, e.g. ``REPRO_FAULTS="engine.window:1.0,tuning.measure:0.5:7"``.
``site=all`` arms every registered site with the same prob/seed.
"""

from __future__ import annotations

import contextlib
import os
import threading
import zlib

FAULTS_ENV = "REPRO_FAULTS"

#: Every injection site threaded through the stack.  Keep in sync with
#: DESIGN.md §16.2 — tests iterate this registry, so adding a site here
#: without a chaos-matrix entry fails test_robust.py's coverage check.
SITES = (
    "engine.window",        # core/engine.py run_window_plan dispatch
    "engine.scan",          # core/engine.py run_scan_plan dispatch
    "engine.gpu.window",    # core/engine_gpu.py run_window_plan_gpu
    "engine.gpu.scan",      # core/engine_gpu.py run_scan_plan_gpu
    "tuning.measure",       # core/tuning.py measure_us (candidate timing)
    "tuning.sidecar.load",  # core/tuning.py load_sidecar bytes
    "tuning.sidecar.save",  # core/tuning.py save_sidecar bytes
    "halo.exchange",        # distributed/halo_exchange.py sharded dispatch
    "serve.step",           # launch/serve.py DecodeServer.step
)

_DEFAULT_SEED = 0


class FaultInjected(RuntimeError):
    """An armed injection site fired.

    Carries ``site`` so the guard (and tests) can attribute the failure
    without string-parsing the message.
    """

    def __init__(self, site: str, occurrence: int):
        super().__init__(
            f"injected fault at site '{site}' (occurrence {occurrence})"
        )
        self.site = site
        self.occurrence = occurrence


# ---------------------------------------------------------------------------
# Armed state.  _armed is the single fast-path bool; everything else is
# only touched once a site is armed.  A lock guards arm/disarm so test
# fixtures and context managers compose, but check() itself stays
# lock-free: counters are per-site ints bumped under the GIL, and chaos
# determinism is per-site, not cross-thread.
# ---------------------------------------------------------------------------

_armed = False
_specs: dict[str, tuple[float, int]] = {}   # site -> (prob, seed)
_counts: dict[str, int] = {}                # site -> occurrences seen
_fired: dict[str, int] = {}                 # site -> occurrences fired
_lock = threading.Lock()


def parse_spec(spec: str) -> dict[str, tuple[float, int]]:
    """Parse a ``site:prob[:seed]`` comma list into a spec dict."""
    out: dict[str, tuple[float, int]] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        bits = part.split(":")
        if len(bits) not in (2, 3):
            raise ValueError(
                f"fault spec '{part}' is not 'site:prob[:seed]'"
            )
        site, prob_s = bits[0].strip(), bits[1]
        try:
            prob = float(prob_s)
        except ValueError:
            raise ValueError(f"fault spec '{part}': prob '{prob_s}' is not a float")
        if not 0.0 <= prob <= 1.0:
            raise ValueError(f"fault spec '{part}': prob must be in [0, 1]")
        seed = int(bits[2]) if len(bits) == 3 else _DEFAULT_SEED
        targets = SITES if site == "all" else (site,)
        for t in targets:
            if t not in SITES:
                raise ValueError(
                    f"unknown fault site '{t}'; registered sites: {', '.join(SITES)}"
                )
            out[t] = (prob, seed)
    return out


def arm(spec: str | dict[str, tuple[float, int]]) -> None:
    """Arm injection sites from a spec string or parsed dict."""
    global _armed
    parsed = parse_spec(spec) if isinstance(spec, str) else dict(spec)
    for site in parsed:
        if site not in SITES:
            raise ValueError(
                f"unknown fault site '{site}'; registered sites: {', '.join(SITES)}"
            )
    with _lock:
        _specs.update(parsed)
        for site in parsed:
            _counts.setdefault(site, 0)
            _fired.setdefault(site, 0)
        _armed = bool(_specs)


def disarm(site: str | None = None) -> None:
    """Disarm one site, or everything (also clears counters)."""
    global _armed
    with _lock:
        if site is None:
            _specs.clear()
            _counts.clear()
            _fired.clear()
        else:
            _specs.pop(site, None)
        _armed = bool(_specs)


def armed_sites() -> dict[str, tuple[float, int]]:
    return dict(_specs)


def fired_counts() -> dict[str, int]:
    """occurrences that actually fired, per site (for tests/benches)."""
    return {k: v for k, v in _fired.items() if v}


@contextlib.contextmanager
def inject(spec: str | dict[str, tuple[float, int]]):
    """Context manager: arm *spec* on entry, restore prior state on exit."""
    global _armed
    with _lock:
        saved = (dict(_specs), dict(_counts), dict(_fired), _armed)
    arm(spec)
    try:
        yield
    finally:
        with _lock:
            _specs.clear()
            _counts.clear()
            _fired.clear()
            s, c, f, a = saved
            _specs.update(s)
            _counts.update(c)
            _fired.update(f)
            _armed = a


def _fires(site: str, seed: int, n: int, prob: float) -> bool:
    if prob >= 1.0:
        return True
    if prob <= 0.0:
        return False
    # crc32 of the (seed, site, occurrence) triple → uniform-ish u32;
    # hash()-based draws would vary with PYTHONHASHSEED across processes.
    digest = zlib.crc32(f"{seed}:{site}:{n}".encode())
    return (digest / 0xFFFFFFFF) < prob


def check(site: str) -> None:
    """Raise :class:`FaultInjected` if *site* is armed and fires.

    The disarmed path is one module-global bool read; keep it that way —
    this sits on every engine dispatch.
    """
    if not _armed:
        return
    spec = _specs.get(site)
    if spec is None:
        return
    prob, seed = spec
    n = _counts.get(site, 0)
    _counts[site] = n + 1
    if _fires(site, seed, n, prob):
        _fired[site] = _fired.get(site, 0) + 1
        raise FaultInjected(site, n)


# Arm from the environment at import so chaos CI runs (and the --chaos
# bench smoke) need no code changes: REPRO_FAULTS="site:prob[:seed],...".
_env_spec = os.environ.get(FAULTS_ENV, "").strip()
if _env_spec:
    arm(_env_spec)
del _env_spec
