"""Optimizer substrate: sharded AdamW + schedules + gradient clipping."""
from .adamw import adamw_state_specs, adamw_update, cosine_schedule, global_norm  # noqa: F401
