"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule.

Optimizer state is described by ParamSpec trees mirroring the parameter
tree (same logical axes ⇒ same sharding ⇒ fully sharded optimizer states,
ZeRO-style along whatever axes the params are sharded on). Moments are
kept in f32 regardless of the (possibly bf16) parameter dtype; the update
is computed in f32 and cast back — the usual mixed-precision recipe.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.spec import ParamSpec, is_spec


def adamw_state_specs(param_specs) -> dict:
    """{'m','v'}: f32 zero trees with the parameters' logical axes; 'step'."""
    def f32_zeros(s: ParamSpec) -> ParamSpec:
        return dataclasses.replace(s, init="zeros", dtype=jnp.float32)

    return {
        "m": jax.tree.map(f32_zeros, param_specs, is_leaf=is_spec),
        "v": jax.tree.map(f32_zeros, param_specs, is_leaf=is_spec),
        "step": ParamSpec((), (), init="zeros", dtype=jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def adamw_update(params, grads, state, *, lr, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 clip_norm: float = 1.0):
    """One AdamW step. ``lr`` may be a traced scalar (schedule applied by caller)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m_new / (1 - b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
