"""Iterative 2-D diffusion (the paper's 2d5pt stencil) with temporal blocking.

Runs a 200-step diffusion simulation three ways and checks they agree:
  * step-by-step jnp reference (zero-Dirichlet interior),
  * SSAM Pallas kernel, one step per launch,
  * SSAM Pallas kernel with temporal blocking (4 fused steps per launch,
    trapezoidal halos — the Fig. 6 configuration),
then reports CPU wall-clock for the fused vs unfused XLA schedules.

  PYTHONPATH=src python examples/stencil_diffusion.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels.stencils import BENCHMARKS


def main():
    sdef = BENCHMARKS["2d5pt"]
    n, steps, tb = 96, 200, 4
    rng = np.random.default_rng(0)
    x0 = jnp.array(rng.standard_normal((n, n)), jnp.float32)

    # reference: step by step
    x_ref = x0
    for _ in range(steps):
        x_ref = ref.stencil_iterate(x_ref, sdef, 1)

    # SSAM kernel, one step per call
    x_k = x0
    for _ in range(steps):
        x_k = ops.stencil(x_k, sdef, impl="interpret", block_h=8, block_w=32)

    # SSAM kernel with temporal blocking: 4 fused steps per call. The
    # fused group uses the pad-once (trapezoidal) boundary semantics, so
    # its like-for-like reference applies the same 4-step groups.
    x_tb = x0
    x_ref_tb = x0
    for _ in range(steps // tb):
        x_tb = ops.stencil(x_tb, sdef, time_steps=tb, impl="interpret",
                           block_h=8, block_w=32)
        x_ref_tb = ref.stencil_iterate(x_ref_tb, sdef, tb)

    e1 = float(jnp.abs(x_k - x_ref).max())
    e2 = float(jnp.abs(x_tb - x_ref_tb).max())
    sem = float(jnp.abs(x_ref_tb - x_ref).max())
    print(f"kernel vs ref: {e1:.2e};  temporal-blocked vs its ref: {e2:.2e}")
    print(f"(boundary-semantics divergence pad-once vs Dirichlet over "
          f"{steps} steps: {sem:.2e} — documented in ssam_stencil2d)")
    assert e1 < 1e-3 and e2 < 1e-3

    # wall-clock of the fused vs unfused XLA schedules (CPU)
    big = jnp.array(rng.standard_normal((512, 512)), jnp.float32)
    fused = jax.jit(lambda v: ref.stencil_iterate(v, sdef, tb))
    single = jax.jit(lambda v: ref.stencil_iterate(v, sdef, 1))
    jax.block_until_ready(fused(big)), jax.block_until_ready(single(big))
    t0 = time.perf_counter()
    jax.block_until_ready(fused(big))
    tf = time.perf_counter() - t0
    t0 = time.perf_counter()
    v = big
    for _ in range(tb):
        v = single(v)
    jax.block_until_ready(v)
    tu = time.perf_counter() - t0
    print(f"temporal blocking (t={tb}, 512^2): fused {tf*1e3:.1f}ms vs "
          f"unfused {tu*1e3:.1f}ms → {tu/tf:.2f}x")
    print("OK")


if __name__ == "__main__":
    main()
