"""End-to-end serving driver: batched decoding with continuous batching.

Serves a small RWKV6 (O(1) decode state — the long-context family) and a
gemma3-family model through the slot-pool server: requests over 4 slots,
per-slot cache indices, greedy sampling.

RWKV6 prefill runs through the **chunked scan plans** (DESIGN.md §12):
``DecodeServer.assign`` calls ``model.prefill``, which executes each
layer's WKV recurrence once over the whole prompt via
``repro.nn.ssm.wkv6_chunked`` — the chunk-streamed engine schedule on
TPU, O(chunk) live state — so a 64-token prompt costs one batched scan
instead of 63 serve_step calls, and only the O(1) recurrent state lands
in the slot. gemma3 (windowed KV cache) has no whole-prompt scan and
feeds its prompt token-by-token.

  PYTHONPATH=src python examples/serve_decode.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    print("=== RWKV6 (recurrent state; prefill = one chunked scan) ===")
    serve_main(["--arch", "rwkv6-1.6b", "--smoke", "--slots", "4",
                "--requests", "12", "--max-new", "16", "--cache-len", "128",
                "--prompt-len", "64"])
    print("=== gemma3 (windowed KV cache; token-by-token prefill) ===")
    serve_main(["--arch", "gemma3-1b", "--smoke", "--slots", "4",
                "--requests", "8", "--max-new", "12", "--cache-len", "128"])
