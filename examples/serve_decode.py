"""End-to-end serving driver: batched decoding with continuous batching.

Serves a small RWKV6 (O(1) decode state — the long-context family) and a
gemma3-family model through the slot-pool server: 12 requests over 4
slots, per-slot cache indices, greedy sampling. This is the
"serve a small model with batched requests" end-to-end driver.

  PYTHONPATH=src python examples/serve_decode.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    print("=== RWKV6 (recurrent state, O(1) per token) ===")
    serve_main(["--arch", "rwkv6-1.6b", "--smoke", "--slots", "4",
                "--requests", "12", "--max-new", "16", "--cache-len", "128"])
    print("=== gemma3 (windowed KV cache) ===")
    serve_main(["--arch", "gemma3-1b", "--smoke", "--slots", "4",
                "--requests", "8", "--max-new", "12", "--cache-len", "128"])
