"""Paper demo (Fig. 4 setting): SSAM 2-D convolution on TPU-shaped tiles.

Walks the three layers of the reproduction for one 2-D convolution:

1. the 𝒥 = (O, D, X, Y) plan (schedule metadata: shifts, taps, halo),
2. the pure-JAX systolic executor (lane rolls — the model semantics),
3. the Pallas TPU kernel in interpret mode (real BlockSpec overlapped
   blocking — the thing that runs on hardware),

validates all three against the jnp oracle, and prices the schedule with
the paper's §5 performance model on V100 + TPU-v5e parameters.

  PYTHONPATH=src python examples/convolution2d.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import conv2d_plan
from repro.core.executor import execute_conv_global
from repro.core.perfmodel import TPU_V5E, V100, dif_smem_reg, l_reg, l_smem
from repro.kernels import ops, ref


def main():
    rng = np.random.default_rng(0)
    M = N = 5
    x = jnp.array(rng.standard_normal((128, 512)), jnp.float32)
    w = jnp.array(rng.standard_normal((N, M)), jnp.float32)

    plan = conv2d_plan(M, N, P=8)
    print(f"SSAM plan: {M}x{N} filter, S={plan.S} lanes, "
          f"C={plan.C} regs/lane (Eq.3), {plan.shift_count()} shifts, "
          f"{plan.mads_per_output_window()} MADs/window")
    print(f"halo ratio: exact {plan.halo_ratio():.3f}, "
          f"paper bound {plan.halo_ratio_paper_bound():.3f}")

    oracle = ref.conv2d_valid(x, w)

    model_out = execute_conv_global(conv2d_plan(M, N, S=512, P=1), x, w)
    err1 = float(jnp.abs(model_out - oracle).max())
    print(f"systolic executor vs oracle: max err {err1:.2e}")

    kern_out = ops.conv2d(x, w, mode="valid", impl="interpret",
                          block_h=8, block_w=128)
    err2 = float(jnp.abs(kern_out - oracle).max())
    print(f"Pallas kernel (interpret) vs oracle: max err {err2:.2e}")

    for hw in (V100, TPU_V5E):
        print(f"{hw.name}: L_smem={l_smem(hw, M, N):.0f}cyc "
              f"L_reg={l_reg(hw, M, N):.0f}cyc "
              f"Dif(Eq.5)={dif_smem_reg(hw, M, N):.0f}cyc "
              f"(register cache wins by "
              f"{l_smem(hw, M, N) / l_reg(hw, M, N):.2f}x)")

    assert err1 < 1e-3 and err2 < 1e-3
    print("OK")


if __name__ == "__main__":
    main()
