"""Quickstart: train a small LM end-to-end on the synthetic pipeline.

Runs the full production stack — config, sharded init, jitted
loss/grad/AdamW step, deterministic data, periodic checkpoints — on CPU
in a couple of minutes. The loss drops well below ln(vocab) because the
synthetic stream's second half repeats its first half (learnable copy
structure).

  PYTHONPATH=src python examples/quickstart.py [--steps 300]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart_ckpt")
    args = ap.parse_args()
    losses = train_main([
        "--arch", "internvl2-1b", "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128", "--lr", "3e-3",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
    ])
    print(f"quickstart: final loss {losses[-1]:.3f} "
          f"(started {losses[0]:.3f}; ln V = 6.24)")
