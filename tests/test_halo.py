"""Unit tests for the factored halo geometry (core.halo) — the single
module the engine, the sharded halo-exchange layer and per-shard tuning
all derive their padding/exchange arithmetic from."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (conv2d_plan, conv2d_same_plan, stencil2d_plan,
                        stencil3d_plan)
from repro.core import tuning
from repro.core.halo import (check_shard_geometry, extended_crop,
                             is_shape_preserving, origin_pads, shard_halo)
from repro.kernels import ref
from repro.kernels.ssam_conv2d import conv2d_same
from repro.kernels.stencils import BENCHMARKS


def _plan(name):
    d = BENCHMARKS[name]
    mk = stencil2d_plan if d.ndim == 2 else stencil3d_plan
    return mk(d.offsets, coeffs=d.coeffs)


class TestGeometry:
    @pytest.mark.parametrize("name", ["2d5pt", "2ds25pt", "3d27pt"])
    @pytest.mark.parametrize("t", [1, 2, 3])
    def test_shard_halo_sums_to_engine_halo(self, name, t):
        """Per axis, low + high shard halo == the engine's block halo —
        two views of the same t·(ext−1) overlap."""
        plan = _plan(name)
        for (lo, hi), total in zip(shard_halo(plan, t), plan.halo(t)):
            assert lo + hi == total

    @pytest.mark.parametrize("name", list(BENCHMARKS))
    def test_table3_plans_are_shape_preserving(self, name):
        plan = _plan(name)
        for a in range(plan.ndim_spatial):
            assert is_shape_preserving(plan, a)

    def test_valid_conv_is_not_shape_preserving(self):
        plan = conv2d_plan(5, 3)
        assert not is_shape_preserving(plan, 0)
        assert not is_shape_preserving(plan, 1)
        same = conv2d_same_plan(5, 3)
        assert is_shape_preserving(same, 0) and is_shape_preserving(same, 1)

    def test_origin_pads_cover_last_block(self):
        plan = _plan("2d9pt")
        pads = origin_pads(plan, (40, 100), grid=(5, 4), block=(8, 32), time_steps=2)
        for (lo, hi), g, b, h, s in zip(pads, (5, 4), (8, 32), plan.halo(2),
                                        (40, 100)):
            assert lo + s + hi == g * b + h     # every input block in-bounds
            assert lo == 2 * 1 * plan.lead_trail()[0][0] or lo >= 0

    def test_extended_crop(self):
        plan = _plan("2d5pt")
        assert extended_crop(plan, 3, 0, 16) == slice(3, 19)


class TestShardGeometryErrors:
    def test_indivisible_axis_raises(self):
        with pytest.raises(ValueError, match="does not divide"):
            check_shard_geometry(_plan("2d5pt"), (30, 64),
                                 (("data", 4), None))

    def test_shard_smaller_than_halo_is_fine_multihop(self):
        # (6, 6) halo over 2-row shards: the exchange layer chains
        # ppermute hops, so geometry checking accepts it.
        local = check_shard_geometry(_plan("2d9pt"), (16, 64),
                                     (("data", 8), None), time_steps=3)
        assert local == (2, 64)

    def test_halo_wider_than_axis_raises(self):
        with pytest.raises(ValueError, match="wider than domain axis"):
            check_shard_geometry(_plan("2d121pt"), (8, 64),
                                 (("data", 8), None), time_steps=2)

    def test_non_shape_preserving_axis_raises(self):
        with pytest.raises(ValueError, match="shape-preserving"):
            check_shard_geometry(conv2d_plan(3, 3), (32, 64),
                                 (("data", 4), None))

    def test_ok_returns_local_shape(self):
        local = check_shard_geometry(_plan("2d5pt"), (32, 64),
                                     (("data", 4), ("model", 2)))
        assert local == (8, 32)


class TestShardTuningShape:
    def test_extends_sharded_axes_only(self):
        plan = _plan("2d9pt")           # radius 2 → (2, 2) halo per axis
        shape = tuning.shard_tuning_shape(plan, (64, 256),
                                          (("data", 8), None))
        assert shape == (64 // 8 + 4, 256)

    def test_single_device_axis_not_extended(self):
        plan = _plan("2d5pt")
        shape = tuning.shard_tuning_shape(plan, (64, 256),
                                          (("data", 1), ("model", 4)))
        assert shape == (64, 256 // 4 + 2)


class TestConv2dSamePlan:
    """The 'same' conv now lowers through plan lead/trail geometry —
    single-device output must still match the pad-then-valid oracle."""

    @pytest.mark.parametrize("fs", [(3, 3), (2, 4), (5, 3), (1, 5)])
    def test_matches_oracle(self, rng, fs):
        x = jnp.array(rng.standard_normal((24, 56)), jnp.float32)
        w = jnp.array(rng.standard_normal(fs), jnp.float32)
        out = conv2d_same(x, w, block_h=8, block_w=32)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.conv2d_same(x, w)),
                                   rtol=3e-5, atol=3e-5)
