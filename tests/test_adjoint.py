"""Adjoint-plan subsystem: gradcheck every engine op against JAX AD of
the ``ref`` oracles, and *prove* the backward pass lowered through the
plan engine (lowering counters + tuner-cache signatures).

Tier-1 runs a fast representative subset; the full Table-3 × time_steps
× variant matrix is ``slow``-marked (CI grad job), and the forced-8-
device sharded-adjoint equivalence cases are ``sharded``-marked (CI
sharded job) using the subprocess pattern of ``test_sharded.py``.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adjoint as adjoint_mod
from repro.core import (conv2d_nchw_plan, conv2d_plan, conv2d_same_plan,
                        depthwise_conv1d_plan, input_adjoint_plan,
                        stencil2d_plan, stencil3d_plan, tuning,
                        weight_adjoint_plan)
from repro.kernels import ops, ref
from repro.kernels.stencils import BENCHMARKS

VARIANTS = ("shift_psum", "shift_data")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def assert_close(a, b, tol=1e-4):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=tol, atol=tol)


def grads(fn, *args, argnums=None):
    """Gradient of ``sum(fn(*args)**2)`` — exercises a non-trivial
    cotangent through the op's vjp."""
    argnums = tuple(range(len(args))) if argnums is None else argnums
    return jax.grad(lambda *a: jnp.sum(fn(*a) ** 2), argnums)(*args)


# ---------------------------------------------------------------------------
# Plan-level derivation rules
# ---------------------------------------------------------------------------

class TestAdjointPlans:
    def test_lead_trail_swap_through_footprint(self):
        """valid ⇒ full; 'same' swaps lead and trail through ext−1."""
        p = conv2d_plan(5, 3)                   # valid: no pads
        a = input_adjoint_plan(p)
        assert a.lead_trail() == ((2, 4), (2, 4))   # full conv pads ext−1
        p = conv2d_same_plan(4, 2)              # asymmetric even filter
        a = input_adjoint_plan(p)
        lead, trail = p.lead_trail()
        assert a.lead_trail() == (
            tuple(e - 1 - l for e, l in zip(p.exts, lead)),
            tuple(e - 1 - r for e, r in zip(p.exts, trail)))

    def test_taps_point_reflected(self):
        sdef = BENCHMARKS["poisson"]            # asymmetric-footprint 3-D
        p = stencil3d_plan(sdef.offsets, coeffs=sdef.coeffs)
        a = input_adjoint_plan(p)
        fwd = {off: cid for off, cid in adjoint_mod.iter_tap_offsets(p)}
        bwd = {off: cid for off, cid in adjoint_mod.iter_tap_offsets(a)}
        E = p.exts
        for off, cid in fwd.items():
            assert bwd[tuple(e - 1 - o for e, o in zip(E, off))] == cid

    @pytest.mark.parametrize("plan", [
        conv2d_plan(5, 3), conv2d_same_plan(3, 3),
        conv2d_nchw_plan(2, 3, 4, 3, 3, mode="same"),
        depthwise_conv1d_plan(4),
        stencil2d_plan(BENCHMARKS["2d9pt"].offsets,
                       coeffs=BENCHMARKS["2d9pt"].coeffs),
        stencil3d_plan(BENCHMARKS["3d7pt"].offsets,
                       coeffs=BENCHMARKS["3d7pt"].coeffs),
    ])
    def test_adjoint_involution(self, plan):
        """The adjoint of the adjoint is identically the original plan."""
        assert input_adjoint_plan(input_adjoint_plan(plan)) == plan

    def test_nchw_channel_roles_swap(self):
        p = conv2d_nchw_plan(2, 3, 4, 3, 3)
        a = input_adjoint_plan(p)
        assert (a.reduce_axes, a.out_axes) == (p.out_axes, p.reduce_axes)

    def test_scan_plan_refused(self):
        from repro.core.plan import scan_plan
        with pytest.raises(ValueError, match="time-reversed"):
            input_adjoint_plan(scan_plan(32))

    def test_table_plans_have_no_weight_grad(self):
        p = stencil2d_plan(BENCHMARKS["2d5pt"].offsets,
                           coeffs=BENCHMARKS["2d5pt"].coeffs)
        with pytest.raises(ValueError, match="no .*weight gradient|table"):
            weight_adjoint_plan(p)

    def test_wgrad_signature_is_distinct(self):
        p = conv2d_nchw_plan(2, 3, 4, 3, 3)
        sigs = {tuning.plan_signature(q)
                for q in (p, input_adjoint_plan(p), weight_adjoint_plan(p))}
        assert len(sigs) == 3       # fwd / bwd-input / bwd-weight all keyed apart


# ---------------------------------------------------------------------------
# Gradcheck: fast tier-1 subset
# ---------------------------------------------------------------------------

class TestGradcheck:
    def setup_method(self):
        adjoint_mod.reset_lowering_counts()

    @pytest.mark.parametrize("mode", ["valid", "same"])
    def test_conv2d_single(self, rng, mode):
        x = jnp.array(rng.standard_normal((14, 40)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 5)), jnp.float32)
        gx, gw = grads(lambda a, b: ops.conv2d(
            a, b, mode=mode, impl="interpret", block_h=8, block_w=16), x, w)
        rx, rw = grads(lambda a, b: ops.conv2d(a, b, mode=mode, impl="xla"),
                       x, w)
        assert_close(gx, rx)
        assert_close(gw, rw, 1e-3)
        assert adjoint_mod.BACKWARD_LOWERINGS["adj_conv2d"] >= 1
        assert adjoint_mod.BACKWARD_LOWERINGS["wgrad_conv2d"] >= 1

    @pytest.mark.parametrize("mode", ["valid", "same"])
    def test_conv2d_nchw(self, rng, mode):
        x = jnp.array(rng.standard_normal((2, 3, 10, 24)), jnp.float32)
        w = jnp.array(rng.standard_normal((4, 3, 3, 3)), jnp.float32)
        gx, gw = grads(lambda a, b: ops.conv2d(
            a, b, mode=mode, impl="interpret", block_h=8, block_w=16), x, w)
        rx, rw = grads(lambda a, b: ops.conv2d(a, b, mode=mode, impl="xla"),
                       x, w)
        assert_close(gx, rx)
        assert_close(gw, rw, 1e-3)
        assert adjoint_mod.BACKWARD_LOWERINGS["adj_conv2d_nchw"] >= 1
        assert adjoint_mod.BACKWARD_LOWERINGS["wgrad_conv2d_nchw"] >= 1

    def test_conv2d_batched(self, rng):
        x = jnp.array(rng.standard_normal((3, 10, 24)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 3)), jnp.float32)
        gx, gw = grads(lambda a, b: ops.conv2d(
            a, b, impl="interpret", block_h=8, block_w=16), x, w)
        rx, rw = grads(lambda a, b: ops.conv2d(a, b, impl="xla"), x, w)
        assert_close(gx, rx)
        assert_close(gw, rw, 1e-3)

    def test_conv1d_causal(self, rng):
        x = jnp.array(rng.standard_normal((2, 17, 8)), jnp.float32)
        w = jnp.array(rng.standard_normal((4, 8)), jnp.float32)
        gx, gw = grads(lambda a, b: ops.conv1d_causal(
            a, b, impl="interpret", block_t=8, block_d=8), x, w)
        rx, rw = grads(lambda a, b: ops.conv1d_causal(a, b, impl="xla"), x, w)
        assert_close(gx, rx)
        assert_close(gw, rw, 1e-3)
        assert adjoint_mod.BACKWARD_LOWERINGS["adj_conv1d"] >= 1
        assert adjoint_mod.BACKWARD_LOWERINGS["wgrad_conv1d"] >= 1

    @pytest.mark.parametrize("name", ["2d5pt", "2ds25pt", "3d7pt"])
    def test_stencil_representatives(self, rng, name):
        sdef = BENCHMARKS[name]
        shape = (20, 40) if sdef.ndim == 2 else (8, 10, 24)
        x = jnp.array(rng.standard_normal(shape), jnp.float32)
        g1 = grads(lambda a: ops.stencil(a, name, impl="interpret"), x)[0]
        g2 = grads(lambda a: ops.stencil(a, name, impl="xla"), x)[0]
        assert_close(g1, g2)
        kind = "adj_stencil2d" if sdef.ndim == 2 else "adj_stencil3d"
        assert adjoint_mod.BACKWARD_LOWERINGS[kind] >= 1

    def test_grad_under_jit(self, rng):
        x = jnp.array(rng.standard_normal((16, 40)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 3)), jnp.float32)
        gx, gw = jax.jit(jax.grad(lambda a, b: jnp.sum(
            ops.conv2d(a, b, impl="interpret") ** 2), (0, 1)))(x, w)
        rx, rw = grads(lambda a, b: ops.conv2d(a, b, impl="xla"), x, w)
        assert_close(gx, rx)
        assert_close(gw, rw, 1e-3)

    def test_cumsum_and_sat(self, rng):
        x = jnp.array(rng.standard_normal((5, 100)), jnp.float32)
        g1 = grads(lambda a: ops.cumsum(a, impl="interpret", block_t=32), x)[0]
        g2 = grads(lambda a: ops.cumsum(a, impl="xla"), x)[0]
        assert_close(g1, g2)
        g1 = grads(lambda a: ops.sat(a, impl="interpret", block_t=32), x)[0]
        g2 = grads(lambda a: ops.sat(a, impl="xla"), x)[0]
        assert_close(g1, g2, 1e-3)
        assert adjoint_mod.BACKWARD_LOWERINGS["adj_scan"] >= 3

    def test_linear_recurrence(self, rng):
        a = jnp.array(rng.uniform(0.5, 1.0, (5, 60)), jnp.float32)
        b = jnp.array(rng.standard_normal((5, 60)), jnp.float32)
        ga, gb = grads(lambda u, v: ops.linear_recurrence(
            u, v, impl="interpret", block_t=32), a, b)
        ra, rb = grads(lambda u, v: ops.linear_recurrence(u, v, impl="xla"),
                       a, b)
        assert_close(ga, ra, 1e-3)
        assert_close(gb, rb, 1e-3)
        assert adjoint_mod.BACKWARD_LOWERINGS["adj_recurrence"] >= 1

    def test_chunked_recurrence_engine_grad(self, rng):
        a = jnp.array(rng.uniform(0.5, 1.0, (2, 3, 70)), jnp.float32)
        b = jnp.array(rng.standard_normal((2, 3, 70)), jnp.float32)
        ga, gb = grads(lambda u, v: ops.chunked_linear_recurrence(
            u, v, chunk=16, impl="engine"), a, b)
        ra, rb = grads(lambda u, v: ref.linear_recurrence(
            u.reshape(-1, 70), v.reshape(-1, 70)).reshape(u.shape), a, b)
        assert_close(ga, ra, 1e-3)
        assert_close(gb, rb, 1e-3)

    def test_autotuned_adjoint_keys_own_signature(self, rng):
        """autotune=True tunes the backward-input plan independently:
        the tuner cache gains an ``adj_*`` plan signature under an
        'adjoint' context — the cache-level proof of engine lowering."""
        tuning.clear_cache()
        x = jnp.array(rng.standard_normal((64, 128)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 3)), jnp.float32)
        gx, gw = grads(lambda a, b: ops.conv2d(
            a, b, impl="interpret", autotune=True), x, w)
        rx, rw = grads(lambda a, b: ops.conv2d(a, b, impl="xla"), x, w)
        assert_close(gx, rx)
        assert_close(gw, rw, 1e-3)
        kinds = [k[0].kind for k in tuning._CACHE]
        ctxs = [k[4] for k in tuning._CACHE]
        assert any(k == "adj_conv2d" for k in kinds), kinds
        assert any(c and c[0] == "adjoint" for c in ctxs), ctxs

    def test_grad_of_temporally_blocked_conv_refused(self, rng):
        x = jnp.array(rng.standard_normal((3, 16, 40)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 3)), jnp.float32)
        with pytest.raises(ValueError, match="temporally-blocked"):
            grads(lambda a, b: ops.conv2d(a, b, impl="interpret",
                                          time_steps=2), x, w)

    def test_bf16_io_grads(self, rng):
        x = jnp.array(rng.standard_normal((14, 40)), jnp.bfloat16)
        w = jnp.array(rng.standard_normal((3, 3)), jnp.bfloat16)
        gx, gw = grads(lambda a, b: ops.conv2d(
            a, b, impl="interpret").astype(jnp.float32), x, w)
        rx, rw = grads(lambda a, b: ops.conv2d(
            a, b, impl="xla").astype(jnp.float32), x, w)
        assert gx.dtype == jnp.bfloat16 and gw.dtype == jnp.bfloat16
        assert_close(gx, rx, 3e-2)
        assert_close(gw, rw, 3e-1)


# ---------------------------------------------------------------------------
# Gradcheck through the MXU lowering (DESIGN.md §13): adjoints transpose
# mxu→mxu, and the backward provably lowers through the engine
# ---------------------------------------------------------------------------

class TestMxuGradcheck:
    def setup_method(self):
        adjoint_mod.reset_lowering_counts()

    def test_adjoint_plan_inherits_strategy(self):
        """input/weight adjoints of a pinned plan stay pinned: the
        transpose of an im2row matmul is an im2row matmul over the
        reflected tap set, never a silent fall-back to lanes."""
        import dataclasses
        for p in (conv2d_plan(5, 3), conv2d_same_plan(3, 3),
                  conv2d_nchw_plan(2, 3, 4, 3, 3, mode="same"),
                  depthwise_conv1d_plan(4)):
            pinned = dataclasses.replace(p, strategy="mxu")
            assert input_adjoint_plan(pinned).strategy == "mxu"
            assert input_adjoint_plan(p).strategy is None

    @pytest.mark.parametrize("mode", ["valid", "same"])
    def test_conv2d_single_mxu(self, rng, mode):
        x = jnp.array(rng.standard_normal((14, 40)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 5)), jnp.float32)
        gx, gw = grads(lambda a, b: ops.conv2d(
            a, b, mode=mode, impl="interpret", strategy="mxu",
            block_h=8, block_w=16), x, w)
        rx, rw = grads(lambda a, b: ops.conv2d(a, b, mode=mode, impl="xla"),
                       x, w)
        assert_close(gx, rx)
        assert_close(gw, rw, 1e-3)
        assert adjoint_mod.BACKWARD_LOWERINGS["adj_conv2d"] >= 1
        assert adjoint_mod.BACKWARD_LOWERINGS["wgrad_conv2d"] >= 1

    def test_conv2d_nchw_mxu(self, rng):
        x = jnp.array(rng.standard_normal((2, 3, 10, 24)), jnp.float32)
        w = jnp.array(rng.standard_normal((4, 3, 3, 3)), jnp.float32)
        gx, gw = grads(lambda a, b: ops.conv2d(
            a, b, mode="same", impl="interpret", strategy="mxu",
            block_h=8, block_w=16), x, w)
        rx, rw = grads(lambda a, b: ops.conv2d(a, b, mode="same", impl="xla"),
                       x, w)
        assert_close(gx, rx)
        assert_close(gw, rw, 1e-3)
        assert adjoint_mod.BACKWARD_LOWERINGS["adj_conv2d_nchw"] >= 1
        assert adjoint_mod.BACKWARD_LOWERINGS["wgrad_conv2d_nchw"] >= 1

    def test_grouped_conv_grads(self, rng):
        x = jnp.array(rng.standard_normal((2, 6, 8, 20)), jnp.float32)
        w = jnp.array(rng.standard_normal((4, 3, 3, 3)), jnp.float32)
        gx, gw = grads(lambda a, b: ops.conv2d(
            a, b, mode="same", impl="interpret", groups=2, strategy="mxu"),
            x, w)
        rx, rw = grads(lambda a, b: ops.conv2d(
            a, b, mode="same", impl="xla", groups=2), x, w)
        assert_close(gx, rx)
        assert_close(gw, rw, 1e-3)

    @pytest.mark.parametrize("name", ["2d25pt", "3d27pt"])
    def test_stencil_mxu(self, rng, name):
        sdef = BENCHMARKS[name]
        shape = (20, 40) if sdef.ndim == 2 else (8, 10, 24)
        x = jnp.array(rng.standard_normal(shape), jnp.float32)
        g1 = grads(lambda a: ops.stencil(a, name, impl="interpret",
                                         strategy="mxu"), x)[0]
        g2 = grads(lambda a: ops.stencil(a, name, impl="xla"), x)[0]
        assert_close(g1, g2)
        kind = "adj_stencil2d" if sdef.ndim == 2 else "adj_stencil3d"
        assert adjoint_mod.BACKWARD_LOWERINGS[kind] >= 1

    def test_conv1d_causal_mxu(self, rng):
        x = jnp.array(rng.standard_normal((2, 17, 8)), jnp.float32)
        w = jnp.array(rng.standard_normal((4, 8)), jnp.float32)
        gx, gw = grads(lambda a, b: ops.conv1d_causal(
            a, b, impl="interpret", strategy="mxu", block_t=8, block_d=8),
            x, w)
        rx, rw = grads(lambda a, b: ops.conv1d_causal(a, b, impl="xla"), x, w)
        assert_close(gx, rx)
        assert_close(gw, rw, 1e-3)
        assert adjoint_mod.BACKWARD_LOWERINGS["adj_conv1d"] >= 1
        assert adjoint_mod.BACKWARD_LOWERINGS["wgrad_conv1d"] >= 1


# ---------------------------------------------------------------------------
# Scan-op sharding rejection (satellite: no silently ignored kwargs)
# ---------------------------------------------------------------------------

class TestScanMeshRejection:
    @pytest.mark.parametrize("op", ["cumsum", "sat"])
    @pytest.mark.parametrize("impl", ["interpret", "xla", None])
    def test_rejects_mesh_kwargs(self, op, impl):
        x = jnp.zeros((4, 32), jnp.float32)
        fn = getattr(ops, op)
        with pytest.raises(ValueError, match="halo-exchange layer"):
            fn(x, impl=impl, mesh=object())
        with pytest.raises(ValueError, match="in_specs"):
            fn(x, impl=impl, in_specs=object())

    def test_linear_recurrence_rejects_mesh(self):
        x = jnp.zeros((4, 32), jnp.float32)
        with pytest.raises(ValueError, match="pjit"):
            ops.linear_recurrence(x, x, mesh=object())

    def test_unknown_kwargs_are_errors(self):
        x = jnp.zeros((4, 32), jnp.float32)
        with pytest.raises(TypeError, match="unexpected kwargs"):
            ops.cumsum(x, impl="interpret", block_q=7)


# ---------------------------------------------------------------------------
# Training defaults ride the engine (satellite: no silent xla fallback)
# ---------------------------------------------------------------------------

class TestTrainingDefaults:
    def test_conv2d_apply_default_trains_on_engine(self, rng):
        from repro.nn import layers as nnl
        adjoint_mod.reset_lowering_counts()
        p = {"w": jnp.array(rng.standard_normal((4, 3, 3, 3)) * 0.1,
                            jnp.float32),
             "b": jnp.zeros((4,), jnp.float32)}
        x = jnp.array(rng.standard_normal((2, 3, 8, 16)), jnp.float32)
        loss = lambda pp, xx: jnp.sum(nnl.conv2d_apply(pp, xx) ** 2)
        g = jax.grad(loss)(p, x)
        rg = jax.grad(lambda pp, xx: jnp.sum(
            nnl.conv2d_apply(pp, xx, impl="xla") ** 2))(p, x)
        assert_close(g["w"], rg["w"], 1e-3)
        assert_close(g["b"], rg["b"], 1e-3)
        # the default path provably lowered its backward through the engine
        assert adjoint_mod.BACKWARD_LOWERINGS["adj_conv2d_nchw"] >= 1
        assert adjoint_mod.BACKWARD_LOWERINGS["wgrad_conv2d_nchw"] >= 1

    def test_mamba_conv_default_trains_on_engine(self, rng):
        from repro.nn import ssm
        adjoint_mod.reset_lowering_counts()
        specs = ssm.mamba_specs(16, d_inner=32, ssm_state=4)
        p = {k: jnp.array(rng.standard_normal(s.shape), jnp.float32) * 0.1
             for k, s in specs.items()}
        x = jnp.array(rng.standard_normal((2, 24, 16)), jnp.float32)
        g = jax.grad(lambda pp: jnp.sum(
            ssm.mamba_apply(pp, x, ssm_state=4)[0] ** 2))(p)
        rg = jax.grad(lambda pp: jnp.sum(
            ssm.mamba_apply(pp, x, ssm_state=4, conv_impl="xla")[0] ** 2))(p)
        assert_close(g["conv_w"], rg["conv_w"], 1e-3)
        assert adjoint_mod.BACKWARD_LOWERINGS["adj_conv1d"] >= 1


# ---------------------------------------------------------------------------
# Full gradcheck matrix (CI grad job)
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestGradcheckMatrix:
    @pytest.mark.parametrize("variant", VARIANTS)
    @pytest.mark.parametrize("t", [1, 2])
    @pytest.mark.parametrize("name", sorted(BENCHMARKS))
    def test_table3_grads(self, rng, name, t, variant):
        sdef = BENCHMARKS[name]
        shape = (24, 48) if sdef.ndim == 2 else (10, 12, 28)
        x = jnp.array(rng.standard_normal(shape), jnp.float32)
        g1 = grads(lambda a: ops.stencil(
            a, name, time_steps=t, impl="interpret", variant=variant), x)[0]
        g2 = grads(lambda a: ref.stencil_iterate(a, sdef, t), x)[0]
        assert_close(g1, g2)

    @pytest.mark.parametrize("variant", VARIANTS)
    @pytest.mark.parametrize("mode", ["valid", "same"])
    @pytest.mark.parametrize("fshape", [(2, 2), (3, 5), (5, 3), (1, 4)])
    def test_conv2d_filter_matrix(self, rng, fshape, mode, variant):
        N, M = fshape
        x = jnp.array(rng.standard_normal((16, 40)), jnp.float32)
        w = jnp.array(rng.standard_normal((N, M)), jnp.float32)
        gx, gw = grads(lambda a, b: ops.conv2d(
            a, b, mode=mode, impl="interpret", variant=variant,
            block_h=8, block_w=16), x, w)
        rx, rw = grads(lambda a, b: ops.conv2d(a, b, mode=mode, impl="xla"),
                       x, w)
        assert_close(gx, rx)
        assert_close(gw, rw, 1e-3)

    @pytest.mark.parametrize("bcc", [(1, 1, 1), (2, 3, 4), (3, 4, 2)])
    @pytest.mark.parametrize("mode", ["valid", "same"])
    def test_nchw_matrix(self, rng, bcc, mode):
        B, C_in, C_out = bcc
        x = jnp.array(rng.standard_normal((B, C_in, 12, 28)), jnp.float32)
        w = jnp.array(rng.standard_normal((C_out, C_in, 3, 5)), jnp.float32)
        gx, gw = grads(lambda a, b: ops.conv2d(
            a, b, mode=mode, impl="interpret", block_h=8, block_w=16), x, w)
        rx, rw = grads(lambda a, b: ops.conv2d(a, b, mode=mode, impl="xla"),
                       x, w)
        assert_close(gx, rx)
        assert_close(gw, rw, 1e-3)

    @pytest.mark.parametrize("K", [1, 2, 4, 8])
    def test_conv1d_k_matrix(self, rng, K):
        x = jnp.array(rng.standard_normal((2, 37, 24)), jnp.float32)
        w = jnp.array(rng.standard_normal((K, 24)), jnp.float32)
        gx, gw = grads(lambda a, b: ops.conv1d_causal(
            a, b, impl="interpret", block_t=16, block_d=8), x, w)
        rx, rw = grads(lambda a, b: ops.conv1d_causal(a, b, impl="xla"), x, w)
        assert_close(gx, rx)
        assert_close(gw, rw, 1e-3)

    @pytest.mark.parametrize("T", [32, 100, 256])
    def test_scan_matrix(self, rng, T):
        x = jnp.array(rng.standard_normal((5, T)), jnp.float32)
        a = jnp.array(rng.uniform(0.5, 1.0, (5, T)), jnp.float32)
        g1 = grads(lambda v: ops.cumsum(v, impl="interpret", block_t=64),
                   x)[0]
        g2 = grads(lambda v: ops.cumsum(v, impl="xla"), x)[0]
        assert_close(g1, g2)
        ga, gb = grads(lambda u, v: ops.linear_recurrence(
            u, v, impl="interpret", block_t=64), a, x)
        ra, rb = grads(lambda u, v: ops.linear_recurrence(u, v, impl="xla"),
                       a, x)
        assert_close(ga, ra, 1e-3)
        assert_close(gb, rb, 1e-3)


# ---------------------------------------------------------------------------
# Sharded adjoint equivalence (CI sharded job; forced-8-device pattern)
# ---------------------------------------------------------------------------

def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n}",
               PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    env.pop("REPRO_TUNING_CACHE", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.sharded
def test_sharded_adjoint_matches_single_device():
    """jax.grad under a mesh == jax.grad on a single device — dx through
    the reversed-ppermute adjoint plan, dw through the psum'd weight
    correlation — and the backward provably lowered through the engine."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import adjoint as adj
        from repro.kernels import ops
        from repro.launch.mesh import make_domain_mesh

        rng = np.random.default_rng(0)
        assert jax.device_count() == 8
        mesh2d = make_domain_mesh((2, 4))
        mesh1d = make_domain_mesh((8,))

        def check(name, got, want, tol=1e-4):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=tol, atol=tol, err_msg=name)
            print("ok", name)

        x = jnp.array(rng.standard_normal((64, 288)), jnp.float32)
        for name, t in (("2d9pt", 1), ("2d5pt", 2), ("2ds25pt", 1)):
            f = lambda a, **kw: jnp.sum(ops.stencil(
                a, name, time_steps=t, impl="interpret", **kw) ** 2)
            want = jax.grad(f)(x)
            got = jax.grad(lambda a: f(a, mesh=mesh2d))(x)
            check(f"stencil {name} t{t} dx", got, want)

        w = jnp.array(rng.standard_normal((3, 5)), jnp.float32)
        f = lambda a, b, **kw: jnp.sum(ops.conv2d(
            a, b, impl="interpret", **kw) ** 2)
        wx, ww = jax.grad(f, (0, 1))(x, w)
        gx, gw = jax.grad(lambda a, b: f(a, b, mesh=mesh2d), (0, 1))(x, w)
        check("conv2d dx", gx, wx)
        check("conv2d dw", gw, ww, 1e-3)
        gx, gw = jax.grad(lambda a, b: f(a, b, mesh=mesh1d,
                                         in_specs=P("data", None)),
                          (0, 1))(x, w)
        check("conv2d rows-mesh dw", gw, ww, 1e-3)

        # NCHW: batch over 'data', lanes over 'model'; dw needs the psum
        xn = jnp.array(rng.standard_normal((4, 3, 24, 96)), jnp.float32)
        wn = jnp.array(rng.standard_normal((5, 3, 3, 3)), jnp.float32)
        wx, ww = jax.grad(f, (0, 1))(xn, wn)
        gx, gw = jax.grad(lambda a, b: f(a, b, mesh=mesh2d), (0, 1))(xn, wn)
        check("nchw dx", gx, wx)
        check("nchw dw", gw, ww, 1e-3)

        assert adj.BACKWARD_LOWERINGS["adj_stencil2d"] >= 3
        assert adj.BACKWARD_LOWERINGS["adj_conv2d"] >= 2
        assert adj.BACKWARD_LOWERINGS["wgrad_conv2d_nchw"] >= 1
        print("DONE")
    """)
    assert "DONE" in run_with_devices(code)


@pytest.mark.sharded
def test_sharded_adjoint_boundaries():
    """wrap transposes to wrap (torus); replicate transposes to the
    edge fold (widened valid adjoint + fold_replicate_edges)."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.kernels import ops
        from repro.kernels.stencils import BENCHMARKS
        from repro.launch.mesh import make_domain_mesh

        rng = np.random.default_rng(0)
        mesh2d = make_domain_mesh((2, 4))
        x = jnp.array(rng.standard_normal((64, 288)), jnp.float32)
        sdef = BENCHMARKS["2d5pt"]

        def periodic(a):
            out = jnp.zeros_like(a)
            for off, c in zip(sdef.offsets, sdef.coeffs):
                out = out + c * jnp.roll(a, [-o for o in off], axis=(0, 1))
            return out

        got = jax.grad(lambda a: jnp.sum(ops.stencil(
            a, "2d5pt", impl="interpret", mesh=mesh2d,
            boundary="wrap") ** 2))(x)
        want = jax.grad(lambda a: jnp.sum(periodic(a) ** 2))(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        print("ok wrap")

        # wrap conv2d: the psum'd weight grad sees the torus halo too
        w = jnp.array(rng.standard_normal((3, 3)), jnp.float32)

        def periodic_conv(a, b):
            out = jnp.zeros_like(a)
            for n in range(3):
                for m in range(3):
                    out = out + b[n, m] * jnp.roll(a, (1 - n, 1 - m),
                                                   axis=(0, 1))
            return out

        wx, ww = jax.grad(lambda a, b: jnp.sum(periodic_conv(a, b) ** 2),
                          (0, 1))(x, w)
        gx, gw = jax.grad(lambda a, b: jnp.sum(ops.conv2d(
            a, b, impl="interpret", mesh=mesh2d, boundary="wrap") ** 2),
            (0, 1))(x, w)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(wx),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(gw), np.asarray(ww),
                                   rtol=1e-3, atol=1e-3)
        print("ok wrap conv dw")

        # replicate: the clamp Eᵀ folds halo cotangents onto the edges
        def clamped(a):
            xp = jnp.pad(a, ((1, 1), (1, 1)), mode="edge")
            out = jnp.zeros_like(a)
            for off, c in zip(sdef.offsets, sdef.coeffs):
                out = out + c * jax.lax.dynamic_slice(
                    xp, (1 + off[0], 1 + off[1]), a.shape)
            return out

        got = jax.grad(lambda a: jnp.sum(ops.stencil(
            a, "2d5pt", impl="interpret", mesh=mesh2d,
            boundary="replicate") ** 2))(x)
        want = jax.grad(lambda a: jnp.sum(clamped(a) ** 2))(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        print("ok replicate dx")

        def clamped_conv(a, b):
            xp = jnp.pad(a, ((1, 1), (1, 1)), mode="edge")
            out = jnp.zeros_like(a)
            for n in range(3):
                for m in range(3):
                    out = out + b[n, m] * jax.lax.dynamic_slice(
                        xp, (n, m), a.shape)
            return out

        wx, ww = jax.grad(lambda a, b: jnp.sum(clamped_conv(a, b) ** 2),
                          (0, 1))(x, w)
        gx, gw = jax.grad(lambda a, b: jnp.sum(ops.conv2d(
            a, b, impl="interpret", mesh=mesh2d,
            boundary="replicate") ** 2), (0, 1))(x, w)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(wx),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(gw), np.asarray(ww),
                                   rtol=1e-3, atol=1e-3)
        print("DONE")
    """)
    assert "DONE" in run_with_devices(code)
