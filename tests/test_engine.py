"""Engine equivalence suite: every plan family through the single
plan→Pallas lowering, validated three ways —

1. engine (Pallas interpret)  vs  the pure-jnp oracles in ``ref.py``,
2. engine                     vs  the plan executor (``executor.py``),
3. ``shift_psum``             vs  ``shift_data`` schedule variants,

across the full ``BENCHMARKS`` stencil table, conv filter shapes
2×2…9×9, ``time_steps ∈ {1, 2, 3}``, plus the perf-model autotuner.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (conv2d_batched_plan, conv2d_nchw_plan, conv2d_plan,
                        conv2d_same_plan, depthwise_conv1d_plan,
                        execute_conv_global, linear_recurrence_plan,
                        run_scan_plan, run_window_plan, run_window_plan_mxu,
                        scan_plan, stencil2d_plan, stencil3d_plan)
from repro.core import tuning
from repro.kernels import ref
from repro.kernels.stencils import BENCHMARKS

VARIANTS = ("shift_psum", "shift_data")


def assert_close(a, b, tol=3e-5):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# conv2d: filter sweep 2×2 … 9×9, engine vs oracle vs executor
# ---------------------------------------------------------------------------

class TestConvThroughEngine:
    @pytest.mark.parametrize("variant", VARIANTS)
    @pytest.mark.parametrize("fs", [2, 3, 5, 7, 9])
    def test_square_filter_sweep(self, rng, fs, variant):
        x = jnp.array(rng.standard_normal((24, 56)), jnp.float32)
        w = jnp.array(rng.standard_normal((fs, fs)), jnp.float32)
        out = run_window_plan(x, w, plan=conv2d_plan(fs, fs),
                              block=(8, 32), variant=variant)
        assert_close(out, ref.conv2d_valid(x, w))

    @pytest.mark.parametrize("fshape", [(2, 5), (5, 2), (1, 4), (4, 1)])
    def test_rectangular_filters(self, rng, fshape):
        N, M = fshape
        x = jnp.array(rng.standard_normal((20, 48)), jnp.float32)
        w = jnp.array(rng.standard_normal((N, M)), jnp.float32)
        out = run_window_plan(x, w, plan=conv2d_plan(M, N), block=(4, 16))
        assert_close(out, ref.conv2d_valid(x, w))

    def test_engine_matches_executor(self, rng):
        """Same plan, two backends: the jnp.roll interpreter and the
        Pallas lowering agree — the schedule *is* the semantics."""
        x = jnp.array(rng.standard_normal((14, 60)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 5)), jnp.float32)
        a = execute_conv_global(conv2d_plan(5, 3, S=60, P=1), x, w)
        b = run_window_plan(x, w, plan=conv2d_plan(5, 3), block=(4, 16))
        assert_close(a, b, 1e-4)

    def test_variants_agree_to_ulp(self, rng):
        """Both variants add the same products in the same per-lane order;
        any residue is XLA FMA-contraction noise (≤ a few ulp)."""
        x = jnp.array(rng.standard_normal((24, 64)), jnp.float32)
        w = jnp.array(rng.standard_normal((4, 6)), jnp.float32)
        plan = conv2d_plan(6, 4)
        outs = [np.asarray(run_window_plan(x, w, plan=plan, block=(8, 32),
                                           variant=v)) for v in VARIANTS]
        np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Reduction axes: batched / NCHW conv2d through the engine
# ---------------------------------------------------------------------------

class TestBatchedConvThroughEngine:
    """The reduce-axes IR: grid over batch × C_out × spatial × C_in with
    an fp32 accumulator across the channel reduction — validated against
    ``jax.lax.conv_general_dilated`` (no Python loop anywhere)."""

    @pytest.mark.parametrize("variant", VARIANTS)
    @pytest.mark.parametrize("mode", ["valid", "same"])
    @pytest.mark.parametrize("bcc", [(1, 1, 1), (2, 3, 4), (3, 4, 2)])
    def test_nchw_vs_lax(self, rng, bcc, mode, variant):
        B, C_in, C_out = bcc
        x = jnp.array(rng.standard_normal((B, C_in, 12, 40)), jnp.float32)
        w = jnp.array(rng.standard_normal((C_out, C_in, 3, 5)), jnp.float32)
        plan = conv2d_nchw_plan(B, C_in, C_out, 5, 3, mode=mode)
        out = run_window_plan(x, w, plan=plan, block=(8, 32), variant=variant)
        assert_close(out, ref.conv2d_nchw(x, w, mode), 1e-4)

    @pytest.mark.parametrize("fshape", [(2, 2), (5, 3), (1, 7), (4, 1)])
    def test_nchw_filter_sweep(self, rng, fshape):
        N, M = fshape
        x = jnp.array(rng.standard_normal((2, 3, 14, 36)), jnp.float32)
        w = jnp.array(rng.standard_normal((2, 3, N, M)), jnp.float32)
        plan = conv2d_nchw_plan(2, 3, 2, M, N)
        out = run_window_plan(x, w, plan=plan, block=(4, 16))
        assert_close(out, ref.conv2d_nchw(x, w, "valid"), 1e-4)

    @pytest.mark.parametrize("variant", VARIANTS)
    @pytest.mark.parametrize("t", [1, 2])
    def test_batched_single_channel(self, rng, t, variant):
        """(B, H, W) stacks: the batch grid axis must reproduce a Python
        loop of per-image engine calls exactly, including under temporal
        blocking (reduce-free batched plans keep full t support)."""
        x = jnp.array(rng.standard_normal((3, 18, 40)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 5)), jnp.float32)
        bplan = conv2d_batched_plan(5, 3, mode="same")
        out = run_window_plan(x, w, plan=bplan, block=(8, 16), time_steps=t,
                              variant=variant)
        splan = conv2d_same_plan(5, 3)
        per_image = jnp.stack([
            run_window_plan(x[i], w, plan=splan, block=(8, 16), time_steps=t,
                            variant=variant)
            for i in range(x.shape[0])])
        assert_close(out, per_image, 1e-5)
        if t == 1:
            assert_close(out, ref.conv2d_batched(x, w, "same"), 1e-4)

    def test_ops_nchw_acceptance(self, rng):
        """Acceptance: ``ops.conv2d`` on an NCHW minibatch matches
        ``jax.lax.conv_general_dilated`` to fp32 tolerance."""
        from repro.kernels import ops
        x = jnp.array(rng.standard_normal((2, 3, 16, 48)), jnp.float32)
        w = jnp.array(rng.standard_normal((4, 3, 3, 3)), jnp.float32)
        for mode in ("same", "valid"):
            want = jax.lax.conv_general_dilated(
                x, w, (1, 1),
                [(1, 1), (1, 1)] if mode == "same" else "VALID",
                dimension_numbers=("NCHW", "OIHW", "NCHW"))
            assert_close(ops.conv2d(x, w, mode=mode, impl="interpret"),
                         want, 1e-4)
            assert_close(ops.conv2d(x, w, mode=mode, impl="xla"), want, 1e-4)

    def test_nchw_rejects_temporal_blocking(self, rng):
        x = jnp.zeros((1, 2, 8, 16), jnp.float32)
        w = jnp.zeros((2, 2, 3, 3), jnp.float32)
        plan = conv2d_nchw_plan(1, 2, 2, 3, 3, mode="same")
        with pytest.raises(AssertionError, match="temporal blocking"):
            run_window_plan(x, w, plan=plan, block=(8, 16), time_steps=2)

    def test_nchw_channel_mismatch(self):
        from repro.kernels import ops
        x = jnp.zeros((1, 3, 8, 16), jnp.float32)
        w = jnp.zeros((2, 4, 3, 3), jnp.float32)
        with pytest.raises(ValueError, match="C_in"):
            ops.conv2d(x, w, impl="interpret")

    def test_nchw_autotune(self, rng):
        """Tuned NCHW keys on the 4-D shape + nchw context — no
        collision with single-image winners."""
        from repro.kernels import ops
        tuning.clear_cache()
        x = jnp.array(rng.standard_normal((2, 2, 16, 64)), jnp.float32)
        w = jnp.array(rng.standard_normal((2, 2, 3, 3)), jnp.float32)
        out = ops.conv2d(x, w, impl="interpret", autotune=True)
        assert_close(out, ref.conv2d_nchw(x, w, "same"), 1e-4)
        keys = list(tuning._CACHE)
        assert any(k[1] == (2, 2, 16, 64) and "conv2d_nchw" in k[4]
                   for k in keys), keys


# ---------------------------------------------------------------------------
# Full BENCHMARKS table × variants × time_steps through the engine
# ---------------------------------------------------------------------------

class TestBenchmarkTableThroughEngine:
    @pytest.mark.parametrize("variant", VARIANTS)
    @pytest.mark.parametrize("name",
                             [n for n, d in BENCHMARKS.items() if d.ndim == 2])
    def test_2d_table(self, rng, name, variant):
        sdef = BENCHMARKS[name]
        x = jnp.array(rng.standard_normal((26, 70)), jnp.float32)
        plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
        out = run_window_plan(x, plan=plan, block=(8, 32), variant=variant)
        assert_close(out, ref.stencil_iterate(x, sdef, 1), 1e-4)

    @pytest.mark.parametrize("variant", VARIANTS)
    @pytest.mark.parametrize("name",
                             [n for n, d in BENCHMARKS.items() if d.ndim == 3])
    def test_3d_table(self, rng, name, variant):
        sdef = BENCHMARKS[name]
        x = jnp.array(rng.standard_normal((10, 12, 40)), jnp.float32)
        plan = stencil3d_plan(sdef.offsets, coeffs=sdef.coeffs)
        out = run_window_plan(x, plan=plan, block=(4, 8, 16), variant=variant)
        assert_close(out, ref.stencil_iterate(x, sdef, 1), 1e-4)

    @pytest.mark.parametrize("t", [1, 2, 3])
    @pytest.mark.parametrize("name", ["2d5pt", "2d9pt", "2d25pt"])
    def test_temporal_blocking_2d(self, rng, name, t):
        sdef = BENCHMARKS[name]
        x = jnp.array(rng.standard_normal((24, 48)), jnp.float32)
        plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
        out = run_window_plan(x, plan=plan, block=(8, 16), time_steps=t)
        assert_close(out, ref.stencil_iterate(x, sdef, t), 1e-4)

    @pytest.mark.parametrize("t", [1, 2, 3])
    def test_temporal_blocking_3d(self, rng, t):
        sdef = BENCHMARKS["3d7pt"]
        x = jnp.array(rng.standard_normal((8, 10, 24)), jnp.float32)
        plan = stencil3d_plan(sdef.offsets, coeffs=sdef.coeffs)
        out = run_window_plan(x, plan=plan, block=(4, 4, 8), time_steps=t)
        assert_close(out, ref.stencil_iterate(x, sdef, t), 1e-4)

    @pytest.mark.parametrize("variant", VARIANTS)
    def test_temporal_variants_agree(self, rng, variant):
        sdef = BENCHMARKS["2d9pt"]
        x = jnp.array(rng.standard_normal((20, 40)), jnp.float32)
        plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
        out = run_window_plan(x, plan=plan, block=(8, 16), time_steps=2,
                              variant=variant)
        assert_close(out, ref.stencil_iterate(x, sdef, 2), 1e-4)


# ---------------------------------------------------------------------------
# conv1d + scan families through the same engine
# ---------------------------------------------------------------------------

class TestScanFamiliesThroughEngine:
    @pytest.mark.parametrize("K", [1, 2, 4, 8])
    def test_depthwise_conv1d(self, rng, K):
        x = jnp.array(rng.standard_normal((2, 37, 24)), jnp.float32)
        w = jnp.array(rng.standard_normal((K, 24)), jnp.float32)
        out = run_window_plan(x, w, plan=depthwise_conv1d_plan(K),
                              block=(16, 8))
        assert_close(out, ref.conv1d_causal(x, w), 1e-4)

    @pytest.mark.parametrize("T", [32, 100, 256])
    def test_cumsum(self, rng, T):
        x = jnp.array(rng.standard_normal((5, T)), jnp.float32)
        out = run_scan_plan(x, plan=scan_plan(32), block_r=4)
        assert_close(out, ref.cumsum(x), 1e-4)

    @pytest.mark.parametrize("T", [32, 100, 256])
    def test_linear_recurrence(self, rng, T):
        a = jnp.array(rng.uniform(0.5, 1.0, (5, T)), jnp.float32)
        b = jnp.array(rng.standard_normal((5, T)), jnp.float32)
        out = run_scan_plan(a, b, plan=linear_recurrence_plan(32), block_r=4)
        assert_close(out, ref.linear_recurrence(a, b), 1e-3)


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------

class TestAutotuner:
    def setup_method(self):
        tuning.clear_cache()

    def test_candidates_respect_shape_and_vmem(self):
        sdef = BENCHMARKS["2d5pt"]
        plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
        cands = tuning.candidate_configs(plan, (64, 96), time_steps=2)
        assert cands
        for c in cands:
            assert c.block[0] <= 64 and c.block[1] <= 96
            loaded = 1
            for b, h in zip(c.block, plan.halo(2)):
                loaded *= b + h
            assert loaded <= tuning.VMEM_BUDGET_ELEMS

    def test_model_prefers_low_halo_blocks(self):
        """§5.3: larger lane tiles amortize the halo — the model must
        rank a (8, 512) block above (8, 128) for a wide stencil."""
        sdef = BENCHMARKS["2d21pt"]
        plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
        small = tuning.model_cost(plan, tuning.KernelConfig((8, 128)))
        big = tuning.model_cost(plan, tuning.KernelConfig((8, 512)))
        assert big < small

    def test_autotuner_changes_default_config(self):
        """The tuner must demonstrably improve on the seed default
        (8, 128, shift_psum) for the Table 3 suite at model level."""
        sdef = BENCHMARKS["2d5pt"]
        plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
        default = tuning.KernelConfig((8, 128))
        res = tuning.autotune(plan, (384, 384), default=default)
        assert res.config != default
        assert res.model_cost <= tuning.model_cost(plan, default)

    def test_measured_winner_never_loses_default(self, rng):
        from repro.kernels import ops
        tuning.clear_cache()
        x = jnp.array(rng.standard_normal((64, 128)), jnp.float32)
        default_us = tuning.measure_us(
            lambda: ops.stencil(x, "2d5pt", impl="interpret"))
        out = ops.stencil(x, "2d5pt", impl="interpret", autotune=True)
        assert_close(out, ref.stencil_iterate(x, BENCHMARKS["2d5pt"], 1), 1e-4)
        res = next(iter(tuning._CACHE.values()))
        assert res.source == "measured"
        # generous 2x guard: interpret-mode timings are noisy, but the
        # tuner measured the default too, so it cannot have picked a
        # config that is materially slower.
        assert res.measured_us <= 2.0 * max(default_us, 1.0)

    def test_cache_hit(self):
        sdef = BENCHMARKS["2d9pt"]
        plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
        r1 = tuning.autotune(plan, (256, 256))
        r2 = tuning.autotune(plan, (256, 256))
        assert r1.config == r2.config
        assert r2.source == "cache"

    def test_scan_candidates(self):
        plan = scan_plan(128)
        cands = tuning.candidate_configs(plan, (64, 8192))
        assert cands
        assert all((c.block[1] & (c.block[1] - 1)) == 0 for c in cands)

    def test_nchw_candidates_use_spatial_shape(self):
        """Reduce/batch axes are block-1 grid axes — candidates tile the
        spatial extents only and stay within the VMEM budget."""
        plan = conv2d_nchw_plan(4, 3, 8, 5, 5)
        cands = tuning.candidate_configs(plan, (4, 3, 64, 96))
        assert cands
        for c in cands:
            assert len(c.block) == 2
            assert c.block[0] <= 60 and c.block[1] <= 92  # valid-mode out

    def test_sidecar_schema_staleness(self, tmp_path):
        """Entries stamped with an old engine schema are ignored on load
        and dropped by the next write-through (the ROADMAP age-out)."""
        import json
        path = tmp_path / "tuning.json"
        stale = {"block": [8, 128], "variant": "shift_psum",
                 "model_cost": 1.0, "measured_us": 5.0,
                 "schema": tuning.ENGINE_SCHEMA_VERSION - 1}
        fresh = dict(stale, schema=tuning.ENGINE_SCHEMA_VERSION)
        path.write_text(json.dumps(
            {"version": 1, "entries": {"stale-key": stale,
                                       "fresh-key": fresh}}))
        tuning.clear_sidecar()
        try:
            assert tuning.load_sidecar(str(path)) == 1   # stale one skipped
            assert "fresh-key" in tuning._SIDECAR
            tuning.save_sidecar(str(path))               # rewrite ages it out
            doc = json.loads(path.read_text())
            assert set(doc["entries"]) == {"fresh-key"}
            assert doc["entries"]["fresh-key"]["schema"] == \
                tuning.ENGINE_SCHEMA_VERSION
        finally:
            tuning.clear_sidecar()


# ---------------------------------------------------------------------------
# Engine-lowered recurrences: the production LM paths through run_scan_plan
# ---------------------------------------------------------------------------

class TestEngineLoweredRecurrences:
    """Acceptance: selective_scan / wkv6 / chunked_linear_recurrence give
    identical outputs through ``impl='engine'`` (run_scan_plan Kogge–
    Stone blocks) as through the chunked production schedules."""

    def test_chunked_linear_recurrence_engine(self, rng):
        from repro.kernels import ops
        a = jnp.array(rng.uniform(0.5, 1.0, (2, 3, 70)), jnp.float32)
        b = jnp.array(rng.standard_normal((2, 3, 70)), jnp.float32)
        want = ops.chunked_linear_recurrence(a, b)
        got = ops.chunked_linear_recurrence(a, b, chunk=32, impl="engine")
        assert_close(got, want, 1e-4)
        with pytest.raises(ValueError):
            ops.chunked_linear_recurrence(a, b, impl="nope")

    def test_selective_scan_engine(self, rng):
        from repro.nn import ssm
        B, T, Di, N = 2, 37, 6, 4
        delta = jnp.array(rng.uniform(0.1, 0.5, (B, T, Di)), jnp.float32)
        A_log = jnp.array(rng.uniform(-1, 0.5, (Di, N)), jnp.float32)
        Bm = jnp.array(rng.standard_normal((B, T, N)), jnp.float32)
        Cm = jnp.array(rng.standard_normal((B, T, N)), jnp.float32)
        x = jnp.array(rng.standard_normal((B, T, Di)), jnp.float32)
        y1, h1 = ssm.selective_scan(delta, A_log, Bm, Cm, x, chunk=16)
        y2, h2 = ssm.selective_scan(delta, A_log, Bm, Cm, x, impl="engine")
        assert_close(y2, y1, 2e-4)
        assert_close(h2, h1, 2e-4)

    def test_wkv6_engine(self, rng):
        from repro.nn import ssm
        B, T, H, K, V = 2, 33, 2, 4, 5
        r = jnp.array(rng.standard_normal((B, T, H, K)), jnp.float32)
        k = jnp.array(rng.standard_normal((B, T, H, K)), jnp.float32)
        v = jnp.array(rng.standard_normal((B, T, H, V)), jnp.float32)
        logw = jnp.array(-np.exp(rng.standard_normal((B, T, H, K))),
                         jnp.float32)
        u = jnp.array(rng.standard_normal((H, K)), jnp.float32)
        y1, S1 = ssm.wkv6_chunked(r, k, v, logw, u, chunk=16)
        y2, S2 = ssm.wkv6_chunked(r, k, v, logw, u, impl="engine")
        y3, _ = ssm.wkv6_sequential(r, k, v, logw, u)
        assert_close(y2, y1, 2e-4)
        assert_close(S2, S1, 2e-4)
        assert_close(y2, y3, 2e-4)      # and both match the gold oracle

    def test_mamba_block_engine_path(self, rng):
        from repro.nn import ssm
        specs = ssm.mamba_specs(16, d_inner=32, ssm_state=4)
        p = {k: jnp.array(rng.standard_normal(s.shape), jnp.float32) * 0.1
             for k, s in specs.items()}
        x = jnp.array(rng.standard_normal((2, 24, 16)), jnp.float32)
        o1, _ = ssm.mamba_apply(p, x, ssm_state=4)
        o2, _ = ssm.mamba_apply(p, x, ssm_state=4, conv_impl="interpret",
                                scan_impl="engine")
        assert_close(o2, o1, 2e-4)


# ---------------------------------------------------------------------------
# MXU lowering strategy (DESIGN.md §13): im2row matmul vs VPU shift-fma
# ---------------------------------------------------------------------------

class TestMxuStrategy:
    """Strategy equivalence matrix: for every windowed plan the MXU
    (im2row-over-the-tap-set matmul) lowering must match the lanes
    (shift-fma) lowering to fp32 tolerance, forward and under temporal
    blocking — so the §5 tuner may choose between them on cost alone."""

    @pytest.mark.parametrize("variant", VARIANTS)
    @pytest.mark.parametrize("t", [1, 2])
    @pytest.mark.parametrize("name", sorted(BENCHMARKS))
    def test_table_matrix(self, rng, name, t, variant):
        sdef = BENCHMARKS[name]
        if sdef.ndim == 2:
            x = jnp.array(rng.standard_normal((22, 48)), jnp.float32)
            plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
            block = (8, 16)
        else:
            x = jnp.array(rng.standard_normal((8, 10, 24)), jnp.float32)
            plan = stencil3d_plan(sdef.offsets, coeffs=sdef.coeffs)
            block = (4, 4, 8)
        lanes = run_window_plan(x, plan=plan, block=block, time_steps=t,
                                variant=variant, strategy="lanes")
        mxu = run_window_plan(x, plan=plan, block=block, time_steps=t,
                              variant=variant, strategy="mxu")
        assert_close(mxu, lanes, 1e-4)
        if t == 1:
            assert_close(mxu, ref.stencil_iterate(x, sdef, 1), 1e-4)

    def test_run_window_plan_mxu_wrapper(self, rng):
        x = jnp.array(rng.standard_normal((20, 48)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 5)), jnp.float32)
        plan = conv2d_plan(5, 3)
        a = run_window_plan_mxu(x, w, plan=plan, block=(8, 16))
        b = run_window_plan(x, w, plan=plan, block=(8, 16), strategy="mxu")
        assert_close(a, b, 1e-6)
        assert_close(a, ref.conv2d_valid(x, w), 1e-4)

    @pytest.mark.parametrize("bcc", [(1, 1, 1), (2, 3, 4), (3, 4, 2)])
    @pytest.mark.parametrize("fshape", [(3, 3), (1, 7), (5, 2)])
    def test_nchw_matrix(self, rng, bcc, fshape):
        """NCHW reduce plans fold C_in·taps into one contraction — the
        MXU path must agree with lanes and lax across B/C/filters."""
        from repro.kernels import ops
        B, C_in, C_out = bcc
        N, M = fshape
        x = jnp.array(rng.standard_normal((B, C_in, 12, 40)), jnp.float32)
        w = jnp.array(rng.standard_normal((C_out, C_in, N, M)), jnp.float32)
        lanes = ops.conv2d(x, w, mode="same", impl="interpret",
                           strategy="lanes")
        mxu = ops.conv2d(x, w, mode="same", impl="interpret", strategy="mxu")
        assert_close(mxu, lanes, 1e-4)
        assert_close(mxu, ref.conv2d_nchw(x, w, "same"), 1e-4)

    def test_strided_conv_mxu(self, rng):
        from repro.kernels import ops
        x = jnp.array(rng.standard_normal((1, 3, 12, 40)), jnp.float32)
        w = jnp.array(rng.standard_normal((2, 3, 3, 3)), jnp.float32)
        want = ops.conv2d(x, w, mode="same", impl="xla", stride=(1, 2))
        for s in ("lanes", "mxu"):
            got = ops.conv2d(x, w, mode="same", impl="interpret",
                             stride=(1, 2), strategy=s)
            assert_close(got, want, 1e-4)

    def test_conv1d_causal_strategies_agree(self, rng):
        """Per-lane (depthwise) coefficients lower on the MXU as a
        lane-batched contraction — same output as the shift-fma path."""
        from repro.kernels import ops
        x = jnp.array(rng.standard_normal((2, 37, 24)), jnp.float32)
        w = jnp.array(rng.standard_normal((4, 24)), jnp.float32)
        lanes = ops.conv1d_causal(x, w, impl="interpret", strategy="lanes")
        mxu = ops.conv1d_causal(x, w, impl="interpret", strategy="mxu")
        assert_close(mxu, lanes, 1e-4)
        assert_close(mxu, ref.conv1d_causal(x, w), 1e-4)

    def test_fused_pipeline_strategies_agree(self, rng):
        from repro.kernels import ops
        x = jnp.array(rng.standard_normal((40, 72)), jnp.float32)
        chain = ["2d5pt", ("2d9pt", "gelu"), "2d5pt"]
        lanes = ops.pipeline(x, chain, impl="interpret", fuse=True,
                             strategy="lanes")
        mxu = ops.pipeline(x, chain, impl="interpret", fuse=True,
                           strategy="mxu")
        assert_close(mxu, lanes, 1e-4)
        assert_close(mxu, ops.pipeline(x, chain, impl="xla"), 1e-4)

    @pytest.mark.parametrize("strategy", ["lanes", "mxu"])
    def test_grouped_conv_vs_lax(self, rng, strategy):
        """groups= slices the reduce axis per group: validated against
        lax.conv_general_dilated's feature_group_count."""
        from repro.kernels import ops
        x = jnp.array(rng.standard_normal((2, 6, 10, 32)), jnp.float32)
        w = jnp.array(rng.standard_normal((4, 3, 3, 3)), jnp.float32)
        want = jax.lax.conv_general_dilated(
            x, w, (1, 1), [(1, 1), (1, 1)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            feature_group_count=2)
        got = ops.conv2d(x, w, mode="same", impl="interpret", groups=2,
                         strategy=strategy)
        assert_close(got, want, 1e-4)
        assert_close(ops.conv2d(x, w, mode="same", impl="xla", groups=2),
                     want, 1e-4)

    def test_depthwise_conv2d_groups(self, rng):
        """groups == C_in == C_out/1-per-group: the depthwise-2d case."""
        from repro.kernels import ops
        C = 6
        x = jnp.array(rng.standard_normal((2, C, 8, 24)), jnp.float32)
        w = jnp.array(rng.standard_normal((C, 1, 3, 3)), jnp.float32)
        want = jax.lax.conv_general_dilated(
            x, w, (1, 1), [(1, 1), (1, 1)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            feature_group_count=C)
        got = ops.conv2d(x, w, mode="same", impl="interpret", groups=C,
                         strategy="mxu")
        assert_close(got, want, 1e-4)

    def test_groups_validation_errors(self):
        from repro.kernels import ops
        x = jnp.zeros((1, 6, 8, 16), jnp.float32)
        with pytest.raises(ValueError, match="group"):
            ops.conv2d(x, jnp.zeros((4, 2, 3, 3), jnp.float32),
                       impl="interpret", groups=4)   # 2*4 != 6
        with pytest.raises(ValueError, match="group"):
            ops.conv2d(x, jnp.zeros((3, 3, 3, 3), jnp.float32),
                       impl="interpret", groups=2)   # C_out 3 % 2 != 0

    def test_invalid_strategy_named_error(self):
        from repro.kernels import ops
        x = jnp.zeros((16, 32), jnp.float32)
        with pytest.raises(ValueError, match="ops.stencil"):
            ops.stencil(x, "2d5pt", impl="interpret", strategy="tensor")

    def test_scan_plans_reject_strategy(self, rng):
        from repro.kernels import ops
        x = jnp.array(rng.standard_normal((4, 64)), jnp.float32)
        with pytest.raises(ValueError, match="strategy"):
            ops.cumsum(x, impl="interpret", strategy="mxu")

    def test_fuse_rejects_conflicting_pins(self):
        import dataclasses
        from repro.core.fuse import fuse_plans
        sdef = BENCHMARKS["2d5pt"]
        mk = lambda s: dataclasses.replace(
            stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs), strategy=s)
        with pytest.raises(ValueError, match="conflicting lowering"):
            fuse_plans(mk("lanes"), mk("mxu"))
        fused = fuse_plans(mk("mxu"), mk(None))   # one pin pins the chain
        assert fused.strategy == "mxu"
        assert fuse_plans(mk(None), mk(None)).strategy is None

    # ---- tuner integration (schema v5 strategy / v6 backend keys) ---------

    def test_candidates_enumerate_strategy(self):
        import dataclasses
        sdef = BENCHMARKS["2d25pt"]
        plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
        cands = tuning.candidate_configs(plan, (64, 96))
        assert {"lanes", "mxu"} <= {c.strategy for c in cands}
        pinned = dataclasses.replace(plan, strategy="mxu")
        pcands = tuning.candidate_configs(pinned, (64, 96))
        assert pcands and all(c.strategy == "mxu" for c in pcands)

    def test_model_crossover_by_tap_count(self):
        """§5 + MXU terms: narrow stencils stay on the VPU lanes, wide
        tap sets flip to the matmul path — the shape-dependent choice
        the strategy dimension exists to expose."""
        def best(name):
            sdef = BENCHMARKS[name]
            plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs) \
                if sdef.ndim == 2 else \
                stencil3d_plan(sdef.offsets, coeffs=sdef.coeffs)
            cands = tuning.candidate_configs(plan, (512, 512) if
                                             sdef.ndim == 2 else (64, 64, 64))
            return min(cands, key=lambda c: tuning.model_cost(plan, c))
        assert best("2d5pt").strategy == "lanes"
        assert best("2d9pt").strategy == "lanes"
        assert best("2d25pt").strategy == "mxu"
        assert best("2d121pt").strategy == "mxu"
        assert best("3d27pt").strategy == "mxu"

    def test_autotune_records_strategy_v6(self, rng, tmp_path, monkeypatch):
        """Measured winners land in the sidecar with the strategy field
        and the 7-component (strategy- and backend-keyed) v6 key."""
        import json
        from repro.kernels import ops
        tuning.clear_cache()
        tuning.clear_sidecar()
        monkeypatch.setenv(tuning.SIDECAR_ENV, str(tmp_path / "side.json"))
        try:
            x = jnp.array(rng.standard_normal((48, 96)), jnp.float32)
            out = ops.stencil(x, "2d25pt", impl="interpret", autotune=True,
                              strategy="mxu")
            assert_close(out, ref.stencil_iterate(x, BENCHMARKS["2d25pt"], 1),
                         1e-4)
            assert tuning._SIDECAR
            key, (cfg, _, _) = next(iter(tuning._SIDECAR.items()))
            parts = json.loads(key)
            assert len(parts) == 7 and parts[-2] == "mxu"
            assert parts[-1] in ("tpu", "gpu")
            assert cfg.strategy == "mxu"
            entries = tuning.sidecar_entries()
            assert all(v["schema"] == tuning.ENGINE_SCHEMA_VERSION
                       and v["strategy"] == "mxu" for v in entries.values())
        finally:
            tuning.clear_sidecar()
            tuning.clear_cache()

    def test_autotune_gpu_backend_v6_entries(self, rng, tmp_path,
                                             monkeypatch):
        """``autotune(backend='gpu')`` lands warp-shaped winners under a
        key whose seventh component says 'gpu' — and the same op tuned
        on the TPU lowering gets its own separate entry."""
        import json
        from repro.kernels import ops
        tuning.clear_cache()
        tuning.clear_sidecar()
        monkeypatch.setenv(tuning.SIDECAR_ENV, str(tmp_path / "side.json"))
        try:
            x = jnp.array(rng.standard_normal((48, 96)), jnp.float32)
            g = ops.stencil(x, "2d5pt", impl="interpret", autotune=True,
                            backend="gpu")
            t = ops.stencil(x, "2d5pt", impl="interpret", autotune=True,
                            backend="tpu")
            assert_close(g, ref.stencil_iterate(x, BENCHMARKS["2d5pt"], 1),
                         1e-4)
            assert_close(t, ref.stencil_iterate(x, BENCHMARKS["2d5pt"], 1),
                         1e-4)
            backends = {json.loads(k)[-1] for k in tuning._SIDECAR}
            assert {"gpu", "tpu"} <= backends
            # GPU winners come from the warp-multiple grid
            for k, (cfg, _, _) in tuning._SIDECAR.items():
                if json.loads(k)[-1] == "gpu" and len(cfg.block) == 2:
                    assert cfg.block[-1] % 32 == 0 or cfg.block[-1] < 32
        finally:
            tuning.clear_sidecar()
            tuning.clear_cache()

    def test_nearest_seed_never_crosses_strategy(self):
        """Satellite regression: nearest-shape seeding requires the
        strategy key component to match — a winner tuned under an 'mxu'
        pin must never seed an auto or 'lanes' tune."""
        sdef = BENCHMARKS["2d9pt"]
        plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
        sig = tuning.plan_signature(plan)
        tuning.clear_sidecar()
        try:
            cfg = tuning.KernelConfig((8, 64), "shift_psum", "mxu")
            key = tuning._sidecar_key(sig, (128, 128), 1, (), "mxu")
            tuning._SIDECAR[key] = (cfg, 1.0, 2.0)
            assert tuning._nearest_sidecar(sig, (96, 96), 1, (), "mxu") == cfg
            assert tuning._nearest_sidecar(sig, (96, 96), 1, (), "auto") \
                is None
            assert tuning._nearest_sidecar(sig, (96, 96), 1, (), "lanes") \
                is None
        finally:
            tuning.clear_sidecar()

    def test_nearest_seed_never_crosses_backend(self):
        """v6 regression: a winner measured against the GPU warp tiling
        must never seed a TPU tune of the same plan/shape — the key's
        seventh component keeps the lowerings apart."""
        sdef = BENCHMARKS["2d9pt"]
        plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
        sig = tuning.plan_signature(plan)
        tuning.clear_sidecar()
        try:
            cfg = tuning.KernelConfig((8, 64), "shift_psum")
            key = tuning._sidecar_key(sig, (128, 128), 1, (), "auto", "gpu")
            tuning._SIDECAR[key] = (cfg, 1.0, 2.0)
            assert tuning._nearest_sidecar(
                sig, (96, 96), 1, (), "auto", "gpu") == cfg
            assert tuning._nearest_sidecar(
                sig, (96, 96), 1, (), "auto", "tpu") is None
        finally:
            tuning.clear_sidecar()

    def test_stale_v5_sidecar_entries_ignored(self, tmp_path):
        """v5 sidecars predate the backend dimension (6-component keys,
        schema 5): the loader and the checkpoint merge path must drop
        every entry — a v5 winner never recorded which lowering it
        measured."""
        import json
        v5_key = json.dumps(["conv2d:5x3", [64, 64], 1, "cpu", [], "auto"])
        entries = {v5_key: {"block": [8, 128], "variant": "shift_psum",
                            "strategy": None, "model_cost": 1.0,
                            "measured_us": 5.0, "schema": 5}}
        path = tmp_path / "v5.json"
        path.write_text(json.dumps({"version": 1, "entries": entries}))
        tuning.clear_sidecar()
        try:
            assert tuning.load_sidecar(str(path)) == 0
            assert not tuning._SIDECAR
            assert tuning.merge_sidecar_entries(entries) == 0
            assert not tuning._SIDECAR
        finally:
            tuning.clear_sidecar()

    def test_stale_v4_sidecar_entries_ignored(self, tmp_path):
        """v4 sidecars predate the strategy dimension (no strategy field,
        5-component keys): both the file loader and the checkpoint merge
        path must drop every entry — a v4 winner was never tuned over
        the algorithm choice."""
        import json
        v4_key = json.dumps(["conv2d:5x3", [64, 64], 1, "cpu", []])
        entries = {v4_key: {"block": [8, 128], "variant": "shift_psum",
                            "model_cost": 1.0, "measured_us": 5.0,
                            "schema": 4}}
        path = tmp_path / "v4.json"
        path.write_text(json.dumps({"version": 1, "entries": entries}))
        tuning.clear_sidecar()
        try:
            assert tuning.load_sidecar(str(path)) == 0
            assert not tuning._SIDECAR
            assert tuning.merge_sidecar_entries(entries) == 0
            assert not tuning._SIDECAR
        finally:
            tuning.clear_sidecar()
