"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Shape/dtype sweeps per the assignment; hypothesis property tests for the
algebraic invariants (linearity, shift-equivariance, associativity).
Block sizes are deliberately small so the interpret-mode grid actually
exercises multi-block + halo paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback examples
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.stencils import BENCHMARKS


def assert_close(a, b, tol=3e-5):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

class TestConv2d:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("fshape", [(2, 2), (3, 3), (5, 2), (2, 5),
                                        (7, 7), (11, 11)])
    def test_filter_sweep(self, rng, fshape, dtype):
        N, M = fshape
        tol = 3e-5 if dtype == jnp.float32 else 3e-2
        x = jnp.array(rng.standard_normal((33, 70)), dtype)
        w = jnp.array(rng.standard_normal((N, M)), dtype)
        out = ops.conv2d(x, w, mode="valid", impl="interpret",
                         block_h=8, block_w=32)
        assert_close(out, ref.conv2d_valid(x, w), tol)

    @pytest.mark.parametrize("variant", ["shift_psum", "shift_data"])
    def test_variants_match(self, rng, variant):
        x = jnp.array(rng.standard_normal((20, 64)), jnp.float32)
        w = jnp.array(rng.standard_normal((4, 6)), jnp.float32)
        out = ops.conv2d(x, w, mode="same", impl="interpret",
                         block_h=4, block_w=16, variant=variant)
        assert_close(out, ref.conv2d_same(x, w))

    @given(
        H=st.integers(5, 24), W=st.integers(8, 48),
        N=st.integers(1, 4), M=st.integers(1, 4), seed=st.integers(0, 2**31),
    )
    @settings(max_examples=15, deadline=None)
    def test_arbitrary_shapes(self, H, W, N, M, seed):
        r = np.random.default_rng(seed)
        x = jnp.array(r.standard_normal((max(H, N), max(W, M))), jnp.float32)
        w = jnp.array(r.standard_normal((N, M)), jnp.float32)
        out = ops.conv2d(x, w, mode="valid", impl="interpret",
                         block_h=4, block_w=16)
        assert_close(out, ref.conv2d_valid(x, w))

    def test_linearity_property(self, rng):
        """conv(αx + βy) == α·conv(x) + β·conv(y)."""
        x = jnp.array(rng.standard_normal((16, 40)), jnp.float32)
        y = jnp.array(rng.standard_normal((16, 40)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 3)), jnp.float32)
        k = lambda v: ops.conv2d(v, w, mode="valid", impl="interpret",
                                 block_h=4, block_w=16)
        assert_close(k(2.0 * x + 0.5 * y), 2.0 * k(x) + 0.5 * k(y), 1e-4)

    def test_delta_filter_is_identity(self, rng):
        x = jnp.array(rng.standard_normal((12, 40)), jnp.float32)
        w = jnp.zeros((3, 3), jnp.float32).at[1, 1].set(1.0)
        out = ops.conv2d(x, w, mode="same", impl="interpret",
                         block_h=4, block_w=16)
        assert_close(out, x)


# ---------------------------------------------------------------------------
# stencils
# ---------------------------------------------------------------------------

class TestStencil2d:
    @pytest.mark.parametrize("name", [n for n, d in BENCHMARKS.items()
                                      if d.ndim == 2])
    def test_all_2d_benchmarks(self, rng, name):
        x = jnp.array(rng.standard_normal((26, 70)), jnp.float32)
        out = ops.stencil(x, name, impl="interpret", block_h=8, block_w=32)
        assert_close(out, ref.stencil_iterate(x, BENCHMARKS[name], 1))

    @pytest.mark.parametrize("t", [2, 4])
    @pytest.mark.parametrize("name", ["2d5pt", "2d9pt"])
    def test_temporal_blocking(self, rng, name, t):
        x = jnp.array(rng.standard_normal((24, 48)), jnp.float32)
        out = ops.stencil(x, name, time_steps=t, impl="interpret",
                          block_h=8, block_w=16)
        assert_close(out, ref.stencil_iterate(x, BENCHMARKS[name], t), 1e-4)

    def test_temporal_matches_dirichlet_interior(self, rng):
        """Pad-once semantics == classic zero-boundary iteration on the
        interior at distance > t·r from the edge (documented property)."""
        sdef = BENCHMARKS["2d5pt"]
        t = 3
        x = jnp.array(rng.standard_normal((30, 40)), jnp.float32)
        a = np.asarray(ref.stencil_iterate(x, sdef, t))
        b = np.asarray(ref.stencil_iterate_dirichlet(x, sdef, t))
        m = t * sdef.radius
        np.testing.assert_allclose(a[m:-m, m:-m], b[m:-m, m:-m],
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, rng, dtype):
        tol = 1e-4 if dtype == jnp.float32 else 3e-2
        x = jnp.array(rng.standard_normal((16, 40)), dtype)
        out = ops.stencil(x, "2d9pt", impl="interpret", block_h=8, block_w=32)
        assert_close(out, ref.stencil_iterate(x, BENCHMARKS["2d9pt"], 1), tol)


class TestStencil3d:
    @pytest.mark.parametrize("name", [n for n, d in BENCHMARKS.items()
                                      if d.ndim == 3])
    def test_all_3d_benchmarks(self, rng, name):
        x = jnp.array(rng.standard_normal((10, 12, 40)), jnp.float32)
        out = ops.stencil(x, name, impl="interpret", block_z=4, block_h=8,
                          block_w=16)
        assert_close(out, ref.stencil_iterate(x, BENCHMARKS[name], 1))

    def test_3d_temporal(self, rng):
        x = jnp.array(rng.standard_normal((8, 10, 24)), jnp.float32)
        out = ops.stencil(x, "3d7pt", time_steps=2, impl="interpret",
                          block_z=4, block_h=4, block_w=8)
        assert_close(out, ref.stencil_iterate(x, BENCHMARKS["3d7pt"], 2), 1e-4)


# ---------------------------------------------------------------------------
# conv1d + scans
# ---------------------------------------------------------------------------

class TestConv1d:
    @pytest.mark.parametrize("K", [1, 2, 4, 8])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_k_sweep(self, rng, K, dtype):
        tol = 1e-4 if dtype == jnp.float32 else 3e-2
        x = jnp.array(rng.standard_normal((2, 37, 24)), dtype)
        w = jnp.array(rng.standard_normal((K, 24)), dtype)
        out = ops.conv1d_causal(x, w, impl="interpret", block_t=16, block_d=8)
        assert_close(out, ref.conv1d_causal(x, w), tol)

    def test_token_shift_special_case(self, rng):
        """RWKV token shift == conv1d with w = [1, 0] (K=2)."""
        x = jnp.array(rng.standard_normal((1, 20, 8)), jnp.float32)
        w = jnp.zeros((2, 8), jnp.float32).at[0].set(1.0)
        out = ops.conv1d_causal(x, w, impl="interpret", block_t=8, block_d=8)
        shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        assert_close(out, shifted)


class TestScan:
    @pytest.mark.parametrize("T", [32, 100, 256])
    def test_cumsum(self, rng, T):
        x = jnp.array(rng.standard_normal((5, T)), jnp.float32)
        out = ops.cumsum(x, impl="interpret", block_r=4, block_t=32)
        assert_close(out, ref.cumsum(x), 1e-4)

    @pytest.mark.parametrize("T", [32, 100, 256])
    def test_linear_recurrence(self, rng, T):
        a = jnp.array(rng.uniform(0.5, 1.0, (5, T)), jnp.float32)
        b = jnp.array(rng.standard_normal((5, T)), jnp.float32)
        out = ops.linear_recurrence(a, b, impl="interpret",
                                    block_r=4, block_t=32)
        assert_close(out, ref.linear_recurrence(a, b), 1e-3)

    @given(T=st.integers(4, 80), seed=st.integers(0, 2**31))
    @settings(max_examples=15, deadline=None)
    def test_chunked_recurrence_property(self, T, seed):
        r = np.random.default_rng(seed)
        a = jnp.array(r.uniform(0.3, 1.0, (3, T)), jnp.float32)
        b = jnp.array(r.standard_normal((3, T)), jnp.float32)
        out = ops.chunked_linear_recurrence(a, b, chunk=16)
        assert_close(out, ref.linear_recurrence(a, b), 1e-3)

    def test_sat(self, rng):
        """Summed-area table == double cumsum oracle (paper §3.6 app)."""
        x = jnp.array(rng.standard_normal((24, 40)), jnp.float32)
        out = ops.sat(x, impl="interpret", block_r=8, block_t=32)
        assert_close(out, ref.sat(x), 1e-4)

    def test_sat_box_sum_property(self, rng):
        """Any box sum from 4 SAT corner reads — the SAT use-case."""
        x = jnp.array(rng.standard_normal((16, 16)), jnp.float32)
        s = np.asarray(ref.sat(x))
        y0, y1, x0, x1 = 3, 11, 2, 13
        box = s[y1, x1] - s[y0 - 1, x1] - s[y1, x0 - 1] + s[y0 - 1, x0 - 1]
        np.testing.assert_allclose(
            box, np.asarray(x)[y0:y1 + 1, x0:x1 + 1].sum(), rtol=1e-4)

    def test_cumsum_is_recurrence_with_a1(self, rng):
        """cumsum == linear recurrence with a ≡ 1 (plan unification)."""
        x = jnp.array(rng.standard_normal((3, 64)), jnp.float32)
        out = ops.linear_recurrence(jnp.ones_like(x), x, impl="interpret",
                                    block_r=4, block_t=32)
        assert_close(out, ref.cumsum(x), 1e-4)


# ---------------------------------------------------------------------------
# SSAM model ↔ kernels: the executor and the Pallas kernel implement the
# same schedule
# ---------------------------------------------------------------------------

class TestModelKernelAgreement:
    def test_conv2d_kernel_matches_executor(self, rng):
        from repro.core import conv2d_plan, execute_conv_global
        x = jnp.array(rng.standard_normal((14, 60)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 5)), jnp.float32)
        plan = conv2d_plan(5, 3, S=60, P=1)
        a = execute_conv_global(plan, x, w)
        b = ops.conv2d(x, w, mode="valid", impl="interpret",
                       block_h=4, block_w=16)
        assert_close(a, b, 1e-4)

    def test_wkv6_vs_ssam_linear_recurrence(self, rng):
        """RWKV6's WKV (chunked matmul form) == the SSAM elementwise
        linear-recurrence kernel on the flattened channel view."""
        from repro.nn.ssm import wkv6_chunked
        B, T, H, K, V = 1, 40, 2, 4, 4
        r = jnp.array(rng.standard_normal((B, T, H, K)) * 0.5, jnp.float32)
        k = jnp.array(rng.standard_normal((B, T, H, K)) * 0.5, jnp.float32)
        v = jnp.array(rng.standard_normal((B, T, H, V)), jnp.float32)
        logw = -jnp.exp(jnp.array(rng.standard_normal((B, T, H, K)) * 0.3,
                                  jnp.float32))
        u = jnp.zeros((H, K), jnp.float32)   # drop bonus for pure recurrence
        y, S_last = wkv6_chunked(r, k, v, logw, u, chunk=16)
        # State recurrence per (h, kk, vv) channel: S_t = e^{logw}·S + k·v
        a = jnp.exp(logw)[..., None] * jnp.ones((1, 1, 1, 1, V))
        b = k[..., None] * v[..., None, :]
        aa = a.transpose(0, 2, 3, 4, 1).reshape(-1, T)
        bb = b.transpose(0, 2, 3, 4, 1).reshape(-1, T)
        S_t = ops.linear_recurrence(aa, bb, impl="interpret",
                                    block_r=4, block_t=16)
        S_ref = S_t[:, -1].reshape(B, H, K, V)
        assert_close(S_last, S_ref, 1e-3)
