"""GPU-backend equivalence suite (DESIGN.md §14).

The GPU lowering (``core/engine_gpu.py``) maps the unchanged plan IR
onto warp-shuffle psum shifts, SMEM skirt staging and per-thread
register accumulators. Interpret mode runs that lowering on any host,
so CI proves here that for every plan family

1. ``warp_shift`` — the shuffle + warp-boundary hand-off decomposition —
   is *bitwise* ``jnp.roll`` (the emulation contract the module
   docstring documents),
2. the GPU lowering matches the TPU lowering and the pure-jnp oracles
   in ``ref.py`` across the full Table-3 zoo × schedule variants ×
   ``time_steps ∈ {1, 2}``, convs (all ranks), scans and recurrences,
3. the ops layer's ``backend=`` / ``repro.config`` session default
   actually select it.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import config
from repro.core import (conv2d_nchw_plan, conv2d_plan, conv2d_same_plan,
                        linear_recurrence_plan, run_scan_plan,
                        run_window_plan, scan_plan, stencil2d_plan,
                        stencil3d_plan)
from repro.core import engine_gpu
from repro.core.engine_gpu import run_scan_plan_gpu, run_window_plan_gpu, \
    warp_shift
from repro.core.plan import GPU_WARP_LANES
from repro.kernels import ref
from repro.kernels.stencils import BENCHMARKS

VARIANTS = ("shift_psum", "shift_data")


def assert_close(a, b, tol=3e-5):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=tol, atol=tol)


def assert_bitwise(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# warp_shift: the shuffle decomposition is exactly a lane roll
# ---------------------------------------------------------------------------

class TestWarpShift:
    @pytest.mark.parametrize("shift", [0, 1, 5, 31, 32, 33, 64, 95, 127])
    @pytest.mark.parametrize("lanes", [32, 64, 128, 256])
    def test_bitwise_roll_warp_aligned(self, rng, lanes, shift):
        """shift = q·warp + r decomposition composes to the exact roll."""
        v = jnp.array(rng.standard_normal((6, lanes)), jnp.float32)
        assert_bitwise(warp_shift(v, shift), jnp.roll(v, shift, axis=-1))

    @pytest.mark.parametrize("shift", [1, 17, 32, 40])
    def test_negative_shift_shfl_down(self, rng, shift):
        v = jnp.array(rng.standard_normal((4, 128)), jnp.float32)
        assert_bitwise(warp_shift(v, -shift), jnp.roll(v, -shift, axis=-1))

    @pytest.mark.parametrize("lanes", [8, 48, 100])
    def test_fractional_warp_falls_back(self, rng, lanes):
        """Lane extents that are not whole warps use the documented
        plain-roll fallback — same values either way."""
        v = jnp.array(rng.standard_normal((3, lanes)), jnp.float32)
        assert_bitwise(warp_shift(v, 3), jnp.roll(v, 3, axis=-1))

    def test_nd_leading_axes(self, rng):
        v = jnp.array(rng.standard_normal((2, 3, 4, 64)), jnp.float32)
        assert_bitwise(warp_shift(v, 33), jnp.roll(v, 33, axis=-1))

    def test_custom_warp_width(self, rng):
        v = jnp.array(rng.standard_normal((2, 64)), jnp.float32)
        assert_bitwise(warp_shift(v, 10, warp=16),
                       jnp.roll(v, 10, axis=-1))


# ---------------------------------------------------------------------------
# Table-3 zoo: GPU lowering vs TPU lowering vs the jnp oracle
# ---------------------------------------------------------------------------

class TestStencilZooGpu:
    @pytest.mark.parametrize("variant", VARIANTS)
    @pytest.mark.parametrize("t", [1, 2])
    @pytest.mark.parametrize("name", sorted(BENCHMARKS))
    def test_zoo_matrix(self, rng, name, t, variant):
        sdef = BENCHMARKS[name]
        if sdef.ndim == 2:
            x = jnp.array(rng.standard_normal((22, 64)), jnp.float32)
            plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
            block = (8, 32)
        else:
            x = jnp.array(rng.standard_normal((8, 10, 32)), jnp.float32)
            plan = stencil3d_plan(sdef.offsets, coeffs=sdef.coeffs)
            block = (4, 4, 32)
        gpu = run_window_plan_gpu(x, plan=plan, block=block, time_steps=t,
                                  variant=variant)
        tpu = run_window_plan(x, plan=plan, block=block, time_steps=t,
                              variant=variant, backend="tpu")
        assert_close(gpu, ref.stencil_iterate(x, sdef, t), 2e-4)
        # same tap walk, same accumulation order → bitwise across backends
        assert_bitwise(gpu, tpu)

    @pytest.mark.parametrize("name", ["2d25pt", "2d121pt", "3d27pt"])
    def test_mxu_strategy_on_gpu(self, rng, name):
        """strategy='mxu' (tensor-core im2row) through the GPU lowering
        matches the lanes schedule to fp32 tolerance."""
        sdef = BENCHMARKS[name]
        if sdef.ndim == 2:
            x = jnp.array(rng.standard_normal((24, 64)), jnp.float32)
            plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
            block = (8, 32)
        else:
            x = jnp.array(rng.standard_normal((8, 10, 32)), jnp.float32)
            plan = stencil3d_plan(sdef.offsets, coeffs=sdef.coeffs)
            block = (4, 4, 32)
        mxu = run_window_plan_gpu(x, plan=plan, block=block, strategy="mxu")
        assert_close(mxu, ref.stencil_iterate(x, sdef, 1), 2e-5)


# ---------------------------------------------------------------------------
# conv family through the GPU lowering
# ---------------------------------------------------------------------------

class TestConvGpu:
    @pytest.mark.parametrize("variant", VARIANTS)
    @pytest.mark.parametrize("fs", [2, 3, 5, 7])
    def test_conv2d_valid(self, rng, fs, variant):
        x = jnp.array(rng.standard_normal((24, 64)), jnp.float32)
        w = jnp.array(rng.standard_normal((fs, fs)), jnp.float32)
        gpu = run_window_plan_gpu(x, w, plan=conv2d_plan(fs, fs),
                                  block=(8, 32), variant=variant)
        tpu = run_window_plan(x, w, plan=conv2d_plan(fs, fs), block=(8, 32),
                              variant=variant, backend="tpu")
        assert_close(gpu, ref.conv2d_valid(x, w))
        assert_bitwise(gpu, tpu)

    def test_conv2d_same(self, rng):
        x = jnp.array(rng.standard_normal((20, 64)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 5)), jnp.float32)
        gpu = run_window_plan_gpu(x, w, plan=conv2d_same_plan(5, 3),
                                  block=(8, 32))
        assert_close(gpu, ref.conv2d_same(x, w))

    def test_conv2d_nchw_register_accumulator(self, rng):
        """The reduce sweep (NCHW C_in accumulation) through the GPU
        kernel's register-accumulator discipline."""
        B, Ci, Co, H, W = 2, 3, 4, 12, 32
        x = jnp.array(rng.standard_normal((B, Ci, H, W)), jnp.float32)
        w = jnp.array(rng.standard_normal((Co, Ci, 3, 3)), jnp.float32)
        plan = conv2d_nchw_plan(B, Ci, Co, 3, 3)
        gpu = run_window_plan_gpu(x, w, plan=plan, block=(8, 16))
        tpu = run_window_plan(x, w, plan=plan, block=(8, 16), backend="tpu")
        assert_close(gpu, ref.conv2d_nchw(x, w, "valid"), 1e-4)
        assert_close(gpu, tpu, 1e-6)

    def test_ops_conv1d_causal_gpu(self, rng):
        from repro.kernels import ops
        x = jnp.array(rng.standard_normal((4, 50, 8)), jnp.float32)
        w = jnp.array(rng.standard_normal((4, 8)), jnp.float32)
        gpu = ops.conv1d_causal(x, w, impl="interpret", backend="gpu")
        assert_close(gpu, ref.conv1d_causal(x, w))

    def test_epilogue_fusion_gpu(self, rng):
        from repro.kernels import ops
        x = jnp.array(rng.standard_normal((20, 64)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 3)), jnp.float32)
        b = jnp.float32(0.7)
        gpu = ops.conv2d(x, w, impl="interpret", backend="gpu",
                         epilogue=("bias", "gelu"),
                         epilogue_args=(b,))
        want = ops.conv2d(x, w, impl="xla", epilogue=("bias", "gelu"),
                          epilogue_args=(b,))
        assert_close(gpu, want, 1e-4)

    def test_strided_grid_gpu(self, rng):
        from repro.kernels import ops
        x = jnp.array(rng.standard_normal((20, 64)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 3)), jnp.float32)
        gpu = ops.conv2d(x, w, impl="interpret", backend="gpu", stride=2)
        want = ops.conv2d(x, w, impl="xla", stride=2)
        assert_close(gpu, want, 1e-4)


# ---------------------------------------------------------------------------
# scans and recurrences
# ---------------------------------------------------------------------------

class TestScanGpu:
    def test_cumsum_bitwise_vs_tpu(self, rng):
        x = jnp.array(rng.standard_normal((8, 256)), jnp.float32)
        plan = scan_plan(128)
        gpu = run_scan_plan_gpu(x, plan=plan, block_r=4)
        tpu = run_scan_plan(x, plan=plan, block_r=4, backend="tpu")
        assert_close(gpu, jnp.cumsum(x, axis=-1), 1e-4)
        assert_bitwise(gpu, tpu)

    def test_linrec_one_ulp_vs_tpu(self, rng):
        """linrec's per-step A·Bs + B may contract to FMA differently
        between the kernel bodies — allow ≤1 ulp, nothing more."""
        a = jnp.array(rng.uniform(0.5, 1.0, (4, 128)), jnp.float32)
        b = jnp.array(rng.standard_normal((4, 128)), jnp.float32)
        plan = linear_recurrence_plan(128)
        gpu = run_scan_plan_gpu(a, b, plan=plan, block_r=4)
        tpu = run_scan_plan(a, b, plan=plan, block_r=4, backend="tpu")
        g, t = np.asarray(gpu), np.asarray(tpu)
        ulp = np.spacing(np.maximum(np.abs(g), np.abs(t)))
        assert np.all(np.abs(g - t) <= ulp)
        want = ref.linear_recurrence(a, b)
        assert_close(gpu, want, 1e-4)

    def test_carry_round_trip(self, rng):
        x = jnp.array(rng.standard_normal((4, 128)), jnp.float32)
        plan = scan_plan(64)
        y1, c1 = run_scan_plan_gpu(x[:, :64], plan=plan, block_r=4,
                                   return_carry=True)
        y2 = run_scan_plan_gpu(x[:, 64:], plan=plan, block_r=4, carry=c1)
        whole = run_scan_plan_gpu(x, plan=plan, block_r=4)
        assert_close(jnp.concatenate([y1, y2], axis=-1), whole, 1e-5)

    def test_chunked_linear_recurrence_gpu(self, rng):
        from repro.kernels import ops
        a = jnp.array(rng.uniform(0.5, 1.0, (2, 3, 70)), jnp.float32)
        b = jnp.array(rng.standard_normal((2, 3, 70)), jnp.float32)
        got = ops.chunked_linear_recurrence(a, b, chunk=32, impl="engine",
                                            backend="gpu")
        want = ops.chunked_linear_recurrence(a, b)
        assert_close(got, want, 1e-4)


# ---------------------------------------------------------------------------
# dispatch: ops backend=, config default, and gradients
# ---------------------------------------------------------------------------

class TestBackendDispatch:
    def test_ops_stencil_backend_kwarg(self, rng):
        from repro.kernels import ops
        x = jnp.array(rng.standard_normal((24, 96)), jnp.float32)
        g = ops.stencil(x, "2d9pt", impl="interpret", backend="gpu",
                        time_steps=2)
        t = ops.stencil(x, "2d9pt", impl="interpret", backend="tpu",
                        time_steps=2)
        assert_bitwise(g, t)

    def test_unknown_backend_named_error(self, rng):
        from repro.kernels import ops
        x = jnp.array(rng.standard_normal((8, 32)), jnp.float32)
        with pytest.raises(ValueError, match="ops.stencil.*cuda"):
            ops.stencil(x, "2d5pt", impl="interpret", backend="cuda")

    def test_config_session_default(self, rng):
        """set_engine_backend('gpu') routes backend=None calls to the
        GPU lowering; None restores auto (tpu on this host)."""
        from repro.kernels import ops
        x = jnp.array(rng.standard_normal((16, 64)), jnp.float32)
        want = ops.stencil(x, "2d5pt", impl="interpret")
        try:
            config.set_engine_backend("gpu")
            assert config.engine_backend() == "gpu"
            got = ops.stencil(x, "2d5pt", impl="interpret")
        finally:
            config.set_engine_backend(None)
        assert config.engine_backend() in ("tpu", "gpu")
        assert_close(got, want, 1e-6)

    def test_env_default(self, monkeypatch):
        monkeypatch.setenv(config.ENGINE_BACKEND_ENV, "gpu")
        assert config.engine_backend() == "gpu"
        monkeypatch.setenv(config.ENGINE_BACKEND_ENV, "bogus")
        with pytest.raises(ValueError, match="bogus"):
            config.engine_backend()

    def test_grad_through_gpu_backend(self, rng):
        """jax.grad of an ops call pinned to the GPU lowering runs the
        adjoint plan through the same backend and matches the oracle."""
        from repro.kernels import ops
        x = jnp.array(rng.standard_normal((16, 64)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 3)), jnp.float32)
        gx, gw = jax.grad(lambda a, b: jnp.sum(ops.conv2d(
            a, b, impl="interpret", backend="gpu") ** 2), (0, 1))(x, w)
        wx, ww = jax.grad(lambda a, b: jnp.sum(ops.conv2d(
            a, b, impl="xla") ** 2), (0, 1))(x, w)
        assert_close(gx, wx, 1e-3)
        assert_close(gw, ww, 1e-3)

    def test_machine_model_registry(self):
        from repro.core import perfmodel, tuning
        gpu = perfmodel.machine_for("gpu")
        tpu = perfmodel.machine_for("tpu")
        assert gpu.backend == "gpu" and gpu.warp == GPU_WARP_LANES
        assert tpu.backend == "tpu" and tpu.lanes == 128
        with pytest.raises(ValueError, match="machine"):
            perfmodel.machine_for("npu")
        # the §5 model prices against the chosen machine's latencies
        sdef = BENCHMARKS["2d9pt"]
        plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
        cfg = tuning.KernelConfig((8, 128), "shift_psum")
        ct = tuning.model_cost(plan, cfg, backend="tpu")
        cg = tuning.model_cost(plan, cfg, backend="gpu")
        assert ct > 0 and cg > 0 and ct != cg

    def test_gpu_candidates_warp_shaped(self):
        from repro.core import tuning
        sdef = BENCHMARKS["2d9pt"]
        plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
        cands = tuning.candidate_configs(plan, (64, 256), backend="gpu")
        assert cands
        lanes = {c.block[-1] for c in cands}
        assert lanes <= {32, 64, 128, 256}, lanes

    def test_fused_pipeline_gpu(self, rng):
        from repro.kernels import ops
        x = jnp.array(rng.standard_normal((24, 96)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 3)), jnp.float32)
        g = ops.pipeline(x, ["2d5pt", (w, "gelu")], impl="interpret",
                         fuse=True, backend="gpu")
        t = ops.pipeline(x, ["2d5pt", (w, "gelu")], impl="interpret",
                         fuse=True, backend="tpu")
        assert_close(g, t, 1e-6)
        assert_close(g, ops.pipeline(x, ["2d5pt", (w, "gelu")], impl="xla"),
                     2e-4)

    def test_smem_staging_requested(self):
        """The GPU lowering requests an SMEM (or documented VMEM stand-in)
        staging buffer — the §14 skirt-through-shared-memory discipline."""
        scratch = []
        sdef = BENCHMARKS["2d5pt"]
        plan = stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)
        orig = engine_gpu._smem

        def spy(shape, dtype):
            scratch.append(shape)
            return orig(shape, dtype)

        engine_gpu._smem = spy
        try:
            x = jnp.zeros((16, 64), jnp.float32)
            run_window_plan_gpu(x, plan=plan, block=(8, 32))
        finally:
            engine_gpu._smem = spy and orig
        assert scratch and scratch[0] == plan.block_in_shape((8, 32), 1)
