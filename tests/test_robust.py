"""Chaos matrix for the guarded-execution layer (DESIGN.md §16).

Covers the PR-10 acceptance gates:

- fault injection units: spec grammar, registry-closed arming, crc32
  determinism, context-manager state restore, one-bool-read off path;
- guard lattice units: demotion order, counter/annotation emission,
  'raise' vs 'fallback' policy semantics, organic errors re-raised
  unchanged, numerics guard;
- the per-site chaos matrix: for every registered engine site, (a)
  'raise' surfaces a structured error naming the site, (b) 'fallback'
  serves an oracle-equal result with the degradation counter bumped,
  (c) nothing armed → zero fired faults and zero demotions;
- acceptance sweep: every site armed at prob 1.0 under 'fallback' →
  the full Table-3 zoo, NCHW conv, fused pipelines and the scan family
  stay reference-equal on both engine backends, demotions observable;
- tuner hardening: retry/backoff, quarantine, model-ranked fallback,
  measurement rejection, tuning budget, sidecar checksums + corrupt-file
  quarantine;
- serving hardening: failed steps surface or shed load per policy,
  deadlines sweep, every request always comes back ``done``.

The suite-wide policy is pinned to 'raise' in tests/conftest.py so the
rest of the test suite can never vacuously pass through a silent oracle
fallback; chaos tests opt into 'fallback' explicitly.
"""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, robust
from repro.core import tuning
from repro.kernels import ops, ref
from repro.kernels.stencils import BENCHMARKS
from repro.robust import faults, guard


@pytest.fixture(autouse=True)
def _clean_slate():
    faults.disarm()
    obs.metrics.reset()
    tuning.clear_cache()
    yield
    faults.disarm()
    tuning.clear_cache()


def _x2d(shape=(48, 128), seed=0):
    return jnp.asarray(np.random.default_rng(seed)
                       .standard_normal(shape).astype(np.float32))


# ---------------------------------------------------------------------------
# Fault-injection units
# ---------------------------------------------------------------------------

class TestFaultSpec:
    def test_parse_single(self):
        assert faults.parse_spec("engine.window:1.0") == {
            "engine.window": (1.0, 0)}

    def test_parse_multi_with_seed(self):
        spec = faults.parse_spec("engine.scan:0.5:7, serve.step:0.25")
        assert spec == {"engine.scan": (0.5, 7), "serve.step": (0.25, 0)}

    def test_parse_all_arms_every_site(self):
        spec = faults.parse_spec("all:0.5:3")
        assert set(spec) == set(faults.SITES)
        assert all(v == (0.5, 3) for v in spec.values())

    def test_unknown_site_is_named_error(self):
        with pytest.raises(ValueError, match="registered sites"):
            faults.parse_spec("engine.wndow:1.0")
        with pytest.raises(ValueError, match="registered sites"):
            faults.arm({"no.such.site": (1.0, 0)})

    def test_bad_prob_rejected(self):
        with pytest.raises(ValueError, match="not a float"):
            faults.parse_spec("engine.window:high")
        with pytest.raises(ValueError, match=r"\[0, 1\]"):
            faults.parse_spec("engine.window:1.5")
        with pytest.raises(ValueError, match="site:prob"):
            faults.parse_spec("engine.window")

    def test_deterministic_firing(self):
        """Which occurrences fire is a pure function of (seed, site, n):
        two fresh armings replay the identical pattern."""
        def pattern():
            out = []
            with robust.inject("engine.window:0.5:11"):
                for _ in range(64):
                    try:
                        faults.check("engine.window")
                        out.append(0)
                    except faults.FaultInjected:
                        out.append(1)
            return out

        p1, p2 = pattern(), pattern()
        assert p1 == p2
        assert 0 < sum(p1) < 64          # p=0.5 actually mixes

    def test_different_seeds_differ(self):
        def pattern(seed):
            out = []
            with robust.inject(f"engine.window:0.5:{seed}"):
                for _ in range(64):
                    try:
                        faults.check("engine.window")
                        out.append(0)
                    except faults.FaultInjected:
                        out.append(1)
            return out

        assert pattern(1) != pattern(2)

    def test_fault_carries_site_and_occurrence(self):
        with robust.inject("engine.scan:1.0"):
            with pytest.raises(faults.FaultInjected) as ei:
                faults.check("engine.scan")
        assert ei.value.site == "engine.scan"
        assert ei.value.occurrence == 0

    def test_inject_restores_prior_state(self):
        faults.arm("serve.step:0.25:9")
        with robust.inject("engine.window:1.0"):
            assert "engine.window" in faults.armed_sites()
        assert faults.armed_sites() == {"serve.step": (0.25, 9)}
        faults.disarm()
        assert faults.armed_sites() == {}

    def test_unarmed_site_never_fires(self):
        with robust.inject("engine.window:1.0"):
            faults.check("engine.scan")     # not armed: no-op
        assert faults.fired_counts() == {}

    def test_disarmed_check_is_cheap(self):
        """The off path is one module-global bool read — bound it loosely
        (10 µs/call) so only a real regression (dict lookup, lock, raise
        machinery on the hot path) can trip it on a noisy host."""
        n = 50_000
        t0 = time.perf_counter()
        for _ in range(n):
            faults.check("engine.window")
        per_call = (time.perf_counter() - t0) / n
        assert per_call < 10e-6, f"{per_call * 1e6:.2f} µs per no-op check"


# ---------------------------------------------------------------------------
# Guard lattice units
# ---------------------------------------------------------------------------

class TestGuardLattice:
    def test_first_success_emits_nothing(self):
        out = guard.run("op", [("tuned", lambda: 42),
                               ("oracle", lambda: 0)])
        assert out == 42
        assert obs.metrics.counter_total("robust.demotion") == 0
        assert obs.metrics.counter_total("robust.served_degraded") == 0

    def test_fallback_walks_lattice_and_counts(self):
        def boom():
            raise faults.FaultInjected("engine.window", 0)

        with robust.failure_policy("fallback"):
            out = guard.run("stencil", [("tuned", boom),
                                        ("default", boom),
                                        ("oracle", lambda: 7)])
        assert out == 7
        dem = obs.metrics.counter("robust.demotion")
        assert dem["stencil:tuned->default"] == 1
        assert dem["stencil:default->oracle"] == 1
        assert obs.metrics.counter(
            "robust.served_degraded")["stencil:oracle"] == 1

    def test_raise_policy_structures_synthetic(self):
        def boom():
            raise faults.FaultInjected("engine.scan", 3)

        with robust.failure_policy("raise"):
            with pytest.raises(guard.GuardedExecutionError) as ei:
                guard.run("cumsum", [("tuned", boom), ("oracle", lambda: 0)])
        assert ei.value.site == "engine.scan"
        assert ei.value.op == "cumsum"
        assert "engine.scan" in str(ei.value)

    def test_raise_policy_reraises_organic_unchanged(self):
        def bad():
            raise ValueError("ops.stencil: some validation message")

        with robust.failure_policy("raise"):
            with pytest.raises(ValueError,
                               match="some validation message"):
                guard.run("stencil", [("tuned", bad), ("oracle", lambda: 0)])

    def test_exhausted_prefers_last_organic_error(self):
        def synth():
            raise faults.FaultInjected("engine.window", 0)

        def organic():
            raise RuntimeError("the real lowering bug")

        with robust.failure_policy("fallback"):
            with pytest.raises(RuntimeError, match="the real lowering bug"):
                guard.run("op", [("tuned", synth), ("oracle", organic)])
        assert obs.metrics.counter_total("robust.exhausted") == 1

    def test_exhausted_all_synthetic_is_structured(self):
        def synth():
            raise faults.FaultInjected("engine.window", 0)

        with robust.failure_policy("fallback"):
            with pytest.raises(guard.GuardedExecutionError) as ei:
                guard.run("op", [("tuned", synth), ("default", synth)])
        assert ei.value.site == "engine.window"
        assert [lvl for lvl, _ in ei.value.failures] == ["tuned", "default"]

    def test_numerics_guard_demotes_nonfinite(self):
        nan = jnp.full((4,), jnp.nan)
        fine = jnp.zeros((4,))
        with robust.failure_policy("fallback"), robust.checking_numerics():
            out = guard.run("op", [("tuned", lambda: nan),
                                   ("oracle", lambda: fine)])
        np.testing.assert_array_equal(np.asarray(out), np.zeros(4))
        assert obs.metrics.counter_total("robust.nonfinite") == 1

    def test_numerics_guard_off_by_default(self):
        nan = jnp.full((4,), jnp.nan)
        with robust.failure_policy("fallback"):
            out = guard.run("op", [("tuned", lambda: nan)])
        assert np.isnan(np.asarray(out)).all()

    def test_empty_levels_rejected(self):
        with pytest.raises(ValueError, match="no execution levels"):
            guard.run("op", [])


# ---------------------------------------------------------------------------
# Per-site chaos matrix over the real ops surfaces
# ---------------------------------------------------------------------------

# site → (engine thunk, oracle thunk). Keep in sync with faults.SITES:
# the registry-coverage test below fails when a site is added without a
# matrix entry (tuning/sidecar/serve sites have their own classes).
_X = (48, 128)
_ENGINE_MATRIX = {
    "engine.window": (
        lambda: ops.stencil(_x2d(_X), "2d5pt", impl="interpret"),
        lambda: ops.stencil(_x2d(_X), "2d5pt", impl="xla"),
    ),
    "engine.gpu.window": (
        lambda: ops.stencil(_x2d(_X), "2d5pt", impl="interpret",
                            backend="gpu"),
        lambda: ops.stencil(_x2d(_X), "2d5pt", impl="xla"),
    ),
    "engine.scan": (
        lambda: ops.cumsum(_x2d(_X), impl="interpret"),
        lambda: ops.cumsum(_x2d(_X), impl="xla"),
    ),
    "engine.gpu.scan": (
        lambda: ops.cumsum(_x2d(_X), impl="interpret", backend="gpu"),
        lambda: ops.cumsum(_x2d(_X), impl="xla"),
    ),
}


class TestChaosMatrix:
    def test_every_site_is_covered(self):
        covered = set(_ENGINE_MATRIX) | {
            "tuning.measure", "tuning.sidecar.load", "tuning.sidecar.save",
            "halo.exchange", "serve.step"}
        assert covered == set(faults.SITES)

    @pytest.mark.parametrize("site", sorted(_ENGINE_MATRIX))
    def test_raise_names_site(self, site):
        run, _ = _ENGINE_MATRIX[site]
        with robust.inject(f"{site}:1.0"), robust.failure_policy("raise"):
            with pytest.raises(guard.GuardedExecutionError) as ei:
                run()
        assert ei.value.site == site

    @pytest.mark.parametrize("site", sorted(_ENGINE_MATRIX))
    def test_fallback_serves_oracle_equal(self, site):
        run, oracle = _ENGINE_MATRIX[site]
        want = oracle()
        with robust.inject(f"{site}:1.0"), robust.failure_policy("fallback"):
            got = run()
            fired = faults.fired_counts()      # inject() restores on exit
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        assert obs.metrics.counter_total("robust.demotion") >= 1
        assert fired.get(site, 0) >= 1

    @pytest.mark.parametrize("site", sorted(_ENGINE_MATRIX))
    def test_off_means_off(self, site):
        run, oracle = _ENGINE_MATRIX[site]
        got = run()
        np.testing.assert_allclose(np.asarray(got), np.asarray(oracle()),
                                   rtol=1e-4, atol=1e-4)
        assert faults.fired_counts() == {}
        assert obs.metrics.counter_total("robust.demotion") == 0

    def test_halo_exchange_fallback_desharding(self):
        """halo.exchange down on a 1-device mesh: the guard deshards
        (boundary='zero' makes that exact) and the answer survives."""
        from repro.launch.mesh import make_domain_mesh
        mesh = make_domain_mesh((1,))
        x = _x2d(_X)
        want = ops.stencil(x, "2d5pt", impl="interpret")
        with robust.inject("halo.exchange:1.0"), \
                robust.failure_policy("fallback"):
            got = ops.stencil(x, "2d5pt", impl="interpret", mesh=mesh)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        assert obs.metrics.counter_total("robust.demotion") >= 1

    def test_halo_exchange_raise(self):
        from repro.launch.mesh import make_domain_mesh
        mesh = make_domain_mesh((1,))
        with robust.inject("halo.exchange:1.0"), \
                robust.failure_policy("raise"):
            with pytest.raises(guard.GuardedExecutionError) as ei:
                ops.stencil(_x2d(_X), "2d5pt", impl="interpret", mesh=mesh)
        assert ei.value.site == "halo.exchange"


class TestChaosAcceptanceSweep:
    """Every site armed at prob 1.0 under 'fallback': the whole surface
    stays reference-equal (fp32) on both engine backends — the PR-10
    acceptance gate. Engine levels fail fast at their dispatch checks
    (before any pallas lowering), so only the XLA oracle computes."""

    @pytest.mark.parametrize("backend", ["tpu", "gpu"])
    def test_table3_zoo_reference_equal(self, backend):
        x2, x3 = _x2d(), _x2d((10, 16, 128), seed=1)
        with robust.inject("all:1.0"), robust.failure_policy("fallback"):
            for name, sdef in sorted(BENCHMARKS.items()):
                x = x2 if sdef.ndim == 2 else x3
                got = ops.stencil(x, name, impl="interpret", backend=backend)
                want = ops.stencil(x, name, impl="xla")
                np.testing.assert_allclose(
                    np.asarray(got), np.asarray(want),
                    rtol=1e-4, atol=1e-4, err_msg=f"{name}/{backend}")
            fired = faults.fired_counts()      # inject() restores on exit
        assert obs.metrics.counter_total("robust.demotion") > 0
        # run_window_plan is the common dispatcher for both backends, so
        # with every site armed its check is always the first to fire
        assert fired.get("engine.window", 0) > 0

    @pytest.mark.parametrize("backend", ["tpu", "gpu"])
    def test_conv_pipeline_scans_reference_equal(self, backend):
        rng = np.random.default_rng(5)
        x = _x2d()
        xc = jnp.asarray(rng.standard_normal((2, 3, 24, 64))
                         .astype(np.float32))
        w = jnp.asarray(rng.standard_normal((4, 3, 3, 3)).astype(np.float32))
        a = jnp.asarray(rng.uniform(0.4, 0.9, (8, 256)).astype(np.float32))
        b = jnp.asarray(rng.standard_normal((8, 256)).astype(np.float32))
        with robust.inject("all:1.0"), robust.failure_policy("fallback"):
            np.testing.assert_allclose(
                np.asarray(ops.conv2d(xc, w, impl="interpret",
                                      backend=backend)),
                np.asarray(ops.conv2d(xc, w, impl="xla")),
                rtol=1e-4, atol=1e-4, err_msg="conv2d")
            np.testing.assert_allclose(
                np.asarray(ops.pipeline(x, ["2d5pt", "2d9pt"],
                                        impl="interpret", backend=backend)),
                np.asarray(ops.pipeline(x, ["2d5pt", "2d9pt"], impl="xla")),
                rtol=1e-4, atol=1e-4, err_msg="pipeline")
            for impl in ("engine", "engine_unchunked"):
                np.testing.assert_allclose(
                    np.asarray(ops.chunked_linear_recurrence(
                        a, b, chunk=64, impl=impl, backend=backend)),
                    np.asarray(ref.linear_recurrence(a, b)),
                    rtol=1e-4, atol=1e-4, err_msg=impl)
            np.testing.assert_allclose(
                np.asarray(ops.linear_recurrence(a, b, impl="interpret",
                                                 backend=backend)),
                np.asarray(ref.linear_recurrence(a, b)),
                rtol=1e-4, atol=1e-4, err_msg="linear_recurrence")
        assert obs.metrics.counter_total("robust.demotion") > 0


# ---------------------------------------------------------------------------
# Tuner hardening (§16.4)
# ---------------------------------------------------------------------------

class TestTunerHardening:
    def test_measure_us_rejects_nonfinite_output(self):
        with pytest.raises(guard.MeasurementError, match="non-finite"):
            tuning.measure_us(lambda: jnp.full((4,), jnp.nan), reps=1)

    def test_measure_candidate_retries_then_succeeds(self):
        calls = []

        def runner(cfg):
            calls.append(cfg)
            if len(calls) == 1:
                raise RuntimeError("transient")
            return tuning.Measurement(10.0, 0.0, 3)

        cfg = tuning.KernelConfig((8, 128))
        with robust.failure_policy("fallback"):
            us = tuning._measure_candidate(runner, cfg, backend="tpu",
                                           retries=2)
        assert float(us) == 10.0 and len(calls) == 2
        assert obs.metrics.counter_total("tuner.measure_retry") == 1

    def test_measure_candidate_quarantines_after_retries(self):
        def runner(cfg):
            raise RuntimeError("persistent")

        with robust.failure_policy("fallback"):
            out = tuning._measure_candidate(runner, tuning.KernelConfig((8, 128)),
                                            backend="tpu", retries=1)
        assert out is None
        assert obs.metrics.counter_total("tuner.quarantined") == 1
        assert obs.metrics.counter_total("tuner.measure_retry") == 2

    def test_measure_candidate_rejects_nonfinite_float(self):
        with robust.failure_policy("fallback"):
            out = tuning._measure_candidate(
                lambda cfg: float("nan"), tuning.KernelConfig((8, 128)),
                backend="tpu", retries=0)
        assert out is None
        assert obs.metrics.counter_total("tuner.measure_nonfinite") == 1

    def test_outlier_spread_remeasured(self):
        seen = []

        def runner(cfg):
            seen.append(1)
            if len(seen) == 1:       # IQR > half the median: noisy sample
                return tuning.Measurement(10.0, 9.0, 3)
            return tuning.Measurement(10.0, 0.1, 3)

        with robust.failure_policy("fallback"):
            us = tuning._measure_candidate(runner, tuning.KernelConfig((8, 128)),
                                           backend="tpu", retries=2)
        assert len(seen) == 2 and us.spread_us == 0.1
        assert obs.metrics.counter_total("tuner.measure_outlier") == 1

    def test_injected_measure_fault_raise_policy(self):
        with robust.inject("tuning.measure:1.0"), \
                robust.failure_policy("raise"):
            with pytest.raises(guard.GuardedExecutionError) as ei:
                tuning._measure_candidate(
                    lambda cfg: tuning.measure_us(lambda: jnp.zeros(4)),
                    tuning.KernelConfig((8, 128)), backend="tpu")
        assert ei.value.site == "tuning.measure"

    def test_all_quarantined_falls_back_to_model_ranking(self):
        from repro.core.plan import scan_plan
        plan = scan_plan(128)

        def runner(cfg):
            raise RuntimeError("measurement rig is down")

        with robust.failure_policy("fallback"):
            res = tuning.autotune(plan, (32, 256), runner=runner)
        assert res.source == "model_fallback"
        assert res.measured_us is None
        assert obs.metrics.counter_total("tuner.model_fallback") == 1
        # the model-ranked pick is cached, not persisted as a winner
        assert tuning.sidecar_entries() == {}

    def test_tuning_budget_skips_tail_not_head(self, monkeypatch):
        from repro.core.plan import scan_plan
        monkeypatch.setenv(tuning.TUNE_BUDGET_ENV, "1e-9")
        measured = []

        def runner(cfg):
            measured.append(cfg)
            return tuning.Measurement(5.0, 0.0, 3)

        with robust.failure_policy("fallback"):
            res = tuning.autotune(scan_plan(128), (32, 256), runner=runner)
        assert res.source == "measured"      # first candidate always measured
        assert len(measured) == 1
        assert obs.metrics.counter_total("tuner.budget_skipped") >= 1

    def test_sidecar_entry_crc_roundtrip_and_tamper(self):
        tuning.clear_sidecar()
        key = tuning._sidecar_key("sig-crc", (32, 256), 1, (), "auto", "tpu")
        tuning._SIDECAR[key] = (tuning.KernelConfig((16, 256)), 1.5, 42.0)
        entries = tuning.sidecar_entries()
        assert entries[key]["crc"] == tuning.entry_crc(entries[key])
        tuning.clear_sidecar()
        assert tuning.merge_sidecar_entries(entries) == 1
        tuning.clear_sidecar()
        tampered = json.loads(json.dumps(entries))
        tampered[key]["block"] = [8, 128]      # flip the winner, keep crc
        assert tuning.merge_sidecar_entries(tampered) == 0
        assert obs.metrics.counter_total("tuner.sidecar_corrupt_entry") == 1
        tuning.clear_sidecar()

    def test_corrupt_sidecar_file_quarantined(self, tmp_path):
        path = tmp_path / "tuning.json"
        path.write_text("{ this is not json")
        with robust.failure_policy("fallback"):
            assert tuning.load_sidecar(str(path)) == 0
        assert not path.exists()
        assert (tmp_path / "tuning.json.corrupt").exists()
        assert obs.metrics.counter_total("tuner.sidecar_quarantined") == 1

    def test_corrupt_sidecar_file_raise_policy(self, tmp_path):
        path = tmp_path / "tuning.json"
        path.write_text("[]")                  # parses, wrong shape
        with robust.failure_policy("raise"):
            with pytest.raises(guard.SidecarError,
                               match="tuning.sidecar.load"):
                tuning.load_sidecar(str(path))
        assert path.exists()                   # raise mode never renames

    def test_corrupt_entry_skipped_file_survives(self, tmp_path):
        tuning.clear_sidecar()
        key = tuning._sidecar_key("sig-ok", (32, 256), 1, (), "auto", "tpu")
        tuning._SIDECAR[key] = (tuning.KernelConfig((16, 256)), 1.0, 10.0)
        entries = tuning.sidecar_entries()
        bad = dict(entries)
        bad["garbage-key"] = {"block": 123,
                              "schema": tuning.ENGINE_SCHEMA_VERSION}
        path = tmp_path / "tuning.json"
        path.write_text(json.dumps({"version": 1, "entries": bad}))
        tuning.clear_sidecar()
        with robust.failure_policy("fallback"):
            assert tuning.load_sidecar(str(path)) == 1
        assert path.exists()                   # per-entry skip, no rename
        assert obs.metrics.counter_total("tuner.sidecar_corrupt_entry") == 1
        tuning.clear_sidecar()

    def test_sidecar_load_fault_quarantines(self, tmp_path):
        path = tmp_path / "tuning.json"
        path.write_text(json.dumps({"version": 1, "entries": {}}))
        with robust.inject("tuning.sidecar.load:1.0"), \
                robust.failure_policy("fallback"):
            assert tuning.load_sidecar(str(path)) == 0
        assert (tmp_path / "tuning.json.corrupt").exists()

    def test_sidecar_save_fault_both_policies(self, tmp_path):
        tuning.clear_sidecar()
        key = tuning._sidecar_key("sig-save", (32, 256), 1, (), "auto", "tpu")
        tuning._SIDECAR[key] = (tuning.KernelConfig((16, 256)), 1.0, 10.0)
        path = str(tmp_path / "tuning.json")
        with robust.inject("tuning.sidecar.save:1.0"):
            with robust.failure_policy("raise"):
                with pytest.raises(guard.SidecarError,
                                   match="tuning.sidecar.save"):
                    tuning.save_sidecar(path)
            with robust.failure_policy("fallback"):
                assert tuning.save_sidecar(path) is None
        assert obs.metrics.counter_total("tuner.sidecar_save_failed") == 1
        assert not os.path.exists(path)
        # faults gone: the very same store saves cleanly (data never lost)
        assert tuning.save_sidecar(path) == path
        assert len(json.load(open(path))["entries"]) == 1
        tuning.clear_sidecar()


# ---------------------------------------------------------------------------
# Serving hardening
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served_model():
    from repro.config import get_config
    from repro.models import build_model
    from repro.nn.spec import init_params

    cfg = get_config("gemma3_1b", smoke=True)
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    return cfg, model, params


def _mk_requests(cfg, n, max_new=4, seed=0):
    from repro.launch.serve import Request
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab, 4, dtype=np.int32), max_new)
            for i in range(n)]


class TestServeChaos:
    def test_step_fault_raise_policy(self, served_model):
        from repro.launch.serve import DecodeServer
        cfg, model, params = served_model
        srv = DecodeServer(model, params, slots=2, cache_len=32)
        with robust.inject("serve.step:1.0"), robust.failure_policy("raise"):
            with pytest.raises(guard.GuardedExecutionError) as ei:
                srv.run(_mk_requests(cfg, 1))
        assert ei.value.site == "serve.step"

    def test_poisoned_steps_shed_load_not_hang(self, served_model):
        """p=1.0: every request still comes back ``done`` with ``.error``
        set — the pre-hardening server looped forever here."""
        from repro.launch.serve import DecodeServer
        cfg, model, params = served_model
        srv = DecodeServer(model, params, slots=2, cache_len=32)
        with robust.inject("serve.step:1.0"), \
                robust.failure_policy("fallback"):
            done = srv.run(_mk_requests(cfg, 3))
        assert len(done) == 3
        assert all(r.done and r.error == "step_failure" for r in done)
        health = srv.health()
        assert health["step_failures"] > 0 and health["active_slots"] == 0
        assert obs.metrics.counter_total("serve.request_error") == 3

    def test_transient_faults_still_complete(self, served_model):
        from repro.launch.serve import DecodeServer
        cfg, model, params = served_model
        srv = DecodeServer(model, params, slots=2, cache_len=32)
        with robust.inject("serve.step:0.3:7"), \
                robust.failure_policy("fallback"):
            done = srv.run(_mk_requests(cfg, 4))
        assert len(done) == 4
        assert all(r.error is None and len(r.out) == 4 for r in done)
        assert srv.step_failures > 0          # faults really did fire

    def test_deadline_evicts(self, served_model):
        from repro.launch.serve import DecodeServer
        cfg, model, params = served_model
        srv = DecodeServer(model, params, slots=1, cache_len=32)
        [timed_out] = _mk_requests(cfg, 1)
        timed_out.deadline_s = 0.0
        [done] = srv.run([timed_out])
        assert done.done and done.error == "deadline"
        assert obs.metrics.counter_total("serve.deadline_exceeded") == 1

    def test_chaos_outputs_match_clean_run(self, served_model):
        """Greedy tokens are invariant under transient step faults: a
        failed step never advances slot state, so the retried step
        reproduces the clean trajectory exactly."""
        from repro.launch.serve import DecodeServer
        cfg, model, params = served_model
        clean = DecodeServer(model, params, slots=2, cache_len=32)
        want = {r.rid: r.out for r in clean.run(_mk_requests(cfg, 3))}
        chaotic = DecodeServer(model, params, slots=2, cache_len=32)
        with robust.inject("serve.step:0.3:7"), \
                robust.failure_policy("fallback"):
            done = chaotic.run(_mk_requests(cfg, 3))
        assert {r.rid: r.out for r in done} == want


# ---------------------------------------------------------------------------
# Zero overhead when off
# ---------------------------------------------------------------------------

class TestOffPathOverhead:
    def test_no_faults_no_robust_counters(self):
        out = ops.stencil(_x2d(), "2d5pt", impl="interpret")
        assert np.isfinite(np.asarray(out)).all()
        assert faults.fired_counts() == {}
        for name in ("robust.demotion", "robust.served_degraded",
                     "robust.exhausted", "robust.nonfinite"):
            assert obs.metrics.counter_total(name) == 0

    def test_guard_run_overhead_bounded(self):
        """The guard's happy path is one try around the primary thunk —
        bound it loosely (50 µs/call) against real regressions (config
        import per call, policy read before success, level prebuild)."""
        levels = [("tuned", lambda: 1)]
        n = 20_000
        guard.run("warm", levels)
        t0 = time.perf_counter()
        for _ in range(n):
            guard.run("hot", levels)
        per_call = (time.perf_counter() - t0) / n
        assert per_call < 50e-6, f"{per_call * 1e6:.2f} µs per guarded call"
