"""Checkpointing: roundtrip, atomicity, async, elastic mesh reshard."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpointing.checkpoint import latest_step

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.int32(7)}}


class TestBasics:
    def test_roundtrip(self, tmp_path):
        t = tree()
        save_checkpoint(str(tmp_path), 3, t)
        t2 = load_checkpoint(str(tmp_path), 3, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomicity_no_commit_ignored(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, tree())
        save_checkpoint(str(tmp_path), 2, tree())
        os.remove(tmp_path / "step_00000002" / "COMMIT")   # simulated crash
        assert latest_step(str(tmp_path)) == 1

    def test_manager_async_and_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, tree())
        mgr.wait()
        steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
        assert steps == ["step_00000003", "step_00000004"]

    def test_restore_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        t = tree()
        mgr.save(9, t)
        mgr.wait()
        step, t2 = mgr.restore_latest(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
        assert step == 9
        np.testing.assert_array_equal(np.asarray(t["a"]), np.asarray(t2["a"]))

    def test_shape_mismatch_rejected(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, tree())
        bad = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree())
        bad["a"] = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        with pytest.raises(ValueError):
            load_checkpoint(str(tmp_path), 1, bad)


class TestTuningSidecarShipsWithCheckpoint:
    """Tuned kernel winners ride the checkpoint (TUNING.json) so a host
    move does not silently retune — or worse, replay stale defaults."""

    def setup_method(self):
        from repro.core import tuning
        tuning.clear_sidecar()

    teardown_method = setup_method

    @staticmethod
    def _entry(block=(8, 64), strategy="mxu", backend="tpu"):
        from repro.core import tuning
        cfg = tuning.KernelConfig(tuple(block), "shift_psum", strategy)
        key = tuning._sidecar_key("sig-ship", (128, 128), 1, (), "mxu",
                                  backend)
        return key, cfg

    def test_save_embeds_and_restore_merges(self, tmp_path):
        from repro.core import tuning
        key, cfg = self._entry()
        tuning._SIDECAR[key] = (cfg, 1.5, 42.0)
        save_checkpoint(str(tmp_path), 2, tree())
        tpath = tmp_path / "step_00000002" / "TUNING.json"
        assert tpath.exists()
        doc = json.loads(tpath.read_text())
        assert doc["entries"][key]["strategy"] == "mxu"
        assert doc["entries"][key]["schema"] == tuning.ENGINE_SCHEMA_VERSION

        tuning.clear_sidecar()              # simulated fresh host
        t = tree()
        load_checkpoint(str(tmp_path), 2, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
        assert tuning._SIDECAR[key][0] == cfg

    def test_restore_never_clobbers_local_winner(self, tmp_path):
        from repro.core import tuning
        key, shipped = self._entry(block=(8, 64))
        tuning._SIDECAR[key] = (shipped, 1.5, 42.0)
        save_checkpoint(str(tmp_path), 3, tree())

        tuning.clear_sidecar()
        _, local = self._entry(block=(16, 128))   # re-measured on this host
        tuning._SIDECAR[key] = (local, 0.5, 7.0)
        load_checkpoint(str(tmp_path), 3, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree()))
        assert tuning._SIDECAR[key][0] == local   # shipped entry lost

    def test_backend_keyed_entries_round_trip(self, tmp_path):
        """v6: per-backend winners for the *same* plan/shape ride the
        checkpoint as distinct entries and restore to distinct keys —
        a host move never collapses the GPU and TPU winners."""
        from repro.core import tuning
        tkey, tcfg = self._entry(block=(8, 128), backend="tpu")
        gkey, gcfg = self._entry(block=(4, 64), backend="gpu")
        assert tkey != gkey
        tuning._SIDECAR[tkey] = (tcfg, 1.5, 42.0)
        tuning._SIDECAR[gkey] = (gcfg, 2.5, 17.0)
        save_checkpoint(str(tmp_path), 4, tree())
        doc = json.loads(
            (tmp_path / "step_00000004" / "TUNING.json").read_text())
        assert json.loads(tkey)[-1] == "tpu"
        assert json.loads(gkey)[-1] == "gpu"
        assert set(doc["entries"]) == {tkey, gkey}

        tuning.clear_sidecar()
        load_checkpoint(str(tmp_path), 4, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree()))
        assert tuning._SIDECAR[tkey][0] == tcfg
        assert tuning._SIDECAR[gkey][0] == gcfg

    def test_empty_sidecar_writes_no_file(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, tree())
        assert not (tmp_path / "step_00000001" / "TUNING.json").exists()
        # and restoring a checkpoint without TUNING.json is fine
        load_checkpoint(str(tmp_path), 1, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree()))


class TestElastic:
    def test_reshard_8_to_4_devices(self, tmp_path):
        """Save under an 8-device (4,2) mesh, restore under 4-device (2,2):
        elastic scaling across device counts."""
        d = str(tmp_path)
        save_code = textwrap.dedent(f"""
            import jax, jax.numpy as jnp
            from repro.checkpointing import save_checkpoint
            from repro.distributed.sharding import shardings_for_specs
            from repro.nn.spec import ParamSpec, init_params
            specs = {{"w": ParamSpec((16, 8), ("ff", "embed"))}}
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            sh = shardings_for_specs(specs, mesh)
            t = jax.device_put(init_params(specs, jax.random.PRNGKey(0)), sh)
            save_checkpoint({d!r}, 5, t)
            print("saved")
        """)
        restore_code = textwrap.dedent(f"""
            import jax, jax.numpy as jnp, numpy as np
            from repro.checkpointing import load_checkpoint
            from repro.distributed.sharding import shardings_for_specs
            from repro.nn.spec import ParamSpec, init_params, abstract_params
            specs = {{"w": ParamSpec((16, 8), ("ff", "embed"))}}
            mesh = jax.make_mesh((2, 2), ("data", "model"))
            sh = shardings_for_specs(specs, mesh)
            t = load_checkpoint({d!r}, 5, abstract_params(specs), shardings=sh)
            ref = init_params(specs, jax.random.PRNGKey(0))
            np.testing.assert_allclose(np.asarray(t["w"]), np.asarray(ref["w"]))
            assert len(t["w"].sharding.device_set) == 4
            print("restored")
        """)
        for code, n, expect in ((save_code, 8, "saved"),
                                (restore_code, 4, "restored")):
            env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu",
                       XLA_FLAGS=f"--xla_force_host_platform_device_count={n}")
            out = subprocess.run([sys.executable, "-c", code],
                                 capture_output=True, text=True, env=env,
                                 timeout=300)
            assert out.returncode == 0, out.stderr[-3000:]
            assert expect in out.stdout
