"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches see
the real single CPU device; only dryrun.py forces 512 host devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _strict_guard_policy():
    """Pin the degradation policy to 'raise' for every test.

    The production default is 'fallback' — under it an engine bug would
    silently demote to the XLA oracle and every engine-vs-reference
    equivalence test would vacuously pass. Chaos tests opt back into
    fallback explicitly via ``robust.failure_policy('fallback')``.
    Also guarantees no armed fault site leaks across tests.
    """
    from repro import config
    from repro.robust import faults

    prev = config._ON_FAILURE
    config.set_on_failure("raise")
    yield
    config._ON_FAILURE = prev
    faults.disarm()
