"""Chunk-streamed engine scans (DESIGN.md §12).

Acceptance tests of the O(chunk)-memory streaming schedule: long-T
equivalence of the streamed engine vs the monolithic engine vs the jnp
oracle for all three recurrence ops, chunk-boundary gradcheck against
reference AD (ragged tail included), the peak-temp-memory assertion
(XLA cost analysis: streamed ≪ monolithic, near-flat in T), the named
chunk-geometry errors, and the tuner's grown ``(BR, BT, chunk)``
candidate dimension.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adjoint as adjoint_mod
from repro.core import engine, tuning
from repro.core.plan import linear_recurrence_plan, normalize_epilogue
from repro.kernels import ops, ref
from repro.nn import ssm

import dataclasses


def assert_close(a, b, tol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=tol, atol=tol)


def _linrec_oracle(a, b):
    """Gold sequential h_t = a_t h_{t-1} + b_t over the last axis."""
    def step(h, ab):
        h = ab[0] * h + ab[1]
        return h, h
    _, hs = jax.lax.scan(step, jnp.zeros(a.shape[:-1], a.dtype),
                         (jnp.moveaxis(a, -1, 0), jnp.moveaxis(b, -1, 0)))
    return jnp.moveaxis(hs, 0, -1)


def _temp_bytes(fn, *args) -> int:
    ma = jax.jit(fn).lower(*args).compile().memory_analysis()
    return int(getattr(ma, "temp_size_in_bytes", -1))


class TestLongTEquivalence:
    def test_linrec_64x_chunk(self, rng):
        """T = 64 × chunk through the streamed engine: equal to the
        monolithic engine and the sequential oracle."""
        chunk = 8
        T = 64 * chunk
        a = jnp.array(rng.uniform(0.7, 1.0, (2, T)), jnp.float32)
        b = jnp.array(rng.standard_normal((2, T)), jnp.float32)
        got = ops.chunked_linear_recurrence(a, b, chunk=chunk, impl="engine")
        mono = ops.chunked_linear_recurrence(a, b, chunk=chunk,
                                             impl="engine_unchunked")
        assert_close(got, mono, 1e-4)
        assert_close(got, _linrec_oracle(a, b), 1e-4)

    def test_linrec_ragged_tail(self, rng):
        """T not a multiple of chunk: the tail chunk pads with identity
        transfers (a=1, b=0) and the crop removes them."""
        a = jnp.array(rng.uniform(0.7, 1.0, (3, 70)), jnp.float32)
        b = jnp.array(rng.standard_normal((3, 70)), jnp.float32)
        got = ops.chunked_linear_recurrence(a, b, chunk=16, impl="engine")
        assert_close(got, _linrec_oracle(a, b), 1e-4)

    @pytest.mark.slow
    @pytest.mark.parametrize("op", ["linrec", "mamba", "rwkv"])
    def test_long_t_matrix(self, rng, op):
        """Full 64×-chunk matrix over all three recurrence ops:
        streamed engine vs monolithic engine vs the jnp/chunked oracle."""
        if op == "linrec":
            chunk = 16
            T = 64 * chunk
            a = jnp.array(rng.uniform(0.8, 1.0, (4, T)), jnp.float32)
            b = jnp.array(rng.standard_normal((4, T)), jnp.float32)
            got = ops.chunked_linear_recurrence(a, b, chunk=chunk,
                                                impl="engine")
            mono = ops.chunked_linear_recurrence(a, b, chunk=chunk,
                                                 impl="engine_unchunked")
            assert_close(got, mono, 1e-4)
            assert_close(got, _linrec_oracle(a, b), 1e-4)
        elif op == "mamba":
            chunk = 16
            B, T, Di, N = 1, 64 * chunk, 2, 4
            delta = jnp.array(rng.uniform(0.1, 0.4, (B, T, Di)), jnp.float32)
            A_log = jnp.array(-rng.uniform(0.5, 1.5, (Di, N)), jnp.float32)
            Bm = jnp.array(rng.standard_normal((B, T, N)), jnp.float32)
            Cm = jnp.array(rng.standard_normal((B, T, N)), jnp.float32)
            x = jnp.array(rng.standard_normal((B, T, Di)), jnp.float32)
            y1, h1 = ssm.selective_scan(delta, A_log, Bm, Cm, x,
                                        chunk=chunk, impl="engine")
            y2, h2 = ssm.selective_scan(delta, A_log, Bm, Cm, x,
                                        chunk=chunk, impl="chunked")
            y3, h3 = ssm.selective_scan(delta, A_log, Bm, Cm, x,
                                        impl="engine_unchunked")
            assert_close(y1, y2, 2e-4)
            assert_close(h1, h2, 2e-4)
            assert_close(y1, y3, 2e-4)
        else:
            chunk = 16
            B, T, H, K, V = 1, 64 * chunk, 1, 3, 3
            r = jnp.array(rng.standard_normal((B, T, H, K)), jnp.float32)
            k = jnp.array(rng.standard_normal((B, T, H, K)), jnp.float32)
            v = jnp.array(rng.standard_normal((B, T, H, V)), jnp.float32)
            logw = jnp.array(-rng.uniform(0.05, 0.5, (B, T, H, K)),
                             jnp.float32)
            u = jnp.array(rng.standard_normal((H, K)), jnp.float32)
            y1, S1 = ssm.wkv6_chunked(r, k, v, logw, u, chunk=chunk,
                                      impl="engine")
            y2, S2 = ssm.wkv6_chunked(r, k, v, logw, u, chunk=chunk,
                                      impl="chunked")
            y3, S3 = ssm.wkv6_sequential(r, k, v, logw, u)
            assert_close(y1, y2, 2e-4)
            assert_close(S1, S2, 2e-4)
            assert_close(y1, y3, 2e-4)


class TestChunkBoundaryGrads:
    def test_linrec_gradcheck_ragged(self, rng):
        """Checkpointed per-chunk backward (boundary carries saved,
        in-chunk states recomputed): grads match reference AD across
        chunk boundaries and through the ragged tail."""
        a = jnp.array(rng.uniform(0.7, 1.0, (3, 70)), jnp.float32)
        b = jnp.array(rng.standard_normal((3, 70)), jnp.float32)
        before = adjoint_mod.BACKWARD_LOWERINGS.get("adj_recurrence_chunk", 0)
        ga, gb = jax.grad(lambda u, v: jnp.sum(ops.chunked_linear_recurrence(
            u, v, chunk=16, impl="engine") ** 2), (0, 1))(a, b)
        ra, rb = jax.grad(lambda u, v: jnp.sum(
            _linrec_oracle(u, v) ** 2), (0, 1))(a, b)
        assert_close(ga, ra, 1e-3)
        assert_close(gb, rb, 1e-3)
        # the λ-recurrence of the chunk VJP lowered through the engine
        # (traced once inside the lax.scan body)
        assert adjoint_mod.BACKWARD_LOWERINGS["adj_recurrence_chunk"] > before

    def test_selective_scan_stream_grads(self, rng):
        B, T, Di, N = 1, 70, 2, 4
        delta = jnp.array(rng.uniform(0.1, 0.4, (B, T, Di)), jnp.float32)
        A_log = jnp.array(-rng.uniform(0.5, 1.5, (Di, N)), jnp.float32)
        Bm = jnp.array(rng.standard_normal((B, T, N)), jnp.float32)
        Cm = jnp.array(rng.standard_normal((B, T, N)), jnp.float32)
        x = jnp.array(rng.standard_normal((B, T, Di)), jnp.float32)

        def loss(impl, d, xx):
            y, _ = ssm.selective_scan(d, A_log, Bm, Cm, xx, chunk=16,
                                      impl=impl)
            return jnp.sum(y ** 2)

        ge = jax.grad(lambda *s: loss("engine", *s), (0, 1))(delta, x)
        gr = jax.grad(lambda *s: loss("chunked", *s), (0, 1))(delta, x)
        for e, r in zip(ge, gr):
            assert_close(e, r, 1e-3)


class TestPeakMemory:
    def test_streamed_temp_memory_is_o_chunk(self, rng):
        """XLA cost analysis of the compiled grad step: the streamed
        schedule's peak temp allocation is well below the monolithic
        engine's O(T) saved state, and near-flat as T grows."""
        chunk, R = 64, 4

        def temp_at(T, impl):
            a = jnp.array(rng.uniform(0.8, 1.0, (R, T)), jnp.float32)
            b = jnp.array(rng.standard_normal((R, T)), jnp.float32)
            g = jax.grad(lambda u, v: jnp.sum(ops.chunked_linear_recurrence(
                u, v, chunk=chunk, impl=impl) ** 2), (0, 1))
            return _temp_bytes(g, a, b)

        t_stream = temp_at(16 * chunk, "engine")
        t_mono = temp_at(16 * chunk, "engine_unchunked")
        assert t_stream < 0.7 * t_mono, (t_stream, t_mono)
        # O(R·chunk) live state: quadrupling T must grow the streamed
        # temp footprint clearly sublinearly (the residual growth is the
        # O(T/chunk) boundary-carry stack + O(T) cotangent staging, not
        # saved scan state), and the gap to the monolithic engine widens
        t_stream4 = temp_at(64 * chunk, "engine")
        t_mono4 = temp_at(64 * chunk, "engine_unchunked")
        assert t_stream4 < 3 * t_stream, (t_stream, t_stream4)
        assert t_stream4 < 0.5 * t_mono4, (t_stream4, t_mono4)

    def test_selective_scan_stream_memory(self, rng):
        B, T, Di, N = 1, 512, 4, 16
        delta = jnp.array(rng.uniform(0.1, 0.4, (B, T, Di)), jnp.float32)
        A_log = jnp.array(-rng.uniform(0.5, 1.5, (Di, N)), jnp.float32)
        Bm = jnp.array(rng.standard_normal((B, T, N)), jnp.float32)
        Cm = jnp.array(rng.standard_normal((B, T, N)), jnp.float32)
        x = jnp.array(rng.standard_normal((B, T, Di)), jnp.float32)

        def g(impl):
            return jax.grad(lambda d, xx: jnp.sum(ssm.selective_scan(
                d, A_log, Bm, Cm, xx, chunk=64, impl=impl)[0] ** 2), (0, 1))

        t_stream = _temp_bytes(g("engine"), delta, x)
        t_mono = _temp_bytes(g("engine_unchunked"), delta, x)
        assert t_stream < 0.7 * t_mono, (t_stream, t_mono)


class TestChunkGeometryErrors:
    def test_epilogue_illegal_under_chunking(self):
        plan = dataclasses.replace(linear_recurrence_plan(16),
                                   epilogue=normalize_epilogue("relu"))
        with pytest.raises(ValueError, match="epilogue stages are illegal"):
            engine.check_chunk_geometry(plan, 32)

    def test_chunk_below_lane_tile(self):
        with pytest.raises(ValueError, match="smaller than the lane tile"):
            engine.check_chunk_geometry(linear_recurrence_plan(64), 32)

    def test_chunk_not_multiple_of_lane_tile(self):
        with pytest.raises(ValueError, match="not a multiple"):
            engine.check_chunk_geometry(linear_recurrence_plan(16), 24)

    def test_ops_surface_raises_pre_pallas(self, rng):
        a = jnp.ones((2, 64), jnp.float32)
        with pytest.raises(ValueError, match="not a multiple"):
            ops.chunked_linear_recurrence(a, a, chunk=12, impl="engine")


class TestTunerChunkDimension:
    def test_schema_bump_and_chunked_candidates(self):
        # v4 added the chunk dimension; v5 (strategy) must not drop it.
        assert tuning.ENGINE_SCHEMA_VERSION >= 4
        plan = linear_recurrence_plan(128)
        cands = tuning.candidate_configs(plan, (64, 4096), chunked=True)
        three = [c for c in cands if len(c.block) == 3]
        assert three, "chunked=True must grow a chunk dimension"
        for cfg in three:
            br, bt, chunk = cfg.block
            # every emitted candidate passes the geometry guard
            assert chunk >= bt and chunk % bt == 0, cfg.block
            kw = cfg.as_kwargs(plan)
            assert kw["chunk"] == chunk
            assert kw["block_r"] == br and kw["block_t"] == bt

    def test_unchunked_candidates_unchanged(self):
        plan = linear_recurrence_plan(128)
        for cfg in tuning.candidate_configs(plan, (64, 4096)):
            assert len(cfg.block) == 2
            assert "chunk" not in cfg.as_kwargs(plan)

    def test_model_cost_charges_inter_chunk_carry(self):
        """§5: the streamed schedule adds an inter-chunk carry
        round-trip amortized by the chunk length — longer chunks cost
        less carry overhead per element."""
        plan = linear_recurrence_plan(128)
        c_small = tuning.model_cost(plan, tuning.KernelConfig((8, 128, 128)))
        c_large = tuning.model_cost(plan, tuning.KernelConfig((8, 128, 512)))
        c_mono = tuning.model_cost(plan, tuning.KernelConfig((8, 128)))
        assert c_mono < c_large < c_small

    def test_autotune_streamed_context(self, rng):
        """autotune=True through the streamed surface measures 3-tuple
        candidates and records a sidecar entry."""
        tuning.clear_cache()
        a = jnp.array(rng.uniform(0.8, 1.0, (8, 256)), jnp.float32)
        b = jnp.array(rng.standard_normal((8, 256)), jnp.float32)
        out = ops.chunked_linear_recurrence(a, b, chunk=64, impl="engine",
                                            autotune=True)
        assert_close(out, _linrec_oracle(a, b), 1e-4)
        assert any("linrec_stream" in str(k) for k in tuning._CACHE)
