"""Distribution substrate: sharding rules, multi-device invariance,
gradient compression. Multi-device cases run in subprocesses with
``--xla_force_host_platform_device_count`` so the main test process keeps
its single real device.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback examples
    from _hypothesis_compat import given, settings, strategies as st

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n}",
               PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


class TestShardingRules:
    def test_divisibility_fallback(self):
        """A 4-head model on a 16-way model axis must not shard heads."""
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import pspec_for_axes
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # fake 16-wide model axis via explicit sizes: use a real query
        spec = pspec_for_axes(("embed", "heads", "head_dim"), (64, 4, 16), mesh)
        assert spec == P(None, "model") or spec == P()  # 4 % 1 == 0 here

    @given(dim=st.integers(1, 64))
    @settings(max_examples=20, deadline=None)
    def test_never_produces_indivisible_spec(self, dim):
        from repro.distributed.sharding import pspec_for_axes
        mesh = jax.make_mesh((1,), ("model",))
        spec = pspec_for_axes(("ff",), (dim,), mesh)
        for entry, size in zip(spec, (dim,)):
            if entry is not None:
                assert size % 1 == 0

    def test_no_mesh_axis_reuse(self):
        from repro.distributed.sharding import pspec_for_axes
        mesh = jax.make_mesh((1,), ("model",))
        # both dims want "model": only the first may take it
        spec = pspec_for_axes(("vocab", "ff"), (128, 128), mesh)
        entries = [e for e in spec if e is not None]
        assert len(entries) == len(set(entries))


MULTIDEV = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np, json
    from repro.config import get_config
    from repro.models import build_model
    from repro.nn.spec import init_params
    from repro.distributed.sharding import (mesh_context, shardings_for_specs,
                                            pspec_for_axes)
    from jax.sharding import NamedSharding
    cfg = get_config("gemma3_1b", smoke=True)
    model = build_model(cfg)
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    batch = dict(tokens=jax.random.randint(k1, (8, 32), 0, cfg.vocab),
                 labels=jax.random.randint(k2, (8, 32), 0, cfg.vocab))
    # single-device loss
    l0 = float(jax.jit(model.loss)(params, batch))
    # sharded loss on (4 data, 2 model)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with mesh, mesh_context(mesh):
        psh = shardings_for_specs(model.specs(), mesh)
        p = jax.device_put(params, psh)
        bsh = {k: NamedSharding(mesh, pspec_for_axes(("batch", "seq"),
               v.shape, mesh)) for k, v in batch.items()}
        b = jax.device_put(batch, bsh)
        l1 = float(jax.jit(model.loss, in_shardings=(psh, bsh))(p, b))
    print(json.dumps({"l0": l0, "l1": l1}))
""")


class TestMultiDevice:
    def test_sharded_loss_matches_single_device(self):
        """Core SPMD invariance: same loss on 1 device and a 4×2 mesh."""
        out = run_with_devices(MULTIDEV)
        vals = json.loads(out.strip().splitlines()[-1])
        assert abs(vals["l0"] - vals["l1"]) < 2e-3, vals

    def test_grad_compression_int8_ef_converges(self):
        """int8+error-feedback psum still optimizes (quadratic to ~0)."""
        code = textwrap.dedent("""
            import jax, jax.numpy as jnp, numpy as np
            from functools import partial
            from repro.distributed.compress import psum_int8_ef
            import jax.experimental.shard_map as shm
            from jax.sharding import PartitionSpec as P
            mesh = jax.make_mesh((8,), ("data",))
            target = jnp.arange(8.0)

            @partial(shm.shard_map, mesh=mesh, in_specs=(P(), P("data"), P()),
                     out_specs=(P(), P()), check_rep=False)
            def step(w, x, err):
                # per-shard gradient of 0.5*(w - target_mean_over_shard)^2
                g = (w - x.mean()) / 1.0
                g, err = psum_int8_ef(g, err, "data")
                return g, err

            w = jnp.zeros(())
            err = jnp.zeros(())
            for i in range(300):
                g, err = step(w, target, err)
                w = w - 0.1 * g
            resid = abs(float(w) - float(target.mean()))
            assert resid < 1e-2, resid
            print("ok", resid)
        """)
        out = run_with_devices(code)
        assert "ok" in out

    def test_bf16_psum(self):
        code = textwrap.dedent("""
            import jax, jax.numpy as jnp
            from functools import partial
            from repro.distributed.compress import psum_bf16
            import jax.experimental.shard_map as shm
            from jax.sharding import PartitionSpec as P
            mesh = jax.make_mesh((8,), ("data",))

            @partial(shm.shard_map, mesh=mesh, in_specs=P("data"),
                     out_specs=P(), check_rep=False)
            def total(x):
                return psum_bf16(x.sum(), "data")

            x = jnp.arange(64.0)
            got = float(total(x))
            assert abs(got - 2016.0) / 2016.0 < 1e-2, got
            print("ok")
        """)
        out = run_with_devices(code)
        assert "ok" in out

    def test_dryrun_single_cell_256dev(self):
        """End-to-end mini version of the assignment's dry-run gate."""
        code = textwrap.dedent("""
            from repro.launch.dryrun import run_cell
            rec = run_cell("whisper_base", "decode_32k", multi_pod=False,
                           out_dir="", verbose=False)
            assert rec["status"] == "ok", rec
            assert rec["collectives"]["total_bytes"] >= 0
            print("ok", rec["cost"]["flops"])
        """)
        out = run_with_devices(code, n=512)
        assert "ok" in out
