"""Sharded-vs-single-device equivalence for the halo-exchange layer.

Every case runs in a subprocess with a forced 8-device CPU host
(``--xla_force_host_platform_device_count=8``, same pattern as
``test_distributed.py``) and asserts that ``ops.stencil`` /
``ops.conv2d`` under a mesh reproduce the single-device engine output —
the full Table-3 suite, ``time_steps ∈ {1, 2, 3}``, both schedule
variants — plus the boundary modes, the pre-``pallas_call``
``ValueError`` paths, and the autotuner's JSON-sidecar persistence
(a warm sidecar must make a cold process measure **nothing**).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.sharded

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8, extra_env: dict | None = None) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n}",
               PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    env.pop("REPRO_TUNING_CACHE", None)
    env.update(extra_env or {})
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


PRELUDE = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.kernels import ops, ref
    from repro.kernels.stencils import BENCHMARKS
    from repro.launch.mesh import make_domain_mesh

    rng = np.random.default_rng(0)
    assert jax.device_count() == 8, jax.device_count()
    mesh2d = make_domain_mesh((2, 4))   # rows over 'data', lanes over 'model'

    def check(name, got, want, tol=1e-5):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol, err_msg=name)
        print("ok", name)
""")

# Shard sizes must cover the widest Table-3 halo (2ds25pt: radius 6,
# t=3 → 18 rows per side), hence 64×288 on the 2×4 mesh.
X2D = "x = jnp.array(rng.standard_normal((64, 288)), jnp.float32)"
X3D = "x = jnp.array(rng.standard_normal((8, 24, 128)), jnp.float32)"


def _suite_code(ndim: int, steps: tuple[int, ...]) -> str:
    return PRELUDE + textwrap.dedent(f"""
        {X2D if ndim == 2 else X3D}
        names = [n for n, d in BENCHMARKS.items() if d.ndim == {ndim}]
        for name in names:
            for t in {steps!r}:
                want = ops.stencil(x, name, time_steps=t, impl="interpret")
                for variant in ("shift_psum", "shift_data"):
                    got = ops.stencil(x, name, time_steps=t, impl="interpret",
                                      variant=variant, mesh=mesh2d)
                    check(f"{{name}} t{{t}} {{variant}}", got, want)
        print("DONE")
    """)


@pytest.mark.parametrize("ndim,steps", [(2, (1,)), (2, (2,)), (2, (3,)),
                                        (3, (1, 2, 3))])
def test_table3_sharded_matches_single_device(ndim, steps):
    """Full Table-3 suite: sharded == single-device engine, both variants."""
    out = run_with_devices(_suite_code(ndim, steps))
    assert "DONE" in out


def test_conv2d_and_meshes():
    """conv2d 'same' + 1-D mesh + explicit in_specs + overlap=False paths."""
    code = PRELUDE + textwrap.dedent("""
        x = jnp.array(rng.standard_normal((64, 288)), jnp.float32)
        mesh1d = make_domain_mesh((8,))
        for fs in ((3, 3), (3, 5), (5, 5)):
            w = jnp.array(rng.standard_normal(fs), jnp.float32)
            want = ops.conv2d(x, w, impl="interpret")
            check(f"conv2d {fs} rows-mesh",
                  ops.conv2d(x, w, impl="interpret", mesh=mesh1d), want)
            check(f"conv2d {fs} 2d-mesh",
                  ops.conv2d(x, w, impl="interpret", mesh=mesh2d), want)
        w = jnp.array(rng.standard_normal((5, 5)), jnp.float32)
        want = ops.conv2d(x, w, impl="interpret")
        got = ops.conv2d(x, w, impl="interpret", mesh=mesh2d,
                         in_specs=P(None, "model"))   # lane-axis only
        check("conv2d lane-axis spec", got, want)
        got = ops.stencil(x, "2d9pt", time_steps=2, impl="interpret",
                          mesh=mesh2d, overlap=False)
        check("monolithic (overlap=False)",
              got, ops.stencil(x, "2d9pt", time_steps=2, impl="interpret"))
        print("DONE")
    """)
    assert "DONE" in run_with_devices(code)


def test_sharded_batch_axis():
    """Batched plans shard the batch axis (no halo exchange — items are
    independent) and compose with spatial sharding; sharded == single
    device for (B, H, W) stacks and NCHW minibatches, and a sharded
    reduce axis is a clear pre-pallas ValueError."""
    code = PRELUDE + textwrap.dedent("""
        mesh1d = make_domain_mesh((8,))

        # (B, H, W) stack: batch over 'data', lanes over 'model'
        xb = jnp.array(rng.standard_normal((8, 32, 288)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 5)), jnp.float32)
        want = ops.conv2d(xb, w, impl="interpret")
        check("batched conv2d 2d-mesh",
              ops.conv2d(xb, w, impl="interpret", mesh=mesh2d), want)
        check("batched conv2d batch-only mesh",
              ops.conv2d(xb, w, impl="interpret", mesh=mesh1d,
                         in_specs=P("data", None, None)), want)
        check("batched conv2d rows+batch",
              ops.conv2d(xb, w, impl="interpret", mesh=mesh2d,
                         in_specs=P("data", "model", None)), want)

        # NCHW minibatch: default spec = batch over 'data', lanes 'model'
        xn = jnp.array(rng.standard_normal((4, 3, 24, 96)), jnp.float32)
        wn = jnp.array(rng.standard_normal((5, 3, 3, 3)), jnp.float32)
        want = ops.conv2d(xn, wn, impl="interpret")
        check("nchw conv2d 2d-mesh",
              ops.conv2d(xn, wn, impl="interpret", mesh=mesh2d), want)
        check("nchw conv2d rows sharded",
              ops.conv2d(xn, wn, impl="interpret", mesh=mesh2d,
                         in_specs=P("data", None, "model", None)), want)
        check("nchw conv2d autotuned",
              ops.conv2d(xn, wn, impl="interpret", mesh=mesh2d,
                         autotune=True), want)

        # sharding the channel-reduction axis is refused pre-pallas
        try:
            ops.conv2d(xn, wn, impl="interpret", mesh=mesh2d,
                       in_specs=P(None, "data", None, None))
        except ValueError as e:
            assert "reduce axis" in str(e), e
            print("ok reduce-axis refusal")
        else:
            raise AssertionError("sharded reduce axis did not raise")

        # depthwise conv1d batched plan: batch over 'data'
        xd = jnp.array(rng.standard_normal((8, 24, 16)), jnp.float32)
        wd = jnp.array(rng.standard_normal((4, 16)), jnp.float32)
        from repro.distributed import halo_exchange as hx
        from repro.kernels import ssam_conv1d
        got = hx.sharded_window_plan(
            xd, wd, plan=ssam_conv1d.plan_for(4), mesh=mesh1d,
            in_spec=P("data", None, None), block=(128, 128))
        check("depthwise conv1d sharded batch", got,
              ref.conv1d_causal(xd, wd), 1e-4)
        print("DONE")
    """)
    assert "DONE" in run_with_devices(code)


def test_sharded_fused_pipeline():
    """A fused chain under a mesh: the composite plan ships ONE
    chain-widened halo per call (summed stage footprints through
    core.halo, same as temporal blocking), and fused epilogues apply
    per shard — sharded fused == single-device fused == unfused."""
    code = PRELUDE + textwrap.dedent("""
        x = jnp.array(rng.standard_normal((64, 288)), jnp.float32)
        chain = ["2d5pt", ("2d9pt", "gelu"), "2d5pt"]
        want = ops.pipeline(x, chain, impl="interpret", fuse=True)
        got = ops.pipeline(x, chain, impl="interpret", fuse=True,
                           mesh=mesh2d)
        check("fused chain 2d-mesh", got, want)
        # epilogue with a replicated bias operand on a sharded stencil
        b = jnp.float32(0.3)
        want = ops.stencil(x, "2d9pt", impl="interpret",
                           epilogue=("bias", "gelu"), epilogue_args=(b,))
        got = ops.stencil(x, "2d9pt", impl="interpret", mesh=mesh2d,
                          epilogue=("bias", "gelu"), epilogue_args=(b,))
        check("sharded epilogue bias", got, want)
        # unfused fallback cannot shard: named pre-pallas error
        try:
            ops.pipeline(x, chain, impl="interpret", fuse=False, mesh=mesh2d)
        except ValueError as e:
            assert "cannot shard" in str(e), e
            print("ok unfused-mesh refusal")
        else:
            raise AssertionError("unfused sharded pipeline did not raise")
        # conv2d_apply under mesh keeps strides as a local subsample of
        # the dense sharded conv (an output-strided grid cannot shard)
        from repro.nn import layers as nnl
        cs = nnl.conv2d_specs(3, 4, (1, 3))
        p = {k: jnp.array(rng.standard_normal(s.shape), jnp.float32) * 0.3
             for k, s in cs.items()}
        xn = jnp.array(rng.standard_normal((8, 3, 1, 64)), jnp.float32)
        want = nnl.conv2d_apply(p, xn, impl="interpret", stride=(1, 2),
                                activation="gelu")
        got = nnl.conv2d_apply(p, xn, impl="interpret", stride=(1, 2),
                               activation="gelu", mesh=mesh2d)
        check("sharded strided conv2d_apply", got, want)
        print("DONE")
    """)
    assert "DONE" in run_with_devices(code)


def test_boundaries():
    """wrap == periodic reference (any t); replicate == edge-clamp (t=1)."""
    code = PRELUDE + textwrap.dedent("""
        x = jnp.array(rng.standard_normal((64, 288)), jnp.float32)
        sdef = BENCHMARKS["2d5pt"]

        def periodic_ref(x, sdef, t):
            x = x.astype(jnp.float32)
            for _ in range(t):
                out = jnp.zeros_like(x)
                for off, c in zip(sdef.offsets, sdef.coeffs):
                    out = out + c * jnp.roll(x, [-o for o in off],
                                             axis=tuple(range(x.ndim)))
                x = out
            return x

        for t in (1, 2, 3):
            got = ops.stencil(x, "2d5pt", time_steps=t, impl="interpret",
                              mesh=mesh2d, boundary="wrap")
            check(f"wrap t{t}", got, periodic_ref(x, sdef, t))

        r = sdef.radius
        xe = jnp.pad(x, ((r, r), (r, r)), mode="edge")
        want = jnp.zeros_like(x)
        for off, c in zip(sdef.offsets, sdef.coeffs):
            want = want + c * xe[r + off[0]:r + off[0] + x.shape[0],
                                 r + off[1]:r + off[1] + x.shape[1]]
        got = ops.stencil(x, "2d5pt", impl="interpret", mesh=mesh2d,
                          boundary="replicate")
        check("replicate t1", got, want)
        print("DONE")
    """)
    assert "DONE" in run_with_devices(code)


def test_multihop_halo_wider_than_shard():
    """Halos wider than one shard chain ppermute hops
    (``halo_exchange._multihop_slab``): a radius-5 stencil over 4-row
    shards pulls from two neighbors per side. zero == single-device
    engine, wrap == periodic reference, replicate == edge-clamp
    reference; a halo wider than the *whole* axis stays a named
    pre-pallas ValueError."""
    code = PRELUDE + textwrap.dedent("""
        mesh1d = make_domain_mesh((8,))
        spec = P("data", None)
        x = jnp.array(rng.standard_normal((32, 288)), jnp.float32)
        sdef = BENCHMARKS["2d121pt"]
        assert x.shape[0] // 8 < sdef.radius     # 4-row shards, (5,5) halo

        want = ops.stencil(x, "2d121pt", impl="interpret")
        got = ops.stencil(x, "2d121pt", impl="interpret", mesh=mesh1d,
                          in_specs=spec)
        check("multihop zero", got, want)

        def periodic_ref(x, sdef, t):
            x = x.astype(jnp.float32)
            for _ in range(t):
                out = jnp.zeros_like(x)
                for off, c in zip(sdef.offsets, sdef.coeffs):
                    out = out + c * jnp.roll(x, [-o for o in off],
                                             axis=tuple(range(x.ndim)))
                x = out
            return x

        got = ops.stencil(x, "2d121pt", impl="interpret", mesh=mesh1d,
                          in_specs=spec, boundary="wrap")
        check("multihop wrap", got, periodic_ref(x, sdef, 1))

        r = sdef.radius
        xe = jnp.pad(x, ((r, r), (r, r)), mode="edge")
        want = jnp.zeros_like(x)
        for off, c in zip(sdef.offsets, sdef.coeffs):
            want = want + c * xe[r + off[0]:r + off[0] + x.shape[0],
                                 r + off[1]:r + off[1] + x.shape[1]]
        got = ops.stencil(x, "2d121pt", impl="interpret", mesh=mesh1d,
                          in_specs=spec, boundary="replicate")
        check("multihop replicate", got, want)

        # t-widened halo: 2d9pt t=3 is a (6, 6) halo over 2-row shards —
        # three hops per side (the layout the pre-multihop layer refused)
        xt = jnp.array(rng.standard_normal((16, 288)), jnp.float32)
        got = ops.stencil(xt, "2d9pt", time_steps=3, impl="interpret",
                          mesh=mesh1d, in_specs=spec)
        check("multihop t3", got,
              ops.stencil(xt, "2d9pt", time_steps=3, impl="interpret"))

        # 2-D mesh: rows multi-hop (4-row shards over 2 devices) while
        # lanes stay single-hop; hop distance == ring size exercises the
        # degenerate self-link of the zero boundary
        xm = jnp.array(rng.standard_normal((8, 288)), jnp.float32)
        got = ops.stencil(xm, "2d121pt", impl="interpret", mesh=mesh2d)
        check("multihop 2d-mesh", got,
              ops.stencil(xm, "2d121pt", impl="interpret"))

        # halo wider than the whole axis: no schedule can source it
        try:
            ops.stencil(jnp.zeros((8, 288), jnp.float32), "2d121pt",
                        time_steps=2, impl="interpret", mesh=mesh1d,
                        in_specs=spec)
        except ValueError as e:
            assert "wider than domain axis" in str(e), e
            print("ok too-wide refusal")
        else:
            raise AssertionError("halo wider than domain axis did not raise")
        print("DONE")
    """)
    assert "DONE" in run_with_devices(code)


def test_sharding_value_errors():
    """Bad layouts fail with a clear ValueError before any pallas_call."""
    code = PRELUDE + textwrap.dedent("""
        mesh1d = make_domain_mesh((8,))
        w = jnp.ones((3, 3), jnp.float32)

        def expect(frag, fn):
            try:
                fn()
            except ValueError as e:
                assert frag in str(e), (frag, str(e))
                print("ok", frag)
            else:
                raise AssertionError(f"no ValueError containing {frag!r}")

        xq = jnp.zeros((30, 256), jnp.float32)
        expect("does not divide", lambda: ops.stencil(
            xq, "2d5pt", impl="interpret", mesh=mesh1d,
            in_specs=P("data", None)))
        xs = jnp.zeros((8, 256), jnp.float32)
        expect("wider than domain axis", lambda: ops.stencil(
            xs, "2d121pt", time_steps=2, impl="interpret", mesh=mesh1d,
            in_specs=P("data", None)))
        x = jnp.zeros((64, 256), jnp.float32)
        expect("mode='same'", lambda: ops.conv2d(
            x, w, mode="valid", impl="interpret", mesh=mesh1d))
        expect("time_steps=1 only", lambda: ops.stencil(
            x, "2d5pt", time_steps=2, impl="interpret", mesh=mesh1d,
            boundary="replicate"))
        expect("pjit", lambda: ops.stencil(
            x, "2d5pt", impl="xla", mesh=mesh1d))
        expect("at most one mesh axis", lambda: ops.stencil(
            x, "2d5pt", impl="interpret", mesh=mesh2d,
            in_specs=P(("data", "model"), None)))
        print("DONE")
    """)
    assert "DONE" in run_with_devices(code)


def test_sharded_autotune_targets_shard_shape():
    """Under a mesh the tuner keys on the halo-extended shard-local shape."""
    code = PRELUDE + textwrap.dedent("""
        from repro.core import tuning
        x = jnp.array(rng.standard_normal((64, 256)), jnp.float32)
        mesh1d = make_domain_mesh((8,))
        got = ops.stencil(x, "2d5pt", impl="interpret", mesh=mesh1d,
                          autotune=True)
        check("autotuned sharded", got, ops.stencil(x, "2d5pt",
                                                    impl="interpret"))
        (key,) = tuning._CACHE
        _, shape, _, _, ctx = key[:5]          # v6 keys append the backend
        assert shape == (64 // 8 + 2, 256), shape   # local rows + (1,1) halo
        assert any("sharded" in str(c) for c in ctx), ctx
        print("DONE")
    """)
    assert "DONE" in run_with_devices(code)


class TestSidecarPersistence:
    """JSON sidecar: write-through, warm reload with zero measurements,
    nearest-shape seeding. Single device is enough — no mesh involved."""

    def _tune_code(self, assert_zero_measure: bool) -> str:
        poison = (
            'def _no_measure(fn, reps=3):\n'
            '    raise AssertionError("tuning measured despite warm sidecar")\n'
            'tuning.measure_us = _no_measure\n'
        ) if assert_zero_measure else ""
        return textwrap.dedent("""
            import json, numpy as np, jax.numpy as jnp
            from repro.core import tuning
            from repro.kernels import ops, ref
            from repro.kernels.stencils import BENCHMARKS
            # POISON
            x = jnp.array(np.random.default_rng(0)
                          .standard_normal((64, 128)), jnp.float32)
            out = ops.stencil(x, "2d5pt", impl="interpret", autotune=True)
            np.testing.assert_allclose(
                np.asarray(out),
                np.asarray(ref.stencil_iterate(x, BENCHMARKS["2d5pt"], 1)),
                rtol=1e-4, atol=1e-4)
            y = jnp.array(np.random.default_rng(1)
                          .standard_normal((96, 160)), jnp.float32)
            out = ops.stencil(y, "2d5pt", impl="interpret", autotune=True)
            print(json.dumps(sorted(r.source for r in
                                    tuning._CACHE.values())))
        """).replace("# POISON\n", poison)

    def test_cold_start_with_warm_sidecar_measures_nothing(self, tmp_path):
        sidecar = str(tmp_path / "tuning.json")
        env = {"REPRO_TUNING_CACHE": sidecar}
        # first shape measures; the second is already seeded from it
        out = run_with_devices(self._tune_code(False), n=1, extra_env=env)
        assert json.loads(out.strip().splitlines()[-1]) == [
            "measured", "seeded"]
        doc = json.load(open(sidecar))
        assert len(doc["entries"]) == 1
        # cold process, warm sidecar: measure_us poisoned, still succeeds —
        # exact-shape hit + nearest-shape seed, zero tuning measurements.
        out = run_with_devices(self._tune_code(True), n=1, extra_env=env)
        assert json.loads(out.strip().splitlines()[-1]) == [
            "seeded", "sidecar"]

    def test_unseen_shape_seeds_from_nearest(self, tmp_path):
        sidecar = str(tmp_path / "tuning.json")
        env = {"REPRO_TUNING_CACHE": sidecar}
        run_with_devices(self._tune_code(False), n=1, extra_env=env)
        code = textwrap.dedent("""
            import json, numpy as np, jax.numpy as jnp
            from repro.core import tuning
            from repro.kernels import ops, ref
            from repro.kernels.stencils import BENCHMARKS
            def _no_measure(fn, reps=3):
                raise AssertionError("seeding must not measure")
            tuning.measure_us = _no_measure
            x = jnp.array(np.random.default_rng(2)
                          .standard_normal((80, 144)), jnp.float32)   # unseen
            out = ops.stencil(x, "2d5pt", impl="interpret", autotune=True)
            np.testing.assert_allclose(
                np.asarray(out),
                np.asarray(ref.stencil_iterate(x, BENCHMARKS["2d5pt"], 1)),
                rtol=1e-4, atol=1e-4)
            (res,) = tuning._CACHE.values()
            assert res.source == "seeded", res
            print("DONE")
        """)
        assert "DONE" in run_with_devices(code, n=1, extra_env=env)
