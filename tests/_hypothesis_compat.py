"""Deterministic stand-in for ``hypothesis`` on bare environments.

When the real hypothesis package is unavailable, ``@given`` degrades to a
fixed number of seeded pseudo-random examples per test (boundary values
first), so the property tests still run — with less search power but the
same assertions. Only the strategy surface this repo uses is provided
(``integers``, ``sampled_from``, ``floats``).
"""
from __future__ import annotations

import functools
import inspect
import random
import types

_MAX_EXAMPLES = 8
_SEED = 0x55A4


class _Strategy:
    def __init__(self, draw, boundaries=()):
        self._draw = draw
        self._boundaries = tuple(boundaries)

    def example(self, rnd: random.Random, i: int):
        if i < len(self._boundaries):
            return self._boundaries[i]
        return self._draw(rnd)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value),
                     boundaries=(min_value, max_value))


def _sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda r: r.choice(seq), boundaries=seq[:2])


def _floats(min_value: float, max_value: float, **_ignored) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value),
                     boundaries=(min_value, max_value))


strategies = types.SimpleNamespace(
    integers=_integers, sampled_from=_sampled_from, floats=_floats,
)


def given(**strats):
    """Run the test once per deterministic example of the strategies."""

    def deco(fn):
        sig = inspect.signature(fn)
        remaining = [p for name, p in sig.parameters.items()
                     if name not in strats]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rnd = random.Random(_SEED)
            for i in range(_MAX_EXAMPLES):
                drawn = {n: s.example(rnd, i) for n, s in strats.items()}
                fn(*args, **drawn, **kwargs)

        # pytest must see the signature *without* the strategy-provided
        # params, or it would look for fixtures named like them.
        wrapper.__signature__ = sig.replace(parameters=remaining)
        del wrapper.__wrapped__
        return wrapper

    return deco


def settings(*args, **_kwargs):
    """No-op settings decorator (max_examples is fixed in this shim)."""
    if args and callable(args[0]):
        return args[0]
    return lambda fn: fn
