"""Fused plan pipelines + epilogues (DESIGN.md §11).

Covers the PR-5 acceptance surface:

* fused-vs-unfused fp32-tolerance equivalence for Table-3 stencil
  chains (``ops.pipeline(fuse=True)`` vs the pad-once unfused fallback
  and the pure-jnp reference), the Whisper mel stem (epilogue + strided
  grid vs the dense+XLA form) and Mamba's conv→bias→silu seam;
* gradcheck of fused pipelines vs the ref oracle, with
  ``BACKWARD_LOWERINGS`` counters proving the backward stays on the
  engine (a *linear* chain transposes to ONE fused adjoint kernel);
* the strided-conv lowering (forward + grads vs the subsample oracle);
* the named pre-pallas ``ValueError``s: epilogue/stride on scan ops,
  NCHW stages in a pipeline, mid-chain operand-bearing epilogues,
  unknown epilogue ops, fuse=True on illegal chains;
* tuner keying: a fused chain is one §5 signature whose model cost is
  cheaper than the summed per-stage costs (one load+store).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adjoint as adj
from repro.core import tuning
from repro.core.fuse import fuse_plans
from repro.core.plan import (EpilogueStage, conv2d_nchw_plan,
                             conv2d_same_plan, depthwise_conv1d_plan,
                             normalize_epilogue, scan_plan, stencil2d_plan)
from repro.core.engine import run_scan_plan, run_window_plan
from repro.kernels import ops, ref
from repro.kernels.stencils import BENCHMARKS


def assert_close(a, b, tol=1e-4):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=tol, atol=tol)


def _plan(name):
    sdef = BENCHMARKS[name]
    return stencil2d_plan(sdef.offsets, coeffs=sdef.coeffs)


# ---------------------------------------------------------------------------
# fuse_plans: composite geometry + plan algebra
# ---------------------------------------------------------------------------

class TestFusePlans:
    def test_composite_geometry(self):
        p5, p9 = _plan("2d5pt"), _plan("2d9pt")
        f = fuse_plans(p5, p9, p5)
        # summed footprints: 3 + 5 + 3 → 1 + (2+4+2) = 9 per axis
        assert f.exts == (9, 9)
        assert f.halo(1) == (8, 8)
        lead, trail = f.lead_trail()
        assert lead == (4, 4) and trail == (4, 4)
        # shape-preserving: out shape == in shape
        assert f.out_shape((64, 64)) == (64, 64)
        # summed flop terms
        assert f.mads_per_output_window() == (
            2 * p5.mads_per_output_window() + p9.mads_per_output_window())

    def test_signature_distinct_and_single_stage_identity(self):
        p5, p9 = _plan("2d5pt"), _plan("2d9pt")
        f = fuse_plans(p5, p9)
        assert tuning.plan_signature(f) != tuning.plan_signature(p5)
        assert fuse_plans(p5) is p5

    def test_adjoint_of_chain_is_reversed_stage_adjoints(self):
        p5, p9 = _plan("2d5pt"), _plan("2d9pt")
        f = fuse_plans(p5, p9)
        af = adj.input_adjoint_plan(f)
        assert af.stages == (adj.input_adjoint_plan(p9),
                             adj.input_adjoint_plan(p5))
        # involution through the chain
        assert adj.input_adjoint_plan(af) == f

    def test_fused_model_cost_beats_summed_stages(self):
        """One load+store for the chain: the §5 cost of the fused plan
        must undercut the sum of the per-stage costs (each of which pays
        its own memory term)."""
        plans = [_plan("2d5pt"), _plan("2d9pt"), _plan("2d5pt")]
        cfg = tuning.KernelConfig((8, 128))
        fused = tuning.model_cost(fuse_plans(*plans), cfg)
        summed = sum(tuning.model_cost(p, cfg) for p in plans)
        assert fused < summed

    def test_fuse_legality_errors(self):
        p5 = _plan("2d5pt")
        with pytest.raises(ValueError, match="reduce/out axes"):
            fuse_plans(p5, conv2d_nchw_plan(1, 2, 2, 3, 3, mode="same"))
        with pytest.raises(ValueError, match="shape-preserving"):
            from repro.core.plan import conv2d_plan
            fuse_plans(p5, conv2d_plan(3, 3))      # 'valid' mode shrinks
        with pytest.raises(ValueError, match="scan plan"):
            fuse_plans(p5, scan_plan(128))
        with pytest.raises(ValueError, match="per-lane"):
            fuse_plans(depthwise_conv1d_plan(4), depthwise_conv1d_plan(4))
        with pytest.raises(ValueError, match="mid-chain"):
            res = dataclasses.replace(
                p5, epilogue=normalize_epilogue("residual_add"))
            fuse_plans(res, p5)
        with pytest.raises(ValueError, match="already a fused chain"):
            fuse_plans(fuse_plans(p5, p5), p5)

    def test_fuse_accepts_mid_chain_bias(self):
        """bias is chain-legal anywhere since it applies to the whole
        pad-once intermediate (residual_add stays final-only)."""
        p5 = _plan("2d5pt")
        biased = dataclasses.replace(p5, epilogue=normalize_epilogue("bias"))
        fused = fuse_plans(biased, p5)
        assert fused.stages[0].epilogue[0].op == "bias"


# ---------------------------------------------------------------------------
# Fused vs unfused equivalence (the Table-3 chain acceptance)
# ---------------------------------------------------------------------------

class TestPipelineEquivalence:
    @pytest.mark.parametrize("chain", [
        ["2d5pt", "2d9pt", "2d5pt"],
        ["2d9pt", "2d25pt"],
        ["2d5pt", ("2d9pt", "gelu"), "2d5pt"],
        [("2d5pt", "relu"), ("2d5pt", ("scale", 0.5)), "2d9pt"],
    ])
    def test_fused_vs_unfused_vs_ref_2d(self, rng, chain):
        x = jnp.array(rng.standard_normal((40, 72)), jnp.float32)
        fused = ops.pipeline(x, chain, impl="interpret", fuse=True)
        unfused = ops.pipeline(x, chain, impl="interpret", fuse=False)
        oracle = ops.pipeline(x, chain, impl="xla")
        assert_close(fused, unfused)
        assert_close(fused, oracle)

    def test_fused_3d_chain(self, rng):
        x = jnp.array(rng.standard_normal((10, 14, 40)), jnp.float32)
        chain = ["3d7pt", "poisson"]
        fused = ops.pipeline(x, chain, impl="interpret", fuse=True,
                             block_z=4, block_h=8, block_w=16)
        oracle = ops.pipeline(x, chain, impl="xla")
        assert_close(fused, oracle)

    def test_homogeneous_chain_matches_temporal_blocking(self, rng):
        """Fusing t copies of one stencil is exactly §6.4 temporal
        blocking: same pad-once semantics as ``ref.stencil_iterate``."""
        x = jnp.array(rng.standard_normal((24, 48)), jnp.float32)
        got = ops.pipeline(x, ["2d5pt"] * 3, impl="interpret", fuse=True)
        assert_close(got, ref.stencil_iterate(x, BENCHMARKS["2d5pt"], 3))
        assert_close(got, ops.stencil(x, "2d5pt", time_steps=3,
                                      impl="interpret"))

    def test_conv_stage_chain(self, rng):
        x = jnp.array(rng.standard_normal((32, 64)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 5)), jnp.float32)
        chain = [("2d5pt", "gelu"), w]
        fused = ops.pipeline(x, chain, impl="interpret", fuse=True)
        assert_close(fused, ops.pipeline(x, chain, impl="xla"))
        assert_close(fused, ops.pipeline(x, chain, impl="interpret",
                                         fuse=False))

    def test_final_stage_bias_and_residual(self, rng):
        x = jnp.array(rng.standard_normal((24, 48)), jnp.float32)
        res = jnp.array(rng.standard_normal((24, 48)), jnp.float32)
        b = jnp.float32(0.7)
        chain = ["2d5pt", ("2d9pt", ("bias", "gelu", "residual_add"))]
        got = ops.pipeline(x, chain, impl="interpret", fuse=True,
                           epilogue_args=(b, res))
        want = ops.pipeline(x, chain, impl="xla", epilogue_args=(b, res))
        assert_close(got, want)

    def test_mid_chain_bias(self, rng):
        """Scalar bias mid-chain: fused == unfused == oracle — it adds
        to the whole pad-once intermediate, so the trapezoidal boundary
        stays shared across the three paths."""
        x = jnp.array(rng.standard_normal((24, 48)), jnp.float32)
        b0, b1 = jnp.float32(0.37), jnp.float32(-1.2)
        chain = [("2d5pt", ("bias", "gelu")), ("2d9pt", "bias")]
        epi = (b0, b1)
        fused = ops.pipeline(x, chain, impl="interpret", fuse=True,
                             epilogue_args=epi)
        unfused = ops.pipeline(x, chain, impl="interpret", fuse=False,
                               epilogue_args=epi)
        oracle = ops.pipeline(x, chain, impl="xla", epilogue_args=epi)
        assert_close(fused, unfused)
        assert_close(fused, oracle)

    def test_mid_chain_bias_grads(self, rng):
        """Fused backward threads the mid-chain bias cotangent: dx and
        both dbias match jax AD on the xla oracle."""
        x = jnp.array(rng.standard_normal((20, 40)), jnp.float32)
        chain = [("2d5pt", "bias"), ("2d9pt", ("bias", "gelu"))]

        def loss(impl, xx, b0, b1):
            y = ops.pipeline(xx, chain, impl=impl, fuse=(impl != "xla"),
                             epilogue_args=(b0, b1))
            return jnp.sum(y ** 2)

        b0, b1 = jnp.float32(0.5), jnp.float32(-0.25)
        ge = jax.grad(lambda *a: loss("interpret", *a),
                      argnums=(0, 1, 2))(x, b0, b1)
        gr = jax.grad(lambda *a: loss("xla", *a),
                      argnums=(0, 1, 2))(x, b0, b1)
        for a, b in zip(ge, gr):
            assert_close(a, b, tol=1e-3)

    def test_pipeline_interior_matches_per_op_loop(self, rng):
        """Pad-once chain semantics agree with the naive per-op loop on
        the interior at distance > Σ radius from the boundary."""
        x = jnp.array(rng.standard_normal((40, 64)), jnp.float32)
        chain = ["2d5pt", "2d9pt"]
        fused = ops.pipeline(x, chain, impl="interpret", fuse=True)
        loop = ops.stencil(ops.stencil(x, "2d5pt", impl="interpret"),
                           "2d9pt", impl="interpret")
        r = 3              # Σ radius = 1 + 2
        assert_close(fused[r:-r, r:-r], loop[r:-r, r:-r])


# ---------------------------------------------------------------------------
# Epilogues on single ops + the engine-level scan epilogue
# ---------------------------------------------------------------------------

class TestEpilogues:
    @pytest.mark.parametrize("epi", ["gelu", "silu", "relu", ("scale", 2.5)])
    def test_stencil_epilogue_matches_oracle(self, rng, epi):
        x = jnp.array(rng.standard_normal((26, 60)), jnp.float32)
        got = ops.stencil(x, "2d9pt", impl="interpret", epilogue=epi)
        want = ops.stencil(x, "2d9pt", impl="xla", epilogue=epi)
        assert_close(got, want)

    def test_nchw_bias_gelu_epilogue(self, rng):
        x = jnp.array(rng.standard_normal((2, 3, 10, 40)), jnp.float32)
        w = jnp.array(rng.standard_normal((4, 3, 3, 3)), jnp.float32)
        b = jnp.array(rng.standard_normal((4,)), jnp.float32)
        got = ops.conv2d(x, w, impl="interpret", epilogue=("bias", "gelu"),
                         epilogue_args=(b,))
        want = jax.nn.gelu(ref.conv2d_nchw(x, w, "same")
                           + b[None, :, None, None], approximate=True)
        assert_close(got, want)

    def test_conv1d_bias_silu_epilogue(self, rng):
        x = jnp.array(rng.standard_normal((2, 31, 16)), jnp.float32)
        w = jnp.array(rng.standard_normal((4, 16)), jnp.float32)
        b = jnp.array(rng.standard_normal((16,)), jnp.float32)
        got = ops.conv1d_causal(x, w, impl="interpret",
                                epilogue=("bias", "silu"),
                                epilogue_args=(b,))
        assert_close(got, jax.nn.silu(ref.conv1d_causal(x, w) + b))

    def test_epilogue_with_temporal_blocking(self, rng):
        x = jnp.array(rng.standard_normal((24, 48)), jnp.float32)
        got = ops.stencil(x, "2d5pt", time_steps=2, impl="interpret",
                          epilogue="gelu")
        want = jax.nn.gelu(ref.stencil_iterate(x, BENCHMARKS["2d5pt"], 2),
                           approximate=True)
        assert_close(got, want)

    def test_scan_plan_epilogue_engine_level(self, rng):
        """run_scan_plan applies operand-free epilogues to the stored
        output only — the inter-block carry keeps the raw scan state."""
        x = jnp.array(rng.standard_normal((5, 100)), jnp.float32)
        plan = dataclasses.replace(scan_plan(32),
                                   epilogue=normalize_epilogue("relu"))
        got = run_scan_plan(x, plan=plan, block_r=4)
        assert_close(got, jnp.maximum(ref.cumsum(x), 0))
        with pytest.raises(ValueError, match="operand-free"):
            bad = dataclasses.replace(scan_plan(32),
                                      epilogue=normalize_epilogue("bias"))
            run_scan_plan(x, plan=bad, block_r=4)

    def test_mamba_fused_conv_matches_xla_path(self, rng):
        from repro.nn import ssm
        specs = ssm.mamba_specs(16, d_inner=32, ssm_state=4)
        p = {k: jnp.array(rng.standard_normal(s.shape), jnp.float32) * 0.1
             for k, s in specs.items()}
        x = jnp.array(rng.standard_normal((2, 24, 16)), jnp.float32)
        o_xla, _ = ssm.mamba_apply(p, x, ssm_state=4, conv_impl="xla")
        o_eng, _ = ssm.mamba_apply(p, x, ssm_state=4, conv_impl="interpret")
        assert_close(o_eng, o_xla, 2e-4)


# ---------------------------------------------------------------------------
# The strided lowering + the Whisper stem
# ---------------------------------------------------------------------------

class TestStridedAndStem:
    def test_strided_conv_matches_subsample(self, rng):
        x = jnp.array(rng.standard_normal((2, 3, 12, 40)), jnp.float32)
        w = jnp.array(rng.standard_normal((4, 3, 3, 3)), jnp.float32)
        for stride in ((1, 2), (2, 2), (2, 1)):
            got = ops.conv2d(x, w, impl="interpret", stride=stride)
            want = ref.conv2d_nchw(x, w, "same")[..., ::stride[0],
                                                 ::stride[1]]
            assert_close(got, want)

    @pytest.mark.parametrize("mode", ["same", "valid"])
    @pytest.mark.parametrize("stride", [2, (1, 2), (2, 1), (3, 3)])
    def test_strided_single_image_modes(self, rng, mode, stride):
        """Mode × stride sweep on the 2-D rank — including the
        valid-mode tilings that need *fewer* input rows than given
        (the origin-pad clamp)."""
        x = jnp.array(rng.standard_normal((24, 64)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 3)), jnp.float32)
        got = ops.conv2d(x, w, impl="interpret", mode=mode, stride=stride)
        sh, sw = (stride, stride) if isinstance(stride, int) else stride
        dense = (ref.conv2d_same(x, w) if mode == "same"
                 else ref.conv2d_valid(x, w))
        assert_close(got, dense[::sh, ::sw])

    def test_strided_conv_grads(self, rng):
        x = jnp.array(rng.standard_normal((1, 2, 6, 24)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 2, 3, 3)), jnp.float32)
        adj.reset_lowering_counts()
        f_e = lambda a, b: jnp.sum(ops.conv2d(
            a, b, impl="interpret", stride=(1, 2)) ** 2)
        f_r = lambda a, b: jnp.sum(
            ref.conv2d_nchw(a, b, "same")[..., ::2] ** 2)
        ge, gr = jax.grad(f_e, (0, 1))(x, w), jax.grad(f_r, (0, 1))(x, w)
        assert_close(ge[0], gr[0], 1e-3)
        assert_close(ge[1], gr[1], 1e-3)
        # the dilated cotangent still lowers through the engine's
        # adjoint + wgrad plans
        assert adj.BACKWARD_LOWERINGS["adj_conv2d_nchw"] >= 1
        assert adj.BACKWARD_LOWERINGS["wgrad_conv2d_nchw"] >= 1

    def test_whisper_stem_fused_vs_oracle(self, rng):
        """conv2d_apply's engine path (fused bias/GELU epilogue +
        output-strided grid) == the XLA oracle form (dense conv,
        subsample, jnp bias+gelu) — forward and grads."""
        from repro.nn import layers as nnl
        cs = nnl.conv2d_specs(3, 8, (1, 3))
        p = {k: jnp.array(rng.standard_normal(s.shape), jnp.float32) * 0.3
             for k, s in cs.items()}
        x = jnp.array(rng.standard_normal((2, 3, 1, 40)), jnp.float32)
        y_e = nnl.conv2d_apply(p, x, impl="interpret", stride=(1, 2),
                               activation="gelu")
        y_x = nnl.conv2d_apply(p, x, impl="xla", stride=(1, 2),
                               activation="gelu")
        assert_close(y_e, y_x)
        g_e = jax.grad(lambda q: jnp.sum(nnl.conv2d_apply(
            q, x, impl="interpret", stride=(1, 2), activation="gelu") ** 2))(p)
        g_x = jax.grad(lambda q: jnp.sum(nnl.conv2d_apply(
            q, x, impl="xla", stride=(1, 2), activation="gelu") ** 2))(p)
        assert_close(g_e["w"], g_x["w"], 2e-3)
        assert_close(g_e["b"], g_x["b"], 2e-3)

    def test_whisper_frontend_engine_vs_xla(self, rng):
        from repro.configs.whisper_base import SMOKE_CONV
        from repro.models.whisper import Whisper
        m = Whisper(SMOKE_CONV)
        p = {name: {k: jnp.array(rng.standard_normal(s.shape),
                                 jnp.float32) * 0.2
                    for k, s in sub.items()}
             for name, sub in m.frontend_specs().items()}
        mel = jnp.array(rng.standard_normal((2, SMOKE_CONV.n_mels, 32)),
                        jnp.float32)
        assert_close(m.frontend(p, mel, impl="interpret"),
                     m.frontend(p, mel, impl="xla"), 2e-4)


# ---------------------------------------------------------------------------
# Gradients of fused pipelines — engine path end-to-end
# ---------------------------------------------------------------------------

class TestPipelineGradients:
    def test_linear_chain_one_fused_adjoint_kernel(self, rng):
        """A purely linear table chain transposes to ONE fused adjoint
        kernel (the reversed chain of stage adjoints)."""
        x = jnp.array(rng.standard_normal((28, 56)), jnp.float32)
        chain = ["2d5pt", "2d9pt"]
        adj.reset_lowering_counts()
        g_e = jax.grad(lambda v: jnp.sum(ops.pipeline(
            v, chain, impl="interpret", fuse=True)))(x)
        g_r = jax.grad(lambda v: jnp.sum(ops.pipeline(
            v, chain, impl="xla")))(x)
        assert_close(g_e, g_r)
        assert adj.BACKWARD_LOWERINGS[
            "pipe2_adj_stencil2d+adj_stencil2d"] == 1

    def test_nonlinear_chain_gradcheck_vs_ref(self, rng):
        x = jnp.array(rng.standard_normal((24, 48)), jnp.float32)
        w = jnp.array(rng.standard_normal((3, 3)), jnp.float32)
        chain = lambda ww: [("2d5pt", "gelu"), ww, ("2d9pt", "silu")]
        adj.reset_lowering_counts()
        f_e = lambda v, ww: jnp.sum(ops.pipeline(
            v, chain(ww), impl="interpret", fuse=True) ** 2)
        f_r = lambda v, ww: jnp.sum(ops.pipeline(
            v, chain(ww), impl="xla") ** 2)
        ge, gr = (jax.grad(f_e, (0, 1))(x, w), jax.grad(f_r, (0, 1))(x, w))
        assert_close(ge[0], gr[0], 2e-3)
        assert_close(ge[1], gr[1], 2e-3)
        # every linear piece of the backward lowered through the engine
        assert adj.BACKWARD_LOWERINGS["adj_stencil2d"] >= 2
        assert adj.BACKWARD_LOWERINGS["adj_conv2d"] >= 1
        assert adj.BACKWARD_LOWERINGS["wgrad_conv2d"] >= 1

    def test_final_epilogue_operand_grads(self, rng):
        x = jnp.array(rng.standard_normal((20, 40)), jnp.float32)
        res = jnp.array(rng.standard_normal((20, 40)), jnp.float32)
        chain = ["2d5pt", ("2d9pt", ("gelu", "residual_add"))]
        f_e = lambda v, r: jnp.sum(ops.pipeline(
            v, chain, impl="interpret", fuse=True,
            epilogue_args=(r,)) ** 2)
        f_r = lambda v, r: jnp.sum(ops.pipeline(
            v, chain, impl="xla", epilogue_args=(r,)) ** 2)
        ge = jax.grad(f_e, (0, 1))(x, res)
        gr = jax.grad(f_r, (0, 1))(x, res)
        assert_close(ge[0], gr[0], 2e-3)
        assert_close(ge[1], gr[1], 2e-3)


# ---------------------------------------------------------------------------
# Named pre-pallas errors (the PR 4 guard pattern extended)
# ---------------------------------------------------------------------------

class TestRejections:
    def test_scan_ops_reject_epilogue(self, rng):
        x = jnp.array(rng.standard_normal((4, 64)), jnp.float32)
        for call in (lambda: ops.cumsum(x, epilogue="gelu"),
                     lambda: ops.sat(x, epilogue="gelu"),
                     lambda: ops.linear_recurrence(x, x, epilogue="gelu"),
                     lambda: ops.cumsum(x, epilogue_args=(x,)),
                     lambda: ops.linear_recurrence(x, x, stride=(1, 2))):
            with pytest.raises(ValueError, match="windowed-plan feature"):
                call()

    def test_scan_ops_still_reject_mesh(self, rng):
        x = jnp.array(rng.standard_normal((4, 64)), jnp.float32)
        with pytest.raises(ValueError, match="halo-exchange"):
            ops.cumsum(x, mesh="anything")

    def test_unknown_epilogue_and_bad_args(self, rng):
        x = jnp.array(rng.standard_normal((16, 32)), jnp.float32)
        with pytest.raises(ValueError, match="vocabulary"):
            ops.stencil(x, "2d5pt", impl="interpret", epilogue="tanh")
        with pytest.raises(ValueError, match="runtime operand"):
            ops.stencil(x, "2d5pt", impl="interpret", epilogue="bias")
        with pytest.raises(ValueError, match="scale"):
            ops.stencil(x, "2d5pt", impl="interpret", epilogue="scale")
        with pytest.raises(ValueError, match="per-channel"):
            ops.conv1d_causal(jnp.zeros((1, 8, 4)), jnp.zeros((2, 4)),
                              impl="interpret", epilogue="bias",
                              epilogue_args=(jnp.zeros((5,)),))

    def test_pipeline_rejections(self, rng):
        x = jnp.array(rng.standard_normal((16, 32)), jnp.float32)
        with pytest.raises(ValueError, match="OIHW"):
            ops.pipeline(x, ["2d5pt", jnp.zeros((2, 2, 3, 3))],
                         impl="interpret")
        with pytest.raises(ValueError, match="unknown stencil"):
            ops.pipeline(x, ["nope"], impl="interpret")
        with pytest.raises(ValueError, match="mid-chain"):
            ops.pipeline(x, [("2d5pt", "residual_add"), "2d9pt"],
                         impl="interpret", epilogue_args=(x,))
        with pytest.raises(ValueError, match="scalar"):
            ops.pipeline(x, [("2d5pt", "bias"), "2d9pt"], impl="interpret",
                         epilogue_args=(jnp.ones((32,)),))
        with pytest.raises(ValueError, match="is 3-D"):
            ops.pipeline(x, ["3d7pt"], impl="interpret")
        with pytest.raises(ValueError, match="at least one stage"):
            ops.pipeline(x, [], impl="interpret")
        with pytest.raises(ValueError, match="fuse must be"):
            ops.pipeline(x, ["2d5pt"], impl="interpret", fuse="maybe")
        with pytest.raises(ValueError, match="not a stencil"):
            ops.pipeline(x, [lambda: None], impl="interpret")

    def test_strided_rejections(self, rng):
        x = jnp.array(rng.standard_normal((2, 2, 8, 16)), jnp.float32)
        w = jnp.array(rng.standard_normal((2, 2, 3, 3)), jnp.float32)
        with pytest.raises(ValueError, match="stride must be"):
            ops.conv2d(x, w, impl="interpret", stride=(0, 2))
        with pytest.raises(ValueError, match="sharded strided"):
            ops.conv2d(x, w, impl="interpret", stride=(1, 2), mesh=object())

    def test_input_adjoint_refuses_strided_plan(self):
        plan = dataclasses.replace(
            conv2d_nchw_plan(1, 2, 2, 3, 3, mode="same"), stride=(1, 2))
        with pytest.raises(ValueError, match="input-dilated"):
            adj.input_adjoint_plan(plan)


# ---------------------------------------------------------------------------
# Tuner integration
# ---------------------------------------------------------------------------

class TestFusedTuning:
    def test_pipeline_autotune_keys_fused_signature(self, rng):
        tuning.clear_cache()
        x = jnp.array(rng.standard_normal((64, 128)), jnp.float32)
        chain = ["2d5pt", "2d9pt"]
        out = ops.pipeline(x, chain, impl="interpret", fuse=True,
                           autotune=True)
        assert_close(out, ops.pipeline(x, chain, impl="xla"))
        keys = list(tuning._CACHE)
        assert any(k[0].kind.startswith("pipe2_") and "pipeline" in k[4]
                   for k in keys), keys

    def test_strided_candidates_single_variant(self):
        plan = dataclasses.replace(
            conv2d_nchw_plan(1, 2, 2, 3, 3, mode="same"), stride=(1, 2))
        cands = tuning.candidate_configs(plan, (1, 2, 8, 64))
        assert cands
        assert all(c.variant == "shift_data" for c in cands)

    def test_epilogue_plan_autotune_measures_actual_kernel(self, rng):
        tuning.clear_cache()
        x = jnp.array(rng.standard_normal((48, 96)), jnp.float32)
        out = ops.stencil(x, "2d5pt", impl="interpret", autotune=True,
                          epilogue="gelu")
        want = jax.nn.gelu(ref.stencil_iterate(x, BENCHMARKS["2d5pt"], 1),
                           approximate=True)
        assert_close(out, want)
        # the cached plan carries the epilogue → its own signature
        assert any(k[0].epilogue for k in tuning._CACHE
                   if isinstance(k[0], type(_plan("2d5pt")))), \
            list(tuning._CACHE)
