"""Integration: train loop (learning + fault-injection restart) and the
continuous-batching serve loop (== sequential decode)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_train(args, timeout=560):
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


class TestTrainLoop:
    def test_loss_decreases(self, tmp_path):
        mfile = str(tmp_path / "metrics.jsonl")
        run_train(["--arch", "internvl2-1b", "--smoke", "--steps", "60",
                   "--batch", "8", "--seq", "64", "--lr", "3e-3",
                   "--metrics-file", mfile])
        import json
        rows = [json.loads(l) for l in open(mfile)]
        first = np.mean([r["loss"] for r in rows[:5]])
        last = np.mean([r["loss"] for r in rows[-5:]])
        assert last < first - 0.5, (first, last)

    def test_failure_injection_and_bitexact_restart(self, tmp_path):
        """Crash at step 7, restart, and match an uninterrupted run exactly."""
        ck1 = str(tmp_path / "a")
        ck2 = str(tmp_path / "b")
        common = ["--arch", "whisper-base", "--smoke", "--steps", "10",
                  "--batch", "2", "--seq", "16", "--ckpt-every", "5"]
        # uninterrupted reference
        run_train(common + ["--ckpt-dir", ck2])
        # crashed run: injected failure after step 7 (post-step-5 checkpoint)
        env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
        crash = subprocess.run(
            [sys.executable, "-m", "repro.launch.train"] + common +
            ["--ckpt-dir", ck1, "--fail-at-step", "7"],
            capture_output=True, text=True, env=env, timeout=560)
        assert crash.returncode != 0 and "injected failure" in crash.stderr
        # restart — resumes from step 5 and completes
        out = run_train(common + ["--ckpt-dir", ck1])
        assert "resumed from step 5" in out
        # final checkpoints bit-identical (same data stream, deterministic)
        import msgpack
        from repro.checkpointing import checkpoint as ckpt
        def final(d):
            step_dir = os.path.join(d, "step_00000010")
            zst = os.path.join(step_dir, ckpt._COMPRESSED)
            if os.path.exists(zst):          # zstandard installed
                raw = ckpt.zstandard.ZstdDecompressor().decompress(
                    open(zst, "rb").read())
            else:                            # bare env: raw msgpack fallback
                raw = open(os.path.join(step_dir, ckpt._RAW), "rb").read()
            return msgpack.unpackb(raw, raw=False)
        a, b = final(ck1), final(ck2)
        assert a.keys() == b.keys()
        for k in a:
            assert a[k]["data"] == b[k]["data"], f"divergence in {k}"


class TestServe:
    def test_continuous_batching_matches_sequential(self):
        """Tokens from the slot-pool server == tokens from naive one-at-a-
        time greedy decode (greedy determinism across batching)."""
        from repro.config import get_config
        from repro.launch.serve import DecodeServer, Request
        from repro.models import build_model
        from repro.nn.spec import init_params

        cfg = get_config("gemma3_1b", smoke=True)
        model = build_model(cfg)
        params = init_params(model.specs(), jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, cfg.vocab, 5, dtype=np.int32)
                   for _ in range(5)]

        # sequential reference (batch of 1, fresh state per request)
        seq_out = []
        step = jax.jit(model.serve_step)
        for p in prompts:
            state = init_params(model.decode_state_specs(1, 32),
                                jax.random.PRNGKey(0))
            toks = list(p)
            out = []
            t = 0
            cur = toks[0]
            pending = toks[1:]
            while len(out) < 6:
                logits, state = step(params, state,
                                     jnp.array([[cur]], jnp.int32),
                                     jnp.int32(t))
                t += 1
                if pending:
                    cur = pending.pop(0)
                else:
                    cur = int(jnp.argmax(logits[0]))
                    out.append(cur)
            seq_out.append(out)

        # continuous batching with 2 slots over 5 requests
        server = DecodeServer(model, params, slots=2, cache_len=32)
        reqs = [Request(i, p, 6) for i, p in enumerate(prompts)]
        done = server.run(reqs)
        for r in done:
            assert r.out == seq_out[r.rid], (r.rid, r.out, seq_out[r.rid])

    def test_slot_reuse_no_state_leak(self):
        """A request decoded in a reused slot matches one in a fresh server."""
        from repro.config import get_config
        from repro.launch.serve import DecodeServer, Request
        from repro.models import build_model
        from repro.nn.spec import init_params

        cfg = get_config("rwkv6_1g6b", smoke=True)
        model = build_model(cfg)
        params = init_params(model.specs(), jax.random.PRNGKey(0))
        p1 = np.array([1, 2, 3], np.int32)
        p2 = np.array([9, 8, 7], np.int32)

        fresh = DecodeServer(model, params, slots=1, cache_len=32)
        [r_fresh] = fresh.run([Request(0, p2, 4)])

        reused = DecodeServer(model, params, slots=1, cache_len=32)
        done = reused.run([Request(0, p1, 4), Request(1, p2, 4)])
        r_reused = [r for r in done if r.rid == 1][0]
        assert r_reused.out == r_fresh.out


class TestData:
    def test_determinism_and_restart(self):
        from repro.data import TokenDataset
        ds = TokenDataset(1000, 32, seed=3)
        a = ds.batch(5, 8)
        b = ds.batch(5, 8)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        # labels are next-token shifted
        np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])

    def test_host_sharding_partitions_global_batch(self):
        from repro.data import TokenDataset
        ds = TokenDataset(1000, 16, seed=0)
        full = ds.batch(2, 8, host_id=0, num_hosts=1)
        parts = [ds.batch(2, 8, host_id=h, num_hosts=4) for h in range(4)]
        np.testing.assert_array_equal(
            np.concatenate([p["tokens"] for p in parts]), full["tokens"])

    def test_learnable_structure(self):
        """The synthetic stream has predictable second-half structure."""
        from repro.data import TokenDataset
        ds = TokenDataset(100, 64, seed=0)
        b = ds.batch(0, 4)
        t = b["tokens"]
        # second half ≈ first half (10% noise)
        half = 32
        match = (t[:, half:2 * half - 1] == t[:, :half - 1]).mean()
        assert match > 0.7, match
