"""SSAM core model: plan algebra + executor vs mathematical oracles.

Property tests (hypothesis) pin down the invariants of §4/§5 of the
paper: register-cache geometry C = N + P − 1, valid lanes S − M + 1,
halo-ratio algebra, and executor equivalence with direct math for
arbitrary shapes/filters.
"""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare env: deterministic fallback examples
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (conv2d_plan, execute_conv_block, execute_conv_global,
                        execute_linear_recurrence, execute_scan,
                        linear_recurrence_plan, scan_plan, stencil2d_plan)
from repro.core.perfmodel import P100, TPU_V5E, V100, dif_smem_reg, l_reg, l_smem


class TestPlanGeometry:
    @given(M=st.integers(1, 12), N=st.integers(1, 12), P=st.integers(1, 8))
    def test_register_cache_size_eq3(self, M, N, P):
        plan = conv2d_plan(M, N, P=P)
        assert plan.C == N + P - 1            # Eq. 3

    @given(M=st.integers(1, 12), N=st.integers(1, 12))
    def test_valid_lanes(self, M, N):
        plan = conv2d_plan(M, N, S=32)
        assert plan.valid_lanes == 32 - M + 1  # §4.4

    @given(M=st.integers(1, 8), N=st.integers(1, 8), P=st.integers(1, 8))
    def test_halo_ratio_bounds(self, M, N, P):
        plan = conv2d_plan(M, N, P=P)
        hr = plan.halo_ratio()
        assert 0.0 <= hr < 1.0
        if M == N == 1:
            assert hr == 0.0

    @given(M=st.integers(2, 8), N=st.integers(2, 8))
    def test_shift_count_is_m_minus_1(self, M, N):
        plan = conv2d_plan(M, N)
        assert plan.shift_count() == M - 1     # (M−1)·T_shfl of Eq. 4
        assert plan.mads_per_output_window() == M * N

    def test_stencil_grouping_matches_listing2(self):
        # 5-point stencil groups into {W}, {N,C,S}, {E} — 3 columns
        offs = [(0, -1), (-1, 0), (0, 0), (1, 0), (0, 1)]
        plan = stencil2d_plan(offs)
        assert plan.M == 3
        assert [len(s.taps) for s in plan.steps] == [1, 3, 1]


class TestPerfModel:
    @pytest.mark.parametrize("hw", [P100, V100, TPU_V5E])
    @given(M=st.integers(2, 20), N=st.integers(2, 20))
    @settings(max_examples=20)
    def test_eq5_positive(self, hw, M, N):
        # the paper's claim: Dif_smem_reg ≫ 0 for M, N ≥ 2
        assert dif_smem_reg(hw, M, N) > 0
        assert l_smem(hw, M, N) > l_reg(hw, M, N)

    def test_advantage_grows_with_filter(self):
        # Fig. 4's trend: the SSAM advantage grows with filter size
        d = [dif_smem_reg(V100, m, m) for m in range(2, 21)]
        assert all(b > a for a, b in zip(d, d[1:]))


class TestExecutor:
    @given(
        M=st.integers(1, 5), N=st.integers(1, 5),
        H=st.integers(6, 16), W=st.integers(8, 40),
        seed=st.integers(0, 2**31),
    )
    @settings(max_examples=25, deadline=None)
    def test_conv_global_matches_oracle(self, M, N, H, W, seed):
        r = np.random.default_rng(seed)
        x = r.standard_normal((max(H, N), max(W, M))).astype(np.float32)
        w = r.standard_normal((N, M)).astype(np.float32)
        plan = conv2d_plan(M, N, S=x.shape[1], P=1)
        out = np.asarray(execute_conv_global(plan, jnp.array(x), jnp.array(w)))
        oh, ow = x.shape[0] - N + 1, x.shape[1] - M + 1
        ref = np.zeros((oh, ow), np.float32)
        for y in range(oh):
            for xx in range(ow):
                ref[y, xx] = (x[y:y + N, xx:xx + M] * w).sum()
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_conv_block_valid_lanes(self, rng):
        M, N, P, S = 4, 3, 2, 32
        plan = conv2d_plan(M, N, S=S, P=P)
        x = rng.standard_normal((plan.C, S)).astype(np.float32)
        w = rng.standard_normal((N, M)).astype(np.float32)
        out = np.asarray(execute_conv_block(plan, jnp.array(x), jnp.array(w)))
        for i in range(P):
            for lane in range(M - 1, S):
                ref = (x[i:i + N, lane - M + 1:lane + 1] * w).sum()
                np.testing.assert_allclose(out[i, lane], ref, rtol=1e-4)

    @given(n=st.sampled_from([8, 32, 128]), seed=st.integers(0, 2**31))
    @settings(max_examples=10, deadline=None)
    def test_scan_is_cumsum(self, n, seed):
        r = np.random.default_rng(seed)
        x = r.standard_normal((3, n)).astype(np.float32)
        out = np.asarray(execute_scan(scan_plan(n), jnp.array(x)))
        np.testing.assert_allclose(out, np.cumsum(x, -1), rtol=1e-4, atol=1e-4)

    @given(n=st.sampled_from([8, 64]), seed=st.integers(0, 2**31))
    @settings(max_examples=10, deadline=None)
    def test_linear_recurrence(self, n, seed):
        r = np.random.default_rng(seed)
        a = r.uniform(0.2, 1.0, (2, n)).astype(np.float32)
        b = r.standard_normal((2, n)).astype(np.float32)
        out = np.asarray(execute_linear_recurrence(
            linear_recurrence_plan(n), jnp.array(a), jnp.array(b)))
        h = np.zeros((2,), np.float32)
        ref = np.zeros_like(b)
        for t in range(n):
            h = a[:, t] * h + b[:, t]
            ref[:, t] = h
        np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)

    def test_scan_associativity_property(self, rng):
        """KS scan == sequential fold for a non-commutative affine op —
        the associativity property the recurrence plan relies on."""
        n = 64
        a = rng.uniform(0.5, 1.5, (1, n)).astype(np.float32)
        b = rng.standard_normal((1, n)).astype(np.float32)
        ks = np.asarray(execute_linear_recurrence(
            linear_recurrence_plan(n), jnp.array(a), jnp.array(b)))
        h = 0.0
        for t in range(n):
            h = a[0, t] * h + b[0, t]
        np.testing.assert_allclose(ks[0, -1], h, rtol=1e-4)
