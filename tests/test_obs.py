"""Observability layer (DESIGN.md §15): tracer, metrics, drift.

Covers the PR-9 acceptance gates:
- disabled-mode fast path: ``span()`` returns the shared no-op, the
  event buffer stays empty, and the per-span overhead is bounded;
- span nesting + Chrome-trace JSON validity (``ph: "X"`` complete
  events with µs timestamps, parent attribution, valid ``json.dumps``);
- engine counters match known launch counts (per-call launches vs
  per-compile lowerings);
- drift recorder math (geomean ratios, backend pooling, worst-cell
  ranking) and the report CLI;
- serve latency histograms (p50/p99 in ``metrics.snapshot()``);
- ``measure_us`` spread + ``$REPRO_MEASURE_REPS`` and the v7 sidecar
  schema (spread persisted, stale v6 entries dropped on load).
"""
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import tuning
from repro.kernels import ops


@pytest.fixture(autouse=True)
def _clean_telemetry():
    """Every test starts (and leaves) with telemetry off and empty."""
    obs.trace.disable()
    obs.trace.clear()
    obs.metrics.reset()
    obs.drift.reset()
    yield
    obs.trace.disable()
    obs.trace.clear()
    obs.metrics.reset()
    obs.drift.reset()


class TestTracerDisabled:
    def test_span_is_shared_noop(self):
        assert obs.span("anything", key="val") is obs.trace.NULL
        assert obs.span("other") is obs.trace.NULL

    def test_no_events_collected(self):
        with obs.span("a"):
            with obs.span("b"):
                pass
        assert obs.trace.events() == []

    def test_disabled_overhead_bounded(self):
        """The no-op path is a function call + a bool read — bound it
        loosely (100 µs/span) so only a real regression (event append,
        clock read, allocation per call) can trip it on a noisy host."""
        n = 10_000
        t0 = time.perf_counter()
        for _ in range(n):
            with obs.span("hot", a=1):
                pass
        per_span = (time.perf_counter() - t0) / n
        assert per_span < 100e-6, f"{per_span * 1e6:.2f} µs per no-op span"
        assert obs.trace.events() == []

    def test_traced_decorator_passthrough(self):
        calls = []

        @obs.trace.traced("deco")
        def fn(v):
            calls.append(v)
            return v + 1

        assert fn(1) == 2
        assert calls == [1]
        assert obs.trace.events() == []


class TestTracerEnabled:
    def test_nesting_and_parent_attribution(self):
        with obs.tracing():
            with obs.span("outer"):
                assert obs.trace.current_stack() == ("outer",)
                with obs.span("inner"):
                    assert obs.trace.current_stack() == ("outer", "inner")
        evs = {e["name"]: e for e in obs.trace.events()}
        assert set(evs) == {"outer", "inner"}
        assert evs["inner"]["args"]["parent"] == "outer"
        assert evs["inner"]["args"]["depth"] == 1
        assert evs["outer"]["args"]["depth"] == 0
        # inner completes within outer's interval
        assert evs["inner"]["ts"] >= evs["outer"]["ts"]
        assert (evs["inner"]["ts"] + evs["inner"]["dur"]
                <= evs["outer"]["ts"] + evs["outer"]["dur"] + 1e-3)

    def test_chrome_trace_export_shape(self, tmp_path):
        path = tmp_path / "trace.json"
        with obs.tracing(str(path)):
            with obs.span("work", cat="test", detail="x"):
                pass
        doc = json.loads(path.read_text())
        assert doc["displayTimeUnit"] == "ms"
        (ev,) = doc["traceEvents"]
        assert ev["ph"] == "X"
        assert ev["name"] == "work" and ev["cat"] == "test"
        assert isinstance(ev["ts"], float) and isinstance(ev["dur"], float)
        assert ev["dur"] >= 0
        assert {"pid", "tid", "args"} <= set(ev)

    def test_tracing_restores_prior_state(self):
        assert not obs.trace.enabled()
        with obs.tracing():
            assert obs.trace.enabled()
        assert not obs.trace.enabled()


class TestMetrics:
    def test_counter_labels_and_total(self):
        obs.metrics.inc("t.c")
        obs.metrics.inc("t.c", "a", 2)
        snap = obs.metrics.snapshot()["counters"]["t.c"]
        assert snap["total"] == 3
        assert snap["by_label"] == {"": 1, "a": 2}
        assert obs.metrics.counter_total("t.c") == 3
        assert obs.metrics.counter_total("never.touched") == 0

    def test_reset_clears_in_place(self):
        c = obs.metrics.counter("t.alias")
        c["k"] += 5
        obs.metrics.reset()
        assert obs.metrics.counter("t.alias") is c     # same object
        assert c.total_count() == 0

    def test_histogram_percentiles(self):
        for v in range(1, 101):
            obs.metrics.observe("t.h", float(v))
        h = obs.metrics.snapshot()["histograms"]["t.h"]
        assert h["count"] == 100 and h["min"] == 1 and h["max"] == 100
        assert 49 <= h["p50"] <= 52
        assert 98 <= h["p99"] <= 100

    def test_backward_lowerings_is_registry_counter(self):
        from repro.core import adjoint
        adjoint.reset_lowering_counts()
        adjoint.record_lowering("adj_test")
        assert adjoint.BACKWARD_LOWERINGS["adj_test"] == 1
        snap = obs.metrics.snapshot()["counters"]
        assert snap["adjoint.backward_lowerings"]["by_label"]["adj_test"] == 1
        obs.metrics.reset()
        assert adjoint.BACKWARD_LOWERINGS["adj_test"] == 0   # alias stays live


class TestEngineCounters:
    def test_launch_count_matches_calls(self):
        x = jnp.ones((8, 256), jnp.float32)
        base = obs.metrics.counter_total("engine.launch")
        for _ in range(3):
            ops.cumsum(x, impl="interpret")
        assert obs.metrics.counter_total("engine.launch") == base + 3
        assert obs.metrics.counter("engine.launch")["tpu:add"] >= 3

    def test_lowering_counts_compiles_not_calls(self):
        x = jnp.ones((8, 320), jnp.float32)      # unique shape → fresh compile
        c = obs.metrics.counter("engine.lowering")
        before = dict(c)
        ops.cumsum(x, impl="interpret")
        ops.cumsum(x, impl="interpret")          # second call: jit cache hit
        delta = c["tpu:scan"] - before.get("tpu:scan", 0)
        assert delta == 1, f"expected 1 compile, counted {delta}"

    def test_engine_spans_when_tracing(self):
        x = jnp.ones((8, 384), jnp.float32)
        with obs.tracing():
            ops.cumsum(x, impl="interpret")
            ops.cumsum(x, impl="interpret")
        names = [e["name"] for e in obs.trace.events()]
        assert names.count("engine.run_scan_plan") == 2   # per call
        assert names.count("engine.lower") == 1           # per compile
        (low,) = [e for e in obs.trace.events()
                  if e["name"] == "engine.lower"]
        assert low["args"]["backend"] == "tpu"
        assert low["args"]["plan"].startswith("scan-")
        assert low["args"]["model_cost"] > 0

    def test_backward_spans_when_tracing(self):
        x = jnp.ones((8, 256), jnp.float32)
        with obs.tracing():
            jax.grad(lambda v: ops.cumsum(v, impl="interpret").sum())(x)
        names = {e["name"] for e in obs.trace.events()}
        assert "ops.cumsum_bwd" in names


class TestDrift:
    def test_record_and_geomean(self):
        # two samples with ratios 2.0 and 8.0 → geomean 4.0
        obs.drift.record("sig-a", "tpu", "lanes", 10.0, 20.0)
        obs.drift.record("sig-a", "tpu", "lanes", 10.0, 80.0)
        (row,) = obs.drift.report()
        assert row["n"] == 2
        assert row["ratio_us_per_cyc"] == pytest.approx(4.0)
        assert row["min_ratio"] == pytest.approx(2.0)
        assert row["max_ratio"] == pytest.approx(8.0)
        # only cell of its backend → drift 1.0 against its own pool
        assert row["drift"] == pytest.approx(1.0)
        # log-space spread: exp(std([log2, log8])) = exp(log2) = 2
        assert row["spread_geo"] == pytest.approx(2.0, rel=1e-6)

    def test_backend_pooling_and_ranking(self):
        obs.drift.record("sig-a", "tpu", "lanes", 1.0, 4.0)    # ratio 4
        obs.drift.record("sig-b", "tpu", "lanes", 1.0, 1.0)    # ratio 1
        obs.drift.record("sig-c", "gpu", "lanes", 1.0, 7.0)
        rows = obs.drift.report()
        tpu = [r for r in rows if r["backend"] == "tpu"]
        assert all(r["backend_ratio"] == pytest.approx(2.0) for r in tpu)
        drifts = sorted(r["drift"] for r in tpu)
        assert drifts == [pytest.approx(0.5), pytest.approx(2.0)]
        # both tpu cells drift equally in |log|; the gpu cell not at all
        agg = obs.drift.aggregate()
        assert agg["gpu"]["max_drift"] == pytest.approx(1.0)
        assert agg["tpu"]["cells"] == 2 and agg["tpu"]["samples"] == 2
        assert agg["tpu"]["worst_signature"] in ("sig-a", "sig-b")

    def test_state_roundtrip_merge(self):
        obs.drift.record("sig-a", "tpu", "lanes", 1.0, 2.0, shape=(8, 128))
        doc = obs.drift.state()
        obs.drift.reset()
        assert obs.drift.report() == []
        assert obs.drift.load_state(doc) == 1
        obs.drift.record("sig-a", "tpu", "lanes", 1.0, 2.0)
        (row,) = obs.drift.report()
        assert row["n"] == 2 and row["last_shape"] == [8, 128]

    def test_ignores_nonpositive(self):
        obs.drift.record("s", "tpu", None, 0.0, 5.0)
        obs.drift.record("s", "tpu", None, 5.0, 0.0)
        assert obs.drift.report() == []

    def test_autotune_records_drift(self):
        tuning.clear_cache()
        x = jnp.ones((32, 256), jnp.float32)
        from repro.kernels import ssam_stencil2d
        from repro.kernels.stencils import BENCHMARKS
        sdef = BENCHMARKS["2d5pt"]
        plan = ssam_stencil2d.plan_for(sdef)
        runner = lambda cfg: tuning.measure_us(
            lambda: ops.stencil(x, sdef, impl="interpret",
                                **cfg.as_kwargs(plan)), reps=1)
        tuning.autotune(plan, x.shape, time_steps=1,
                        default=tuning.KernelConfig((8, 128)), runner=runner,
                        context=("test_obs_drift",))
        rows = obs.drift.report()
        assert rows, "measured autotune pass must feed the drift recorder"
        assert all(r["ratio_us_per_cyc"] > 0 for r in rows)
        assert {r["signature"] for r in rows} == {
            tuning.plan_signature(plan)}

    def test_report_cli_renders(self, tmp_path, capsys):
        from repro.obs import report
        obs.drift.record("sig-x", "tpu", "lanes", 1.0, 3.0, shape=(4, 128))
        path = tmp_path / "metrics.json"
        obs.metrics.export(str(path))
        assert report.main([str(path)]) == 0
        out = capsys.readouterr().out
        assert "sig-x" in out and "backend" in out
        assert "[tpu]" in out

    def test_report_empty(self, capsys):
        from repro.obs import report
        assert report.main(["--live"]) == 0
        assert "no model-vs-measured samples" in capsys.readouterr().out


class TestMeasureUs:
    def test_measurement_carries_spread(self):
        m = tuning.measure_us(lambda: jnp.zeros(8), reps=5)
        assert isinstance(m, float)
        assert m > 0 and m.reps == 5
        assert m.spread_us >= 0.0

    def test_reps_env_override(self, monkeypatch):
        monkeypatch.setenv(tuning.MEASURE_REPS_ENV, "7")
        m = tuning.measure_us(lambda: jnp.zeros(8))
        assert m.reps == 7
        monkeypatch.setenv(tuning.MEASURE_REPS_ENV, "not-a-number")
        assert tuning.measure_us(lambda: jnp.zeros(8)).reps == 3

    def test_plain_float_runner_still_legal(self):
        """Monkeypatched measure_us stand-ins return bare floats
        (test_sharded does); spread access must degrade, not crash."""
        us = 17.0
        assert getattr(us, "spread_us", None) is None


class TestSidecarV7:
    def test_spread_persisted_roundtrip(self, tmp_path):
        tuning.clear_cache()
        tuning.clear_sidecar()
        key = tuning._sidecar_key("sig-v7", (32, 256), 1, (), "auto", "tpu")
        tuning._SIDECAR[key] = (tuning.KernelConfig((8, 128)), 1.5, 42.0)
        tuning._SIDECAR_SPREAD[key] = 3.25
        path = tmp_path / "sidecar.json"
        tuning.save_sidecar(str(path))
        doc = json.loads(path.read_text())
        (entry,) = doc["entries"].values()
        assert entry["schema"] == tuning.ENGINE_SCHEMA_VERSION == 7
        assert entry["spread_us"] == 3.25
        tuning.clear_sidecar()
        assert tuning.load_sidecar(str(path)) == 1
        assert tuning._SIDECAR_SPREAD[key] == 3.25
        tuning.clear_sidecar()

    def test_stale_v6_dropped_on_load(self, tmp_path):
        tuning.clear_sidecar()
        path = tmp_path / "sidecar.json"
        path.write_text(json.dumps({"version": 1, "entries": {
            "stale-key": {"block": [8, 128], "variant": "shift_psum",
                          "strategy": None, "model_cost": 1.0,
                          "measured_us": 5.0, "schema": 6},
        }}))
        assert tuning.load_sidecar(str(path)) == 0
        assert "stale-key" not in tuning._SIDECAR
        assert obs.metrics.counter_total("tuner.sidecar_stale") == 1

    def test_checkpoint_entries_carry_spread(self):
        tuning.clear_sidecar()
        key = tuning._sidecar_key("sig-ck", (8, 128), 1, (), "auto", "tpu")
        tuning._SIDECAR[key] = (tuning.KernelConfig((8, 128)), 1.0, 9.0)
        tuning._SIDECAR_SPREAD[key] = 0.5
        entries = tuning.sidecar_entries()
        assert entries[key]["spread_us"] == 0.5
        tuning.clear_sidecar()
        assert tuning.merge_sidecar_entries(entries) == 1
        assert tuning._SIDECAR_SPREAD[key] == 0.5
        tuning.clear_sidecar()


class TestTunerCounters:
    def test_hit_miss_seed_accounting(self):
        tuning.clear_cache()
        tuning.clear_sidecar()
        obs.metrics.reset()
        from repro.kernels import ssam_stencil2d
        from repro.kernels.stencils import BENCHMARKS
        plan = ssam_stencil2d.plan_for(BENCHMARKS["2d5pt"])
        ctx = ("test_obs_tuner",)
        tuning.autotune(plan, (32, 256), context=ctx)       # model-only: miss
        assert obs.metrics.counter_total("tuner.sidecar_miss") == 1
        tuning.autotune(plan, (32, 256), context=ctx)       # replay: cache hit
        assert obs.metrics.counter_total("tuner.cache_hit") == 1
        assert obs.metrics.counter_total("tuner.sidecar_hit") == 0


class TestServeHistograms:
    @pytest.fixture(scope="class")
    def server_metrics(self):
        from repro.config import get_config
        from repro.launch.serve import DecodeServer, Request
        from repro.models import build_model
        from repro.nn.spec import init_params

        obs.metrics.reset()
        cfg = get_config("gemma3_1b", smoke=True)
        model = build_model(cfg)
        params = init_params(model.specs(), jax.random.PRNGKey(0))
        server = DecodeServer(model, params, slots=2, cache_len=32)
        rng = np.random.default_rng(0)
        reqs = [Request(i, rng.integers(0, cfg.vocab, 4, dtype=np.int32), 3)
                for i in range(3)]
        done = server.run(reqs)
        return len(done), obs.metrics.snapshot()

    def test_request_latency_p50_p99(self, server_metrics):
        n_done, snap = server_metrics
        h = snap["histograms"]["serve.request_us"]
        assert h["count"] == n_done == 3
        assert 0 < h["p50"] <= h["p99"] <= h["max"]
        assert h["min"] > 0
        assert snap["counters"]["serve.requests"]["total"] == n_done

    def test_step_latency_histogram(self, server_metrics):
        _, snap = server_metrics
        h = snap["histograms"]["serve.step_us"]
        assert h["count"] >= 3                 # ≥ tokens decoded per request
        assert 0 < h["p50"] <= h["max"]
