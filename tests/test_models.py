"""Per-architecture smoke tests (assignment requirement): reduced config,
one forward/train step on CPU, output shapes + no NaNs — plus
prefill↔decode consistency for every family's cache/state machinery.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ARCHS, get_config
from repro.models import build_model
from repro.nn.spec import abstract_params, init_params, param_count
from repro.optim import adamw_state_specs, adamw_update


def make_batch(cfg, B=2, S=24, seed=7):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    batch = {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(k3, (B, cfg.n_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(k3, (B, cfg.n_prefix, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def smoke_models():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        params = init_params(model.specs(), jax.random.PRNGKey(0))
        out[arch] = (cfg, model, params)
    return out


@pytest.mark.parametrize("arch", ARCHS)
class TestSmoke:
    def test_forward_loss_finite(self, smoke_models, arch):
        cfg, model, params = smoke_models[arch]
        loss = jax.jit(model.loss)(params, make_batch(cfg))
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), arch
        # init loss ≈ ln(vocab) for a calibrated readout
        assert abs(float(loss) - np.log(cfg.vocab)) < 1.5, float(loss)

    def test_train_step_updates_and_finite(self, smoke_models, arch):
        cfg, model, params = smoke_models[arch]
        ospecs = adamw_state_specs(model.specs())
        opt = init_params(ospecs, jax.random.PRNGKey(1))
        batch = make_batch(cfg)

        @jax.jit
        def step(p, o, b):
            loss, g = jax.value_and_grad(model.loss)(p, b)
            p2, o2, gn = adamw_update(p, g, o, lr=1e-3)
            return p2, o2, loss, gn

        p2, o2, loss, gnorm = step(params, opt, batch)
        assert bool(jnp.isfinite(loss)) and bool(jnp.isfinite(gnorm))
        # params actually moved
        moved = jax.tree.reduce(
            lambda acc, ab: acc + float(jnp.abs(ab).max()),
            jax.tree.map(lambda a, b: a - b, params, p2), 0.0)
        assert moved > 0.0
        finite = jax.tree.map(lambda x: bool(jnp.all(jnp.isfinite(x))), p2)
        assert all(jax.tree.leaves(finite)), arch

    def test_serve_step_shapes(self, smoke_models, arch):
        cfg, model, params = smoke_models[arch]
        B, CL = 2, 32
        state = init_params(model.decode_state_specs(B, CL), jax.random.PRNGKey(2))
        tokens = jnp.zeros((B, 1), jnp.int32)
        logits, new_state = jax.jit(model.serve_step)(params, state, tokens,
                                                      jnp.int32(0))
        assert logits.shape == (B, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        # state structure preserved
        assert jax.tree_util.tree_structure(state) == \
            jax.tree_util.tree_structure(new_state)


@pytest.mark.parametrize("arch", ["stablelm_12b", "gemma3_1b", "hymba_1g5b",
                                  "rwkv6_1g6b", "deepseek_v2_236b"])
def test_prefill_decode_consistency(smoke_models, arch):
    """Decoding token-by-token reproduces the full-sequence forward —
    validates every cache/recurrent-state path end to end."""
    cfg, model, params = smoke_models[arch]
    if cfg.moe:
        # capacity-dropping MoE routes differently at different batch
        # shapes by design — test with drop-free capacity
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
        model = build_model(cfg)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    if cfg.family == "vlm":
        pytest.skip("prefix handling covered in full-forward test")
    x_full, _ = model.forward(params, batch)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["w"].T)
    logits_full = x_full[:, -1] @ table.T

    state = init_params(model.decode_state_specs(B, S + 4), jax.random.PRNGKey(3))
    step = jax.jit(model.serve_step)
    for t in range(S):
        logits, state = step(params, state, batch["tokens"][:, t:t + 1],
                             jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_full),
                               rtol=2e-2, atol=2e-2)


def test_vector_index_decode_matches_scalar(smoke_models):
    """Per-slot (continuous batching) indices == scalar lockstep when equal."""
    cfg, model, params = smoke_models["gemma3_1b"]
    B = 2
    state = init_params(model.decode_state_specs(B, 16), jax.random.PRNGKey(0))
    tok = jnp.array([[3], [5]], jnp.int32)
    l1, s1 = jax.jit(model.serve_step)(params, state, tok, jnp.int32(4))
    l2, s2 = jax.jit(model.serve_step)(params, state, tok,
                                       jnp.array([4, 4], jnp.int32))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4,
                               atol=1e-4)


def test_whisper_conv_frontend():
    """The real mel conv stem (SSAM engine reduce-axes plan) trains end
    to end: finite loss, gradients reach the conv filters, and the
    engine-lowered frontend matches the XLA oracle path."""
    from repro.configs.whisper_base import SMOKE_CONV
    from repro.models.whisper import Whisper

    model = Whisper(SMOKE_CONV)
    params = init_params(model.specs(), jax.random.PRNGKey(0))
    assert "frontend" in params
    inp, _ = model.train_inputs(2, 8)
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    batch = {
        "mel": jax.random.normal(k1, inp["mel"].shape, inp["mel"].dtype),
        "tokens": jax.random.randint(k2, (2, 8), 0, SMOKE_CONV.vocab),
        "labels": jax.random.randint(k2, (2, 8), 0, SMOKE_CONV.vocab),
    }
    loss, g = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = float(jnp.linalg.norm(g["frontend"]["conv1"]["w"]))
    assert np.isfinite(gnorm) and gnorm > 0.0
    f_xla = model.frontend(params["frontend"], batch["mel"], impl="xla")
    f_eng = model.frontend(params["frontend"], batch["mel"],
                           impl="interpret")
    assert f_eng.shape == (2, SMOKE_CONV.n_frames, SMOKE_CONV.d_model)
    np.testing.assert_allclose(np.asarray(f_eng), np.asarray(f_xla),
                               rtol=3e-5, atol=3e-5)


def test_exact_param_counts():
    """The full configs reproduce the published parameter counts."""
    expect = {
        "rwkv6_1g6b": (1.4, 1.7), "stablelm_12b": (11.5, 12.5),
        "chatglm3_6b": (5.9, 6.5), "gemma3_1b": (0.9, 1.1),
        "starcoder2_3b": (2.8, 3.3), "dbrx_132b": (125, 136),
        "deepseek_v2_236b": (230, 243), "hymba_1g5b": (1.4, 1.75),
        "internvl2_1b": (0.4, 0.55), "whisper_base": (0.06, 0.12),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        n = param_count(build_model(cfg).specs()) / 1e9
        assert lo <= n <= hi, (arch, n)


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3_1b")
    g = np.asarray(cfg.is_global_layers())
    assert g.sum() == 4                      # 26 layers, every 6th global
    assert g[5] and g[11] and g[17] and g[23]
    assert not g[0] and not g[4]


def test_hymba_global_layers():
    cfg = get_config("hymba_1g5b")
    g = np.asarray(cfg.is_global_layers())
    assert g[0] and g[15] and g[31] and g.sum() == 3


def test_moe_matches_dense_oracle():
    """Sort-based dispatch == per-token dense top-k mixture (no drops)."""
    from repro.nn import moe as M
    from repro.nn.spec import init_params as ip
    p = ip(M.moe_specs(16, 32, 4), jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
    out, aux = M.moe_apply(p, x, top_k=2, capacity_factor=4.0)
    xf = x.reshape(-1, 16)
    probs = jax.nn.softmax((xf @ p["router"]).astype(jnp.float32), -1)
    g, idx = jax.lax.top_k(probs, 2)
    g = g / g.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xf)
    for t in range(xf.shape[0]):
        for k in range(2):
            e = int(idx[t, k])
            h = jax.nn.silu(xf[t] @ p["w_gate"][e]) * (xf[t] @ p["w_up"][e])
            ref = ref.at[t].add(g[t, k] * (h @ p["w_down"][e]))
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 16)),
                               np.asarray(ref), rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With tight capacity some assignments drop; output = partial mixture
    (never NaN, never the full mixture)."""
    from repro.nn import moe as M
    from repro.nn.spec import init_params as ip
    p = ip(M.moe_specs(16, 32, 2), jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 16))
    tight, _ = M.moe_apply(p, x, top_k=2, capacity_factor=0.25)
    loose, _ = M.moe_apply(p, x, top_k=2, capacity_factor=4.0)
    assert bool(jnp.all(jnp.isfinite(tight)))
    assert float(jnp.abs(tight - loose).max()) > 1e-3
