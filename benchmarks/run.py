"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the repo convention.

| function                  | paper analogue | what is measured here            |
|---------------------------|----------------|----------------------------------|
| bench_conv2d_filter_sweep | Fig. 4         | CPU wall-time: XLA direct conv vs SSAM systolic schedule (jit'd roll form); TPU perf-model Dif (Eq. 5) |
| bench_stencil_suite       | Table 3/Fig. 5 | GCells/s, jnp shift-add reference vs SSAM schedule |
| bench_temporal_blocking   | Fig. 6         | fused t-step stencil vs t separate steps |
| bench_perf_model          | Table 2/§5     | hardware latency tables, L_smem/L_reg/AvgDif, halo ratios |
| bench_scan                | §3.6           | Kogge–Stone cumsum / linear recurrence vs lax reference |
| bench_sharded (--mesh AxB)| (beyond paper) | sharded halo-exchange vs single device: per-device bandwidth + §5 scaling prediction |
| bench_grad (--grad)       | (beyond paper) | fwd vs fwd+bwd through the adjoint plans, vs §5 fwd+adjoint cost |
| bench_fused (--fused)     | (beyond paper) | fused plan pipelines + epilogues vs the unfused HBM-round-trip sequence (stencil chain, Whisper stem) |
| bench_scan_chunked (--scan-chunked) | (beyond paper) | chunk-streamed engine scans vs monolithic engine vs XLA chunked: tokens/sec + peak temp memory at long T |
| bench_strategy (--strategy) | §5 + (beyond paper) | lanes (VPU shift-fma) vs mxu (im2row matmul) lowering per shape class: MB/s both ways, the tuner's pick, and §5 predicted-vs-measured ranking agreement |
| bench_backend (--backend) | §4 + (beyond paper) | TPU lane-roll vs GPU warp-shift lowering of the same plans: per-backend MB/s + each backend's machine-model prediction |
| bench_obs (--obs)         | §5 + (beyond paper) | telemetry readout: tuner sidecar hit-rates, engine launch/recompile counts, per-backend model-vs-measured drift aggregates |
| bench_chaos (--chaos)     | (beyond paper) | guarded execution under injected faults: idle-guard overhead (< 1%), fallback vs engine MB/s at fault prob 0/0.5/1.0 with demotion counts, decode-server survival under step faults |
| bench_lm_roofline         | (assignment)   | summary of dry-run roofline artifacts |

``--json PATH`` additionally writes every row as machine-readable JSON
(name, µs, parsed derived fields + run metadata) — the committed
``BENCH_5.json`` perf-trajectory artifact comes from
``--fused --json BENCH_5.json``, ``BENCH_6.json`` from
``--scan-chunked --json BENCH_6.json``, ``BENCH_7.json`` from
``--strategy auto --json BENCH_7.json``, ``BENCH_8.json`` from
``--backend auto --json BENCH_8.json``, ``BENCH_9.json`` from
``--obs --json BENCH_9.json`` (with ``--trace``/``--metrics`` sidecars)
and ``BENCH_10.json`` from ``--chaos --json BENCH_10.json``.

The container is CPU-only: wall-times are CPU XLA numbers that compare
*schedules*, not TPU performance; TPU performance is reported by the
roofline pipeline (artifacts → benchmarks/roofline.py → EXPERIMENTS.md).
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, reps: int = 3) -> float:
    """Median wall-time (µs) of a jitted call, post-warmup."""
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


_JSON_ROWS: list | None = None     # set by main() when --json is given


def _parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        if not part or "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v.rstrip("x").rstrip("cyc").rstrip("pct"))
        except ValueError:
            out[k] = v
    return out


def _row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
    if _JSON_ROWS is not None:
        _JSON_ROWS.append({"name": name, "us_per_call": round(us, 2),
                           "derived": _parse_derived(derived)})


def _git_sha() -> str | None:
    import subprocess
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5, cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except Exception:
        return None


def _write_json(path: str) -> None:
    from repro.core.tuning import ENGINE_SCHEMA_VERSION
    doc = {
        "meta": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            # provenance: which code produced these numbers — a BENCH_N
            # row is only comparable to another measured at the same
            # engine schema (winners mean different kernels otherwise)
            "git_sha": _git_sha(),
            "engine_schema_version": ENGINE_SCHEMA_VERSION,
            "jax_version": jax.__version__,
            "note": "CPU interpret-mode wall-times compare schedules, "
                    "not TPU performance",
        },
        "rows": _JSON_ROWS,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {len(_JSON_ROWS)} rows to {path}")


# ---------------------------------------------------------------------------
# Fig. 4 — 2-D convolution, filter sizes 2×2 … 20×20
# ---------------------------------------------------------------------------

def bench_conv2d_filter_sweep(img: int = 256):
    from repro.core import conv2d_plan
    from repro.core.executor import execute_conv_global
    from repro.core.perfmodel import TPU_V5E, V100, dif_smem_reg
    from repro.kernels import ref

    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((img, img)), jnp.float32)
    print("# Fig4: 2D convolution filter sweep "
          f"(image {img}x{img}, CPU wall-time)")
    for m in (2, 3, 5, 7, 9, 13):   # (17/20 compile too slowly on CPU-XLA; model values in bench_perf_model)
        w = jnp.array(rng.standard_normal((m, m)), jnp.float32)
        direct = jax.jit(ref.conv2d_valid)
        plan = conv2d_plan(m, m, S=img, P=1)
        ssam = jax.jit(lambda xx, ww: execute_conv_global(plan, xx, ww))
        t_direct = _timeit(direct, x, w)
        t_ssam = _timeit(ssam, x, w)
        model_dif_v100 = dif_smem_reg(V100, m, m)
        model_dif_tpu = dif_smem_reg(TPU_V5E, m, m)
        cells = (img - m + 1) ** 2
        _row(f"conv2d_direct_{m}x{m}", t_direct,
             f"gcells_s={cells / t_direct / 1e3:.2f}")
        _row(f"conv2d_ssam_{m}x{m}", t_ssam,
             f"gcells_s={cells / t_ssam / 1e3:.2f};"
             f"dif_v100={model_dif_v100:.0f}cyc;dif_tpu={model_dif_tpu:.0f}cyc")


# ---------------------------------------------------------------------------
# Batched NCHW convolution through the reduce-axes engine (--batch/--channels)
# ---------------------------------------------------------------------------

def bench_conv2d_batched(batch: int = 4, channels: tuple[int, int] = (3, 8),
                         img: int = 64, filters: tuple[int, ...] = (3, 5)):
    """NCHW minibatch conv: engine reduce-axes plan vs XLA direct conv.

    Reports per-image achieved bandwidth (useful traffic: one f32 read
    of the C_in planes + one write of the C_out planes per image) next
    to the §5 model's predicted cycles per output element — the
    per-channel-iterate ``model_cost`` times ``C_in``, since the
    channel reduction runs the tap group once per input channel.
    Interpret-mode wall-times compare schedules, not TPU performance.
    """
    from repro.core import conv2d_nchw_plan, tuning
    from repro.kernels import ops, ref

    C_in, C_out = channels
    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((batch, C_in, img, img)), jnp.float32)
    print(f"# NCHW conv2d: batch={batch} channels={C_in}->{C_out} "
          f"image {img}x{img} (interpret-mode wall-time)")
    for fs in filters:
        w = jnp.array(rng.standard_normal((C_out, C_in, fs, fs)), jnp.float32)
        t_xla = _timeit(jax.jit(lambda a, b: ref.conv2d_nchw(a, b, "same")),
                        x, w)
        t_eng = _timeit(lambda: ops.conv2d(x, w, impl="interpret"))
        plan = conv2d_nchw_plan(batch, C_in, C_out, fs, fs, mode="same")
        base = tuning.KernelConfig(tuple(min(b, img) for b in (8, 128)))
        # §5 prediction: per-output cycles = C_in channel iterates of the
        # per-iterate block cost (the tap-group cost of one reduce step).
        cyc = tuning.model_cost(plan, base) * C_in
        # useful traffic per image (bytes/µs = MB/s; batch cancels out of
        # the per-image rate, so it never enters the expression)
        bytes_per_img = (C_in + C_out) * img * img * 4
        _row(f"conv2d_nchw_xla_{fs}x{fs}", t_xla,
             f"mb_s_per_img={bytes_per_img / max(t_xla, 1e-9):.2f}")
        _row(f"conv2d_nchw_engine_{fs}x{fs}", t_eng,
             f"mb_s_per_img={bytes_per_img / max(t_eng, 1e-9):.2f};"
             f"model_cyc={cyc:.1f};xla_ratio={t_eng / t_xla:.2f}x")


# ---------------------------------------------------------------------------
# Table 3 / Fig. 5 — stencil suite
# ---------------------------------------------------------------------------

def bench_stencil_suite(size2d: int = 384, size3d: int = 40):
    from repro.kernels import ref
    from repro.kernels.stencils import BENCHMARKS

    rng = np.random.default_rng(0)
    print(f"# Table3/Fig5: stencil suite (2D {size2d}^2, 3D {size3d}^3, "
          "CPU wall-time)")
    for name, sdef in BENCHMARKS.items():
        if sdef.ndim == 2:
            x = jnp.array(rng.standard_normal((size2d, size2d)), jnp.float32)
        else:
            x = jnp.array(rng.standard_normal((size3d,) * 3), jnp.float32)
        fn = jax.jit(lambda xx, s=sdef: ref.stencil_iterate(xx, s, 1))
        t = _timeit(fn, x)
        cells = x.size
        _row(f"stencil_{name}", t,
             f"gcells_s={cells / t / 1e3:.3f};"
             f"gflops_s={cells * sdef.fpp / t / 1e3:.2f};fpp={sdef.fpp}")


# ---------------------------------------------------------------------------
# Fig. 6 — temporal blocking
# ---------------------------------------------------------------------------

def bench_temporal_blocking(size: int = 384):
    from repro.kernels import ref
    from repro.kernels.stencils import BENCHMARKS

    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((size, size)), jnp.float32)
    print("# Fig6: temporal blocking (fused t steps in one program vs t "
          "separate launches)")
    for name in ("2d5pt", "2d9pt", "3d7pt"):
        sdef = BENCHMARKS[name]
        if sdef.ndim == 3:
            xx = jnp.array(rng.standard_normal((48, 48, 48)), jnp.float32)
        else:
            xx = x
        for t_steps in (1, 2, 4):
            fused = jax.jit(lambda v, s=sdef, n=t_steps: ref.stencil_iterate(v, s, n))
            single = jax.jit(lambda v, s=sdef: ref.stencil_iterate(v, s, 1))

            def unfused(v):
                for _ in range(t_steps):
                    v = single(v)
                return v

            tf = _timeit(fused, xx)
            tu = _timeit(unfused, xx)
            cells = xx.size * t_steps
            _row(f"temporal_{name}_t{t_steps}_fused", tf,
                 f"gcells_s={cells / tf / 1e3:.3f}")
            _row(f"temporal_{name}_t{t_steps}_unfused", tu,
                 f"gcells_s={cells / tu / 1e3:.3f};speedup={tu / tf:.2f}x")


# ---------------------------------------------------------------------------
# Table 2 / §5 — analytical performance model
# ---------------------------------------------------------------------------

def bench_perf_model():
    from repro.core import conv2d_plan
    from repro.core.perfmodel import (P100, TPU_V5E, V100,
                                      avg_dif_lower_bound, dif_smem_reg,
                                      l_reg, l_smem)

    print("# Table2/§5: analytical model (cycles; paper-measured GPU "
          "latencies + TPU estimates)")
    for hw in (P100, V100, TPU_V5E):
        _row(f"latency_{hw.name}_shfl", hw.t_shfl, "cycles")
        _row(f"latency_{hw.name}_mad", hw.t_mad, "cycles")
        _row(f"latency_{hw.name}_smem_read", hw.t_smem_read, "cycles")
    for m in (3, 5, 9, 20):
        for hw in (V100, TPU_V5E):
            _row(f"model_{hw.name}_L_smem_{m}x{m}", l_smem(hw, m, m), "cycles")
            _row(f"model_{hw.name}_L_reg_{m}x{m}", l_reg(hw, m, m),
                 f"dif={dif_smem_reg(hw, m, m):.0f}cyc")
    for S in (32, 128):
        plan = conv2d_plan(5, 5, S=S, P=4)
        _row(f"halo_ratio_S{S}_5x5_P4", plan.halo_ratio() * 100,
             f"paper_bound={plan.halo_ratio_paper_bound() * 100:.1f}pct;"
             f"avgdif_v100={avg_dif_lower_bound(V100, plan):.0f}cyc")


# ---------------------------------------------------------------------------
# §3.6 — scan operator
# ---------------------------------------------------------------------------

def bench_scan(rows: int = 64, T: int = 8192):
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((rows, T)), jnp.float32)
    a = jnp.array(rng.uniform(0.5, 1.0, (rows, T)), jnp.float32)
    print(f"# §3.6 scan: ({rows}, {T}) CPU wall-time")
    t_ref = _timeit(jax.jit(ref.cumsum), x)
    _row("cumsum_ref", t_ref, f"gelem_s={x.size / t_ref / 1e3:.3f}")
    t_seq = _timeit(jax.jit(ref.linear_recurrence), a, x)
    _row("linrec_sequential", t_seq, f"gelem_s={x.size / t_seq / 1e3:.3f}")
    ck = jax.jit(lambda aa, bb: ops.chunked_linear_recurrence(aa, bb, chunk=128))
    t_ck = _timeit(ck, a, x)
    _row("linrec_chunked_ssam", t_ck,
         f"gelem_s={x.size / t_ck / 1e3:.3f};speedup={t_seq / t_ck:.1f}x")
    xs = x[:, :1024]
    t_sat = _timeit(jax.jit(ref.sat), xs)
    _row("sat_ref_64x1024", t_sat, f"gelem_s={xs.size / t_sat / 1e3:.3f}")


# ---------------------------------------------------------------------------
# Chunk-streamed engine scans: O(chunk) memory at long T (--scan-chunked)
# ---------------------------------------------------------------------------

def _temp_bytes(fn, *args) -> int:
    """Peak temp allocation of the compiled computation (XLA cost
    analysis); -1 when the backend does not report one."""
    try:
        ma = jax.jit(fn).lower(*args).compile().memory_analysis()
        return int(getattr(ma, "temp_size_in_bytes", -1))
    except Exception:
        return -1


def bench_scan_chunked(rows: int = 8, T: int = 4096, chunk: int = 128):
    """Chunk-streamed engine scans vs the monolithic engine and the XLA
    chunked baseline (DESIGN.md §12) — the BENCH_6 artifact.

    Three comparisons, each with fwd and fwd+bwd wall-time, tokens/sec
    and the compiled computation's peak temp allocation:

    * ``chunked_linear_recurrence`` on ``(rows, T)``: impl='engine'
      (the (R, chunk)-slab ``lax.scan`` stream with checkpointed
      backward — O(R·chunk) live state) vs 'engine_unchunked' (the
      monolithic O(T) engine lowering) vs 'chunked' (the non-engine XLA
      schedule).
    * a Mamba selective-scan train step (grad of a scalar loss) over
      increasing T — the tokens/sec + peak-memory *trajectory*;
    * the same trajectory for the RWKV6 WKV recurrence.

    Interpret-mode wall-times compare schedules, not TPU performance;
    the memory column is the schedule property the tentpole is about.
    """
    from repro.kernels import ops
    from repro.nn import ssm

    rng = np.random.default_rng(0)
    a = jnp.array(rng.uniform(0.5, 1.0, (rows, T)), jnp.float32)
    b = jnp.array(rng.standard_normal((rows, T)), jnp.float32)
    print(f"# §12 chunk-streamed scans: linrec ({rows}, {T}) chunk={chunk}; "
          "Mamba/RWKV train-step trajectories (interpret-mode wall-time)")
    for impl in ("engine", "engine_unchunked", "chunked"):
        fwd = lambda aa, bb, _i=impl: ops.chunked_linear_recurrence(
            aa, bb, chunk=chunk, impl=_i)
        loss = lambda aa, bb, _i=impl: jnp.sum(fwd(aa, bb, _i=_i) ** 2)
        grad = jax.jit(jax.grad(loss, (0, 1)))
        t_f = _timeit(jax.jit(fwd), a, b)
        t_g = _timeit(grad, a, b)
        mb_f = _temp_bytes(fwd, a, b)
        mb_g = _temp_bytes(jax.grad(loss, (0, 1)), a, b)
        _row(f"scanchunk_linrec_{impl}_fwd", t_f,
             f"tok_s={rows * T / max(t_f, 1e-9) * 1e6:.0f};"
             f"temp_bytes={mb_f}")
        _row(f"scanchunk_linrec_{impl}_fwdbwd", t_g,
             f"tok_s={rows * T / max(t_g, 1e-9) * 1e6:.0f};"
             f"temp_bytes={mb_g}")

    # Train-step trajectories: tokens/sec + peak temp memory vs T.
    # 'engine' is the streamed schedule; 'chunked' the non-engine
    # baseline; the monolithic engine only at the shortest T (its O(T)
    # state is the thing the stream removes).
    Bsz, Di, N = 1, 4, 8
    H, K, V = 2, 4, 4
    for Tm in (256, 512, 1024):
        delta = jnp.array(rng.uniform(0.1, 0.4, (Bsz, Tm, Di)), jnp.float32)
        A_log = jnp.array(-rng.uniform(0.5, 1.5, (Di, N)), jnp.float32)
        Bm = jnp.array(rng.standard_normal((Bsz, Tm, N)), jnp.float32)
        Cm = jnp.array(rng.standard_normal((Bsz, Tm, N)), jnp.float32)
        xm = jnp.array(rng.standard_normal((Bsz, Tm, Di)), jnp.float32)
        for impl in ("engine", "chunked") + (
                ("engine_unchunked",) if Tm == 256 else ()):
            loss = lambda d, x_, _i=impl: jnp.sum(ssm.selective_scan(
                d, A_log, Bm, Cm, x_, chunk=64, impl=_i)[0] ** 2)
            grad = jax.jit(jax.grad(loss, (0, 1)))
            t_g = _timeit(grad, delta, xm)
            mb_g = _temp_bytes(jax.grad(loss, (0, 1)), delta, xm)
            _row(f"scanchunk_mamba_{impl}_T{Tm}", t_g,
                 f"tok_s={Bsz * Tm / max(t_g, 1e-9) * 1e6:.0f};"
                 f"temp_bytes={mb_g}")
        r = jnp.array(rng.standard_normal((Bsz, Tm, H, K)), jnp.float32)
        k = jnp.array(rng.standard_normal((Bsz, Tm, H, K)), jnp.float32)
        v = jnp.array(rng.standard_normal((Bsz, Tm, H, V)), jnp.float32)
        logw = jnp.array(-rng.uniform(0.05, 0.5, (Bsz, Tm, H, K)),
                         jnp.float32)
        u = jnp.array(rng.standard_normal((H, K)), jnp.float32)
        for impl in ("engine", "chunked") + (
                ("engine_unchunked",) if Tm == 256 else ()):
            loss = lambda rr, vv, _i=impl: jnp.sum(ssm.wkv6_chunked(
                rr, k, vv, logw, u, chunk=64, impl=_i)[0] ** 2)
            grad = jax.jit(jax.grad(loss, (0, 1)))
            t_g = _timeit(grad, r, v)
            mb_g = _temp_bytes(jax.grad(loss, (0, 1)), r, v)
            _row(f"scanchunk_rwkv_{impl}_T{Tm}", t_g,
                 f"tok_s={Bsz * Tm / max(t_g, 1e-9) * 1e6:.0f};"
                 f"temp_bytes={mb_g}")


# ---------------------------------------------------------------------------
# Autotuner: tuned vs default block configs for the Table 3 suite
# ---------------------------------------------------------------------------

def bench_autotune(size2d: int = 192, size3d: int = 32):
    """Tuned vs default engine configs (µs + §5 model cost) per stencil.

    The tuner measures its model's top candidates *and* the default, so
    ``speedup`` is ≥ ~1.0 up to timer noise. Sizes are kept modest: the
    interpret-mode Pallas kernels this container can run are far slower
    than compiled Mosaic, and the point here is config selection, not
    absolute throughput.
    """
    from repro.core import tuning
    from repro.kernels import ops
    from repro.kernels import ssam_stencil2d, ssam_stencil3d
    from repro.kernels.stencils import BENCHMARKS

    rng = np.random.default_rng(0)
    print(f"# Autotune: tuned vs default block configs (2D {size2d}^2, "
          f"3D {size3d}^3, interpret-mode wall-time)")
    for name, sdef in BENCHMARKS.items():
        if sdef.ndim == 2:
            x = jnp.array(rng.standard_normal((size2d, size2d)), jnp.float32)
            mod, default = ssam_stencil2d, tuning.KernelConfig((8, 128))
        else:
            x = jnp.array(rng.standard_normal((size3d,) * 3), jnp.float32)
            mod, default = ssam_stencil3d, tuning.KernelConfig((4, 8, 128))
        plan = mod.plan_for(sdef)
        t_default = tuning.measure_us(
            lambda: ops.stencil(x, sdef, impl="interpret",
                                **default.as_kwargs(plan)))
        runner = lambda cfg: tuning.measure_us(
            lambda: ops.stencil(x, sdef, impl="interpret",
                                **cfg.as_kwargs(plan)))
        t0 = time.perf_counter()
        tuned = tuning.autotune(plan, x.shape, default=default, runner=runner)
        tune_s = time.perf_counter() - t0
        cfg = tuned.config
        t_tuned = tuning.measure_us(
            lambda: ops.stencil(x, sdef, impl="interpret",
                                **cfg.as_kwargs(plan)))
        dif = (tuning.model_cost(plan, default)
               - tuning.model_cost(plan, cfg))
        _row(f"autotune_{name}_default", t_default,
             f"cfg={'x'.join(map(str, default.block))}")
        _row(f"autotune_{name}_tuned", t_tuned,
             f"cfg={'x'.join(map(str, cfg.block))};variant={cfg.variant};"
             f"model_dif={dif:.1f}cyc;speedup={t_default / t_tuned:.2f}x;"
             f"tune_cost_s={tune_s:.1f}")


# ---------------------------------------------------------------------------
# Sharded halo-exchange: per-device bandwidth vs the §5 model (--mesh AxB)
# ---------------------------------------------------------------------------

def bench_sharded(mesh_shape: tuple[int, ...], size2d: int = 256,
                  size3d: int = 32, time_steps: int = 1):
    """Sharded vs single-device engine wall-time on an ``AxB`` host mesh.

    Reports per-device *achieved* bandwidth (8 bytes per cell per step:
    one f32 read + one write of useful traffic) next to the §5 model's
    per-element cost for the shard-local halo-extended block — whose
    ratio to the single-device cost is the model's predicted scaling
    efficiency (the halo a shard re-loads is exactly the §5.3
    redundancy term evaluated at the shard size).
    """
    import math as _math

    from repro.core import tuning
    from repro.kernels import ops
    from repro.kernels import ssam_stencil2d, ssam_stencil3d
    from repro.launch.mesh import make_domain_mesh
    from repro.kernels.stencils import BENCHMARKS

    ndev = _math.prod(mesh_shape)
    if jax.device_count() < ndev:
        print(f"# sharded: need {ndev} devices, have {jax.device_count()} — "
              "set XLA_FLAGS=--xla_force_host_platform_device_count="
              f"{ndev} (or run on a {ndev}-chip mesh)")
        return
    mesh = make_domain_mesh(mesh_shape)
    rng = np.random.default_rng(0)
    print(f"# Sharded halo exchange on {'x'.join(map(str, mesh_shape))} mesh "
          f"(2D {size2d}^2, 3D {size3d}^3, t={time_steps}, interpret-mode "
          "wall-time; CPU numbers compare schedules, not TPU perf)")
    for name in ("2d5pt", "2d9pt", "2ds25pt", "2d121pt", "3d7pt", "poisson"):
        sdef = BENCHMARKS[name]
        from repro.distributed import halo_exchange as hx
        if sdef.ndim == 2:
            x = jnp.array(rng.standard_normal((size2d, size2d)), jnp.float32)
            mod = ssam_stencil2d
        else:
            x = jnp.array(rng.standard_normal((size3d,) * 3), jnp.float32)
            mod = ssam_stencil3d
        plan = mod.plan_for(sdef)
        # Resolve the layout exactly the way the timed call will (the
        # rule-table default spec), so the reported geometry describes
        # the run that is measured.
        spec = hx.default_domain_spec(x.shape, mesh)
        per_axis = hx._axis_assignments(spec, mesh, plan.ndim_spatial)
        try:
            shard_shape = tuning.shard_tuning_shape(
                plan, x.shape, per_axis, time_steps)
        except ValueError as e:
            _row(f"sharded_{name}", 0.0, f"skipped={e}")
            continue
        t_single = _timeit(
            lambda: ops.stencil(x, sdef, time_steps=time_steps,
                                impl="interpret"))
        t_shard = _timeit(
            lambda: ops.stencil(x, sdef, time_steps=time_steps,
                                impl="interpret", mesh=mesh))
        from repro.core.halo import check_shard_geometry
        local = check_shard_geometry(plan, x.shape, tuple(per_axis),
                                     time_steps)
        base = (8, 128) if sdef.ndim == 2 else (4, 8, 128)
        # §5 prediction: the same default schedule, block clamped to the
        # global vs the shard-local extent — the shard's smaller lane
        # tile amortizes less halo (§5.3), which is the model's whole
        # forecast of sharding overhead.
        cyc_single = tuning.model_cost(plan, tuning.KernelConfig(
            tuple(min(b, n) for b, n in zip(base, x.shape))), time_steps)
        cyc_shard = tuning.model_cost(plan, tuning.KernelConfig(
            tuple(min(b, n) for b, n in zip(base, local))), time_steps)
        bytes_useful = x.size * 8 * time_steps
        mbs_dev = bytes_useful / max(t_shard, 1e-9) / ndev   # bytes/µs = MB/s
        mbs_single = bytes_useful / max(t_single, 1e-9)
        _row(f"sharded_{name}_single", t_single,
             f"mb_s={mbs_single:.2f};model_cyc={cyc_single:.1f}")
        _row(f"sharded_{name}_{'x'.join(map(str, mesh_shape))}", t_shard,
             f"mb_s_per_dev={mbs_dev:.2f};model_cyc={cyc_shard:.1f};"
             f"pred_eff={cyc_single / cyc_shard:.2f};"
             f"speedup={t_single / t_shard:.2f}x;"
             f"shard={'x'.join(map(str, shard_shape))}")


# ---------------------------------------------------------------------------
# Adjoint plans: fwd+bwd bandwidth vs the §5 model (--grad)
# ---------------------------------------------------------------------------

def bench_grad(size2d: int = 128, size3d: int = 24,
               batch: int = 2, channels: tuple[int, int] = (3, 8),
               img: int = 48):
    """Forward vs forward+backward wall-time per engine op, next to the
    §5 model's prediction that bwd ≈ fwd + the adjoint plan's cost.

    Table-3 stencils differentiate through the point-reflected adjoint
    plan (backward-input only — 'table' coefficients have no weight
    grad); NCHW conv adds the backward-weight correlation, whose cost
    the model approximates by a second forward sweep (it reads the same
    x volume once more against the cotangent). MB/s counts useful
    traffic: fwd = read+write of the domain; fwd+bwd = 3× (forward,
    cotangent in, input-grad out) per step. Interpret-mode wall-times
    compare schedules, not TPU performance.
    """
    import jax

    from repro.core import adjoint as adjoint_mod
    from repro.core import conv2d_nchw_plan, input_adjoint_plan, tuning
    from repro.kernels import ops
    from repro.kernels import ssam_stencil2d, ssam_stencil3d
    from repro.kernels.stencils import BENCHMARKS

    rng = np.random.default_rng(0)
    print(f"# Adjoint plans: fwd vs fwd+bwd (2D {size2d}^2, 3D {size3d}^3, "
          "interpret-mode wall-time; model: cyc_fwd + cyc_adj per element)")
    for name in ("2d5pt", "2d9pt", "2ds25pt", "2d121pt", "3d7pt", "poisson"):
        sdef = BENCHMARKS[name]
        if sdef.ndim == 2:
            x = jnp.array(rng.standard_normal((size2d, size2d)), jnp.float32)
            mod, base = ssam_stencil2d, (8, 128)
        else:
            x = jnp.array(rng.standard_normal((size3d,) * 3), jnp.float32)
            mod, base = ssam_stencil3d, (4, 8, 128)
        plan = mod.plan_for(sdef)
        cfg = tuning.KernelConfig(tuple(min(b, n) for b, n in
                                        zip(base, x.shape)))
        fwd = jax.jit(lambda v: ops.stencil(v, sdef, impl="interpret"))
        vjp = jax.jit(jax.grad(lambda v: jnp.sum(
            ops.stencil(v, sdef, impl="interpret"))))
        t_fwd = _timeit(fwd, x)
        t_bwd = _timeit(vjp, x)
        cyc_f = tuning.model_cost(plan, cfg)
        cyc_a = tuning.model_cost(input_adjoint_plan(plan), cfg)
        mb_f = x.size * 8 / max(t_fwd, 1e-9)
        mb_b = x.size * 8 * 3 / max(t_bwd, 1e-9)
        _row(f"grad_{name}_fwd", t_fwd,
             f"mb_s={mb_f:.2f};model_cyc={cyc_f:.1f}")
        _row(f"grad_{name}_fwdbwd", t_bwd,
             f"mb_s={mb_b:.2f};model_cyc={cyc_f + cyc_a:.1f};"
             f"bwd_ratio={t_bwd / t_fwd:.2f}x;"
             f"model_ratio={(cyc_f + cyc_a) / cyc_f:.2f}x")

    C_in, C_out = channels
    x = jnp.array(rng.standard_normal((batch, C_in, img, img)), jnp.float32)
    w = jnp.array(rng.standard_normal((C_out, C_in, 3, 3)), jnp.float32)
    plan = conv2d_nchw_plan(batch, C_in, C_out, 3, 3, mode="same")
    cfg = tuning.KernelConfig((min(8, img), min(128, img)))
    fwd = jax.jit(lambda a, b: ops.conv2d(a, b, impl="interpret"))
    vjp = jax.jit(jax.grad(
        lambda a, b: jnp.sum(ops.conv2d(a, b, impl="interpret")), (0, 1)))
    t_fwd = _timeit(fwd, x, w)
    t0 = _timeit(lambda: vjp(x, w))
    cyc_f = tuning.model_cost(plan, cfg) * C_in
    cyc_a = tuning.model_cost(input_adjoint_plan(plan), cfg) * C_out
    bytes_img = (C_in + C_out) * img * img * 4
    _row(f"grad_nchw_{C_in}x{C_out}_fwd", t_fwd,
         f"mb_s_per_img={bytes_img / max(t_fwd, 1e-9):.2f};"
         f"model_cyc={cyc_f:.1f}")
    _row(f"grad_nchw_{C_in}x{C_out}_fwdbwd", t0,
         f"mb_s_per_img={3 * bytes_img / max(t0, 1e-9):.2f};"
         f"model_cyc={2 * cyc_f + cyc_a:.1f};"      # + wgrad ≈ one fwd sweep
         f"bwd_ratio={t0 / t_fwd:.2f}x")
    print(f"# backward lowerings: {dict(adjoint_mod.BACKWARD_LOWERINGS)}")


# ---------------------------------------------------------------------------
# Fused plan pipelines: epilogues + chain composition (--fused)
# ---------------------------------------------------------------------------

def bench_fused(size2d: int = 192, B: int = 1, n_mels: int = 8,
                d_model: int = 16, T: int = 256):
    """Fused pipelines vs the unfused HBM-round-trip sequence.

    Two workloads (DESIGN.md §11):

    * a 3-deep 2-D stencil chain — ``ops.pipeline(fuse=True)`` lowers
      ONE engine kernel over the chain-widened halo vs ``fuse=False``
      (three kernels, two full HBM round-trips of the activation).
      The §5 model prediction next to it: summed flop terms + one
      load/store for the fused chain vs a load/store per stage unfused.
    * the Whisper mel stem — two k=3 NCHW convs with bias+GELU fused as
      kernel epilogues and the second conv's stride-2 lowered as an
      output-strided grid (half the lanes), vs the unfused form (dense
      engine convs, XLA bias/GELU between them, subsample at the end).

    Both fused paths are fp32-tolerance identical to the unfused ones
    (asserted here, not just in tests) and differentiable with backward
    on the engine. Interpret-mode wall-times compare schedules, not TPU
    performance.
    """
    from repro.core import tuning
    from repro.core.fuse import fuse_plans
    from repro.kernels import ops
    from repro.kernels import ssam_stencil2d
    from repro.kernels.stencils import BENCHMARKS
    from repro.nn import layers as nnl

    rng = np.random.default_rng(0)
    chain = ["2d5pt", "2d9pt", "2d5pt"]
    x = jnp.array(rng.standard_normal((size2d, size2d)), jnp.float32)
    print(f"# Fused pipelines: {'+'.join(chain)} chain ({size2d}^2) and the "
          f"Whisper stem (B={B}, {n_mels} mels -> d={d_model}, T={T}); "
          "interpret-mode wall-time")

    fused = jax.jit(lambda v: ops.pipeline(v, chain, impl="interpret",
                                           fuse=True))
    unfused = jax.jit(lambda v: ops.pipeline(v, chain, impl="interpret",
                                             fuse=False))
    np.testing.assert_allclose(np.asarray(fused(x)), np.asarray(unfused(x)),
                               rtol=1e-4, atol=1e-4)
    t_f = _timeit(fused, x)
    t_u = _timeit(unfused, x)
    plans = [ssam_stencil2d.plan_for(BENCHMARKS[n]) for n in chain]
    fplan = fuse_plans(*plans)
    cfg = tuning.KernelConfig(tuple(min(b, n) for b, n in
                                    zip((8, 128), x.shape)))
    cyc_f = tuning.model_cost(fplan, cfg)
    cyc_u = sum(tuning.model_cost(p, cfg) for p in plans)
    bytes_useful = x.size * 8            # one read + one write of the domain
    _row(f"fused_chain_{'+'.join(chain)}_unfused", t_u,
         f"mb_s={bytes_useful / max(t_u, 1e-9):.2f};model_cyc={cyc_u:.1f}")
    _row(f"fused_chain_{'+'.join(chain)}_fused", t_f,
         f"mb_s={bytes_useful / max(t_f, 1e-9):.2f};model_cyc={cyc_f:.1f};"
         f"speedup={t_u / t_f:.2f}x;model_speedup={cyc_u / cyc_f:.2f}x")

    # Whisper stem: conv(n_mels->d) + GELU, conv(d->d, stride 2) + GELU.
    p1 = {"w": jnp.array(rng.standard_normal((d_model, n_mels, 1, 3)),
                         jnp.float32) * 0.2,
          "b": jnp.array(rng.standard_normal((d_model,)), jnp.float32)}
    p2 = {"w": jnp.array(rng.standard_normal((d_model, d_model, 1, 3)),
                         jnp.float32) * 0.2,
          "b": jnp.array(rng.standard_normal((d_model,)), jnp.float32)}
    mel = jnp.array(rng.standard_normal((B, n_mels, 1, T)), jnp.float32)

    def stem_fused(v):
        h = nnl.conv2d_apply(p1, v, impl="interpret", activation="gelu")
        return nnl.conv2d_apply(p2, h, impl="interpret", stride=(1, 2),
                                activation="gelu")

    def stem_unfused(v):
        # pre-§11 engine form: dense conv kernels, bias/GELU in XLA
        # between the calls, stride as an output subsample.
        h = ops.conv2d(v, p1["w"], impl="interpret")
        h = jax.nn.gelu(h + p1["b"][:, None, None], approximate=True)
        h = ops.conv2d(h, p2["w"], impl="interpret")
        h = jax.nn.gelu(h + p2["b"][:, None, None], approximate=True)
        return h[..., ::2]

    jf, ju = jax.jit(stem_fused), jax.jit(stem_unfused)
    np.testing.assert_allclose(np.asarray(jf(mel)), np.asarray(ju(mel)),
                               rtol=1e-4, atol=1e-4)
    t_f = _timeit(jf, mel)
    t_u = _timeit(ju, mel)
    bytes_stem = (mel.size + B * d_model * (T // 2)) * 4
    _row("fused_whisper_stem_unfused", t_u,
         f"mb_s={bytes_stem / max(t_u, 1e-9):.2f}")
    _row("fused_whisper_stem_fused", t_f,
         f"mb_s={bytes_stem / max(t_f, 1e-9):.2f};"
         f"speedup={t_u / t_f:.2f}x")


# ---------------------------------------------------------------------------
# Lowering strategy: VPU lanes vs MXU im2row matmul (--strategy)
# ---------------------------------------------------------------------------

def bench_strategy(strategy: str = "auto", size2d: int = 160,
                   size3d: int = 24, batch: int = 2,
                   channels: tuple[int, int] = (4, 8), img: int = 48):
    """Lanes vs MXU lowering per shape class — the BENCH_7 artifact.

    For a tap-count sweep of Table-3 stencils plus an NCHW conv (whose
    ``C_in·taps`` contraction is the MXU's best case), measures the same
    plan through both lowerings (``strategy='lanes'`` shift-fma vs
    ``strategy='mxu'`` im2row matmul), then lets the §5+MXU cost model
    and the measuring tuner each pick — reporting, per shape:

    * MB/s of useful traffic under each strategy,
    * the model's predicted winner and the measured winner (their
      agreement fraction across shapes is the §5 validation number),
    * the tuner's recorded choice and its speedup over the fixed
      pre-v5 default (always-lanes).

    With ``strategy='lanes'`` or ``'mxu'`` only that lowering is
    measured (a pinned-strategy smoke run). Interpret-mode wall-times
    compare schedules, not TPU performance — but the *algorithm choice*
    is real work either way (taps·rolls vs one gathered contraction).
    """
    from repro.core import tuning
    from repro.kernels import ops
    from repro.kernels import ssam_conv2d, ssam_stencil2d, ssam_stencil3d
    from repro.kernels.stencils import BENCHMARKS

    rng = np.random.default_rng(0)
    strategies = ("lanes", "mxu") if strategy == "auto" else (strategy,)
    names = ["2d5pt", "2d9pt", "2d13pt", "2d25pt", "2d121pt",
             "3d7pt", "3d27pt"]
    print(f"# Strategy: lanes vs mxu lowering (2D {size2d}^2, 3D {size3d}^3, "
          f"NCHW {batch}x{channels[0]}->{channels[1]}x{img}^2; "
          "interpret-mode wall-time)")
    agree = total = 0

    def _report(tag, plan, shape, run_fixed, run_cfg):
        """Measure every strategy, then model-pick, measure-pick and
        tuner-pick; returns 1 if model and measurement agree."""
        nonlocal agree, total
        times, model = {}, {}
        bytes_useful = int(np.prod(shape)) * 8
        for s in strategies:
            t = tuning.measure_us(lambda: run_fixed(s))
            cands = [c for c in tuning.candidate_configs(plan, shape)
                     if c.strategy == s]
            cyc = min(tuning.model_cost(plan, c) for c in cands)
            times[s], model[s] = t, cyc
            _row(f"strategy_{tag}_{s}", t,
                 f"mb_s={bytes_useful / max(t, 1e-9):.2f};"
                 f"model_cyc={cyc:.1f}")
        if strategy != "auto":
            return
        predicted = min(model, key=model.get)
        measured = min(times, key=times.get)
        tuning.clear_cache()
        runner = lambda cfg: tuning.measure_us(lambda: run_cfg(cfg))
        tuned = tuning.autotune(plan, shape, runner=runner)
        choice = tuned.config.strategy or "lanes"
        t_choice = tuning.measure_us(lambda: run_cfg(tuned.config))
        total += 1
        agree += int(predicted == measured)
        # speedup vs the fixed pre-v5 default: always-lanes at the
        # family default block — the thing the strategy dimension (plus
        # per-strategy shortlists) exists to beat.
        _row(f"strategy_{tag}_choice", t_choice,
             f"tuner={choice};cfg={'x'.join(map(str, tuned.config.block))};"
             f"predicted={predicted};measured={measured};"
             f"agree={int(predicted == measured)};"
             f"speedup_vs_default={times['lanes'] / max(t_choice, 1e-9):.2f}x")

    for name in names:
        sdef = BENCHMARKS[name]
        if sdef.ndim == 2:
            x = jnp.array(rng.standard_normal((size2d, size2d)), jnp.float32)
            mod = ssam_stencil2d
        else:
            x = jnp.array(rng.standard_normal((size3d,) * 3), jnp.float32)
            mod = ssam_stencil3d
        plan = mod.plan_for(sdef)
        _report(name, plan, x.shape,
                lambda s, x=x, sdef=sdef: ops.stencil(
                    x, sdef, impl="interpret", strategy=s),
                lambda cfg, x=x, sdef=sdef, plan=plan: ops.stencil(
                    x, sdef, impl="interpret", **cfg.as_kwargs(plan)))

    C_in, C_out = channels
    xn = jnp.array(rng.standard_normal((batch, C_in, img, img)), jnp.float32)
    w = jnp.array(rng.standard_normal((C_out, C_in, 3, 3)), jnp.float32)
    plan = ssam_conv2d.plan_for_nchw(xn.shape, w.shape, "same")
    _report(f"conv2d_nchw_{C_in}x{C_out}", plan, xn.shape,
            lambda s: ops.conv2d(xn, w, mode="same", impl="interpret",
                                 strategy=s),
            lambda cfg: ops.conv2d(xn, w, mode="same", impl="interpret",
                                   **cfg.as_kwargs(plan)))

    if strategy == "auto" and total:
        _row("strategy_model_agreement", 0.0,
             f"agree_frac={agree / total:.2f};n={total}")


# ---------------------------------------------------------------------------
# Engine backends: TPU lane rolls vs GPU warp shifts (--backend)
# ---------------------------------------------------------------------------

def bench_backend(backend: str = "auto", size2d: int = 160, size3d: int = 24,
                  rows: int = 8, T: int = 1024):
    """TPU vs GPU engine lowering of the same plans — the BENCH_8 artifact.

    The plan IR is backend-neutral; ``backend='tpu'`` lowers shifts as
    whole-lane ``jnp.roll`` (the VREG lattice), ``backend='gpu'`` as
    ``engine_gpu.warp_shift`` (intra-warp lane roll + SMEM-staged
    inter-warp hand-off, the ``__shfl_up_sync`` emulation). For a
    tap-count sweep of Table-3 stencils, a 5x5 conv and the scan pair,
    measures each requested backend and reports MB/s of useful traffic
    next to that backend's *own* machine-model prediction
    (``perfmodel.machine_for``: TPUv5e lane geometry vs A100 warp
    geometry — different latency tables, different best blocks).

    With ``--backend auto`` both lowerings run on every shape, their
    outputs are asserted fp32-identical, and each row carries the
    model's predicted winner next to the measured one. Interpret-mode
    wall-times compare schedules, not device performance: both backends
    execute on the CPU interpreter here, so the wall-time gap measures
    schedule overhead (warp staging vs whole-lane rolls) while the
    model columns carry the per-device forecasts.
    """
    from repro.core import tuning
    from repro.core.perfmodel import machine_for
    from repro.kernels import ops
    from repro.kernels import ssam_conv2d, ssam_stencil2d, ssam_stencil3d
    from repro.kernels.stencils import BENCHMARKS

    rng = np.random.default_rng(0)
    backends = ("tpu", "gpu") if backend == "auto" else (backend,)
    for b in backends:
        m = machine_for(b)
        _row(f"backend_machine_{b}", 0.0,
             f"model={m.name};warp={m.warp};lanes={m.lanes};"
             f"hbm_gbps={m.hbm_gbps}")
    names = ["2d5pt", "2d9pt", "2d25pt", "2d121pt", "3d7pt", "3d27pt"]
    print(f"# Backends {'+'.join(backends)}: stencils (2D {size2d}^2, "
          f"3D {size3d}^3), conv2d 5x5, scans ({rows}, {T}); "
          "interpret-mode wall-time")

    def _report(tag, plan, shape, nbytes, run):
        times, model = {}, {}
        for b in backends:
            t = tuning.measure_us(lambda: run(b))
            cyc = min(tuning.model_cost(plan, c, backend=b) for c in
                      tuning.candidate_configs(plan, shape, backend=b))
            times[b], model[b] = t, cyc
            _row(f"backend_{tag}_{b}", t,
                 f"mb_s={nbytes / max(t, 1e-9):.2f};model_cyc={cyc:.2f}")
        if len(backends) == 2:
            np.testing.assert_allclose(
                np.asarray(run("tpu")), np.asarray(run("gpu")),
                rtol=1e-5, atol=1e-5, err_msg=tag)
            _row(f"backend_{tag}_pick", 0.0,
                 f"predicted={min(model, key=model.get)};"
                 f"measured={min(times, key=times.get)}")

    for name in names:
        sdef = BENCHMARKS[name]
        if sdef.ndim == 2:
            x = jnp.array(rng.standard_normal((size2d, size2d)), jnp.float32)
            mod = ssam_stencil2d
        else:
            x = jnp.array(rng.standard_normal((size3d,) * 3), jnp.float32)
            mod = ssam_stencil3d
        plan = mod.plan_for(sdef)
        _report(name, plan, x.shape, x.size * 8,
                lambda b, x=x, sdef=sdef: ops.stencil(
                    x, sdef, impl="interpret", backend=b))

    w = jnp.array(rng.standard_normal((5, 5)), jnp.float32)
    x = jnp.array(rng.standard_normal((size2d, size2d)), jnp.float32)
    plan = ssam_conv2d.plan_for(w.shape, "same")
    _report("conv2d_5x5", plan, x.shape, x.size * 8,
            lambda b: ops.conv2d(x, w, impl="interpret", backend=b))

    a = jnp.array(rng.uniform(0.5, 1.0, (rows, T)), jnp.float32)
    bb = jnp.array(rng.standard_normal((rows, T)), jnp.float32)
    from repro.core.plan import linear_recurrence_plan, scan_plan
    _report("cumsum", scan_plan(T), bb.shape, bb.size * 8,
            lambda k: ops.cumsum(bb, impl="interpret", backend=k))
    _report("linrec", linear_recurrence_plan(T), bb.shape, bb.size * 12,
            lambda k: ops.linear_recurrence(a, bb, impl="interpret",
                                            backend=k))


# ---------------------------------------------------------------------------
# LM roofline summary (assignment §Roofline)
# ---------------------------------------------------------------------------

def bench_lm_roofline():
    sys.path.insert(0, os.path.dirname(__file__))
    import roofline as rl

    recs = rl.load_records()
    if not recs:
        print("# roofline: no artifacts found (run repro.launch.dryrun)")
        return
    print("# LM roofline summary (single-pod; seconds per step; "
          "full table in EXPERIMENTS.md)")
    for r in recs:
        if r["mesh"] != "pod16x16" or r["status"] != "ok":
            continue
        rr = rl.roofline_of(r)
        _row(f"roofline_{r['arch']}_{r['shape']}", rr.bound_s * 1e6,
             f"dominant={rr.dominant};frac={rr.roofline_fraction:.3f};"
             f"useful={rr.useful_flops_ratio:.2f}")


# ---------------------------------------------------------------------------
# Telemetry: tuner hit-rates + model-vs-measured drift (--obs, BENCH_9.json)
# ---------------------------------------------------------------------------

def bench_obs(size2d: int = 128):
    """Exercise tuner + both engine backends under telemetry and report
    what the observability layer saw (DESIGN.md §15): sidecar hit/seed/
    miss rates, engine launch and lowering (recompile) counts, and the
    per-backend model-vs-measured drift aggregates — the BENCH_9.json
    rows. Absolute µs are CPU interpret-mode; the drift *ratios* are the
    artifact (they recalibrate the §5 constants on real hardware)."""
    from repro import obs
    from repro.core import tuning
    from repro.kernels import ops, ssam_stencil2d
    from repro.kernels.stencils import BENCHMARKS

    obs.metrics.reset()
    obs.drift.reset()
    tuning.clear_cache()
    tuning.clear_sidecar()
    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((size2d, size2d)), jnp.float32)
    names = [n for n, s in BENCHMARKS.items() if s.ndim == 2][:3]
    print(f"# Telemetry: tuner + drift over {names} on tpu+gpu lowerings "
          f"({size2d}^2, interpret mode)")
    for backend in ("tpu", "gpu"):
        for name in names:
            sdef = BENCHMARKS[name]
            plan = ssam_stencil2d.plan_for(sdef)
            default = tuning.KernelConfig((8, 128))
            runner = lambda cfg: tuning.measure_us(
                lambda: ops.stencil(x, sdef, impl="interpret",
                                    backend=backend, **cfg.as_kwargs(plan)))
            tuning.autotune(plan, x.shape, default=default, runner=runner,
                            backend=backend)
            # replay: the second autotune of the same key must cache-hit
            tuning.autotune(plan, x.shape, default=default, runner=runner,
                            backend=backend)

    snap = obs.metrics.snapshot()
    counters = snap["counters"]

    def total(cname):
        return counters.get(cname, {}).get("total", 0.0)

    hits = total("tuner.cache_hit") + total("tuner.sidecar_hit")
    lookups = hits + total("tuner.sidecar_seed") + total("tuner.sidecar_miss")
    _row("obs_tuner_hit_rate", 0.0,
         f"hits={hits:.0f};lookups={lookups:.0f};"
         f"rate={hits / max(lookups, 1):.2f};"
         f"measured={total('tuner.measure'):.0f}")
    for label, n in sorted(
            counters.get("engine.launch", {}).get("by_label", {}).items()):
        _row(f"obs_launch_{label.replace(':', '_')}", 0.0, f"count={n:.0f}")
    _row("obs_recompiles", 0.0,
         f"count={total('engine.lowering'):.0f}")

    for backend, agg in sorted(obs.drift.aggregate().items()):
        _row(f"obs_drift_{backend}", 0.0,
             f"pooled_ratio={agg['pooled_ratio']:.4g};"
             f"cells={agg['cells']};samples={agg['samples']};"
             f"max_drift={agg['max_drift']:.3f}x;"
             f"worst={agg['worst_signature']}")
    from repro.obs import report as obs_report
    print("# drift table (python -m repro.obs.report):")
    for line in obs_report.render().splitlines():
        print(f"#   {line}")


def bench_chaos(size2d: int = 160):
    """Guarded execution under injected faults (DESIGN.md §16) — the
    BENCH_10.json artifact.

    Three sections: (1) overhead-when-off — the guarded engine dispatch
    vs the raw engine call with the robustness layer idle, asserted
    < 1% (the fault check is one bool read and the guard one try frame);
    (2) fault sweep — MB/s served at engine-site fault probabilities
    {0, 0.5, 1.0} under ``on_failure='fallback'`` with the demotion
    counts, quantifying what degraded (oracle) service costs next to the
    engine path; (3) serve chaos — decode-server tokens/sec clean vs
    under transient step faults, with shed-request counts. Absolute µs
    are CPU interpret-mode; the *ratios* and counters are the artifact.
    """
    from repro import obs, robust
    from repro.core import tuning
    from repro.kernels import ops, ssam_stencil2d
    from repro.kernels.stencils import BENCHMARKS
    from repro.robust import faults

    obs.metrics.reset()
    tuning.clear_cache()
    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal((size2d, size2d)), jnp.float32)
    sdef = BENCHMARKS["2d5pt"]
    plan = ssam_stencil2d.plan_for(sdef)
    mb = 2 * x.size * 4 / 1e6               # in + out, fp32, MB per call

    print(f"# Chaos: guarded dispatch, 2d5pt {size2d}^2, interpret mode")

    # -- 1. overhead when the robustness layer is off ----------------------
    # The interpret-mode engine call jitters a few percent run-to-run,
    # which swamps a µs-scale guard in any A/B wall-time comparison
    # (the A/B delta is reported as an informational field only). So
    # measure the machinery directly: the full guarded dispatch with
    # the engine op stubbed to identity is exactly what the guard adds
    # per call — level-list build + one try frame — and that cost is
    # asserted against the real engine call's wall-time.
    cfg = ops._window_cfg(plan, {}, interpret=True)
    raw_f = lambda: ops._window_op(cfg, x, None, ())
    grd_f = lambda: ops._guarded_window("stencil", cfg, x, None, (), None)
    raw_f(); grd_f()                      # warm the jit caches
    raw_s, grd_s = [], []
    for _ in range(40):                   # interleaved to cancel drift
        t0 = time.perf_counter(); raw_f().block_until_ready()
        raw_s.append((time.perf_counter() - t0) * 1e6)
        t0 = time.perf_counter(); grd_f().block_until_ready()
        grd_s.append((time.perf_counter() - t0) * 1e6)
    raw_us = float(np.median(raw_s))
    ab_delta_pct = (float(np.median(grd_s)) - raw_us) / raw_us * 100
    real_op = ops._window_op
    ops._window_op = lambda c, xx, ww, ee: xx      # identity engine stub
    try:
        guard_us = _timeit(
            lambda: ops._guarded_window("stencil", cfg, x, None, (), None),
            reps=200)
    finally:
        ops._window_op = real_op
    overhead_pct = guard_us / raw_us * 100
    _row("chaos_guard_overhead_off", raw_us,
         f"guard_us={guard_us:.2f};overhead_pct={overhead_pct:.4f};"
         f"ab_delta_pct={ab_delta_pct:.2f}")
    assert overhead_pct < 1.0, (
        f"idle guard machinery is {overhead_pct:.2f}% of an engine call "
        f"(>1% budget)")

    # -- 2. fault sweep: engine MB/s vs fallback (oracle) MB/s -------------
    for site, call in (
        ("engine.window",
         lambda: ops.stencil(x, sdef, impl="interpret")),
        ("engine.scan",
         lambda: ops.cumsum(x, impl="interpret")),
    ):
        for prob in (0.0, 0.5, 1.0):
            with robust.inject(f"{site}:{prob}:3"), \
                    robust.failure_policy("fallback"):
                d0 = obs.metrics.counter_total("robust.demotion")
                us = _timeit(call, reps=9)
                demoted = obs.metrics.counter_total("robust.demotion") - d0
                fired = faults.fired_counts().get(site, 0)
            tag = site.split(".")[1]
            _row(f"chaos_{tag}_p{int(prob * 100)}", us,
                 f"mbps={mb * 1e6 / us:.1f};prob={prob};"
                 f"demotions={demoted:.0f};fired={fired}")

    # -- 3. decode-server throughput under step faults ---------------------
    from repro.config import get_config
    from repro.launch.serve import DecodeServer, Request
    from repro.models import build_model
    from repro.nn.spec import init_params

    cfgm = get_config("gemma3_1b", smoke=True)
    model = build_model(cfgm)
    params = init_params(model.specs(), jax.random.PRNGKey(0))

    def serve_run(spec: str | None):
        srv = DecodeServer(model, params, slots=2, cache_len=32)
        reqs = [Request(i, rng.integers(0, cfgm.vocab, 4, dtype=np.int32), 8)
                for i in range(6)]
        t0 = time.perf_counter()
        with robust.failure_policy("fallback"):
            if spec:
                with robust.inject(spec):
                    done = srv.run(reqs)
            else:
                done = srv.run(reqs)
        dt = time.perf_counter() - t0
        tok = sum(len(r.out) for r in done if r.error is None)
        shed = sum(1 for r in done if r.error)
        return tok / dt, shed, srv.step_failures

    serve_run(None)                       # warm the serve_step jit cache
    clean_tps, _, _ = serve_run(None)
    chaos_tps, shed, failures = serve_run("serve.step:0.3:7")
    _row("chaos_serve_clean", 0.0, f"tok_per_s={clean_tps:.1f}")
    _row("chaos_serve_p30", 0.0,
         f"tok_per_s={chaos_tps:.1f};shed={shed};step_failures={failures};"
         f"ratio={chaos_tps / max(clean_tps, 1e-9):.3f}")


def main(argv=None) -> None:
    import argparse

    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument(
        "--mesh", default=None, metavar="AxB",
        help="run the sharded halo-exchange bench on an AxB device mesh "
             "(e.g. 2x4 or 8x1); needs A*B devices — on CPU set "
             "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    p.add_argument(
        "--time-steps", type=int, default=1,
        help="fused temporal steps for the sharded bench (default 1)")
    p.add_argument(
        "--grad", action="store_true",
        help="run the adjoint-plan benchmark: fwd vs fwd+bwd MB/s for "
             "Table-3 stencils and NCHW conv next to the §5 model's "
             "fwd + adjoint-plan cost prediction")
    p.add_argument(
        "--batch", type=int, default=None, metavar="B",
        help="run the NCHW conv bench with a B-image minibatch through "
             "the reduce-axes engine plan")
    p.add_argument(
        "--channels", default=None, metavar="Cin,Cout",
        help="input,output channel counts for the NCHW conv bench "
             "(default 3,8; implies --batch 4 when only --channels given)")
    p.add_argument(
        "--fused", action="store_true",
        help="run the fused-pipeline benchmark: fused vs unfused wall-time "
             "and §5 cost for a 3-deep stencil chain (ops.pipeline) and "
             "the epilogue+strided Whisper mel stem")
    p.add_argument(
        "--scan-chunked", action="store_true",
        help="run the chunk-streamed scan benchmark: streamed engine vs "
             "monolithic engine vs XLA chunked linrec, plus Mamba/RWKV "
             "train-step tokens/sec + peak-temp-memory trajectories over "
             "increasing T (the BENCH_6.json artifact)")
    p.add_argument(
        "--strategy", default=None, choices=("lanes", "mxu", "auto"),
        help="run the lowering-strategy benchmark: lanes (VPU shift-fma) "
             "vs mxu (im2row matmul) MB/s per Table-3 shape class + NCHW "
             "conv, the tuner's per-shape pick and the §5 predicted-vs-"
             "measured ranking agreement (the BENCH_7.json artifact uses "
             "'auto'; 'lanes'/'mxu' measure only that lowering)")
    p.add_argument(
        "--backend", default=None, choices=("tpu", "gpu", "auto"),
        help="run the per-backend engine benchmark: TPU lane-roll vs GPU "
             "warp-shift lowering of the same plans, MB/s per backend next "
             "to each backend's machine-model prediction "
             "(perfmodel.machine_for); 'auto' measures both and asserts "
             "equivalence (the BENCH_8.json artifact uses 'auto')")
    p.add_argument(
        "--obs", action="store_true",
        help="run the telemetry benchmark: tuner sidecar hit-rates, engine "
             "launch/recompile counts and per-backend model-vs-measured "
             "drift aggregates (the BENCH_9.json artifact; pairs with "
             "--trace/--metrics)")
    p.add_argument(
        "--chaos", action="store_true",
        help="run the guarded-execution benchmark: idle-guard overhead "
             "(asserted < 1%%), MB/s served under injected engine faults "
             "at prob 0/0.5/1.0 with demotion counts, and decode-server "
             "throughput under transient step faults (the BENCH_10.json "
             "artifact)")
    p.add_argument(
        "--trace", default=None, metavar="PATH",
        help="collect engine/tuner/halo spans for the whole run and write "
             "Chrome-trace JSON (chrome://tracing / Perfetto) to PATH")
    p.add_argument(
        "--metrics", default=None, metavar="PATH",
        help="write the metrics registry snapshot + drift recorder state "
             "as JSON to PATH at exit (render the drift table with "
             "python -m repro.obs.report PATH)")
    p.add_argument(
        "--json", default=None, metavar="PATH",
        help="also write every benchmark row as machine-readable JSON "
             "(per-kernel µs, MB/s, tuned config, §5 prediction, fused vs "
             "unfused) to PATH")
    args = p.parse_args(argv)
    global _JSON_ROWS
    if args.json:
        _JSON_ROWS = []
    from repro import obs
    if args.trace:
        obs.trace.enable(args.trace)
    try:
        if args.mesh:
            shape = tuple(int(v) for v in args.mesh.lower().split("x"))
            bench_sharded(shape, time_steps=args.time_steps)
        elif args.grad:
            bench_grad()
        elif args.fused:
            bench_fused()
        elif args.scan_chunked:
            bench_scan_chunked()
        elif args.strategy:
            bench_strategy(args.strategy)
        elif args.backend:
            bench_backend(args.backend)
        elif args.obs:
            bench_obs()
        elif args.chaos:
            bench_chaos()
        elif args.batch is not None or args.channels is not None:
            ch = tuple(int(v) for v in (args.channels or "3,8").split(","))
            bench_conv2d_batched(args.batch if args.batch is not None else 4,
                                 ch)
        else:
            bench_perf_model()
            bench_conv2d_filter_sweep()
            bench_stencil_suite()
            bench_temporal_blocking()
            bench_scan()
            bench_autotune()
            bench_fused()
            bench_lm_roofline()
    finally:
        if args.trace:
            out = obs.trace.export(args.trace)
            print(f"# wrote {len(obs.trace.events())} spans to {out}")
        if args.metrics:
            print(f"# wrote metrics+drift to {obs.metrics.export(args.metrics)}")
        if args.json:
            _write_json(args.json)


if __name__ == "__main__":
    main()
