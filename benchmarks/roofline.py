"""Roofline table builder: dry-run JSON artifacts → per-cell three-term
TPU v5e roofline (§Roofline of EXPERIMENTS.md).

Reads artifacts/dryrun/*.json written by repro.launch.dryrun and emits a
markdown table plus machine-readable CSV. Per (arch × shape × mesh):
compute/memory/collective terms in seconds (per-device program ÷
per-chip bandwidths), dominant term, MODEL_FLOPS/HLO_FLOPs utilization,
and the roofline fraction (ideal compute time ÷ modeled bound).
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.rooflines import Roofline  # noqa: E402

# v5e: 4 ICI links/chip usable for the collective term on a 2-D torus axis;
# we keep 1 link (worst case) so collective terms are upper bounds.
ICI_LINKS = 1


def load_records(art_dir: str = "artifacts/dryrun", tag: str | None = "baseline"):
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if tag is not None and r.get("tag") != tag:
            continue
        recs.append(r)
    return recs


def roofline_of(rec: dict) -> Roofline | None:
    if rec.get("status") != "ok":
        return None
    from repro.core.rooflines import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    # trip-count-aware roll-up when available (see repro.core.hlo_cost);
    # raw cost_analysis kept in the artifact for comparison.
    hc = rec.get("hlo_cost")
    if hc:
        # memory term uses the ideal-fusion bytes (TPU-like coalescing);
        # the raw CPU-granularity bytes stay in the artifact as the upper
        # bound (see repro.core.hlo_cost docstring).
        flops, coll = hc["flops"], hc["collective_bytes"]
        byts = hc.get("bytes_fused", hc["bytes"])
    else:
        flops = rec["cost"].get("flops", 0.0)
        byts = rec["cost"].get("bytes accessed", 0.0)
        coll = rec["collectives"]["total_bytes"]
    return Roofline(
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=byts / HBM_BW,
        collective_s=coll / (ICI_BW * ICI_LINKS),
        hlo_flops=flops,
        hlo_bytes=byts,
        collective_bytes=coll,
        chips=rec["chips"],
        model_flops=rec.get("model_flops", 0.0),
    )


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-6:
        return f"{x*1e9:.1f}ns"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.3f}s"


def table(recs, mesh: str = "pod16x16") -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bound | "
        "MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"{r['reason']} | — | — |")
            continue
        rl = roofline_of(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rl.compute_s)} | "
            f"{fmt_s(rl.memory_s)} | {fmt_s(rl.collective_s)} | "
            f"**{rl.dominant}** | {rl.useful_flops_ratio:.2f} | "
            f"{rl.roofline_fraction:.2%} |")
    return "\n".join(lines)


def csv(recs) -> str:
    out = ["arch,shape,mesh,tag,compute_s,memory_s,collective_s,dominant,"
           "useful_ratio,roofline_frac"]
    for r in recs:
        if r["status"] != "ok":
            continue
        rl = roofline_of(r)
        out.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{r.get('tag','baseline')},"
            f"{rl.compute_s:.6e},{rl.memory_s:.6e},{rl.collective_s:.6e},"
            f"{rl.dominant},{rl.useful_flops_ratio:.4f},"
            f"{rl.roofline_fraction:.4f}")
    return "\n".join(out)


def main():
    recs = load_records()
    print("## Roofline — single-pod 16×16 (256 chips)\n")
    print(table(recs, "pod16x16"))
    print("\n## Multi-pod 2×16×16 (512 chips)\n")
    print(table(recs, "pod2x16x16"))
    print("\n## CSV\n")
    print(csv(recs))


if __name__ == "__main__":
    main()
