"""EXPERIMENTS.md generator.

Assembles the experiment report from the dry-run artifacts:
  §Dry-run   — per-cell compile evidence (memory_analysis, collective mix)
  §Roofline  — the 40-cell three-term table (both meshes) + analysis notes
  §Perf      — concatenated from benchmarks/perf_log.md (the hand-written
               hypothesis→change→measure→verdict hillclimbing log)
  §Paper     — pointer to the paper-table benchmarks (benchmarks.run)

Regenerate with:  PYTHONPATH=src python benchmarks/report.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import roofline as rl


def gib(x):
    return f"{x / 2**30:.2f}GiB" if x else "—"


def dryrun_section(recs):
    lines = [
        "## §Dry-run — multi-pod compile evidence",
        "",
        "Every (architecture × shape) lowered **and compiled** for the",
        "single-pod 16×16 (256 chips) and multi-pod 2×16×16 (512 chips)",
        "production meshes with full train/serve-step programs (loss + grads",
        "+ AdamW for `train_4k`; one-token decode against the full cache for",
        "decode shapes). Artifacts: `artifacts/dryrun/*.json`. Columns:",
        "per-device argument bytes (params+optimizer+cache shards — proves",
        "fit), temp bytes at peak, and the collective op mix.",
        "",
        "| arch | shape | mesh | args/dev | temps/dev | collectives (count) | compile |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "ok":
            continue
        mem = r["memory"]
        cc = r["collectives"]["count_by_kind"]
        cstr = " ".join(f"{k}:{v}" for k, v in sorted(cc.items())) or "none"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{gib(mem['argument_size_bytes'])} | {gib(mem['temp_size_bytes'])} | "
            f"{cstr} | {r['compile_s']:.0f}s |")
    skips = [r for r in recs if r["status"] == "skip" and r["mesh"] == "pod16x16"]
    lines += ["", "Skipped cells (recorded per assignment):", ""]
    for r in skips:
        lines.append(f"* `{r['arch']} × {r['shape']}` — {r['reason']}")
    return "\n".join(lines)


def roofline_section(recs):
    notes = analysis_notes(recs)
    return "\n".join([
        "## §Roofline — TPU v5e three-term model",
        "",
        "Terms per the assignment: `compute = HLO_FLOPs/(peak 197 TF/s bf16)`,",
        "`memory = HLO_bytes/(819 GB/s HBM)`, `collective = collective_bytes/",
        "(50 GB/s ICI link)` — all **per-device** quantities of the",
        "SPMD-partitioned module (equivalent to the global/chips form).",
        "`cost_analysis()` counts `while` bodies once, so all three inputs",
        "come from `repro.core.hlo_cost`: a trip-count-aware roll-up over the",
        "optimized HLO (validated exact on scan-vs-unrolled probes). The",
        "memory term uses ideal-fusion bytes (elementwise producer→consumer",
        "chains coalesced, in-place DUS) — the raw CPU-granularity bytes are",
        "kept in each artifact as an upper bound. MODEL_FLOPS = 6·N_active·D",
        "(train) / 2·N_active·D (inference); `roofline frac` = time at peak",
        "compute ÷ modeled bound.",
        "",
        "### Single-pod 16×16 (256 chips) — baseline (paper-faithful + "
        "pre-hillclimb defaults)",
        "",
        rl.table(recs, "pod16x16"),
        "",
        "### Multi-pod 2×16×16 (512 chips) — baseline",
        "",
        rl.table(recs, "pod2x16x16"),
        "",
        final_section(),
        "",
        "### Per-cell bottleneck analysis (baseline)",
        "",
        notes,
    ])


def final_section():
    recs = rl.load_records(tag="final")
    if not recs:
        return "(final-tag table pending)"
    return "\n".join([
        "### Single-pod 16×16 — FINAL (beyond-paper defaults folded in: "
        "flash-backward remat; decode cells additionally measured with "
        "constrain_cache + write-outside in §Perf)",
        "",
        rl.table(recs, "pod16x16"),
    ])


def analysis_notes(recs):
    """One sentence per single-pod cell on what would move the dominant term."""
    out = []
    for r in recs:
        if r["mesh"] != "pod16x16" or r["status"] != "ok":
            continue
        rr = rl.roofline_of(r)
        arch, shape = r["arch"], r["shape"]
        dom = rr.dominant
        if dom == "memory":
            if shape.startswith("decode") or shape == "long_500k":
                note = ("decode is params+cache-read bound: shard the cache "
                        "seq axis over 'model' and/or quantize cache to int8 "
                        "to cut the per-token read.")
            elif r["arch"].startswith(("rwkv", "hymba")):
                note = ("recurrence-chunk boundary traffic dominates: larger "
                        "chunks + bf16 chunk intermediates (or the fused SSAM "
                        "Pallas scan kernel on real TPU) cut HBM round-trips.")
            else:
                note = ("f32 norm/residual chains and remat recompute "
                        "dominate: fewer f32 round-trips, saveable-norm remat "
                        "policy, bf16 CE logits.")
        elif dom == "collective":
            note = ("collective-bound: re-pin scan-carried cache/activation "
                    "shardings (constrain_cache) and use bf16 gradient "
                    "all-reduce to halve bytes.")
        else:
            note = ("compute-bound: raise MXU utilization (bigger per-device "
                    "batch or less remat recompute); causal block-skipping "
                    "in flash attention removes masked-half waste.")
        out.append(f"* `{arch} × {shape}`: dominant={dom}, "
                   f"useful-FLOPs ratio {rr.useful_flops_ratio:.2f} — {note}")
    return "\n".join(out)


HEADER = """# EXPERIMENTS

System: SSAM (SC'19) reproduction inside the multi-pod JAX LM framework —
see DESIGN.md for the architecture and README.md for how to run.
Hardware target: TPU v5e pods (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI); this container is CPU-only, so kernel correctness is interpret-mode
validated and performance is reported through the compiled-artifact
roofline below.

## Paper-claim validation (reproduction gate)

The paper's claims, checked against this implementation (CPU-measurable):

1. **Eq. 5 (`Dif_smem_reg ≫ 0` for M,N ≥ 2)** — property-tested for all
   M,N ∈ [2,20] on P100/V100 (paper's Table-2 latencies) and the TPU-v5e
   re-parameterization (`tests/test_core_plan.py::TestPerfModel`); the
   advantage grows monotonically with filter size exactly as Fig. 4's
   spread predicts (`test_advantage_grows_with_filter`).
2. **Systolic schedule correctness** — the 𝒥=(O,D,X,Y) executor and the
   Pallas kernels reproduce the mathematical oracles to float tolerance
   for conv2d (2×2…20×20, incl. non-square), all 15 Table-3 stencils,
   scans and linear recurrences (73+ kernel/core tests).
3. **Halo algebra (§5.3)** — `C = N+P−1`, valid lanes `S−M+1`, and the
   halo-ratio bound hold for all plan shapes (hypothesis property tests);
   at S=128 (TPU lanes) the exact halo ratio is *lower* than the paper's
   S=32 — the TPU adaptation wins on redundancy.
4. **Temporal blocking (Fig. 6 analogue)** — the trapezoidal fused-step
   kernel matches its reference to float tolerance (t ∈ {2,4}).
5. **Fig. 4 analogue, measured** — even through XLA-CPU, the SSAM
   systolic schedule (roll-based executor) runs the 2-D convolution
   3.6–7.1× faster than the direct `lax.conv` lowering at every filter
   size 2×2…13×13 (bench_output.txt `conv2d_*` rows) — the schedule
   itself, not just the hardware mapping, carries the win.

CPU wall-clock benchmarks per paper table: `python -m benchmarks.run`
(outputs in bench_output.txt; they compare *schedules* under XLA-CPU, not
TPU performance — the roofline below is the perf report).
"""


def main():
    recs = rl.load_records()
    parts = [HEADER, dryrun_section(recs), "", roofline_section(recs), ""]
    perf_log = os.path.join(os.path.dirname(__file__), "perf_log.md")
    if os.path.exists(perf_log):
        parts.append(open(perf_log).read())
    out = "\n".join(parts)
    path = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
    with open(path, "w") as f:
        f.write(out)
    print(f"wrote {os.path.abspath(path)} ({len(out)} chars)")


if __name__ == "__main__":
    main()
