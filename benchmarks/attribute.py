"""Byte/flop attribution over a cell's compiled HLO — the §Perf profiler.

  PYTHONPATH=src python benchmarks/attribute.py --arch rwkv6-1.6b \
      --shape train_4k [--set scan_dtype=bfloat16] [--top 12]

Prints the top contributors to the fused-bytes memory term, grouped by
(opcode, op-name-stem), with trip multiplication — the "profile" the
hypothesis loop reads (per the assignment: the dry-run artifact IS the
profile on this CPU-only container).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import collections
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def attribute(hlo: str, top: int = 12):
    from repro.core import hlo_cost as H
    comps = H.parse_computations(hlo)
    entry = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo).group(1)
    contrib = collections.Counter()
    flops_c = collections.Counter()
    ex = {}

    def walk(name, mult):
        ops = comps.get(name, [])
        shapes = {o.name: o.type_str for o in ops}
        fusible = {o.name for o in ops if H._is_fusible_elementwise(o)}
        op_by_name = {o.name: o for o in ops}
        memo = {}

        def roots_of(on):
            if on in memo:
                return memo[on]
            o = op_by_name.get(on)
            if o is None or o.name not in fusible:
                memo[on] = (on,)
                return memo[on]
            memo[on] = ()
            rs = []
            for o2 in H._OPERAND.findall(o.rest.split("),", 1)[0]):
                if o2 in shapes:
                    rs.extend(roots_of(o2))
            memo[on] = tuple(dict.fromkeys(rs))
            return memo[on]

        for op in ops:
            if op.opcode in H._SKIP_OPS:
                continue
            if op.opcode == "while":
                wm = H._WHILE_ATTRS.search(op.rest)
                tm = H._TRIP_CFG.search(op.rest)
                n = float(tm.group(1)) if tm else 1.0
                if wm:
                    walk(wm.group(2), mult * n)
                continue
            if op.name in fusible:
                continue
            if op.opcode == "dynamic-update-slice":
                b = 0.0
            elif op.opcode in ("dynamic-slice", "gather"):
                b = 2 * H._shape_bytes(op.type_str)
            elif op.opcode == "fusion" and "dynamic-update-slice" in op.name:
                b = 2 * H._dus_update_bytes(op, comps)
            else:
                b = H._shape_bytes(op.type_str)
                seen = set()
                for on in H._OPERAND.findall(op.rest.split("),", 1)[0]):
                    if on not in shapes or on in seen:
                        continue
                    seen.add(on)
                    elems = H._shape_elems(shapes[on])
                    width = None
                    for r in roots_of(on):
                        m = H._SHAPE.search(shapes.get(r, ""))
                        if m and m.group(1) in H._DTYPE_BYTES:
                            w = H._DTYPE_BYTES[m.group(1)]
                            width = w if width is None else min(width, w)
                    if width is None:
                        m = H._SHAPE.search(shapes[on])
                        width = H._DTYPE_BYTES.get(m.group(1), 4) if m else 4
                    b += elems * width
            fl = 0.0
            if op.opcode in ("dot", "dot-general"):
                fl = H._dot_flops(op, shapes)
            key = (op.opcode, op.type_str.split("{")[0][:40], mult)
            contrib[key] += b * mult
            flops_c[key] += fl * mult
            if key not in ex:
                ex[key] = op.name.split(".")[0][:34]

    walk(entry, 1.0)
    tot = sum(contrib.values())
    print(f"fused-bytes total {tot:.3e} = {tot/819e9:.3f}s @819GB/s")
    for k, v in contrib.most_common(top):
        print(f"{str(k):50s} {v:.3e} ({v/tot:5.1%}) flops={flops_c[k]:.2e} "
              f"ex={ex[k]}")
    return contrib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    import dataclasses
    from repro.config import SHAPES, get_config, normalize_arch
    from repro.launch.cell import build_cell
    from repro.launch.mesh import make_production_mesh
    sys.path.insert(0, os.path.dirname(__file__))
    from repro.launch.perf import parse_value

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_value(v)
    cfg = get_config(normalize_arch(args.arch))
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    mesh = make_production_mesh()
    hlo = build_cell(cfg, SHAPES[args.shape], mesh).lower(mesh).compile().as_text()
    attribute(hlo, args.top)


if __name__ == "__main__":
    main()
